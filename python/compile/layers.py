"""Explicit-residual layers with hand-written backward passes.

Every layer is a pair of pure functions:

    *_fwd(..., tape)   -> output            (appends residuals to the tape)
    *_bwd(dout, loaded, grads, ...)         -> dinput  (reads residuals back)

The tape is a *flat, named* list of arrays — exactly what crosses the
HLO boundary between the ``fwd`` and ``bwd`` artifacts, and exactly what the
Rust coordinator holds in its ActivationStore between the two calls.  With
RMM enabled a linear layer's residual is the sketch ``X_proj = SᵀX`` plus
nothing else (S is rematerialized from the seed in ``*_bwd``); with RMM
disabled it is the full input X, reproducing the baseline's memory
behaviour (paper Table 1).

The hand-written backward (RMM off) is pinned against ``jax.grad`` of the
same forward in ``python/tests/test_model_grads.py``.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax.numpy as jnp

from . import rmm


class Tape:
    """Ordered named residual recorder (forward side)."""

    def __init__(self):
        self.items: List[Tuple[str, jnp.ndarray]] = []

    def save(self, name: str, arr):
        self.items.append((name, arr))

    def names(self):
        return [n for n, _ in self.items]

    def arrays(self):
        return [a for _, a in self.items]


class Loaded:
    """Residuals re-assembled by name (backward side)."""

    def __init__(self, names, arrays):
        assert len(names) == len(arrays), (len(names), len(arrays))
        self.d = dict(zip(names, arrays))

    def __getitem__(self, name):
        return self.d[name]

    def __contains__(self, name):
        return name in self.d


def accumulate(grads: Dict[str, jnp.ndarray], name: str, g):
    """Sum gradient contributions for shared parameters."""
    if name in grads:
        grads[name] = grads[name] + g
    else:
        grads[name] = g


# ---------------------------------------------------------------------------
# Input store: the heart of Algorithm 1.
# ---------------------------------------------------------------------------


def store_rows(tape: Tape, name: str, x2d, seed, rho: float, kind: str,
               use_kernels: bool):
    """Record the backward-pass evidence for a linear layer's input.

    ρ ≥ 1 stores X itself (baseline); ρ < 1 stores SᵀX (RMM).  One store can
    feed several linears reading the same input (e.g. Q/K/V), mirroring how
    autograd keeps a single copy of a shared activation.
    """
    rows = x2d.shape[0]
    if rho >= 1.0:
        tape.save(name, x2d)
    else:
        b_proj = rmm.b_proj_for(rows, rho)
        tape.save(name, rmm.project_rows(x2d, seed, b_proj, kind, use_kernels))


def grad_w_from_store(loaded: Loaded, name: str, dy2d, seed, rho: float,
                      kind: str, use_kernels: bool):
    """∂L/∂W from whatever the forward stored (exact or eq. 4 estimate)."""
    stored = loaded[name]
    if rho >= 1.0:
        return jnp.dot(dy2d.T, stored, preferred_element_type=jnp.float32)
    return rmm.grad_w(dy2d, stored, seed, kind, use_kernels)


# ---------------------------------------------------------------------------
# Linear (weights w: (n_out, n_in), bias b: (n_out,); x2d: (rows, n_in))
# ---------------------------------------------------------------------------


def linear_fwd(x2d, w, b, use_kernels: bool):
    return rmm.linear_matmul(x2d, w.T, use_kernels) + b[None, :]


def linear_bwd_dx(dy2d, w, use_kernels: bool):
    """∂L/∂X = ∂L/∂X̂ · W  (paper eq. 2 — always exact, no sketch)."""
    return rmm.linear_matmul(dy2d, w, use_kernels)


def linear_bwd_db(dy2d):
    """∂L/∂b = ∂L/∂X̂ᵀ·1  (paper eq. 3 — needs no stored input)."""
    return jnp.sum(dy2d, axis=0)


# ---------------------------------------------------------------------------
# LayerNorm (last axis)
# ---------------------------------------------------------------------------

LN_EPS = 1e-5


def layernorm_fwd(tape: Tape, name: str, x2d, g, b):
    mean = jnp.mean(x2d, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x2d - mean), axis=-1, keepdims=True)
    rstd = 1.0 / jnp.sqrt(var + LN_EPS)
    xhat = (x2d - mean) * rstd
    tape.save(f"{name}.xhat", xhat)
    tape.save(f"{name}.rstd", rstd)
    return xhat * g[None, :] + b[None, :]


def layernorm_bwd(loaded: Loaded, name: str, dout, g, grads, gname, bname):
    xhat = loaded[f"{name}.xhat"]
    rstd = loaded[f"{name}.rstd"]
    accumulate(grads, gname, jnp.sum(dout * xhat, axis=0))
    accumulate(grads, bname, jnp.sum(dout, axis=0))
    dxhat = dout * g[None, :]
    m1 = jnp.mean(dxhat, axis=-1, keepdims=True)
    m2 = jnp.mean(dxhat * xhat, axis=-1, keepdims=True)
    return rstd * (dxhat - m1 - xhat * m2)


# ---------------------------------------------------------------------------
# GELU (tanh approximation, as in RoBERTa/GPT)
# ---------------------------------------------------------------------------

_GELU_C = math.sqrt(2.0 / math.pi)


def gelu_fwd(tape: Tape, name: str, x2d):
    tape.save(f"{name}.x", x2d)
    inner = _GELU_C * (x2d + 0.044715 * x2d**3)
    return 0.5 * x2d * (1.0 + jnp.tanh(inner))


def gelu_bwd(loaded: Loaded, name: str, dout):
    x = loaded[f"{name}.x"]
    inner = _GELU_C * (x + 0.044715 * x**3)
    t = jnp.tanh(inner)
    dinner = _GELU_C * (1.0 + 3 * 0.044715 * x**2)
    return dout * (0.5 * (1.0 + t) + 0.5 * x * (1.0 - t**2) * dinner)


# ---------------------------------------------------------------------------
# Multi-head attention (post-LN RoBERTa block internals)
# ---------------------------------------------------------------------------


def mha_fwd(tape: Tape, name: str, x3, mask, p, prefix, seed, cfg):
    """x3: (B, T, d); mask: (B, T) in {0,1}. Returns (B, T, d).

    Residuals: one shared input store for Q/K/V (same X, same seed ⇒ one
    sketch), per-head tensors q/k/v, the attention probabilities A, and one
    input store for the output projection.
    """
    B, T, d = x3.shape
    H = cfg.n_heads
    hd = d // H
    x2 = x3.reshape(B * T, d)

    seed_qkv = rmm.derive_seed(seed, _seed_idx(prefix, 0))
    seed_o = rmm.derive_seed(seed, _seed_idx(prefix, 1))

    store_rows(tape, f"{name}.qkv_in", x2, seed_qkv, cfg.rho, cfg.sketch,
               cfg.use_kernels)

    def heads(z2):
        return z2.reshape(B, T, H, hd).transpose(0, 2, 1, 3)

    q = heads(linear_fwd(x2, p[f"{prefix}.q_w"], p[f"{prefix}.q_b"], cfg.use_kernels))
    k = heads(linear_fwd(x2, p[f"{prefix}.k_w"], p[f"{prefix}.k_b"], cfg.use_kernels))
    v = heads(linear_fwd(x2, p[f"{prefix}.v_w"], p[f"{prefix}.v_b"], cfg.use_kernels))
    tape.save(f"{name}.q", q)
    tape.save(f"{name}.k", k)
    tape.save(f"{name}.v", v)

    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.float32(math.sqrt(hd))
    neg = (1.0 - mask[:, None, None, :]) * jnp.float32(-1e9)
    a = jnp.exp(scores + neg - jnp.max(scores + neg, axis=-1, keepdims=True))
    a = a / jnp.sum(a, axis=-1, keepdims=True)
    tape.save(f"{name}.a", a)

    ctx = jnp.einsum("bhts,bhsd->bhtd", a, v)
    ctx2 = ctx.transpose(0, 2, 1, 3).reshape(B * T, d)
    store_rows(tape, f"{name}.o_in", ctx2, seed_o, cfg.rho, cfg.sketch,
               cfg.use_kernels)
    out2 = linear_fwd(ctx2, p[f"{prefix}.o_w"], p[f"{prefix}.o_b"], cfg.use_kernels)
    return out2.reshape(B, T, d)


def mha_bwd(loaded: Loaded, name: str, dout3, p, prefix, seed, cfg, grads):
    B, T, d = dout3.shape
    H = cfg.n_heads
    hd = d // H
    dout2 = dout3.reshape(B * T, d)

    seed_qkv = rmm.derive_seed(seed, _seed_idx(prefix, 0))
    seed_o = rmm.derive_seed(seed, _seed_idx(prefix, 1))

    # Output projection.
    accumulate(grads, f"{prefix}.o_w",
               grad_w_from_store(loaded, f"{name}.o_in", dout2, seed_o,
                                 cfg.rho, cfg.sketch, cfg.use_kernels))
    accumulate(grads, f"{prefix}.o_b", linear_bwd_db(dout2))
    dctx2 = linear_bwd_dx(dout2, p[f"{prefix}.o_w"], cfg.use_kernels)
    dctx = dctx2.reshape(B, T, H, hd).transpose(0, 2, 1, 3)

    a = loaded[f"{name}.a"]
    q = loaded[f"{name}.q"]
    k = loaded[f"{name}.k"]
    v = loaded[f"{name}.v"]

    da = jnp.einsum("bhtd,bhsd->bhts", dctx, v)
    dv = jnp.einsum("bhts,bhtd->bhsd", a, dctx)
    # softmax backward (the additive mask has zero gradient)
    dscores = a * (da - jnp.sum(da * a, axis=-1, keepdims=True))
    dscores = dscores / jnp.float32(math.sqrt(hd))
    dq = jnp.einsum("bhts,bhsd->bhtd", dscores, k)
    dk = jnp.einsum("bhts,bhtd->bhsd", dscores, q)

    def flat(z):
        return z.transpose(0, 2, 1, 3).reshape(B * T, d)

    dq2, dk2, dv2 = flat(dq), flat(dk), flat(dv)

    # Q/K/V share one stored input (and one sketch seed).
    for nm, dz in (("q", dq2), ("k", dk2), ("v", dv2)):
        accumulate(grads, f"{prefix}.{nm}_w",
                   grad_w_from_store(loaded, f"{name}.qkv_in", dz, seed_qkv,
                                     cfg.rho, cfg.sketch, cfg.use_kernels))
        accumulate(grads, f"{prefix}.{nm}_b", linear_bwd_db(dz))

    dx2 = (linear_bwd_dx(dq2, p[f"{prefix}.q_w"], cfg.use_kernels)
           + linear_bwd_dx(dk2, p[f"{prefix}.k_w"], cfg.use_kernels)
           + linear_bwd_dx(dv2, p[f"{prefix}.v_w"], cfg.use_kernels))
    return dx2.reshape(B, T, d)


def _seed_idx(prefix: str, slot: int) -> int:
    """Stable per-layer seed index derived from the parameter prefix."""
    h = 0
    for ch in prefix:
        h = (h * 131 + ord(ch)) & 0x7FFFFFFF
    return (h * 8 + slot) & 0x7FFFFFFF


# ---------------------------------------------------------------------------
# Feed-forward block (linear → GELU → linear)
# ---------------------------------------------------------------------------


def ffn_fwd(tape: Tape, name: str, h2, p, prefix, seed, cfg):
    seed_f1 = rmm.derive_seed(seed, _seed_idx(prefix, 2))
    seed_f2 = rmm.derive_seed(seed, _seed_idx(prefix, 3))

    store_rows(tape, f"{name}.f1_in", h2, seed_f1, cfg.rho, cfg.sketch,
               cfg.use_kernels)
    z = linear_fwd(h2, p[f"{prefix}.f1_w"], p[f"{prefix}.f1_b"], cfg.use_kernels)
    g = gelu_fwd(tape, f"{name}.gelu", z)
    store_rows(tape, f"{name}.f2_in", g, seed_f2, cfg.rho, cfg.sketch,
               cfg.use_kernels)
    return linear_fwd(g, p[f"{prefix}.f2_w"], p[f"{prefix}.f2_b"], cfg.use_kernels)


def ffn_bwd(loaded: Loaded, name: str, dout2, p, prefix, seed, cfg, grads,
            probe=None):
    seed_f1 = rmm.derive_seed(seed, _seed_idx(prefix, 2))
    seed_f2 = rmm.derive_seed(seed, _seed_idx(prefix, 3))

    accumulate(grads, f"{prefix}.f2_w",
               grad_w_from_store(loaded, f"{name}.f2_in", dout2, seed_f2,
                                 cfg.rho, cfg.sketch, cfg.use_kernels))
    accumulate(grads, f"{prefix}.f2_b", linear_bwd_db(dout2))
    dg = linear_bwd_dx(dout2, p[f"{prefix}.f2_w"], cfg.use_kernels)
    dz = gelu_bwd(loaded, f"{name}.gelu", dg)

    if probe is not None:
        # Variance probe (paper §3.3 / Fig. 4): X = full f1 input (stored
        # separately by the probe), Y = upstream gradient at the f1 output.
        probe["x"] = loaded[f"{name}.f1_probe_x"]
        probe["y"] = dz

    accumulate(grads, f"{prefix}.f1_w",
               grad_w_from_store(loaded, f"{name}.f1_in", dz, seed_f1,
                                 cfg.rho, cfg.sketch, cfg.use_kernels))
    accumulate(grads, f"{prefix}.f1_b", linear_bwd_db(dz))
    return linear_bwd_dx(dz, p[f"{prefix}.f1_w"], cfg.use_kernels)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def embed_fwd(tape: Tape, name: str, tokens, p, cfg):
    B, T = tokens.shape
    x3 = p["emb.tok"][tokens] + p["emb.pos"][None, :T, :]
    x2 = x3.reshape(B * T, cfg.d_model)
    out2 = layernorm_fwd(tape, f"{name}.ln", x2, p["emb.ln_g"], p["emb.ln_b"])
    return out2.reshape(B, T, cfg.d_model)


def embed_bwd(loaded: Loaded, name: str, dout3, tokens, p, cfg, grads):
    B, T, d = dout3.shape
    dx2 = layernorm_bwd(loaded, f"{name}.ln", dout3.reshape(B * T, d),
                        p["emb.ln_g"], grads, "emb.ln_g", "emb.ln_b")
    dx3 = dx2.reshape(B, T, d)
    dtok = jnp.zeros_like(p["emb.tok"]).at[tokens].add(dx3)
    accumulate(grads, "emb.tok", dtok)
    accumulate(grads, "emb.pos", jnp.sum(dx3, axis=0))
