"""AOT compile path: lower every model variant to HLO text + manifest.

Run once at build time (``make artifacts``); Python never appears on the
training/request path.  For each variant (a ModelConfig + entry-point list)
this emits::

    artifacts/<variant>/fwd.hlo.txt     loss/logits/residuals
    artifacts/<variant>/bwd.hlo.txt     grads (+ variance-probe scalars)
    artifacts/<variant>/eval.hlo.txt    logits only
    artifacts/init_<geom>.bin           raw-f32 initial parameters
    artifacts/manifest.json             arg/output specs for the Rust runtime

Interchange is HLO **text**, not serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids which the xla crate's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import sys
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

MANIFEST_VERSION = 2


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the only proto-safe route)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(name: str, arr, role: str) -> Dict:
    return {
        "name": name,
        "shape": list(arr.shape),
        "dtype": str(arr.dtype),
        "role": role,
    }


def example_inputs(cfg: M.ModelConfig):
    """Zero-valued example args defining shapes/dtypes for lowering."""
    tokens = jnp.zeros((cfg.batch_size, cfg.seq_len), jnp.int32)
    mask = jnp.ones((cfg.batch_size, cfg.seq_len), jnp.float32)
    labels = (jnp.zeros((cfg.batch_size,), jnp.float32) if cfg.regression
              else jnp.zeros((cfg.batch_size,), jnp.int32))
    seed = jnp.zeros((2,), jnp.uint32)
    return tokens, mask, labels, seed


def lower_entry(cfg: M.ModelConfig, entry: str):
    """Returns (hlo_text, arg_specs, out_specs) for one entry point."""
    pspec = M.param_spec(cfg)
    params = [jnp.zeros(s, jnp.float32) for _, s in pspec]
    tokens, mask, labels, seed = example_inputs(cfg)
    res_names = M.residual_names(cfg)

    if entry == "fwd":
        fn = M.make_fwd(cfg)
        args = [*params, tokens, mask, labels, seed]
        arg_specs = ([_spec(n, p, "param") for (n, _), p in zip(pspec, params)]
                     + [_spec("tokens", tokens, "tokens"),
                        _spec("mask", mask, "mask"),
                        _spec("labels", labels, "labels"),
                        _spec("seed", seed, "seed")])
        outs = jax.eval_shape(fn, *args)
        out_names = ["loss", "logits"] + res_names
        out_roles = ["metric", "logits"] + ["residual"] * len(res_names)
    elif entry == "bwd":
        fn = M.make_bwd(cfg)
        res_shapes = _residual_shapes(cfg)
        residuals = [jnp.zeros(s, d) for s, d in res_shapes]
        args = [*params, tokens, mask, labels, seed, *residuals]
        arg_specs = ([_spec(n, p, "param") for (n, _), p in zip(pspec, params)]
                     + [_spec("tokens", tokens, "tokens"),
                        _spec("mask", mask, "mask"),
                        _spec("labels", labels, "labels"),
                        _spec("seed", seed, "seed")]
                     + [_spec(n, r, "residual")
                        for n, r in zip(res_names, residuals)])
        outs = jax.eval_shape(fn, *args)
        out_names = [n for n, _ in pspec]
        out_roles = ["grad"] * len(pspec)
        if cfg.probe_layer >= 0:
            out_names += list(M.PROBE_NAMES)
            out_roles += ["probe"] * len(M.PROBE_NAMES)
    elif entry == "eval":
        fn = M.make_eval(cfg)
        args = [*params, tokens, mask]
        arg_specs = ([_spec(n, p, "param") for (n, _), p in zip(pspec, params)]
                     + [_spec("tokens", tokens, "tokens"),
                        _spec("mask", mask, "mask")])
        outs = jax.eval_shape(fn, *args)
        out_names = ["logits"]
        out_roles = ["logits"]
    else:
        raise ValueError(entry)

    out_specs = [_spec(n, o, r) for n, o, r in zip(out_names, outs, out_roles)]
    # Unused-arg pinning: ρ=1.0 graphs ignore `seed`, eval ignores labels…
    # jax's keep_unused keeps them in the MLIR signature, but the
    # mlir→XlaComputation converter drops parameters with no uses, which
    # would desynchronize the runtime's arg list from the manifest.  Fold a
    # zero-valued dependency on every argument into the first (f32) output.
    def pinned(*call_args):
        outs = fn(*call_args)
        ka = jnp.float32(0.0)
        for a in call_args:
            ka = ka + jnp.sum(jnp.ravel(a)[:1].astype(jnp.float32)) * jnp.float32(0.0)
        return (outs[0] + ka, *outs[1:])

    hlo = to_hlo_text(jax.jit(pinned, keep_unused=True).lower(*args))
    return hlo, arg_specs, out_specs


def _residual_shapes(cfg) -> List[Tuple[Tuple[int, ...], object]]:
    tokens, mask, labels, seed = example_inputs(cfg)
    params = {n: jnp.zeros(s, jnp.float32) for n, s in M.param_spec(cfg)}
    fn = M.make_fwd(cfg)
    names = [n for n, _ in M.param_spec(cfg)]
    outs = jax.eval_shape(
        fn, *[params[n] for n in names], tokens, mask, labels, seed
    )
    return [(o.shape, o.dtype) for o in outs[2:]]


# ---------------------------------------------------------------------------
# Variant sets
# ---------------------------------------------------------------------------

# The "small" geometry used across the experiment suite.  See DESIGN.md §2
# for the RoBERTa→small-encoder substitution rationale (single CPU core).
SMALL = dict(vocab_size=256, seq_len=32, batch_size=16, d_model=64,
             n_heads=4, n_layers=2, d_ff=256)
TINY = dict(vocab_size=64, seq_len=8, batch_size=4, d_model=16,
            n_heads=2, n_layers=1, d_ff=32)

HEADS = {
    "cls2": dict(n_classes=2, regression=False),
    "cls3": dict(n_classes=3, regression=False),
    "reg": dict(n_classes=1, regression=True),
}

RHO_TAG = {1.0: "r100", 0.9: "r90", 0.5: "r50", 0.2: "r20", 0.1: "r10"}


def rho_name(rho: float) -> str:
    return RHO_TAG.get(rho, f"r{int(round(rho * 100)):03d}")


def build_variants(which: str) -> Dict[str, Tuple[M.ModelConfig, List[str]]]:
    """Variant name -> (config, entry list)."""
    v: Dict[str, Tuple[M.ModelConfig, List[str]]] = {}

    def add(name, cfg_kwargs, entries):
        cfg = M.ModelConfig(**cfg_kwargs)
        cfg.validate()
        v[name] = (cfg, entries)

    if which == "quick":
        add("tiny_cls2_r100_gauss", dict(**TINY, **HEADS["cls2"], rho=1.0),
            ["fwd", "bwd", "eval"])
        add("tiny_cls2_r50_gauss", dict(**TINY, **HEADS["cls2"], rho=0.5),
            ["fwd", "bwd", "eval"])
        add("tinyk_cls2_r50_gauss",
            dict(**TINY, **HEADS["cls2"], rho=0.5, use_kernels=True),
            ["fwd", "bwd"])
        return v

    # 1. Table 2 / Fig 5 / Fig 6: gauss sweep over ρ for each head type.
    for head, hk in HEADS.items():
        for rho in (1.0, 0.9, 0.5, 0.2, 0.1):
            add(f"small_{head}_{rho_name(rho)}_gauss",
                dict(**SMALL, **hk, rho=rho, sketch="gauss"),
                ["fwd", "bwd", "eval"])

    # 2. Table 4: sketch-family comparison on the CoLA-like (cls2) task.
    for kind in ("rademacher", "dct", "dft", "rowsample"):
        for rho in (0.5, 0.2, 0.1):
            add(f"small_cls2_{rho_name(rho)}_{kind}",
                dict(**SMALL, **HEADS["cls2"], rho=rho, sketch=kind),
                ["fwd", "bwd", "eval"])

    # 3. Fig 4/7: variance probe (block 1 FFN, ρ=0.5, gauss).
    add("probe_cls2_r50_gauss",
        dict(**SMALL, **HEADS["cls2"], rho=0.5, sketch="gauss", probe_layer=1),
        ["fwd", "bwd"])

    # 4. Table 3 / Fig 3 / Fig 8: batch-size sweep (B=16 reuses set 1).
    for bsz in (8, 32, 64):
        for rho in (1.0, 0.5, 0.2, 0.1):
            add(f"small_cls2_b{bsz}_{rho_name(rho)}_gauss",
                dict(**{**SMALL, "batch_size": bsz}, **HEADS["cls2"],
                     rho=rho, sketch="gauss"),
                ["fwd", "bwd"])

    # 5. Kernel-path validation: full Pallas pipeline through PJRT (tiny —
    #    interpret-mode lowering is bulky, so keep the geometry minimal).
    add("tinyk_cls2_r50_gauss",
        dict(**TINY, **HEADS["cls2"], rho=0.5, sketch="gauss",
             use_kernels=True),
        ["fwd", "bwd"])
    add("tiny_cls2_r50_gauss",
        dict(**TINY, **HEADS["cls2"], rho=0.5, sketch="gauss"),
        ["fwd", "bwd", "eval"])
    add("tiny_cls2_r100_gauss",
        dict(**TINY, **HEADS["cls2"], rho=1.0, sketch="gauss"),
        ["fwd", "bwd", "eval"])
    return v


# ---------------------------------------------------------------------------
# Init params
# ---------------------------------------------------------------------------


def geometry_key(cfg: M.ModelConfig) -> str:
    """Geometry hash — variants sharing it share initial parameters."""
    geom = (cfg.vocab_size, cfg.seq_len, cfg.d_model, cfg.n_heads,
            cfg.n_layers, cfg.d_ff, cfg.n_classes, cfg.regression)
    return hashlib.sha1(repr(geom).encode()).hexdigest()[:10]


def write_init(cfg: M.ModelConfig, out_dir: str, seed: int = 0) -> str:
    key = geometry_key(cfg)
    fname = f"init_{key}.bin"
    path = os.path.join(out_dir, fname)
    if not os.path.exists(path):
        params = M.init_params(cfg, seed)
        with open(path, "wb") as f:
            for name, _ in M.param_spec(cfg):
                f.write(np.ascontiguousarray(params[name]).tobytes())
    return fname


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--set", dest="which", default="default",
                    choices=["default", "quick"])
    ap.add_argument("--force", action="store_true",
                    help="rebuild even if the manifest is up to date")
    args = ap.parse_args(argv)

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")

    variants = build_variants(args.which)
    stamp = {"version": MANIFEST_VERSION, "set": args.which,
             "variants": sorted(variants)}
    if not args.force and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            if (old.get("version") == MANIFEST_VERSION
                    and old.get("set") == args.which
                    and sorted(old.get("variants", {})) == stamp["variants"]):
                print(f"manifest up to date ({len(variants)} variants); "
                      "use --force to rebuild")
                return 0
        except (json.JSONDecodeError, OSError):
            pass

    manifest = {"version": MANIFEST_VERSION, "set": args.which,
                "variants": {}}
    t_all = time.time()
    for name, (cfg, entries) in sorted(variants.items()):
        vdir = os.path.join(out_dir, name)
        os.makedirs(vdir, exist_ok=True)
        ventry = {}
        for entry in entries:
            t0 = time.time()
            hlo, arg_specs, out_specs = lower_entry(cfg, entry)
            rel = f"{name}/{entry}.hlo.txt"
            with open(os.path.join(out_dir, rel), "w") as f:
                f.write(hlo)
            ventry[entry] = {"file": rel, "args": arg_specs,
                             "outputs": out_specs}
            print(f"  {rel:46s} {len(hlo)/1e6:6.2f} MB  "
                  f"{time.time()-t0:5.1f}s", flush=True)
        init_file = write_init(cfg, out_dir)
        manifest["variants"][name] = {
            "config": dataclasses.asdict(cfg),
            "rows": cfg.rows,
            "b_proj": cfg.b_proj,
            "init_params": init_file,
            "param_count": int(sum(
                int(np.prod(s)) for _, s in M.param_spec(cfg))),
            "entries": ventry,
        }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {manifest_path}: {len(variants)} variants "
          f"in {time.time()-t_all:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
