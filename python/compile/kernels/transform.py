"""Structured-sketch Pallas kernel: SORS projection with DCT-II / real-DFT.

X_proj = Sᵀ X with S = sqrt(B/B_proj) · D Hᵀ R (paper §3.5):
  D — diagonal of random signs (Philox stream SIGNS),
  H — orthonormal transform with *closed-form entries* (DCT-II or real DFT),
  R — uniform row selection with replacement (Philox stream ROWSEL).

Hardware adaptation (DESIGN.md §3): on GPU the fast transform is a butterfly
network over warp shuffles; that idiom has no TPU equivalent.  Instead the
transform is expressed as a structured matmul whose tiles are *generated
from the closed-form entry formula in VMEM* — same O(1) memory for S, and
the contraction runs on the MXU.  The asymptotic O(B log B) fast path is
exercised by the Rust radix-2 FFT substrate (``rust/src/rmm/fft.rs``) and
its crossover bench.

The selected row indices (B_proj ints) are generated *inside* the kernel
from the seed, so — like the dense sketches — nothing but the seed crosses
the forward/backward boundary.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import prng, tiling


def _dct_tile(sel, pos, b):
    """H[sel, pos] tile for orthonormal DCT-II of order b."""
    kf = sel.astype(jnp.float32)
    i_f = pos.astype(jnp.float32)
    scale = jnp.where(sel == 0, jnp.float32(1.0 / math.sqrt(2.0)), jnp.float32(1.0))
    return (
        scale
        * jnp.float32(math.sqrt(2.0 / b))
        * jnp.cos(jnp.float32(math.pi) * (2.0 * i_f + 1.0) * kf / jnp.float32(2.0 * b))
    )


def _dft_tile(sel, pos, b):
    """H[sel, pos] tile for the orthonormal real DFT of order b."""
    kf = sel.astype(jnp.float32)
    i_f = pos.astype(jnp.float32)
    m = jnp.floor((kf + 1.0) / 2.0)
    ang = jnp.float32(2.0 * math.pi) * m * i_f / jnp.float32(b)
    is_cos = (sel % 2) == 1
    base = jnp.where(is_cos, jnp.cos(ang), jnp.sin(ang)) * jnp.float32(
        math.sqrt(2.0 / b)
    )
    dc = jnp.float32(1.0 / math.sqrt(b)) * jnp.ones_like(base)
    nyq = jnp.where((pos % 2) == 0, jnp.float32(1.0), jnp.float32(-1.0)) * jnp.float32(
        1.0 / math.sqrt(b)
    )
    out = jnp.where(sel == 0, dc, base)
    if b % 2 == 0:
        out = jnp.where(sel == b - 1, nyq, out)
    return out


def _sors_kernel(seed_ref, x_ref, o_ref, *, tile_b, tile_bp, b, b_proj, kind):
    i = pl.program_id(0)  # B_proj tile
    k = pl.program_id(2)  # B tile (reduction)
    seed_lo = seed_ref[0]
    seed_hi = seed_ref[1]

    # Selected frequency indices for this output tile, regenerated from the
    # seed (stream ROWSEL): sel[j] = uniform_int(0, j_logical; b).
    j_log = (i * tile_bp + jax.lax.iota(jnp.int32, tile_bp)).astype(jnp.uint32)
    sel = prng.element_uniform_int(jnp.uint32(0), j_log, seed_lo, seed_hi, b)

    # Input positions covered by this reduction tile + their random signs
    # (stream SIGNS).
    pos = (k * tile_b + jax.lax.iota(jnp.int32, tile_b)).astype(jnp.int32)
    signs = prng.element_rademacher(
        jnp.uint32(0), pos.astype(jnp.uint32), seed_lo, seed_hi, prng.STREAM_SIGNS
    )

    sel2 = sel[:, None]  # (tile_bp, 1)
    pos2 = pos[None, :]  # (1, tile_b)
    if kind == "dct":
        h = _dct_tile(sel2, pos2, b)
    elif kind == "dft":
        h = _dft_tile(sel2, pos2, b)
    else:
        raise ValueError(f"unknown transform {kind!r}")

    # Sᵀ tile = sqrt(b/b_proj) · H[sel, pos] · sign(pos); padded X rows are
    # zero so out-of-range positions contribute nothing, and padded output
    # rows are sliced off by the wrapper.
    st = h * signs[None, :] * jnp.float32(math.sqrt(b / b_proj))

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(st, x_ref[...], preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("b_proj", "kind", "tile_b", "tile_bp", "tile_n")
)
def sors_project(
    x, seed, b_proj, kind="dct", *, tile_b=None, tile_bp=None, tile_n=None
):
    """X_proj = Sᵀ X for the SORS sketch; matches ``ref.project(..., kind)``."""
    b, n = x.shape
    tb = tile_b or tiling.pick_tile(b)
    tbp = tile_bp or tiling.pick_tile(b_proj)
    tn = tile_n or tiling.pick_tile(n)

    x_p = tiling.pad_to(tiling.pad_to(x, 0, tb), 1, tn)
    bp_pad = ((b_proj + tbp - 1) // tbp) * tbp
    grid = (
        bp_pad // tbp,
        tiling.grid_dim(x_p.shape[1], tn),
        tiling.grid_dim(x_p.shape[0], tb),
    )
    kernel = functools.partial(
        _sors_kernel, tile_b=tb, tile_bp=tbp, b=b, b_proj=b_proj, kind=kind
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i, j, k: (0,)),
            pl.BlockSpec((tb, tn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tbp, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp_pad, x_p.shape[1]), jnp.float32),
        interpret=True,
    )(jnp.asarray(seed, jnp.uint32), x_p)
    return out[:b_proj, :n]
