"""Fused seeded-projection Pallas kernel: X_proj = Sᵀ X with S generated
on the fly.

This is the paper's Algorithm 1 made literal at the kernel level: the
sketching matrix S ∈ R^{B×B_proj} is *never materialized in HBM*.  Each
grid step generates one (tile_b × tile_bp) tile of S inside VMEM from the
Philox counter PRNG keyed by (seed, logical_row, logical_col) and
immediately contracts it against the matching X tile.  The backward pass
calls the same kernel with the same seed on Y = ∂L/∂X̂, reproducing S
bit-identically — the "random state" the paper stores is our two 32-bit
seed words.

VMEM per grid step at default 128-tiles: S tile (64 KiB) + X tile (64 KiB)
+ f32 accumulator (64 KiB) = 192 KiB.  The S-tile generation is ~40 integer
VPU ops/element (10 Philox rounds) fused ahead of an MXU contraction — on
real TPU this pipelines with the dot; in interpret mode it lowers to plain
HLO (the only mode CPU PJRT can run — see DESIGN.md §3).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import prng, tiling


def _sketch_tile(rows, cols, seed_lo, seed_hi, b_proj, kind):
    """One (tile_b, tile_bp) tile of S from logical element indices."""
    if kind == "gauss":
        z = prng.element_normal(rows, cols, seed_lo, seed_hi)
    elif kind == "rademacher":
        z = prng.element_rademacher(rows, cols, seed_lo, seed_hi)
    else:
        raise ValueError(f"dense sketch kind {kind!r} not supported here")
    return z * jnp.float32(1.0 / math.sqrt(b_proj))


def _project_kernel(seed_ref, x_ref, o_ref, *, tile_b, tile_bp, b_proj, kind):
    i = pl.program_id(0)  # B_proj tile index
    k = pl.program_id(2)  # B tile index (reduction axis)

    rows = (k * tile_b + jax.lax.broadcasted_iota(jnp.int32, (tile_b, tile_bp), 0)).astype(
        jnp.uint32
    )
    cols = (i * tile_bp + jax.lax.broadcasted_iota(jnp.int32, (tile_b, tile_bp), 1)).astype(
        jnp.uint32
    )
    s = _sketch_tile(rows, cols, seed_ref[0], seed_ref[1], b_proj, kind)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (tile_bp, tile_b) @ (tile_b, tile_n) — padded X rows are zero, so
    # sketch values generated for out-of-range rows contribute nothing.
    o_ref[...] += jnp.dot(s.T, x_ref[...], preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("b_proj", "kind", "tile_b", "tile_bp", "tile_n"),
)
def project(x, seed, b_proj, kind="gauss", *, tile_b=None, tile_bp=None, tile_n=None):
    """X_proj = Sᵀ X for X:(B, N) → (b_proj, N), S rematerialized from seed.

    ``seed`` is a (2,) uint32 array (lo, hi).  Matches
    ``ref.project(x, lo, hi, b_proj, kind)`` exactly for gauss/rademacher.
    """
    b, n = x.shape
    tb = tile_b or tiling.pick_tile(b)
    tbp = tile_bp or tiling.pick_tile(b_proj)
    tn = tile_n or tiling.pick_tile(n)

    x_p = tiling.pad_to(tiling.pad_to(x, 0, tb), 1, tn)
    bp_pad = ((b_proj + tbp - 1) // tbp) * tbp
    grid = (
        bp_pad // tbp,
        tiling.grid_dim(x_p.shape[1], tn),
        tiling.grid_dim(x_p.shape[0], tb),
    )
    kernel = functools.partial(
        _project_kernel, tile_b=tb, tile_bp=tbp, b_proj=b_proj, kind=kind
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i, j, k: (0,)),
            pl.BlockSpec((tb, tn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tbp, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp_pad, x_p.shape[1]), jnp.float32),
        interpret=True,
    )(jnp.asarray(seed, jnp.uint32), x_p)
    return out[:b_proj, :n]


@functools.partial(jax.jit, static_argnames=("kind",))
def rmm_grad_w(y, x_proj, seed, kind="gauss"):
    """∂L/∂W ≈ (Sᵀ Y)ᵀ X_proj (paper eq. 4), fully kernel-backed.

    Reuses the fused projection (identical seed ⇒ identical S) followed by
    the tiled matmul kernel.
    """
    from . import matmul as mm

    b_proj = x_proj.shape[0]
    y_proj = project(y, seed, b_proj, kind)  # (B_proj, N_out)
    return mm.matmul(y_proj.T, x_proj)  # (N_out, N_in)
