"""Pure-jnp oracles for every Pallas kernel and sketch family.

These are the CORE correctness signal: each kernel in this package must
match its oracle here to float32 tolerance (pytest + hypothesis sweeps),
and the L2 model's randomized backward is defined in terms of these
semantics.  Everything is a deterministic function of (seed, shapes), so
the oracle, the kernel, and the Rust reference implementation
(``rust/src/rmm/``) can be cross-checked bit-for-bit at the PRNG level and
to ~1e-5 at the float level.

Sketch families (all satisfy E[S Sᵀ] = I_B for S ∈ R^{B×B_proj}):

* ``gauss``       — S = P / sqrt(B_proj), P_ij ~ N(0, 1) iid (paper eq. 5)
* ``rademacher``  — S = R / sqrt(B_proj), R_ij ~ ±1 iid
* ``dct`` / ``dft`` — SORS-style: S = sqrt(B/B_proj) · D Hᵀ R with H an
  orthonormal transform (DCT-II or real DFT), D random signs, R a uniform
  column-sampling matrix (paper §3.5, Iwen et al. 2021)
* ``rowsample``   — S = sqrt(B/B_proj) · R, uniform row sampling with
  replacement (the memory-compatible cousin of Adelman et al. 2021's
  norm-based sampling, which needs ‖y_k‖ and hence cannot precompute SᵀX)
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from . import prng

SKETCH_KINDS = ("gauss", "rademacher", "dct", "dft", "rowsample")


# ---------------------------------------------------------------------------
# Dense sketch entries (gauss / rademacher)
# ---------------------------------------------------------------------------


def gauss_sketch(b, b_proj, seed_lo, seed_hi):
    """S[i, j] = N(0,1)(seed, i, j) / sqrt(b_proj), shape (b, b_proj)."""
    i = jnp.arange(b, dtype=jnp.uint32)[:, None]
    j = jnp.arange(b_proj, dtype=jnp.uint32)[None, :]
    z = prng.element_normal(i, j, seed_lo, seed_hi)
    return z / jnp.float32(math.sqrt(b_proj))


def rademacher_sketch(b, b_proj, seed_lo, seed_hi):
    """S[i, j] = ±1 / sqrt(b_proj), shape (b, b_proj)."""
    i = jnp.arange(b, dtype=jnp.uint32)[:, None]
    j = jnp.arange(b_proj, dtype=jnp.uint32)[None, :]
    z = prng.element_rademacher(i, j, seed_lo, seed_hi)
    return z / jnp.float32(math.sqrt(b_proj))


# ---------------------------------------------------------------------------
# Structured transforms (orthonormal, defined by closed-form entries so a
# kernel can generate any tile without materializing the full matrix)
# ---------------------------------------------------------------------------


def dct_entry(k, i, b):
    """Orthonormal DCT-II matrix entry H[k, i] for an order-b transform."""
    kf = jnp.asarray(k, jnp.float32)
    i_f = jnp.asarray(i, jnp.float32)
    bf = jnp.float32(b)
    scale = jnp.where(
        jnp.asarray(k) == 0, jnp.float32(1.0 / math.sqrt(2.0)), jnp.float32(1.0)
    )
    return (
        scale
        * jnp.float32(math.sqrt(2.0 / b))
        * jnp.cos(jnp.float32(math.pi) * (2.0 * i_f + 1.0) * kf / (2.0 * bf))
    )


def dft_entry(k, i, b):
    """Orthonormal *real* DFT matrix entry H[k, i] for an order-b transform.

    Row layout (b even): row 0 = 1/sqrt(b); odd rows k=2m−1 are cosine rows
    with frequency m; even rows k=2m are sine rows with frequency m; the
    last row (k=b−1, b even) is the Nyquist row (−1)^i / sqrt(b).
    """
    k = jnp.asarray(k)
    i = jnp.asarray(i)
    kf = k.astype(jnp.float32)
    i_f = i.astype(jnp.float32)
    bf = jnp.float32(b)
    m = jnp.floor((kf + 1.0) / 2.0)
    ang = jnp.float32(2.0 * math.pi) * m * i_f / bf
    is_cos = (k % 2) == 1
    base = jnp.where(is_cos, jnp.cos(ang), jnp.sin(ang)) * jnp.float32(
        math.sqrt(2.0 / b)
    )
    dc = jnp.float32(1.0 / math.sqrt(b)) * jnp.ones_like(base)
    nyq = jnp.where((i % 2) == 0, jnp.float32(1.0), jnp.float32(-1.0)) * jnp.float32(
        1.0 / math.sqrt(b)
    )
    out = jnp.where(k == 0, dc, base)
    if b % 2 == 0:
        out = jnp.where(k == b - 1, nyq, out)
    return out


def transform_matrix(kind, b):
    """Full b×b orthonormal transform matrix H (oracle only)."""
    k = jnp.arange(b, dtype=jnp.int32)[:, None]
    i = jnp.arange(b, dtype=jnp.int32)[None, :]
    if kind == "dct":
        return dct_entry(k, i, b)
    if kind == "dft":
        return dft_entry(k, i, b)
    raise ValueError(f"unknown transform {kind!r}")


def row_selection(b, b_proj, seed_lo, seed_hi):
    """b_proj uniform row indices in [0, b), with replacement."""
    j = jnp.arange(b_proj, dtype=jnp.uint32)
    return prng.element_uniform_int(jnp.uint32(0), j, seed_lo, seed_hi, b)


def sign_flips(b, seed_lo, seed_hi):
    """Random ±1 per input position (the D matrix of SORS)."""
    i = jnp.arange(b, dtype=jnp.uint32)
    return prng.element_rademacher(
        jnp.uint32(0), i, seed_lo, seed_hi, prng.STREAM_SIGNS
    )


def sors_sketch(kind, b, b_proj, seed_lo, seed_hi):
    """S = sqrt(b/b_proj) · D Hᵀ R as a dense (b, b_proj) matrix (oracle)."""
    h = transform_matrix(kind, b)  # (b, b)
    sel = row_selection(b, b_proj, seed_lo, seed_hi)  # (b_proj,)
    d = sign_flips(b, seed_lo, seed_hi)  # (b,)
    # Column j of S is sqrt(b/b_proj) · D · H[sel_j, :]ᵀ
    s = h[sel, :].T * d[:, None]
    return s * jnp.float32(math.sqrt(b / b_proj))


def rowsample_sketch(b, b_proj, seed_lo, seed_hi):
    """S = sqrt(b/b_proj) · R: column j is e_{sel_j} (uniform, replacement)."""
    sel = row_selection(b, b_proj, seed_lo, seed_hi)
    s = jnp.zeros((b, b_proj), jnp.float32).at[sel, jnp.arange(b_proj)].set(1.0)
    return s * jnp.float32(math.sqrt(b / b_proj))


def sketch(kind, b, b_proj, seed_lo, seed_hi):
    """Dense sketch matrix S ∈ R^{b×b_proj} (oracle for all kernel paths)."""
    if kind == "gauss":
        return gauss_sketch(b, b_proj, seed_lo, seed_hi)
    if kind == "rademacher":
        return rademacher_sketch(b, b_proj, seed_lo, seed_hi)
    if kind in ("dct", "dft"):
        return sors_sketch(kind, b, b_proj, seed_lo, seed_hi)
    if kind == "rowsample":
        return rowsample_sketch(b, b_proj, seed_lo, seed_hi)
    raise ValueError(f"unknown sketch kind {kind!r}")


# ---------------------------------------------------------------------------
# Oracles for the kernels
# ---------------------------------------------------------------------------


def matmul(a, b):
    """Plain f32 matmul oracle."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def project(x, seed_lo, seed_hi, b_proj, kind="gauss"):
    """X_proj = Sᵀ X — what the forward pass stores instead of X."""
    b = x.shape[0]
    s = sketch(kind, b, b_proj, seed_lo, seed_hi)
    return jnp.dot(s.T, x, preferred_element_type=jnp.float32)


def rmm_grad_w(y, x_proj, seed_lo, seed_hi, kind="gauss"):
    """∂L/∂W estimate = (Yᵀ S) X_proj = (Sᵀ Y)ᵀ X_proj  (paper eq. 4).

    y: (B, N_out) upstream gradient; x_proj: (B_proj, N_in) stored sketch.
    Returns (N_out, N_in).
    """
    b_proj = x_proj.shape[0]
    y_proj = project(y, seed_lo, seed_hi, b_proj, kind)  # (B_proj, N_out)
    return jnp.dot(y_proj.T, x_proj, preferred_element_type=jnp.float32)


def exact_grad_w(y, x):
    """Exact ∂L/∂W = Yᵀ X (the no-RMM baseline, paper eq. 3)."""
    return jnp.dot(y.T, x, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Variance estimators (paper eqs. 9, 11, 13) — also mirrored in rust/rmm
# ---------------------------------------------------------------------------


def d2_sgd(x, y):
    """Lemma 2.1: aposteriori SGD variance estimate (eq. 9)."""
    b = x.shape[0]
    row = jnp.sum(x * x, axis=1) * jnp.sum(y * y, axis=1)
    xty = jnp.dot(x.T, y, preferred_element_type=jnp.float32)
    fro2 = jnp.sum(xty * xty)
    return (b / (b - 1.0)) * jnp.sum(row) - fro2 / (b - 1.0)


def d2_rmm(x, y, b_proj):
    """Lemma 2.2: apriori RMM variance — *as stated in the paper* (eq. 11).

    Soundness note (see EXPERIMENTS.md §Discrepancies): the paper's proof
    drops the Gaussian fourth-moment excess in eq. (36); the exact variance
    is :func:`d2_rmm_exact` (same expression with +‖XᵀY‖² instead of −).
    The two agree to O(α) and α ≪ 1 throughout training, so the paper's
    empirical figures are unaffected; we keep this form to reproduce
    Fig. 4/7 and pin the exact form against Monte-Carlo in the tests.
    """
    xf2 = jnp.sum(x * x)
    yf2 = jnp.sum(y * y)
    xty = jnp.dot(x.T, y, preferred_element_type=jnp.float32)
    fro2 = jnp.sum(xty * xty)
    return (xf2 * yf2 - fro2) / b_proj


def d2_rmm_exact(x, y, b_proj):
    """Exact Gaussian-sketch variance: (‖X‖²‖Y‖² + ‖XᵀY‖²)/B_proj."""
    xf2 = jnp.sum(x * x)
    yf2 = jnp.sum(y * y)
    xty = jnp.dot(x.T, y, preferred_element_type=jnp.float32)
    fro2 = jnp.sum(xty * xty)
    return (xf2 * yf2 + fro2) / b_proj


def alpha(x, y):
    """Correlation ratio α = ‖XᵀY‖²_F / (‖X‖²_F ‖Y‖²_F)  (eq. 13)."""
    xty = jnp.dot(x.T, y, preferred_element_type=jnp.float32)
    num = jnp.sum(xty * xty)
    den = jnp.sum(x * x) * jnp.sum(y * y)
    return num / jnp.maximum(den, jnp.float32(1e-30))


def variance_ratio_lhs(x, y, b_proj):
    """LHS of Theorem 2.3 inequality (eq. 12)."""
    b = x.shape[0]
    return (b_proj / (b - 1.0)) * d2_rmm(x, y, b_proj) / jnp.maximum(
        d2_sgd(x, y), jnp.float32(1e-30)
    )


def numpy_sketch(kind, b, b_proj, seed):
    """Convenience: dense sketch as numpy (used by Monte-Carlo tests)."""
    lo, hi = prng.split_seed(seed)
    return np.asarray(sketch(kind, b, b_proj, lo, hi))
