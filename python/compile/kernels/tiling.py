"""Shared tiling helpers for the Pallas kernels.

TPU-oriented block policy: the MXU consumes 128×128 tiles, so blocks default
to 128 on every axis and shrink (to the next multiple of 8, floor 8) when
the logical dimension is smaller.  Inputs are zero-padded up to the block
grid; outputs are sliced back to logical shape.  Zero padding is safe for
every kernel here because (a) matmul/projection contributions from padded
rows are exactly zero and (b) sketch-entry generation is keyed on *logical*
(row, col) indices, so padding never shifts the random stream.
"""

from __future__ import annotations

import jax.numpy as jnp

MXU_TILE = 128
MIN_TILE = 8


def pick_tile(dim: int, preferred: int = MXU_TILE) -> int:
    """Largest "nice" tile ≤ preferred that keeps padding small."""
    if dim >= preferred:
        return preferred
    # round dim up to a multiple of MIN_TILE
    return max(MIN_TILE, ((dim + MIN_TILE - 1) // MIN_TILE) * MIN_TILE)


def pad_to(x, axis: int, multiple: int):
    """Zero-pad ``x`` along ``axis`` to the next multiple of ``multiple``."""
    dim = x.shape[axis]
    rem = (-dim) % multiple
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths)


def grid_dim(dim: int, tile: int) -> int:
    return (dim + tile - 1) // tile
