"""Tiled Pallas matmul — the MXU-shaped baseline contraction.

Used for the linear-layer forward (X Wᵀ) and the second half of the RMM
backward ((Sᵀ Y)ᵀ · X_proj).  Grid is (M-tiles, N-tiles, K-tiles) with the
K axis innermost so each output block stays resident in VMEM across the
whole accumulation (one (tm, tn) f32 accumulator + one (tm, tk) and one
(tk, tn) operand tile ⇒ VMEM footprint 3·128·128·4 B = 192 KiB at default
tiles, well under a TPU core's ~16 MiB VMEM with headroom for
double-buffering).

Always lowered with ``interpret=True``: CPU PJRT cannot execute Mosaic
custom-calls, and interpret mode lowers the kernel to plain HLO (see
DESIGN.md §3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling


def _matmul_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "tile_k"))
def matmul(a, b, *, tile_m=None, tile_n=None, tile_k=None):
    """C = A @ B for f32 A:(M,K), B:(K,N) via a tiled Pallas kernel."""
    m, ka = a.shape
    kb, n = b.shape
    assert ka == kb, f"inner dims mismatch: {a.shape} @ {b.shape}"
    tm = tile_m or tiling.pick_tile(m)
    tn = tile_n or tiling.pick_tile(n)
    tk = tile_k or tiling.pick_tile(ka)

    a_p = tiling.pad_to(tiling.pad_to(a, 0, tm), 1, tk)
    b_p = tiling.pad_to(tiling.pad_to(b, 0, tk), 1, tn)
    grid = (
        tiling.grid_dim(a_p.shape[0], tm),
        tiling.grid_dim(b_p.shape[1], tn),
        tiling.grid_dim(a_p.shape[1], tk),
    )
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a_p.shape[0], b_p.shape[1]), jnp.float32),
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n]
