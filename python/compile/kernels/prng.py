"""Counter-based PRNG (Philox4x32-10) in pure jnp integer ops.

This is the "PRNG state" of the paper made concrete: the sketching matrix S
is never stored — every element S[i, j] is a pure function of
(seed, i, j, stream), so S can be rematerialized tile-by-tile inside a
Pallas kernel (forward pass) and again in the backward pass, bit-identically,
with O(1) state (the two 32-bit seed words).

Implemented with 16-bit-split multiplies so it works under JAX's default
32-bit mode (no uint64), and therefore also inside Pallas kernel bodies in
interpret mode.  The same algorithm is mirrored in ``rust/src/rng/philox.rs``
and pinned by the Random123 reference test vectors on both sides.
"""

from __future__ import annotations

import jax.numpy as jnp

# Philox4x32 round constants (Salmon et al., "Parallel Random Numbers: As
# Easy as 1, 2, 3", SC'11).
PHILOX_M0 = 0xD2511F53
PHILOX_M1 = 0xCD9E8D57
PHILOX_W0 = 0x9E3779B9
PHILOX_W1 = 0xBB67AE85

# Stream tags: disjoint Philox streams per use so sketches, row selections
# and sign flips never collide even under the same seed.
STREAM_SKETCH = 0  # dense sketch entries (gauss / rademacher)
STREAM_ROWSEL = 1  # SORS / row-sample row selection
STREAM_SIGNS = 2  # SORS random sign flips
STREAM_DATA = 3  # reserved (host-side data generation uses rust philox)


def _u32(x):
    return jnp.asarray(x, jnp.uint32)


def mulhilo32(a, b):
    """(hi, lo) 32-bit halves of the 64-bit product a*b, using u32 ops only.

    JAX runs in 32-bit mode by default (no uint64), so the 64-bit product is
    assembled from 16-bit limbs.  All intermediate products of 16-bit limbs
    fit in uint32; carries are recovered from wrap-around comparisons.
    """
    a = _u32(a)
    b = _u32(b)
    a_lo = a & 0xFFFF
    a_hi = a >> 16
    b_lo = b & 0xFFFF
    b_hi = b >> 16

    t = a_lo * b_lo
    m1 = a_hi * b_lo
    m2 = a_lo * b_hi
    mid = m1 + m2
    carry_mid = (mid < m1).astype(jnp.uint32)  # wrapped?

    lo = t + (mid << 16)
    carry_lo = (lo < t).astype(jnp.uint32)

    hi = a_hi * b_hi + (mid >> 16) + (carry_mid << 16) + carry_lo
    return hi, lo


def philox4x32(c0, c1, c2, c3, k0, k1, rounds: int = 10):
    """Philox4x32 block cipher: counter (c0..c3), key (k0, k1) -> 4 u32.

    All arguments broadcast elementwise, so this evaluates a whole tile of
    counters in one call (vectorized over arbitrary shapes).
    """
    c0, c1, c2, c3 = _u32(c0), _u32(c1), _u32(c2), _u32(c3)
    k0, k1 = _u32(k0), _u32(k1)
    m0 = _u32(PHILOX_M0)
    m1 = _u32(PHILOX_M1)
    w0 = _u32(PHILOX_W0)
    w1 = _u32(PHILOX_W1)
    for r in range(rounds):
        hi0, lo0 = mulhilo32(m0, c0)
        hi1, lo1 = mulhilo32(m1, c2)
        c0, c1, c2, c3 = hi1 ^ c1 ^ k0, lo1, hi0 ^ c3 ^ k1, lo0
        if r != rounds - 1:
            k0 = k0 + w0
            k1 = k1 + w1
    return c0, c1, c2, c3


def uniform01(bits):
    """u32 -> f32 uniform in the open interval (0, 1).

    Uses the top 24 bits plus a half-ulp offset so the result is never 0
    (safe for log in Box-Muller) and never 1.
    """
    bits = _u32(bits)
    return ((bits >> 8).astype(jnp.float32) + 0.5) * jnp.float32(1.0 / (1 << 24))


def normal_pair(a, b):
    """Box-Muller: two u32 words -> two standard normals (f32)."""
    u1 = uniform01(a)
    u2 = uniform01(b)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    theta = jnp.float32(2.0 * 3.14159265358979323846) * u2
    return r * jnp.cos(theta), r * jnp.sin(theta)


def element_normal(i, j, seed_lo, seed_hi, stream=STREAM_SKETCH):
    """Standard-normal draw for logical element (i, j) of a sketch matrix.

    The counter encodes (i, j, stream); the key is the 64-bit seed.  This is
    position-stable: padding a tile or evaluating elements in any order and
    grouping yields identical values.

    §Perf note: a pair-mapped variant (one Philox block feeding the
    (even, odd) column pair via `where`-selects) was tried and reverted —
    it cut the host path 16% but slowed the *lowered graph* 54% because the
    elementwise formulation still evaluates a full block per element and
    adds the selects (EXPERIMENTS.md §Perf iteration 1).
    """
    c0, c1, c2, c3 = philox4x32(i, j, _u32(stream), _u32(0), seed_lo, seed_hi)
    z0, _ = normal_pair(c0, c1)
    return z0


def element_rademacher(i, j, seed_lo, seed_hi, stream=STREAM_SKETCH):
    """±1 draw for logical element (i, j)."""
    c0, _, _, _ = philox4x32(i, j, _u32(stream), _u32(0), seed_lo, seed_hi)
    return jnp.where((c0 & 1) == 1, jnp.float32(1.0), jnp.float32(-1.0))


def element_uniform_int(i, j, seed_lo, seed_hi, bound, stream=STREAM_ROWSEL):
    """Uniform int in [0, bound) for logical element (i, j).

    Uses the multiply-shift trick (bits * bound) >> 32 via mulhilo32 so no
    modulo bias larger than bound/2^32 is introduced.
    """
    c0, _, _, _ = philox4x32(i, j, _u32(stream), _u32(0), seed_lo, seed_hi)
    hi, _ = mulhilo32(c0, _u32(bound))
    return hi.astype(jnp.int32)


def split_seed(seed):
    """Split a python/int64-ish seed into (lo, hi) u32 words."""
    seed = int(seed) & 0xFFFFFFFFFFFFFFFF
    return seed & 0xFFFFFFFF, (seed >> 32) & 0xFFFFFFFF
