"""L2: RoBERTa-style encoder with explicit residuals and hand-written bwd.

Two entry points are lowered to HLO artifacts (plus one for eval):

    fwd(params…, tokens, mask, labels, seed) -> (loss, logits, residuals…)
    bwd(params…, tokens, mask, labels, seed, residuals…) -> (grads…[, probe…])
    eval(params…, tokens, mask)              -> (logits,)

The split at exactly the forward/backward boundary is deliberate: the Rust
coordinator holds the residual buffers between the two calls, which makes
the paper's memory claim a *measured* quantity (bytes of live PJRT
literals), not a model.  See DESIGN.md §1.

Architecture (post-LN, as RoBERTa): embeddings(+LN) → n_layers ×
[MHA → add&LN → FFN → add&LN] → CLS pooler(tanh) → classifier.  All
block-internal linear layers (Q/K/V/O, FFN1/FFN2) route through the RMM
store (Algorithm 1) when ρ < 1; the pooler/classifier operate on B rows
(not B·T) and stay exact, matching the paper's focus on *large* linear
layers.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers, rmm, variance
from .layers import Loaded, Tape


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static model + batch geometry (one HLO artifact per distinct config)."""

    vocab_size: int = 1024
    seq_len: int = 64
    batch_size: int = 16
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 512
    n_classes: int = 2
    regression: bool = False
    rho: float = 1.0          # ≥ 1.0 disables RMM (baseline)
    sketch: str = "gauss"     # gauss | rademacher | dct | dft | rowsample
    use_kernels: bool = False  # route matmuls through the Pallas kernels
    probe_layer: int = -1      # block index for the variance probe; -1 = off

    @property
    def rows(self) -> int:
        return self.batch_size * self.seq_len

    @property
    def b_proj(self) -> int:
        return rmm.b_proj_for(self.rows, self.rho)

    def validate(self):
        assert self.d_model % self.n_heads == 0
        assert 0.0 < self.rho
        assert self.sketch in ("gauss", "rademacher", "dct", "dft", "rowsample")
        assert self.probe_layer < self.n_layers
        if self.regression:
            assert self.n_classes == 1


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the flat calling convention of the HLO."""
    d, ff = cfg.d_model, cfg.d_ff
    spec: List[Tuple[str, Tuple[int, ...]]] = [
        ("emb.tok", (cfg.vocab_size, d)),
        ("emb.pos", (cfg.seq_len, d)),
        ("emb.ln_g", (d,)),
        ("emb.ln_b", (d,)),
    ]
    for i in range(cfg.n_layers):
        pre = f"blk{i}"
        spec += [
            (f"{pre}.q_w", (d, d)), (f"{pre}.q_b", (d,)),
            (f"{pre}.k_w", (d, d)), (f"{pre}.k_b", (d,)),
            (f"{pre}.v_w", (d, d)), (f"{pre}.v_b", (d,)),
            (f"{pre}.o_w", (d, d)), (f"{pre}.o_b", (d,)),
            (f"{pre}.ln1_g", (d,)), (f"{pre}.ln1_b", (d,)),
            (f"{pre}.f1_w", (ff, d)), (f"{pre}.f1_b", (ff,)),
            (f"{pre}.f2_w", (d, ff)), (f"{pre}.f2_b", (d,)),
            (f"{pre}.ln2_g", (d,)), (f"{pre}.ln2_b", (d,)),
        ]
    spec += [
        ("pool.w", (d, d)), ("pool.b", (d,)),
        ("cls.w", (cfg.n_classes, d)), ("cls.b", (cfg.n_classes,)),
    ]
    return spec


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, np.ndarray]:
    """BERT-style init (trunc-normal 0.02 for matrices, zeros/ones for LN)."""
    rng = np.random.default_rng(seed)
    params: Dict[str, np.ndarray] = {}
    for name, shape in param_spec(cfg):
        if name.endswith(("ln_g", "ln1_g", "ln2_g")) or name.endswith("_g"):
            params[name] = np.ones(shape, np.float32)
        elif name.endswith("_b") or name.endswith(".b"):
            params[name] = np.zeros(shape, np.float32)
        elif len(shape) == 1:
            params[name] = np.zeros(shape, np.float32)
        else:
            std = 0.02
            w = rng.normal(0.0, std, size=shape)
            params[name] = np.clip(w, -2 * std, 2 * std).astype(np.float32)
    return params


def params_to_list(cfg, params: Dict[str, np.ndarray]):
    return [params[n] for n, _ in param_spec(cfg)]


def params_from_list(cfg, lst) -> Dict[str, jnp.ndarray]:
    return {n: a for (n, _), a in zip(param_spec(cfg), lst)}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _block_fwd(tape: Tape, i: int, x3, mask, p, seed, cfg: ModelConfig):
    B, T, d = x3.shape
    pre = f"blk{i}"
    a3 = layers.mha_fwd(tape, f"{pre}.mha", x3, mask, p, pre, seed, cfg)
    h2 = layers.layernorm_fwd(tape, f"{pre}.ln1", (x3 + a3).reshape(B * T, d),
                              p[f"{pre}.ln1_g"], p[f"{pre}.ln1_b"])
    if cfg.probe_layer == i:
        # The probe needs the *full* FFN1 input (eq. 9 uses per-row norms);
        # stored in addition to the sketch, only in probe-enabled artifacts.
        tape.save(f"{pre}.ffn.f1_probe_x", h2)  # name read by ffn_bwd
    f2 = layers.ffn_fwd(tape, f"{pre}.ffn", h2, p, pre, seed, cfg)
    out2 = layers.layernorm_fwd(tape, f"{pre}.ln2", h2 + f2,
                                p[f"{pre}.ln2_g"], p[f"{pre}.ln2_b"])
    return out2.reshape(B, T, d)


def _heads_fwd(tape: Tape, x3, p, cfg: ModelConfig):
    """CLS pooler + classifier (exact linears; B rows only)."""
    x_cls = x3[:, 0, :]
    tape.save("pool.in", x_cls)
    z = layers.linear_fwd(x_cls, p["pool.w"], p["pool.b"], cfg.use_kernels)
    t = jnp.tanh(z)
    tape.save("pool.tanh", t)
    logits = layers.linear_fwd(t, p["cls.w"], p["cls.b"], cfg.use_kernels)
    return logits


def _loss_fwd(logits, labels, cfg: ModelConfig):
    if cfg.regression:
        pred = logits[:, 0]
        return jnp.mean(jnp.square(pred - labels))
    shifted = logits - jnp.max(logits, axis=-1, keepdims=True)
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    logp = jnp.take_along_axis(shifted, labels[:, None], axis=-1)[:, 0] - logz
    return -jnp.mean(logp)


def _dlogits(logits, labels, cfg: ModelConfig):
    B = logits.shape[0]
    if cfg.regression:
        d = 2.0 * (logits[:, 0] - labels) / B
        return d[:, None]
    shifted = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(shifted)
    sm = e / jnp.sum(e, axis=-1, keepdims=True)
    onehot = jax.nn.one_hot(labels, cfg.n_classes, dtype=jnp.float32)
    return (sm - onehot) / B


def forward(params: Dict, tokens, mask, labels, seed, cfg: ModelConfig):
    """Full forward. Returns (loss, logits, tape)."""
    tape = Tape()
    x3 = layers.embed_fwd(tape, "emb", tokens, params, cfg)
    for i in range(cfg.n_layers):
        x3 = _block_fwd(tape, i, x3, mask, params, seed, cfg)
    logits = _heads_fwd(tape, x3, params, cfg)
    tape.save("logits", logits)
    loss = _loss_fwd(logits, labels, cfg)
    return loss, logits, tape


def residual_names(cfg: ModelConfig) -> List[str]:
    """Names of the tape entries, in order (defines the HLO interface)."""
    cfg.validate()
    tokens = jnp.zeros((cfg.batch_size, cfg.seq_len), jnp.int32)
    mask = jnp.ones((cfg.batch_size, cfg.seq_len), jnp.float32)
    labels = (jnp.zeros((cfg.batch_size,), jnp.float32) if cfg.regression
              else jnp.zeros((cfg.batch_size,), jnp.int32))
    seed = jnp.zeros((2,), jnp.uint32)
    params = {n: jnp.zeros(s, jnp.float32) for n, s in param_spec(cfg)}

    names: List[str] = []

    def f(params, tokens, mask, labels, seed):
        _, _, tape = forward(params, tokens, mask, labels, seed, cfg)
        names.clear()
        names.extend(tape.names())
        return tuple(tape.arrays())

    # eval_shape traces abstractly — no arrays materialize, but the tape
    # still records its names (cheap even for big configs).
    jax.eval_shape(f, params, tokens, mask, labels, seed)
    return names


# ---------------------------------------------------------------------------
# Backward
# ---------------------------------------------------------------------------


def backward(params: Dict, tokens, mask, labels, seed, loaded: Loaded,
             cfg: ModelConfig):
    """Hand-written full-model backward from the stored residuals.

    Returns (grads dict, probe metrics dict or None).
    """
    B, T, d = cfg.batch_size, cfg.seq_len, cfg.d_model
    grads: Dict[str, jnp.ndarray] = {}

    logits = loaded["logits"]
    dlogits = _dlogits(logits, labels, cfg)

    # Heads.
    t = loaded["pool.tanh"]
    layers.accumulate(grads, "cls.w",
                      jnp.dot(dlogits.T, t, preferred_element_type=jnp.float32))
    layers.accumulate(grads, "cls.b", layers.linear_bwd_db(dlogits))
    dt = layers.linear_bwd_dx(dlogits, params["cls.w"], cfg.use_kernels)
    dz = dt * (1.0 - t * t)
    x_cls = loaded["pool.in"]
    layers.accumulate(grads, "pool.w",
                      jnp.dot(dz.T, x_cls, preferred_element_type=jnp.float32))
    layers.accumulate(grads, "pool.b", layers.linear_bwd_db(dz))
    dx_cls = layers.linear_bwd_dx(dz, params["pool.w"], cfg.use_kernels)

    dx3 = jnp.zeros((B, T, d), jnp.float32).at[:, 0, :].add(dx_cls)

    probe: Optional[Dict] = {} if cfg.probe_layer >= 0 else None
    probe_out = None
    for i in reversed(range(cfg.n_layers)):
        pre = f"blk{i}"
        dout2 = layers.layernorm_bwd(loaded, f"{pre}.ln2",
                                     dx3.reshape(B * T, d),
                                     params[f"{pre}.ln2_g"], grads,
                                     f"{pre}.ln2_g", f"{pre}.ln2_b")
        # out2 = LN2(h + f): gradient flows to both h and f.
        block_probe = probe if cfg.probe_layer == i else None
        df2 = dout2
        dh2 = layers.ffn_bwd(loaded, f"{pre}.ffn", df2, params, pre, seed,
                             cfg, grads, probe=block_probe)
        dh2 = dh2 + dout2  # skip connection
        dsum2 = layers.layernorm_bwd(loaded, f"{pre}.ln1", dh2,
                                     params[f"{pre}.ln1_g"], grads,
                                     f"{pre}.ln1_g", f"{pre}.ln1_b")
        da3 = dsum2.reshape(B, T, d)
        dxa3 = layers.mha_bwd(loaded, f"{pre}.mha", da3, params, pre, seed,
                              cfg, grads)
        dx3 = da3 + dxa3  # skip connection: d(x + a)
        if block_probe is not None and "x" in block_probe:
            probe_out = variance.probe_metrics(
                block_probe["x"], block_probe["y"], cfg.b_proj)

    layers.embed_bwd(loaded, "emb", dx3, tokens, params, cfg, grads)
    return grads, probe_out


# ---------------------------------------------------------------------------
# Flat entry points (what aot.py lowers)
# ---------------------------------------------------------------------------

PROBE_NAMES = ("d2_sgd", "d2_rmm", "alpha", "ratio_lhs", "bound_rhs")


def make_fwd(cfg: ModelConfig):
    names = [n for n, _ in param_spec(cfg)]

    def fwd(*args):
        plist = args[: len(names)]
        tokens, mask, labels, seed = args[len(names):]
        params = {n: a for n, a in zip(names, plist)}
        loss, logits, tape = forward(params, tokens, mask, labels, seed, cfg)
        return (loss, logits, *tape.arrays())

    return fwd


def make_bwd(cfg: ModelConfig):
    names = [n for n, _ in param_spec(cfg)]
    res_names = residual_names(cfg)

    def bwd(*args):
        plist = args[: len(names)]
        tokens, mask, labels, seed = args[len(names): len(names) + 4]
        res = args[len(names) + 4:]
        params = {n: a for n, a in zip(names, plist)}
        loaded = Loaded(res_names, list(res))
        grads, probe_out = backward(params, tokens, mask, labels, seed,
                                    loaded, cfg)
        out = [grads[n] for n in names]
        if cfg.probe_layer >= 0:
            assert probe_out is not None
            out += [probe_out[k] for k in PROBE_NAMES]
        return tuple(out)

    return bwd


def make_eval(cfg: ModelConfig):
    names = [n for n, _ in param_spec(cfg)]

    def evalf(*args):
        plist = args[: len(names)]
        tokens, mask = args[len(names):]
        params = {n: a for n, a in zip(names, plist)}
        tape = Tape()
        x3 = layers.embed_fwd(tape, "emb", tokens, params, cfg)
        for i in range(cfg.n_layers):
            x3 = _block_fwd(tape, i, x3, mask, params, seed_dummy(), cfg)
        logits = _heads_fwd(tape, x3, params, cfg)
        return (logits,)

    return evalf


def seed_dummy():
    return jnp.zeros((2,), jnp.uint32)


# ---------------------------------------------------------------------------
# Pure-JAX training step (used by pytest oracles; never lowered)
# ---------------------------------------------------------------------------


def loss_fn_autodiff(params: Dict, tokens, mask, labels, cfg: ModelConfig):
    """Same forward, loss only — differentiable by jax.grad (RMM must be
    off for gradient equality; with RMM on jax.grad would differentiate
    *through* the sketch, which is not Algorithm 1)."""
    loss, _, _ = forward(params, tokens, mask, labels, seed_dummy(), cfg)
    return loss
