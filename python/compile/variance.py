"""Variance estimators of Section 2.3, as lowered into the bwd artifact.

Thin jnp layer over the oracle formulas in ``kernels.ref`` (single source of
truth); the Rust mirror lives in ``rust/src/rmm/variance.rs``.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import ref


def probe_metrics(x, y, b_proj: int):
    """All Fig. 4/7 series for one (X, Y) pair at one layer.

    Returns a dict of scalars: d2_sgd (eq. 9), d2_rmm (eq. 11), alpha
    (eq. 13), ratio_lhs (LHS of eq. 12) and bound_rhs ((α+1)/α).
    """
    d2s = ref.d2_sgd(x, y)
    d2r = ref.d2_rmm(x, y, b_proj)
    a = ref.alpha(x, y)
    b = x.shape[0]
    ratio = (b_proj / (b - 1.0)) * d2r / jnp.maximum(d2s, jnp.float32(1e-30))
    bound = (a + 1.0) / jnp.maximum(a, jnp.float32(1e-30))
    return {
        "d2_sgd": d2s,
        "d2_rmm": d2r,
        "alpha": a,
        "ratio_lhs": ratio,
        "bound_rhs": bound,
    }
