"""Sketch API used by the L2 layers: project rows, estimate ∂W from a sketch.

Dispatches between the Pallas kernel path (``use_kernels=True``) and the
pure-jnp reference path.  Both are numerically equivalent (pinned by
pytest); the jnp path lowers to a leaner HLO for the large end-to-end
training artifacts, while the kernel path exercises the fused
generate-S-in-VMEM kernels (DESIGN.md §3).
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import matmul as mm
from .kernels import prng
from .kernels import project as proj
from .kernels import ref
from .kernels import transform as tfm

DENSE_KINDS = ("gauss", "rademacher")
SORS_KINDS = ("dct", "dft")


def derive_seed(seed, idx: int):
    """Per-layer (2,)-u32 seed from the step seed, via one Philox block.

    Evaluated identically in forward and backward lowerings, so each layer's
    S is rematerialized bit-exactly (the paper's "PRNG state").
    """
    seed = jnp.asarray(seed, jnp.uint32)
    c0, c1, _, _ = prng.philox4x32(
        jnp.uint32(idx), jnp.uint32(0x5EED), jnp.uint32(0), jnp.uint32(0),
        seed[0], seed[1],
    )
    return jnp.stack([c0, c1])


def b_proj_for(rows: int, rho: float) -> int:
    """Static projected row count: B_proj = clamp(round(ρ·rows), 1, rows)."""
    return max(1, min(rows, int(round(rho * rows))))


def project_rows(x2d, seed, b_proj: int, kind: str, use_kernels: bool):
    """X_proj = Sᵀ X (Algorithm 1 forward-side sketch)."""
    if use_kernels:
        if kind in DENSE_KINDS:
            return proj.project(x2d, seed, b_proj, kind)
        if kind in SORS_KINDS:
            return tfm.sors_project(x2d, seed, b_proj, kind)
        # rowsample has no kernel (it is a gather); fall through to ref.
    return ref.project(x2d, seed[0], seed[1], b_proj, kind)


def grad_w(dy2d, x_proj, seed, kind: str, use_kernels: bool):
    """∂L/∂W ≈ (Sᵀ Y)ᵀ X_proj (Algorithm 1 backward side, eq. 4)."""
    b_proj = x_proj.shape[0]
    y_proj = project_rows(dy2d, seed, b_proj, kind, use_kernels)
    if use_kernels:
        return mm.matmul(y_proj.T, x_proj)
    return jnp.dot(y_proj.T, x_proj, preferred_element_type=jnp.float32)


def linear_matmul(a, b, use_kernels: bool):
    """A @ B through the tiled kernel or jnp (forward-path contraction)."""
    if use_kernels:
        return mm.matmul(a, b)
    return jnp.dot(a, b, preferred_element_type=jnp.float32)
