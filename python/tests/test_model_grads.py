"""L2 model: hand-written backward vs jax.grad; RMM unbiasedness; shapes."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.layers import Loaded

CFG = M.ModelConfig(vocab_size=64, seq_len=8, batch_size=4, d_model=16,
                    n_heads=2, n_layers=2, d_ff=32, n_classes=3, rho=1.0)


def make_batch(cfg, seed=1):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(cfg.batch_size, cfg.seq_len)),
        jnp.int32)
    mask = jnp.ones((cfg.batch_size, cfg.seq_len), jnp.float32)
    mask = mask.at[0, cfg.seq_len - 2:].set(0.0)
    if cfg.regression:
        labels = jnp.asarray(rng.normal(size=(cfg.batch_size,)), jnp.float32)
    else:
        labels = jnp.asarray(
            rng.integers(0, cfg.n_classes, size=(cfg.batch_size,)), jnp.int32)
    return tokens, mask, labels


def run_fwd_bwd(cfg, params, tokens, mask, labels, seed):
    loss, logits, tape = M.forward(params, tokens, mask, labels, seed, cfg)
    loaded = Loaded(tape.names(), tape.arrays())
    grads, probe = M.backward(params, tokens, mask, labels, seed, loaded, cfg)
    return loss, logits, grads, probe, tape


@pytest.fixture(scope="module")
def params():
    return {k: jnp.asarray(v) for k, v in M.init_params(CFG, 0).items()}


class TestHandBackwardVsAutodiff:
    @pytest.mark.parametrize("head", ["cls", "reg"])
    def test_grads_match(self, head):
        cfg = CFG if head == "cls" else dataclasses.replace(
            CFG, n_classes=1, regression=True)
        p = {k: jnp.asarray(v) for k, v in M.init_params(cfg, 0).items()}
        tokens, mask, labels = make_batch(cfg)
        _, _, grads, _, _ = run_fwd_bwd(cfg, p, tokens, mask, labels,
                                        M.seed_dummy())
        ad = jax.grad(M.loss_fn_autodiff)(p, tokens, mask, labels, cfg)
        assert set(ad) == set(grads)
        for k in ad:
            scale = float(jnp.max(jnp.abs(ad[k]))) + 1e-8
            err = float(jnp.max(jnp.abs(ad[k] - grads[k]))) / scale
            assert err < 1e-3, f"{k}: rel err {err}"

    def test_loss_finite_and_positive(self, params):
        tokens, mask, labels = make_batch(CFG)
        loss, logits, *_ = run_fwd_bwd(CFG, params, tokens, mask, labels,
                                       M.seed_dummy())
        assert np.isfinite(float(loss)) and float(loss) > 0
        assert logits.shape == (CFG.batch_size, CFG.n_classes)


class TestResidualInterface:
    def test_names_match_tape(self, params):
        tokens, mask, labels = make_batch(CFG)
        _, _, tape = M.forward(params, tokens, mask, labels, M.seed_dummy(),
                               CFG)
        assert M.residual_names(CFG) == tape.names()

    def test_rmm_shrinks_residuals(self, params):
        cfg_rmm = dataclasses.replace(CFG, rho=0.25)
        tokens, mask, labels = make_batch(CFG)
        _, _, t_full = M.forward(params, tokens, mask, labels, M.seed_dummy(),
                                 CFG)
        _, _, t_rmm = M.forward(params, tokens, mask, labels, M.seed_dummy(),
                                cfg_rmm)
        bytes_full = sum(a.size for a in t_full.arrays())
        bytes_rmm = sum(a.size for a in t_rmm.arrays())
        assert bytes_rmm < bytes_full
        # linear-layer stores are (rows → ρ·rows); check one specifically
        d_full = dict(zip(t_full.names(), t_full.arrays()))
        d_rmm = dict(zip(t_rmm.names(), t_rmm.arrays()))
        assert d_full["blk0.ffn.f1_in"].shape[0] == cfg_rmm.rows
        assert d_rmm["blk0.ffn.f1_in"].shape[0] == cfg_rmm.b_proj

    def test_probe_adds_full_input(self, params):
        cfg = dataclasses.replace(CFG, rho=0.5, probe_layer=1)
        names = M.residual_names(cfg)
        assert "blk1.ffn.f1_probe_x" in names

    def test_param_spec_covers_grads(self, params):
        tokens, mask, labels = make_batch(CFG)
        _, _, grads, _, _ = run_fwd_bwd(CFG, params, tokens, mask, labels,
                                        M.seed_dummy())
        spec_names = [n for n, _ in M.param_spec(CFG)]
        assert set(spec_names) == set(grads)
        for n, shape in M.param_spec(CFG):
            assert grads[n].shape == shape


class TestRmmGradient:
    def test_unbiased_around_exact(self, params):
        """Average RMM ∂W over seeds converges to the exact gradient."""
        cfg = dataclasses.replace(CFG, rho=0.5)
        tokens, mask, labels = make_batch(CFG)
        _, _, g_exact, _, _ = run_fwd_bwd(CFG, params, tokens, mask, labels,
                                          M.seed_dummy())
        key = "blk0.f1_w"
        acc = np.zeros(g_exact[key].shape, np.float32)
        trials = 80
        for s in range(trials):
            seed = jnp.asarray([s * 13 + 1, s * 101 + 7], jnp.uint32)
            _, _, g, _, _ = run_fwd_bwd(cfg, params, tokens, mask, labels,
                                        seed)
            acc += np.asarray(g[key])
        acc /= trials
        exact = np.asarray(g_exact[key])
        rel = np.abs(acc - exact).max() / (np.abs(exact).max() + 1e-9)
        assert rel < 0.35, rel  # MC error ~ 1/sqrt(trials)

    def test_exact_parts_unaffected_by_rmm(self, params):
        """∂L/∂b and LN grads do not depend on the sketch (eqs. 2–3)."""
        cfg = dataclasses.replace(CFG, rho=0.5)
        tokens, mask, labels = make_batch(CFG)
        _, _, g_exact, _, _ = run_fwd_bwd(CFG, params, tokens, mask, labels,
                                          M.seed_dummy())
        _, _, g_rmm, _, _ = run_fwd_bwd(cfg, params, tokens, mask, labels,
                                        jnp.asarray([5, 6], jnp.uint32))
        # the *last* block's biases see exact upstream grads (RMM only
        # perturbs ∂W; ∂X̂ paths into them are exact at the top of bwd)
        np.testing.assert_allclose(g_exact["cls.b"], g_rmm["cls.b"],
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(g_exact["cls.w"], g_rmm["cls.w"],
                                   rtol=1e-4, atol=1e-5)

    def test_seed_reproducibility(self, params):
        cfg = dataclasses.replace(CFG, rho=0.5)
        tokens, mask, labels = make_batch(CFG)
        seed = jnp.asarray([9, 11], jnp.uint32)
        _, _, g1, _, _ = run_fwd_bwd(cfg, params, tokens, mask, labels, seed)
        _, _, g2, _, _ = run_fwd_bwd(cfg, params, tokens, mask, labels, seed)
        for k in g1:
            np.testing.assert_array_equal(np.asarray(g1[k]), np.asarray(g2[k]))

    @pytest.mark.parametrize("kind", ["gauss", "rademacher", "dct", "dft",
                                      "rowsample"])
    def test_all_sketches_run(self, params, kind):
        cfg = dataclasses.replace(CFG, rho=0.5, sketch=kind)
        tokens, mask, labels = make_batch(CFG)
        loss, _, grads, _, _ = run_fwd_bwd(cfg, params, tokens, mask, labels,
                                           jnp.asarray([3, 4], jnp.uint32))
        assert np.isfinite(float(loss))
        for k, g in grads.items():
            assert np.all(np.isfinite(np.asarray(g))), k


class TestProbe:
    def test_probe_outputs(self, params):
        cfg = dataclasses.replace(CFG, rho=0.5, probe_layer=0)
        tokens, mask, labels = make_batch(CFG)
        _, _, _, probe, _ = run_fwd_bwd(cfg, params, tokens, mask, labels,
                                        jnp.asarray([1, 2], jnp.uint32))
        assert probe is not None
        for k in M.PROBE_NAMES:
            assert np.isfinite(float(probe[k])), k
        assert float(probe["ratio_lhs"]) <= float(probe["bound_rhs"]) * 1.001


class TestTrainingSanity:
    def test_loss_decreases_under_sgd(self, params):
        """A few SGD steps on a fixed batch reduce the loss (both modes)."""
        for rho in (1.0, 0.5):
            cfg = dataclasses.replace(CFG, rho=rho)
            p = {k: jnp.asarray(v) for k, v in M.init_params(cfg, 0).items()}
            tokens, mask, labels = make_batch(cfg)
            first = last = None
            for step in range(8):
                seed = jnp.asarray([step * 7 + 1, 2], jnp.uint32)
                loss, _, grads, _, _ = run_fwd_bwd(cfg, p, tokens, mask,
                                                   labels, seed)
                if first is None:
                    first = float(loss)
                last = float(loss)
                p = {k: v - 0.5 * grads[k] for k, v in p.items()}
            assert last < first, (rho, first, last)
