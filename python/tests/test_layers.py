"""Per-layer gradient checks: each hand-written bwd against jax.grad."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers
from compile.layers import Loaded, Tape


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def check_grads(fwd_fn, bwd_fn, args, tol=2e-4):
    """fwd_fn(*args, tape) -> out; bwd_fn(loaded, dout) -> grads dict keyed
    like jax.grad over args dict."""
    tape = Tape()
    out = fwd_fn(tape)
    dout = jnp.ones_like(out)
    loaded = Loaded(tape.names(), tape.arrays())
    got = bwd_fn(loaded, dout)

    def scalar(args_):
        t2 = Tape()
        return jnp.sum(fwd_fn(t2, override=args_))

    ad = jax.grad(scalar)(args)
    for k in ad:
        scale = float(jnp.max(jnp.abs(ad[k]))) + 1e-8
        err = float(jnp.max(jnp.abs(ad[k] - got[k]))) / scale
        assert err < tol, f"{k}: rel err {err}"


class TestLayerNorm:
    def test_grads(self):
        rng = np.random.default_rng(0)
        x = rand(rng, 6, 8)
        g = rand(rng, 8)
        b = rand(rng, 8)
        args = {"x": x, "g": g, "b": b}

        def fwd(tape, override=None):
            a = override or args
            return layers.layernorm_fwd(tape, "ln", a["x"], a["g"], a["b"])

        def bwd(loaded, dout):
            grads = {}
            dx = layers.layernorm_bwd(loaded, "ln", dout, args["g"], grads, "g", "b")
            grads["x"] = dx
            return grads

        check_grads(fwd, bwd, args)

    def test_normalizes(self):
        rng = np.random.default_rng(1)
        x = rand(rng, 4, 16) * 10 + 3
        tape = Tape()
        out = layers.layernorm_fwd(tape, "ln", x, jnp.ones(16), jnp.zeros(16))
        np.testing.assert_allclose(np.mean(out, -1), 0, atol=1e-5)
        np.testing.assert_allclose(np.std(out, -1), 1, atol=1e-3)


class TestGelu:
    def test_grads(self):
        rng = np.random.default_rng(2)
        x = rand(rng, 5, 7)
        args = {"x": x}

        def fwd(tape, override=None):
            a = override or args
            return layers.gelu_fwd(tape, "g", a["x"])

        def bwd(loaded, dout):
            return {"x": layers.gelu_bwd(loaded, "g", dout)}

        check_grads(fwd, bwd, args)

    def test_matches_jax_gelu(self):
        x = jnp.linspace(-4, 4, 41)
        tape = Tape()
        ours = layers.gelu_fwd(tape, "g", x)
        theirs = jax.nn.gelu(x, approximate=True)
        np.testing.assert_allclose(ours, theirs, atol=1e-5)


class TestStoreRows:
    def test_full_mode_stores_input(self):
        rng = np.random.default_rng(3)
        x = rand(rng, 10, 4)
        tape = Tape()
        layers.store_rows(tape, "s", x, jnp.zeros(2, jnp.uint32), 1.0, "gauss", False)
        assert tape.items[0][0] == "s"
        np.testing.assert_array_equal(tape.items[0][1], x)

    @pytest.mark.parametrize("rho,expected", [(0.5, 5), (0.09, 1), (0.99, 10)])
    def test_proj_mode_shrinks_rows(self, rho, expected):
        rng = np.random.default_rng(4)
        x = rand(rng, 10, 4)
        tape = Tape()
        layers.store_rows(tape, "s", x, jnp.zeros(2, jnp.uint32), rho, "gauss", False)
        assert tape.items[0][1].shape == (expected, 4)

    def test_grad_from_store_exact_vs_rmm(self):
        rng = np.random.default_rng(5)
        x = rand(rng, 64, 6)
        dy = rand(rng, 64, 8)
        seed = jnp.asarray([3, 7], jnp.uint32)
        tape = Tape()
        layers.store_rows(tape, "s", x, seed, 1.0, "gauss", False)
        loaded = Loaded(tape.names(), tape.arrays())
        exact = layers.grad_w_from_store(loaded, "s", dy, seed, 1.0, "gauss", False)
        np.testing.assert_allclose(exact, dy.T @ x, rtol=1e-5, atol=1e-5)
        # RMM estimate is unbiased: average over seeds approaches exact
        acc = np.zeros((8, 6), np.float32)
        trials = 300
        for t in range(trials):
            s = jnp.asarray([t * 13 + 1, 5], jnp.uint32)
            t2 = Tape()
            layers.store_rows(t2, "s", x, s, 0.5, "gauss", False)
            l2 = Loaded(t2.names(), t2.arrays())
            acc += np.asarray(
                layers.grad_w_from_store(l2, "s", dy, s, 0.5, "gauss", False))
        acc /= trials
        exact_np = np.asarray(dy.T @ x)
        rel = np.abs(acc - exact_np).max() / np.abs(exact_np).max()
        assert rel < 0.25, rel


class TestMha:
    def _cfg(self):
        import dataclasses
        from compile import model as M

        return M.ModelConfig(vocab_size=32, seq_len=6, batch_size=3,
                             d_model=8, n_heads=2, n_layers=1, d_ff=16,
                             n_classes=2, rho=1.0)

    def test_grads_vs_autodiff(self):
        cfg = self._cfg()
        rng = np.random.default_rng(6)
        x3 = rand(rng, 3, 6, 8)
        mask = jnp.ones((3, 6), jnp.float32).at[0, 4:].set(0.0)
        p = {
            f"blk0.{n}_{s}": (rand(rng, 8, 8) * 0.3 if s == "w" else rand(rng, 8) * 0.1)
            for n in ["q", "k", "v", "o"]
            for s in ["w", "b"]
        }
        seed = jnp.zeros(2, jnp.uint32)

        def f(p_and_x):
            tape = Tape()
            out = layers.mha_fwd(tape, "m", p_and_x["x"], mask, p_and_x, "blk0",
                                 seed, cfg)
            return jnp.sum(out)

        args = dict(p)
        args["x"] = x3
        ad = jax.grad(f)(args)

        tape = Tape()
        out = layers.mha_fwd(tape, "m", x3, mask, p, "blk0", seed, cfg)
        loaded = Loaded(tape.names(), tape.arrays())
        grads = {}
        dx = layers.mha_bwd(loaded, "m", jnp.ones_like(out), p, "blk0", seed,
                            cfg, grads)
        for k in p:
            # floor the scale: k_b has ~zero true gradient (softmax is
            # invariant to per-query constant score shifts), so a pure
            # relative check would amplify float noise
            scale = max(float(jnp.max(jnp.abs(ad[k]))), 1e-3)
            err = float(jnp.max(jnp.abs(ad[k] - grads[k]))) / scale
            assert err < 5e-4, f"{k}: {err}"
        scale = float(jnp.max(jnp.abs(ad["x"]))) + 1e-8
        err = float(jnp.max(jnp.abs(ad["x"] - dx))) / scale
        assert err < 5e-4, f"x: {err}"

    def test_mask_blocks_attention(self):
        cfg = self._cfg()
        rng = np.random.default_rng(7)
        x3 = rand(rng, 3, 6, 8)
        p = {
            f"blk0.{n}_{s}": (rand(rng, 8, 8) * 0.3 if s == "w" else rand(rng, 8) * 0.1)
            for n in ["q", "k", "v", "o"]
            for s in ["w", "b"]
        }
        seed = jnp.zeros(2, jnp.uint32)
        mask = jnp.ones((3, 6), jnp.float32).at[:, 3:].set(0.0)
        tape = Tape()
        layers.mha_fwd(tape, "m", x3, mask, p, "blk0", seed, cfg)
        a = dict(zip(tape.names(), tape.arrays()))["m.a"]
        # probabilities on masked keys must be ~0
        assert float(jnp.max(a[..., 3:])) < 1e-6
