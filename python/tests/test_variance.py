"""Variance theory (Section 2.3): Lemmas 2.1/2.2, Theorem 2.3, eq. 14–16."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile import variance

settings.register_profile("var", max_examples=25, deadline=None)
settings.load_profile("var")


def _xy(seed, b=12, n=5, m=7):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.normal(size=(b, n)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, m)), jnp.float32))


class TestLemma21:
    def test_matches_direct_estimator(self):
        """Eq. (9) equals the textbook per-sample variance estimator (eq. 20-21)."""
        x, y = _xy(0)
        b = x.shape[0]
        xn, yn = np.asarray(x), np.asarray(y)
        zbar = xn.T @ yn
        # D²_Z = 1/B Σ‖B·x_k y_kᵀ − Z̄‖² ;  D²_SGD = D²_Z / (B−1)
        d2z = sum(
            np.linalg.norm(b * np.outer(xn[k], yn[k]) - zbar, "fro") ** 2
            for k in range(b)) / b
        expected = d2z / (b - 1)
        got = float(ref.d2_sgd(x, y))
        assert got == pytest.approx(expected, rel=1e-4)

    @given(seed=st.integers(0, 10000), b=st.integers(2, 40),
           n=st.integers(1, 16), m=st.integers(1, 16))
    def test_nonnegative(self, seed, b, n, m):
        x, y = _xy(seed, b, n, m)
        assert float(ref.d2_sgd(x, y)) >= -1e-3

    def test_zero_for_identical_rank_one(self):
        """If every per-sample gradient equals the mean, variance is 0."""
        x = jnp.ones((8, 3), jnp.float32)
        y = jnp.ones((8, 4), jnp.float32)
        assert float(ref.d2_sgd(x, y)) == pytest.approx(0.0, abs=1e-3)


class TestLemma22:
    @given(seed=st.integers(0, 10000), b=st.integers(2, 24),
           n=st.integers(1, 8), m=st.integers(1, 8),
           b_proj=st.integers(1, 24))
    def test_nonnegative(self, seed, b, n, m, b_proj):
        x, y = _xy(seed, b, n, m)
        # Cauchy-Schwarz: ‖XᵀY‖²_F ≤ ‖X‖²_F ‖Y‖²_F
        assert float(ref.d2_rmm(x, y, b_proj)) >= -1e-3

    @pytest.mark.parametrize("b_proj", [2, 4, 8])
    def test_exact_formula_matches_monte_carlo(self, b_proj):
        """The *exact* variance (fourth moment included) matches MC.

        The paper's eq. (11) misses +2‖XᵀY‖²/B_proj (proof of eq. 36 drops
        the Gaussian excess kurtosis) — see EXPERIMENTS.md §Discrepancies.
        """
        x, y = _xy(1, b=10, n=4, m=3)
        xn, yn = np.asarray(x), np.asarray(y)
        exact = xn.T @ yn
        trials = 3000
        acc = 0.0
        for t in range(trials):
            s = ref.numpy_sketch("gauss", 10, b_proj, t * 101 + 3)
            acc += np.linalg.norm(xn.T @ s @ s.T @ yn - exact, "fro") ** 2
        mc = acc / trials
        formula = float(ref.d2_rmm_exact(x, y, b_proj))
        assert mc == pytest.approx(formula, rel=0.15)
        # and the paper's form is a strict lower bound at the exact gap
        paper = float(ref.d2_rmm(x, y, b_proj))
        gap = 2 * float(np.linalg.norm(exact, "fro") ** 2) / b_proj
        assert formula - paper == pytest.approx(gap, rel=1e-4)

    def test_paper_form_accurate_when_alpha_small(self):
        """α ≪ 1 (the training regime) ⇒ eq. (11) ≈ exact."""
        x, y = _xy(3, b=64, n=8, m=8)
        assert float(ref.alpha(x, y)) < 0.05
        exact = float(ref.d2_rmm_exact(x, y, 8))
        paper = float(ref.d2_rmm(x, y, 8))
        assert (exact - paper) / exact < 0.1

    def test_scaling_in_b_proj(self):
        x, y = _xy(2)
        assert float(ref.d2_rmm(x, y, 10)) == pytest.approx(
            float(ref.d2_rmm(x, y, 5)) / 2, rel=1e-4)


class TestTheorem23:
    """Theorem 2.3 soundness finding (see EXPERIMENTS.md §Discrepancies):

    the proof's step (43)→(45) silently drops a +2‖X‖²‖Y‖² term, so the
    inequality as *stated* is false in general (hypothesis found e.g.
    B=3, N=1, M=2, B_proj=1 violations).  What is true is the identity

        B_proj·D²_RMM − (B−1)·((α+1)/α)·D²_SGD
            = 2‖X‖²‖Y‖² − B·((α+1)/α)·Σ_k‖x_k‖²‖y_k‖²,

    whose RHS is ≤ 0 in the training regime (per-row mass B·Σ‖x_k‖²‖y_k‖²
    dominating ‖X‖²‖Y‖²), which is why the paper's Fig. 4 ratio does sit
    below (α+1)/α empirically — our Fig 4 driver confirms the same.
    """

    @given(seed=st.integers(0, 20000), b=st.integers(3, 32),
           n=st.integers(1, 12), m=st.integers(1, 12),
           b_proj=st.integers(1, 32))
    def test_corrected_identity(self, seed, b, n, m, b_proj):
        x, y = _xy(seed, b, n, m)
        xn, yn = np.asarray(x, np.float64), np.asarray(y, np.float64)
        p = (xn**2).sum() * (yn**2).sum()
        r = ((xn**2).sum(1) * (yn**2).sum(1)).sum()
        q = np.linalg.norm(xn.T @ yn, "fro") ** 2
        if q < 1e-9 * p:
            return  # alpha -> 0: (α+1)/α diverges
        a = q / p
        lhs = (b_proj * float(ref.d2_rmm(x, y, b_proj))
               - (b - 1) * ((a + 1) / a) * float(ref.d2_sgd(x, y)))
        rhs = 2 * p - b * ((a + 1) / a) * r
        assert lhs == pytest.approx(rhs, rel=2e-3, abs=1e-3 * abs(rhs) + 1e-4)

    def test_paper_bound_has_counterexample(self):
        """Pin the violation hypothesis discovered (B=3, N=1, M=2, B_proj=1)."""
        x, y = _xy(3307, 3, 1, 2)
        a = float(ref.alpha(x, y))
        lhs = float(ref.variance_ratio_lhs(x, y, 1))
        rhs = (a + 1) / a
        assert lhs > rhs, "expected a Theorem 2.3 violation at this seed"

    @given(seed=st.integers(0, 5000))
    def test_bound_holds_in_training_regime(self, seed):
        """With iid rows and enough of them (the regime of Fig. 4), the
        per-row mass dominates and the paper's bound holds."""
        x, y = _xy(seed, b=32, n=8, m=8)
        a = float(ref.alpha(x, y))
        if a < 1e-7:
            return
        lhs = float(ref.variance_ratio_lhs(x, y, 16))
        rhs = (a + 1) / a
        assert lhs <= rhs * (1 + 1e-3)

    def test_alpha_in_unit_interval(self):
        for seed in range(20):
            x, y = _xy(seed)
            a = float(ref.alpha(x, y))
            assert -1e-6 <= a <= 1 + 1e-6

    def test_adversarial_example_eq_14_16(self):
        """The paper's ε example: XᵀY = 0 makes the ratio arbitrarily large."""
        for eps in (0.5, 0.1, 0.01):
            x = jnp.asarray([[1.0, 0.0], [-eps, 0.0]], jnp.float32)
            y = jnp.asarray([[1.0, 0.0], [1.0 / eps, 0.0]], jnp.float32)
            b, b_proj = 2, 1
            # eq. (15): (B−1) D²_SGD = 4
            assert float(ref.d2_sgd(x, y)) * (b - 1) == pytest.approx(4.0, rel=1e-3)
            # eq. (16): B_proj D²_RMM = 2 + ε² + ε⁻²
            assert float(ref.d2_rmm(x, y, b_proj)) * b_proj == pytest.approx(
                2 + eps**2 + eps**-2, rel=1e-3)
        # and the ratio grows without bound as ε → 0
        ratios = []
        for eps in (0.5, 0.1, 0.02):
            x = jnp.asarray([[1.0, 0.0], [-eps, 0.0]], jnp.float32)
            y = jnp.asarray([[1.0, 0.0], [1.0 / eps, 0.0]], jnp.float32)
            ratios.append(float(ref.d2_rmm(x, y, 1)) / float(ref.d2_sgd(x, y)))
        assert ratios[0] < ratios[1] < ratios[2]


class TestProbeMetrics:
    def test_keys_and_bound(self):
        x, y = _xy(7, b=16)
        m = variance.probe_metrics(x, y, b_proj=8)
        assert set(m) == {"d2_sgd", "d2_rmm", "alpha", "ratio_lhs", "bound_rhs"}
        assert float(m["ratio_lhs"]) <= float(m["bound_rhs"]) * (1 + 1e-3)
