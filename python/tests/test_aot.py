"""AOT pipeline: lowering, manifest schema, init-params blob, arg pinning."""

import json
import os
import re

import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def quick_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    rc = aot.main(["--out-dir", str(out), "--set", "quick", "--force"])
    assert rc == 0
    return out


def load_manifest(quick_dir):
    with open(os.path.join(quick_dir, "manifest.json")) as f:
        return json.load(f)


class TestManifestSchema:
    def test_version_and_variants(self, quick_dir):
        m = load_manifest(quick_dir)
        assert m["version"] == aot.MANIFEST_VERSION
        assert set(m["variants"]) == {
            "tiny_cls2_r100_gauss",
            "tiny_cls2_r50_gauss",
            "tinyk_cls2_r50_gauss",
        }

    def test_entry_files_exist(self, quick_dir):
        m = load_manifest(quick_dir)
        for v in m["variants"].values():
            for e in v["entries"].values():
                path = os.path.join(quick_dir, e["file"])
                assert os.path.exists(path), path
                assert os.path.getsize(path) > 100

    def test_arg_specs_complete(self, quick_dir):
        m = load_manifest(quick_dir)
        for vname, v in m["variants"].items():
            fwd = v["entries"]["fwd"]
            roles = [a["role"] for a in fwd["args"]]
            n_params = roles.count("param")
            assert roles == ["param"] * n_params + ["tokens", "mask", "labels", "seed"]
            out_roles = [o["role"] for o in fwd["outputs"]]
            assert out_roles[0] == "metric" and out_roles[1] == "logits"
            assert all(r == "residual" for r in out_roles[2:])
            bwd = v["entries"]["bwd"]
            assert [o["role"] for o in bwd["outputs"]][:n_params] == ["grad"] * n_params
            # fwd residual outputs align with bwd residual args
            f_res = [o for o in fwd["outputs"] if o["role"] == "residual"]
            b_res = [a for a in bwd["args"] if a["role"] == "residual"]
            assert [o["name"] for o in f_res] == [a["name"] for a in b_res], vname
            assert [o["shape"] for o in f_res] == [a["shape"] for a in b_res]

    def test_rho_shrinks_residual_bytes(self, quick_dir):
        m = load_manifest(quick_dir)

        def resid_bytes(vname):
            fwd = m["variants"][vname]["entries"]["fwd"]
            return sum(
                4 * int(np.prod(o["shape"] or [1]))
                for o in fwd["outputs"]
                if o["role"] == "residual"
            )

        assert resid_bytes("tiny_cls2_r50_gauss") < resid_bytes("tiny_cls2_r100_gauss")

    def test_b_proj_recorded(self, quick_dir):
        m = load_manifest(quick_dir)
        v = m["variants"]["tiny_cls2_r50_gauss"]
        assert v["b_proj"] == v["rows"] // 2


class TestArgPinning:
    def test_all_args_survive_conversion(self, quick_dir):
        """The ρ=1 graph ignores `seed`; arg pinning must keep it (else the
        runtime's buffer count desynchronizes — the bug this guards)."""
        m = load_manifest(quick_dir)
        for vname, v in m["variants"].items():
            for ename, e in v["entries"].items():
                path = os.path.join(quick_dir, e["file"])
                with open(path) as f:
                    txt = f.read()
                entry = txt[txt.index("ENTRY"):]
                params = set(re.findall(r"parameter\((\d+)\)", entry))
                assert len(params) == len(e["args"]), f"{vname}/{ename}"


class TestInitParams:
    def test_blob_size_matches_spec(self, quick_dir):
        m = load_manifest(quick_dir)
        for v in m["variants"].values():
            blob = os.path.join(quick_dir, v["init_params"])
            assert os.path.getsize(blob) == 4 * v["param_count"]

    def test_shared_geometry_shares_blob(self, quick_dir):
        m = load_manifest(quick_dir)
        a = m["variants"]["tiny_cls2_r100_gauss"]["init_params"]
        b = m["variants"]["tiny_cls2_r50_gauss"]["init_params"]
        assert a == b

    def test_init_statistics(self, quick_dir):
        m = load_manifest(quick_dir)
        v = m["variants"]["tiny_cls2_r100_gauss"]
        blob = np.fromfile(os.path.join(quick_dir, v["init_params"]), np.float32)
        # trunc-normal(0.02) matrices + zeros/ones vectors
        assert np.abs(blob).max() <= 1.0 + 1e-6
        assert np.isfinite(blob).all()


class TestIdempotence:
    def test_second_run_is_noop(self, quick_dir, capsys):
        rc = aot.main(["--out-dir", str(quick_dir), "--set", "quick"])
        assert rc == 0
        out = capsys.readouterr().out
        assert "up to date" in out


class TestVariantSets:
    def test_default_set_covers_experiments(self):
        v = aot.build_variants("default")
        names = set(v)
        # Table 2: all three heads × 5 rhos (gauss)
        for head in ["cls2", "cls3", "reg"]:
            for tag in ["r100", "r90", "r50", "r20", "r10"]:
                assert f"small_{head}_{tag}_gauss" in names
        # Table 4 sketch families
        for kind in ["rademacher", "dct", "dft", "rowsample"]:
            for tag in ["r50", "r20", "r10"]:
                assert f"small_cls2_{tag}_{kind}" in names
        # probe + batch sweep + kernel validation
        assert "probe_cls2_r50_gauss" in names
        for b in [8, 32, 64]:
            assert f"small_cls2_b{b}_r50_gauss" in names
        assert "tinyk_cls2_r50_gauss" in names

    def test_configs_validate(self):
        for name, (cfg, entries) in aot.build_variants("default").items():
            cfg.validate()
            assert "fwd" in entries or "eval" in entries, name
