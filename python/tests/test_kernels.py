"""L1 kernels vs pure-jnp oracles — hypothesis sweeps over shapes/seeds.

This is the CORE correctness gate for the Pallas layer: every kernel must
agree with ``ref.py`` on arbitrary (non-tile-aligned) shapes, which also
exercises the zero-padding and logical-index seeding logic.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as mm
from compile.kernels import project as proj
from compile.kernels import ref
from compile.kernels import transform as tfm

settings.register_profile("kernels", max_examples=10, deadline=None)
settings.load_profile("kernels")


def _arr(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


class TestMatmulKernel:
    @given(m=st.integers(1, 70), k=st.integers(1, 70), n=st.integers(1, 70),
           seed=st.integers(0, 2**16))
    def test_matches_ref(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        a, b = _arr(rng, m, k), _arr(rng, k, n)
        np.testing.assert_allclose(
            mm.matmul(a, b), ref.matmul(a, b), rtol=1e-4, atol=1e-4)

    def test_tile_aligned(self):
        rng = np.random.default_rng(0)
        a, b = _arr(rng, 128, 128), _arr(rng, 128, 128)
        np.testing.assert_allclose(
            mm.matmul(a, b), ref.matmul(a, b), rtol=1e-4, atol=1e-4)

    def test_custom_tiles(self):
        rng = np.random.default_rng(1)
        a, b = _arr(rng, 40, 24), _arr(rng, 24, 56)
        out = mm.matmul(a, b, tile_m=16, tile_n=16, tile_k=8)
        np.testing.assert_allclose(out, ref.matmul(a, b), rtol=1e-4, atol=1e-4)

    def test_shape_mismatch_raises(self):
        rng = np.random.default_rng(2)
        with pytest.raises(AssertionError):
            mm.matmul(_arr(rng, 4, 5), _arr(rng, 6, 7))


class TestProjectKernel:
    @given(b=st.integers(2, 80), n=st.integers(1, 40),
           frac=st.floats(0.05, 1.0), seed=st.integers(0, 2**16),
           kind=st.sampled_from(["gauss", "rademacher"]))
    def test_matches_ref(self, b, n, frac, seed, kind):
        rng = np.random.default_rng(seed)
        x = _arr(rng, b, n)
        b_proj = max(1, int(frac * b))
        s = jnp.asarray([seed & 0xFFFF, seed >> 4], jnp.uint32)
        out = proj.project(x, s, b_proj, kind)
        exp = ref.project(x, int(s[0]), int(s[1]), b_proj, kind)
        np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)

    def test_seed_changes_output(self):
        rng = np.random.default_rng(3)
        x = _arr(rng, 32, 8)
        a = proj.project(x, jnp.asarray([1, 0], jnp.uint32), 8, "gauss")
        b = proj.project(x, jnp.asarray([2, 0], jnp.uint32), 8, "gauss")
        assert not np.allclose(a, b)

    def test_fwd_bwd_same_sketch(self):
        """The same seed must reproduce the identical S — eq. (4)'s premise."""
        rng = np.random.default_rng(4)
        x = _arr(rng, 24, 6)
        y = _arr(rng, 24, 10)
        s = jnp.asarray([11, 13], jnp.uint32)
        smat = ref.sketch("gauss", 24, 8, 11, 13)
        got = proj.rmm_grad_w(y, proj.project(x, s, 8, "gauss"), s, "gauss")
        exp = np.asarray(y).T @ np.asarray(smat) @ np.asarray(smat).T @ np.asarray(x)
        np.testing.assert_allclose(got, exp, rtol=1e-3, atol=1e-3)


class TestSorsKernel:
    @given(b=st.integers(2, 64), n=st.integers(1, 24),
           frac=st.floats(0.05, 1.0), seed=st.integers(0, 2**16),
           kind=st.sampled_from(["dct", "dft"]))
    def test_matches_ref(self, b, n, frac, seed, kind):
        rng = np.random.default_rng(seed)
        x = _arr(rng, b, n)
        b_proj = max(1, int(frac * b))
        s = jnp.asarray([seed & 0xFFFF, seed >> 4], jnp.uint32)
        out = tfm.sors_project(x, s, b_proj, kind)
        exp = ref.project(x, int(s[0]), int(s[1]), b_proj, kind)
        np.testing.assert_allclose(out, exp, rtol=1e-3, atol=1e-3)


class TestTransformMatrices:
    @pytest.mark.parametrize("kind", ["dct", "dft"])
    @pytest.mark.parametrize("b", [2, 8, 16, 32, 33, 64])
    def test_orthonormal(self, kind, b):
        if kind == "dft" and b % 2 == 1:
            pytest.skip("real DFT layout defined for even orders")
        h = np.asarray(ref.transform_matrix(kind, b))
        np.testing.assert_allclose(h @ h.T, np.eye(b), atol=2e-5)

    def test_dct_dc_row(self):
        h = np.asarray(ref.transform_matrix("dct", 16))
        np.testing.assert_allclose(h[0], np.full(16, 1 / 4.0), atol=1e-6)


class TestSketchStatistics:
    """E[S Sᵀ] = I — the single requirement the paper imposes on S (§2.1)."""

    @pytest.mark.parametrize("kind", ref.SKETCH_KINDS)
    def test_unbiased_identity(self, kind):
        b, b_proj, trials = 12, 6, 600
        acc = np.zeros((b, b))
        for t in range(trials):
            s = ref.numpy_sketch(kind, b, b_proj, t * 9973 + 17)
            acc += s @ s.T
        acc /= trials
        # per-entry MC std: rowsample diag entries have var ≈ B/B_proj, so
        # std-of-mean ≈ sqrt(2/600) ≈ 0.06 — use a ≥3σ tolerance.
        np.testing.assert_allclose(acc, np.eye(b), atol=0.2)

    @pytest.mark.parametrize("kind", ref.SKETCH_KINDS)
    def test_unbiased_matmul(self, kind):
        rng = np.random.default_rng(0)
        x = rng.normal(size=(10, 4)).astype(np.float32)
        y = rng.normal(size=(10, 5)).astype(np.float32)
        exact = x.T @ y
        trials = 800
        acc = np.zeros_like(exact)
        for t in range(trials):
            s = ref.numpy_sketch(kind, 10, 5, t * 31 + 7)
            acc += x.T @ s @ s.T @ y
        acc /= trials
        # per-entry MC std ≈ sqrt(D²_RMM/(N·M))/sqrt(trials) ≈ 0.13 here;
        # use a ≥3σ tolerance to keep the test deterministic-stable
        np.testing.assert_allclose(acc, exact, atol=0.45)

    def test_gauss_scale(self):
        s = ref.numpy_sketch("gauss", 200, 100, 5)
        # elements ~ N(0, 1/b_proj) → column norms ≈ sqrt(200/100)
        assert abs(np.std(s) - 1 / np.sqrt(100)) < 0.002

    def test_rowsample_columns_are_scaled_basis(self):
        s = ref.numpy_sketch("rowsample", 16, 8, 3)
        scale = np.sqrt(16 / 8)
        for j in range(8):
            col = s[:, j]
            assert (col != 0).sum() == 1
            assert np.isclose(np.abs(col).max(), scale)
