"""Philox PRNG: reference vectors, distributional checks, stream hygiene."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import prng


def _run(c, k):
    out = prng.philox4x32(*[jnp.uint32(x) for x in c], *[jnp.uint32(x) for x in k])
    return [int(o) for o in out]


class TestPhiloxVectors:
    """Known-answer tests from the Random123 distribution (Salmon et al.)."""

    def test_zero_counter_zero_key(self):
        assert _run((0, 0, 0, 0), (0, 0)) == [
            0x6627E8D5, 0xE169C58D, 0xBC57AC4C, 0x9B00DBD8]

    def test_all_ones(self):
        assert _run((0xFFFFFFFF,) * 4, (0xFFFFFFFF,) * 2) == [
            0x408F276D, 0x41C83B0E, 0xA20BC7C6, 0x6D5451FD]

    def test_pi_digits(self):
        assert _run(
            (0x243F6A88, 0x85A308D3, 0x13198A2E, 0x03707344),
            (0xA4093822, 0x299F31D0),
        ) == [0xD16CFE09, 0x94FDCCEB, 0x5001E420, 0x24126EA1]


class TestMulhilo:
    @pytest.mark.parametrize("a,b", [
        (0, 0), (1, 1), (0xFFFFFFFF, 0xFFFFFFFF), (0xD2511F53, 0x12345678),
        (0x10000, 0x10000), (0xDEADBEEF, 0xCAFEBABE), (1, 0xFFFFFFFF),
    ])
    def test_matches_64bit(self, a, b):
        hi, lo = prng.mulhilo32(jnp.uint32(a), jnp.uint32(b))
        prod = a * b
        assert int(hi) == prod >> 32
        assert int(lo) == prod & 0xFFFFFFFF

    def test_vectorized_matches_scalar(self):
        rng = np.random.default_rng(0)
        a = rng.integers(0, 2**32, size=100, dtype=np.uint32)
        b = rng.integers(0, 2**32, size=100, dtype=np.uint32)
        hi, lo = prng.mulhilo32(jnp.asarray(a), jnp.asarray(b))
        exp = a.astype(np.uint64) * b.astype(np.uint64)
        np.testing.assert_array_equal(np.asarray(hi), (exp >> 32).astype(np.uint32))
        np.testing.assert_array_equal(np.asarray(lo), exp.astype(np.uint32))


class TestDistributions:
    def test_uniform01_range(self):
        bits = jnp.arange(0, 2**32 - 1, 65537, dtype=jnp.uint32)
        u = prng.uniform01(bits)
        assert float(jnp.min(u)) > 0.0
        assert float(jnp.max(u)) < 1.0

    def test_normal_moments(self):
        i = jnp.arange(20000, dtype=jnp.uint32)
        z = prng.element_normal(i, jnp.uint32(0), 1, 2)
        z = np.asarray(z)
        assert abs(z.mean()) < 0.03
        assert abs(z.std() - 1.0) < 0.03
        # tail sanity: |z|>4 should be very rare
        assert (np.abs(z) > 6).sum() == 0

    def test_rademacher_balance(self):
        i = jnp.arange(20000, dtype=jnp.uint32)
        r = np.asarray(prng.element_rademacher(i, jnp.uint32(3), 5, 6))
        assert set(np.unique(r)) == {-1.0, 1.0}
        assert abs(r.mean()) < 0.03

    def test_uniform_int_range_and_mean(self):
        i = jnp.arange(20000, dtype=jnp.uint32)
        v = np.asarray(prng.element_uniform_int(jnp.uint32(0), i, 11, 13, 97))
        assert v.min() >= 0 and v.max() < 97
        assert abs(v.mean() - 48.0) < 2.0

    def test_streams_are_independent(self):
        i = jnp.arange(1000, dtype=jnp.uint32)
        a = np.asarray(prng.element_normal(i, jnp.uint32(0), 1, 2,
                                           prng.STREAM_SKETCH))
        b = np.asarray(prng.element_normal(i, jnp.uint32(0), 1, 2,
                                           prng.STREAM_SIGNS))
        assert not np.allclose(a, b)
        corr = np.corrcoef(a, b)[0, 1]
        assert abs(corr) < 0.1

    def test_seed_sensitivity(self):
        i = jnp.arange(1000, dtype=jnp.uint32)
        a = np.asarray(prng.element_normal(i, jnp.uint32(0), 1, 2))
        b = np.asarray(prng.element_normal(i, jnp.uint32(0), 1, 3))
        c = np.asarray(prng.element_normal(i, jnp.uint32(0), 2, 2))
        assert not np.allclose(a, b)
        assert not np.allclose(a, c)

    def test_determinism(self):
        i = jnp.arange(64, dtype=jnp.uint32)[:, None]
        j = jnp.arange(32, dtype=jnp.uint32)[None, :]
        a = np.asarray(prng.element_normal(i, j, 42, 43))
        b = np.asarray(prng.element_normal(i, j, 42, 43))
        np.testing.assert_array_equal(a, b)

    def test_position_stability(self):
        """Element (i, j) value is independent of evaluation tile/order."""
        full = np.asarray(prng.element_normal(
            jnp.arange(16, dtype=jnp.uint32)[:, None],
            jnp.arange(12, dtype=jnp.uint32)[None, :], 7, 8))
        one = float(prng.element_normal(jnp.uint32(9), jnp.uint32(5), 7, 8))
        assert full[9, 5] == one


class TestSplitSeed:
    def test_roundtrip(self):
        lo, hi = prng.split_seed(0x1234567890ABCDEF)
        assert lo == 0x90ABCDEF and hi == 0x12345678

    def test_negative_and_large(self):
        lo, hi = prng.split_seed(2**64 + 5)
        assert lo == 5 and hi == 0
