#!/usr/bin/env python3
"""Diff a fresh reports/BENCH_kernels.json against the committed baseline.

The bench report's `baseline_ref` field names the committed copy of
itself; this script resolves that copy via `git show HEAD:<ref>` and
prints per-kernel GFLOP/s deltas (keyed on kernel/backend/simd/shape),
plus the headline speedups.  It is a trend monitor, not a gate: every
exit path is status 0, so CI can run it unconditionally — a missing
fresh report, a repo with no committed baseline yet, or malformed JSON
all degrade to an explanatory message.

Usage: scripts/bench_diff.py [fresh_report] [--baseline-rev REV]
"""

import json
import subprocess
import sys

DEFAULT_REPORT = "reports/BENCH_kernels.json"


def row_key(row):
    return (
        row.get("kernel", "?"),
        row.get("backend", "?"),
        row.get("simd", "auto"),
        int(row.get("m", 0)),
        int(row.get("k", 0)),
        int(row.get("n", 0)),
    )


def load_fresh(path):
    try:
        with open(path) as f:
            return json.load(f)
    except OSError as e:
        print(f"bench-diff: no fresh report at {path} ({e}); nothing to diff")
    except ValueError as e:
        print(f"bench-diff: {path} is not valid JSON ({e})")
    return None


def load_baseline(rev, ref):
    proc = subprocess.run(
        ["git", "show", f"{rev}:{ref}"], capture_output=True, text=True
    )
    if proc.returncode != 0:
        print(
            f"bench-diff: no committed baseline at {rev}:{ref} "
            "(first run on this machine?); skipping diff"
        )
        return None
    try:
        return json.loads(proc.stdout)
    except ValueError as e:
        print(f"bench-diff: committed {rev}:{ref} is not valid JSON ({e})")
        return None


def main(argv):
    path = DEFAULT_REPORT
    rev = "HEAD"
    args = list(argv)
    while args:
        a = args.pop(0)
        if a == "--baseline-rev" and args:
            rev = args.pop(0)
        else:
            path = a

    fresh = load_fresh(path)
    if fresh is None:
        return 0
    ref = fresh.get("baseline_ref", DEFAULT_REPORT)
    base = load_baseline(rev, ref)
    if base is None:
        return 0

    base_rows = {row_key(r): r for r in base.get("rows", [])}
    fresh_rows = [(row_key(r), r) for r in fresh.get("rows", [])]
    print(f"bench-diff: {path} vs {rev}:{ref} ({len(fresh_rows)} rows)")

    for name in ("speedup_512", "sors_batched_speedup_1024"):
        f, b = fresh.get(name), base.get(name)
        if isinstance(f, (int, float)) and isinstance(b, (int, float)) and b:
            print(f"  {name}: {b:.2f}x -> {f:.2f}x ({100.0 * (f - b) / b:+.1f}%)")

    missing = 0
    for key, row in fresh_rows:
        kernel, backend, simd, m, k, n = key
        label = f"{kernel}/{backend}+{simd}/{m}x{k}x{n}"
        f_gf = row.get("gflops")
        b_row = base_rows.get(key)
        if b_row is None:
            print(f"  {label:<44} {f_gf:>8.2f} GFLOP/s  (new row)")
            continue
        b_gf = b_row.get("gflops")
        if not isinstance(f_gf, (int, float)) or not isinstance(b_gf, (int, float)) or not b_gf:
            print(f"  {label:<44} unmeasurable (null GFLOP/s)")
            continue
        delta = 100.0 * (f_gf - b_gf) / b_gf
        print(f"  {label:<44} {b_gf:>8.2f} -> {f_gf:>8.2f} GFLOP/s ({delta:+6.1f}%)")
    for key in base_rows:
        if key not in dict(fresh_rows):
            missing += 1
    if missing:
        print(f"bench-diff: {missing} baseline row(s) absent from the fresh report")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
