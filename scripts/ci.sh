#!/usr/bin/env bash
# Offline CI gate: formatting, lints, release build, tests.
# Run from anywhere; operates on the workspace root.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy =="
# Lints are advisory for now (no -D warnings): the offline toolchain's
# clippy version drifts, and a lint bump must not brick the gate.
cargo clippy --workspace --all-targets || true

echo "== cargo build --release =="
cargo build --release

# The test suite runs twice, serial and multi-threaded: the compute pool
# guarantees bit-identical results for every RMM_THREADS value, and the
# prop_pool/prop_kernels/prop_sweep equality assertions fail this gate on
# any divergence between the two configurations (prop_sweep also covers
# the sharded-sweep and prefetch-batcher bit-identity contracts).
echo "== cargo test (RMM_THREADS=1) =="
RMM_THREADS=1 cargo test -q

echo "== cargo test (RMM_THREADS=4) =="
RMM_THREADS=4 cargo test -q

# SIMD dispatch byte-identity gate, tier-1 half: the whole suite again
# with the microkernel pinned to the portable tile.  The auto runs above
# dispatched the widest ISA the CPU supports (avx512/avx2/neon), so any
# divergence between a SIMD kernel and the portable accumulation order
# fails the same equality assertions here (prop_kernels.rs additionally
# forces every supported level in-process and over subprocesses).
echo "== cargo test (RMM_SIMD=portable, RMM_THREADS=4) =="
RMM_SIMD=portable RMM_THREADS=4 cargo test -q

# Smoke the multi-process sweep path with real worker subprocesses: the
# mock grid sharded over 2 workers must merge byte-identically to the
# serial run (the --shards N vs --shards 1 acceptance check, minus the
# engine).  Run at both thread counts like the tests.
echo "== sweep smoke (mock grid, --shards 2, worker subprocesses) =="
RMM_THREADS=1 target/release/repro sweep-selftest --shards 2
RMM_THREADS=4 target/release/repro sweep-selftest --shards 2

# Same smoke under the dynamic claim/lease scheduler: workers pull cells
# through the shared claim store instead of --shard i/N round-robin, and
# the merged report must still match the serial bytes (prop_sched.rs is
# the fine-grained gate; this exercises the released binary end to end).
echo "== sweep smoke (mock grid, --shards 2, --schedule dynamic) =="
RMM_THREADS=1 target/release/repro sweep-selftest --shards 2 --schedule dynamic
RMM_THREADS=4 target/release/repro sweep-selftest --shards 2 --schedule dynamic

# Warm-session byte-identity gate: the data grid runs the session layer's
# real tokenizer/dataset caches and prefetch pipeline in worker processes.
# The selftest's serial reference is always computed COLD, so running the
# sharded side with --session-cache on AND off at both thread counts pins
# warm == cold == serial merged bytes end to end (prop_session.rs is the
# fine-grained gate).
echo "== sweep smoke (data grid, dynamic, session cache on/off) =="
for T in 1 4; do
  RMM_THREADS=$T target/release/repro sweep-selftest --shards 2 --schedule dynamic --grid data --session-cache on
  RMM_THREADS=$T target/release/repro sweep-selftest --shards 2 --schedule dynamic --grid data --session-cache off
done

# Chaos byte-identity gate: a fixed-seed fault schedule (worker kill
# mid-lease on slot 0, corrupted fragment commit, transient claim-store
# IO errors, clock skew on other slots — the "crash" profile) hits the
# sharded side only; the selftest's serial reference stays fault-free,
# so the byte-compare pins the chaos acceptance invariant end to end:
# faults may cost retries, reclaims and respawns, never results.  The
# synth grid is the seeded synthetic workload (skewed planned costs),
# run at both thread counts (prop_chaos.rs is the fine-grained gate).
echo "== sweep smoke (synth grid, dynamic, chaos: kill + corrupt + transient IO) =="
for T in 1 4; do
  RMM_THREADS=$T target/release/repro sweep-selftest --shards 2 --schedule dynamic \
    --grid synth-easy --chaos-seed 11 --chaos-profile crash
done

# Controller-determinism gate: the budget grid runs the closed-loop
# variance controller (plus fixed-estimator and approximate-VJP axes) on
# Philox probe tensors — engine-free like mock/data — and the selftest
# byte-compares the sharded dynamic run against the serial reference.
# This pins the (family, rho) choice sequence, its digest, and every
# fragment as a pure function of the cell for any worker/thread count.
echo "== sweep smoke (budget grid, dynamic, closed-loop controller) =="
for T in 1 4; do
  RMM_THREADS=$T target/release/repro sweep-selftest --shards 2 --schedule dynamic --grid budget
done

# SIMD dispatch byte-identity gate, end-to-end half: the budget grid's
# serial reference bytes under forced-portable dispatch vs the auto
# probe must be identical (the dispatch level, like thread count and
# blocking, is bit-invisible in every report).
echo "== sweep byte-compare (budget grid, RMM_SIMD=portable vs auto) =="
S=$(mktemp -d)
RMM_SIMD=portable target/release/repro sweep-selftest --grid budget --out "$S/portable.json"
target/release/repro sweep-selftest --grid budget --out "$S/auto.json"
cmp "$S/portable.json" "$S/auto.json"
rm -rf "$S"

# Daemon byte-identity gate: the same synth grid served through the
# sweep-daemon queue path (enqueue -> drain -> merge -> report) must
# publish exactly the bytes sweep-selftest --out writes for its serial
# reference, and --replay-verify requires the events.jsonl tee to
# round-trip the emitted typed event stream (ids and order included).
# Run at both thread counts like every other byte-identity gate
# (prop_events.rs is the fine-grained gate).
echo "== sweep daemon (synth grid through the queue path, replay-verified) =="
for T in 1 4; do
  Q=$(mktemp -d)
  RMM_THREADS=$T target/release/repro sweep-selftest --grid synth-easy --out "$Q/ref.json"
  RMM_THREADS=$T target/release/repro sweep-enqueue --queue "$Q/queue" --grid synth-easy --lane ci --name synth
  RMM_THREADS=$T target/release/repro sweep-daemon --queue "$Q/queue" --workers 2 --drain --replay-verify
  cmp "$Q/ref.json" "$Q/queue/reports/ci__synth.json"
  rm -rf "$Q"
done

# Daemon crash/resume gate: a seeded chaos kill takes the daemon down
# mid-sweep (exit code 86), leaving the dequeued spec in active/ and its
# committed fragments on disk; the --chaos-gen 1 restart (already-fired
# kills filtered from the replayed schedule) finishes exactly the
# missing cells and must publish the identical fault-free report bytes.
echo "== sweep daemon (chaos kill + resume) =="
Q=$(mktemp -d)
target/release/repro sweep-selftest --grid synth-easy --out "$Q/ref.json"
target/release/repro sweep-enqueue --queue "$Q/queue" --grid synth-easy --lane ci --name crash
set +e
target/release/repro sweep-daemon --queue "$Q/queue" --drain --lease-ttl-ms 1000 \
  --chaos-seed 11 --chaos-profile "sched.cell@2=kill"
code=$?
set -e
test "$code" -eq 86
test -f "$Q/queue/active/ci__crash.json"
target/release/repro sweep-daemon --queue "$Q/queue" --drain --lease-ttl-ms 1000 \
  --chaos-seed 11 --chaos-profile "sched.cell@2=kill" --chaos-gen 1
cmp "$Q/ref.json" "$Q/queue/reports/ci__crash.json"
rm -rf "$Q"

# Fleet gate: the synth-medium grid over 3 dynamic worker processes in
# fleet mode (--artifact-cache on): every worker registers in the
# workers/ registry under the sweep dir and shares the on-disk blob
# cache, while the seeded crash profile kills one registered worker
# mid-lease.  Survivors reclaim the orphaned cell (the dead worker's
# registry entry ages out like its stale claim) and the merged bytes
# must equal the selftest's fault-free COLD serial reference — the
# registry, the cache/ blobs, and the killed worker are all invisible
# to the report; cache hit/publish counters surface only in worker
# stderr (prop_sched.rs / prop_session.rs are the fine-grained gates).
echo "== sweep fleet (synth-medium, 3 registered workers, kill + shared cache) =="
for T in 1 4; do
  RMM_THREADS=$T target/release/repro sweep-selftest --shards 3 --schedule dynamic \
    --grid synth-medium --chaos-seed 11 --chaos-profile crash --artifact-cache on
done

# Perf-trend monitor (non-gating): regenerate the kernel GFLOP/s report
# and diff it against the committed baseline named by its baseline_ref.
# Timing noise must never brick the gate, so both steps are best-effort.
echo "== bench diff vs committed baseline (non-gating) =="
cargo bench -p rmmlinear --bench rmm_micro -- --json || true
python3 scripts/bench_diff.py || true

echo "ci: all gates passed"
