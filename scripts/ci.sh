#!/usr/bin/env bash
# Offline CI gate: formatting, lints, release build, tests.
# Run from anywhere; operates on the workspace root.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy =="
# Lints are advisory for now (no -D warnings): the offline toolchain's
# clippy version drifts, and a lint bump must not brick the gate.
cargo clippy --workspace --all-targets || true

echo "== cargo build --release =="
cargo build --release

echo "== cargo test =="
cargo test -q

echo "ci: all gates passed"
