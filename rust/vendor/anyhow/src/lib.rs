//! Offline stand-in for the `anyhow` crate, covering exactly the subset the
//! workspace uses: `Result`/`Error`, `anyhow!`, `bail!`, and the `Context`
//! extension trait (`.context(..)` / `.with_context(..)` on `Result` and
//! `Option`).  Error chains render like upstream anyhow: `{}` shows the
//! outermost message, `{:#}` the colon-joined chain, `{:?}` a multi-line
//! "Caused by" listing.
//!
//! Mirrors upstream's coherence trick: `Error` deliberately does NOT
//! implement `std::error::Error`, which is what lets the blanket
//! `From<E: std::error::Error>` impl and the `Context` impls coexist.

use std::fmt;

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error with a chain of context messages.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: ctx.to_string(), source: Some(Box::new(self)) }
    }

    /// Capture a `std::error::Error` together with its source chain.
    pub fn from_std(e: &(dyn std::error::Error + 'static)) -> Error {
        let source = e.source().map(|s| Box::new(Error::from_std(s)));
        Error { msg: e.to_string(), source }
    }

    /// Iterate the chain from the outermost message inward.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    /// The innermost error message.
    pub fn root_cause(&self) -> &Error {
        let mut cur = self;
        while let Some(s) = &cur.source {
            cur = s;
        }
        cur
    }
}

/// Iterator over an error chain (see [`Error::chain`]).
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;

    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next?;
        self.next = cur.source.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = self.source.as_deref();
            let mut i = 0usize;
            while let Some(e) = cur {
                write!(f, "\n    {i}: {}", e.msg)?;
                cur = e.source.as_deref();
                i += 1;
            }
        }
        Ok(())
    }
}

// `Error: !std::error::Error`, so this cannot overlap `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

mod ext {
    use super::Error;

    /// Sealed conversion helper so `Context` covers both plain
    /// `std::error::Error` values and `anyhow::Error` itself.
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> Error {
            Error::from_std(&self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_render() {
        let r: Result<()> = Err(io_err()).context("opening config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: gone");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context_and_bail() {
        fn f(x: Option<u32>) -> Result<u32> {
            let v = x.context("missing")?;
            if v == 0 {
                bail!("zero not allowed: {v}");
            }
            Ok(v)
        }
        assert_eq!(f(Some(3)).unwrap(), 3);
        assert_eq!(format!("{}", f(None).unwrap_err()), "missing");
        assert_eq!(format!("{}", f(Some(0)).unwrap_err()), "zero not allowed: 0");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here")?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn with_context_and_chain_iter() {
        let r: Result<(), Error> = Err(Error::msg("inner"));
        let e = r.with_context(|| format!("outer {}", 1)).unwrap_err();
        let msgs: Vec<String> = e.chain().map(|x| x.msg.clone()).collect();
        assert_eq!(msgs, vec!["outer 1".to_string(), "inner".to_string()]);
        assert_eq!(format!("{}", e.root_cause()), "inner");
    }

    #[test]
    fn anyhow_macro_forms() {
        let a = anyhow!("plain");
        let b = anyhow!("x = {}", 2);
        let c = anyhow!(String::from("owned"));
        assert_eq!(format!("{a}"), "plain");
        assert_eq!(format!("{b}"), "x = 2");
        assert_eq!(format!("{c}"), "owned");
    }
}
