//! The O(B²N) dense-sketch vs O(BN log B) fast-transform crossover
//! (paper §3.5: DCT/DFT "have theoretically computational advantage" —
//! here we measure where it actually materializes), plus the batched
//! (panel-FFT, pool-dispatched) vs column-by-column SORS comparison.

use rmmlinear::rmm::fft::{sors_project_cols, sors_project_fast};
use rmmlinear::rmm::{self, SketchKind};
use rmmlinear::rng::philox::PhiloxStream;
use rmmlinear::tensor::{kernels, pool, Tensor};
use rmmlinear::util::bench::{black_box, Bencher};

fn randt(rows: usize, cols: usize, seed: u64) -> Tensor {
    let mut s = PhiloxStream::new(seed, 3);
    Tensor::from_fn(rows, cols, |_, _| s.next_normal())
}

fn main() {
    kernels::init_from_env();
    println!(
        "host backend: {} ({} threads, {} pool workers)",
        kernels::active().name(),
        kernels::threads::num_threads(),
        pool::global().workers(),
    );
    let mut b = Bencher::new();
    let n = 64;
    for log_b in [6usize, 8, 10, 12] {
        let rows = 1 << log_b;
        let b_proj = rows / 8;
        let x = randt(rows, n, log_b as u64);
        b.bench(&format!("dense_gauss/B={rows}"), || {
            black_box(rmm::project(SketchKind::Gauss, &x, b_proj, (1, 2)));
        });
        b.bench(&format!("dense_dct/B={rows}"), || {
            black_box(rmm::project(SketchKind::Dct, &x, b_proj, (1, 2)));
        });
        // batched panel path (the default) under its own label, with the
        // column-by-column reference alongside for the same shape
        b.bench(&format!("fast_dct_batched/B={rows}"), || {
            black_box(sors_project_fast(true, &x, b_proj, (1, 2)));
        });
        b.bench(&format!("fast_dct_cols/B={rows}"), || {
            black_box(sors_project_cols(true, &x, b_proj, (1, 2)));
        });
        b.bench(&format!("fast_dft_batched/B={rows}"), || {
            black_box(sors_project_fast(false, &x, b_proj, (1, 2)));
        });
        b.bench(&format!("fast_dft_cols/B={rows}"), || {
            black_box(sors_project_cols(false, &x, b_proj, (1, 2)));
        });
    }
    // The batched path must be visible in the report under its own label
    // (downstream tooling diffs on these names).
    for needle in ["fast_dct_batched/", "fast_dft_batched/", "fast_dct_cols/"] {
        assert!(
            b.results.iter().any(|r| r.name.contains(needle)),
            "missing '{needle}' series in the crossover report"
        );
    }
    b.write_report("reports/bench_fft_crossover.json");
}
