//! End-to-end training-step latency through PJRT per compression ratio —
//! the Fig. 6 measurement as a microbench (fwd + store + bwd + optimizer).
//!
//! Requires `make artifacts`; skips gracefully when artifacts are missing
//! (e.g. bare `cargo bench` in CI before the AOT step).

use std::path::Path;

use rmmlinear::config::TrainConfig;
use rmmlinear::coordinator::Trainer;
use rmmlinear::data::{Batcher, Split, Task, TaskGen, Tokenizer};
use rmmlinear::runtime::{Engine, Manifest};
use rmmlinear::util::bench::Bencher;

fn main() {
    rmmlinear::tensor::kernels::init_from_env();
    let manifest = match Manifest::load(Path::new("artifacts")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping step_latency bench (no artifacts): {e}");
            return;
        }
    };
    let mut engine = Engine::cpu().expect("pjrt cpu");
    let mut b = Bencher::new();

    for tag in ["r100", "r50", "r20", "r10"] {
        let vname = format!("small_cls2_{tag}_gauss");
        let variant = match manifest.variant(&vname) {
            Ok(v) => v,
            Err(_) => continue,
        };
        let cfg = TrainConfig { steps: 1, warmup_steps: 0, ..Default::default() };
        let tok = Tokenizer::new(variant.config.vocab_size);
        let mut trainer =
            Trainer::new(&manifest, variant, Task::Cola, cfg).expect("trainer");
        let gen = TaskGen::new(Task::Cola, &tok, variant.config.seq_len, 1);
        let batch = Batcher::new(&gen, Split::Train, variant.config.batch_size, 0)
            .next()
            .unwrap();
        // warm the compile cache outside the timed region
        trainer.train_step(&mut engine, &batch).expect("warmup step");
        b.bench(&format!("train_step/{tag}"), || {
            trainer.train_step(&mut engine, &batch).expect("step");
        });
    }

    // eval-only latency (logits path)
    if let Ok(variant) = manifest.variant("small_cls2_r100_gauss") {
        let cfg = TrainConfig { steps: 1, warmup_steps: 0, ..Default::default() };
        let tok = Tokenizer::new(variant.config.vocab_size);
        let mut trainer =
            Trainer::new(&manifest, variant, Task::Cola, cfg).expect("trainer");
        trainer.evaluate(&mut engine, &tok).expect("warm eval");
        b.bench("evaluate_dev/cola/r100", || {
            trainer.evaluate(&mut engine, &tok).expect("eval");
        });
    }

    b.write_report("reports/bench_step_latency.json");
}
