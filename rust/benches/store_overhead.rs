//! ActivationStore bookkeeping overhead: the L3 store must be negligible
//! next to a training step (paper's coordinator should never be the
//! bottleneck).  Also benches the JSON codec and the literal staging copy
//! that sit on the step path.

use rmmlinear::memory::ActivationStore;
use rmmlinear::util::bench::{black_box, Bencher};
use rmmlinear::util::json::Json;

fn main() {
    rmmlinear::tensor::kernels::init_from_env();
    let mut b = Bencher::new();

    // Typical step: ~32 residuals staged then drained.
    let names: Vec<String> = (0..32).map(|i| format!("blk{}.res{}", i / 8, i)).collect();
    b.bench("store/put_take_32", || {
        let mut s: ActivationStore<Vec<f32>> = ActivationStore::new();
        for n in &names {
            s.put(n, vec![0.0f32; 16], 64);
        }
        for n in &names {
            black_box(s.take(n));
        }
    });

    // Host param clone (the per-step upload staging copy).
    let params: Vec<Vec<f32>> = vec![vec![0.5f32; 4096]; 32];
    b.bench("staging/clone_params_512k", || {
        black_box(params.clone());
    });

    // Metrics JSON encode (log hot path).
    b.bench("json/encode_metric_record", || {
        let rec = Json::obj(vec![
            ("step", Json::num(123.0)),
            ("loss", Json::num(0.451)),
            ("lr", Json::num(1e-4)),
            ("grad_norm", Json::num(2.3)),
        ]);
        black_box(rec.to_string());
    });

    let manifest_like = r#"{"version":2,"variants":{"v":{"rows":512,"entries":{"fwd":{"file":"f","args":[],"outputs":[]}}}}}"#;
    b.bench("json/parse_small_manifest", || {
        black_box(Json::parse(manifest_like).unwrap());
    });

    b.write_report("reports/bench_store_overhead.json");
}
