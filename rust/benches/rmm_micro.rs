//! Micro-bench: the Table 1 cost model on the host reference —
//! exact ∂W = YᵀX vs RMM's project+contract at several ρ, plus the
//! streamed (O(1)-memory-for-S) projection vs dense-S materialization,
//! plus the kernel-backend GFLOP/s sweep (scalar vs packed per shape).
//!
//! Expected shape: RMM backward cost scales ~linearly with ρ; the
//! crossover vs exact happens below ρ ≈ N_in/(B + N_in) (paper §2.4.2);
//! the packed backend clears the scalar reference by ≥4× at 512³.
//!
//! `--json` additionally writes `reports/BENCH_kernels.json` (GFLOP/s per
//! kernel × shape × backend — including one forced row per supported SIMD
//! dispatch level — the 512³ speedup, the active dispatch level + tuned
//! cache blocking, the compute pool's task grain / steal counters, the
//! batched-vs-column SORS comparison, and the closed-form variance-at-ρ
//! entry per estimator configuration) so later PRs have a perf trajectory
//! to diff against; `baseline_ref` names the committed report
//! `scripts/bench_diff.py` diffs a fresh run against.

use rmmlinear::bench_harness::runner::num_or_null;
use rmmlinear::data::{AnyBatcher, Batcher, Split, Task, TaskGen, Tokenizer};
use rmmlinear::rmm::{self, fft, sketch, SketchKind};
use rmmlinear::rng::philox::PhiloxStream;
use rmmlinear::tensor::kernels::{self, dispatch, packed, tune, Backend, PACKED, SCALAR};
use rmmlinear::tensor::{matmul_at, pool, Tensor};
use rmmlinear::util::bench::{black_box, Bencher};
use rmmlinear::util::json::Json;

fn randt(rows: usize, cols: usize, seed: u64) -> Tensor {
    let mut s = PhiloxStream::new(seed, 3);
    Tensor::from_fn(rows, cols, |_, _| s.next_normal())
}

struct KernelRow {
    kernel: &'static str,
    backend: &'static str,
    /// Dispatch level the row ran at: a forced level name, or "auto"
    /// (whatever `active_level()` resolved when the bench started).
    simd: &'static str,
    m: usize,
    k: usize,
    n: usize,
    mean_ns: f64,
    gflops: f64,
}

impl KernelRow {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kernel", Json::str(self.kernel)),
            ("backend", Json::str(self.backend)),
            ("simd", Json::str(self.simd)),
            ("m", Json::num(self.m as f64)),
            ("k", Json::num(self.k as f64)),
            ("n", Json::num(self.n as f64)),
            ("mean_ns", Json::num(self.mean_ns)),
            ("gflops", Json::num(self.gflops)),
        ])
    }
}

/// Time one kernel invocation and derive its GFLOP/s row (all BENCH_kernels
/// rows share the 2·m·k·n useful-flops accounting).
fn bench_row(
    b: &mut Bencher,
    kernel: &'static str,
    backend: &'static str,
    label: &str,
    (m, k, n): (usize, usize, usize),
    f: impl FnMut(),
) -> KernelRow {
    let mean_ns = b.bench(label, f).mean_ns;
    KernelRow {
        kernel,
        backend,
        simd: "auto",
        m,
        k,
        n,
        mean_ns,
        gflops: 2.0 * (m * k * n) as f64 / mean_ns,
    }
}

fn main() {
    kernels::init_from_env();
    let json_mode = std::env::args().any(|a| a == "--json");
    let mut b = Bencher::new();
    let (rows, n_in, n_out) = (512, 64, 256);
    let x = randt(rows, n_in, 1);
    let y = randt(rows, n_out, 2);

    b.bench("exact_grad_w/512x64x256", || {
        black_box(rmm::exact_grad_w(&y, &x));
    });

    for rho in [0.5f64, 0.2, 0.1, 0.05] {
        let b_proj = ((rho * rows as f64) as usize).max(1);
        let x_proj = rmm::project(SketchKind::Gauss, &x, b_proj, (7, 8));
        b.bench(&format!("rmm_grad_w/gauss/rho={rho}"), || {
            black_box(rmm::rmm_grad_w(SketchKind::Gauss, &y, &x_proj, (7, 8)));
        });
        b.bench(&format!("project/gauss/rho={rho}"), || {
            black_box(rmm::project(SketchKind::Gauss, &x, b_proj, (7, 8)));
        });
    }

    // Streamed (fused, tile-generated S) projection vs dense-S
    // materialization (memory-traffic study)
    let b_proj = 64;
    b.bench("project_streamed/gauss", || {
        black_box(sketch::project_streamed(SketchKind::Gauss, &x, b_proj, (3, 4)));
    });
    b.bench("project_dense_s/gauss", || {
        let s = sketch::sketch(SketchKind::Gauss, rows, b_proj, (3, 4));
        black_box(matmul_at(&s, &x));
    });

    // Sketch-family generation cost at fixed rho (Table 4's cost axis);
    // dct/dft/rowsample now run the fused path instead of dense fallback.
    for kind in SketchKind::ALL {
        b.bench(&format!("project/{}/rho=0.2", kind.name()), || {
            black_box(rmm::project(kind, &x, 102, (5, 6)));
        });
    }

    // ---- kernel backend sweep: GFLOP/s per kernel × shape × backend ----
    let backends: [(&'static str, &'static dyn Backend); 2] =
        [("scalar", &SCALAR), ("packed", &PACKED)];
    let mut krows: Vec<KernelRow> = Vec::new();

    for &(m, k, n) in
        &[(64usize, 64usize, 64usize), (128, 128, 128), (256, 256, 256), (384, 256, 512), (512, 512, 512)]
    {
        let a = randt(m, k, 11);
        let bm = randt(k, n, 12);
        for (bname, bk) in backends {
            let label = format!("gemm/{bname}/{m}x{k}x{n}");
            krows.push(bench_row(&mut b, "matmul", bname, &label, (m, k, n), || {
                black_box(bk.matmul(&a, &bm));
            }));
        }
    }

    // ---- forced SIMD dispatch rows: GFLOP/s per microkernel ISA ----
    // The packed driver fetches its microkernel per GEMM call, so
    // overriding the dispatch level between timings measures every ISA
    // this CPU supports on the same tensors (outputs are bit-identical
    // by the dispatch contract — only throughput moves).  "auto" rows
    // elsewhere ran whatever `active_level()` resolved at startup.
    for level in dispatch::supported_levels() {
        dispatch::set_simd_override(Some(level)).expect("level came from supported_levels");
        for &(m, k, n) in &[(256usize, 256usize, 256usize), (512, 512, 512)] {
            let a = randt(m, k, 11);
            let bm = randt(k, n, 12);
            let label = format!("gemm/packed+{}/{m}x{k}x{n}", level.name());
            let mut row = bench_row(&mut b, "matmul", "packed", &label, (m, k, n), || {
                black_box(PACKED.matmul(&a, &bm));
            });
            row.simd = level.name();
            krows.push(row);
        }
    }
    dispatch::set_simd_override(None).expect("clearing the override is infallible");

    // transpose variants at one representative shape
    {
        let (m, k, n) = (256usize, 256usize, 256usize);
        let at = randt(k, m, 13); // (k, m) operand for Aᵀ·B
        let bn = randt(k, n, 14);
        let bt = randt(n, k, 15); // (n, k) operand for A·Bᵀ
        let am = randt(m, k, 16);
        for (bname, bk) in backends {
            let label = format!("gemm_at/{bname}/{m}x{k}x{n}");
            krows.push(bench_row(&mut b, "matmul_at", bname, &label, (m, k, n), || {
                black_box(bk.matmul_at(&at, &bn));
            }));
            let label = format!("gemm_bt/{bname}/{m}x{k}x{n}");
            krows.push(bench_row(&mut b, "matmul_bt", bname, &label, (m, k, n), || {
                black_box(bk.matmul_bt(&am, &bt));
            }));
        }
    }

    // fused projection throughput per family (2·B·B_proj·N useful flops)
    {
        let (bb, nn, bp) = (512usize, 256usize, 128usize);
        let xp = randt(bb, nn, 17);
        for kind in SketchKind::ALL {
            let label = format!("project_fused/{}/{bb}x{bp}x{nn}", kind.name());
            krows.push(bench_row(
                &mut b,
                "project_streamed",
                kind.name(),
                &label,
                (bb, bp, nn),
                || {
                    black_box(sketch::project_streamed(kind, &xp, bp, (5, 6)));
                },
            ));
        }
    }

    // ---- closed-form variance at ρ per family (Lemma 2.2 closed forms) ----
    // One row per (estimator configuration, ρ) on the bench tensors above,
    // mirroring the equal-budget table's accuracy axis: the seven
    // configurations are the six `SketchKind`s plus the approximate-VJP
    // variant (grad-weight variance identical to its underlying family,
    // grad-input exact).
    let variance_rows: Vec<Json> = {
        let mut vrows = Vec::new();
        for rho in [0.5f64, 0.2, 0.1, 0.05] {
            let b_proj = ((rho * rows as f64) as usize).max(1);
            for kind in SketchKind::ALL {
                vrows.push(Json::obj(vec![
                    ("estimator", Json::str(kind.name())),
                    ("rho", Json::num(rho)),
                    ("b_proj", Json::num(b_proj as f64)),
                    ("d2", num_or_null(rmm::variance::d2_family(kind, &x, &y, b_proj))),
                ]));
            }
            vrows.push(Json::obj(vec![
                ("estimator", Json::str("avjp-gauss")),
                ("rho", Json::num(rho)),
                ("b_proj", Json::num(b_proj as f64)),
                (
                    "d2",
                    num_or_null(rmm::variance::d2_approx_vjp(
                        SketchKind::Gauss,
                        &x,
                        &y,
                        b_proj,
                    )),
                ),
            ]));
        }
        vrows
    };
    println!(
        "variance-at-rho entries: {} (families x rho, incl. avjp-gauss)",
        variance_rows.len()
    );

    // ---- batched vs column-by-column SORS (the fft.rs rewrite) ----
    let mut sors_batched_speedup_1024 = f64::NAN;
    for &bb in &[1024usize, 2048] {
        let (nn, bp) = (64usize, bb / 8);
        let xs = randt(bb, nn, 23);
        let batched = {
            let label = format!("sors_fast/batched/B={bb}");
            bench_row(&mut b, "sors_fast", "batched", &label, (bb, bp, nn), || {
                black_box(fft::sors_project_fast(true, &xs, bp, (5, 6)));
            })
        };
        let cols = {
            let label = format!("sors_fast/cols/B={bb}");
            bench_row(&mut b, "sors_fast", "cols", &label, (bb, bp, nn), || {
                black_box(fft::sors_project_cols(true, &xs, bp, (5, 6)));
            })
        };
        if bb == 1024 && batched.mean_ns > 0.0 {
            sors_batched_speedup_1024 = cols.mean_ns / batched.mean_ns;
        }
        krows.push(batched);
        krows.push(cols);
    }
    println!("batched vs column SORS speedup @ B=1024: {sors_batched_speedup_1024:.2}x");

    // ---- prefetch on/off step latency (the sweep cell's inner loop) ----
    // One dev epoch of the SST2-like task with a small GEMM standing in
    // for the per-step compute; prefetch overlaps batch assembly with it,
    // so the per-batch delta is the data-pipeline latency bought back.
    let tok = Tokenizer::new(256);
    let gen = TaskGen::new(Task::Sst2, &tok, 32, 7);
    let pbsz = 32usize;
    let n_batches = Batcher::new(&gen, Split::Dev, pbsz, 0).n_batches() as f64;
    let step_a = randt(48, 48, 31);
    let step_b = randt(48, 48, 32);
    let sync_epoch_ns = b
        .bench("batcher/sync/sst2_dev_epoch", || {
            for batch in AnyBatcher::new(&gen, Split::Dev, pbsz, 0, false, 1) {
                black_box(&batch);
                black_box(PACKED.matmul(&step_a, &step_b));
            }
        })
        .mean_ns;
    let prefetch_epoch_ns = b
        .bench("batcher/prefetch/sst2_dev_epoch", || {
            for batch in AnyBatcher::new(&gen, Split::Dev, pbsz, 0, true, 1) {
                black_box(&batch);
                black_box(PACKED.matmul(&step_a, &step_b));
            }
        })
        .mean_ns;
    let prefetch2_epoch_ns = b
        .bench("batcher/prefetch_d2/sst2_dev_epoch", || {
            for batch in AnyBatcher::new(&gen, Split::Dev, pbsz, 0, true, 2) {
                black_box(&batch);
                black_box(PACKED.matmul(&step_a, &step_b));
            }
        })
        .mean_ns;
    let sync_ns_per_batch = sync_epoch_ns / n_batches;
    let prefetch_ns_per_batch = prefetch_epoch_ns / n_batches;
    let prefetch2_ns_per_batch = prefetch2_epoch_ns / n_batches;
    println!(
        "prefetch step latency: sync {:.1} µs/batch, prefetch d1 {:.1} µs/batch \
         ({:.2}x), d2 {:.1} µs/batch ({:.2}x)",
        sync_ns_per_batch / 1e3,
        prefetch_ns_per_batch / 1e3,
        sync_ns_per_batch / prefetch_ns_per_batch,
        prefetch2_ns_per_batch / 1e3,
        sync_ns_per_batch / prefetch2_ns_per_batch
    );

    // ---- warm-session executable reuse: cache stats per cell schedule ----
    // Replays a Table-2-shaped cell list (6 variants × 4 tasks × 2 seeds;
    // every cell touches its variant's fwd/bwd/eval artifacts) through
    // the engine's real `ExeCache` structure at a capacity that holds 3
    // warm variants.  The canonical grid order interleaves variants (the
    // cold-start worst case); the affinity schedule groups same-variant
    // cells the way the warm-session scheduler claims them.
    let exe_cache_sim = {
        use rmmlinear::runtime::ExeCache;
        let (variants, tasks, seeds, entries) = (6usize, 4usize, 2usize, 3usize);
        let capacity = 3 * entries; // 3 warm variants
        let mut canonical = Vec::new();
        for t in 0..tasks {
            for v in 0..variants {
                for _s in 0..seeds {
                    canonical.push(v);
                }
            }
        }
        let mut affinity = canonical.clone();
        affinity.sort_unstable(); // group same-variant cells, order preserved
        let replay = |order: &[usize]| {
            let mut cache: ExeCache<usize> = ExeCache::new(capacity);
            let (mut hits, mut misses, mut evictions) = (0u64, 0u64, 0u64);
            for &v in order {
                for entry in ["fwd", "bwd", "eval"] {
                    let key = format!("v{v}/{entry}");
                    if cache.get(&key).is_some() {
                        hits += 1;
                    } else {
                        misses += 1;
                        evictions += cache.insert(key, v);
                    }
                }
            }
            (hits, misses, evictions)
        };
        let (ch, cm, ce) = replay(&canonical);
        let (ah, am, ae) = replay(&affinity);
        println!(
            "exe cache (cap {capacity} exes, {} cells): canonical {ch}h/{cm}m/{ce}ev, \
             affinity {ah}h/{am}m/{ae}ev — same-variant reuse {:.0}% vs {:.0}%",
            canonical.len(),
            100.0 * ah as f64 / (ah + am) as f64,
            100.0 * ch as f64 / (ch + cm) as f64,
        );
        let stats = |h: u64, m: u64, e: u64| {
            Json::obj(vec![
                ("hits", Json::num(h as f64)),
                ("misses", Json::num(m as f64)),
                ("evictions", Json::num(e as f64)),
                ("hit_rate", num_or_null(h as f64 / (h + m) as f64)),
            ])
        };
        Json::obj(vec![
            ("capacity", Json::num(capacity as f64)),
            ("cells", Json::num(canonical.len() as f64)),
            ("entries_per_cell", Json::num(3.0)),
            ("canonical_order", stats(ch, cm, ce)),
            ("affinity_order", stats(ah, am, ae)),
        ])
    };

    let speedup_512 = {
        let find = |bname: &str| {
            krows
                .iter()
                .find(|r| r.kernel == "matmul" && r.backend == bname && r.m == 512)
                .map(|r| r.mean_ns)
        };
        match (find("scalar"), find("packed")) {
            (Some(s), Some(p)) if p > 0.0 => s / p,
            _ => f64::NAN,
        }
    };
    println!("packed vs scalar speedup @ 512x512x512: {speedup_512:.2}x");

    // ---- pool observability: task grain + steal counts for one 512³ ----
    let nt = kernels::threads::num_threads();
    let pool_512 = {
        let a = randt(512, 512, 21);
        let bm = randt(512, 512, 22);
        let before = pool::stats();
        black_box(PACKED.matmul(&a, &bm));
        pool::stats().delta_since(before)
    };
    let totals = pool::stats();
    println!(
        "pool: {} threads ({} workers), 512³ grain {} rows, {} tasks / {} steals per 512³ gemm",
        nt,
        pool::global().workers(),
        packed::gemm_task_grain(512, nt),
        pool_512.tasks,
        pool_512.steals,
    );

    // ---- dispatch + blocking observability (stderr, like exe-cache) ----
    let level = dispatch::active_level();
    let blk = tune::blocking();
    eprintln!(
        "simd dispatch: active {} (probe {}, supported: {}); blocking mc={} kc={} nc={} ({})",
        level.name(),
        dispatch::probe().name(),
        dispatch::supported_levels()
            .iter()
            .map(|l| l.name())
            .collect::<Vec<_>>()
            .join(","),
        blk.mc,
        blk.kc,
        blk.nc,
        if tune::blocking_override().is_some() { "tuned" } else { "default" },
    );

    b.write_report("reports/bench_rmm_micro.json");
    if json_mode {
        let report = Json::obj(vec![
            ("experiment", Json::str("kernels")),
            // The committed copy of this report a fresh run should be
            // diffed against (scripts/bench_diff.py resolves it via
            // `git show HEAD:<baseline_ref>`).
            ("baseline_ref", Json::str("reports/BENCH_kernels.json")),
            ("threads", Json::num(nt as f64)),
            ("default_backend", Json::str(kernels::active().name())),
            (
                "simd",
                Json::obj(vec![
                    ("level", Json::str(level.name())),
                    ("probe", Json::str(dispatch::probe().name())),
                    (
                        "supported",
                        Json::Arr(
                            dispatch::supported_levels()
                                .iter()
                                .map(|l| Json::str(l.name()))
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                "blocking",
                Json::obj(vec![
                    ("mc", Json::num(blk.mc as f64)),
                    ("kc", Json::num(blk.kc as f64)),
                    ("nc", Json::num(blk.nc as f64)),
                    ("tuned", Json::Bool(tune::blocking_override().is_some())),
                ]),
            ),
            // num_or_null: the JSON codec rejects NaN, and either speedup
            // can be NaN if a timing came back degenerate
            ("speedup_512", num_or_null(speedup_512)),
            ("sors_batched_speedup_1024", num_or_null(sors_batched_speedup_1024)),
            (
                "prefetch",
                Json::obj(vec![
                    ("task", Json::str("sst2")),
                    ("split", Json::str("dev")),
                    ("batch_size", Json::num(pbsz as f64)),
                    ("batches_per_epoch", Json::num(n_batches)),
                    ("sync_ns_per_batch", num_or_null(sync_ns_per_batch)),
                    ("prefetch_ns_per_batch", num_or_null(prefetch_ns_per_batch)),
                    (
                        "prefetch_depth2_ns_per_batch",
                        num_or_null(prefetch2_ns_per_batch),
                    ),
                    (
                        "delta_ns_per_batch",
                        num_or_null(sync_ns_per_batch - prefetch_ns_per_batch),
                    ),
                    (
                        "speedup",
                        num_or_null(sync_ns_per_batch / prefetch_ns_per_batch),
                    ),
                ]),
            ),
            ("exe_cache", exe_cache_sim),
            (
                "pool",
                Json::obj(vec![
                    ("threads", Json::num(nt as f64)),
                    ("workers", Json::num(pool::global().workers() as f64)),
                    (
                        "gemm_grain_512",
                        Json::num(packed::gemm_task_grain(512, nt) as f64),
                    ),
                    ("tasks_per_512_gemm", Json::num(pool_512.tasks as f64)),
                    ("steals_per_512_gemm", Json::num(pool_512.steals as f64)),
                    ("total_runs", Json::num(totals.runs as f64)),
                    ("total_par_runs", Json::num(totals.par_runs as f64)),
                    ("total_tasks", Json::num(totals.tasks as f64)),
                    ("total_steals", Json::num(totals.steals as f64)),
                ]),
            ),
            ("variance", Json::Arr(variance_rows)),
            ("rows", Json::Arr(krows.iter().map(|r| r.to_json()).collect())),
        ]);
        let path = "reports/BENCH_kernels.json";
        if let Some(dir) = std::path::Path::new(path).parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        match std::fs::write(path, report.to_string_pretty()) {
            Ok(()) => println!("report -> {path}"),
            Err(e) => eprintln!("warn: could not write {path}: {e}"),
        }
    }
}
