//! Micro-bench: the Table 1 cost model on the host reference —
//! exact ∂W = YᵀX vs RMM's project+contract at several ρ, plus the
//! streamed (O(1)-memory-for-S) projection vs dense-S materialization.
//!
//! Expected shape: RMM backward cost scales ~linearly with ρ; the
//! crossover vs exact happens below ρ ≈ N_in/(B + N_in) (paper §2.4.2).

use rmmlinear::rmm::{self, sketch, SketchKind};
use rmmlinear::rng::philox::PhiloxStream;
use rmmlinear::tensor::{matmul_at, Tensor};
use rmmlinear::util::bench::{black_box, Bencher};

fn randt(rows: usize, cols: usize, seed: u64) -> Tensor {
    let mut s = PhiloxStream::new(seed, 3);
    Tensor::from_fn(rows, cols, |_, _| s.next_normal())
}

fn main() {
    let mut b = Bencher::new();
    let (rows, n_in, n_out) = (512, 64, 256);
    let x = randt(rows, n_in, 1);
    let y = randt(rows, n_out, 2);

    b.bench("exact_grad_w/512x64x256", || {
        black_box(rmm::exact_grad_w(&y, &x));
    });

    for rho in [0.5f64, 0.2, 0.1, 0.05] {
        let b_proj = ((rho * rows as f64) as usize).max(1);
        let x_proj = rmm::project(SketchKind::Gauss, &x, b_proj, (7, 8));
        b.bench(&format!("rmm_grad_w/gauss/rho={rho}"), || {
            black_box(rmm::rmm_grad_w(SketchKind::Gauss, &y, &x_proj, (7, 8)));
        });
        b.bench(&format!("project/gauss/rho={rho}"), || {
            black_box(rmm::project(SketchKind::Gauss, &x, b_proj, (7, 8)));
        });
    }

    // Streamed projection vs dense-S materialization (memory-traffic study)
    let b_proj = 64;
    b.bench("project_streamed/gauss", || {
        black_box(sketch::project_streamed(SketchKind::Gauss, &x, b_proj, (3, 4)));
    });
    b.bench("project_dense_s/gauss", || {
        let s = sketch::sketch(SketchKind::Gauss, rows, b_proj, (3, 4));
        black_box(matmul_at(&s, &x));
    });

    // Sketch-family generation cost at fixed rho (Table 4's cost axis)
    for kind in SketchKind::ALL {
        b.bench(&format!("project/{}/rho=0.2", kind.name()), || {
            black_box(rmm::project(kind, &x, 102, (5, 6)));
        });
    }

    b.write_report("reports/bench_rmm_micro.json");
}
