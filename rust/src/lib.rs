//! # rmmlinear
//!
//! Production-grade reproduction of **"Memory-Efficient Backpropagation
//! through Large Linear Layers"** (Bershatsky et al., 2022) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L1** — Pallas kernels (build-time Python) computing the randomized
//!   projection `X_proj = SᵀX` with the sketch matrix generated *inside*
//!   the kernel from a Philox counter PRNG (never materialized in HBM).
//! * **L2** — an explicit-residual transformer encoder (build-time JAX)
//!   whose hand-written backward implements the paper's Algorithm 1,
//!   AOT-lowered to HLO-text artifacts.
//! * **L3** — this crate: the training coordinator that loads the
//!   artifacts via PJRT, owns the residual buffers between `fwd` and
//!   `bwd` (making the paper's memory claim a measured quantity), runs
//!   optimizers/schedules, generates the synthetic GLUE suite, and
//!   regenerates every table and figure of the paper's evaluation.
//!
//! See DESIGN.md for the architecture and experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod bench_harness;
pub mod chaos;
pub mod config;
pub mod coordinator;
pub mod daemon;
pub mod data;
pub mod memory;
pub mod rmm;
pub mod rng;
pub mod runtime;
pub mod session;
pub mod sweep;
pub mod tensor;
pub mod util;
