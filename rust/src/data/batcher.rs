//! Fixed-shape batching for XLA: pads/truncates to (batch, seq_len),
//! emits attention masks, shuffles deterministically per epoch.
//!
//! XLA executables have static shapes, so the final partial batch of an
//! epoch is padded by *wrapping around*; `Batch::valid` records how many
//! leading rows are real (the evaluator weights metrics accordingly —
//! wrapped rows must never reach a metric, which `tests/prop_data.rs`
//! pins exhaustively around the `n % batch_size` edge cases).
//!
//! [`PrefetchBatcher`] is the async twin: the next batch(es) are
//! assembled on a background thread while the trainer consumes the
//! current one.  Its `depth` is the number of finished batches allowed
//! to wait in the hand-off channel — depth 1 (the default) is classic
//! double buffering; deeper queues absorb burstier consumers (e.g. a
//! step that occasionally recompiles).  Because every batch is a pure
//! function of `(task, split, batch_size, epoch, seed)`, the prefetched
//! stream is **bit-identical** to the synchronous iterator at *every*
//! depth — prefetch is a latency knob, never a results knob (enforced by
//! `tests/prop_sweep.rs`).

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;

use crate::rng::philox::{PhiloxStream, STREAM_DATA};

use super::tasks::{Example, Split, TaskGen};
use super::tokenizer::PAD;

/// One fixed-shape batch, layout-ready for literal upload.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Vec<i32>, // (batch, seq_len) row-major
    pub mask: Vec<f32>,   // (batch, seq_len)
    pub labels_i: Vec<i32>,
    pub labels_f: Vec<f32>,
    pub batch_size: usize,
    pub seq_len: usize,
    /// Number of non-wrapped (real) rows.
    pub valid: usize,
}

impl Batch {
    fn new(batch_size: usize, seq_len: usize) -> Self {
        Self {
            tokens: vec![PAD as i32; batch_size * seq_len],
            mask: vec![0.0; batch_size * seq_len],
            labels_i: vec![0; batch_size],
            labels_f: vec![0.0; batch_size],
            batch_size,
            seq_len,
            valid: 0,
        }
    }

    fn fill_row(&mut self, row: usize, ex: &Example) {
        let off = row * self.seq_len;
        for (k, &t) in ex.tokens.iter().take(self.seq_len).enumerate() {
            self.tokens[off + k] = t as i32;
            self.mask[off + k] = 1.0;
        }
        self.labels_i[row] = ex.label as i32;
        self.labels_f[row] = ex.label;
    }
}

/// Deterministic epoch iterator over a task split.
pub struct Batcher<'a> {
    gen: &'a TaskGen,
    split: Split,
    order: Vec<usize>,
    cursor: usize,
    batch_size: usize,
}

impl<'a> Batcher<'a> {
    pub fn new(gen: &'a TaskGen, split: Split, batch_size: usize, epoch: u64) -> Self {
        // batch_size == 0 would make `next` never advance the cursor (an
        // infinite iterator of empty batches) — fail loudly instead.
        assert!(batch_size > 0, "batch_size must be > 0");
        let n = gen.task.split_size(split);
        let mut order: Vec<usize> = (0..n).collect();
        if split == Split::Train {
            let mut r = PhiloxStream::new(
                gen.seed ^ (epoch.wrapping_mul(0xA5A5_5A5A_1234_5678)),
                STREAM_DATA,
            );
            r.shuffle(&mut order);
        }
        Self { gen, split, order, cursor: 0, batch_size }
    }

    pub fn n_examples(&self) -> usize {
        self.order.len()
    }

    pub fn n_batches(&self) -> usize {
        self.order.len().div_ceil(self.batch_size)
    }
}

impl<'a> Iterator for Batcher<'a> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        if self.cursor >= self.order.len() {
            return None;
        }
        let mut batch = Batch::new(self.batch_size, self.gen.seq_len);
        for row in 0..self.batch_size {
            // wrap around for the final partial batch (static shapes)
            let idx = self.order[(self.cursor + row) % self.order.len()];
            let ex = self.gen.example(self.split, idx);
            batch.fill_row(row, &ex);
        }
        batch.valid = (self.order.len() - self.cursor).min(self.batch_size);
        self.cursor += self.batch_size;
        Some(batch)
    }
}

/// Asynchronous batcher: a background thread regenerates the exact
/// `Batcher` stream for `(task, split, batch_size, epoch, seed)` and
/// hands batches over a bounded channel of capacity `depth`, so at most
/// `depth` finished batches wait while the next is being assembled
/// (depth 1 = classic double buffering, the default).
///
/// The producer owns a clone of the generator (an Arc handle to the
/// shared tokenizer plus the pure stream parameters), so no borrow
/// crosses the thread and the emitted sequence is bit-identical to the
/// synchronous iterator at every depth.  The compute pool's `run` API is
/// a blocking fork-join and cannot host a producer that outlives the
/// call, hence one dedicated thread here; intra-batch kernels still run
/// on the pool.
pub struct PrefetchBatcher {
    rx: Option<Receiver<Batch>>,
    worker: Option<JoinHandle<()>>,
    n_examples: usize,
    batch_size: usize,
}

impl PrefetchBatcher {
    pub fn new(gen: &TaskGen, split: Split, batch_size: usize, epoch: u64) -> Self {
        Self::with_depth(gen, split, batch_size, epoch, 1)
    }

    /// `depth >= 1` finished batches may queue ahead of the consumer.
    pub fn with_depth(
        gen: &TaskGen,
        split: Split,
        batch_size: usize,
        epoch: u64,
        depth: usize,
    ) -> Self {
        assert!(batch_size > 0, "batch_size must be > 0");
        assert!(depth > 0, "prefetch depth must be > 0");
        let gen = gen.clone();
        let n_examples = gen.task.split_size(split);
        let (tx, rx) = sync_channel::<Batch>(depth);
        let worker = std::thread::Builder::new()
            .name("rmm-prefetch".to_string())
            .spawn(move || {
                for batch in Batcher::new(&gen, split, batch_size, epoch) {
                    if tx.send(batch).is_err() {
                        break; // consumer hung up early (e.g. drop mid-epoch)
                    }
                }
            })
            .expect("spawning prefetch thread");
        PrefetchBatcher { rx: Some(rx), worker: Some(worker), n_examples, batch_size }
    }

    pub fn n_examples(&self) -> usize {
        self.n_examples
    }

    pub fn n_batches(&self) -> usize {
        self.n_examples.div_ceil(self.batch_size)
    }
}

impl Iterator for PrefetchBatcher {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        self.rx.as_ref().and_then(|rx| rx.recv().ok())
    }
}

impl Drop for PrefetchBatcher {
    fn drop(&mut self) {
        // Disconnect first so a producer blocked on `send` unblocks, then
        // reap the thread (it exits promptly on the send error).
        drop(self.rx.take());
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

/// Either batching strategy behind one iterator type, selected by the
/// `prefetch` / `prefetch_depth` train-config knobs (`--prefetch`,
/// `--prefetch-depth` / `train.prefetch`, `train.prefetch_depth`).
pub enum AnyBatcher<'a> {
    Sync(Batcher<'a>),
    Prefetch(PrefetchBatcher),
}

impl<'a> AnyBatcher<'a> {
    pub fn new(
        gen: &'a TaskGen,
        split: Split,
        batch_size: usize,
        epoch: u64,
        prefetch: bool,
        depth: usize,
    ) -> Self {
        if prefetch {
            AnyBatcher::Prefetch(PrefetchBatcher::with_depth(
                gen, split, batch_size, epoch, depth,
            ))
        } else {
            AnyBatcher::Sync(Batcher::new(gen, split, batch_size, epoch))
        }
    }
}

impl Iterator for AnyBatcher<'_> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        match self {
            AnyBatcher::Sync(b) => b.next(),
            AnyBatcher::Prefetch(p) => p.next(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::Task;
    use crate::data::tokenizer::{Tokenizer, CLS};

    fn setup() -> (Tokenizer,) {
        (Tokenizer::new(256),)
    }

    #[test]
    fn shapes_and_mask() {
        let (tok,) = setup();
        let g = TaskGen::new(Task::Sst2, &tok, 16, 1);
        let b = Batcher::new(&g, Split::Dev, 8, 0).next().unwrap();
        assert_eq!(b.tokens.len(), 8 * 16);
        assert_eq!(b.mask.len(), 8 * 16);
        for row in 0..8 {
            assert_eq!(b.tokens[row * 16], CLS as i32);
            assert_eq!(b.mask[row * 16], 1.0);
            // mask is a prefix of ones
            let m = &b.mask[row * 16..(row + 1) * 16];
            let ones = m.iter().take_while(|&&v| v == 1.0).count();
            assert!(m[ones..].iter().all(|&v| v == 0.0));
            // padded positions hold PAD
            let t = &b.tokens[row * 16..(row + 1) * 16];
            assert!(t[ones..].iter().all(|&v| v == PAD as i32));
        }
    }

    #[test]
    fn epoch_covers_all_examples() {
        let (tok,) = setup();
        let g = TaskGen::new(Task::Wnli, &tok, 16, 1);
        let batcher = Batcher::new(&g, Split::Train, 32, 0);
        let n = batcher.n_examples();
        let total_valid: usize = batcher.map(|b| b.valid).sum();
        assert_eq!(total_valid, n);
    }

    #[test]
    fn shuffle_differs_across_epochs_but_not_runs() {
        let (tok,) = setup();
        let g = TaskGen::new(Task::Cola, &tok, 16, 1);
        let b0: Vec<i32> = Batcher::new(&g, Split::Train, 4, 0).next().unwrap().tokens;
        let b0_again: Vec<i32> =
            Batcher::new(&g, Split::Train, 4, 0).next().unwrap().tokens;
        let b1: Vec<i32> = Batcher::new(&g, Split::Train, 4, 1).next().unwrap().tokens;
        assert_eq!(b0, b0_again);
        assert_ne!(b0, b1);
    }

    #[test]
    fn dev_split_is_not_shuffled() {
        let (tok,) = setup();
        let g = TaskGen::new(Task::Cola, &tok, 16, 1);
        let a: Vec<i32> = Batcher::new(&g, Split::Dev, 4, 0).next().unwrap().tokens;
        let b: Vec<i32> = Batcher::new(&g, Split::Dev, 4, 5).next().unwrap().tokens;
        assert_eq!(a, b);
    }

    #[test]
    fn last_batch_wraps() {
        let (tok,) = setup();
        let g = TaskGen::new(Task::Wnli, &tok, 16, 1);
        let n = g.task.split_size(Split::Dev); // 70
        let batches: Vec<Batch> = Batcher::new(&g, Split::Dev, 32, 0).collect();
        assert_eq!(batches.len(), n.div_ceil(32));
        assert_eq!(batches.last().unwrap().valid, n % 32);
    }

    #[test]
    #[should_panic(expected = "batch_size must be > 0")]
    fn zero_batch_size_panics() {
        let (tok,) = setup();
        let g = TaskGen::new(Task::Wnli, &tok, 16, 1);
        let _ = Batcher::new(&g, Split::Dev, 0, 0);
    }

    #[test]
    fn prefetch_matches_sync_for_one_epoch() {
        let (tok,) = setup();
        let g = TaskGen::new(Task::Cola, &tok, 16, 9);
        let sync: Vec<Batch> = Batcher::new(&g, Split::Train, 24, 2).collect();
        let pre: Vec<Batch> = PrefetchBatcher::new(&g, Split::Train, 24, 2).collect();
        assert_eq!(sync.len(), pre.len());
        for (a, b) in sync.iter().zip(&pre) {
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.mask, b.mask);
            assert_eq!(a.labels_i, b.labels_i);
            assert_eq!(a.labels_f, b.labels_f);
            assert_eq!(a.valid, b.valid);
        }
    }

    #[test]
    fn prefetch_drop_mid_epoch_does_not_hang() {
        let (tok,) = setup();
        let g = TaskGen::new(Task::Mnli, &tok, 16, 1);
        let mut p = PrefetchBatcher::new(&g, Split::Train, 8, 0);
        assert!(p.next().is_some());
        drop(p); // producer is mid-stream; Drop must disconnect + join
    }

    #[test]
    fn prefetch_depths_all_match_sync() {
        let (tok,) = setup();
        let g = TaskGen::new(Task::Cola, &tok, 16, 5);
        let sync: Vec<Batch> = Batcher::new(&g, Split::Train, 24, 1).collect();
        for depth in [1usize, 2, 3, 7] {
            let pre: Vec<Batch> =
                PrefetchBatcher::with_depth(&g, Split::Train, 24, 1, depth).collect();
            assert_eq!(sync.len(), pre.len(), "depth {depth}");
            for (a, b) in sync.iter().zip(&pre) {
                assert_eq!(a.tokens, b.tokens, "depth {depth}");
                assert_eq!(a.labels_f, b.labels_f, "depth {depth}");
                assert_eq!(a.valid, b.valid, "depth {depth}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "prefetch depth must be > 0")]
    fn zero_prefetch_depth_panics() {
        let (tok,) = setup();
        let g = TaskGen::new(Task::Wnli, &tok, 16, 1);
        let _ = PrefetchBatcher::with_depth(&g, Split::Dev, 8, 0, 0);
    }

    #[test]
    fn any_batcher_dispatches_both_modes() {
        let (tok,) = setup();
        let g = TaskGen::new(Task::Wnli, &tok, 16, 1);
        let a: Vec<Batch> = AnyBatcher::new(&g, Split::Dev, 16, 0, false, 1).collect();
        let b: Vec<Batch> = AnyBatcher::new(&g, Split::Dev, 16, 0, true, 2).collect();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.valid, y.valid);
        }
    }
}
