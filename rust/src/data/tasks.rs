//! Synthetic GLUE suite: nine generated tasks matching the *types* and
//! relative scales of the GLUE benchmark (paper Table 2's substitution —
//! see DESIGN.md §2).  Each task has a latent rule of controllable
//! difficulty plus label noise, so fine-tuning quality degrades with
//! gradient noise the same qualitative way the real benchmark does:
//! big/easy tasks (MNLI-, SST2-like) are robust to RMM compression, small/
//! noisy ones (WNLI-, RTE-like) are fragile.
//!
//! Every example is a pure function of (task, split, index, seed): the
//! suite is fully deterministic, needs no storage, and both workers and
//! tests can regenerate any example in O(seq_len).

use crate::rng::philox::{PhiloxStream, STREAM_DATA};

use super::tokenizer::{Tokenizer, CLS, SEP};

/// Which GLUE metric a task reports (paper Table 2 conventions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    Accuracy,
    F1,
    Matthews,
    PearsonSpearman,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Dev,
}

/// One labelled example; `label` is a class index, or a score in [0, 5]
/// for the regression task (STSB-like).
#[derive(Debug, Clone)]
pub struct Example {
    pub tokens: Vec<u32>,
    pub label: f32,
}

/// Task identifiers, named after their GLUE counterparts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    Cola,
    Mnli,
    MnliMM,
    Mrpc,
    Qnli,
    Qqp,
    Rte,
    Sst2,
    Stsb,
    Wnli,
}

impl Task {
    pub const ALL: [Task; 10] = [
        Task::Cola,
        Task::Mnli,
        Task::MnliMM,
        Task::Mrpc,
        Task::Qnli,
        Task::Qqp,
        Task::Rte,
        Task::Sst2,
        Task::Stsb,
        Task::Wnli,
    ];

    pub fn parse(s: &str) -> Option<Task> {
        Some(match s.to_lowercase().as_str() {
            "cola" => Task::Cola,
            "mnli" => Task::Mnli,
            "mnli-mm" | "mnlimm" => Task::MnliMM,
            "mrpc" => Task::Mrpc,
            "qnli" => Task::Qnli,
            "qqp" => Task::Qqp,
            "rte" => Task::Rte,
            "sst2" | "sst-2" => Task::Sst2,
            "stsb" | "sts-b" => Task::Stsb,
            "wnli" => Task::Wnli,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Task::Cola => "cola",
            Task::Mnli => "mnli",
            Task::MnliMM => "mnli-mm",
            Task::Mrpc => "mrpc",
            Task::Qnli => "qnli",
            Task::Qqp => "qqp",
            Task::Rte => "rte",
            Task::Sst2 => "sst2",
            Task::Stsb => "stsb",
            Task::Wnli => "wnli",
        }
    }

    pub fn n_classes(&self) -> usize {
        match self {
            Task::Mnli | Task::MnliMM => 3,
            Task::Stsb => 1,
            _ => 2,
        }
    }

    pub fn is_regression(&self) -> bool {
        matches!(self, Task::Stsb)
    }

    pub fn metric(&self) -> Metric {
        match self {
            Task::Cola => Metric::Matthews,
            Task::Mrpc | Task::Qqp => Metric::F1,
            Task::Stsb => Metric::PearsonSpearman,
            _ => Metric::Accuracy,
        }
    }

    /// Scaled-down GLUE split sizes (relative ordering preserved).
    pub fn split_size(&self, split: Split) -> usize {
        let (train, dev) = match self {
            Task::Mnli => (6000, 600),
            Task::MnliMM => (6000, 600),
            Task::Qqp => (6000, 600),
            Task::Qnli => (3000, 400),
            Task::Sst2 => (3000, 400),
            Task::Cola => (2000, 300),
            Task::Mrpc => (1200, 200),
            Task::Stsb => (1200, 200),
            Task::Rte => (600, 150),
            Task::Wnli => (250, 70),
        };
        match split {
            Split::Train => train,
            Split::Dev => dev,
        }
    }

    /// Label noise rate (fraction of flipped labels) — WNLI is famously
    /// adversarial/noisy, RTE small and hard; the big tasks are clean.
    fn noise(&self) -> f32 {
        match self {
            Task::Wnli => 0.35,
            Task::Rte => 0.15,
            Task::Cola => 0.08,
            Task::Mrpc => 0.08,
            Task::Stsb => 0.0, // noise injected on the score instead
            _ => 0.03,
        }
    }
}

/// Deterministic generator over a task. Word classes carve up the lexicon:
///   nouns    = [0, n/3)      verbs = [n/3, 2n/3)     modifiers = rest,
/// with word *valence* = +1 for even lexicon index, −1 for odd (used by the
/// SST2-like sentiment rule).
///
/// Owns an (Arc-backed) handle to its tokenizer, so the generator is
/// `Clone + Send` — the prefetch batcher ships a clone to its producer
/// thread and the warm-session layer caches generators freely, while
/// every stream stays a pure function of `(task, vocab, seq_len, seed)`.
#[derive(Debug, Clone)]
pub struct TaskGen {
    pub task: Task,
    tok: Tokenizer,
    pub seq_len: usize,
    pub seed: u64,
}

impl TaskGen {
    pub fn new(task: Task, tok: &Tokenizer, seq_len: usize, seed: u64) -> Self {
        Self { task, tok: tok.clone(), seq_len, seed }
    }

    /// The shared tokenizer handle this generator draws its lexicon from.
    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tok
    }

    fn rng_for(&self, split: Split, index: usize) -> PhiloxStream {
        let split_tag = match split {
            Split::Train => 0u64,
            Split::Dev => 1u64,
        };
        let task_tag = self.task as u64;
        // disjoint stream per (seed, task, split, index)
        let mix = self
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(task_tag << 40 | split_tag << 32 | index as u64);
        PhiloxStream::new(mix, STREAM_DATA)
    }

    fn n_words(&self) -> u32 {
        self.tok.n_words()
    }

    fn noun(&self, r: &mut PhiloxStream) -> u32 {
        r.next_below(self.n_words() / 3)
    }

    fn verb(&self, r: &mut PhiloxStream) -> u32 {
        self.n_words() / 3 + r.next_below(self.n_words() / 3)
    }

    fn any_word(&self, r: &mut PhiloxStream) -> u32 {
        r.next_below(self.n_words())
    }

    /// MNLI-MM draws content words from the *upper* half of the lexicon —
    /// the "mismatched domain" analogue.
    fn domain_word(&self, r: &mut PhiloxStream) -> u32 {
        match self.task {
            Task::MnliMM => self.n_words() / 2 + r.next_below(self.n_words() / 2),
            _ => r.next_below(self.n_words() / 2),
        }
    }

    pub fn example(&self, split: Split, index: usize) -> Example {
        let mut r = self.rng_for(split, index);
        let mut ex = match self.task {
            Task::Cola => self.gen_cola(&mut r),
            Task::Sst2 => self.gen_sst2(&mut r),
            Task::Mrpc | Task::Qqp => self.gen_paraphrase(&mut r),
            Task::Mnli | Task::MnliMM => self.gen_nli(&mut r),
            Task::Qnli => self.gen_qnli(&mut r),
            Task::Rte => self.gen_rte(&mut r),
            Task::Stsb => self.gen_stsb(&mut r),
            Task::Wnli => self.gen_rte(&mut r), // same family, noisier
        };
        // label noise (classification only)
        let noise = self.task.noise();
        if !self.task.is_regression() && noise > 0.0 && r.next_f32() < noise {
            let c = self.task.n_classes() as u32;
            ex.label = ((ex.label as u32 + 1 + r.next_below(c - 1)) % c) as f32;
        }
        // clip/pad to seq_len
        ex.tokens.truncate(self.seq_len);
        ex
    }

    fn word_tok(&self, lex: u32) -> u32 {
        super::tokenizer::FIRST_WORD + lex
    }

    /// CoLA-like acceptability: "grammatical" = positive net valence.
    /// The latent signal is weak (small per-example drift), so examples sit
    /// near the decision boundary — CoLA is the paper's hardest task and
    /// the first to degrade under gradient noise.
    fn gen_cola(&self, r: &mut PhiloxStream) -> Example {
        let len = 5 + r.next_below((self.seq_len as u32 - 6).min(12)) as usize;
        let (tokens, sum) = self.counting_body(r, len, 0.14, false);
        Example { tokens, label: if sum > 0 { 1.0 } else { 0.0 } }
    }

    /// SST2-like sentiment: label = sign of summed word valence, with a
    /// strong per-example drift (easy, large task — robust under RMM).
    fn gen_sst2(&self, r: &mut PhiloxStream) -> Example {
        let len = 6 + r.next_below((self.seq_len as u32 - 7).min(16)) as usize;
        let (tokens, sum) = self.counting_body(r, len, 0.3, false);
        Example { tokens, label: if sum > 0 { 1.0 } else { 0.0 } }
    }

    /// MRPC/QQP-like "consistent pair": two segments (second drawn from the
    /// upper lexicon half so the model can tell them apart lexically);
    /// positive iff the pair's joint valence clears an off-center
    /// threshold (off-center ⇒ class imbalance ⇒ F1 is the right metric,
    /// as in GLUE).
    fn gen_paraphrase(&self, r: &mut PhiloxStream) -> Example {
        let (len, bias, thr) = match self.task {
            Task::Qqp => (6 + r.next_below(6) as usize, 0.25, 0),
            _ => (4 + r.next_below(5) as usize, 0.18, 1),
        };
        let (mut tokens, sum_a) = self.counting_body(r, len, bias, false);
        tokens.push(SEP);
        let (body_b, sum_b) = self.counting_body(r, len, bias, true);
        tokens.extend(&body_b[1..]); // skip the CLS of the second body
        let label = if sum_a + sum_b > thr { 1.0 } else { 0.0 };
        Example { tokens, label }
    }

    /// MNLI-like 3-way: the pooled valence of premise+hypothesis buckets
    /// into entail / neutral / contradict (two learnable thresholds on one
    /// pooled feature; the large training set makes this the most
    /// RMM-robust task, as MNLI is in the paper).
    fn gen_nli(&self, r: &mut PhiloxStream) -> Example {
        let plen = 5 + r.next_below(6) as usize;
        let hlen = 4 + r.next_below(3) as usize;
        // aim for one of three drift buckets, label from the ACTUAL sum
        let bucket = r.next_below(3);
        let bias = match bucket {
            0 => 0.35,
            1 => 0.0,
            _ => -0.35,
        };
        let (mut tokens, sum_p) = self.counting_body_signed(r, plen, bias);
        tokens.push(SEP);
        let (body_h, sum_h) = self.counting_body_signed(r, hlen, bias);
        tokens.extend(&body_h[1..]);
        let s = sum_p + sum_h;
        let label = if s >= 3 {
            0.0
        } else if s <= -3 {
            2.0
        } else {
            1.0
        };
        Example { tokens, label }
    }

    /// QNLI-like: a decorative "question" prefix plus an answer sentence;
    /// positive iff the sentence's valence is positive.  Mid-size, mid
    /// difficulty.
    fn gen_qnli(&self, r: &mut PhiloxStream) -> Example {
        let q = self.domain_word(r);
        let slen = 6 + r.next_below(8) as usize;
        let (body, sum) = self.counting_body(r, slen, 0.22, false);
        let mut tokens = vec![CLS, self.word_tok(q), SEP];
        tokens.extend(&body[1..]);
        Example { tokens, label: if sum > 0 { 1.0 } else { 0.0 } }
    }

    /// RTE/WNLI-like: the same pooled-valence rule with a weaker drift —
    /// combined with their high label-noise rates and tiny training sets
    /// these are the fragile tasks (as RTE/WNLI are in the paper).
    fn gen_rte(&self, r: &mut PhiloxStream) -> Example {
        let plen = 5 + r.next_below(6) as usize;
        let hlen = 3 + r.next_below(3) as usize;
        let (mut tokens, sum_p) = self.counting_body(r, plen, 0.16, false);
        tokens.push(SEP);
        let (body_h, sum_h) = self.counting_body(r, hlen, 0.16, true);
        tokens.extend(&body_h[1..]);
        Example { tokens, label: if sum_p + sum_h > 0 { 1.0 } else { 0.0 } }
    }

    /// STSB-like regression: score in [0, 5] is an affine map of the mean
    /// valence plus mild observation noise.
    fn gen_stsb(&self, r: &mut PhiloxStream) -> Example {
        let len = 6 + r.next_below(8) as usize;
        let (mut tokens, sum_a) = self.counting_body(r, len, 0.3, false);
        tokens.push(SEP);
        let (body_b, sum_b) = self.counting_body(r, len, 0.3, true);
        tokens.extend(&body_b[1..]);
        let mean = (sum_a + sum_b) as f32 / (2 * len) as f32; // in [-1, 1]
        let score = 2.5 + 2.5 * mean + 0.12 * r.next_normal();
        Example { tokens, label: score.clamp(0.0, 5.0) }
    }

    /// Shared generator core: `len` words drawn with a random per-example
    /// drift of magnitude `bias` toward one valence; returns the token body
    /// (starting with CLS) and the realized valence sum (word valence =
    /// +1 for even lexicon ids, -1 for odd).  `upper` draws from the upper
    /// lexicon half (segment-B / mismatched-domain encoding).
    fn counting_body(
        &self,
        r: &mut PhiloxStream,
        len: usize,
        bias: f32,
        upper: bool,
    ) -> (Vec<u32>, i32) {
        let dir = if r.next_u32() & 1 == 1 { 1.0 } else { -1.0 };
        self.counting_body_dir(r, len, bias * dir, upper)
    }

    /// Like `counting_body` but with a signed bias (for bucketed tasks).
    fn counting_body_signed(
        &self,
        r: &mut PhiloxStream,
        len: usize,
        bias: f32,
    ) -> (Vec<u32>, i32) {
        self.counting_body_dir(r, len, bias, false)
    }

    fn counting_body_dir(
        &self,
        r: &mut PhiloxStream,
        len: usize,
        bias: f32,
        upper: bool,
    ) -> (Vec<u32>, i32) {
        let p_pos = 0.5 + bias.clamp(-0.45, 0.45);
        let mut tokens = vec![CLS];
        let mut sum = 0i32;
        let n = self.n_words();
        let (lo, span) = if upper || self.task == Task::MnliMM {
            (n / 2, n / 2)
        } else {
            (0, n / 2)
        };
        for _ in 0..len {
            let want_pos = r.next_f32() < p_pos;
            // draw a word of the wanted valence from the domain slice
            let w = loop {
                let w = lo + r.next_below(span);
                if (w % 2 == 0) == want_pos {
                    break w;
                }
            };
            sum += if w % 2 == 0 { 1 } else { -1 };
            tokens.push(self.word_tok(w));
        }
        (tokens, sum)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(_task: Task) -> (Tokenizer, u64) {
        (Tokenizer::new(256), 42)
    }

    #[test]
    fn deterministic_examples() {
        for task in Task::ALL {
            let (tok, seed) = gen(task);
            let g = TaskGen::new(task, &tok, 32, seed);
            let a = g.example(Split::Train, 7);
            let b = g.example(Split::Train, 7);
            assert_eq!(a.tokens, b.tokens, "{task:?}");
            assert_eq!(a.label, b.label, "{task:?}");
        }
    }

    #[test]
    fn splits_and_indices_differ() {
        let (tok, seed) = gen(Task::Sst2);
        let g = TaskGen::new(Task::Sst2, &tok, 32, seed);
        let a = g.example(Split::Train, 0);
        let b = g.example(Split::Dev, 0);
        let c = g.example(Split::Train, 1);
        assert_ne!(a.tokens, b.tokens);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn tokens_in_range_and_start_with_cls() {
        for task in Task::ALL {
            let (tok, seed) = gen(task);
            let g = TaskGen::new(task, &tok, 32, seed);
            for i in 0..50 {
                let ex = g.example(Split::Train, i);
                assert_eq!(ex.tokens[0], CLS, "{task:?}");
                assert!(ex.tokens.len() <= 32);
                assert!(ex.tokens.iter().all(|&t| (t as usize) < 256), "{task:?}");
            }
        }
    }

    #[test]
    fn labels_in_range() {
        for task in Task::ALL {
            let (tok, seed) = gen(task);
            let g = TaskGen::new(task, &tok, 32, seed);
            for i in 0..100 {
                let ex = g.example(Split::Train, i);
                if task.is_regression() {
                    assert!((0.0..=5.0).contains(&ex.label), "{task:?} {}", ex.label);
                } else {
                    let c = ex.label as usize;
                    assert!(c < task.n_classes(), "{task:?} {}", ex.label);
                    assert_eq!(c as f32, ex.label);
                }
            }
        }
    }

    #[test]
    fn classes_roughly_balanced() {
        for task in [Task::Cola, Task::Sst2, Task::Mnli, Task::Qnli] {
            let (tok, seed) = gen(task);
            let g = TaskGen::new(task, &tok, 32, seed);
            let n = 600;
            let mut counts = vec![0usize; task.n_classes()];
            for i in 0..n {
                counts[g.example(Split::Train, i).label as usize] += 1;
            }
            let expected = n / task.n_classes();
            for (c, &cnt) in counts.iter().enumerate() {
                assert!(
                    cnt > expected / 2 && cnt < expected * 2,
                    "{task:?} class {c}: {counts:?}"
                );
            }
        }
    }

    #[test]
    fn mnli_mm_uses_shifted_domain() {
        let tok = Tokenizer::new(256);
        let g_m = TaskGen::new(Task::Mnli, &tok, 32, 1);
        let g_mm = TaskGen::new(Task::MnliMM, &tok, 32, 1);
        let lex_of = |ex: &Example| -> Vec<u32> {
            ex.tokens
                .iter()
                .filter(|&&t| t >= super::super::tokenizer::FIRST_WORD)
                .map(|&t| t - super::super::tokenizer::FIRST_WORD)
                .collect()
        };
        let n_words = tok.n_words();
        let mut mm_low = 0;
        let mut m_high = 0;
        for i in 0..100 {
            for w in lex_of(&g_mm.example(Split::Train, i)) {
                if w < n_words / 2 {
                    mm_low += 1;
                }
            }
            for w in lex_of(&g_m.example(Split::Train, i)) {
                if w >= n_words / 2 {
                    m_high += 1;
                }
            }
        }
        // antonym-flip (xor 1) can cross the boundary only at the midpoint,
        // so leakage is negligible
        assert!(mm_low < 10, "mm drew {mm_low} low-domain words");
        assert!(m_high < 10, "m drew {m_high} high-domain words");
    }

    #[test]
    fn split_sizes_ordered_like_glue() {
        assert!(Task::Mnli.split_size(Split::Train) > Task::Rte.split_size(Split::Train));
        assert!(Task::Rte.split_size(Split::Train) > Task::Wnli.split_size(Split::Train));
        for task in Task::ALL {
            assert!(task.split_size(Split::Dev) < task.split_size(Split::Train));
        }
    }

    #[test]
    fn parse_names_roundtrip() {
        for task in Task::ALL {
            assert_eq!(Task::parse(task.name()), Some(task), "{task:?}");
        }
    }
}
