//! GLUE-style task metrics computed from logits/labels (paper Table 2).

use crate::util::stats;

use super::tasks::{Metric, Task};

/// Accumulates predictions over dev batches and produces the task metric.
#[derive(Debug, Default)]
pub struct MetricAccum {
    preds: Vec<f64>,
    labels: Vec<f64>,
}

impl MetricAccum {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one batch of logits ((valid, n_classes) for classification,
    /// (valid, 1) regression scores otherwise).
    pub fn add_logits(
        &mut self,
        task: Task,
        logits: &[f32],
        n_classes: usize,
        labels_i: &[i32],
        labels_f: &[f32],
        valid: usize,
    ) {
        for row in 0..valid {
            if task.is_regression() {
                self.preds.push(logits[row] as f64);
                self.labels.push(labels_f[row] as f64);
            } else {
                let ls = &logits[row * n_classes..(row + 1) * n_classes];
                let argmax = ls
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                self.preds.push(argmax as f64);
                self.labels.push(labels_i[row] as f64);
            }
        }
    }

    pub fn count(&self) -> usize {
        self.preds.len()
    }

    /// The task's primary GLUE metric in percent (as paper Table 2).
    pub fn score(&self, task: Task) -> f64 {
        compute_metric(task.metric(), &self.preds, &self.labels) * 100.0
    }

    pub fn accuracy(&self) -> f64 {
        if self.preds.is_empty() {
            return 0.0;
        }
        let hits = self
            .preds
            .iter()
            .zip(&self.labels)
            .filter(|(p, l)| (*p - *l).abs() < 0.5)
            .count();
        hits as f64 / self.preds.len() as f64
    }
}

/// Metric in [~-1, 1]/[0, 1] units (×100 for Table 2 display).
pub fn compute_metric(metric: Metric, preds: &[f64], labels: &[f64]) -> f64 {
    match metric {
        Metric::Accuracy => {
            if preds.is_empty() {
                return 0.0;
            }
            preds
                .iter()
                .zip(labels)
                .filter(|(p, l)| (*p - *l).abs() < 0.5)
                .count() as f64
                / preds.len() as f64
        }
        Metric::F1 => {
            let (mut tp, mut fp, mut fn_, mut tn) = (0, 0, 0, 0);
            count_confusion(preds, labels, &mut tp, &mut fp, &mut fn_, &mut tn);
            stats::f1(tp, fp, fn_)
        }
        Metric::Matthews => {
            let (mut tp, mut fp, mut fn_, mut tn) = (0, 0, 0, 0);
            count_confusion(preds, labels, &mut tp, &mut fp, &mut fn_, &mut tn);
            stats::matthews(tp, tn, fp, fn_)
        }
        Metric::PearsonSpearman => {
            // GLUE reports the average of Pearson and Spearman for STS-B.
            (stats::pearson(preds, labels) + stats::spearman(preds, labels)) / 2.0
        }
    }
}

fn count_confusion(
    preds: &[f64],
    labels: &[f64],
    tp: &mut usize,
    fp: &mut usize,
    fn_: &mut usize,
    tn: &mut usize,
) {
    for (p, l) in preds.iter().zip(labels) {
        let p = *p >= 0.5;
        let l = *l >= 0.5;
        match (p, l) {
            (true, true) => *tp += 1,
            (true, false) => *fp += 1,
            (false, true) => *fn_ += 1,
            (false, false) => *tn += 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::Task;

    #[test]
    fn accuracy_path() {
        let mut acc = MetricAccum::new();
        // logits for 3 rows, 2 classes; preds = [1, 0, 1]; labels [1, 1, 1]
        acc.add_logits(
            Task::Qnli,
            &[0.1, 0.9, 0.8, 0.2, 0.0, 1.0],
            2,
            &[1, 1, 1],
            &[],
            3,
        );
        assert!((acc.score(Task::Qnli) - 200.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn valid_truncates() {
        let mut acc = MetricAccum::new();
        acc.add_logits(Task::Qnli, &[0.1, 0.9, 0.8, 0.2], 2, &[1, 0], &[], 1);
        assert_eq!(acc.count(), 1);
        assert!((acc.score(Task::Qnli) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn matthews_perfect_and_inverted() {
        let preds = vec![1.0, 0.0, 1.0, 0.0];
        let labels = vec![1.0, 0.0, 1.0, 0.0];
        assert!((compute_metric(Metric::Matthews, &preds, &labels) - 1.0).abs() < 1e-9);
        let inv: Vec<f64> = labels.iter().map(|l| 1.0 - l).collect();
        assert!((compute_metric(Metric::Matthews, &inv, &labels) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn f1_mixed() {
        let preds = vec![1.0, 1.0, 0.0, 0.0];
        let labels = vec![1.0, 0.0, 1.0, 0.0];
        // tp=1 fp=1 fn=1 → f1 = 2/(2+1+1) = 0.5
        assert!((compute_metric(Metric::F1, &preds, &labels) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn stsb_regression_path() {
        let mut acc = MetricAccum::new();
        acc.add_logits(
            Task::Stsb,
            &[1.0, 2.0, 3.0],
            1,
            &[],
            &[1.1, 2.2, 2.9],
            3,
        );
        let s = acc.score(Task::Stsb);
        assert!(s > 95.0, "near-perfect correlation expected: {s}");
    }
}
