//! Word-level tokenizer + vocabulary for the synthetic GLUE suite.
//!
//! The synthetic corpus is made of lexicon words ("w017", …) plus the
//! special tokens below.  Word ids are stable (lexicon order), so the
//! vocabulary is a pure function of `vocab_size` and never needs to be
//! shipped with checkpoints.

use std::collections::HashMap;
use std::sync::Arc;

pub const PAD: u32 = 0;
pub const CLS: u32 = 1;
pub const SEP: u32 = 2;
pub const UNK: u32 = 3;
pub const FIRST_WORD: u32 = 4;

/// The lexicon tables behind a shared, Arc-backed handle: cloning a
/// `Tokenizer` is a reference-count bump, so the warm-session cache and
/// the prefetch producer thread can hand the same vocabulary around
/// without rebuilding the O(vocab) tables per cell or per epoch.
#[derive(Debug)]
struct Lexicon {
    vocab_size: usize,
    word_to_id: HashMap<String, u32>,
    id_to_word: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct Tokenizer {
    lex: Arc<Lexicon>,
}

impl Tokenizer {
    /// Build the deterministic lexicon for a model vocabulary of
    /// `vocab_size` ids (ids 0..4 are the special tokens).
    pub fn new(vocab_size: usize) -> Self {
        assert!(vocab_size > FIRST_WORD as usize + 1, "vocab too small");
        let mut id_to_word = vec![
            "<pad>".to_string(),
            "<cls>".to_string(),
            "<sep>".to_string(),
            "<unk>".to_string(),
        ];
        for w in FIRST_WORD..vocab_size as u32 {
            id_to_word.push(format!("w{:03}", w - FIRST_WORD));
        }
        let word_to_id = id_to_word
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as u32))
            .collect();
        Self { lex: Arc::new(Lexicon { vocab_size, word_to_id, id_to_word }) }
    }

    pub fn vocab_size(&self) -> usize {
        self.lex.vocab_size
    }

    pub fn n_words(&self) -> u32 {
        self.lex.vocab_size as u32 - FIRST_WORD
    }

    /// Word string for a lexicon index (0-based over content words).
    pub fn word(&self, lexicon_idx: u32) -> &str {
        &self.lex.id_to_word[(FIRST_WORD + lexicon_idx) as usize]
    }

    pub fn encode_word(&self, word: &str) -> u32 {
        *self.lex.word_to_id.get(word).unwrap_or(&UNK)
    }

    /// Encode a whitespace-separated sentence, prepending CLS.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = vec![CLS];
        for w in text.split_whitespace() {
            out.push(self.encode_word(w));
        }
        out
    }

    /// Encode a sentence pair: CLS a… SEP b…
    pub fn encode_pair(&self, a: &str, b: &str) -> Vec<u32> {
        let mut out = self.encode(a);
        out.push(SEP);
        for w in b.split_whitespace() {
            out.push(self.encode_word(w));
        }
        out
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|&i| {
                self.lex
                    .id_to_word
                    .get(i as usize)
                    .map(|s| s.as_str())
                    .unwrap_or("<bad>")
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specials_are_fixed() {
        let t = Tokenizer::new(64);
        assert_eq!(t.encode_word("<pad>"), PAD);
        assert_eq!(t.encode_word("<cls>"), CLS);
        assert_eq!(t.encode_word("<sep>"), SEP);
        assert_eq!(t.encode_word("nonsense"), UNK);
    }

    #[test]
    fn roundtrip() {
        let t = Tokenizer::new(64);
        let ids = t.encode("w000 w005 w059");
        assert_eq!(ids, vec![CLS, 4, 9, 63]);
        assert_eq!(t.decode(&ids), "<cls> w000 w005 w059");
    }

    #[test]
    fn pair_encoding_has_sep() {
        let t = Tokenizer::new(64);
        let ids = t.encode_pair("w000", "w001");
        assert_eq!(ids, vec![CLS, 4, SEP, 5]);
    }

    #[test]
    fn word_ids_are_dense_and_stable() {
        let t = Tokenizer::new(100);
        assert_eq!(t.n_words(), 96);
        for i in 0..t.n_words() {
            assert_eq!(t.encode_word(t.word(i)), FIRST_WORD + i);
        }
    }

    #[test]
    #[should_panic]
    fn tiny_vocab_rejected() {
        Tokenizer::new(4);
    }

    #[test]
    fn clones_share_one_lexicon() {
        let a = Tokenizer::new(64);
        let b = a.clone();
        assert_eq!(b.vocab_size(), a.vocab_size());
        assert_eq!(b.encode("w000 w001"), a.encode("w000 w001"));
        // handle-level clone: no second lexicon is ever built
        assert_eq!(std::sync::Arc::strong_count(&a.lex), 2);
    }
}
