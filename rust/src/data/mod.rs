//! Data substrate: synthetic GLUE suite, tokenizer, fixed-shape batcher,
//! and GLUE-style metrics.  See DESIGN.md §2 for the GLUE→synthetic
//! substitution rationale.

pub mod batcher;
pub mod metrics;
pub mod tasks;
pub mod tokenizer;

pub use batcher::{AnyBatcher, Batch, Batcher, PrefetchBatcher};
pub use metrics::MetricAccum;
pub use tasks::{Example, Metric, Split, Task, TaskGen};
pub use tokenizer::Tokenizer;
