//! Typed experiment configuration (JSON-backed; see util::json for why not
//! TOML/serde).  One `ExperimentConfig` fully describes a run: which
//! artifact variant, which task, optimizer/schedule hyperparameters, and
//! logging.  Defaults mirror the Fairseq GLUE fine-tuning recipe the paper
//! uses (AdamW, linear warmup-decay), scaled to the small geometry.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Valid LR-schedule names — the single source of truth shared by
/// `validate()` and the CLI's `--schedule` disambiguation (the same flag
/// selects the sweep scheduler when its value is static|dynamic; the
/// value sets must stay disjoint).
pub const LR_SCHEDULES: &[&str] = &["linear", "const", "poly"];

#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    pub steps: usize,
    pub warmup_steps: usize,
    pub lr: f64,
    pub weight_decay: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub clip_norm: f64,
    pub optimizer: String, // "adamw" | "adam" | "sgd" | "momentum"
    pub schedule: String,  // "linear" | "const" | "poly"
    pub eval_every: usize,
    pub log_every: usize,
    pub seed: u64,
    /// Assemble the next batch on a background thread while the trainer
    /// consumes the current one (`data::PrefetchBatcher`).  Bit-identical
    /// to synchronous batching — a pure latency knob.
    pub prefetch: bool,
    /// How many finished batches may queue ahead of the consumer when
    /// prefetching (>= 1; depth 1 = classic double buffering).  Like
    /// `prefetch` itself, a pure latency knob: the emitted batch
    /// sequence is bit-identical at every depth.
    pub prefetch_depth: usize,
}

impl TrainConfig {
    pub fn to_json(&self) -> Json {
        train_to_json(self)
    }

    pub fn from_json(j: &Json) -> Result<TrainConfig> {
        parse_train(j)
    }
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            steps: 400,
            warmup_steps: 24,
            lr: 1e-3,
            weight_decay: 0.01,
            beta1: 0.9,
            beta2: 0.98, // RoBERTa fine-tuning convention
            eps: 1e-6,
            clip_norm: 1.0,
            optimizer: "adamw".to_string(),
            schedule: "linear".to_string(),
            eval_every: 100,
            log_every: 20,
            seed: 42,
            prefetch: false,
            prefetch_depth: 1,
        }
    }
}

/// Compute-pool knobs (see `tensor::pool`).  `None` fields express no
/// preference: the `RMM_THREADS` / `RMM_POOL_GRAIN` env vars and the
/// built-in derivations then decide per run.  Neither knob can change
/// results — the pool is deterministic for any setting — they only trade
/// dispatch overhead against load balance.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PoolConfig {
    /// Participants per parallel run (caller + workers), >= 1.
    pub threads: Option<usize>,
    /// Rows per task for row-partitioned kernels, >= 1 (kernels align and
    /// clamp it to their block geometry).
    pub grain_rows: Option<usize>,
}

impl PoolConfig {
    pub fn is_unset(&self) -> bool {
        self.threads.is_none() && self.grain_rows.is_none()
    }
}

/// Sweep-orchestrator knobs (see `sweep::mod`).  `None` fields express
/// no preference (the CLI flags / built-in defaults decide).  None of
/// these knobs can change merged-report *content* for deterministic
/// cells — sharding, scheduling, lease TTLs and resume only change how
/// cells are distributed to workers, never what a cell computes.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SweepConfig {
    /// Worker processes a sweep driver shards its grid across, >= 1.
    pub shards: Option<usize>,
    /// Reuse completed-cell manifests from a previous (killed) sweep.
    pub resume: bool,
    /// Cell scheduler: "static" (round-robin `--shard i/N`, the default)
    /// or "dynamic" (claim/lease work stealing, `sweep::scheduler`).
    pub schedule: Option<String>,
    /// Dynamic-schedule lease TTL in ms: a claim older than this is
    /// considered abandoned and reclaimable.  With heartbeat ticks from
    /// the trainer loop it need only exceed the tick interval; without
    /// them, the worst-case cell wall time (default 600000 = 10 min).
    pub lease_ttl_ms: Option<u64>,
    /// Reuse warm per-worker session state (engine executable cache,
    /// per-variant trainer setups, tokenizer/dataset caches) across a
    /// worker's cells (`--session-cache on|off`, default on).
    /// Byte-invisible in reports — the warm path is pinned identical to
    /// cold.
    pub session_cache: Option<bool>,
    /// Dynamic schedule only: prefer unclaimed cells matching a worker's
    /// warm (variant, task) key before canonical order (`sweep.affinity`,
    /// default on).  A pure claim-order preference.
    pub affinity: Option<bool>,
    /// Shared on-disk artifact cache + fleet worker registry under the
    /// sweep dir (`--artifact-cache on|off`, default off): new worker
    /// processes warm-start from init-param and dev-batch blobs
    /// published by earlier workers.  Byte-invisible in reports — blobs
    /// round-trip bit-exactly and cache counters go to stderr only.
    pub artifact_cache: Option<bool>,
    /// Seed for worker-process fault injection (`--chaos-seed`); the
    /// seed is the on-switch — absent means no chaos.  Like every knob
    /// here it cannot change merged-report content: chaos costs
    /// retries/respawns, never results (see `chaos::mod`).
    pub chaos_seed: Option<u64>,
    /// Chaos profile name ("light"|"crash"|"heavy") or an explicit
    /// `point@hit=action;...` schedule (`--chaos-profile`); inert
    /// without `chaos_seed`.
    pub chaos_profile: Option<String>,
    /// Total crashed-worker respawns the sweep supervisor allows
    /// (`--respawn-budget`; default 3 under chaos, else 0 = fail fast).
    pub respawn_budget: Option<u32>,
}

impl SweepConfig {
    pub fn is_unset(&self) -> bool {
        self.shards.is_none()
            && !self.resume
            && self.schedule.is_none()
            && self.lease_ttl_ms.is_none()
            && self.session_cache.is_none()
            && self.affinity.is_none()
            && self.artifact_cache.is_none()
            && self.chaos_seed.is_none()
            && self.chaos_profile.is_none()
            && self.respawn_budget.is_none()
    }
}

/// Sweep-daemon knobs (see `crate::daemon`).  `None` fields express no
/// preference: the `sweep-daemon` CLI flags / built-in defaults then
/// decide.  Like the sweep knobs, nothing here can change a merged
/// report — the daemon only changes how sweeps are queued and served.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DaemonConfig {
    /// In-process worker threads per daemon (`--workers`, default 1).
    pub workers: Option<usize>,
    /// Per-lane queue-depth cap before backpressure sheds specs to
    /// `rejected/` (`--queue-cap`, default `daemon::DEFAULT_QUEUE_CAP`).
    pub queue_cap: Option<usize>,
    /// Idle poll interval in ms when the queue is empty and the daemon
    /// is not draining (`--poll-ms`, default `daemon::DEFAULT_POLL_MS`).
    pub poll_ms: Option<u64>,
}

impl DaemonConfig {
    pub fn is_unset(&self) -> bool {
        self.workers.is_none() && self.queue_cap.is_none() && self.poll_ms.is_none()
    }
}

/// Kernel-layer knobs (see the "SIMD dispatch + autotune knobs" section
/// of the `tensor::kernels` module doc).  `None` fields express no
/// preference: `RMM_SIMD` / the CPU probe pick the dispatch level and
/// the shipped blocking defaults apply.  Neither knob can change
/// results — dispatch levels are bit-identical by the no-FMA contract
/// and blocking only regroups the ascending-k accumulation — they are
/// pure speed knobs, which is what makes persisting a machine-tuned
/// winner compatible with byte-reproducible sweeps.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct KernelsConfig {
    /// Forced SIMD dispatch level: "scalar" | "portable" | "avx2" |
    /// "avx512" | "neon" (strictly validated; applying a level this CPU
    /// cannot run is an error, not a fallback).
    pub simd: Option<String>,
    /// Autotuned cache blocking `(mc, kc, nc)` — the `kernels.tuned`
    /// section `tune-kernels --config` persists.  Consumers re-apply it
    /// without re-timing; `tune-kernels --retune` refreshes it.
    pub tuned: Option<(usize, usize, usize)>,
}

impl KernelsConfig {
    pub fn is_unset(&self) -> bool {
        self.simd.is_none() && self.tuned.is_none()
    }
}

/// RMM estimator knobs (see `rmm::controller`).  `None` fields express no
/// preference: the CLI flags / grid axes then decide per run.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct RmmConfig {
    /// Per-step memory budget for the closed-loop controller: the allowed
    /// fraction of the exact (ρ=1) residual, in (0, 1]
    /// (`--mem-budget`).  When set, the controller picks the
    /// minimum-variance (family, ρ) per layer under this budget instead
    /// of a static grid axis.
    pub mem_budget: Option<f64>,
}

impl RmmConfig {
    pub fn is_unset(&self) -> bool {
        self.mem_budget.is_none()
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// Artifact variant name (a key of manifest.json), e.g.
    /// "small_cls2_r50_gauss".
    pub variant: String,
    /// Task name from the synthetic GLUE suite.
    pub task: String,
    pub artifacts_dir: String,
    pub out_dir: String,
    /// Host GEMM backend: "packed" or "scalar" (reference).  `None` means
    /// the config expresses no preference and lower-precedence sources
    /// (env var, built-in default) decide.
    pub backend: Option<String>,
    /// Compute-pool thread-count / task-grain overrides.
    pub pool: PoolConfig,
    /// Kernel SIMD-dispatch / tuned-blocking overrides.
    pub kernels: KernelsConfig,
    /// Sweep-orchestrator defaults (shard count, resume).
    pub sweep: SweepConfig,
    /// Sweep-daemon defaults (worker count, queue cap, poll interval).
    pub daemon: DaemonConfig,
    /// RMM estimator / variance-controller knobs.
    pub rmm: RmmConfig,
    pub train: TrainConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            variant: "small_cls2_r100_gauss".to_string(),
            task: "cola".to_string(),
            artifacts_dir: "artifacts".to_string(),
            out_dir: "runs".to_string(),
            backend: None,
            pool: PoolConfig::default(),
            kernels: KernelsConfig::default(),
            sweep: SweepConfig::default(),
            daemon: DaemonConfig::default(),
            rmm: RmmConfig::default(),
            train: TrainConfig::default(),
        }
    }
}

impl ExperimentConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = ExperimentConfig::default();
        let obj = j.as_obj().context("config root must be an object")?;
        for (k, v) in obj {
            match k.as_str() {
                "variant" => cfg.variant = req_str(v, k)?,
                "task" => cfg.task = req_str(v, k)?,
                "artifacts_dir" => cfg.artifacts_dir = req_str(v, k)?,
                "out_dir" => cfg.out_dir = req_str(v, k)?,
                "backend" => cfg.backend = Some(req_str(v, k)?),
                "pool" => cfg.pool = parse_pool(v)?,
                "kernels" => cfg.kernels = parse_kernels(v)?,
                "sweep" => cfg.sweep = parse_sweep(v)?,
                "daemon" => cfg.daemon = parse_daemon(v)?,
                "rmm" => cfg.rmm = parse_rmm(v)?,
                "train" => cfg.train = parse_train(v)?,
                other => bail!("unknown config key '{other}'"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        Self::from_json(&j)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("variant", Json::str(self.variant.clone())),
            ("task", Json::str(self.task.clone())),
            ("artifacts_dir", Json::str(self.artifacts_dir.clone())),
            ("out_dir", Json::str(self.out_dir.clone())),
            ("train", train_to_json(&self.train)),
        ]);
        if let Some(b) = &self.backend {
            if let Json::Obj(map) = &mut j {
                map.insert("backend".to_string(), Json::str(b.clone()));
            }
        }
        if !self.pool.is_unset() {
            let mut p = Vec::new();
            if let Some(t) = self.pool.threads {
                p.push(("threads", Json::num(t as f64)));
            }
            if let Some(g) = self.pool.grain_rows {
                p.push(("grain_rows", Json::num(g as f64)));
            }
            if let Json::Obj(map) = &mut j {
                map.insert("pool".to_string(), Json::obj(p));
            }
        }
        if !self.kernels.is_unset() {
            let mut kv = Vec::new();
            if let Some(s) = &self.kernels.simd {
                kv.push(("simd", Json::str(s.clone())));
            }
            if let Some((mc, kc, nc)) = self.kernels.tuned {
                kv.push((
                    "tuned",
                    Json::obj(vec![
                        ("mc", Json::num(mc as f64)),
                        ("kc", Json::num(kc as f64)),
                        ("nc", Json::num(nc as f64)),
                    ]),
                ));
            }
            if let Json::Obj(map) = &mut j {
                map.insert("kernels".to_string(), Json::obj(kv));
            }
        }
        if !self.sweep.is_unset() {
            let mut s = Vec::new();
            if let Some(n) = self.sweep.shards {
                s.push(("shards", Json::num(n as f64)));
            }
            if self.sweep.resume {
                s.push(("resume", Json::Bool(true)));
            }
            if let Some(sched) = &self.sweep.schedule {
                s.push(("schedule", Json::str(sched.clone())));
            }
            if let Some(ttl) = self.sweep.lease_ttl_ms {
                s.push(("lease_ttl_ms", Json::num(ttl as f64)));
            }
            if let Some(sc) = self.sweep.session_cache {
                s.push(("session_cache", Json::Bool(sc)));
            }
            if let Some(a) = self.sweep.affinity {
                s.push(("affinity", Json::Bool(a)));
            }
            if let Some(ac) = self.sweep.artifact_cache {
                s.push(("artifact_cache", Json::Bool(ac)));
            }
            if let Some(cs) = self.sweep.chaos_seed {
                s.push(("chaos_seed", Json::num(cs as f64)));
            }
            if let Some(cp) = &self.sweep.chaos_profile {
                s.push(("chaos_profile", Json::str(cp.clone())));
            }
            if let Some(rb) = self.sweep.respawn_budget {
                s.push(("respawn_budget", Json::num(rb as f64)));
            }
            if let Json::Obj(map) = &mut j {
                map.insert("sweep".to_string(), Json::obj(s));
            }
        }
        if !self.daemon.is_unset() {
            let mut d = Vec::new();
            if let Some(w) = self.daemon.workers {
                d.push(("workers", Json::num(w as f64)));
            }
            if let Some(c) = self.daemon.queue_cap {
                d.push(("queue_cap", Json::num(c as f64)));
            }
            if let Some(p) = self.daemon.poll_ms {
                d.push(("poll_ms", Json::num(p as f64)));
            }
            if let Json::Obj(map) = &mut j {
                map.insert("daemon".to_string(), Json::obj(d));
            }
        }
        if !self.rmm.is_unset() {
            let mut r = Vec::new();
            if let Some(mb) = self.rmm.mem_budget {
                r.push(("mem_budget", Json::num(mb)));
            }
            if let Json::Obj(map) = &mut j {
                map.insert("rmm".to_string(), Json::obj(r));
            }
        }
        j
    }

    /// Install this config's backend as the process-global dispatch.
    /// Returns whether the config actually named one — callers use this
    /// to decide if lower-precedence sources (env) still apply.
    pub fn apply_backend(&self) -> bool {
        match self.backend.as_deref().and_then(crate::tensor::kernels::BackendKind::parse) {
            Some(kind) => {
                crate::tensor::kernels::set_backend(kind);
                true
            }
            None => false,
        }
    }

    /// Install this config's pool overrides (thread count, task grain) as
    /// process-global settings.  Unset fields are left to the `RMM_*` env
    /// vars / built-in derivations; returns whether anything was applied.
    pub fn apply_pool(&self) -> bool {
        if let Some(t) = self.pool.threads {
            crate::tensor::kernels::threads::set_threads_override(t);
        }
        if let Some(g) = self.pool.grain_rows {
            crate::tensor::pool::set_grain_override(g);
        }
        !self.pool.is_unset()
    }

    /// Install this config's kernel overrides (forced SIMD level, tuned
    /// blocking) as process-global settings.  Errors if the level cannot
    /// run on this CPU — a config tuned on another machine must fail
    /// loudly, not silently fall back.  Returns whether anything was
    /// applied.
    pub fn apply_kernels(&self) -> Result<bool> {
        use crate::tensor::kernels::{dispatch, tune};
        if let Some(s) = &self.kernels.simd {
            let l = dispatch::SimdLevel::parse_or_err(s)?;
            dispatch::set_simd_override(Some(l))?;
        }
        if let Some((mc, kc, nc)) = self.kernels.tuned {
            tune::set_blocking_override(Some(tune::Blocking { mc, kc, nc }))?;
        }
        Ok(!self.kernels.is_unset())
    }

    pub fn validate(&self) -> Result<()> {
        if crate::data::Task::parse(&self.task).is_none() {
            bail!("unknown task '{}'", self.task);
        }
        if let Some(b) = &self.backend {
            if crate::tensor::kernels::BackendKind::parse(b).is_none() {
                bail!("unknown backend '{b}' (expected packed|scalar)");
            }
        }
        if self.pool.threads == Some(0) {
            bail!("pool.threads must be >= 1");
        }
        if self.pool.grain_rows == Some(0) {
            bail!("pool.grain_rows must be >= 1");
        }
        if let Some(s) = &self.kernels.simd {
            // Name validity only — whether this CPU can run the level is
            // checked at apply time, so a tuned config stays loadable
            // (e.g. for inspection) on any machine.
            crate::tensor::kernels::dispatch::SimdLevel::parse_or_err(s)?;
        }
        if let Some((mc, kc, nc)) = self.kernels.tuned {
            crate::tensor::kernels::tune::Blocking { mc, kc, nc }.validate()?;
        }
        if self.sweep.shards == Some(0) {
            bail!("sweep.shards must be >= 1");
        }
        if let Some(s) = &self.sweep.schedule {
            if crate::sweep::Schedule::parse(s).is_none() {
                bail!("unknown sweep.schedule '{s}' (expected static|dynamic)");
            }
        }
        if self.sweep.lease_ttl_ms == Some(0) {
            bail!("sweep.lease_ttl_ms must be >= 1");
        }
        if let Some(seed) = self.sweep.chaos_seed {
            // JSON numbers travel as f64; a seed past 2^53 would not
            // round-trip and two runs "with the same config" could
            // compile different fault schedules.
            if seed > (1u64 << 53) {
                bail!("sweep.chaos_seed must fit in 2^53 (JSON round-trip)");
            }
        }
        if let Some(p) = &self.sweep.chaos_profile {
            crate::chaos::validate_profile(p)
                .with_context(|| format!("bad sweep.chaos_profile '{p}'"))?;
        }
        if self.daemon.workers == Some(0) {
            bail!("daemon.workers must be >= 1");
        }
        if self.daemon.queue_cap == Some(0) {
            bail!("daemon.queue_cap must be >= 1");
        }
        if self.daemon.poll_ms == Some(0) {
            bail!("daemon.poll_ms must be >= 1");
        }
        if let Some(mb) = self.rmm.mem_budget {
            if !mb.is_finite() || mb <= 0.0 || mb > 1.0 {
                bail!("rmm.mem_budget must be in (0, 1], got {mb}");
            }
        }
        let t = &self.train;
        if t.steps == 0 {
            bail!("train.steps must be > 0");
        }
        if t.prefetch_depth == 0 {
            bail!("train.prefetch_depth must be >= 1");
        }
        if !(0.0..1.0).contains(&(t.warmup_steps as f64 / t.steps.max(1) as f64)) {
            bail!("warmup_steps must be < steps");
        }
        if t.lr <= 0.0 || !t.lr.is_finite() {
            bail!("train.lr must be positive");
        }
        if !matches!(t.optimizer.as_str(), "adamw" | "adam" | "sgd" | "momentum") {
            bail!("unknown optimizer '{}'", t.optimizer);
        }
        if !LR_SCHEDULES.contains(&t.schedule.as_str()) {
            bail!("unknown schedule '{}'", t.schedule);
        }
        Ok(())
    }
}

fn req_str(v: &Json, key: &str) -> Result<String> {
    v.as_str()
        .map(|s| s.to_string())
        .with_context(|| format!("'{key}' must be a string"))
}

fn parse_pool(j: &Json) -> Result<PoolConfig> {
    let mut p = PoolConfig::default();
    let obj = j.as_obj().context("'pool' must be an object")?;
    for (k, v) in obj {
        match k.as_str() {
            "threads" => p.threads = Some(num(v, k)? as usize),
            "grain_rows" => p.grain_rows = Some(num(v, k)? as usize),
            other => bail!("unknown pool key '{other}'"),
        }
    }
    Ok(p)
}

fn parse_kernels(j: &Json) -> Result<KernelsConfig> {
    let mut kcfg = KernelsConfig::default();
    let obj = j.as_obj().context("'kernels' must be an object")?;
    for (k, v) in obj {
        match k.as_str() {
            "simd" => kcfg.simd = Some(req_str(v, k)?),
            "tuned" => {
                let t = v.as_obj().context("'kernels.tuned' must be an object")?;
                let (mut mc, mut kc, mut nc) = (None, None, None);
                for (tk, tv) in t {
                    match tk.as_str() {
                        "mc" => mc = Some(num(tv, tk)? as usize),
                        "kc" => kc = Some(num(tv, tk)? as usize),
                        "nc" => nc = Some(num(tv, tk)? as usize),
                        other => bail!("unknown kernels.tuned key '{other}'"),
                    }
                }
                match (mc, kc, nc) {
                    (Some(mc), Some(kc), Some(nc)) => kcfg.tuned = Some((mc, kc, nc)),
                    _ => bail!("kernels.tuned needs all of mc, kc, nc"),
                }
            }
            other => bail!("unknown kernels key '{other}'"),
        }
    }
    Ok(kcfg)
}

fn parse_sweep(j: &Json) -> Result<SweepConfig> {
    let mut s = SweepConfig::default();
    let obj = j.as_obj().context("'sweep' must be an object")?;
    for (k, v) in obj {
        match k.as_str() {
            "shards" => s.shards = Some(num(v, k)? as usize),
            "resume" => {
                s.resume = v.as_bool().context("'resume' must be a bool")?
            }
            "schedule" => s.schedule = Some(req_str(v, k)?),
            "lease_ttl_ms" => s.lease_ttl_ms = Some(num(v, k)? as u64),
            "session_cache" => {
                s.session_cache =
                    Some(v.as_bool().context("'session_cache' must be a bool")?)
            }
            "affinity" => {
                s.affinity = Some(v.as_bool().context("'affinity' must be a bool")?)
            }
            "artifact_cache" => {
                s.artifact_cache =
                    Some(v.as_bool().context("'artifact_cache' must be a bool")?)
            }
            "chaos_seed" => s.chaos_seed = Some(num(v, k)? as u64),
            "chaos_profile" => s.chaos_profile = Some(req_str(v, k)?),
            "respawn_budget" => s.respawn_budget = Some(num(v, k)? as u32),
            other => bail!("unknown sweep key '{other}'"),
        }
    }
    Ok(s)
}

fn parse_daemon(j: &Json) -> Result<DaemonConfig> {
    let mut d = DaemonConfig::default();
    let obj = j.as_obj().context("'daemon' must be an object")?;
    for (k, v) in obj {
        match k.as_str() {
            "workers" => d.workers = Some(num(v, k)? as usize),
            "queue_cap" => d.queue_cap = Some(num(v, k)? as usize),
            "poll_ms" => d.poll_ms = Some(num(v, k)? as u64),
            other => bail!("unknown daemon key '{other}'"),
        }
    }
    Ok(d)
}

fn parse_rmm(j: &Json) -> Result<RmmConfig> {
    let mut r = RmmConfig::default();
    let obj = j.as_obj().context("'rmm' must be an object")?;
    for (k, v) in obj {
        match k.as_str() {
            "mem_budget" => r.mem_budget = Some(num(v, k)?),
            other => bail!("unknown rmm key '{other}'"),
        }
    }
    Ok(r)
}

fn parse_train(j: &Json) -> Result<TrainConfig> {
    let mut t = TrainConfig::default();
    let obj = j.as_obj().context("'train' must be an object")?;
    for (k, v) in obj {
        match k.as_str() {
            "steps" => t.steps = num(v, k)? as usize,
            "warmup_steps" => t.warmup_steps = num(v, k)? as usize,
            "lr" => t.lr = num(v, k)?,
            "weight_decay" => t.weight_decay = num(v, k)?,
            "beta1" => t.beta1 = num(v, k)?,
            "beta2" => t.beta2 = num(v, k)?,
            "eps" => t.eps = num(v, k)?,
            "clip_norm" => t.clip_norm = num(v, k)?,
            "optimizer" => t.optimizer = req_str(v, k)?,
            "schedule" => t.schedule = req_str(v, k)?,
            "eval_every" => t.eval_every = num(v, k)? as usize,
            "log_every" => t.log_every = num(v, k)? as usize,
            "seed" => t.seed = num(v, k)? as u64,
            "prefetch" => {
                t.prefetch = v.as_bool().context("'prefetch' must be a bool")?
            }
            "prefetch_depth" => {
                // Checked here, not just in ExperimentConfig::validate:
                // SweepSpec::from_json parses a TrainConfig directly, and
                // a depth-0 sweep.json must fail with this error in the
                // worker, not a PrefetchBatcher assert panic mid-cell.
                t.prefetch_depth = num(v, k)? as usize;
                if t.prefetch_depth == 0 {
                    bail!("train.prefetch_depth must be >= 1");
                }
            }
            other => bail!("unknown train key '{other}'"),
        }
    }
    Ok(t)
}

fn num(v: &Json, key: &str) -> Result<f64> {
    v.as_f64().with_context(|| format!("'{key}' must be a number"))
}

fn train_to_json(t: &TrainConfig) -> Json {
    Json::obj(vec![
        ("steps", Json::num(t.steps as f64)),
        ("warmup_steps", Json::num(t.warmup_steps as f64)),
        ("lr", Json::num(t.lr)),
        ("weight_decay", Json::num(t.weight_decay)),
        ("beta1", Json::num(t.beta1)),
        ("beta2", Json::num(t.beta2)),
        ("eps", Json::num(t.eps)),
        ("clip_norm", Json::num(t.clip_norm)),
        ("optimizer", Json::str(t.optimizer.clone())),
        ("schedule", Json::str(t.schedule.clone())),
        ("eval_every", Json::num(t.eval_every as f64)),
        ("log_every", Json::num(t.log_every as f64)),
        ("seed", Json::num(t.seed as f64)),
        ("prefetch", Json::Bool(t.prefetch)),
        ("prefetch_depth", Json::num(t.prefetch_depth as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = ExperimentConfig::default();
        cfg.task = "mnli".into();
        cfg.train.lr = 5e-4;
        cfg.train.optimizer = "sgd".into();
        let j = cfg.to_json();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn rejects_unknown_keys() {
        let j = Json::parse(r#"{"bogus": 1}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn backend_selection_parses() {
        let j = Json::parse(r#"{"backend": "scalar"}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.backend.as_deref(), Some("scalar"));
        // absent key -> no preference: applies nothing, leaving the
        // decision to lower-precedence sources (env var / default)
        let cfg = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(cfg.backend, None);
        assert!(!cfg.apply_backend());
    }

    #[test]
    fn rejects_bad_values() {
        for src in [
            r#"{"task": "nope"}"#,
            r#"{"backend": "cuda"}"#,
            r#"{"train": {"steps": 0}}"#,
            r#"{"train": {"optimizer": "rmsprop"}}"#,
            r#"{"train": {"lr": -1}}"#,
            r#"{"pool": {"threads": 0}}"#,
            r#"{"pool": {"grain_rows": 0}}"#,
            r#"{"pool": {"bogus": 1}}"#,
            r#"{"sweep": {"shards": 0}}"#,
            r#"{"sweep": {"bogus": 1}}"#,
            r#"{"sweep": {"resume": 3}}"#,
            r#"{"sweep": {"schedule": "round-robin"}}"#,
            r#"{"sweep": {"schedule": "linear"}}"#,
            r#"{"sweep": {"lease_ttl_ms": 0}}"#,
            r#"{"sweep": {"session_cache": "on"}}"#,
            r#"{"sweep": {"affinity": 1}}"#,
            r#"{"sweep": {"artifact_cache": "on"}}"#,
            r#"{"train": {"prefetch": "yes"}}"#,
            r#"{"train": {"prefetch_depth": 0}}"#,
            r#"{"daemon": {"workers": 0}}"#,
            r#"{"daemon": {"queue_cap": 0}}"#,
            r#"{"daemon": {"poll_ms": 0}}"#,
            r#"{"daemon": {"bogus": 1}}"#,
            r#"{"daemon": {"workers": "many"}}"#,
            r#"{"kernels": {"bogus": 1}}"#,
            r#"{"kernels": {"simd": "sse9"}}"#,
            r#"{"kernels": {"simd": 2}}"#,
            r#"{"kernels": {"tuned": {"mc": 128}}}"#,
            r#"{"kernels": {"tuned": {"mc": 129, "kc": 256, "nc": 1024}}}"#,
            r#"{"kernels": {"tuned": {"mc": 128, "kc": 0, "nc": 1024}}}"#,
            r#"{"kernels": {"tuned": {"mc": 128, "kc": 256, "nc": 12}}}"#,
            r#"{"kernels": {"tuned": {"mc": 128, "kc": 256, "nc": 1024, "oc": 1}}}"#,
            r#"{"rmm": {"bogus": 1}}"#,
            r#"{"rmm": {"mem_budget": 0}}"#,
            r#"{"rmm": {"mem_budget": -0.5}}"#,
            r#"{"rmm": {"mem_budget": 1.5}}"#,
            r#"{"rmm": {"mem_budget": "tight"}}"#,
        ] {
            let j = Json::parse(src).unwrap();
            assert!(ExperimentConfig::from_json(&j).is_err(), "{src}");
        }
    }

    #[test]
    fn pool_section_parses_roundtrips_and_applies() {
        let _g = crate::tensor::pool::knob_test_lock();
        let j = Json::parse(r#"{"pool": {"threads": 3, "grain_rows": 16}}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.pool.threads, Some(3));
        assert_eq!(cfg.pool.grain_rows, Some(16));
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        assert!(cfg.apply_pool());
        // restore process defaults for the other tests in this binary
        crate::tensor::kernels::threads::set_threads_override(0);
        crate::tensor::pool::set_grain_override(0);

        // absent section -> no preference, nothing applied
        let cfg = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert!(cfg.pool.is_unset());
        assert!(!cfg.apply_pool());
    }

    #[test]
    fn kernels_section_parses_roundtrips_and_applies() {
        use crate::tensor::kernels::{dispatch, tune};
        let _g = crate::tensor::pool::knob_test_lock();
        let j = Json::parse(
            r#"{"kernels": {"simd": "portable",
                            "tuned": {"mc": 64, "kc": 128, "nc": 512}}}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.kernels.simd.as_deref(), Some("portable"));
        assert_eq!(cfg.kernels.tuned, Some((64, 128, 512)));
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        // apply installs both process-globals ("portable" runs anywhere)
        assert!(cfg.apply_kernels().unwrap());
        assert_eq!(dispatch::active_level(), dispatch::SimdLevel::Portable);
        assert_eq!(
            tune::blocking(),
            tune::Blocking { mc: 64, kc: 128, nc: 512 }
        );
        dispatch::set_simd_override(None).unwrap();
        tune::set_blocking_override(None).unwrap();

        // a level this CPU can't run: valid config, failing apply
        if let Some(&bad) = dispatch::SimdLevel::ALL.iter().find(|l| !l.supported()) {
            let j = Json::parse(&format!(r#"{{"kernels": {{"simd": "{}"}}}}"#, bad.name()))
                .unwrap();
            let cfg = ExperimentConfig::from_json(&j).unwrap();
            assert!(cfg.apply_kernels().is_err());
        }

        // absent section -> no preference, nothing applied, json omits it
        let cfg = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert!(cfg.kernels.is_unset());
        assert!(!cfg.apply_kernels().unwrap());
        assert!(cfg.to_json().get("kernels").is_null());
    }

    #[test]
    fn sweep_section_parses_and_roundtrips() {
        let j = Json::parse(
            r#"{"sweep": {"shards": 3, "resume": true,
                          "schedule": "dynamic", "lease_ttl_ms": 5000,
                          "session_cache": false, "affinity": true,
                          "artifact_cache": true,
                          "chaos_seed": 11, "chaos_profile": "crash",
                          "respawn_budget": 2}}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.sweep.shards, Some(3));
        assert!(cfg.sweep.resume);
        assert_eq!(cfg.sweep.schedule.as_deref(), Some("dynamic"));
        assert_eq!(cfg.sweep.lease_ttl_ms, Some(5000));
        assert_eq!(cfg.sweep.session_cache, Some(false));
        assert_eq!(cfg.sweep.affinity, Some(true));
        assert_eq!(cfg.sweep.artifact_cache, Some(true));
        assert_eq!(cfg.sweep.chaos_seed, Some(11));
        assert_eq!(cfg.sweep.chaos_profile.as_deref(), Some("crash"));
        assert_eq!(cfg.sweep.respawn_budget, Some(2));
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        // "static" is also a valid explicit choice
        let j = Json::parse(r#"{"sweep": {"schedule": "static"}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_ok());
        // an explicit point@hit=action schedule is a valid profile too
        let j = Json::parse(
            r#"{"sweep": {"chaos_profile": "w0:claim.create@0=err:interrupted"}}"#,
        )
        .unwrap();
        assert!(ExperimentConfig::from_json(&j).is_ok());
        // absent section -> no preference
        let cfg = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert!(cfg.sweep.is_unset());
    }

    #[test]
    fn chaos_config_rejects_bad_values() {
        for bad in [
            r#"{"sweep": {"chaos_profile": 3}}"#,
            r#"{"sweep": {"chaos_profile": "nope"}}"#,
            r#"{"sweep": {"chaos_profile": "claim.create@0=explode"}}"#,
            r#"{"sweep": {"chaos_seed": 1e17}}"#,
            r#"{"sweep": {"respawn_budget": "many"}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(
                ExperimentConfig::from_json(&j).is_err(),
                "config should be rejected: {bad}"
            );
        }
    }

    #[test]
    fn daemon_section_parses_and_roundtrips() {
        let j = Json::parse(
            r#"{"daemon": {"workers": 2, "queue_cap": 5, "poll_ms": 100}}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.daemon.workers, Some(2));
        assert_eq!(cfg.daemon.queue_cap, Some(5));
        assert_eq!(cfg.daemon.poll_ms, Some(100));
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        // absent section -> no preference, and to_json omits it
        let cfg = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert!(cfg.daemon.is_unset());
        assert!(cfg.to_json().get("daemon").is_null());
    }

    #[test]
    fn rmm_section_parses_and_roundtrips() {
        let j = Json::parse(r#"{"rmm": {"mem_budget": 0.25}}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.rmm.mem_budget, Some(0.25));
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        // the whole residual is a valid (trivial) budget
        let j = Json::parse(r#"{"rmm": {"mem_budget": 1.0}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_ok());
        // absent section -> no preference, and to_json omits it
        let cfg = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert!(cfg.rmm.is_unset());
        assert!(cfg.to_json().get("rmm").is_null());
    }

    #[test]
    fn train_prefetch_parses_and_roundtrips() {
        let j =
            Json::parse(r#"{"train": {"prefetch": true, "prefetch_depth": 3}}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert!(cfg.train.prefetch);
        assert_eq!(cfg.train.prefetch_depth, 3);
        let back = TrainConfig::from_json(&cfg.train.to_json()).unwrap();
        assert_eq!(cfg.train, back);
        assert!(!TrainConfig::default().prefetch);
        assert_eq!(TrainConfig::default().prefetch_depth, 1);
        // the direct TrainConfig parse (the sweep.json path) must reject
        // a zero depth too, not defer to ExperimentConfig::validate
        let j = Json::parse(r#"{"prefetch_depth": 0}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
    }
}
