//! Bench harness: one driver per paper table/figure (DESIGN.md §5).
//!
//! Each driver prints the paper-style rows and returns a JSON report the
//! CLI writes under `reports/` for EXPERIMENTS.md regeneration.

pub mod budget;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod runner;
pub mod table2;
pub mod table3;
pub mod table4;

use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

/// Write a driver's JSON report under `reports/<name>.json`.
pub fn write_report(dir: &Path, name: &str, report: &Json) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, report.to_string_pretty())?;
    println!("report -> {}", path.display());
    Ok(())
}
