//! Figure 3 / Figure 8: peak memory vs batch size for several ρ.
//!
//! Paper shape: stored-activation bytes grow ~linearly in B, with slope
//! scaling with ρ for the linear-layer share (near-linear scaling "confirms
//! correctness of the implementation", §3.2).  Measured store bytes for
//! B ∈ {8,16,32,64} plus the analytic model and its RoBERTa-scale
//! extrapolation; `--all-tasks` sweeps the task suite (Fig. 8).

use anyhow::Result;

use crate::config::TrainConfig;
use crate::data::Task;
use crate::memory::{MemoryModel, ModelGeometry};
use crate::session::Session;
use crate::util::json::Json;

use super::runner::{run_finetune, RunOpts};

pub const BATCHES: [usize; 4] = [8, 16, 32, 64];
pub const RHOS: [f64; 4] = [1.0, 0.5, 0.2, 0.1];

fn variant_for(bsz: usize, rho: f64) -> String {
    let tag = match rho {
        r if (r - 1.0).abs() < 1e-9 => "r100",
        r if (r - 0.5).abs() < 1e-9 => "r50",
        r if (r - 0.2).abs() < 1e-9 => "r20",
        _ => "r10",
    };
    if bsz == 16 {
        format!("small_cls2_{tag}_gauss")
    } else {
        format!("small_cls2_b{bsz}_{tag}_gauss")
    }
}

pub fn run(session: &mut Session, tasks: &[Task], steps: usize) -> Result<Json> {
    let mut series = Vec::new();
    // Batch-size variants are lowered for the 2-class head geometry only.
    let tasks: Vec<Task> = tasks
        .iter()
        .copied()
        .filter(|t| !t.is_regression() && t.n_classes() == 2)
        .collect();
    for &task in &tasks {
        println!("\nFig 3 (task {}): peak residual bytes vs batch size", task.name());
        println!("{:>8} {:>8} {:>14} {:>14} {:>16}", "rho", "batch", "measured KiB", "model KiB", "roberta MiB");
        for &rho in &RHOS {
            for &bsz in &BATCHES {
                let vname = variant_for(bsz, rho);
                let geometry = session.manifest()?.variant(&vname)?.config.geometry();
                let train = TrainConfig {
                    steps,
                    warmup_steps: 0,
                    log_every: steps.max(1),
                    ..TrainConfig::default()
                };
                let res = run_finetune(
                    session,
                    &vname,
                    task,
                    RunOpts { train, skip_eval: true, ..Default::default() },
                )?;
                let model = MemoryModel::new(geometry, rho);
                let rob =
                    MemoryModel::new(ModelGeometry::roberta_base(bsz * 2, 128), rho);
                println!(
                    "{:>8.2} {:>8} {:>14.1} {:>14.1} {:>16.1}",
                    rho,
                    bsz,
                    res.peak_residual_bytes as f64 / 1024.0,
                    model.residual_bytes() as f64 / 1024.0,
                    rob.residual_bytes() as f64 / (1024.0 * 1024.0),
                );
                series.push(Json::obj(vec![
                    ("task", Json::str(task.name())),
                    ("rho", Json::num(rho)),
                    ("batch", Json::num(bsz as f64)),
                    ("measured_bytes", Json::num(res.peak_residual_bytes as f64)),
                    ("model_bytes", Json::num(model.residual_bytes() as f64)),
                    ("roberta_bytes", Json::num(rob.residual_bytes() as f64)),
                ]));
            }
        }
    }
    Ok(Json::obj(vec![
        ("experiment", Json::str("fig3")),
        ("series", Json::Arr(series)),
    ]))
}
