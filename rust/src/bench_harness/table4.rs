//! Table 4: randomized-matmul variants (Gauss / Rademacher / DCT / DFT /
//! RowSample) on the CoLA-like task — score + training time.
//!
//! Paper shape: all sketch families degrade gracefully with ρ; training
//! time differs by family (their naive PyTorch DCT/DFT were *slower* than
//! Gauss despite better asymptotics — our FFT crossover bench shows where
//! the asymptotics win).
//!
//! Thin grid declaration over `sweep::` — the no-RMM baseline is the
//! sketch="none" cell at index 0, then (family × ρ) cells in order.
//! Scheduling (static shards or dynamic claim/lease stealing) lives in
//! `sweep::`; the baseline cell is identified by its *index*, not by
//! completion order, so any schedule assembles the same report.

use crate::config::TrainConfig;
use crate::sweep::SweepSpec;
use crate::util::json::Json;

pub const KINDS: [&str; 5] = ["gauss", "rademacher", "dct", "dft", "rowsample"];
pub const RHOS: [f64; 3] = [0.5, 0.2, 0.1];

/// The Table 4 grid: the baseline cell first, then family-major.
pub fn spec(train: TrainConfig) -> SweepSpec {
    let seed = train.seed;
    let mut spec = SweepSpec::new("table4", train);
    spec.push("small_cls2_r100_gauss", "cola", 1.0, "none", seed, 0);
    for kind in KINDS {
        for &rho in &RHOS {
            let tag = match rho {
                r if (r - 0.5).abs() < 1e-9 => "r50",
                r if (r - 0.2).abs() < 1e-9 => "r20",
                _ => "r10",
            };
            spec.push(format!("small_cls2_{tag}_{kind}"), "cola", rho, kind, seed, 0);
        }
    }
    spec
}

/// Fold merged cell results (`RunResult` JSON per cell) into the console
/// table and the report rows (baseline row omits `host_rmm_ms`, matching
/// its no-RMM semantics).
pub fn assemble(spec: &SweepSpec, results: &[Json]) -> Json {
    let backend = results
        .first()
        .map(|r| r.get("backend").as_str().unwrap_or("?").to_string())
        .unwrap_or_else(|| "?".to_string());
    println!(
        "\nTable 4: sketch variants on CoLA (score, train time; host grads \
         via the '{backend}' backend)"
    );
    println!(
        "{:>12} {:>6} {:>8} {:>10} {:>12} {:>12}",
        "matmul", "rate", "score", "time s", "host exact", "host rmm"
    );
    let mut rows = Vec::new();
    for (cell, res) in spec.cells.iter().zip(results) {
        let score = res.get("score").as_f64().unwrap_or(f64::NAN);
        let wall_s = res.get("wall_s").as_f64().unwrap_or(f64::NAN);
        let exact = res.get("host_exact_ms").as_f64().unwrap_or(f64::NAN);
        if cell.sketch == "none" {
            println!(
                "{:>12} {:>6} {:>8.2} {:>10.1} {:>10.2}ms {:>12}",
                "No RMM", "-", score, wall_s, exact, "-"
            );
            rows.push(Json::obj(vec![
                ("kind", Json::str("none")),
                ("rho", Json::num(1.0)),
                ("score", res.get("score").clone()),
                ("wall_s", res.get("wall_s").clone()),
                ("backend", res.get("backend").clone()),
                ("host_exact_ms", res.get("host_exact_ms").clone()),
            ]));
        } else {
            let rmm = res.get("host_rmm_ms").as_f64().unwrap_or(f64::NAN);
            println!(
                "{:>12} {:>5.0}% {:>8.2} {:>10.1} {:>10.2}ms {:>10.2}ms",
                cell.sketch,
                cell.rho * 100.0,
                score,
                wall_s,
                exact,
                rmm
            );
            rows.push(Json::obj(vec![
                ("kind", Json::str(cell.sketch.clone())),
                ("rho", Json::num(cell.rho)),
                ("score", res.get("score").clone()),
                ("wall_s", res.get("wall_s").clone()),
                ("backend", res.get("backend").clone()),
                ("host_exact_ms", res.get("host_exact_ms").clone()),
                ("host_rmm_ms", res.get("host_rmm_ms").clone()),
            ]));
        }
    }
    Json::obj(vec![
        ("experiment", Json::str("table4")),
        ("rows", Json::Arr(rows)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_has_baseline_then_family_major_cells() {
        let s = spec(TrainConfig::default());
        assert_eq!(s.cells.len(), 1 + KINDS.len() * RHOS.len());
        assert_eq!(s.cells[0].sketch, "none");
        assert_eq!(s.cells[0].variant, "small_cls2_r100_gauss");
        assert_eq!(s.cells[1].sketch, "gauss");
        assert_eq!(s.cells[1].variant, "small_cls2_r50_gauss");
        assert_eq!(s.cells[4].sketch, "rademacher");
        assert_eq!(s.cells[6].variant, "small_cls2_r10_rademacher");
    }

    #[test]
    fn assemble_omits_host_rmm_on_baseline_only() {
        let s = spec(TrainConfig::default());
        let results: Vec<Json> = s
            .cells
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("score", Json::num(c.index as f64)),
                    ("wall_s", Json::num(1.0)),
                    ("backend", Json::str("packed")),
                    ("host_exact_ms", Json::num(2.0)),
                    ("host_rmm_ms", Json::num(3.0)),
                ])
            })
            .collect();
        let rep = assemble(&s, &results);
        let rows = rep.get("rows").as_arr().unwrap();
        assert!(rows[0].get("host_rmm_ms").is_null());
        assert_eq!(rows[1].get("host_rmm_ms").as_f64(), Some(3.0));
        assert_eq!(rows[0].get("kind").as_str(), Some("none"));
    }
}
