//! Table 4: randomized-matmul variants (Gauss / Rademacher / DCT / DFT /
//! RowSample) on the CoLA-like task — score + training time.
//!
//! Paper shape: all sketch families degrade gracefully with ρ; training
//! time differs by family (their naive PyTorch DCT/DFT were *slower* than
//! Gauss despite better asymptotics — our FFT crossover bench shows where
//! the asymptotics win).

use anyhow::Result;

use crate::config::TrainConfig;
use crate::data::Task;
use crate::runtime::{Engine, Manifest};
use crate::util::json::Json;

use super::runner::{run_finetune, RunOpts};

pub const KINDS: [&str; 5] = ["gauss", "rademacher", "dct", "dft", "rowsample"];
pub const RHOS: [f64; 3] = [0.5, 0.2, 0.1];

pub fn run(
    engine: &mut Engine,
    manifest: &Manifest,
    train: TrainConfig,
) -> Result<Json> {
    let task = Task::Cola;
    let mut rows = Vec::new();

    // Baseline row (no RMM).
    let base = run_finetune(
        engine,
        manifest,
        "small_cls2_r100_gauss",
        task,
        RunOpts { train: train.clone(), ..Default::default() },
    )?;
    println!(
        "\nTable 4: sketch variants on CoLA (score, train time; host grads \
         via the '{}' backend)",
        base.backend
    );
    println!(
        "{:>12} {:>6} {:>8} {:>10} {:>12} {:>12}",
        "matmul", "rate", "score", "time s", "host exact", "host rmm"
    );
    println!(
        "{:>12} {:>6} {:>8.2} {:>10.1} {:>10.2}ms {:>12}",
        "No RMM", "-", base.score, base.wall_s, base.host_exact_ms, "-"
    );
    rows.push(Json::obj(vec![
        ("kind", Json::str("none")),
        ("rho", Json::num(1.0)),
        ("score", Json::num(base.score)),
        ("wall_s", Json::num(base.wall_s)),
        ("backend", Json::str(base.backend.clone())),
        ("host_exact_ms", Json::num(base.host_exact_ms)),
    ]));

    for kind in KINDS {
        for &rho in &RHOS {
            let tag = match rho {
                r if (r - 0.5).abs() < 1e-9 => "r50",
                r if (r - 0.2).abs() < 1e-9 => "r20",
                _ => "r10",
            };
            let vname = format!("small_cls2_{tag}_{kind}");
            eprintln!("table4: {vname}");
            let res = run_finetune(
                engine,
                manifest,
                &vname,
                task,
                RunOpts { train: train.clone(), ..Default::default() },
            )?;
            println!(
                "{:>12} {:>5.0}% {:>8.2} {:>10.1} {:>10.2}ms {:>10.2}ms",
                kind,
                rho * 100.0,
                res.score,
                res.wall_s,
                res.host_exact_ms,
                res.host_rmm_ms
            );
            rows.push(Json::obj(vec![
                ("kind", Json::str(kind)),
                ("rho", Json::num(rho)),
                ("score", Json::num(res.score)),
                ("wall_s", Json::num(res.wall_s)),
                ("backend", Json::str(res.backend.clone())),
                ("host_exact_ms", Json::num(res.host_exact_ms)),
                ("host_rmm_ms", Json::num(res.host_rmm_ms)),
            ]));
        }
    }
    Ok(Json::obj(vec![
        ("experiment", Json::str("table4")),
        ("rows", Json::Arr(rows)),
    ]))
}
