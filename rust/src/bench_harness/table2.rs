//! Table 2: GLUE scores vs compression ratio ρ (gauss sketch).
//!
//! Paper shape to reproduce: ρ=0.9/0.5 ≈ baseline, ρ=0.2 slightly lower,
//! ρ=0.1 visibly lower — with small/noisy tasks (WNLI, RTE) degrading the
//! most and occasional noise *wins* on individual tasks.
//!
//! The driver is a thin grid declaration: [`spec`] lays the (ρ × task ×
//! seed) cells out in canonical order and [`assemble`] folds the merged
//! cell results back into the paper-style table + JSON report.  Cell
//! execution, scheduling (static `--shard i/N` or dynamic claim/lease
//! stealing — this grid is the skew poster child: an MNLI cell dwarfs a
//! WNLI cell, so `--schedule dynamic` erases the straggler shard) and
//! resume all live in `sweep::` (see its module doc).  [`assemble`] must
//! stay a pure function of (spec, merged results): canonical cell order
//! is the *only* order it may rely on, because the dynamic schedule runs
//! cells in claim order.

use crate::config::TrainConfig;
use crate::data::Task;
use crate::sweep::{Cell, SweepSpec};
use crate::util::json::Json;

use super::runner::{head_for, variant_name};

pub const RHOS: [f64; 5] = [1.0, 0.9, 0.5, 0.2, 0.1];

pub fn tasks_from_arg(arg: Option<&str>) -> Vec<Task> {
    match arg {
        None | Some("all") => Task::ALL.to_vec(),
        Some(list) => list
            .split(',')
            .filter_map(|t| Task::parse(t.trim()))
            .collect(),
    }
}

/// The Table 2 grid: ρ outermost (so report rows group naturally), then
/// task, then seed.
pub fn spec(tasks: &[Task], rhos: &[f64], seeds: &[u64], train: TrainConfig) -> SweepSpec {
    let mut spec = SweepSpec::new("table2", train);
    for &rho in rhos {
        for &task in tasks {
            for &seed in seeds {
                let vname = variant_name("small", head_for(task), rho, "gauss");
                spec.push(vname, task.name(), rho, "gauss", seed, 0);
            }
        }
    }
    spec
}

/// Fold merged cell results (one `RunResult` JSON per cell, in canonical
/// cell order) into the paper-style console table and the report JSON.
/// Pure in `(spec, results)` — the byte-identity across shard counts
/// that `tests/prop_sweep.rs` verifies rests on this purity.
pub fn assemble(spec: &SweepSpec, results: &[Json]) -> Json {
    // Group (cell, result) pairs by the contiguous rho runs of the grid.
    let mut rows: Vec<(f64, Vec<(&Cell, &Json)>)> = Vec::new();
    for (cell, res) in spec.cells.iter().zip(results) {
        match rows.last_mut() {
            Some((rho, group)) if *rho == cell.rho => group.push((cell, res)),
            _ => rows.push((cell.rho, vec![(cell, res)])),
        }
    }
    // Distinct task order as laid out within a rho group.
    let tasks: Vec<String> = rows
        .first()
        .map(|(_, group)| {
            let mut ts: Vec<String> = Vec::new();
            for (c, _) in group {
                if !ts.contains(&c.task) {
                    ts.push(c.task.clone());
                }
            }
            ts
        })
        .unwrap_or_default();

    println!("\nTable 2: fine-tuning scores vs compression ratio (gauss)");
    print!("{:>8}", "rho");
    for task in &tasks {
        print!("{:>9}", task.to_uppercase());
    }
    println!("{:>9}", "Avg");
    for (rho, group) in &rows {
        if (*rho - 1.0).abs() < 1e-9 {
            print!("{:>8}", "No RMM");
        } else {
            print!("{:>7.0}%", rho * 100.0);
        }
        let mut sum = 0.0;
        for task in &tasks {
            // average over the seed axis of this (rho, task)
            let scores: Vec<f64> = group
                .iter()
                .filter(|(c, _)| &c.task == task)
                .map(|(_, r)| r.get("score").as_f64().unwrap_or(f64::NAN))
                .collect();
            let avg = scores.iter().sum::<f64>() / scores.len().max(1) as f64;
            print!("{:>9.2}", avg);
            sum += avg;
        }
        println!("{:>9.2}", sum / tasks.len().max(1) as f64);
    }

    Json::obj(vec![
        ("experiment", Json::str("table2")),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|(rho, group)| {
                        Json::obj(vec![
                            ("rho", Json::num(*rho)),
                            (
                                "results",
                                Json::Arr(
                                    group.iter().map(|(_, r)| (*r).clone()).collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_order_is_rho_task_seed() {
        let tasks = [Task::Cola, Task::Sst2];
        let s = spec(&tasks, &[1.0, 0.5], &[1, 2], TrainConfig::default());
        assert_eq!(s.cells.len(), 8);
        assert_eq!(s.experiment, "table2");
        assert_eq!(s.cells[0].task, "cola");
        assert_eq!(s.cells[0].seed, 1);
        assert_eq!(s.cells[1].seed, 2);
        assert_eq!(s.cells[2].task, "sst2");
        assert!((s.cells[4].rho - 0.5).abs() < 1e-12);
        assert_eq!(s.cells[0].variant, "small_cls2_r100_gauss");
        assert_eq!(s.cells[4].variant, "small_cls2_r50_gauss");
    }

    #[test]
    fn assemble_groups_by_rho_and_is_pure() {
        let tasks = [Task::Cola, Task::Wnli];
        let s = spec(&tasks, &[1.0, 0.1], &[7], TrainConfig::default());
        let results: Vec<Json> = s
            .cells
            .iter()
            .map(|c| Json::obj(vec![("score", Json::num(c.index as f64))]))
            .collect();
        let a = assemble(&s, &results);
        let b = assemble(&s, &results);
        assert_eq!(a.to_string_pretty(), b.to_string_pretty());
        let rows = a.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("results").as_arr().unwrap().len(), 2);
    }
}
