//! Table 2: GLUE scores vs compression ratio ρ (gauss sketch).
//!
//! Paper shape to reproduce: ρ=0.9/0.5 ≈ baseline, ρ=0.2 slightly lower,
//! ρ=0.1 visibly lower — with small/noisy tasks (WNLI, RTE) degrading the
//! most and occasional noise *wins* on individual tasks.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::data::Task;
use crate::runtime::{Engine, Manifest};
use crate::util::json::Json;

use super::runner::{head_for, run_finetune, variant_name, RunOpts, RunResult};

pub const RHOS: [f64; 5] = [1.0, 0.9, 0.5, 0.2, 0.1];

pub fn tasks_from_arg(arg: Option<&str>) -> Vec<Task> {
    match arg {
        None | Some("all") => Task::ALL.to_vec(),
        Some(list) => list
            .split(',')
            .filter_map(|t| Task::parse(t.trim()))
            .collect(),
    }
}

pub fn run(
    engine: &mut Engine,
    manifest: &Manifest,
    tasks: &[Task],
    rhos: &[f64],
    train: TrainConfig,
) -> Result<Json> {
    let mut rows: Vec<(f64, Vec<RunResult>)> = Vec::new();
    for &rho in rhos {
        let mut results = Vec::new();
        for &task in tasks {
            let vname = variant_name("small", head_for(task), rho, "gauss");
            eprintln!("table2: rho={rho} task={} variant={vname}", task.name());
            let res = run_finetune(
                engine,
                manifest,
                &vname,
                task,
                RunOpts { train: train.clone(), ..Default::default() },
            )?;
            eprintln!("  -> score {:.2}", res.score);
            results.push(res);
        }
        rows.push((rho, results));
    }

    // ---- paper-style table ----
    println!("\nTable 2: fine-tuning scores vs compression ratio (gauss)");
    print!("{:>8}", "rho");
    for task in tasks {
        print!("{:>9}", task.name().to_uppercase());
    }
    println!("{:>9}", "Avg");
    for (rho, results) in &rows {
        if (*rho - 1.0).abs() < 1e-9 {
            print!("{:>8}", "No RMM");
        } else {
            print!("{:>7.0}%", rho * 100.0);
        }
        let mut sum = 0.0;
        for r in results {
            print!("{:>9.2}", r.score);
            sum += r.score;
        }
        println!("{:>9.2}", sum / results.len() as f64);
    }

    Ok(Json::obj(vec![
        ("experiment", Json::str("table2")),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|(rho, results)| {
                        Json::obj(vec![
                            ("rho", Json::num(*rho)),
                            (
                                "results",
                                Json::Arr(results.iter().map(|r| r.to_json()).collect()),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]))
}
