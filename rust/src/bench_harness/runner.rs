//! Shared experiment runner: one fine-tuning run = (variant, task, config)
//! → final metric, loss curves, throughput, memory stats.  Every table and
//! figure driver composes this.
//!
//! Runs execute through a worker's warm [`Session`] (`crate::session`):
//! the engine's executable cache, per-variant trainer setups, tokenizers
//! and dev-batch sets all persist across `run_finetune` calls, so
//! same-variant sweep cells skip cold start.  Caching is observation-free
//! — a warm run is byte-identical to a cold one (see the session module
//! doc for the contract and `tests/prop_session.rs` for the pin).

use anyhow::{bail, Context as _, Result};

use crate::config::TrainConfig;
use crate::coordinator::{MetricsLog, Trainer};
use crate::data::{AnyBatcher, Batch, Batcher, Split, Task, TaskGen};
use crate::memory::{MemoryModel, ModelGeometry};
use crate::rmm;
use crate::rng::philox::PhiloxStream;
use crate::runtime::Variant;
use crate::session::Session;
use crate::sweep::{mock_cell, Cell, CellCtx, SweepSpec};
use crate::tensor::{kernels, pool, Tensor};
use crate::util::fnv;
use crate::util::json::Json;

/// Everything measured in one run (a row of a table / a series of a fig).
#[derive(Debug, Clone)]
pub struct RunResult {
    pub variant: String,
    pub task: String,
    pub rho: f64,
    pub sketch: String,
    pub score: f64,
    pub final_train_loss: f64,
    pub steps: usize,
    pub wall_s: f64,
    pub samples_per_s: f64,
    pub peak_residual_bytes: usize,
    /// Host GEMM backend the baselines below were measured with.
    pub backend: String,
    /// Host-side exact ∂W = YᵀX at this variant's geometry (ms/step).
    pub host_exact_ms: f64,
    /// Host-side RMM project + contract at this variant's geometry (ms/step).
    pub host_rmm_ms: f64,
    /// Compute-pool thread policy in force during the run.
    pub pool_threads: usize,
    /// Pool tasks executed over the whole run (host kernels only).
    pub pool_tasks: u64,
    /// Tasks claimed cross-queue (work stealing) over the whole run.
    pub pool_steals: u64,
    /// Engine executable-cache hits during this run: non-zero whenever a
    /// warm session let this cell reuse executables compiled by an
    /// earlier same-variant cell (or by earlier steps of this one).
    /// Deliberately NOT serialized by [`Self::to_json`]: the value
    /// depends on the worker's warm history, and fragments must stay a
    /// pure function of the cell for the byte-identity contract —
    /// `run_cell` reports it on stderr instead.
    pub exe_cache_hits: u64,
    /// Executable compiles this run forced (cache misses); warm-history-
    /// dependent like `exe_cache_hits`, so stderr-only as well.
    pub exe_cache_misses: u64,
    pub train_losses: Vec<(usize, f64)>,
    pub eval_losses: Vec<(usize, f64)>,
    pub probe_series: Vec<(usize, [f64; 5])>,
}

/// Finite number or JSON null (the codec rejects NaN/Infinity, so a
/// skipped measurement must not leak an unparseable literal into reports).
pub fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::num(v)
    } else {
        Json::Null
    }
}

impl RunResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("variant", Json::str(self.variant.clone())),
            ("task", Json::str(self.task.clone())),
            ("rho", Json::num(self.rho)),
            ("sketch", Json::str(self.sketch.clone())),
            ("score", num_or_null(self.score)),
            // num_or_null throughout: a skipped measurement (skip_eval, a
            // zero-step run, a no-RMM variant) must serialize as null, not
            // as an unparseable NaN literal — sweep fragments are parsed
            // back during merge, so this is load-bearing, not cosmetic.
            ("final_train_loss", num_or_null(self.final_train_loss)),
            ("steps", Json::num(self.steps as f64)),
            ("wall_s", num_or_null(self.wall_s)),
            ("samples_per_s", num_or_null(self.samples_per_s)),
            ("peak_residual_bytes", Json::num(self.peak_residual_bytes as f64)),
            ("backend", Json::str(self.backend.clone())),
            ("host_exact_ms", num_or_null(self.host_exact_ms)),
            ("host_rmm_ms", num_or_null(self.host_rmm_ms)),
            ("pool_threads", Json::num(self.pool_threads as f64)),
            ("pool_tasks", Json::num(self.pool_tasks as f64)),
            ("pool_steals", Json::num(self.pool_steals as f64)),
            // exe_cache_{hits,misses} intentionally omitted: they depend
            // on the worker's warm history, and this JSON becomes a sweep
            // fragment that must be a pure function of the cell.
        ])
    }
}

/// Host-baseline cost of the gradient contraction at a variant's geometry,
/// measured through the *selected kernel backend* so every reported
/// baseline number reflects the optimized path: returns
/// `(exact ∂W = YᵀX, RMM project + contract)` in ms/step (best of 3 after
/// a warmup).  Results are cached per (geometry, sketch, backend) so a
/// Table 4 / Fig 5 sweep measures each distinct baseline once instead of
/// once per row.
pub fn host_grad_baseline(variant: &Variant) -> (f64, f64) {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    type Key = (usize, usize, usize, usize, String, &'static str);
    static CACHE: OnceLock<Mutex<HashMap<Key, (f64, f64)>>> = OnceLock::new();

    let g = variant.config.geometry();
    let key: Key = (
        variant.rows,
        variant.b_proj,
        g.d_model,
        g.d_ff,
        variant.config.sketch.clone(),
        kernels::active().name(),
    );
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(&hit) = cache.lock().unwrap().get(&key) {
        return hit;
    }
    let result = measure_grad_baseline(variant);
    cache.lock().unwrap().insert(key, result);
    result
}

fn measure_grad_baseline(variant: &Variant) -> (f64, f64) {
    let g = variant.config.geometry();
    let rows = variant.rows.max(1);
    let b_proj = variant.b_proj.max(1);
    let mut s = PhiloxStream::new(0xB45E, 3);
    let x = Tensor::from_fn(rows, g.d_model, |_, _| s.next_normal());
    let y = Tensor::from_fn(rows, g.d_ff, |_, _| s.next_normal());
    let seed = (7, 8);

    let time_best = |f: &dyn Fn()| -> f64 {
        f(); // warm
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t = std::time::Instant::now();
            f();
            best = best.min(t.elapsed().as_secs_f64() * 1e3);
        }
        best
    };
    let exact_ms = time_best(&|| {
        std::hint::black_box(rmm::exact_grad_w(&y, &x));
    });
    // Only measure the RMM side when the variant actually names an
    // estimator configuration (a family, or its `avjp-` per-path form —
    // both share the grad-weight kernel being timed here); fabricating a
    // default-Gauss number for a no-RMM variant would put a
    // concrete-but-wrong timing in the report.
    let rmm_ms = match rmm::EstimatorSpec::parse(&variant.config.sketch) {
        Ok(est) => time_best(&|| {
            let xp = rmm::project(est.kind, &x, b_proj, seed);
            std::hint::black_box(rmm::rmm_grad_w(est.kind, &y, &xp, seed));
        }),
        Err(_) => f64::NAN, // "none" and friends: no RMM path to measure
    };
    (exact_ms, rmm_ms)
}

/// Options modulating a run (eval cadence, logging, warm start).
pub struct RunOpts<'a> {
    pub train: TrainConfig,
    pub log: Option<&'a mut MetricsLog>,
    /// Record eval loss every N steps (0 = never) — Fig. 5 series.
    pub eval_loss_every: usize,
    /// Warm-start encoder body from (names, params) if provided.
    pub warm_start: Option<(&'a [String], &'a [Vec<f32>])>,
    /// Skip the final dev-metric evaluation (memory/throughput-only runs).
    pub skip_eval: bool,
    /// Called from inside the train loop every `log_every` steps — the
    /// sweep scheduler hooks its claim-lease heartbeat here
    /// (`CellCtx::tick`), so `--lease-ttl-ms` can drop below cell wall
    /// time.  Must be cheap and side-effect-free w.r.t. results.
    pub tick: Option<&'a dyn Fn()>,
}

impl<'a> Default for RunOpts<'a> {
    fn default() -> Self {
        Self {
            train: TrainConfig::default(),
            log: None,
            eval_loss_every: 0,
            warm_start: None,
            skip_eval: false,
            tick: None,
        }
    }
}

/// Fine-tune `variant` on `task` through a warm session and measure
/// everything.  Warm state (tokenizer, trainer setup, dev batches,
/// compiled executables) is reused when the session's cache is on;
/// results are byte-identical either way.
pub fn run_finetune(
    session: &mut Session,
    variant_name: &str,
    task: Task,
    mut opts: RunOpts<'_>,
) -> Result<RunResult> {
    let pool_before = pool::stats();
    // Warm lookups first: everything below is Arc/handle-based, so no
    // borrow of the session outlives this block …
    let (vocab, seq_len, bsz) = {
        let v = session.manifest()?.variant(variant_name)?;
        (v.config.vocab_size, v.config.seq_len, v.config.batch_size)
    };
    let setup = session.trainer_setup(variant_name)?;
    let tok = session.tokenizer(vocab);
    let dev = if opts.skip_eval {
        None
    } else {
        session.cached_dev_batches(task, seq_len, vocab, bsz, opts.train.seed)
    };
    let caching = session.caching();
    // … and this split borrow (engine mutably, manifest shared) carries
    // the rest of the run: the trainer holds the manifest while every
    // step takes the engine.
    let (engine, manifest) = session.engine_manifest()?;
    if !caching {
        // Honest cold path: without this, executables compiled by an
        // earlier run would still be warm purely by engine lifetime,
        // and `--session-cache off` would not control what its docs say
        // it controls.  (Within the run the cache still works — every
        // step needs it.)
        engine.reset_cache();
    }
    let variant = manifest.variant(variant_name)?;
    let engine_stats_before = engine.stats;
    let mut trainer =
        Trainer::from_setup(manifest, variant, &setup, task, opts.train.clone())?;
    if let Some((names, params)) = opts.warm_start {
        let n = trainer.load_matching(names, params);
        eprintln!("warm start: loaded {n}/{} params", trainer.params.len());
    }

    // First heartbeat before step 0: the first step carries the one-time
    // XLA compile, which must not outlive a log_every-sized lease TTL.
    if let Some(tick) = opts.tick {
        tick();
    }

    let gen = TaskGen::new(task, &tok, seq_len, opts.train.seed);
    let mut train_losses = Vec::new();
    let mut eval_losses = Vec::new();
    let mut probe_series = Vec::new();

    let t0 = std::time::Instant::now();
    let mut epoch = 0u64;
    let prefetch = opts.train.prefetch;
    let depth = opts.train.prefetch_depth;
    let mut batches = AnyBatcher::new(&gen, Split::Train, bsz, epoch, prefetch, depth);
    let mut compile_time = 0.0f64;
    for step in 0..opts.train.steps {
        let batch = match batches.next() {
            Some(b) => b,
            None => {
                epoch += 1;
                batches = AnyBatcher::new(&gen, Split::Train, bsz, epoch, prefetch, depth);
                batches.next().expect("empty task split")
            }
        };
        let pre_compile = engine.stats.compile_s;
        let stats = trainer.train_step(engine, &batch)?;
        compile_time += engine.stats.compile_s - pre_compile;

        if step % opts.train.log_every == 0 || step + 1 == opts.train.steps {
            if let Some(tick) = opts.tick {
                tick(); // keep the scheduler's lease heartbeat fresh
            }
            train_losses.push((step, stats.loss));
            if let Some(log) = opts.log.as_deref_mut() {
                let mut rec = vec![
                    ("kind", Json::str("train")),
                    ("step", Json::num(step as f64)),
                    ("loss", Json::num(stats.loss)),
                    ("lr", Json::num(stats.lr)),
                    ("grad_norm", Json::num(stats.grad_norm)),
                    ("residual_bytes", Json::num(stats.residual_bytes as f64)),
                ];
                if let Some(p) = stats.probe {
                    rec.push(("d2_sgd", Json::num(p.d2_sgd)));
                    rec.push(("d2_rmm", Json::num(p.d2_rmm)));
                    rec.push(("alpha", Json::num(p.alpha)));
                    rec.push(("ratio_lhs", Json::num(p.ratio_lhs)));
                    rec.push(("bound_rhs", Json::num(p.bound_rhs)));
                }
                log.log(Json::obj(rec));
            }
        }
        if let Some(p) = stats.probe {
            probe_series.push((
                step,
                [p.d2_sgd, p.d2_rmm, p.alpha, p.ratio_lhs, p.bound_rhs],
            ));
        }
        if opts.eval_loss_every > 0 && step % opts.eval_loss_every == 0 {
            let dev = Batcher::new(&gen, Split::Dev, bsz, 0).next().unwrap();
            let el = trainer.eval_loss(engine, &dev)?;
            eval_losses.push((step, el));
            if let Some(log) = opts.log.as_deref_mut() {
                log.log(Json::obj(vec![
                    ("kind", Json::str("eval_loss")),
                    ("step", Json::num(step as f64)),
                    ("loss", Json::num(el)),
                ]));
            }
        }
    }
    // exclude one-time XLA compilation from throughput accounting
    let wall_s = t0.elapsed().as_secs_f64() - compile_time;
    // Final dev-metric pass: cached batches when the session holds them,
    // otherwise the (pre)fetching stream — both are the canonical dev
    // sequence Trainer::evaluate would build, so the score is identical.
    // The whole pass runs between train-loop heartbeats, so tick per dev
    // batch to keep the lease fresh through a long dev split.
    let score = if opts.skip_eval {
        f64::NAN
    } else {
        match &dev {
            Some(batches) => trainer.eval_score(
                engine,
                batches.iter().inspect(|_| {
                    if let Some(tick) = opts.tick {
                        tick();
                    }
                }),
            )?,
            None => trainer.eval_score(
                engine,
                AnyBatcher::new(&gen, Split::Dev, bsz, 0, prefetch, depth).inspect(
                    |_| {
                        if let Some(tick) = opts.tick {
                            tick();
                        }
                    },
                ),
            )?,
        }
    };
    let (host_exact_ms, host_rmm_ms) = host_grad_baseline(variant);
    let engine_stats_after = engine.stats;
    let pool_delta = pool::stats().delta_since(pool_before);
    // Machine-shaped knobs (selected microkernel ISA, tuned cache
    // blocking) go to stderr like the exe-cache counters: fragments must
    // stay a pure function of the cell, and both knobs are bit-invisible
    // in results by the dispatch/blocking contracts.
    {
        use crate::tensor::kernels::{dispatch, tune};
        let blk = tune::blocking();
        eprintln!(
            "  kernels: simd {} / blocking mc={} kc={} nc={} ({})",
            dispatch::active_level().name(),
            blk.mc,
            blk.kc,
            blk.nc,
            if tune::blocking_override().is_some() { "tuned" } else { "default" },
        );
    }
    Ok(RunResult {
        variant: variant_name.to_string(),
        task: task.name().to_string(),
        rho: variant.config.rho,
        sketch: variant.config.sketch.clone(),
        score,
        backend: kernels::active().name().to_string(),
        host_exact_ms,
        host_rmm_ms,
        pool_threads: kernels::threads::num_threads(),
        pool_tasks: pool_delta.tasks,
        pool_steals: pool_delta.steals,
        exe_cache_hits: engine_stats_after
            .cache_hits
            .saturating_sub(engine_stats_before.cache_hits),
        exe_cache_misses: engine_stats_after
            .cache_misses
            .saturating_sub(engine_stats_before.cache_misses),
        final_train_loss: train_losses.last().map(|&(_, l)| l).unwrap_or(f64::NAN),
        steps: opts.train.steps,
        wall_s,
        samples_per_s: (opts.train.steps * bsz) as f64 / wall_s.max(1e-9),
        peak_residual_bytes: trainer.peak_residual_bytes,
        train_losses,
        eval_losses,
        probe_series,
    })
}

/// Execute one sweep cell — the shared executor behind `sweep-worker`
/// and the inline `--shards 1` path, dispatched on the spec's experiment
/// key.  The cell's result JSON is exactly what lands in its fragment
/// manifest, so everything a driver's `assemble` needs (including the
/// Table 3 memory-model numbers, which need manifest access) is computed
/// here, inside the process that owns the session.  The scheduler's
/// [`CellCtx`] threads through to the trainer loop as a lease-heartbeat
/// tick.
pub fn run_cell(
    session: &mut Session,
    spec: &SweepSpec,
    cell: &Cell,
    ctx: &CellCtx<'_>,
) -> Result<Json> {
    // Chaos "session.evict" fault: drop the warm caches between cells.
    // Safe by the warm ≡ cold session contract — the chaos selftests
    // pin that an evicted session still commits identical fragments.
    if crate::chaos::should_evict() {
        session.evict_warm_state();
    }
    let mut train = spec.train.clone();
    train.seed = cell.seed;
    let tick = || ctx.tick();
    match spec.experiment.as_str() {
        "mock" => Ok(mock_cell(cell)),
        synth if synth.starts_with("synth-") => {
            // The seeded synthetic workload: burn the cell's planned
            // (deterministic, tier-skewed) cost as wall time, commit
            // its pure-function result.
            let cost = crate::sweep::synth_cost_ms(synth, cell);
            if cost > 0 {
                std::thread::sleep(std::time::Duration::from_millis(cost));
            }
            Ok(crate::sweep::synth_cell(synth, cell))
        }
        "mockdata" => run_data_cell(session, spec, cell),
        "budget" => run_budget_cell(cell),
        "table2" | "table4" => {
            let task = Task::parse(&cell.task)
                .with_context(|| format!("unknown task '{}' in cell", cell.task))?;
            eprintln!(
                "{}: cell {} variant={} task={} rho={}",
                spec.experiment, cell.index, cell.variant, cell.task, cell.rho
            );
            let res = run_finetune(
                session,
                &cell.variant,
                task,
                RunOpts { train, tick: Some(&tick), ..Default::default() },
            )?;
            eprintln!(
                "  -> score {:.2} (exe cache {}h/{}m)",
                res.score, res.exe_cache_hits, res.exe_cache_misses
            );
            Ok(res.to_json())
        }
        "table3" => {
            let task = Task::parse(&cell.task)
                .with_context(|| format!("unknown task '{}' in cell", cell.task))?;
            let steps = train.steps;
            let train = TrainConfig {
                steps,
                warmup_steps: 1.min(steps.saturating_sub(1)),
                eval_every: usize::MAX,
                log_every: steps.max(1),
                ..train
            };
            eprintln!(
                "table3: cell {} variant={} task={} rho={}",
                cell.index, cell.variant, cell.task, cell.rho
            );
            let res = run_finetune(
                session,
                &cell.variant,
                task,
                RunOpts { train, skip_eval: true, tick: Some(&tick), ..Default::default() },
            )?;
            let variant = session.manifest()?.variant(&cell.variant)?;
            let model = MemoryModel::new(variant.config.geometry(), cell.rho);
            // Paper-scale extrapolation: RoBERTa-base with the paper's
            // batch geometry (batch×seq scaled up proportionally).
            let rob = MemoryModel::new(
                ModelGeometry::roberta_base(cell.batch * 2, 128),
                cell.rho,
            );
            Ok(Json::obj(vec![
                ("task", Json::str(cell.task.clone())),
                ("batch", Json::num(cell.batch as f64)),
                ("rho", Json::num(cell.rho)),
                (
                    "measured_residual_bytes",
                    Json::num(res.peak_residual_bytes as f64),
                ),
                ("model_total_bytes", Json::num(model.total_bytes() as f64)),
                ("model_saving_pct", Json::num(model.saving_vs_baseline())),
                ("roberta_total_bytes", Json::num(rob.total_bytes() as f64)),
                ("roberta_saving_pct", Json::num(rob.saving_vs_baseline())),
            ]))
        }
        other => bail!("unknown sweep experiment '{other}'"),
    }
}

/// Geometry of the engine-free `mockdata` cells (the session-layer
/// selftest grid, `sweep::selftest_data_spec`).
pub const DATA_CELL_VOCAB: usize = 64;
pub const DATA_CELL_SEQ: usize = 16;

/// Fold a batch's full content (tokens, mask, labels, shape, validity)
/// into an FNV-1a digest — any single-bit divergence between the warm
/// and cold data paths changes the cell result.
fn fnv_batch(h: u64, b: &Batch) -> u64 {
    let h = fnv::fold(h, b.tokens.iter().flat_map(|t| t.to_le_bytes()));
    let h = fnv::fold(h, b.mask.iter().flat_map(|m| m.to_bits().to_le_bytes()));
    let h = fnv::fold(h, b.labels_i.iter().flat_map(|l| l.to_le_bytes()));
    let h = fnv::fold(h, b.labels_f.iter().flat_map(|l| l.to_bits().to_le_bytes()));
    fnv::fold(
        h,
        [b.batch_size, b.seq_len, b.valid]
            .iter()
            .flat_map(|v| (*v as u64).to_le_bytes()),
    )
}

/// A deterministic, engine-free sweep cell over the *real* data path:
/// one shuffled train epoch through the configured (pre)fetch pipeline
/// plus the dev pass through the session's dataset cache, digested to a
/// pure function of the cell.  This is what lets CI pin warm-vs-cold
/// byte-identity of the session layer without artifacts.
pub fn run_data_cell(session: &mut Session, spec: &SweepSpec, cell: &Cell) -> Result<Json> {
    let task = Task::parse(&cell.task)
        .with_context(|| format!("unknown task '{}' in mockdata cell", cell.task))?;
    let bsz = if cell.batch > 0 { cell.batch } else { 8 };
    let tok = session.tokenizer(DATA_CELL_VOCAB);
    let gen = TaskGen::new(task, &tok, DATA_CELL_SEQ, cell.seed);

    let mut train_digest = fnv::OFFSET_BASIS;
    let mut n_train = 0usize;
    for batch in AnyBatcher::new(
        &gen,
        Split::Train,
        bsz,
        0,
        spec.train.prefetch,
        spec.train.prefetch_depth,
    ) {
        train_digest = fnv_batch(train_digest, &batch);
        n_train += 1;
    }

    let mut dev_digest = fnv::OFFSET_BASIS;
    let mut n_dev = 0usize;
    match session.cached_dev_batches(task, DATA_CELL_SEQ, DATA_CELL_VOCAB, bsz, cell.seed)
    {
        Some(batches) => {
            for batch in batches.iter() {
                dev_digest = fnv_batch(dev_digest, batch);
                n_dev += 1;
            }
        }
        None => {
            // cache off: stream the identical canonical sequence
            for batch in AnyBatcher::new(
                &gen,
                Split::Dev,
                bsz,
                0,
                spec.train.prefetch,
                spec.train.prefetch_depth,
            ) {
                dev_digest = fnv_batch(dev_digest, &batch);
                n_dev += 1;
            }
        }
    }

    Ok(Json::obj(vec![
        ("task", Json::str(cell.task.clone())),
        ("seed", Json::num(cell.seed as f64)),
        ("batch_size", Json::num(bsz as f64)),
        ("n_train_batches", Json::num(n_train as f64)),
        ("n_dev_batches", Json::num(n_dev as f64)),
        // digests as hex strings: u64 does not survive the f64 JSON codec
        ("train_digest", Json::str(format!("{train_digest:016x}"))),
        ("dev_digest", Json::str(format!("{dev_digest:016x}"))),
    ]))
}

/// Probe geometry of the engine-free `budget` cells: layers × steps of
/// Philox-generated (X, Y) probe pairs per cell, at these widths.
pub const BUDGET_CELL_LAYERS: usize = 3;
pub const BUDGET_CELL_STEPS: usize = 4;
const BUDGET_CELL_N: usize = 24;
const BUDGET_CELL_M: usize = 12;

/// A deterministic, engine-free sweep cell for the closed-loop variance
/// controller: the cell's ρ axis carries the per-step memory budget and
/// its sketch axis selects either the controller ("auto" / "avjp-auto" —
/// the controller picks (family, ρ) per layer-step) or a fixed estimator
/// configuration priced at the same budget.  Probe tensors are Philox-
/// generated from the cell seed, so the recorded choice sequence — and
/// therefore the whole fragment — is a pure function of the cell: the
/// byte-identity contract the `--grid budget` selftest pins across
/// schedules, worker counts and `RMM_THREADS`.
pub fn run_budget_cell(cell: &Cell) -> Result<Json> {
    use crate::rmm::controller::Controller;
    let rows = if cell.batch > 0 { cell.batch } else { 16 };
    let budget = cell.rho; // the budget grid carries mem_budget on the ρ axis
    let axis = cell.sketch.trim().to_ascii_lowercase();
    let fixed = match axis.as_str() {
        "auto" | "avjp-auto" => None,
        other => Some(
            rmm::EstimatorSpec::parse(other)
                .with_context(|| format!("budget cell {} sketch axis", cell.index))?,
        ),
    };
    let mut ctl = Controller::new(budget);
    ctl.approx_vjp = match &fixed {
        Some(est) => est.approx_vjp(),
        None => axis == "avjp-auto",
    };

    let mut choices = Vec::new();
    let mut digest = fnv::OFFSET_BASIS;
    let mut d2_sum = 0.0f64;
    let mut peak_bytes = 0usize;
    for layer in 0..BUDGET_CELL_LAYERS {
        for step in 0..BUDGET_CELL_STEPS {
            // One probe pair per (layer, step), keyed off the cell seed;
            // stream 3 is the shared synthetic-data stream.
            let tag = (cell.seed << 8) ^ ((layer * BUDGET_CELL_STEPS + step) as u64);
            let mut s = PhiloxStream::new(tag, 3);
            let x = Tensor::from_fn(rows, BUDGET_CELL_N, |_, _| s.next_normal());
            let y = Tensor::from_fn(rows, BUDGET_CELL_M, |_, _| s.next_normal());
            let choice = match &fixed {
                None => ctl.choose(&x, &y),
                Some(est) => ctl.price(est.kind, budget, &x, &y),
            };
            digest = fnv::fold(digest, choice.estimator_name().bytes());
            digest = fnv::fold(digest, choice.rho.to_bits().to_le_bytes());
            digest = fnv::fold(digest, (choice.b_proj as u64).to_le_bytes());
            d2_sum += choice.d2;
            peak_bytes = peak_bytes.max(choice.bytes);
            choices.push(choice.to_json());
        }
    }
    let n = (BUDGET_CELL_LAYERS * BUDGET_CELL_STEPS) as f64;
    Ok(Json::obj(vec![
        ("estimator_axis", Json::str(cell.sketch.clone())),
        ("mem_budget", Json::num(budget)),
        ("rows", Json::num(rows as f64)),
        ("decisions", Json::num(n)),
        ("mean_d2", num_or_null(d2_sum / n)),
        ("peak_bytes", Json::num(peak_bytes as f64)),
        ("choices", Json::Arr(choices)),
        // digest as hex: u64 does not survive the f64 JSON codec
        ("choice_digest", Json::str(format!("{digest:016x}"))),
    ]))
}

/// Variant name scheme shared with aot.py.
pub fn variant_name(prefix: &str, head: &str, rho: f64, sketch: &str) -> String {
    let tag = match rho {
        r if (r - 1.0).abs() < 1e-9 => "r100".to_string(),
        r if (r - 0.9).abs() < 1e-9 => "r90".to_string(),
        r if (r - 0.5).abs() < 1e-9 => "r50".to_string(),
        r if (r - 0.2).abs() < 1e-9 => "r20".to_string(),
        r if (r - 0.1).abs() < 1e-9 => "r10".to_string(),
        r => format!("r{:03}", (r * 100.0).round() as usize),
    };
    format!("{prefix}_{head}_{tag}_{sketch}")
}

/// Which head geometry a task uses (cls2/cls3/reg, matching aot.py HEADS).
pub fn head_for(task: Task) -> &'static str {
    if task.is_regression() {
        "reg"
    } else if task.n_classes() == 3 {
        "cls3"
    } else {
        "cls2"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nan_result() -> RunResult {
        RunResult {
            variant: "v".into(),
            task: "cola".into(),
            rho: 0.5,
            sketch: "gauss".into(),
            score: f64::NAN,
            final_train_loss: f64::NAN,
            steps: 0,
            wall_s: f64::NAN,
            samples_per_s: f64::INFINITY,
            peak_residual_bytes: 0,
            backend: "packed".into(),
            host_exact_ms: f64::NAN,
            host_rmm_ms: f64::NEG_INFINITY,
            pool_threads: 1,
            pool_tasks: 0,
            pool_steals: 0,
            exe_cache_hits: 0,
            exe_cache_misses: 0,
            train_losses: Vec::new(),
            eval_losses: Vec::new(),
            probe_series: Vec::new(),
        }
    }

    #[test]
    fn non_finite_metrics_serialize_as_null_and_round_trip() {
        // Every float metric a skipped/degenerate run can leave non-finite
        // must land as JSON null: fragments are parsed back during merge,
        // so a NaN literal would poison the whole sweep report.
        let j = nan_result().to_json();
        for field in
            ["score", "final_train_loss", "wall_s", "samples_per_s", "host_exact_ms", "host_rmm_ms"]
        {
            assert!(j.get(field).is_null(), "{field} must serialize as null");
        }
        let text = j.to_string_pretty();
        let back = Json::parse(&text).expect("fragment text must re-parse");
        assert_eq!(back.to_string_pretty(), text);
        assert!(!text.contains("NaN") && !text.contains("inf"), "{text}");
    }

    #[test]
    fn budget_cells_are_pure_functions_of_the_cell() {
        let spec = crate::sweep::selftest_budget_spec();
        assert_eq!(spec.experiment, "budget");
        let mut seen = std::collections::BTreeSet::new();
        for cell in &spec.cells {
            let a = run_budget_cell(cell).unwrap().to_string_pretty();
            let b = run_budget_cell(cell).unwrap().to_string_pretty();
            assert_eq!(a, b, "cell {} not deterministic", cell.index);
            assert!(!a.contains("NaN") && !a.contains("inf"), "{a}");
            seen.insert(a);
        }
        // distinct cells must produce distinct fragments (the digest
        // would otherwise hide a grid that collapsed onto one result)
        assert_eq!(seen.len(), spec.cells.len());
    }

    #[test]
    fn budget_cell_records_choices_under_budget() {
        let spec = crate::sweep::selftest_budget_spec();
        for cell in &spec.cells {
            let j = run_budget_cell(cell).unwrap();
            let rows = j.get("rows").as_f64().unwrap();
            let choices = j.get("choices").as_arr().unwrap();
            assert_eq!(choices.len(), BUDGET_CELL_LAYERS * BUDGET_CELL_STEPS);
            let auto = cell.sketch.ends_with("auto");
            for c in choices {
                let bp = c.get("b_proj").as_f64().unwrap();
                assert!(bp >= 1.0 && bp <= rows);
                // controller rows honor the budget whenever it is
                // satisfiable at all (ρ·B ≥ 1 on every grid cell here)
                if auto {
                    assert!(
                        bp <= cell.rho * rows + 1e-9,
                        "cell {}: b_proj {bp} over budget {}",
                        cell.index,
                        cell.rho
                    );
                }
                let est = c.get("estimator").as_str().unwrap();
                if cell.sketch.starts_with("avjp-") {
                    assert!(est.starts_with("avjp-"), "{est}");
                }
            }
        }
    }
}
