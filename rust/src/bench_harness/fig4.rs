//! Figure 4 / Figure 7: evolution of the variance estimates during
//! fine-tuning — D²_SGD (eq. 9), D²_RMM (eq. 11), the ratio LHS of
//! Theorem 2.3's inequality (12), and α (eq. 13) — at the probe layer
//! (FFN1 of the middle block, matching the paper's "transformer block #7").
//!
//! Paper shape: variances slowly increase, their ratio stabilizes, the
//! bound always holds, α stays small.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::data::Task;
use crate::session::Session;
use crate::util::json::Json;

use super::runner::{run_finetune, RunOpts};

pub fn run(session: &mut Session, train: TrainConfig) -> Result<Json> {
    let res = run_finetune(
        session,
        "probe_cls2_r50_gauss",
        Task::Cola,
        RunOpts { train, skip_eval: true, ..Default::default() },
    )?;

    println!("\nFig 4/7: variance probe series (CoLA, rho=0.5, gauss)");
    println!(
        "{:>6} {:>13} {:>13} {:>9} {:>11} {:>11}",
        "step", "d2_sgd", "d2_rmm", "alpha", "ratio_lhs", "bound_rhs"
    );
    let stride = (res.probe_series.len() / 24).max(1);
    let mut violations = 0usize;
    for (i, (step, p)) in res.probe_series.iter().enumerate() {
        if p[3] > p[4] * 1.001 {
            violations += 1;
        }
        if i % stride == 0 || i + 1 == res.probe_series.len() {
            println!(
                "{:>6} {:>13.4e} {:>13.4e} {:>9.4} {:>11.4} {:>11.2}",
                step, p[0], p[1], p[2], p[3], p[4]
            );
        }
    }
    println!(
        "bound violations: {violations}/{} (Theorem 2.3 holds: {})",
        res.probe_series.len(),
        violations == 0
    );

    Ok(Json::obj(vec![
        ("experiment", Json::str("fig4")),
        ("bound_violations", Json::num(violations as f64)),
        (
            "series",
            Json::Arr(
                res.probe_series
                    .iter()
                    .map(|(s, p)| {
                        Json::obj(vec![
                            ("step", Json::num(*s as f64)),
                            ("d2_sgd", Json::num(p[0])),
                            ("d2_rmm", Json::num(p[1])),
                            ("alpha", Json::num(p[2])),
                            ("ratio_lhs", Json::num(p[3])),
                            ("bound_rhs", Json::num(p[4])),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]))
}
