//! Figure 6: relative training throughput (samples/s vs baseline) as a
//! function of compression ratio ρ.
//!
//! Paper shape: randomized layers cost extra at ρ≈0.5 (the projection adds
//! work), approach parity as ρ shrinks, and win below ρ≈0.1 where the
//! backward contraction's O(ρ·B·N_out·(B+N_in)) beats the baseline's
//! O(B·N_in·N_out).

use anyhow::Result;

use crate::config::TrainConfig;
use crate::data::Task;
use crate::session::Session;
use crate::util::json::Json;

use super::runner::{head_for, run_finetune, variant_name, RunOpts};

pub const RHOS: [f64; 5] = [1.0, 0.9, 0.5, 0.2, 0.1];

pub fn run(session: &mut Session, task: Task, steps: usize) -> Result<Json> {
    let mut rows = Vec::new();
    let mut baseline = f64::NAN;
    println!("\nFig 6: relative throughput vs compression ratio ({})", task.name());
    println!("{:>8} {:>14} {:>12}", "rho", "samples/s", "relative");
    for &rho in &RHOS {
        let vname = variant_name("small", head_for(task), rho, "gauss");
        let train = TrainConfig {
            steps,
            warmup_steps: 0,
            log_every: steps.max(1),
            ..TrainConfig::default()
        };
        let res = run_finetune(
            session,
            &vname,
            task,
            RunOpts { train, skip_eval: true, ..Default::default() },
        )?;
        if (rho - 1.0).abs() < 1e-9 {
            baseline = res.samples_per_s;
        }
        let rel = res.samples_per_s / baseline;
        println!("{:>8.2} {:>14.1} {:>12.3}", rho, res.samples_per_s, rel);
        rows.push(Json::obj(vec![
            ("rho", Json::num(rho)),
            ("samples_per_s", Json::num(res.samples_per_s)),
            ("relative", Json::num(rel)),
        ]));
    }
    Ok(Json::obj(vec![
        ("experiment", Json::str("fig6")),
        ("task", Json::str(task.name())),
        ("rows", Json::Arr(rows)),
    ]))
}
