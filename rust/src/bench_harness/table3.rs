//! Table 3: peak memory during training + saving % per (task, batch, ρ).
//!
//! Two columns of evidence: the *measured* activation-store peak (exact
//! residual bytes held between fwd and bwd) and the analytic whole-process
//! model (weights + grads + Adam state + residuals), plus the same model
//! extrapolated to RoBERTa-base/V100 scale — the setting of the paper's
//! actual table.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::data::Task;
use crate::memory::{MemoryModel, ModelGeometry};
use crate::runtime::{Engine, Manifest};
use crate::util::json::Json;

use super::runner::{run_finetune, RunOpts};

/// (task, batch-variant) pairs — scaled-down analogues of the paper's
/// MRPC/128, QNLI/16, SST2/256 rows (see DESIGN.md §2).
pub const SETTINGS: [(&str, usize); 3] = [("mrpc", 64), ("qnli", 8), ("sst2", 32)];

pub const RHOS: [f64; 4] = [1.0, 0.5, 0.2, 0.1];

fn batch_variant(bsz: usize, rho: f64) -> String {
    let tag = match rho {
        r if (r - 1.0).abs() < 1e-9 => "r100",
        r if (r - 0.5).abs() < 1e-9 => "r50",
        r if (r - 0.2).abs() < 1e-9 => "r20",
        _ => "r10",
    };
    if bsz == 16 {
        format!("small_cls2_{tag}_gauss")
    } else {
        format!("small_cls2_b{bsz}_{tag}_gauss")
    }
}

pub fn run(
    engine: &mut Engine,
    manifest: &Manifest,
    steps: usize,
) -> Result<Json> {
    let mut out_rows = Vec::new();
    println!("\nTable 3: peak memory and saving vs rho");
    println!(
        "{:>6} {:>6} {:>8} {:>14} {:>10} {:>14} {:>10} {:>14}",
        "task", "batch", "rate", "resid KiB", "saving%", "model MiB", "saving%", "roberta GiB"
    );
    for (task_name, bsz) in SETTINGS {
        let task = Task::parse(task_name).unwrap();
        let mut base_resid = 0usize;
        for &rho in &RHOS {
            let vname = batch_variant(bsz, rho);
            let variant = manifest.variant(&vname)?;
            let train = TrainConfig {
                steps,
                warmup_steps: 1.min(steps.saturating_sub(1)),
                eval_every: usize::MAX,
                log_every: steps.max(1),
                ..TrainConfig::default()
            };
            let res = run_finetune(
                engine,
                manifest,
                &vname,
                task,
                RunOpts { train, skip_eval: true, ..Default::default() },
            )?;
            if (rho - 1.0).abs() < 1e-9 {
                base_resid = res.peak_residual_bytes;
            }
            let resid_saving = 100.0
                * (1.0 - res.peak_residual_bytes as f64 / base_resid.max(1) as f64);
            let model = MemoryModel::new(variant.config.geometry(), rho);
            // Paper-scale extrapolation: RoBERTa-base with the paper's batch
            // geometry (batch×seq scaled up proportionally).
            let rob = MemoryModel::new(
                ModelGeometry::roberta_base(bsz * 2, 128),
                rho,
            );
            let rate = if (rho - 1.0).abs() < 1e-9 {
                "No RMM".to_string()
            } else {
                format!("{:.0}%", rho * 100.0)
            };
            println!(
                "{:>6} {:>6} {:>8} {:>14.1} {:>10.1} {:>14.2} {:>10.1} {:>14.2}",
                task_name,
                bsz,
                rate,
                res.peak_residual_bytes as f64 / 1024.0,
                resid_saving,
                model.total_bytes() as f64 / (1024.0 * 1024.0),
                model.saving_vs_baseline(),
                rob.total_bytes() as f64 / (1024.0 * 1024.0 * 1024.0),
            );
            out_rows.push(Json::obj(vec![
                ("task", Json::str(task_name)),
                ("batch", Json::num(bsz as f64)),
                ("rho", Json::num(rho)),
                ("measured_residual_bytes", Json::num(res.peak_residual_bytes as f64)),
                ("residual_saving_pct", Json::num(resid_saving)),
                ("model_total_bytes", Json::num(model.total_bytes() as f64)),
                ("model_saving_pct", Json::num(model.saving_vs_baseline())),
                ("roberta_total_bytes", Json::num(rob.total_bytes() as f64)),
                ("roberta_saving_pct", Json::num(rob.saving_vs_baseline())),
            ]));
        }
    }
    Ok(Json::obj(vec![
        ("experiment", Json::str("table3")),
        ("rows", Json::Arr(out_rows)),
    ]))
}
