//! Table 3: peak memory during training + saving % per (task, batch, ρ).
//!
//! Two columns of evidence: the *measured* activation-store peak (exact
//! residual bytes held between fwd and bwd) and the analytic whole-process
//! model (weights + grads + Adam state + residuals), plus the same model
//! extrapolated to RoBERTa-base/V100 scale — the setting of the paper's
//! actual table.
//!
//! Thin grid declaration over `sweep::` — each cell's result (measured
//! peak + model numbers, computed in `runner::run_cell` where the
//! manifest lives) is independent; only the saving-vs-baseline column is
//! cross-cell and is derived in [`assemble`] from the ρ=1.0 cell of the
//! same (task, batch) group.  That derivation reads the *merged*,
//! canonically-ordered results — never on-disk state — so it is
//! schedule-agnostic: static shards and dynamic claim/lease workers
//! (`--schedule dynamic`) assemble the same bytes.

use crate::config::TrainConfig;
use crate::sweep::SweepSpec;
use crate::util::json::Json;

/// (task, batch-variant) pairs — scaled-down analogues of the paper's
/// MRPC/128, QNLI/16, SST2/256 rows (see DESIGN.md §2).
pub const SETTINGS: [(&str, usize); 3] = [("mrpc", 64), ("qnli", 8), ("sst2", 32)];

pub const RHOS: [f64; 4] = [1.0, 0.5, 0.2, 0.1];

fn batch_variant(bsz: usize, rho: f64) -> String {
    let tag = match rho {
        r if (r - 1.0).abs() < 1e-9 => "r100",
        r if (r - 0.5).abs() < 1e-9 => "r50",
        r if (r - 0.2).abs() < 1e-9 => "r20",
        _ => "r10",
    };
    if bsz == 16 {
        format!("small_cls2_{tag}_gauss")
    } else {
        format!("small_cls2_b{bsz}_{tag}_gauss")
    }
}

/// The Table 3 grid: (task, batch) settings outermost, ρ inner — the
/// ρ=1.0 baseline of each group precedes its compressed cells.
pub fn spec(train: TrainConfig) -> SweepSpec {
    let seed = train.seed;
    let mut spec = SweepSpec::new("table3", train);
    for (task, bsz) in SETTINGS {
        for &rho in &RHOS {
            spec.push(batch_variant(bsz, rho), task, rho, "gauss", seed, bsz);
        }
    }
    spec
}

/// Fold merged cell results into the console table + report JSON, adding
/// the residual-saving column relative to each group's ρ=1.0 cell.
pub fn assemble(spec: &SweepSpec, results: &[Json]) -> Json {
    let mut out_rows = Vec::new();
    println!("\nTable 3: peak memory and saving vs rho");
    println!(
        "{:>6} {:>6} {:>8} {:>14} {:>10} {:>14} {:>10} {:>14}",
        "task", "batch", "rate", "resid KiB", "saving%", "model MiB", "saving%", "roberta GiB"
    );
    let mut base_resid = 0usize;
    for (cell, res) in spec.cells.iter().zip(results) {
        let resid = res.get("measured_residual_bytes").as_f64().unwrap_or(0.0) as usize;
        if (cell.rho - 1.0).abs() < 1e-9 {
            base_resid = resid; // the group's baseline cell comes first
        }
        let resid_saving = 100.0 * (1.0 - resid as f64 / base_resid.max(1) as f64);
        let model_total = res.get("model_total_bytes").as_f64().unwrap_or(f64::NAN);
        let model_saving = res.get("model_saving_pct").as_f64().unwrap_or(f64::NAN);
        let rob_total = res.get("roberta_total_bytes").as_f64().unwrap_or(f64::NAN);
        let rate = if (cell.rho - 1.0).abs() < 1e-9 {
            "No RMM".to_string()
        } else {
            format!("{:.0}%", cell.rho * 100.0)
        };
        println!(
            "{:>6} {:>6} {:>8} {:>14.1} {:>10.1} {:>14.2} {:>10.1} {:>14.2}",
            cell.task,
            cell.batch,
            rate,
            resid as f64 / 1024.0,
            resid_saving,
            model_total / (1024.0 * 1024.0),
            model_saving,
            rob_total / (1024.0 * 1024.0 * 1024.0),
        );
        out_rows.push(Json::obj(vec![
            ("task", Json::str(cell.task.clone())),
            ("batch", Json::num(cell.batch as f64)),
            ("rho", Json::num(cell.rho)),
            ("measured_residual_bytes", Json::num(resid as f64)),
            ("residual_saving_pct", Json::num(resid_saving)),
            ("model_total_bytes", res.get("model_total_bytes").clone()),
            ("model_saving_pct", res.get("model_saving_pct").clone()),
            ("roberta_total_bytes", res.get("roberta_total_bytes").clone()),
            ("roberta_saving_pct", res.get("roberta_saving_pct").clone()),
        ]));
    }
    Json::obj(vec![
        ("experiment", Json::str("table3")),
        ("rows", Json::Arr(out_rows)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_settings_times_rhos() {
        let s = spec(TrainConfig::default());
        assert_eq!(s.cells.len(), SETTINGS.len() * RHOS.len());
        assert_eq!(s.experiment, "table3");
        // each group starts with its rho=1.0 baseline
        for g in 0..SETTINGS.len() {
            let first = &s.cells[g * RHOS.len()];
            assert!((first.rho - 1.0).abs() < 1e-12);
            assert_eq!(first.task, SETTINGS[g].0);
            assert_eq!(first.batch, SETTINGS[g].1);
        }
        assert_eq!(s.cells[0].variant, "small_cls2_b64_r100_gauss");
    }

    #[test]
    fn assemble_computes_saving_vs_group_baseline() {
        let s = spec(TrainConfig::default());
        let results: Vec<Json> = s
            .cells
            .iter()
            .map(|c| {
                // baseline 1000 bytes, compressed cells scale with rho
                let bytes = (1000.0 * c.rho).round();
                Json::obj(vec![
                    ("measured_residual_bytes", Json::num(bytes)),
                    ("model_total_bytes", Json::num(1.0)),
                    ("model_saving_pct", Json::num(0.0)),
                    ("roberta_total_bytes", Json::num(1.0)),
                    ("roberta_saving_pct", Json::num(0.0)),
                ])
            })
            .collect();
        let rep = assemble(&s, &results);
        let rows = rep.get("rows").as_arr().unwrap();
        // the rho=0.5 row of the first group saves ~50%
        let saving = rows[1].get("residual_saving_pct").as_f64().unwrap();
        assert!((saving - 50.0).abs() < 1e-9, "{saving}");
        // baselines save 0%
        let base = rows[0].get("residual_saving_pct").as_f64().unwrap();
        assert!(base.abs() < 1e-9, "{base}");
    }
}
