//! Equal-budget estimator comparison: accuracy proxy (closed-form
//! grad-weight variance, Lemma 2.2) vs memory for **all seven estimator
//! configurations** — the five original families (Gauss / Rademacher /
//! DCT / DFT / RowSample) plus WTA-CRS and an approximate-VJP variant —
//! at one shared per-step memory budget, next to the closed-loop
//! controller ("auto" / "avjp-auto") choosing (family, ρ) online under
//! the same budget.
//!
//! Engine-free by construction: the `budget` cells run on Philox-seeded
//! probe tensors (see `runner::run_budget_cell`), so this table is
//! runnable anywhere the crate builds — CI included — and every row is a
//! pure function of its cell.  Lower mean D² at equal bytes is the
//! paper's accuracy order: Lemma 2.2 bounds the estimator's excess loss
//! by its gradient variance, so at a fixed memory budget the
//! minimum-variance configuration is the accuracy winner.
//!
//! Thin grid declaration over `sweep::`, like `table4`: controller rows
//! first, then the seven fixed configurations in canonical order.

use crate::config::TrainConfig;
use crate::sweep::SweepSpec;
use crate::util::json::Json;

/// The seven estimator configurations the table compares at equal
/// budget: five original families, WTA-CRS, and one approximate-VJP
/// per-path variant.
pub const ESTIMATORS: [&str; 7] =
    ["gauss", "rademacher", "dct", "dft", "rowsample", "wtacrs", "avjp-gauss"];

/// Controller axes: the closed loop picks (family, ρ) per layer-step
/// under the budget; `avjp-auto` does the same with the grad-input path
/// kept exact.
pub const CONTROLLER_AXES: [&str; 2] = ["auto", "avjp-auto"];

/// The equal-budget grid: controller rows first, then the seven fixed
/// estimator configurations, each at the shared `mem_budget` for every
/// seed.
pub fn spec(train: TrainConfig, mem_budget: f64, seeds: &[u64]) -> SweepSpec {
    let mut spec = SweepSpec::new("budget", train);
    for &axis in &CONTROLLER_AXES {
        let variant = if axis == "auto" { "ctl_auto" } else { "ctl_avjp" };
        for &seed in seeds {
            spec.push(variant, "probe", mem_budget, axis, seed, 16);
        }
    }
    for &est in &ESTIMATORS {
        for &seed in seeds {
            spec.push(format!("est_{est}"), "probe", mem_budget, est, seed, 16);
        }
    }
    spec
}

/// Fold merged `budget` cell results into the console table and the
/// report rows.  Controller rows additionally carry their recorded
/// choice digest, pinning the (family, ρ) sequence into the report.
pub fn assemble(spec: &SweepSpec, results: &[Json]) -> Json {
    println!(
        "\nEqual-budget estimator comparison (mean closed-form D\u{b2} vs \
         residual bytes; lower D\u{b2} at equal bytes wins)"
    );
    println!(
        "{:>12} {:>8} {:>6} {:>12} {:>14} {:>18}",
        "estimator", "budget", "seed", "peak bytes", "mean D2", "choice digest"
    );
    let mut rows = Vec::new();
    for (cell, res) in spec.cells.iter().zip(results) {
        let d2 = res.get("mean_d2").as_f64();
        let bytes = res.get("peak_bytes").as_f64().unwrap_or(f64::NAN);
        let digest = res.get("choice_digest").as_str().unwrap_or("?");
        println!(
            "{:>12} {:>8} {:>6} {:>12.0} {:>14} {:>18}",
            cell.sketch,
            cell.rho,
            cell.seed,
            bytes,
            match d2 {
                Some(v) => format!("{v:.4}"),
                None => "-".to_string(),
            },
            digest
        );
        rows.push(Json::obj(vec![
            ("estimator_axis", Json::str(cell.sketch.clone())),
            ("mem_budget", Json::num(cell.rho)),
            ("seed", Json::num(cell.seed as f64)),
            ("rows", res.get("rows").clone()),
            ("peak_bytes", res.get("peak_bytes").clone()),
            ("mean_d2", res.get("mean_d2").clone()),
            ("choice_digest", res.get("choice_digest").clone()),
        ]));
    }
    Json::obj(vec![
        ("experiment", Json::str("budget")),
        ("rows", Json::Arr(rows)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::runner::run_budget_cell;

    #[test]
    fn grid_covers_controller_rows_and_all_seven_estimators() {
        let s = spec(TrainConfig::default(), 0.5, &[1, 2]);
        assert_eq!(
            s.cells.len(),
            (CONTROLLER_AXES.len() + ESTIMATORS.len()) * 2
        );
        assert_eq!(s.cells[0].sketch, "auto");
        for est in ESTIMATORS {
            assert!(
                s.cells.iter().any(|c| c.sketch == est),
                "estimator '{est}' missing from the grid"
            );
        }
        for cell in &s.cells {
            assert!((cell.rho - 0.5).abs() < 1e-12, "unequal budget on {cell:?}");
        }
    }

    #[test]
    fn controller_never_loses_to_a_fixed_family_at_equal_budget() {
        // The closed loop scans every (family, ρ) the fixed rows price,
        // so at the same budget its mean D² must be ≤ each fixed row's
        // (it can also trade down ρ, which fixed rows cannot).
        let s = spec(TrainConfig::default(), 0.5, &[3]);
        let results: Vec<Json> =
            s.cells.iter().map(|c| run_budget_cell(c).unwrap()).collect();
        let auto_d2 = results[0].get("mean_d2").as_f64().unwrap();
        for (cell, res) in s.cells.iter().zip(&results).skip(CONTROLLER_AXES.len()) {
            let fixed_d2 = res.get("mean_d2").as_f64().unwrap();
            assert!(
                auto_d2 <= fixed_d2 + 1e-9,
                "controller {auto_d2} worse than fixed {} {fixed_d2}",
                cell.sketch
            );
        }
    }

    #[test]
    fn assemble_carries_digests_and_budget_per_row() {
        let s = spec(TrainConfig::default(), 0.2, &[1]);
        let results: Vec<Json> =
            s.cells.iter().map(|c| run_budget_cell(c).unwrap()).collect();
        let rep = assemble(&s, &results);
        let rows = rep.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), s.cells.len());
        for row in rows {
            assert_eq!(row.get("mem_budget").as_f64(), Some(0.2));
            let digest = row.get("choice_digest").as_str().unwrap();
            assert_eq!(digest.len(), 16, "digest must be 16 hex chars: {digest}");
        }
    }
}
