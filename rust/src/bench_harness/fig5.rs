//! Figure 5 / Figure 9: train/eval cross-entropy curves vs ρ on the
//! MNLI-like task.
//!
//! Paper shape: curves shift smoothly as ρ decreases — train loss rises
//! (noisier gradients fit less) while the eval curve flattens; the
//! overfitting point stays roughly in place.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::data::Task;
use crate::session::Session;
use crate::util::json::Json;

use super::runner::{head_for, run_finetune, variant_name, RunOpts};

pub const RHOS: [f64; 4] = [1.0, 0.5, 0.2, 0.1];

pub fn run(session: &mut Session, task: Task, train: TrainConfig) -> Result<Json> {
    let mut curves = Vec::new();
    for &rho in &RHOS {
        let vname = variant_name("small", head_for(task), rho, "gauss");
        eprintln!("fig5: rho={rho} -> {vname}");
        let res = run_finetune(
            session,
            &vname,
            task,
            RunOpts {
                train: train.clone(),
                eval_loss_every: (train.steps / 16).max(1),
                skip_eval: true,
                ..Default::default()
            },
        )?;
        curves.push((rho, res));
    }

    println!("\nFig 5/9: loss curves on {} (train | eval)", task.name());
    print!("{:>6}", "step");
    for (rho, _) in &curves {
        print!("  tr r={rho:<4} ev r={rho:<4}");
    }
    println!();
    let steps: Vec<usize> = curves[0].1.eval_losses.iter().map(|&(s, _)| s).collect();
    for &s in &steps {
        print!("{s:>6}");
        for (_, res) in &curves {
            let tr = res
                .train_losses
                .iter()
                .min_by_key(|(ts, _)| ts.abs_diff(s))
                .map(|&(_, l)| l)
                .unwrap_or(f64::NAN);
            let ev = res
                .eval_losses
                .iter()
                .find(|&&(ts, _)| ts == s)
                .map(|&(_, l)| l)
                .unwrap_or(f64::NAN);
            print!("  {tr:>9.4} {ev:>9.4}");
        }
        println!();
    }

    Ok(Json::obj(vec![
        ("experiment", Json::str("fig5")),
        ("task", Json::str(task.name())),
        (
            "curves",
            Json::Arr(
                curves
                    .iter()
                    .map(|(rho, res)| {
                        Json::obj(vec![
                            ("rho", Json::num(*rho)),
                            ("backend", Json::str(res.backend.clone())),
                            ("host_rmm_ms", super::runner::num_or_null(res.host_rmm_ms)),
                            (
                                "train",
                                Json::Arr(
                                    res.train_losses
                                        .iter()
                                        .map(|&(s, l)| {
                                            Json::arr(vec![
                                                Json::num(s as f64),
                                                Json::num(l),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                            (
                                "eval",
                                Json::Arr(
                                    res.eval_losses
                                        .iter()
                                        .map(|&(s, l)| {
                                            Json::arr(vec![
                                                Json::num(s as f64),
                                                Json::num(l),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]))
}
