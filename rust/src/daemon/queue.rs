//! Filesystem sweep queue: the daemon's intake surface.
//!
//! # Layout (under the `--queue` directory)
//!
//! ```text
//! incoming/<lane>/<name>.json   queued specs, one file per sweep
//! active/<lane>__<name>.json    the spec currently (or last) running
//! done/<lane>__<name>.json      specs whose report merged
//! rejected/<lane>__<name>.json  backpressure victims + unusable specs
//! sweeps/<lane>__<name>/        per-sweep fragment store (sole state)
//! reports/<lane>__<name>.json   merged reports (selftest byte format)
//! events.jsonl                  raw tee of the typed event stream
//! ```
//!
//! # Atomicity
//!
//! Enqueue reuses the `sweep::claim` idiom: write the spec to a
//! process-unique tmp name, then `hard_link` it to the final path —
//! the link is atomic and fails with `AlreadyExists` if another tenant
//! queued the same `(lane, name)` first, so there is exactly one
//! winner and readers never observe a torn spec.  The scan only
//! accepts `*.json` names, which keeps tmp litter (a writer killed
//! mid-enqueue) invisible.  Dequeue is a rename into `active/`, run
//! under the transient-IO retry budget with the `daemon.dequeue` chaos
//! fault point inside; a daemon killed after dequeue leaves the spec
//! in `active/`, and startup recovery simply runs `active/` entries
//! first (fragments make the re-run a resume).
//!
//! # Naming
//!
//! Lanes are tenant identities: `[A-Za-z0-9-]` (no underscore, so the
//! `__` separator in the sweep id `<lane>__<name>` is unambiguous).
//! Names are `[A-Za-z0-9_-]`.  Both non-empty.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use crate::sweep::retry;
use crate::sweep::SweepSpec;
use crate::util::json::Json;

/// A queued spec discovered by [`scan`], not yet dequeued.
#[derive(Debug, Clone, PartialEq)]
pub struct Pending {
    pub lane: String,
    pub name: String,
    pub path: PathBuf,
}

impl Pending {
    pub fn sweep_id(&self) -> String {
        sweep_id(&self.lane, &self.name)
    }
}

pub fn incoming_dir(queue: &Path) -> PathBuf {
    queue.join("incoming")
}
pub fn active_dir(queue: &Path) -> PathBuf {
    queue.join("active")
}
pub fn done_dir(queue: &Path) -> PathBuf {
    queue.join("done")
}
pub fn rejected_dir(queue: &Path) -> PathBuf {
    queue.join("rejected")
}
pub fn sweeps_dir(queue: &Path) -> PathBuf {
    queue.join("sweeps")
}
pub fn reports_dir(queue: &Path) -> PathBuf {
    queue.join("reports")
}
pub fn events_path(queue: &Path) -> PathBuf {
    queue.join("events.jsonl")
}

/// Create the queue directory skeleton (idempotent).
pub fn ensure_layout(queue: &Path) -> Result<()> {
    for d in [
        incoming_dir(queue),
        active_dir(queue),
        done_dir(queue),
        rejected_dir(queue),
        sweeps_dir(queue),
        reports_dir(queue),
    ] {
        std::fs::create_dir_all(&d)
            .with_context(|| format!("creating queue dir {}", d.display()))?;
    }
    Ok(())
}

/// Validate a lane id: non-empty, `[A-Za-z0-9-]` only.
pub fn validate_lane(lane: &str) -> Result<()> {
    if lane.is_empty() {
        bail!("lane must be non-empty");
    }
    if !lane.chars().all(|c| c.is_ascii_alphanumeric() || c == '-') {
        bail!("lane '{lane}' has characters outside [A-Za-z0-9-]");
    }
    Ok(())
}

/// Validate a sweep name: non-empty, `[A-Za-z0-9_-]` only.
pub fn validate_name(name: &str) -> Result<()> {
    if name.is_empty() {
        bail!("sweep name must be non-empty");
    }
    if !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_') {
        bail!("sweep name '{name}' has characters outside [A-Za-z0-9_-]");
    }
    Ok(())
}

/// The daemon-scoped sweep id.  Lanes exclude `_`, so splitting on the
/// first `__` always recovers `(lane, name)` exactly.
pub fn sweep_id(lane: &str, name: &str) -> String {
    format!("{lane}__{name}")
}

/// Invert [`sweep_id`].  Both halves are re-validated against the lane
/// and name charsets — `split_id` is the trust boundary for ids read
/// back off disk (`active/`, report names), which later get joined into
/// paths (`sweeps/<id>/`, `reports/<id>.json`).  Without the charset
/// check an id like `ci__../evil` would path-traverse out of the queue
/// directory; with it, any such entry is simply invisible.
pub fn split_id(id: &str) -> Option<(&str, &str)> {
    let sep = id.find("__")?;
    let (lane, rest) = id.split_at(sep);
    let name = &rest[2..];
    if validate_lane(lane).is_err() || validate_name(name).is_err() {
        return None;
    }
    Some((lane, name))
}

/// Atomically enqueue `spec` as `incoming/<lane>/<name>.json`.
/// Exactly one concurrent enqueue of the same `(lane, name)` wins; the
/// losers get an error naming the collision.
pub fn enqueue(queue: &Path, lane: &str, name: &str, spec: &SweepSpec) -> Result<PathBuf> {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    validate_lane(lane)?;
    validate_name(name)?;
    let dir = incoming_dir(queue).join(lane);
    std::fs::create_dir_all(&dir).with_context(|| format!("creating lane dir {}", dir.display()))?;
    let tmp = dir.join(format!(
        "{name}.json.tmp.{}.{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let body = spec.to_json().to_string_pretty();
    std::fs::write(&tmp, body.as_bytes())
        .with_context(|| format!("staging spec at {}", tmp.display()))?;
    let path = dir.join(format!("{name}.json"));
    // hard_link is atomic and fails if the final path exists: the
    // create-exclusive winner rule, with full content already durable.
    let linked = std::fs::hard_link(&tmp, &path);
    let _ = std::fs::remove_file(&tmp);
    match linked {
        Ok(()) => Ok(path),
        Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
            bail!("sweep '{}' is already queued at {}", sweep_id(lane, name), path.display())
        }
        Err(e) => Err(e).with_context(|| format!("publishing spec at {}", path.display())),
    }
}

fn json_stem(file_name: &str) -> Option<&str> {
    file_name.strip_suffix(".json")
}

/// Scan `incoming/` for queued specs: lanes in sorted order, specs
/// sorted within each lane.  Tmp litter and foreign files are skipped.
pub fn scan(queue: &Path) -> Result<Vec<Pending>> {
    let mut out = Vec::new();
    let root = incoming_dir(queue);
    let mut lanes: Vec<PathBuf> = match std::fs::read_dir(&root) {
        Ok(rd) => rd.filter_map(|e| e.ok()).map(|e| e.path()).filter(|p| p.is_dir()).collect(),
        Err(_) => return Ok(out),
    };
    lanes.sort();
    for lane_dir in lanes {
        let lane = match lane_dir.file_name().and_then(|n| n.to_str()) {
            Some(l) if validate_lane(l).is_ok() => l.to_string(),
            _ => continue,
        };
        let mut specs: Vec<(String, PathBuf)> = std::fs::read_dir(&lane_dir)
            .with_context(|| format!("scanning lane {}", lane_dir.display()))?
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let file = e.file_name().to_str()?.to_string();
                let name = json_stem(&file)?.to_string();
                validate_name(&name).ok()?;
                Some((name, e.path()))
            })
            .collect();
        specs.sort();
        out.extend(
            specs
                .into_iter()
                .map(|(name, path)| Pending { lane: lane.clone(), name, path }),
        );
    }
    Ok(out)
}

/// Dequeue a pending spec: rename it into `active/<lane>__<name>.json`.
/// Runs under the transient-IO retry budget with the `daemon.dequeue`
/// chaos fault point inside.
pub fn dequeue(queue: &Path, p: &Pending) -> Result<PathBuf> {
    let id = p.sweep_id();
    let dst = active_dir(queue).join(format!("{id}.json"));
    retry::io_retry(&format!("daemon.dequeue:{id}"), || {
        crate::chaos::fault("daemon.dequeue")?;
        std::fs::rename(&p.path, &dst)
    })
    .with_context(|| format!("dequeueing {} to {}", p.path.display(), dst.display()))?;
    Ok(dst)
}

/// Sweep ids (with their spec paths) left in `active/` — specs a prior
/// daemon dequeued but never finished.  Sorted, so recovery order is
/// deterministic.
pub fn active_entries(queue: &Path) -> Result<Vec<(String, PathBuf)>> {
    let mut out: Vec<(String, PathBuf)> = match std::fs::read_dir(active_dir(queue)) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let file = e.file_name().to_str()?.to_string();
                let id = json_stem(&file)?.to_string();
                split_id(&id)?;
                Some((id, e.path()))
            })
            .collect(),
        Err(_) => Vec::new(),
    };
    out.sort();
    Ok(out)
}

/// Move a spec file to `rejected/<id>.json` (backpressure victims and
/// specs the daemon cannot run).  Best-effort rename with a unique
/// fallback name if a same-id reject already sits there.
pub fn reject(queue: &Path, id: &str, path: &Path) -> Result<()> {
    let dst = rejected_dir(queue).join(format!("{id}.json"));
    if dst.exists() {
        let alt = rejected_dir(queue).join(format!("{id}.json.{}", std::process::id()));
        std::fs::rename(path, &alt)
            .with_context(|| format!("rejecting {} to {}", path.display(), alt.display()))?;
        return Ok(());
    }
    std::fs::rename(path, &dst)
        .with_context(|| format!("rejecting {} to {}", path.display(), dst.display()))
}

/// Retire a finished sweep's spec from `active/` to `done/`.
pub fn finish(queue: &Path, id: &str, active_path: &Path) -> Result<()> {
    let dst = done_dir(queue).join(format!("{id}.json"));
    std::fs::rename(active_path, &dst)
        .with_context(|| format!("retiring {} to {}", active_path.display(), dst.display()))
}

/// Load and parse a spec file.
pub fn load_spec(path: &Path) -> Result<SweepSpec> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading spec {}", path.display()))?;
    let j = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("spec {}: {e}", path.display()))?;
    SweepSpec::from_json(&j).with_context(|| format!("spec {}", path.display()))
}

/// True when the experiment runs without an engine or manifest — the
/// only specs the daemon accepts (its workers hold `data_only`
/// sessions; engine-backed experiments still go through the CLI).
pub fn engine_free(spec: &SweepSpec) -> bool {
    matches!(spec.experiment.as_str(), "mock" | "mockdata" | "budget")
        || spec.experiment.starts_with("synth-")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("rmm_queue_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn lane_and_name_charsets_keep_the_id_separator_unambiguous() {
        assert!(validate_lane("tenant-a").is_ok());
        assert!(validate_lane("tenant_a").is_err(), "lanes must exclude '_'");
        assert!(validate_lane("").is_err());
        assert!(validate_name("synth_easy-1").is_ok());
        assert!(validate_name("a/b").is_err());
        assert_eq!(split_id("t-a__syn_th"), Some(("t-a", "syn_th")));
        assert_eq!(split_id("noseparator"), None);
    }

    #[test]
    fn split_id_rejects_ids_outside_the_charsets() {
        // Traversal and separator abuse: these ids would escape the
        // queue directory if joined into sweeps/<id> or reports/<id>.
        for bad in [
            "ci__../evil",
            "..__evil",
            "ci__a/b",
            "ci__.hidden",
            "a b__name",
            "ci__",
            "__name",
            "ci__na me",
        ] {
            assert_eq!(split_id(bad), None, "{bad:?} must not split");
        }
        // The validators themselves refuse the same material at enqueue.
        assert!(validate_name("../evil").is_err());
        assert!(validate_name(".hidden").is_err());
        assert!(validate_lane("..").is_err());
    }

    #[test]
    fn active_entries_skip_ids_that_fail_the_charset_check() {
        let q = tmp("trav");
        ensure_layout(&q).unwrap();
        // A hostile or corrupted entry in active/ with a traversal name.
        std::fs::write(active_dir(&q).join("ci__..%2Fevil.json"), b"{}").unwrap();
        std::fs::create_dir_all(active_dir(&q).join("sub")).unwrap();
        std::fs::write(active_dir(&q).join("ci__ok.json"), b"{}").unwrap();
        let entries = active_entries(&q).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].0, "ci__ok");
        let _ = std::fs::remove_dir_all(&q);
    }

    #[test]
    fn enqueue_is_create_exclusive_and_scan_orders_lanes_then_names() {
        let q = tmp("enq");
        ensure_layout(&q).unwrap();
        let spec = crate::sweep::selftest_spec();
        enqueue(&q, "b-lane", "one", &spec).unwrap();
        enqueue(&q, "a-lane", "two", &spec).unwrap();
        enqueue(&q, "a-lane", "one", &spec).unwrap();
        let err = enqueue(&q, "a-lane", "one", &spec).unwrap_err();
        assert!(format!("{err:#}").contains("already queued"), "{err:#}");
        let ids: Vec<String> = scan(&q).unwrap().iter().map(|p| p.sweep_id()).collect();
        assert_eq!(ids, ["a-lane__one", "a-lane__two", "b-lane__one"]);
        // The published spec parses back to the original.
        let got = load_spec(&scan(&q).unwrap()[0].path).unwrap();
        assert_eq!(got.cells.len(), spec.cells.len());
        let _ = std::fs::remove_dir_all(&q);
    }

    #[test]
    fn tmp_litter_is_invisible_to_the_scan() {
        let q = tmp("litter");
        ensure_layout(&q).unwrap();
        let lane = incoming_dir(&q).join("ci");
        std::fs::create_dir_all(&lane).unwrap();
        std::fs::write(lane.join("x.json.tmp.999.0"), b"{").unwrap();
        std::fs::write(lane.join("notes.txt"), b"hi").unwrap();
        assert!(scan(&q).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&q);
    }

    #[test]
    fn dequeue_moves_to_active_and_finish_retires_to_done() {
        let q = tmp("deq");
        ensure_layout(&q).unwrap();
        let spec = crate::sweep::selftest_spec();
        enqueue(&q, "ci", "syn", &spec).unwrap();
        let p = scan(&q).unwrap().remove(0);
        let active = dequeue(&q, &p).unwrap();
        assert!(scan(&q).unwrap().is_empty());
        let entries = active_entries(&q).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].0, "ci__syn");
        finish(&q, "ci__syn", &active).unwrap();
        assert!(active_entries(&q).unwrap().is_empty());
        assert!(done_dir(&q).join("ci__syn.json").exists());
        let _ = std::fs::remove_dir_all(&q);
    }

    #[test]
    fn engine_free_covers_exactly_the_daemon_runnable_experiments() {
        let mk = |e: &str| SweepSpec::new(e, crate::sweep::selftest_spec().train.clone());
        for e in ["mock", "mockdata", "budget", "synth-easy", "synth-hard"] {
            assert!(engine_free(&mk(e)), "{e}");
        }
        assert!(!engine_free(&mk("glue")));
    }
}
