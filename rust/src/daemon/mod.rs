//! Sweep-as-a-service: a persistent orchestrator over the filesystem
//! queue ([`queue`]) that runs sweeps through the existing dynamic
//! claim/lease scheduler with worker `Session`s held warm *between*
//! sweeps, and narrates everything as typed JSONL events ([`events`]).
//!
//! # Design
//!
//! The daemon owns no state of its own.  Queue transitions are atomic
//! renames, per-sweep fragments are the sole source of truth, and the
//! event log is a pure witness: kill the daemon at any instant and the
//! next `sweep-daemon` invocation recovers `active/` specs first, where
//! `resume::prepare(.., resume=true)` turns the re-run into a resume
//! that executes exactly the missing cells.  Merged reports are written
//! in the selftest byte format, so daemon-vs-CLI byte identity is a
//! `cmp` away (the CI gate).
//!
//! # Fairness and backpressure
//!
//! Tenants map to queue *lanes*.  The daemon scans lanes in sorted
//! order but dequeues round-robin: each pick takes the lexicographically
//! first spec from the first non-empty lane cyclically *after* the lane
//! served last, so one chatty tenant cannot starve the others.  Depth
//! is bounded per lane: at scan time every spec beyond the first
//! `queue_cap` (sorted order) is moved to `rejected/` with a typed
//! `sweep_rejected` event carrying the observed depth and the cap —
//! callers learn they were shed from the event stream alone.
//!
//! # Workers
//!
//! Worker threads persist for the daemon lifetime, each owning its
//! `Session` (created inside the thread — sessions never cross a
//! thread boundary).  A sweep is dispatched by sending one job to every
//! worker; they race through the shared claim store exactly like
//! subprocess workers, then trim their session caches with
//! `retain_across_sweeps` so warm state amortizes across sweeps without
//! growing unboundedly (warm ≡ cold keeps this observation-free).  A
//! worker that returns an error is respawned cold (fresh thread +
//! session, generation + 1) under `respawn_budget`, with a
//! `worker_respawned` event; past the budget the sweep's spec stays in
//! `active/` and the daemon exits with the error — restart to resume.

pub mod events;
pub mod queue;

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use anyhow::{bail, Context, Result};

use crate::session::Session;
use crate::sweep::fleet;
use crate::sweep::{merge, resume, DynamicConfig, DynamicRun, SweepSpec};
use crate::util::json::Json;

use events::{Event, EventKind};
use queue::Pending;

/// Daemon configuration (CLI flags layered over the config file's
/// `daemon` section; see `config::DaemonConfig`).
#[derive(Debug, Clone)]
pub struct DaemonOpts {
    /// Queue directory root.
    pub queue: PathBuf,
    /// In-process worker threads racing each sweep's claim store.
    pub workers: usize,
    /// Max queued specs per lane; excess is shed to `rejected/`.
    pub queue_cap: usize,
    /// Claim lease TTL handed to the dynamic scheduler.
    pub lease_ttl_ms: u64,
    /// Affinity-first claiming (see `DynamicConfig::with_affinity`).
    pub affinity: bool,
    /// Warm session caches in the workers.
    pub session_cache: bool,
    /// Shared on-disk artifact cache under each sweep dir (`cache/`),
    /// plus fleet registry entries under `workers/`.  Lets a respawned
    /// (cold) worker warm-start from blobs its predecessors published.
    pub artifact_cache: bool,
    /// Exit once the queue is empty instead of polling forever.
    pub drain: bool,
    /// Idle poll interval when not draining.
    pub poll_ms: u64,
    /// Cold worker respawns allowed across the daemon lifetime.
    pub respawn_budget: u32,
    /// Mirror events to stdout (the tee to `events.jsonl` is always on).
    pub stdout_events: bool,
    /// After a drain, replay-parse the tee and require it to round-trip
    /// the in-memory emitted stream exactly.  Needs a fresh queue (the
    /// tee is append-only across runs) and a fault-free tee.
    pub replay_verify: bool,
}

impl Default for DaemonOpts {
    fn default() -> DaemonOpts {
        DaemonOpts {
            queue: PathBuf::new(),
            workers: 1,
            queue_cap: DEFAULT_QUEUE_CAP,
            lease_ttl_ms: crate::sweep::DEFAULT_LEASE_TTL_MS,
            affinity: true,
            session_cache: true,
            artifact_cache: false,
            drain: false,
            poll_ms: DEFAULT_POLL_MS,
            respawn_budget: 0,
            stdout_events: false,
            replay_verify: false,
        }
    }
}

/// Default per-lane queue-depth cap.
pub const DEFAULT_QUEUE_CAP: usize = 8;
/// Default idle poll interval (ms).
pub const DEFAULT_POLL_MS: u64 = 250;

/// What a daemon run did, plus the full emitted event stream (the
/// in-memory side of replay verification).
#[derive(Debug)]
pub struct DaemonSummary {
    pub merged: usize,
    pub rejected: usize,
    pub events: Vec<Event>,
}

/// A sweep dispatched to the worker pool.  Plain owned data: the only
/// thing that crosses a thread boundary.
struct SweepJob {
    dir: PathBuf,
    spec: SweepSpec,
    lease_ttl_ms: u64,
    affinity: bool,
    artifact_cache: bool,
}

struct Worker {
    sender: mpsc::Sender<Arc<SweepJob>>,
    gen: usize,
    handle: thread::JoinHandle<()>,
}

fn spawn_worker(
    slot: usize,
    gen: usize,
    session_cache: bool,
    results: mpsc::Sender<(usize, Result<DynamicRun>)>,
) -> Worker {
    let (tx, rx) = mpsc::channel::<Arc<SweepJob>>();
    let handle = thread::spawn(move || {
        // The session lives and dies with this thread; warm state
        // survives from sweep to sweep, trimmed between jobs.
        let mut session = Session::data_only(session_cache);
        for job in rx {
            let cfg = DynamicConfig::new(&format!("daemon-w{slot}g{gen}"), job.lease_ttl_ms)
                .with_affinity(job.affinity);
            // Fleet registry + artifact cache are per-sweep-dir state:
            // register for this job's mount, attach its cache, detach
            // both before the next job.  Registration is best-effort —
            // the registry is observability, never correctness.
            let reg = if job.artifact_cache {
                match fleet::ArtifactCache::open(&job.dir) {
                    Ok(cache) => session.set_artifact_cache(Some(cache)),
                    Err(e) => eprintln!("sweep-daemon: worker {slot}: artifact cache: {e:#}"),
                }
                fleet::register(&job.dir, &cfg.worker, job.lease_ttl_ms).ok()
            } else {
                None
            };
            let res =
                crate::sweep::run_dynamic_registered(&job.dir, &job.spec, &cfg, reg.as_ref(), &mut |c, ctx| {
                    crate::bench_harness::runner::run_cell(&mut session, &job.spec, c, ctx)
                });
            if let Some(reg) = reg {
                reg.deregister();
            }
            session.set_artifact_cache(None);
            session.retain_across_sweeps();
            if results.send((slot, res)).is_err() {
                break;
            }
        }
    });
    Worker { sender: tx, gen, handle }
}

struct WorkerPool {
    workers: Vec<Worker>,
    results_tx: mpsc::Sender<(usize, Result<DynamicRun>)>,
    results_rx: mpsc::Receiver<(usize, Result<DynamicRun>)>,
    session_cache: bool,
    respawns_left: u32,
}

impl WorkerPool {
    fn spawn(count: usize, session_cache: bool, respawn_budget: u32) -> WorkerPool {
        let (results_tx, results_rx) = mpsc::channel();
        let workers = (0..count)
            .map(|slot| spawn_worker(slot, 0, session_cache, results_tx.clone()))
            .collect();
        WorkerPool { workers, results_tx, results_rx, session_cache, respawns_left: respawn_budget }
    }

    /// Race every worker through one sweep's claim store; block until
    /// all of them report the grid complete.  A failed worker respawns
    /// cold (gen+1) and re-enters the race while the budget lasts.
    fn run_sweep(&mut self, job: Arc<SweepJob>) -> Result<()> {
        for w in &self.workers {
            w.sender.send(job.clone()).ok().context("daemon worker channel closed")?;
        }
        let mut pending = self.workers.len();
        while pending > 0 {
            let (slot, res) =
                self.results_rx.recv().ok().context("daemon worker result channel closed")?;
            match res {
                Ok(_) => pending -= 1,
                Err(e) => {
                    if self.respawns_left == 0 {
                        return Err(e).with_context(|| {
                            format!("daemon worker {slot} failed with no respawn budget left")
                        });
                    }
                    self.respawns_left -= 1;
                    let gen = self.workers[slot].gen + 1;
                    eprintln!(
                        "sweep-daemon: worker {slot} failed ({e:#}); respawning as gen {gen} \
                         ({} respawns left)",
                        self.respawns_left
                    );
                    let fresh = spawn_worker(slot, gen, self.session_cache, self.results_tx.clone());
                    events::worker_respawned(slot, gen);
                    fresh.sender.send(job.clone()).ok().context("daemon worker channel closed")?;
                    // Replacing the slot drops the dead worker's sender,
                    // which ends its job loop and lets the thread exit.
                    self.workers[slot] = fresh;
                }
            }
        }
        Ok(())
    }

    fn shutdown(self) {
        drop(self.results_tx);
        for w in self.workers {
            let Worker { sender, handle, .. } = w;
            drop(sender);
            let _ = handle.join();
        }
    }
}

/// Serialize a merged report in the exact byte format `sweep-selftest
/// --out` writes: pretty-printed row array plus a trailing newline.
/// This equality is the daemon-vs-CLI acceptance contract.
pub fn report_bytes(rows: Vec<Json>) -> String {
    let mut s = Json::Arr(rows).to_string_pretty();
    s.push('\n');
    s
}

/// Run the daemon: recover `active/`, then serve the queue until
/// drained (`drain`) or forever (polling).  See the module doc.
pub fn run(opts: &DaemonOpts) -> Result<DaemonSummary> {
    queue::ensure_layout(&opts.queue)?;
    if opts.workers == 0 {
        bail!("daemon needs at least one worker");
    }
    events::install(Some(&queue::events_path(&opts.queue)), opts.stdout_events)
        .context("opening events.jsonl tee")?;
    let res = run_inner(opts);
    let emitted = events::clear();
    let (merged, rejected) = res?;
    if opts.replay_verify {
        replay_verify(opts, &emitted)?;
    }
    Ok(DaemonSummary { merged, rejected, events: emitted })
}

fn run_inner(opts: &DaemonOpts) -> Result<(usize, usize)> {
    events::emit(EventKind::DaemonStarted {
        queue: opts.queue.display().to_string(),
        workers: opts.workers,
    });
    let mut pool = WorkerPool::spawn(opts.workers, opts.session_cache, opts.respawn_budget);
    let mut merged = 0usize;
    let mut rejected = 0usize;
    let mut queued_seen: BTreeSet<String> = BTreeSet::new();
    let mut last_lane: Option<String> = None;

    loop {
        // Crash recovery first: specs a prior daemon dequeued but never
        // retired.  Deterministic (sorted) order.
        let recovered = queue::active_entries(&opts.queue)?;
        for (id, path) in recovered {
            process_sweep(opts, &mut pool, &id, &path, &mut merged, &mut rejected)?;
        }

        // Intake: admit within the per-lane cap, shed the rest.
        let pending = scan_and_shed(opts, &mut queued_seen, &mut rejected)?;
        if pending.is_empty() {
            if opts.drain {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(opts.poll_ms.max(1)));
            continue;
        }

        // Fair pick: first non-empty lane cyclically after the last
        // lane served, lexicographically first spec within it.
        let pick = pick_round_robin(&pending, last_lane.as_deref());
        last_lane = Some(pick.lane.clone());
        let id = pick.sweep_id();
        let active = queue::dequeue(&opts.queue, &pick)?;
        process_sweep(opts, &mut pool, &id, &active, &mut merged, &mut rejected)?;
    }

    events::emit(EventKind::DaemonStopped { sweeps: merged });
    pool.shutdown();
    Ok((merged, rejected))
}

/// Scan `incoming/`, emit `sweep_queued` for newly seen specs, and
/// enforce the per-lane depth cap (excess specs move to `rejected/`
/// with a `sweep_rejected` event).  Returns the admitted pendings.
fn scan_and_shed(
    opts: &DaemonOpts,
    queued_seen: &mut BTreeSet<String>,
    rejected: &mut usize,
) -> Result<Vec<Pending>> {
    let mut admitted = Vec::new();
    let mut by_lane: Vec<(String, Vec<Pending>)> = Vec::new();
    for p in queue::scan(&opts.queue)? {
        match by_lane.last_mut() {
            Some((lane, group)) if *lane == p.lane => group.push(p),
            _ => by_lane.push((p.lane.clone(), vec![p])),
        }
    }
    for (_, group) in by_lane {
        let depth = group.len();
        for (i, p) in group.into_iter().enumerate() {
            let id = p.sweep_id();
            if i < opts.queue_cap {
                if queued_seen.insert(id.clone()) {
                    events::emit(EventKind::SweepQueued { sweep: id, lane: p.lane.clone() });
                }
                admitted.push(p);
            } else {
                events::emit(EventKind::SweepRejected {
                    sweep: id.clone(),
                    lane: p.lane.clone(),
                    depth,
                    cap: opts.queue_cap,
                });
                eprintln!(
                    "sweep-daemon: lane '{}' over depth cap ({depth} > {}), shedding '{id}'",
                    p.lane, opts.queue_cap
                );
                queue::reject(&opts.queue, &id, &p.path)?;
                *rejected += 1;
            }
        }
    }
    Ok(admitted)
}

/// Round-robin lane pick over a sorted pending list: the first spec of
/// the first non-empty lane strictly after `last` in cyclic lane order.
fn pick_round_robin(pending: &[Pending], last: Option<&str>) -> Pending {
    debug_assert!(!pending.is_empty());
    if let Some(last) = last {
        if let Some(p) = pending.iter().find(|p| p.lane.as_str() > last) {
            return p.clone();
        }
    }
    pending[0].clone()
}

/// Run one dequeued sweep end to end: parse + admission-check the
/// spec, resume-prepare its fragment dir, race the pool, merge, write
/// the report, retire the spec.  Unusable specs go to `rejected/` with
/// a stderr diagnostic; scheduler failures leave the spec in `active/`
/// and propagate (restart = resume).
fn process_sweep(
    opts: &DaemonOpts,
    pool: &mut WorkerPool,
    id: &str,
    active_path: &std::path::Path,
    merged: &mut usize,
    rejected: &mut usize,
) -> Result<()> {
    let lane = queue::split_id(id).map(|(l, _)| l.to_string()).unwrap_or_default();
    let spec = match queue::load_spec(active_path) {
        Ok(spec) if queue::engine_free(&spec) => spec,
        Ok(spec) => {
            eprintln!(
                "sweep-daemon: rejecting '{id}': experiment '{}' needs an engine; \
                 run it via sweep-selftest/bench instead",
                spec.experiment
            );
            queue::reject(&opts.queue, id, active_path)?;
            *rejected += 1;
            return Ok(());
        }
        Err(e) => {
            eprintln!("sweep-daemon: rejecting '{id}': {e:#}");
            queue::reject(&opts.queue, id, active_path)?;
            *rejected += 1;
            return Ok(());
        }
    };

    let sdir = queue::sweeps_dir(&opts.queue).join(id);
    // resume=true: fragments from a crashed prior run are kept, so the
    // re-run executes exactly the missing cells.
    resume::prepare(&sdir, &spec, true)?;
    events::set_sweep(Some(id));
    events::emit(EventKind::SweepStarted {
        sweep: id.to_string(),
        lane,
        cells: spec.cells.len(),
    });

    let job = Arc::new(SweepJob {
        dir: sdir.clone(),
        spec: spec.clone(),
        lease_ttl_ms: opts.lease_ttl_ms,
        affinity: opts.affinity,
        artifact_cache: opts.artifact_cache,
    });
    let raced = pool.run_sweep(job);
    if let Err(e) = raced {
        events::set_sweep(None);
        return Err(e).with_context(|| format!("running sweep '{id}'"));
    }

    let rows = merge::merge(&sdir, &spec)?;
    let cells = rows.len();
    let report = report_bytes(rows);
    write_report(opts, id, &report)?;
    events::emit(EventKind::SweepMerged { sweep: id.to_string(), cells });
    events::set_sweep(None);
    queue::finish(&opts.queue, id, active_path)?;
    *merged += 1;
    Ok(())
}

/// Publish `reports/<id>.json` atomically (unique tmp + rename).
fn write_report(opts: &DaemonOpts, id: &str, report: &str) -> Result<()> {
    let dir = queue::reports_dir(&opts.queue);
    let tmp = dir.join(format!("{id}.json.tmp.{}", std::process::id()));
    std::fs::write(&tmp, report.as_bytes())
        .with_context(|| format!("staging report {}", tmp.display()))?;
    let dst = dir.join(format!("{id}.json"));
    std::fs::rename(&tmp, &dst)
        .with_context(|| format!("publishing report {}", dst.display()))?;
    Ok(())
}

/// Replay-parse the tee and require an exact round-trip of the emitted
/// stream: same events, same order, same synthetic ids, no diagnostics.
fn replay_verify(opts: &DaemonOpts, emitted: &[Event]) -> Result<()> {
    let path = queue::events_path(&opts.queue);
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading tee {}", path.display()))?;
    let parsed = events::parse_lines(&text);
    if !parsed.diagnostics.is_empty() {
        bail!(
            "replay-verify: tee {} has {} unparseable line(s); first: {}",
            path.display(),
            parsed.diagnostics.len(),
            parsed.diagnostics[0]
        );
    }
    if parsed.events != emitted {
        bail!(
            "replay-verify: tee {} round-trip mismatch ({} parsed vs {} emitted events)",
            path.display(),
            parsed.events.len(),
            emitted.len()
        );
    }
    eprintln!("sweep-daemon: replay-verify ok ({} events round-tripped)", emitted.len());
    Ok(())
}
