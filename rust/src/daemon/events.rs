//! Typed JSONL progress events: the daemon's observable surface.
//!
//! # Wire format
//!
//! One compact JSON object per line.  Every line carries a snake_case
//! `"type"` discriminant and a `t_ms` wall-clock timestamp
//! (unix-epoch ms); the remaining keys are the event's payload fields
//! (see the schema table in the `sweep` module doc).  Synthetic ids are
//! **not** on the wire: both the emitter and the replay parser assign
//! them from a monotonic counter starting at 1, so a replay-parse of a
//! teed `events.jsonl` reproduces the emitted [`Event`] stream exactly,
//! ids included.
//!
//! # Replay guarantees ([`parse_lines`])
//!
//! * Blank / whitespace-only lines are ignored (covers a trailing
//!   newline and a torn final line that never got its payload).
//! * A single trailing `'\r'` is trimmed per line (CRLF logs parse
//!   identically to LF logs); no other trimming is applied.
//! * An unknown `"type"`, a malformed JSON line, or a known type with a
//!   missing required field yields a per-line *diagnostic* — parsing
//!   continues with the next line, never a hard error.
//! * Unknown extra fields on a known event type are silently ignored
//!   (only the schema's keys are read), so the contract is
//!   forward-compatible with new payload fields.
//! * Ids are assigned only to successfully parsed events, monotonically
//!   across the whole input — concatenated logs never reset the
//!   counter mid-stream.
//!
//! # Sink
//!
//! The process-global sink mirrors the `chaos` install pattern: an
//! atomic fast path ([`enabled`]) so the library hooks in
//! `sweep::{scheduler,merge}` are free when no daemon is running, plus
//! a mutex-held [`Sink`] that serializes concurrent worker-thread
//! emissions — the tee file order is therefore the emitted order for
//! any worker count.  Tee appends run under `sweep::retry::io_retry`
//! with the `event.tee` chaos fault point inside; a non-transient tee
//! failure drops the line and moves on, because the event log is a
//! pure witness, never an input (fragments are the sole state).

use std::fs::File;
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::json::Json;

/// A typed daemon progress event.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    /// Monotonic synthetic id, assigned from 1 by the emitter and
    /// re-derived identically by [`parse_lines`]; never on the wire.
    pub id: u64,
    pub kind: EventKind,
    /// Wall-clock unix-epoch milliseconds.  The only nondeterministic
    /// field: same-seed comparisons strip it (see [`Event::with_t0`]).
    pub t_ms: u64,
}

impl Event {
    /// The event with its timestamp zeroed — the canonical form for
    /// "identical modulo timing fields" comparisons.
    pub fn with_t0(&self) -> Event {
        Event { id: self.id, kind: self.kind.clone(), t_ms: 0 }
    }

    /// Serialize to one compact JSONL line (no trailing newline).
    pub fn to_line(&self) -> String {
        let mut fields = vec![("type", Json::str(self.kind.type_name()))];
        fields.extend(self.kind.fields());
        fields.push(("t_ms", Json::num(self.t_ms as f64)));
        Json::obj(fields).to_string()
    }
}

/// The event vocabulary.  `sweep` is the daemon-scoped sweep id
/// (`<lane>__<name>`); `cell` is the cell index within its spec;
/// `worker` is the claim-protocol worker id.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    DaemonStarted { queue: String, workers: usize },
    SweepQueued { sweep: String, lane: String },
    SweepRejected { sweep: String, lane: String, depth: usize, cap: usize },
    SweepStarted { sweep: String, lane: String, cells: usize },
    CellClaimed { sweep: String, cell: usize, worker: String },
    CellDone { sweep: String, cell: usize, worker: String },
    FragmentCommitted { sweep: String, cell: usize },
    WorkerRespawned { sweep: String, slot: usize, gen: usize },
    SweepMerged { sweep: String, cells: usize },
    DaemonStopped { sweeps: usize },
}

impl EventKind {
    /// The snake_case wire discriminant.
    pub fn type_name(&self) -> &'static str {
        match self {
            EventKind::DaemonStarted { .. } => "daemon_started",
            EventKind::SweepQueued { .. } => "sweep_queued",
            EventKind::SweepRejected { .. } => "sweep_rejected",
            EventKind::SweepStarted { .. } => "sweep_started",
            EventKind::CellClaimed { .. } => "cell_claimed",
            EventKind::CellDone { .. } => "cell_done",
            EventKind::FragmentCommitted { .. } => "fragment_committed",
            EventKind::WorkerRespawned { .. } => "worker_respawned",
            EventKind::SweepMerged { .. } => "sweep_merged",
            EventKind::DaemonStopped { .. } => "daemon_stopped",
        }
    }

    fn fields(&self) -> Vec<(&'static str, Json)> {
        let n = |v: usize| Json::num(v as f64);
        match self {
            EventKind::DaemonStarted { queue, workers } => {
                vec![("queue", Json::str(queue.clone())), ("workers", n(*workers))]
            }
            EventKind::SweepQueued { sweep, lane } => {
                vec![("sweep", Json::str(sweep.clone())), ("lane", Json::str(lane.clone()))]
            }
            EventKind::SweepRejected { sweep, lane, depth, cap } => vec![
                ("sweep", Json::str(sweep.clone())),
                ("lane", Json::str(lane.clone())),
                ("depth", n(*depth)),
                ("cap", n(*cap)),
            ],
            EventKind::SweepStarted { sweep, lane, cells } => vec![
                ("sweep", Json::str(sweep.clone())),
                ("lane", Json::str(lane.clone())),
                ("cells", n(*cells)),
            ],
            EventKind::CellClaimed { sweep, cell, worker } => vec![
                ("sweep", Json::str(sweep.clone())),
                ("cell", n(*cell)),
                ("worker", Json::str(worker.clone())),
            ],
            EventKind::CellDone { sweep, cell, worker } => vec![
                ("sweep", Json::str(sweep.clone())),
                ("cell", n(*cell)),
                ("worker", Json::str(worker.clone())),
            ],
            EventKind::FragmentCommitted { sweep, cell } => {
                vec![("sweep", Json::str(sweep.clone())), ("cell", n(*cell))]
            }
            EventKind::WorkerRespawned { sweep, slot, gen } => vec![
                ("sweep", Json::str(sweep.clone())),
                ("slot", n(*slot)),
                ("gen", n(*gen)),
            ],
            EventKind::SweepMerged { sweep, cells } => {
                vec![("sweep", Json::str(sweep.clone())), ("cells", n(*cells))]
            }
            EventKind::DaemonStopped { sweeps } => vec![("sweeps", n(*sweeps))],
        }
    }
}

// ---------------------------------------------------------------------------
// Replay parser
// ---------------------------------------------------------------------------

/// The result of replay-parsing an event log: the reconstructed typed
/// stream plus one diagnostic per skipped line.
#[derive(Debug, Default)]
pub struct ParsedLog {
    pub events: Vec<Event>,
    /// `"line <n>: <why>"` for every line that failed to parse into a
    /// known event (1-based line numbers).
    pub diagnostics: Vec<String>,
}

fn req_str(j: &Json, key: &str) -> Result<String, String> {
    j.get(key).as_str().map(str::to_string).ok_or_else(|| format!("missing field '{key}'"))
}

fn req_usize(j: &Json, key: &str) -> Result<usize, String> {
    j.get(key).as_usize().ok_or_else(|| format!("missing field '{key}'"))
}

fn parse_kind(j: &Json) -> Result<EventKind, String> {
    let ty = j.get("type").as_str().ok_or("missing field 'type'")?;
    match ty {
        "daemon_started" => Ok(EventKind::DaemonStarted {
            queue: req_str(j, "queue")?,
            workers: req_usize(j, "workers")?,
        }),
        "sweep_queued" => Ok(EventKind::SweepQueued {
            sweep: req_str(j, "sweep")?,
            lane: req_str(j, "lane")?,
        }),
        "sweep_rejected" => Ok(EventKind::SweepRejected {
            sweep: req_str(j, "sweep")?,
            lane: req_str(j, "lane")?,
            depth: req_usize(j, "depth")?,
            cap: req_usize(j, "cap")?,
        }),
        "sweep_started" => Ok(EventKind::SweepStarted {
            sweep: req_str(j, "sweep")?,
            lane: req_str(j, "lane")?,
            cells: req_usize(j, "cells")?,
        }),
        "cell_claimed" => Ok(EventKind::CellClaimed {
            sweep: req_str(j, "sweep")?,
            cell: req_usize(j, "cell")?,
            worker: req_str(j, "worker")?,
        }),
        "cell_done" => Ok(EventKind::CellDone {
            sweep: req_str(j, "sweep")?,
            cell: req_usize(j, "cell")?,
            worker: req_str(j, "worker")?,
        }),
        "fragment_committed" => Ok(EventKind::FragmentCommitted {
            sweep: req_str(j, "sweep")?,
            cell: req_usize(j, "cell")?,
        }),
        "worker_respawned" => Ok(EventKind::WorkerRespawned {
            sweep: req_str(j, "sweep")?,
            slot: req_usize(j, "slot")?,
            gen: req_usize(j, "gen")?,
        }),
        "sweep_merged" => Ok(EventKind::SweepMerged {
            sweep: req_str(j, "sweep")?,
            cells: req_usize(j, "cells")?,
        }),
        "daemon_stopped" => Ok(EventKind::DaemonStopped { sweeps: req_usize(j, "sweeps")? }),
        other => Err(format!("unknown event type '{other}'")),
    }
}

/// Replay-parse a raw JSONL event log (see the module doc for the
/// tolerance contract).  Never fails: unparseable lines become
/// diagnostics and the stream continues.
pub fn parse_lines(text: &str) -> ParsedLog {
    let mut log = ParsedLog::default();
    let mut next_id: u64 = 1;
    for (i, raw) in text.split('\n').enumerate() {
        // CRLF tolerance: trim ONE trailing '\r' and nothing else —
        // a full trim would hide payload whitespace differences.
        let line = raw.strip_suffix('\r').unwrap_or(raw);
        if line.trim().is_empty() {
            continue;
        }
        let lineno = i + 1;
        let j = match Json::parse(line) {
            Ok(j) => j,
            Err(e) => {
                log.diagnostics.push(format!("line {lineno}: {e}"));
                continue;
            }
        };
        match parse_kind(&j) {
            Ok(kind) => {
                // t_ms is tolerated missing (0): timing is advisory.
                let t_ms = j.get("t_ms").as_f64().unwrap_or(0.0) as u64;
                log.events.push(Event { id: next_id, kind, t_ms });
                next_id += 1;
            }
            Err(why) => log.diagnostics.push(format!("line {lineno}: {why}")),
        }
    }
    log
}

// ---------------------------------------------------------------------------
// Process-global sink
// ---------------------------------------------------------------------------

struct Sink {
    next_id: u64,
    /// Current sweep label, injected into the library-hook events
    /// (`cell_claimed` etc.) that can't know which sweep they serve.
    sweep: Option<String>,
    tee: Option<File>,
    emitted: Vec<Event>,
    stdout: bool,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<Sink>> = Mutex::new(None);

/// True when a sink is installed — the fast path the library hooks
/// check before paying for any lock or allocation.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Install the process-global sink.  `tee` appends raw lines to the
/// given file (created if absent — append keeps a crash/resume pair of
/// daemon runs in one log); `stdout` mirrors lines to stdout.
pub fn install(tee: Option<&Path>, stdout: bool) -> std::io::Result<()> {
    let tee = match tee {
        Some(p) => Some(std::fs::OpenOptions::new().create(true).append(true).open(p)?),
        None => None,
    };
    let mut guard = SINK.lock().unwrap();
    *guard = Some(Sink { next_id: 1, sweep: None, tee, emitted: Vec::new(), stdout });
    ENABLED.store(true, Ordering::SeqCst);
    Ok(())
}

/// Tear the sink down, returning everything it emitted (the in-memory
/// side of the replay-verify comparison).
pub fn clear() -> Vec<Event> {
    ENABLED.store(false, Ordering::SeqCst);
    SINK.lock().unwrap().take().map(|s| s.emitted).unwrap_or_default()
}

/// Snapshot the emitted stream without tearing the sink down.
pub fn snapshot() -> Vec<Event> {
    SINK.lock().unwrap().as_ref().map(|s| s.emitted.clone()).unwrap_or_default()
}

/// Set (or clear) the sweep label stamped onto library-hook events.
pub fn set_sweep(label: Option<&str>) {
    if let Some(sink) = SINK.lock().unwrap().as_mut() {
        sink.sweep = label.map(str::to_string);
    }
}

fn emit_locked(sink: &mut Sink, kind: EventKind) {
    let t_ms = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0);
    let ev = Event { id: sink.next_id, kind, t_ms };
    sink.next_id += 1;
    let line = ev.to_line();
    if sink.stdout {
        println!("{line}");
    }
    if let Some(f) = sink.tee.as_mut() {
        // Transient tee errors heal under the retry budget; anything
        // worse drops the line — the log is a witness, not state.
        let _ = crate::sweep::retry::io_retry("event.tee", || {
            crate::chaos::fault("event.tee")?;
            writeln!(f, "{line}")
        });
    }
    sink.emitted.push(ev);
}

/// Emit an event with an explicit kind (daemon-side call sites that
/// know their full payload).  No-op when no sink is installed.
pub fn emit(kind: EventKind) {
    if !enabled() {
        return;
    }
    let mut guard = SINK.lock().unwrap();
    if let Some(sink) = guard.as_mut() {
        emit_locked(sink, kind);
    }
}

/// Emit an event whose kind needs the sink's current sweep label
/// (the library hooks below).  Single lock for label + emission.
fn emit_scoped(make: impl FnOnce(String) -> EventKind) {
    if !enabled() {
        return;
    }
    let mut guard = SINK.lock().unwrap();
    if let Some(sink) = guard.as_mut() {
        let sweep = sink.sweep.clone().unwrap_or_default();
        let kind = make(sweep);
        emit_locked(sink, kind);
    }
}

/// Library hook (`sweep::scheduler`): a worker won a cell's lease.
pub fn cell_claimed(cell: usize, worker: &str) {
    let worker = worker.to_string();
    emit_scoped(|sweep| EventKind::CellClaimed { sweep, cell, worker });
}

/// Library hook (`sweep::scheduler`): a cell's fragment committed and
/// its lease released.
pub fn cell_done(cell: usize, worker: &str) {
    let worker = worker.to_string();
    emit_scoped(|sweep| EventKind::CellDone { sweep, cell, worker });
}

/// Library hook (`sweep::merge`): a fragment landed valid on disk.
pub fn fragment_committed(cell: usize) {
    emit_scoped(|sweep| EventKind::FragmentCommitted { sweep, cell });
}

/// Hook for worker supervision (daemon pool and the subprocess
/// supervisor): a dead worker slot was respawned as `gen`.
pub fn worker_respawned(slot: usize, gen: usize) {
    emit_scoped(|sweep| EventKind::WorkerRespawned { sweep, slot, gen });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_kinds() -> Vec<EventKind> {
        vec![
            EventKind::DaemonStarted { queue: "/tmp/q".into(), workers: 2 },
            EventKind::SweepQueued { sweep: "ci__synth".into(), lane: "ci".into() },
            EventKind::SweepRejected {
                sweep: "ci__late".into(),
                lane: "ci".into(),
                depth: 9,
                cap: 8,
            },
            EventKind::SweepStarted { sweep: "ci__synth".into(), lane: "ci".into(), cells: 8 },
            EventKind::CellClaimed { sweep: "ci__synth".into(), cell: 3, worker: "w-1-0".into() },
            EventKind::CellDone { sweep: "ci__synth".into(), cell: 3, worker: "w-1-0".into() },
            EventKind::FragmentCommitted { sweep: "ci__synth".into(), cell: 3 },
            EventKind::WorkerRespawned { sweep: "ci__synth".into(), slot: 0, gen: 1 },
            EventKind::SweepMerged { sweep: "ci__synth".into(), cells: 8 },
            EventKind::DaemonStopped { sweeps: 1 },
        ]
    }

    #[test]
    fn every_kind_round_trips_through_its_wire_line() {
        let events: Vec<Event> = sample_kinds()
            .into_iter()
            .enumerate()
            .map(|(i, kind)| Event { id: i as u64 + 1, kind, t_ms: 1000 + i as u64 })
            .collect();
        let text: String = events.iter().map(|e| e.to_line() + "\n").collect();
        let log = parse_lines(&text);
        assert!(log.diagnostics.is_empty(), "{:?}", log.diagnostics);
        assert_eq!(log.events, events);
    }

    #[test]
    fn crlf_blank_lines_and_a_torn_tail_are_tolerated() {
        let a = Event {
            id: 1,
            kind: EventKind::DaemonStopped { sweeps: 0 },
            t_ms: 5,
        };
        let text = format!("\r\n  \n{}\r\n{{\"type\":\"sweep_m", a.to_line());
        let log = parse_lines(&text);
        assert_eq!(log.events, vec![a]);
        assert_eq!(log.diagnostics.len(), 1, "torn tail must diagnose, not error");
    }

    #[test]
    fn unknown_types_and_missing_fields_diagnose_without_consuming_ids() {
        let good = Event {
            id: 1,
            kind: EventKind::SweepQueued { sweep: "a__b".into(), lane: "a".into() },
            t_ms: 0,
        };
        let text = format!(
            "{{\"type\":\"comet_sighted\",\"t_ms\":1}}\n{}\n{{\"type\":\"cell_done\",\"sweep\":\"x__y\"}}\n",
            good.to_line()
        );
        let log = parse_lines(&text);
        assert_eq!(log.events, vec![good], "good line must get id 1, skips consume none");
        assert_eq!(log.diagnostics.len(), 2);
        assert!(log.diagnostics[0].contains("line 1"), "{}", log.diagnostics[0]);
        assert!(log.diagnostics[0].contains("unknown event type 'comet_sighted'"));
        assert!(log.diagnostics[1].contains("line 3"));
        assert!(log.diagnostics[1].contains("missing field"));
    }

    #[test]
    fn unknown_extra_fields_on_known_types_are_ignored() {
        let text = "{\"type\":\"daemon_stopped\",\"sweeps\":3,\"t_ms\":7,\"galaxy\":\"m31\"}\n";
        let log = parse_lines(text);
        assert!(log.diagnostics.is_empty(), "{:?}", log.diagnostics);
        assert_eq!(
            log.events,
            vec![Event { id: 1, kind: EventKind::DaemonStopped { sweeps: 3 }, t_ms: 7 }]
        );
    }

    #[test]
    fn missing_t_ms_parses_as_zero() {
        let log = parse_lines("{\"type\":\"daemon_stopped\",\"sweeps\":1}\n");
        assert_eq!(log.events[0].t_ms, 0);
        assert!(log.diagnostics.is_empty());
    }

    #[test]
    fn ids_stay_monotonic_across_a_concatenated_log() {
        let one = "{\"type\":\"daemon_stopped\",\"sweeps\":1}\n";
        let text = format!("{one}{one}{one}");
        let log = parse_lines(&text);
        let ids: Vec<u64> = log.events.iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![1, 2, 3], "concatenation must never reset the counter");
    }
}
