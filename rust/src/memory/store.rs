//! ActivationStore: owns the residual buffers between `fwd` and `bwd`.
//!
//! In a fused autodiff graph the forward→backward residency is decided by
//! the compiler; by splitting the graph at exactly that boundary, the
//! coordinator holds the residuals as named buffers, and "stored
//! activations" becomes a measured byte count — the quantity in the
//! paper's Table 3 / Fig. 3.  The store tracks live and peak bytes across
//! the step lifecycle (put-all → consume-all), with per-name size
//! breakdown for the memory reports.

use std::collections::BTreeMap;

/// A named residual buffer staged between fwd and bwd.
pub struct Slot<T> {
    pub value: T,
    pub bytes: usize,
}

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StoreStats {
    pub live_bytes: usize,
    pub peak_bytes: usize,
    pub puts: usize,
    pub takes: usize,
}

/// Generic over the buffer payload so unit tests run without PJRT (the
/// trainer instantiates `ActivationStore<PjRtBuffer>`).
pub struct ActivationStore<T> {
    slots: BTreeMap<String, Slot<T>>,
    stats: StoreStats,
}

impl<T> Default for ActivationStore<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> ActivationStore<T> {
    pub fn new() -> Self {
        Self { slots: BTreeMap::new(), stats: StoreStats::default() }
    }

    /// Stage a residual. Replacing an existing name is a bug upstream.
    pub fn put(&mut self, name: &str, value: T, bytes: usize) {
        let prev = self.slots.insert(name.to_string(), Slot { value, bytes });
        assert!(prev.is_none(), "residual '{name}' staged twice");
        self.stats.puts += 1;
        self.stats.live_bytes += bytes;
        self.stats.peak_bytes = self.stats.peak_bytes.max(self.stats.live_bytes);
    }

    /// Remove and return a residual (bwd consumes each exactly once).
    pub fn take(&mut self, name: &str) -> Option<T> {
        let slot = self.slots.remove(name)?;
        self.stats.takes += 1;
        self.stats.live_bytes -= slot.bytes;
        Some(slot.value)
    }

    pub fn contains(&self, name: &str) -> bool {
        self.slots.contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn stats(&self) -> StoreStats {
        self.stats
    }

    /// Reset peak tracking (per-step accounting) without touching content.
    pub fn reset_peak(&mut self) {
        self.stats.peak_bytes = self.stats.live_bytes;
    }

    /// Per-name byte sizes, largest first (for the memory breakdown table).
    pub fn breakdown(&self) -> Vec<(String, usize)> {
        let mut v: Vec<(String, usize)> =
            self.slots.iter().map(|(k, s)| (k.clone(), s.bytes)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1));
        v
    }

    /// Drop everything (e.g. on abort); accounting stays consistent.
    pub fn clear(&mut self) {
        self.stats.live_bytes = 0;
        self.slots.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_take_accounting() {
        let mut s: ActivationStore<Vec<u8>> = ActivationStore::new();
        s.put("a", vec![0; 100], 100);
        s.put("b", vec![0; 50], 50);
        assert_eq!(s.stats().live_bytes, 150);
        assert_eq!(s.stats().peak_bytes, 150);
        assert!(s.take("a").is_some());
        assert_eq!(s.stats().live_bytes, 50);
        assert_eq!(s.stats().peak_bytes, 150); // peak persists
        assert!(s.take("a").is_none());
        assert!(s.take("b").is_some());
        assert!(s.is_empty());
        assert_eq!(s.stats().puts, 2);
        assert_eq!(s.stats().takes, 2);
    }

    #[test]
    #[should_panic(expected = "staged twice")]
    fn double_put_panics() {
        let mut s: ActivationStore<u32> = ActivationStore::new();
        s.put("x", 1, 4);
        s.put("x", 2, 4);
    }

    #[test]
    fn peak_across_steps() {
        let mut s: ActivationStore<u32> = ActivationStore::new();
        s.put("x", 1, 1000);
        s.take("x");
        s.reset_peak();
        s.put("y", 2, 10);
        assert_eq!(s.stats().peak_bytes, 10);
    }

    #[test]
    fn breakdown_sorted() {
        let mut s: ActivationStore<u32> = ActivationStore::new();
        s.put("small", 1, 10);
        s.put("big", 2, 99);
        assert_eq!(
            s.breakdown(),
            vec![("big".to_string(), 99), ("small".to_string(), 10)]
        );
    }

    #[test]
    fn clear_resets_live() {
        let mut s: ActivationStore<u32> = ActivationStore::new();
        s.put("x", 1, 7);
        s.clear();
        assert_eq!(s.stats().live_bytes, 0);
        assert!(s.is_empty());
    }
}
