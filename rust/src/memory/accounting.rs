//! Analytic whole-process memory model.
//!
//! Mirrors the L2 tape layout exactly (one formula per residual in
//! `python/compile/layers.py`), so the integration tests can check it
//! against the *measured* ActivationStore bytes, and the bench harness can
//! extrapolate Table 3 / Fig. 3 to paper-scale geometry (RoBERTa-base on a
//! 16 GB V100) where direct execution is impractical on this testbed.

const F32: usize = 4;

/// Static geometry of an encoder + batch (the quantities Table 1 ranges
/// over: B·T rows, N_in/N_out of every linear).
#[derive(Debug, Clone, Copy)]
pub struct ModelGeometry {
    pub vocab_size: usize,
    pub seq_len: usize,
    pub batch_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub n_classes: usize,
}

impl ModelGeometry {
    pub fn rows(&self) -> usize {
        self.batch_size * self.seq_len
    }

    /// Parameter count (matches `model.param_spec`).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let ff = self.d_ff;
        let emb = self.vocab_size * d + self.seq_len * d + 2 * d;
        let block = 4 * (d * d + d) + 2 * d + (ff * d + ff) + (d * ff + d) + 2 * d;
        let heads = d * d + d + self.n_classes * d + self.n_classes;
        emb + self.n_layers * block + heads
    }

    /// RoBERTa-base-like geometry at the paper's scale (for extrapolated
    /// rows of Table 3).
    pub fn roberta_base(batch_size: usize, seq_len: usize) -> Self {
        Self {
            vocab_size: 50265,
            seq_len,
            batch_size,
            d_model: 768,
            n_heads: 12,
            n_layers: 12,
            d_ff: 3072,
            n_classes: 2,
        }
    }
}

/// Byte accounting for one training step at compression ratio ρ.
#[derive(Debug, Clone, Copy)]
pub struct MemoryModel {
    pub geom: ModelGeometry,
    pub rho: f64,
}

impl MemoryModel {
    pub fn new(geom: ModelGeometry, rho: f64) -> Self {
        Self { geom, rho }
    }

    pub fn b_proj(&self) -> usize {
        let rows = self.geom.rows();
        if self.rho >= 1.0 {
            rows
        } else {
            ((self.rho * rows as f64).round() as usize).clamp(1, rows)
        }
    }

    /// Rows actually stored for a linear-layer input (the paper's saving).
    fn stored_rows(&self) -> usize {
        self.b_proj()
    }

    /// Residual bytes per encoder block — mirrors layers.py tape order.
    pub fn block_residual_bytes(&self) -> usize {
        let g = &self.geom;
        let rows = g.rows();
        let sr = self.stored_rows();
        let d = g.d_model;
        let ff = g.d_ff;
        let att = g.batch_size * g.n_heads * g.seq_len * g.seq_len;
        let mut b = 0usize;
        b += sr * d; // mha.qkv_in (shared q/k/v store)
        b += 3 * rows * d; // q, k, v head tensors
        b += att; // attention probabilities A
        b += sr * d; // mha.o_in
        b += rows * d + rows; // ln1 xhat + rstd
        b += sr * d; // ffn.f1_in
        b += rows * ff; // gelu input
        b += sr * ff; // ffn.f2_in
        b += rows * d + rows; // ln2 xhat + rstd
        b * F32
    }

    /// All residual bytes staged between fwd and bwd (matches the
    /// ActivationStore measurement for the same config).
    pub fn residual_bytes(&self) -> usize {
        let g = &self.geom;
        let rows = g.rows();
        let emb = (rows * g.d_model + rows) * F32; // emb.ln xhat + rstd
        let heads =
            (2 * g.batch_size * g.d_model + g.batch_size * g.n_classes) * F32;
        emb + self.geom.n_layers * self.block_residual_bytes() + heads
    }

    /// Bytes for parameters / gradients (one copy each).
    pub fn param_bytes(&self) -> usize {
        self.geom.param_count() * F32
    }

    /// Optimizer state (Adam: m and v).
    pub fn optimizer_bytes(&self) -> usize {
        2 * self.param_bytes()
    }

    /// Whole-step footprint: weights + grads + Adam state + residuals.
    pub fn total_bytes(&self) -> usize {
        2 * self.param_bytes() + self.optimizer_bytes() + self.residual_bytes()
    }

    /// Percent of whole-step memory saved vs the ρ=1 baseline (Table 3's
    /// SAVING column).
    pub fn saving_vs_baseline(&self) -> f64 {
        let base = MemoryModel::new(self.geom, 1.0).total_bytes() as f64;
        100.0 * (1.0 - self.total_bytes() as f64 / base)
    }

    /// Residual-only saving (the direct Algorithm 1 effect).
    pub fn residual_saving(&self) -> f64 {
        let base = MemoryModel::new(self.geom, 1.0).residual_bytes() as f64;
        100.0 * (1.0 - self.residual_bytes() as f64 / base)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ModelGeometry {
        ModelGeometry {
            vocab_size: 256,
            seq_len: 32,
            batch_size: 16,
            d_model: 64,
            n_heads: 4,
            n_layers: 2,
            d_ff: 256,
            n_classes: 2,
        }
    }

    #[test]
    fn baseline_rho_one_stores_full_rows() {
        let m = MemoryModel::new(small(), 1.0);
        assert_eq!(m.b_proj(), 512);
        assert_eq!(m.saving_vs_baseline(), 0.0);
    }

    #[test]
    fn saving_monotone_in_rho() {
        let mut last = -1.0;
        for rho in [0.9, 0.5, 0.2, 0.1, 0.05] {
            let s = MemoryModel::new(small(), rho).saving_vs_baseline();
            assert!(s > last, "rho={rho}: {s} <= {last}");
            last = s;
        }
    }

    #[test]
    fn residual_bytes_scale_linearly_in_batch() {
        // Fig 3's claim: near-linear growth in B with slope shrinking with ρ.
        let b1 = MemoryModel::new(ModelGeometry { batch_size: 32, ..small() }, 0.2);
        let b2 = MemoryModel::new(ModelGeometry { batch_size: 64, ..small() }, 0.2);
        let r = b2.residual_bytes() as f64 / b1.residual_bytes() as f64;
        assert!((r - 2.0).abs() < 0.05, "ratio {r}");
    }

    #[test]
    fn whole_process_saving_in_plausible_band() {
        // Paper §3.2: 5-10x compression cuts total runtime memory ~10-25%.
        // At our small scale, other activations (attention probs, GELU
        // inputs, LN caches) plus Adam state dominate similarly.
        let m = MemoryModel::new(small(), 0.1);
        let s = m.saving_vs_baseline();
        assert!(s > 3.0 && s < 40.0, "saving {s}%");
    }

    #[test]
    fn roberta_extrapolation_matches_paper_order() {
        // RoBERTa-base, B=128, T=128 (MRPC-ish): residual saving should be
        // substantial at rho=0.1, whole-step saving in the tens of percent.
        let g = ModelGeometry::roberta_base(128, 128);
        let m = MemoryModel::new(g, 0.1);
        assert!(
            m.geom.param_count() > 80_000_000 && m.geom.param_count() < 140_000_000
        );
        let s = m.saving_vs_baseline();
        assert!(s > 5.0 && s < 60.0, "saving {s}%");
    }

    #[test]
    fn b_proj_clamps() {
        let m = MemoryModel::new(small(), 0.000001);
        assert_eq!(m.b_proj(), 1);
        let m = MemoryModel::new(small(), 2.0);
        assert_eq!(m.b_proj(), 512);
    }
}
