//! Memory substrate: the activation store that holds residual buffers
//! between the `fwd` and `bwd` executions (where the paper's saving is
//! *measured*), plus the analytic whole-process memory model used to
//! extrapolate Table 3 / Fig. 3 to paper-scale geometry.

mod accounting;
mod store;

pub use accounting::{MemoryModel, ModelGeometry};
pub use store::{ActivationStore, StoreStats};
