//! Host matmul entry points, dispatching to the selected kernel backend
//! (`tensor::kernels`): `Packed` (cache-blocked, register-tiled, multi-
//! threaded) by default, `Scalar` (the seed reference loop) on request.
//!
//! Every host hot path — the pure-Rust RMM reference, the Table 4 cost
//! model, the FFT crossover study and the micro benches — goes through
//! these three functions, so backend selection changes *all* reported
//! host-baseline numbers coherently.

use super::kernels;
use super::Tensor;

/// C = A · B.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.cols, b.rows, "matmul inner-dim mismatch");
    kernels::active().matmul(a, b)
}

/// C = Aᵀ · B  (A: (k, m), B: (k, n) -> C: (m, n)) without materializing Aᵀ.
pub fn matmul_at(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rows, b.rows, "matmul_at row mismatch");
    kernels::active().matmul_at(a, b)
}

/// C = A · Bᵀ  (A: (m, k), B: (n, k) -> C: (m, n)) without materializing Bᵀ.
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.cols, b.cols, "matmul_bt col mismatch");
    kernels::active().matmul_bt(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::philox::PhiloxStream;

    fn randt(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut s = PhiloxStream::new(seed, 3);
        Tensor::from_fn(rows, cols, |_, _| s.next_normal())
    }

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let mut c = Tensor::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0;
                for k in 0..a.cols {
                    acc += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = acc;
            }
        }
        c
    }

    #[test]
    fn blocked_matches_naive() {
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (70, 65, 130), (128, 64, 64)] {
            let a = randt(m, k, 1);
            let b = randt(k, n, 2);
            let c = matmul(&a, &b);
            assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-3, "({m},{k},{n})");
        }
    }

    #[test]
    fn at_variant_matches_transpose() {
        let a = randt(40, 17, 3);
        let b = randt(40, 23, 4);
        let c1 = matmul_at(&a, &b);
        let c2 = matmul(&a.transpose(), &b);
        assert!(c1.max_abs_diff(&c2) < 1e-4);
    }

    #[test]
    fn bt_variant_matches_transpose() {
        let a = randt(19, 31, 5);
        let b = randt(27, 31, 6);
        let c1 = matmul_bt(&a, &b);
        let c2 = matmul(&a, &b.transpose());
        assert!(c1.max_abs_diff(&c2) < 1e-4);
    }

    #[test]
    #[should_panic]
    fn mismatch_panics() {
        matmul(&Tensor::zeros(2, 3), &Tensor::zeros(4, 2));
    }

    #[test]
    fn both_backends_match_naive_directly() {
        use crate::tensor::kernels::{Backend, PACKED, SCALAR};
        let a = randt(33, 47, 7);
        let b = randt(47, 21, 8);
        let want = naive(&a, &b);
        assert!(SCALAR.matmul(&a, &b).max_abs_diff(&want) < 1e-3);
        assert!(PACKED.matmul(&a, &b).max_abs_diff(&want) < 1e-3);
    }
}
