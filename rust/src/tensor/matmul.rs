//! Blocked matmul kernels for the host tensor type.
//!
//! Used by the pure-Rust RMM reference and the criterion-style micro
//! benches (Table 4's cost model, the FFT crossover study).  Single-core
//! cache-blocked f32 with a k-innermost microkernel; fast enough that the
//! Rust-side baseline is a fair comparator for the sketch algebra.

use super::Tensor;

const BLOCK: usize = 64;

/// C = A · B.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.cols, b.rows, "matmul inner-dim mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Tensor::zeros(m, n);
    // i-k-j loop order with blocking: B rows stream through cache, C rows
    // accumulate in registers/L1.
    for i0 in (0..m).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(m);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in i0..i1 {
                let arow = &a.data[i * k..(i + 1) * k];
                let crow = &mut c.data[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b.data[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
    }
    c
}

/// C = Aᵀ · B  (A: (k, m), B: (k, n) -> C: (m, n)) without materializing Aᵀ.
pub fn matmul_at(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rows, b.rows, "matmul_at row mismatch");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Tensor::zeros(m, n);
    for kk in 0..k {
        let arow = &a.data[kk * m..(kk + 1) * m];
        let brow = &b.data[kk * n..(kk + 1) * n];
        for i in 0..m {
            let aik = arow[i];
            if aik == 0.0 {
                continue;
            }
            let crow = &mut c.data[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
    c
}

/// C = A · Bᵀ  (A: (m, k), B: (n, k) -> C: (m, n)) without materializing Bᵀ.
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.cols, b.cols, "matmul_bt col mismatch");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Tensor::zeros(m, n);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut c.data[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b.data[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            crow[j] = acc;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::philox::PhiloxStream;

    fn randt(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut s = PhiloxStream::new(seed, 3);
        Tensor::from_fn(rows, cols, |_, _| s.next_normal())
    }

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let mut c = Tensor::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0;
                for k in 0..a.cols {
                    acc += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = acc;
            }
        }
        c
    }

    #[test]
    fn blocked_matches_naive() {
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (70, 65, 130), (128, 64, 64)] {
            let a = randt(m, k, 1);
            let b = randt(k, n, 2);
            let c = matmul(&a, &b);
            assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-3, "({m},{k},{n})");
        }
    }

    #[test]
    fn at_variant_matches_transpose() {
        let a = randt(40, 17, 3);
        let b = randt(40, 23, 4);
        let c1 = matmul_at(&a, &b);
        let c2 = matmul(&a.transpose(), &b);
        assert!(c1.max_abs_diff(&c2) < 1e-4);
    }

    #[test]
    fn bt_variant_matches_transpose() {
        let a = randt(19, 31, 5);
        let b = randt(27, 31, 6);
        let c1 = matmul_bt(&a, &b);
        let c2 = matmul(&a, &b.transpose());
        assert!(c1.max_abs_diff(&c2) < 1e-4);
    }

    #[test]
    #[should_panic]
    fn mismatch_panics() {
        matmul(&Tensor::zeros(2, 3), &Tensor::zeros(4, 2));
    }
}
