//! Elementwise / reduction helpers used by optimizers and metrics.

use super::Tensor;

/// Frobenius inner product ⟨A, B⟩_F.
pub fn dot(a: &Tensor, b: &Tensor) -> f64 {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    a.data
        .iter()
        .zip(&b.data)
        .map(|(&x, &y)| x as f64 * y as f64)
        .sum()
}

/// Global L2 norm over a list of tensors (for gradient clipping).
pub fn global_norm(ts: &[&Tensor]) -> f64 {
    ts.iter().map(|t| t.fro2()).sum::<f64>().sqrt()
}

/// In-place a += s * b (axpy).
pub fn axpy(a: &mut Tensor, s: f32, b: &Tensor) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += s * y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_norms() {
        let a = Tensor::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(1, 3, vec![4.0, 5.0, 6.0]);
        assert!((dot(&a, &b) - 32.0).abs() < 1e-9);
        assert!((global_norm(&[&a, &b]) - (14.0f64 + 77.0).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn axpy_works() {
        let mut a = Tensor::from_vec(1, 2, vec![1.0, 1.0]);
        let b = Tensor::from_vec(1, 2, vec![2.0, 4.0]);
        axpy(&mut a, 0.5, &b);
        assert_eq!(a.data, vec![2.0, 3.0]);
    }
}
