//! Host tensor substrate: row-major f32 matrices/vectors for everything the
//! coordinator does outside XLA — optimizer math, the pure-Rust RMM
//! reference, metric computation, and literal staging.
//!
//! Deliberately minimal: the heavy lifting runs inside compiled HLO; this
//! exists so the hot host paths (optimizer update, variance estimators,
//! bench baselines) are allocation-disciplined and dependency-free.

pub mod kernels;
mod matmul;
pub mod ops;
pub mod pool;

pub use matmul::{matmul, matmul_at, matmul_bt};
pub use ops::{axpy, dot, global_norm};

use std::fmt;

/// Dense row-major f32 matrix (rows × cols).  A vector is `rows == 1`.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor[{}x{}]", self.rows, self.cols)
    }
}

impl Tensor {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn transpose(&self) -> Tensor {
        let mut out = Tensor::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Squared Frobenius norm ‖A‖²_F.
    pub fn fro2(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Squared L2 norm of row i.
    pub fn row_norm2(&self, i: usize) -> f64 {
        self.row(i).iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Tensor::from_vec(
            self.rows,
            self.cols,
            self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        )
    }

    /// Max |a - b| over elements — for test assertions.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_accessors() {
        let t = Tensor::from_fn(2, 3, |i, j| (i * 10 + j) as f32);
        assert_eq!(t.at(1, 2), 12.0);
        assert_eq!(t.row(1), &[10.0, 11.0, 12.0]);
    }

    #[test]
    fn transpose_involution() {
        let t = Tensor::from_fn(3, 5, |i, j| (i * 7 + j * 3) as f32);
        assert_eq!(t.transpose().transpose(), t);
    }

    #[test]
    fn fro2_matches_manual() {
        let t = Tensor::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert!((t.fro2() - 30.0).abs() < 1e-9);
        assert!((t.row_norm2(1) - 25.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_vec(2, 2, vec![1.0; 3]);
    }
}
