//! Per-participant task queues with work stealing.
//!
//! A [`TaskQueues`] is built once per pool run: the task indices
//! `0..tasks` are dealt out as contiguous runs, one queue per
//! participant.  Each participant pops from the *front* of its home
//! queue (so it walks its own tasks in ascending order, cache-friendly
//! for adjacent cache blocks) and, when the home queue is empty, steals
//! from the *back* of the other queues (so a thief takes the work
//! farthest from the victim's current position).
//!
//! Queues are `Mutex<VecDeque>` rather than lock-free Chase-Lev deques:
//! pool tasks are cache-block sized (microseconds to milliseconds), so a
//! sub-100ns uncontended lock per claim is noise, and the Mutex version
//! is trivially correct under the no-external-crates constraint.
//! Which thread executes which task never affects results — every task
//! owns a disjoint output region — so stealing is a pure load-balance
//! mechanism.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

pub struct TaskQueues {
    queues: Vec<Mutex<VecDeque<usize>>>,
    steals: AtomicU64,
}

impl TaskQueues {
    /// Deal `tasks` indices into `nq` queues as contiguous runs
    /// (queue q gets `[q*per .. )` with the remainder spread over the
    /// first queues, mirroring the old row-band split).
    pub fn split(tasks: usize, nq: usize) -> TaskQueues {
        let nq = nq.max(1).min(tasks.max(1));
        let base = tasks / nq;
        let extra = tasks % nq;
        let mut queues = Vec::with_capacity(nq);
        let mut next = 0usize;
        for q in 0..nq {
            let len = base + usize::from(q < extra);
            queues.push(Mutex::new((next..next + len).collect()));
            next += len;
        }
        debug_assert_eq!(next, tasks);
        TaskQueues { queues, steals: AtomicU64::new(0) }
    }

    pub fn len(&self) -> usize {
        self.queues.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queues.is_empty()
    }

    /// Claim the next task for participant `home`: own queue front first,
    /// then steal from the back of the others (scanning forward from
    /// `home + 1` so thieves spread over victims).  `None` means every
    /// queue is empty — in-flight tasks may still be executing.
    pub fn next(&self, home: usize) -> Option<usize> {
        let nq = self.queues.len();
        debug_assert!(home < nq);
        if let Some(t) = self.queues[home].lock().unwrap().pop_front() {
            return Some(t);
        }
        for d in 1..nq {
            let victim = (home + d) % nq;
            if let Some(t) = self.queues[victim].lock().unwrap().pop_back() {
                self.steals.fetch_add(1, Ordering::Relaxed);
                return Some(t);
            }
        }
        None
    }

    /// Successful steals so far (monotone; read after the run completes).
    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_covers_every_task_once() {
        for tasks in [0usize, 1, 2, 5, 7, 16, 33] {
            for nq in [1usize, 2, 3, 8] {
                let q = TaskQueues::split(tasks, nq);
                let mut seen = vec![false; tasks];
                for home in 0..q.len() {
                    while let Some(t) = {
                        let got = q.queues[home].lock().unwrap().pop_front();
                        got
                    } {
                        assert!(!seen[t], "task {t} dealt twice");
                        seen[t] = true;
                    }
                }
                assert!(seen.iter().all(|&s| s), "tasks={tasks} nq={nq}");
            }
        }
    }

    #[test]
    fn next_drains_all_tasks_and_counts_steals() {
        let q = TaskQueues::split(10, 3);
        // participant 0 drains everything: its own queue plus steals.
        let mut got = Vec::new();
        while let Some(t) = q.next(0) {
            got.push(t);
        }
        got.sort_unstable();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
        assert!(q.steals() > 0, "draining foreign queues must count as steals");
    }

    #[test]
    fn more_queues_than_tasks_collapses() {
        let q = TaskQueues::split(2, 8);
        assert_eq!(q.len(), 2);
        let q = TaskQueues::split(0, 4);
        assert_eq!(q.len(), 1);
        assert_eq!(q.next(0), None);
    }
}
