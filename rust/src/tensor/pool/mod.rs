//! Persistent work-stealing compute pool shared by every parallel host
//! kernel: the packed GEMM driver, the fused streamed sketch projection,
//! and the batched SORS FFT.
//!
//! # Why a pool
//!
//! The PR-1 kernels spawned scoped `std::thread`s per call
//! (`par_row_bands`), which is fine for one 512³ GEMM but charges a full
//! spawn/join round-trip to every small matmul in the optimizer path and
//! to every k-block of a blocked GEMM.  Here the workers are spawned
//! **once** (lazily, on first parallel run), parked on a condvar between
//! runs, and handed cache-block tasks through per-participant deques with
//! work stealing ([`queue::TaskQueues`]) — dispatching a run costs one
//! mutex store plus a wakeup instead of N thread spawns.
//!
//! # Determinism guarantee
//!
//! Every task **owns a disjoint region of the output buffer**, and the
//! accumulation order *within* each output element is fixed by the kernel
//! (ascending k-block, ascending k for GEMM; ascending input row for the
//! fused projection; the serial FFT butterfly order per column panel for
//! batched SORS).  Work stealing only changes *which thread* runs a task,
//! never what the task computes, so results are **bit-identical for any
//! `RMM_THREADS` value and any task grain** — including the fully serial
//! inline path.  `rust/tests/prop_pool.rs` and the dual-thread-count CI
//! run (`scripts/ci.sh`) enforce this.
//!
//! # Knobs (precedence: config/CLI override > `RMM_*` env > derived)
//!
//! * **Thread count** — `ExperimentConfig.pool.threads` / `--threads`
//!   install a process override via
//!   [`threads::set_threads_override`](crate::tensor::kernels::threads);
//!   otherwise `RMM_THREADS` is read **per run** (the PR-1 `OnceLock`
//!   cache made later env changes silently invisible), falling back to
//!   the machine parallelism.  Values above the worker count are clamped;
//!   `1` runs inline on the caller with zero pool traffic.
//! * **Task grain** — `ExperimentConfig.pool.grain_rows` /
//!   `--pool-grain` via [`set_grain_override`], else `RMM_POOL_GRAIN`,
//!   else derived as ~`rows / (4 · threads)` so each participant sees ~4
//!   stealable tasks ([`task_grain`]).  Grain affects load balance only,
//!   never results.
//!
//! Counters for runs/tasks/steals are process-global ([`stats`]) and are
//! surfaced by `rmm_micro --json` next to the GFLOP/s rows and by the
//! bench harness runner.

pub mod queue;

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use queue::TaskQueues;

/// Stealable tasks targeted per participant when deriving a grain.
const OVERSUBSCRIBE: usize = 4;

// ---------------------------------------------------------------------------
// Process-global instrumentation
// ---------------------------------------------------------------------------

static RUNS: AtomicU64 = AtomicU64::new(0);
static PAR_RUNS: AtomicU64 = AtomicU64::new(0);
static TASKS: AtomicU64 = AtomicU64::new(0);
static STEALS: AtomicU64 = AtomicU64::new(0);

/// Monotone counters since process start; read twice and subtract to
/// attribute pool traffic to a region of interest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// `run` invocations (including inline/serial ones).
    pub runs: u64,
    /// Runs that actually fanned out to workers.
    pub par_runs: u64,
    /// Tasks executed.
    pub tasks: u64,
    /// Tasks claimed from a queue other than the claimant's home queue.
    pub steals: u64,
}

impl PoolStats {
    pub fn delta_since(self, earlier: PoolStats) -> PoolStats {
        PoolStats {
            runs: self.runs - earlier.runs,
            par_runs: self.par_runs - earlier.par_runs,
            tasks: self.tasks - earlier.tasks,
            steals: self.steals - earlier.steals,
        }
    }
}

pub fn stats() -> PoolStats {
    PoolStats {
        runs: RUNS.load(Ordering::Relaxed),
        par_runs: PAR_RUNS.load(Ordering::Relaxed),
        tasks: TASKS.load(Ordering::Relaxed),
        steals: STEALS.load(Ordering::Relaxed),
    }
}

// ---------------------------------------------------------------------------
// Task grain policy
// ---------------------------------------------------------------------------

static GRAIN_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Install a process-global task-grain override in rows (config / CLI
/// layer).  `0` clears it, restoring `RMM_POOL_GRAIN`-or-derived.
pub fn set_grain_override(rows: usize) {
    GRAIN_OVERRIDE.store(rows, Ordering::Relaxed);
}

fn grain_override() -> usize {
    let o = GRAIN_OVERRIDE.load(Ordering::Relaxed);
    if o > 0 {
        return o;
    }
    // Strict like RMM_EXE_CACHE_CAP / RMM_SIMD: an operator who *set*
    // the grain must not silently run with the derived one on a typo.
    // Grain is read deep inside kernels (no Result channel), so a
    // malformed value panics with the canonical knob message.
    match crate::util::env::var_positive_usize("RMM_POOL_GRAIN") {
        Ok(Some(n)) => n,
        Ok(None) => 0,
        Err(e) => panic!("{e}"),
    }
}

/// Rows per task for a kernel splitting `rows` across `nt` participants:
/// the override if set, else `rows / (4·nt)` — rounded up to `align`
/// (microtile height for GEMM, S-tile height for the projection) and
/// clamped to `[align, max_rows]`.  Purely a load-balance choice; see the
/// module doc for why it cannot affect results.
pub fn task_grain(rows: usize, nt: usize, align: usize, max_rows: usize) -> usize {
    let align = align.max(1);
    let max_rows = max_rows.max(align);
    let o = grain_override();
    let target = if o > 0 {
        o
    } else {
        (rows / (nt.max(1) * OVERSUBSCRIBE)).max(1)
    };
    let rounded = (target + align - 1) / align * align;
    rounded.clamp(align, max_rows)
}

// ---------------------------------------------------------------------------
// Disjoint-write pointer wrapper
// ---------------------------------------------------------------------------

/// A raw pointer that kernels share across pool tasks to write disjoint
/// regions of one output buffer (row blocks of C, column panels of
/// X_proj).  The wrapper only makes the pointer `Send + Sync`; every
/// dereference stays `unsafe` and every call site must guarantee that no
/// two concurrent tasks touch the same element.
pub struct SharedMut<T>(*mut T);

impl<T> SharedMut<T> {
    pub fn new(p: *mut T) -> Self {
        SharedMut(p)
    }

    pub fn ptr(&self) -> *mut T {
        self.0
    }
}

impl<T> Clone for SharedMut<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SharedMut<T> {}
// SAFETY: the wrapper adds no aliasing rules of its own; call sites
// partition the pointee so concurrent tasks never alias an element.
unsafe impl<T> Send for SharedMut<T> {}
unsafe impl<T> Sync for SharedMut<T> {}

// ---------------------------------------------------------------------------
// The pool
// ---------------------------------------------------------------------------

struct Slot {
    epoch: u64,
    job: Option<Arc<Job>>,
}

struct Shared {
    slot: Mutex<Slot>,
    work_cv: Condvar,
}

/// One parallel run: a lifetime-erased task closure plus the queues and
/// completion bookkeeping.  Workers hold it through an `Arc`; the closure
/// pointer is only dereferenced for claimed tasks, and all claims finish
/// before `Pool::run` returns, so the borrow never escapes the call.
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize),
    queues: TaskQueues,
    joined: AtomicUsize,
    remaining: AtomicUsize,
    panicked: AtomicBool,
    done_m: Mutex<bool>,
    done_cv: Condvar,
}

// SAFETY: `data` points at an `F: Fn(usize) + Sync` owned by the caller
// of `Pool::run`, which blocks until `remaining == 0`; every dereference
// happens between claim and that completion signal.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// Claim-and-execute loop for one participant.
    fn work(&self, home: usize) {
        while let Some(t) = self.queues.next(home) {
            let ok = catch_unwind(AssertUnwindSafe(|| unsafe { (self.call)(self.data, t) }));
            if ok.is_err() {
                self.panicked.store(true, Ordering::Relaxed);
            }
            if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                let mut d = self.done_m.lock().unwrap();
                *d = true;
                self.done_cv.notify_all();
            }
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let mut seen = 0u64;
    loop {
        let job: Arc<Job> = {
            let mut s = shared.slot.lock().unwrap();
            loop {
                if s.epoch != seen {
                    seen = s.epoch;
                    if let Some(j) = s.job.clone() {
                        break j;
                    }
                }
                s = shared.work_cv.wait(s).unwrap();
            }
        };
        // Claim a home queue; latecomers to an already-saturated (or
        // finished) job simply go back to sleep.
        let home = job.joined.fetch_add(1, Ordering::Relaxed);
        if home < job.queues.len() {
            job.work(home);
        }
    }
}

pub struct Pool {
    shared: Arc<Shared>,
    n_workers: usize,
}

impl Pool {
    fn spawn() -> Pool {
        let want =
            crate::tensor::kernels::threads::machine_parallelism().saturating_sub(1);
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot { epoch: 0, job: None }),
            work_cv: Condvar::new(),
        });
        let mut n_workers = 0;
        for i in 0..want {
            let sh = Arc::clone(&shared);
            let ok = std::thread::Builder::new()
                .name(format!("rmm-pool-{i}"))
                .spawn(move || worker_loop(sh))
                .is_ok();
            if ok {
                n_workers += 1;
            }
        }
        Pool { shared, n_workers }
    }

    /// Worker threads backing this pool (the caller participates too, so
    /// peak parallelism is `workers() + 1`).
    pub fn workers(&self) -> usize {
        self.n_workers
    }

    /// Execute `f(0), f(1), …, f(tasks - 1)` exactly once each across at
    /// most `nt` participants (the caller plus woken workers), returning
    /// when all tasks have finished.
    ///
    /// With `nt <= 1`, no workers, or a single task, every task runs
    /// inline on the caller in ascending order — the serial reference
    /// path.  Tasks must write disjoint data (see [`SharedMut`]); under
    /// that contract the result is independent of `nt`, the grain, and
    /// which participant ran which task.
    ///
    /// A panic inside a task is caught on the worker (keeping the pool
    /// alive), the run completes, and the panic is re-raised here.
    pub fn run<F>(&self, nt: usize, tasks: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if tasks == 0 {
            return;
        }
        RUNS.fetch_add(1, Ordering::Relaxed);
        TASKS.fetch_add(tasks as u64, Ordering::Relaxed);
        let nt = nt.max(1).min(tasks).min(self.n_workers + 1);
        if nt <= 1 {
            for i in 0..tasks {
                f(i);
            }
            return;
        }
        PAR_RUNS.fetch_add(1, Ordering::Relaxed);

        unsafe fn shim<F: Fn(usize) + Sync>(p: *const (), i: usize) {
            (*(p as *const F))(i);
        }
        let job = Arc::new(Job {
            data: &f as *const F as *const (),
            call: shim::<F>,
            queues: TaskQueues::split(tasks, nt),
            joined: AtomicUsize::new(1), // caller is participant 0
            remaining: AtomicUsize::new(tasks),
            panicked: AtomicBool::new(false),
            done_m: Mutex::new(false),
            done_cv: Condvar::new(),
        });
        let installed_epoch = {
            let mut s = self.shared.slot.lock().unwrap();
            s.epoch += 1;
            s.job = Some(Arc::clone(&job));
            s.epoch
        };
        self.shared.work_cv.notify_all();

        // The caller is participant 0: drain its queue, steal, then wait
        // for in-flight tasks on other participants.
        job.work(0);
        {
            let mut d = job.done_m.lock().unwrap();
            while !*d {
                d = job.done_cv.wait(d).unwrap();
            }
        }
        {
            let mut s = self.shared.slot.lock().unwrap();
            if s.epoch == installed_epoch {
                s.job = None;
            }
        }
        STEALS.fetch_add(job.queues.steals(), Ordering::Relaxed);
        if job.panicked.load(Ordering::Relaxed) {
            panic!("pool task panicked (original panic reported above)");
        }
    }
}

/// The process-wide pool, spawned on first use and parked between runs.
pub fn global() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(Pool::spawn)
}

/// Serializes tests that mutate or assert on the process-global knobs
/// (grain/thread overrides) so they stay stable under the parallel test
/// runner.  Production code never takes this lock; knob *values* cannot
/// affect results either way — this only quiets assertions about
/// specific settings.
#[doc(hidden)]
pub fn knob_test_lock() -> std::sync::MutexGuard<'static, ()> {
    static L: Mutex<()> = Mutex::new(());
    L.lock().unwrap_or_else(|e| e.into_inner())
}

/// Split `rows` (each `ld` floats) into `grain`-row blocks and run
/// `f(first_row, block_rows, block_slice)` for each as pool tasks, where
/// `block_slice` is the disjoint `&mut` sub-slice of `data` covering the
/// block.  The pool-backed successor of PR-1's `par_row_bands`: same
/// disjoint-rows contract, but block-grained and stealable instead of one
/// fat band per thread.
pub fn par_row_blocks<F>(nt: usize, rows: usize, ld: usize, grain: usize, data: &mut [f32], f: &F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(data.len(), rows * ld);
    if rows == 0 || ld == 0 || nt <= 1 || rows <= grain {
        f(0, rows, data);
        return;
    }
    let grain = grain.max(1);
    let tasks = (rows + grain - 1) / grain;
    let base = SharedMut::new(data.as_mut_ptr());
    global().run(nt, tasks, |t| {
        let r0 = t * grain;
        let nr = grain.min(rows - r0);
        // SAFETY: blocks [r0, r0 + nr) are disjoint across tasks and in
        // bounds; `base` outlives the run (we block until completion).
        let block = unsafe { std::slice::from_raw_parts_mut(base.ptr().add(r0 * ld), nr * ld) };
        f(r0, nr, block);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn run_executes_every_task_exactly_once() {
        for &tasks in &[1usize, 2, 3, 17, 64, 257] {
            for &nt in &[1usize, 2, 3, 8] {
                let hits: Vec<AtomicU32> = (0..tasks).map(|_| AtomicU32::new(0)).collect();
                global().run(nt, tasks, |i| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::Relaxed), 1, "tasks={tasks} nt={nt} i={i}");
                }
            }
        }
    }

    #[test]
    fn zero_tasks_is_a_noop() {
        global().run(4, 0, |_| panic!("must not run"));
    }

    #[test]
    fn stats_counters_advance() {
        // other tests in this binary pump the global counters
        // concurrently, so assert monotone growth, not exact deltas
        let before = stats();
        global().run(2, 8, |_| {});
        let d = stats().delta_since(before);
        assert!(d.runs >= 1, "{d:?}");
        assert!(d.tasks >= 8, "{d:?}");
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let r = catch_unwind(AssertUnwindSafe(|| {
            global().run(2, 4, |i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic must propagate to the caller");
        // pool still works afterwards
        let n = AtomicU32::new(0);
        global().run(2, 16, |_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn par_row_blocks_covers_rows_like_the_old_bands() {
        for rows in [0usize, 1, 2, 3, 7, 16, 17, 130] {
            for nt in [1usize, 2, 3, 8] {
                let ld = 3;
                let mut data = vec![0.0f32; rows * ld];
                par_row_blocks(nt, rows, ld, 4, &mut data, &|r0, nr, block| {
                    assert_eq!(block.len(), nr * ld);
                    for (i, v) in block.iter_mut().enumerate() {
                        *v += (r0 * ld + i) as f32 + 1.0;
                    }
                });
                for (i, v) in data.iter().enumerate() {
                    assert_eq!(*v, i as f32 + 1.0, "rows={rows} nt={nt} i={i}");
                }
            }
        }
    }

    #[test]
    fn task_grain_respects_align_clamp_and_override() {
        let _g = knob_test_lock();
        // derived: 512 rows / (4 participants * 4) = 32, already aligned
        assert_eq!(task_grain(512, 4, 8, 128), 32);
        // rounding up to align
        assert_eq!(task_grain(100, 4, 8, 128), 8);
        // clamped to max
        assert_eq!(task_grain(10_000, 1, 8, 128), 128);
        // clamped to align from below
        assert_eq!(task_grain(1, 16, 8, 128), 8);
        // override wins and is aligned
        set_grain_override(20);
        assert_eq!(task_grain(512, 4, 8, 128), 24);
        set_grain_override(0);
        assert_eq!(task_grain(512, 4, 8, 128), 32);
    }

    #[test]
    fn nested_runs_complete() {
        // a task issuing its own pool run must not deadlock: the inner
        // caller drains inline/steals, never waiting on a parked worker.
        let n = AtomicU32::new(0);
        global().run(2, 4, |_| {
            global().run(2, 4, |_| {
                n.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(n.load(Ordering::Relaxed), 16);
    }
}
