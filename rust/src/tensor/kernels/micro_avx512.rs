//! AVX-512F 16-wide microkernel: the 8×8 tile as four zmm accumulators,
//! each covering one contiguous *row pair* of the tile.
//!
//! A naive 16-wide kernel would need NR = 16 panels (breaking the shared
//! NR = 8 pack layout) or k-vectorization (breaking ascending-k order).
//! Instead each zmm holds rows (2p, 2p+1) side by side; per k step the
//! NR-wide B row is duplicated into both halves and the two A elements
//! of the pair are broadcast into their respective halves, so one
//! mul+add advances two rows at once.  Per C element that is still
//! exactly one IEEE multiply then one IEEE add per ascending k — no FMA
//! intrinsic anywhere, and LLVM does not contract separate mul/add
//! without fast-math — so output stays bit-identical to the portable
//! tile and every other dispatch level.
//!
//! Only AVX-512F intrinsics are used (`permutexvar` rather than the
//! AVX-512DQ `insertf32x8`/`broadcast_f32x8`), so the F probe alone
//! gates this kernel.

use super::micro::{MR, NR};

#[cfg(target_arch = "x86")]
use std::arch::x86::*;
#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Safe entry with the shared [`super::dispatch::MicroKernel`] shape.
/// Callers reach this only through dispatch, which verified AVX-512F at
/// probe/override time — that check is what makes the wrap sound.
pub fn kernel(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
    // SAFETY: AVX-512F availability was established by dispatch (probe
    // or validated override); the panel bounds were asserted above.
    unsafe { kernel_avx512(kc, ap.as_ptr(), bp.as_ptr(), acc) }
}

#[target_feature(enable = "avx512f")]
unsafe fn kernel_avx512(
    kc: usize,
    ap: *const f32,
    bp: *const f32,
    acc: &mut [[f32; NR]; MR],
) {
    debug_assert!(MR == 8 && NR == 8);
    // Lane index vectors (highest lane first in set_epi32): `bidx` maps a
    // 256-bit B row into both zmm halves; `aidx[p]` broadcasts packed A
    // elements 2p / 2p+1 into the halves owning rows 2p / 2p+1.
    let bidx = _mm512_set_epi32(7, 6, 5, 4, 3, 2, 1, 0, 7, 6, 5, 4, 3, 2, 1, 0);
    let aidx = [
        _mm512_set_epi32(1, 1, 1, 1, 1, 1, 1, 1, 0, 0, 0, 0, 0, 0, 0, 0),
        _mm512_set_epi32(3, 3, 3, 3, 3, 3, 3, 3, 2, 2, 2, 2, 2, 2, 2, 2),
        _mm512_set_epi32(5, 5, 5, 5, 5, 5, 5, 5, 4, 4, 4, 4, 4, 4, 4, 4),
        _mm512_set_epi32(7, 7, 7, 7, 7, 7, 7, 7, 6, 6, 6, 6, 6, 6, 6, 6),
    ];
    // The tile is a contiguous [[f32; 8]; 8]: row pair p is 16 floats at
    // offset 16·p.  Go through the whole-array pointer (not a row borrow)
    // so the 16-float access stays inside one allocation's provenance.
    let cp = acc.as_mut_ptr() as *mut f32;
    let mut pairs = [
        _mm512_loadu_ps(cp),
        _mm512_loadu_ps(cp.add(16)),
        _mm512_loadu_ps(cp.add(32)),
        _mm512_loadu_ps(cp.add(48)),
    ];
    for k in 0..kc {
        // Upper 256 bits after the cast are undefined, but every permute
        // index is < 8, so only the defined lower lanes are ever read.
        let b = _mm512_castps256_ps512(_mm256_loadu_ps(bp.add(k * NR)));
        let bv = _mm512_permutexvar_ps(bidx, b);
        let a = _mm512_castps256_ps512(_mm256_loadu_ps(ap.add(k * MR)));
        for (p, pair) in pairs.iter_mut().enumerate() {
            let av = _mm512_permutexvar_ps(aidx[p], a);
            // Unfused on purpose: mul then add, matching the portable
            // tile's per-element f32 sequence bit-for-bit.
            *pair = _mm512_add_ps(*pair, _mm512_mul_ps(av, bv));
        }
    }
    _mm512_storeu_ps(cp, pairs[0]);
    _mm512_storeu_ps(cp.add(16), pairs[1]);
    _mm512_storeu_ps(cp.add(32), pairs[2]);
    _mm512_storeu_ps(cp.add(48), pairs[3]);
}

#[cfg(test)]
mod tests {
    use super::super::{dispatch::SimdLevel, micro};
    use super::*;

    #[test]
    fn matches_portable_bitwise_when_supported() {
        if !SimdLevel::Avx512.supported() {
            eprintln!("skipping: AVX-512F unavailable on this CPU");
            return;
        }
        let kc = 23;
        let ap: Vec<f32> = (0..kc * MR).map(|i| (i as f32 * 0.7).sin()).collect();
        let bp: Vec<f32> = (0..kc * NR).map(|i| (i as f32 * 1.3).cos()).collect();
        let mut want = [[0.5f32; NR]; MR];
        micro::kernel(kc, &ap, &bp, &mut want);
        let mut got = [[0.5f32; NR]; MR];
        kernel(kc, &ap, &bp, &mut got);
        for r in 0..MR {
            assert_eq!(got[r].map(f32::to_bits), want[r].map(f32::to_bits), "row {r}");
        }
    }
}
