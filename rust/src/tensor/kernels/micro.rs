//! The register-tiled inner kernel: an MR×NR accumulator tile updated by
//! rank-1 products streamed from packed A/B panels.
//!
//! Written so LLVM autovectorizes without intrinsics: the k-loop walks both
//! panels with `chunks_exact`, every inner loop has a compile-time trip
//! count (MR/NR), and the tile is a local `[[f32; NR]; MR]` that SROA
//! promotes to vector registers once the kernel inlines into the blocked
//! driver.  With f32 and 256-bit SIMD the 8×8 tile is exactly eight
//! vector accumulators — the classic BLIS-style shape.

/// Microkernel tile height (rows of C per tile).
pub const MR: usize = 8;
/// Microkernel tile width (columns of C per tile).
pub const NR: usize = 8;

/// acc[r][c] += sum_k Ap[k][r] * Bp[k][c] over `kc` packed k-steps.
///
/// `ap` is an MR-row panel in k-major layout (`ap[k * MR + r]`), `bp` an
/// NR-column panel in k-major layout (`bp[k * NR + c]`); both are
/// zero-padded at block edges by the packers, so the kernel itself never
/// branches on bounds.
#[inline]
pub fn kernel(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    debug_assert!(ap.len() >= kc * MR);
    debug_assert!(bp.len() >= kc * NR);
    for (a, b) in ap[..kc * MR]
        .chunks_exact(MR)
        .zip(bp[..kc * NR].chunks_exact(NR))
    {
        let a: &[f32; MR] = a.try_into().unwrap();
        let b: &[f32; NR] = b.try_into().unwrap();
        for r in 0..MR {
            let ar = a[r];
            let row = &mut acc[r];
            for c in 0..NR {
                row[c] += ar * b[c];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_rank1_updates() {
        // Ap: 3 k-steps of an MR panel, Bp: 3 k-steps of an NR panel.
        let kc = 3;
        let ap: Vec<f32> = (0..kc * MR).map(|i| (i % 5) as f32 - 2.0).collect();
        let bp: Vec<f32> = (0..kc * NR).map(|i| (i % 7) as f32 * 0.5).collect();
        let mut acc = [[0.0f32; NR]; MR];
        kernel(kc, &ap, &bp, &mut acc);
        for r in 0..MR {
            for c in 0..NR {
                let want: f32 =
                    (0..kc).map(|k| ap[k * MR + r] * bp[k * NR + c]).sum();
                assert!((acc[r][c] - want).abs() < 1e-6, "({r},{c})");
            }
        }
    }

    #[test]
    fn accumulates_into_existing_tile() {
        let mut acc = [[1.0f32; NR]; MR];
        kernel(1, &[1.0; MR], &[2.0; NR], &mut acc);
        for row in &acc {
            for v in row {
                assert_eq!(*v, 3.0);
            }
        }
    }

    #[test]
    fn zero_kc_is_noop() {
        let mut acc = [[4.0f32; NR]; MR];
        kernel(0, &[], &[], &mut acc);
        assert_eq!(acc[0][0], 4.0);
    }
}
