//! NEON microkernel (aarch64): the 8×8 tile as sixteen q-register
//! accumulators, two 4-lane halves per row.
//!
//! Per k step each row broadcasts its A element and does an explicit
//! `vmulq_f32` followed by `vaddq_f32` — never `vfmaq`/`vmlaq`, and
//! LLVM does not contract separate mul/add without fast-math — so per C
//! element the f32 sequence (ascending k, unfused multiply then add) is
//! exactly the portable tile's and output is bit-identical across
//! dispatch levels.  NEON is baseline on aarch64, so `supported()` is a
//! compile-time fact rather than a CPUID probe.

use super::micro::{MR, NR};

use std::arch::aarch64::*;

/// Safe entry with the shared [`super::dispatch::MicroKernel`] shape.
/// NEON is mandatory on aarch64 targets, so reaching this module at all
/// (it is compiled only there) makes the inner call sound.
pub fn kernel(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
    // SAFETY: aarch64 baseline includes NEON; panel bounds asserted above.
    unsafe { kernel_neon(kc, ap.as_ptr(), bp.as_ptr(), acc) }
}

#[target_feature(enable = "neon")]
unsafe fn kernel_neon(
    kc: usize,
    ap: *const f32,
    bp: *const f32,
    acc: &mut [[f32; NR]; MR],
) {
    debug_assert!(MR == 8 && NR == 8);
    let mut rows = [[vdupq_n_f32(0.0); 2]; MR];
    for (r, row) in rows.iter_mut().enumerate() {
        row[0] = vld1q_f32(acc[r].as_ptr());
        row[1] = vld1q_f32(acc[r].as_ptr().add(4));
    }
    for k in 0..kc {
        let b0 = vld1q_f32(bp.add(k * NR));
        let b1 = vld1q_f32(bp.add(k * NR + 4));
        for (r, row) in rows.iter_mut().enumerate() {
            let a = vdupq_n_f32(*ap.add(k * MR + r));
            // Unfused on purpose: mul then add, matching the portable
            // tile's per-element f32 sequence bit-for-bit.
            row[0] = vaddq_f32(row[0], vmulq_f32(a, b0));
            row[1] = vaddq_f32(row[1], vmulq_f32(a, b1));
        }
    }
    for (r, row) in rows.iter().enumerate() {
        vst1q_f32(acc[r].as_mut_ptr(), row[0]);
        vst1q_f32(acc[r].as_mut_ptr().add(4), row[1]);
    }
}

#[cfg(test)]
mod tests {
    use super::super::micro;
    use super::*;

    #[test]
    fn matches_portable_bitwise() {
        let kc = 29;
        let ap: Vec<f32> = (0..kc * MR).map(|i| (i as f32 * 0.9).sin()).collect();
        let bp: Vec<f32> = (0..kc * NR).map(|i| (i as f32 * 0.4).cos()).collect();
        let mut want = [[1.5f32; NR]; MR];
        micro::kernel(kc, &ap, &bp, &mut want);
        let mut got = [[1.5f32; NR]; MR];
        kernel(kc, &ap, &bp, &mut got);
        for r in 0..MR {
            assert_eq!(got[r].map(f32::to_bits), want[r].map(f32::to_bits), "row {r}");
        }
    }
}
