//! AVX2 8-wide microkernel: the 8×8 tile as eight ymm row accumulators.
//!
//! Each k step broadcasts one A element per row and does an explicit
//! `_mm256_mul_ps` followed by `_mm256_add_ps` — never an FMA intrinsic,
//! and LLVM does not contract separate mul/add without fast-math — so
//! per C element the operation sequence (ascending k, unfused multiply
//! then add) is exactly the portable tile's and the output is
//! bit-identical to every other dispatch level.  Keeping the tile in
//! registers across k instead of round-tripping memory is value-neutral
//! for f32.

use super::micro::{MR, NR};

#[cfg(target_arch = "x86")]
use std::arch::x86::*;
#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Safe entry with the shared [`super::dispatch::MicroKernel`] shape.
/// Callers reach this only through dispatch, which verified AVX2 at
/// probe/override time — that check is what makes the wrap sound.
pub fn kernel(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
    // SAFETY: AVX2 availability was established by dispatch (probe or
    // validated override) before this pointer was handed out; the panel
    // bounds were asserted above.
    unsafe { kernel_avx2(kc, ap.as_ptr(), bp.as_ptr(), acc) }
}

#[target_feature(enable = "avx2")]
unsafe fn kernel_avx2(
    kc: usize,
    ap: *const f32,
    bp: *const f32,
    acc: &mut [[f32; NR]; MR],
) {
    debug_assert_eq!(NR, 8);
    let mut rows = [_mm256_setzero_ps(); MR];
    for (r, row) in rows.iter_mut().enumerate() {
        *row = _mm256_loadu_ps(acc[r].as_ptr());
    }
    for k in 0..kc {
        let b = _mm256_loadu_ps(bp.add(k * NR));
        for (r, row) in rows.iter_mut().enumerate() {
            let a = _mm256_set1_ps(*ap.add(k * MR + r));
            // Unfused on purpose: mul then add, matching the portable
            // tile's per-element f32 sequence bit-for-bit.
            *row = _mm256_add_ps(*row, _mm256_mul_ps(a, b));
        }
    }
    for (r, row) in rows.iter().enumerate() {
        _mm256_storeu_ps(acc[r].as_mut_ptr(), *row);
    }
}

#[cfg(test)]
mod tests {
    use super::super::{dispatch::SimdLevel, micro};
    use super::*;

    #[test]
    fn matches_portable_bitwise_when_supported() {
        if !SimdLevel::Avx2.supported() {
            eprintln!("skipping: AVX2 unavailable on this CPU");
            return;
        }
        let kc = 19;
        let ap: Vec<f32> = (0..kc * MR).map(|i| (i as f32).sin()).collect();
        let bp: Vec<f32> = (0..kc * NR).map(|i| (i as f32).cos()).collect();
        let mut want = [[0.25f32; NR]; MR];
        micro::kernel(kc, &ap, &bp, &mut want);
        let mut got = [[0.25f32; NR]; MR];
        kernel(kc, &ap, &bp, &mut got);
        for r in 0..MR {
            assert_eq!(got[r].map(f32::to_bits), want[r].map(f32::to_bits), "row {r}");
        }
    }
}
