//! MC/KC/NC cache-blocking autotuner for the packed GEMM driver.
//!
//! The BLIS-style constants the driver shipped with (MC = 128, KC = 256,
//! NC = 1024) encode one guess about the cache hierarchy.  This module
//! makes the guess measurable: [`autotune`] times the *actual* packed
//! GEMM over a small deterministic candidate grid (axis sweeps around
//! the default) on fixed Philox-seeded probe shapes and reports GFLOP/s
//! per candidate; the winner is persisted by the `tune-kernels`
//! subcommand into the config file's `kernels.tuned` section
//! (`{"mc": .., "kc": .., "nc": ..}`), which sweeps re-apply via
//! [`crate::config::ExperimentConfig::apply_kernels`] without ever
//! re-timing (`--retune` forces a fresh probe).
//!
//! Blocking is a pure locality/perf knob: per C element the packed
//! driver accumulates k ascending through KC-blocks *in order*, so any
//! (MC, KC, NC) produces bit-identical results — which is what makes it
//! safe to persist a machine-specific winner while sweeps stay
//! byte-reproducible (`packed.rs` tests pin this across blockings).

use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{bail, Result};

use super::micro::{MR, NR};

/// One cache-blocking choice for the packed driver's loop nest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Blocking {
    /// Max rows of C per task / A-pack block (MR-aligned).
    pub mc: usize,
    /// k-depth per packed block.
    pub kc: usize,
    /// Columns of C per B-pack slab (NR-aligned).
    pub nc: usize,
}

/// The shipped defaults (the pre-autotuner constants).
pub const DEFAULT: Blocking = Blocking { mc: 128, kc: 256, nc: 1024 };

/// Upper sanity bound per dimension: past this the staging buffers stop
/// fitting any cache story and a typo'd config would silently allocate
/// gigabytes.
const MAX_DIM: usize = 1 << 16;

impl Blocking {
    /// Reject geometrically invalid blockings with the canonical knob
    /// error shape (field, offending value, valid domain).
    pub fn validate(&self) -> Result<()> {
        if self.mc < MR || self.mc % MR != 0 || self.mc > MAX_DIM {
            bail!(
                "kernels.tuned mc must be a multiple of {MR} in [{MR}, {MAX_DIM}], got {}",
                self.mc
            );
        }
        if self.kc == 0 || self.kc > MAX_DIM {
            bail!("kernels.tuned kc must be in [1, {MAX_DIM}], got {}", self.kc);
        }
        if self.nc < NR || self.nc % NR != 0 || self.nc > MAX_DIM {
            bail!(
                "kernels.tuned nc must be a multiple of {NR} in [{NR}, {MAX_DIM}], got {}",
                self.nc
            );
        }
        Ok(())
    }
}

// 0 = unset; installed together under the config/CLI layer (or the knob
// test lock), read per GEMM call.
static TUNED_MC: AtomicUsize = AtomicUsize::new(0);
static TUNED_KC: AtomicUsize = AtomicUsize::new(0);
static TUNED_NC: AtomicUsize = AtomicUsize::new(0);

/// Install (or clear, with `None`) the process-global tuned blocking.
/// Validates geometry first so a malformed `kernels.tuned` section can
/// never install a blocking the packers would misindex.
pub fn set_blocking_override(b: Option<Blocking>) -> Result<()> {
    match b {
        None => {
            TUNED_MC.store(0, Ordering::Relaxed);
            TUNED_KC.store(0, Ordering::Relaxed);
            TUNED_NC.store(0, Ordering::Relaxed);
        }
        Some(bl) => {
            bl.validate()?;
            TUNED_MC.store(bl.mc, Ordering::Relaxed);
            TUNED_KC.store(bl.kc, Ordering::Relaxed);
            TUNED_NC.store(bl.nc, Ordering::Relaxed);
        }
    }
    Ok(())
}

/// The currently installed override, if any.
pub fn blocking_override() -> Option<Blocking> {
    let mc = TUNED_MC.load(Ordering::Relaxed);
    if mc == 0 {
        return None;
    }
    Some(Blocking {
        mc,
        kc: TUNED_KC.load(Ordering::Relaxed),
        nc: TUNED_NC.load(Ordering::Relaxed),
    })
}

/// The blocking the packed driver uses right now: tuned override if one
/// was applied, else [`DEFAULT`].
pub fn blocking() -> Blocking {
    blocking_override().unwrap_or(DEFAULT)
}

/// The deterministic candidate grid: the default plus single-axis sweeps
/// and two diagonal moves.  Small on purpose — the autotuner is a
/// subcommand a machine runs once, not a per-process startup cost.
pub fn candidates() -> Vec<Blocking> {
    vec![
        DEFAULT,
        Blocking { mc: 64, kc: 256, nc: 1024 },
        Blocking { mc: 256, kc: 256, nc: 1024 },
        Blocking { mc: 128, kc: 128, nc: 1024 },
        Blocking { mc: 128, kc: 512, nc: 1024 },
        Blocking { mc: 128, kc: 256, nc: 512 },
        Blocking { mc: 128, kc: 256, nc: 2048 },
        Blocking { mc: 64, kc: 128, nc: 512 },
        Blocking { mc: 256, kc: 512, nc: 2048 },
    ]
}

/// Fixed probe shapes (m, k, n): one square, one rectangular like the
/// projection-heavy paths.  Deterministic Philox contents so every run
/// of the tuner multiplies the same matrices.
const PROBE_SHAPES: [(usize, usize, usize); 2] = [(256, 256, 256), (384, 320, 256)];

/// Time every candidate over the probe grid and return `(winner, rows)`
/// where each row is `(candidate, GFLOP/s)` in candidate order (best of
/// `reps` timed repetitions after one warmup).  The caller's blocking
/// override is saved and restored, so probing never leaks a candidate
/// into the process state — installing the winner is an explicit,
/// separate step.
pub fn autotune_with(cands: &[Blocking], reps: usize) -> (Blocking, Vec<(Blocking, f64)>) {
    use crate::rng::philox::PhiloxStream;
    use crate::tensor::Tensor;

    assert!(!cands.is_empty() && reps >= 1);
    let probes: Vec<(Tensor, Tensor)> = PROBE_SHAPES
        .iter()
        .map(|&(m, k, n)| {
            let mut s = PhiloxStream::new(0x70u64 + m as u64, 3);
            let a = Tensor::from_fn(m, k, |_, _| s.next_normal());
            let b = Tensor::from_fn(k, n, |_, _| s.next_normal());
            (a, b)
        })
        .collect();
    let flops: f64 = PROBE_SHAPES
        .iter()
        .map(|&(m, k, n)| 2.0 * m as f64 * k as f64 * n as f64)
        .sum();

    let prior = blocking_override();
    let mut rows = Vec::with_capacity(cands.len());
    for &cand in cands {
        set_blocking_override(Some(cand)).expect("candidate grid must be valid");
        let run = || {
            for (a, b) in &probes {
                let mut c = Tensor::zeros(a.rows, b.cols);
                super::packed::gemm(
                    super::packed::MatRef::dense(a),
                    super::packed::MatRef::dense(b),
                    &mut c,
                );
                std::hint::black_box(&c);
            }
        };
        run(); // warmup: page in the staging buffers at this geometry
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            run();
            best = best.min(t0.elapsed().as_secs_f64());
        }
        rows.push((cand, flops / best / 1e9));
    }
    set_blocking_override(prior).expect("prior override was valid");

    let mut winner = rows[0];
    for &r in &rows[1..] {
        if r.1 > winner.1 {
            winner = r;
        }
    }
    (winner.0, rows)
}

/// [`autotune_with`] over the standard [`candidates`] grid.
pub fn autotune(reps: usize) -> (Blocking, Vec<(Blocking, f64)>) {
    autotune_with(&candidates(), reps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::pool;

    #[test]
    fn validate_rejects_bad_geometry() {
        for bad in [
            Blocking { mc: 0, kc: 256, nc: 1024 },
            Blocking { mc: 129, kc: 256, nc: 1024 },
            Blocking { mc: 128, kc: 0, nc: 1024 },
            Blocking { mc: 128, kc: 256, nc: 0 },
            Blocking { mc: 128, kc: 256, nc: 1025 },
            Blocking { mc: MAX_DIM * 2, kc: 256, nc: 1024 },
        ] {
            let err = bad.validate().unwrap_err().to_string();
            assert!(err.contains("kernels.tuned"), "{err}");
            assert!(set_blocking_override(Some(bad)).is_err());
        }
        DEFAULT.validate().unwrap();
        for c in candidates() {
            c.validate().unwrap();
        }
    }

    #[test]
    fn override_roundtrip_and_default() {
        let _g = pool::knob_test_lock();
        assert_eq!(blocking(), DEFAULT);
        let b = Blocking { mc: 64, kc: 128, nc: 512 };
        set_blocking_override(Some(b)).unwrap();
        assert_eq!(blocking(), b);
        assert_eq!(blocking_override(), Some(b));
        set_blocking_override(None).unwrap();
        assert_eq!(blocking_override(), None);
        assert_eq!(blocking(), DEFAULT);
    }

    #[test]
    fn autotune_reports_every_candidate_and_restores_override() {
        let _g = pool::knob_test_lock();
        set_blocking_override(None).unwrap();
        let cands = [DEFAULT, Blocking { mc: 64, kc: 128, nc: 512 }];
        let (best, rows) = autotune_with(&cands, 1);
        assert_eq!(rows.len(), cands.len());
        assert!(rows.iter().any(|&(c, _)| c == best));
        for &(_, gf) in &rows {
            assert!(gf.is_finite() && gf > 0.0);
        }
        // probing must not leak a candidate into process state
        assert_eq!(blocking_override(), None);
    }
}
