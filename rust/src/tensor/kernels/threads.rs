//! Thread-count policy for the compute pool (`tensor::pool`).
//!
//! PR-1's `par_row_bands` (scoped per-call thread spawns) lived here; the
//! dispatch itself moved to the persistent work-stealing pool, and this
//! module now only answers "how many participants should a parallel run
//! use?".
//!
//! # The env contract (and the cache bug this fixes)
//!
//! `RMM_THREADS` is read **on every call**.  The PR-1 implementation
//! cached the first read in a `OnceLock`, which silently ignored any
//! later change — in particular the per-test overrides that
//! `rust/tests/prop_pool.rs` and the dual-thread-count CI run rely on.
//! Precedence, highest first:
//!
//! 1. [`set_threads_override`] — installed by the config file's
//!    `pool.threads` key or the `--threads` CLI flag;
//! 2. `RMM_THREADS` env var (≥ 1), re-read per call;
//! 3. the machine parallelism (cached — it cannot change mid-process).
//!
//! The count only controls how many pool participants a run recruits;
//! results are bit-identical for every value (see `tensor::pool`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

static OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Install a process-global thread-count override (config / CLI layer).
/// `0` clears it, restoring the `RMM_THREADS`-or-machine default.
pub fn set_threads_override(n: usize) {
    OVERRIDE.store(n, Ordering::Relaxed);
}

/// The machine's available parallelism (cached once; this is a hardware
/// fact, not a knob).
pub fn machine_parallelism() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Participants a parallel run should use right now: override, else
/// `RMM_THREADS` (re-read per call), else the machine parallelism.
pub fn num_threads() -> usize {
    let o = OVERRIDE.load(Ordering::Relaxed);
    if o >= 1 {
        return o;
    }
    if let Ok(v) = std::env::var("RMM_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    machine_parallelism()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_threads_is_at_least_one() {
        assert!(num_threads() >= 1);
        assert!(machine_parallelism() >= 1);
    }

    #[test]
    fn override_beats_env_and_clears() {
        // Other tests read num_threads() concurrently — that only
        // modulates their parallelism, never their results (pool
        // determinism) — but tests that *assert* on knob values share
        // the knob lock.
        let _g = crate::tensor::pool::knob_test_lock();
        set_threads_override(3);
        assert_eq!(num_threads(), 3);
        set_threads_override(0);
        assert!(num_threads() >= 1);
    }
}
