//! Row-band work dispatch over scoped std threads (rayon is unavailable
//! offline).  All parallel host kernels in this crate split their *output*
//! rows into contiguous bands, so every band owns a disjoint `&mut` slice
//! of the result buffer and no synchronization is ever needed.  Each output
//! element is always accumulated by exactly one thread in the same order as
//! the serial code, so results are bit-identical for any thread count.

use std::sync::OnceLock;

/// Worker count: `RMM_THREADS` env override, else the machine parallelism.
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("RMM_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n >= 1 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Split `rows` into at most `nt` contiguous bands and run
/// `f(first_row, band_rows, band_slice)` for each, where `band_slice` is
/// the `&mut` sub-slice of `data` covering those rows (`ld` floats per
/// row).  With `nt <= 1` (or a single row) this degenerates to one plain
/// call on the current thread — no spawn overhead on small problems.
pub fn par_row_bands<F>(nt: usize, rows: usize, ld: usize, data: &mut [f32], f: &F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(data.len(), rows * ld);
    let nt = nt.min(rows.max(1));
    if nt <= 1 || ld == 0 {
        f(0, rows, data);
        return;
    }
    // ceil(rows / nt) rows per band: at most nt bands, last may be short.
    let band_rows = (rows + nt - 1) / nt;
    std::thread::scope(|s| {
        for (idx, chunk) in data.chunks_mut(band_rows * ld).enumerate() {
            let r0 = idx * band_rows;
            let br = chunk.len() / ld;
            s.spawn(move || f(r0, br, chunk));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bands_cover_all_rows_exactly_once() {
        for rows in [0usize, 1, 2, 3, 7, 16, 17] {
            for nt in [1usize, 2, 3, 8] {
                let ld = 3;
                let mut data = vec![0.0f32; rows * ld];
                par_row_bands(nt, rows, ld, &mut data, &|r0, br, band| {
                    assert_eq!(band.len(), br * ld);
                    for (i, v) in band.iter_mut().enumerate() {
                        *v += (r0 * ld + i) as f32 + 1.0;
                    }
                });
                for (i, v) in data.iter().enumerate() {
                    assert_eq!(*v, i as f32 + 1.0, "rows={rows} nt={nt} i={i}");
                }
            }
        }
    }

    #[test]
    fn num_threads_is_at_least_one() {
        assert!(num_threads() >= 1);
    }
}
