//! Host GEMM backend subsystem: cache-blocked, register-tiled, multi-
//! threaded f32 kernels behind a runtime-selectable [`Backend`] trait.
//!
//! # Why
//!
//! The paper's memory win (store `X_proj = SᵀX` instead of `X`) only
//! translates into a wall-clock win if the randomized matmuls are fast.
//! Every host-side hot path — `tensor::{matmul, matmul_at, matmul_bt}` and
//! the streamed sketch projection — routes through this module, so the
//! Rust baselines quoted by the bench harness reflect what the hardware
//! actually allows rather than a naive scalar loop.
//!
//! # Packing / tiling scheme (`Packed` backend)
//!
//! The blocked driver ([`packed`]) follows the GotoBLAS/BLIS loop nest:
//!
//! ```text
//! for jc in 0..n step NC          // C column slab; B slab ≈ L3
//!   for pc in 0..k step KC        // k-block; pack B(pc..,jc..) → bbuf
//!     for ic in 0..m step MC      // C row block; pack A(ic..,pc..) → abuf
//!       for jp in 0..nc step NR   // microtile columns
//!         for ip in 0..mc step MR // microtile rows → 8×8 register tile
//! ```
//!
//! * **Packing** ([`pack`]): A blocks are laid out as k-major MR-row
//!   panels, B blocks as k-major NR-column panels, zero-padded at the
//!   edges.  The microkernel therefore streams both operands with unit
//!   stride and never branches on bounds.  Packing reads through a strided
//!   [`packed::MatRef`] view, which is how `Aᵀ·B` / `A·Bᵀ` reuse the same
//!   driver without materializing transposes.
//! * **Microkernel** ([`micro`] + [`dispatch`]): an `MR×NR = 8×8`
//!   accumulator tile updated by rank-1 steps.  The portable tile relies
//!   on LLVM autovectorization; runtime dispatch upgrades it to explicit
//!   AVX2/AVX-512/NEON kernels when the CPU supports them (see the
//!   *SIMD dispatch + autotune knobs* section below).
//! * **Threading** ([`threads`] + [`crate::tensor::pool`]): work is cut
//!   into `(jc, row-block)` cache-block tasks and dispatched through the
//!   persistent work-stealing pool (workers spawned once, parked between
//!   runs — rayon is unavailable offline).  Tasks own disjoint output
//!   regions — no locks — and per-element accumulation order is
//!   task-independent, so results are bit-identical for any thread count
//!   (`RMM_THREADS`, re-read per run) and any task grain
//!   (`RMM_POOL_GRAIN`).
//!
//! The [`Scalar`] backend is the seed's single-threaded blocked loop
//! (minus its vectorization-hostile zero-skip branch), kept as the
//! reference both for tests and for honest before/after bench numbers.
//!
//! # Selection
//!
//! `Packed` is the default.  Override order: `ExperimentConfig::backend`
//! (config file) / `--backend` (CLI) → [`set_backend`]; `RMM_BACKEND`
//! env var → [`init_from_env`].  Thread count and task grain follow the
//! same layering through `ExperimentConfig::pool` / `--threads` /
//! `--pool-grain` and the `RMM_THREADS` / `RMM_POOL_GRAIN` env vars
//! (see [`threads`] and [`crate::tensor::pool`]).
//!
//! # SIMD dispatch + autotune knobs
//!
//! This is the canonical reference for the kernel-speed knobs; the
//! module docs of [`dispatch`] and [`tune`] carry the implementation
//! detail.
//!
//! * **Probe order** ([`dispatch::probe`]): one cached CPU-feature probe
//!   selects the first supported level in `avx512 → avx2 → neon →
//!   portable`.  `scalar` (the per-element reference loop) is never
//!   auto-selected; it exists to be forced by the identity tests.
//! * **Override env**: `RMM_SIMD=scalar|portable|avx2|avx512|neon`
//!   forces a level.  Parsing is *strict* — an unknown name or a level
//!   this CPU cannot run is an error (name + offending value + valid
//!   domain), never a silent fallback — matching `RMM_EXE_CACHE_CAP`
//!   and `RMM_POOL_GRAIN`.  Precedence: config `kernels.simd` / CLI
//!   `--simd` ([`dispatch::set_simd_override`]) > `RMM_SIMD` > probe.
//! * **Tuned-config persistence** ([`tune`]): `repro tune-kernels
//!   --config FILE` times the deterministic candidate grid and writes
//!   the winner to the config's `kernels.tuned` section as
//!   `{"mc": M, "kc": K, "nc": N}`.  Sweeps and runs consuming that
//!   config re-apply the stored blocking and **never re-time**; pass
//!   `--retune` to force a fresh probe.  Unset → the shipped
//!   [`tune::DEFAULT`] (128, 256, 1024).  The pool task grain derives
//!   from the tuned MC ([`packed::gemm_task_grain`]), so blocking and
//!   stealing granularity cannot drift apart.
//! * **No-FMA bit-identity contract**: every dispatch level performs,
//!   per C element, the identical f32 sequence — ascending k, one IEEE
//!   multiply then one IEEE add per step, no FMA contraction — and
//!   blocking only regroups that sequence without reordering it, so
//!   kernel output is bit-identical across every (SIMD level, MC/KC/NC,
//!   thread count, task grain) combination.  `prop_kernels.rs` pins the
//!   matrix; `scripts/ci.sh` gates `RMM_SIMD=portable` vs auto end to
//!   end.

pub mod dispatch;
pub mod micro;
#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
pub mod micro_avx2;
#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
pub mod micro_avx512;
#[cfg(target_arch = "aarch64")]
pub mod micro_neon;
pub mod pack;
pub mod packed;
pub mod scalar;
pub mod threads;
pub mod tune;

use std::sync::atomic::{AtomicU8, Ordering};

use crate::tensor::Tensor;

use packed::MatRef;

/// A host GEMM implementation.  All three products share one contract:
/// inputs are row-major `Tensor`s, the result is freshly allocated.
pub trait Backend: Sync {
    fn name(&self) -> &'static str;

    /// C = A · B.
    fn matmul(&self, a: &Tensor, b: &Tensor) -> Tensor;

    /// C = Aᵀ · B  (A: (k, m), B: (k, n) → C: (m, n)).
    fn matmul_at(&self, a: &Tensor, b: &Tensor) -> Tensor;

    /// C = A · Bᵀ  (A: (m, k), B: (n, k) → C: (m, n)).
    fn matmul_bt(&self, a: &Tensor, b: &Tensor) -> Tensor;
}

/// Seed-style single-threaded blocked loops (reference).
pub struct Scalar;

/// Packed-panel register-tiled multithreaded kernels (default).
pub struct Packed;

/// The two backend instances (unit structs, usable directly in tests and
/// benches without touching the global selection).
pub static SCALAR: Scalar = Scalar;
pub static PACKED: Packed = Packed;

impl Backend for Scalar {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        scalar::matmul(a, b)
    }

    fn matmul_at(&self, a: &Tensor, b: &Tensor) -> Tensor {
        scalar::matmul_at(a, b)
    }

    fn matmul_bt(&self, a: &Tensor, b: &Tensor) -> Tensor {
        scalar::matmul_bt(a, b)
    }
}

impl Backend for Packed {
    fn name(&self) -> &'static str {
        "packed"
    }

    fn matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let mut c = Tensor::zeros(a.rows, b.cols);
        packed::gemm(MatRef::dense(a), MatRef::dense(b), &mut c);
        c
    }

    fn matmul_at(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let mut c = Tensor::zeros(a.cols, b.cols);
        packed::gemm(MatRef::transposed(a), MatRef::dense(b), &mut c);
        c
    }

    fn matmul_bt(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let mut c = Tensor::zeros(a.rows, b.rows);
        packed::gemm(MatRef::dense(a), MatRef::transposed(b), &mut c);
        c
    }
}

/// Which backend the free functions in `tensor` dispatch to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Scalar,
    Packed,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "scalar" => Some(BackendKind::Scalar),
            "packed" => Some(BackendKind::Packed),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Scalar => "scalar",
            BackendKind::Packed => "packed",
        }
    }
}

static ACTIVE: AtomicU8 = AtomicU8::new(1); // 0 = Scalar, 1 = Packed

/// Select the process-global backend (config / CLI layer calls this).
pub fn set_backend(kind: BackendKind) {
    let v = match kind {
        BackendKind::Scalar => 0,
        BackendKind::Packed => 1,
    };
    ACTIVE.store(v, Ordering::Relaxed);
}

/// The currently selected backend kind.
pub fn backend_kind() -> BackendKind {
    match ACTIVE.load(Ordering::Relaxed) {
        0 => BackendKind::Scalar,
        _ => BackendKind::Packed,
    }
}

/// The currently selected backend instance.
pub fn active() -> &'static dyn Backend {
    match backend_kind() {
        BackendKind::Scalar => &SCALAR,
        BackendKind::Packed => &PACKED,
    }
}

/// Honor `RMM_BACKEND=scalar|packed` (bench/CLI entry points call this
/// once at startup; unknown values are ignored, keeping Packed).
pub fn init_from_env() {
    if let Ok(v) = std::env::var("RMM_BACKEND") {
        if let Some(k) = BackendKind::parse(v.trim()) {
            set_backend(k);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::philox::PhiloxStream;

    fn randt(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut s = PhiloxStream::new(seed, 3);
        Tensor::from_fn(rows, cols, |_, _| s.next_normal())
    }

    #[test]
    fn backends_agree_on_all_three_products() {
        let a = randt(37, 29, 1);
        let b = randt(29, 41, 2);
        assert!(SCALAR.matmul(&a, &b).max_abs_diff(&PACKED.matmul(&a, &b)) < 1e-4);

        let at = randt(29, 37, 3); // (k, m) for the Aᵀ variant
        assert!(
            SCALAR.matmul_at(&at, &b).max_abs_diff(&PACKED.matmul_at(&at, &b)) < 1e-4
        );

        let bt = randt(41, 29, 4); // (n, k) for the Bᵀ variant
        assert!(
            SCALAR.matmul_bt(&a, &bt).max_abs_diff(&PACKED.matmul_bt(&a, &bt)) < 1e-4
        );
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in [BackendKind::Scalar, BackendKind::Packed] {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
        }
        assert_eq!(BackendKind::parse("bogus"), None);
    }

    #[test]
    fn selection_switches_dispatch() {
        // Don't rely on the default (other tests may run concurrently);
        // just check set/get coherence through the names.
        set_backend(BackendKind::Packed);
        assert_eq!(active().name(), "packed");
        set_backend(BackendKind::Scalar);
        assert_eq!(active().name(), "scalar");
        set_backend(BackendKind::Packed);
    }
}
