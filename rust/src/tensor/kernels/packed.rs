//! Blocked GEMM driver over the packed microkernel, plus the strided
//! matrix view that lets one driver serve `A·B`, `Aᵀ·B` and `A·Bᵀ`.
//!
//! Loop nest (BLIS/GotoBLAS order): NC-wide column slabs of C, KC-deep
//! k-blocks (B panel packed once per slab×block), MC-tall row blocks
//! (A panel packed per block), then NR×MR microkernel tiles.  C tiles are
//! loaded, updated and stored through a stack tile so edge handling stays
//! out of the hot loop.
//!
//! Per C element the k-accumulation order is ascending (KC blocks in
//! order, k ascending inside the kernel), independent of blocking and of
//! the thread count — results are deterministic.

use super::micro::{kernel, MR, NR};
use super::pack::{pack_a, pack_b};
use super::threads;
use crate::tensor::Tensor;

/// Rows of C per A-pack block (L2-sized: MC·KC·4B ≈ 128 KiB).
const MC: usize = 128;
/// k-depth per packed block (panel strips stay L1-resident).
const KC: usize = 256;
/// Columns of C per B-pack slab (B slab ≈ 1 MiB, L3-resident).
const NC: usize = 1024;

/// Minimum FLOP count before fanning out to threads (below this the spawn
/// cost dominates).
const PAR_FLOP_THRESHOLD: f64 = 4.0e6;

/// Read-only strided view of a logical `rows × cols` f32 matrix.
#[derive(Clone, Copy)]
pub struct MatRef<'a> {
    pub data: &'a [f32],
    pub rows: usize,
    pub cols: usize,
    pub row_stride: usize,
    pub col_stride: usize,
}

impl<'a> MatRef<'a> {
    /// View a tensor as-is (row-major).
    pub fn dense(t: &'a Tensor) -> Self {
        MatRef {
            data: &t.data,
            rows: t.rows,
            cols: t.cols,
            row_stride: t.cols,
            col_stride: 1,
        }
    }

    /// View a tensor's transpose without materializing it.
    pub fn transposed(t: &'a Tensor) -> Self {
        MatRef {
            data: &t.data,
            rows: t.cols,
            cols: t.rows,
            row_stride: 1,
            col_stride: t.cols,
        }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.row_stride + j * self.col_stride]
    }
}

/// out = a · b for logical views (out must be zeroed, `a.cols == b.rows`).
///
/// The B slab for each (column slab, k-block) is packed **once** on the
/// calling thread and shared read-only across the row bands, so the
/// O(k·n) packing work does not scale with the thread count.
pub fn gemm(a: MatRef<'_>, b: MatRef<'_>, out: &mut Tensor) {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    debug_assert_eq!(a.cols, b.rows);
    debug_assert_eq!((out.rows, out.cols), (m, n));
    if m == 0 || n == 0 || k == 0 {
        return; // out is already zero
    }
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    let nt = if flops < PAR_FLOP_THRESHOLD { 1 } else { threads::num_threads() };

    let b_panel_cols = ((n.min(NC) + NR - 1) / NR) * NR;
    let mut bbuf = vec![0.0f32; b_panel_cols * k.min(KC)];

    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            pack_b(&mut bbuf, b, pc, kc, jc, nc);
            let bshared: &[f32] = &bbuf;
            threads::par_row_bands(nt, m, n, &mut out.data, &|i0, band_rows, band| {
                gemm_rows(a, bshared, kc, pc, jc, nc, i0, band_rows, band, n);
            });
            pc += KC;
        }
        jc += NC;
    }
}

/// Microtile sweep for C rows `i_off .. i_off + mrows` against one packed
/// B slab (`bbuf`, covering columns `jc .. jc + nc` at k-depth `kc` from
/// `pc`).  `c` is the row band's slice of the full `? × n` C buffer.
#[allow(clippy::too_many_arguments)]
fn gemm_rows(
    a: MatRef<'_>,
    bbuf: &[f32],
    kc: usize,
    pc: usize,
    jc: usize,
    nc: usize,
    i_off: usize,
    mrows: usize,
    c: &mut [f32],
    n: usize,
) {
    if mrows == 0 {
        return;
    }
    let a_panel_rows = ((mrows.min(MC) + MR - 1) / MR) * MR;
    let mut abuf = vec![0.0f32; a_panel_rows * kc];
    let mut tile = [[0.0f32; NR]; MR];

    let mut ic = 0;
    while ic < mrows {
        let mc = MC.min(mrows - ic);
        pack_a(&mut abuf, a, i_off + ic, mc, pc, kc);
        let mut jp = 0;
        while jp < nc {
            let nr = NR.min(nc - jp);
            let bp = &bbuf[(jp / NR) * NR * kc..(jp / NR) * NR * kc + NR * kc];
            let mut ip = 0;
            while ip < mc {
                let mr = MR.min(mc - ip);
                let ap = &abuf[(ip / MR) * MR * kc..(ip / MR) * MR * kc + MR * kc];
                // load C tile (padded lanes start at zero; the packers
                // zero-pad A/B so they stay inert)
                for (r, trow) in tile.iter_mut().enumerate() {
                    if r < mr {
                        let c0 = (ic + ip + r) * n + jc + jp;
                        trow[..nr].copy_from_slice(&c[c0..c0 + nr]);
                        for v in trow[nr..].iter_mut() {
                            *v = 0.0;
                        }
                    } else {
                        *trow = [0.0; NR];
                    }
                }
                kernel(kc, ap, bp, &mut tile);
                for (r, trow) in tile.iter().enumerate().take(mr) {
                    let c0 = (ic + ip + r) * n + jc + jp;
                    c[c0..c0 + nr].copy_from_slice(&trow[..nr]);
                }
                ip += MR;
            }
            jp += NR;
        }
        ic += MC;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::philox::PhiloxStream;

    fn randt(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut s = PhiloxStream::new(seed, 3);
        Tensor::from_fn(rows, cols, |_, _| s.next_normal())
    }

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let mut c = Tensor::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f64;
                for k in 0..a.cols {
                    acc += (a.at(i, k) * b.at(k, j)) as f64;
                }
                *c.at_mut(i, j) = acc as f32;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive_across_blocking_edges() {
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (7, 13, 11),
            (8, 8, 8),
            (9, 17, 33),
            (130, 70, 150),
            (257, 300, 129),
        ] {
            let a = randt(m, k, 1);
            let b = randt(k, n, 2);
            let mut c = Tensor::zeros(m, n);
            gemm(MatRef::dense(&a), MatRef::dense(&b), &mut c);
            let want = naive(&a, &b);
            assert!(c.max_abs_diff(&want) < 1e-3, "({m},{k},{n})");
        }
    }

    #[test]
    fn gemm_transposed_views() {
        let a = randt(23, 31, 3); // used as Aᵀ: logical 31 x 23
        let b = randt(23, 19, 4);
        let mut c = Tensor::zeros(31, 19);
        gemm(MatRef::transposed(&a), MatRef::dense(&b), &mut c);
        let want = naive(&a.transpose(), &b);
        assert!(c.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn gemm_zero_dims_are_noops() {
        for &(m, k, n) in &[(0usize, 5usize, 7usize), (5, 0, 7), (5, 7, 0)] {
            let a = randt(m, k, 5);
            let b = randt(k, n, 6);
            let mut c = Tensor::zeros(m, n);
            gemm(MatRef::dense(&a), MatRef::dense(&b), &mut c);
            assert_eq!(c.data, vec![0.0f32; m * n]);
        }
    }

    #[test]
    fn gemm_is_deterministic_across_thread_counts() {
        // Band splits must agree bit-for-bit because each element's
        // accumulation order is band-independent.  (97, 61, 83) fits one
        // (jc, pc) block, so one shared packed B slab serves all bands.
        let (m, k, n) = (97usize, 61usize, 83usize);
        let a = randt(m, k, 7);
        let b = randt(k, n, 8);
        let b_panel_cols = ((n + NR - 1) / NR) * NR;
        let mut bbuf = vec![0.0f32; b_panel_cols * k];
        pack_b(&mut bbuf, MatRef::dense(&b), 0, k, 0, n);
        let bshared: &[f32] = &bbuf;

        let mut c1 = Tensor::zeros(m, n);
        let mut c2 = Tensor::zeros(m, n);
        threads::par_row_bands(1, m, n, &mut c1.data, &|i0, br, band| {
            gemm_rows(MatRef::dense(&a), bshared, k, 0, 0, n, i0, br, band, n);
        });
        threads::par_row_bands(4, m, n, &mut c2.data, &|i0, br, band| {
            gemm_rows(MatRef::dense(&a), bshared, k, 0, 0, n, i0, br, band, n);
        });
        assert_eq!(c1.data, c2.data);

        // and the public entry point agrees with the manual sweep
        let mut c3 = Tensor::zeros(m, n);
        gemm(MatRef::dense(&a), MatRef::dense(&b), &mut c3);
        assert_eq!(c1.data, c3.data);
    }
}
