//! Blocked GEMM driver over the packed microkernel, plus the strided
//! matrix view that lets one driver serve `A·B`, `Aᵀ·B` and `A·Bᵀ`.
//!
//! Loop nest (BLIS/GotoBLAS order): NC-wide column slabs of C, KC-deep
//! k-blocks, MC-tall row blocks, then NR×MR microkernel tiles.  C tiles
//! are loaded, updated and stored through a stack tile so edge handling
//! stays out of the hot loop.
//!
//! # Pool dispatch
//!
//! Parallelism comes from the persistent work-stealing pool
//! (`tensor::pool`), two waves per NC-wide C column slab:
//!
//! 1. **Pack B** — the slab's `pc` k-blocks are packed into one shared
//!    staging buffer, one pool task per block (disjoint destination
//!    ranges at the closed-form offset `pcols · pc`).  The buffer is
//!    allocated once per GEMM and bounded at `padded(min(n, NC)) · k`
//!    floats — the full-k image of ONE column slab, not of all of B —
//!    and is read-only during the compute wave.
//! 2. **Compute C** — tasks are row blocks of the slab (height from
//!    `pool::task_grain`, MR-aligned, at most MC).  Each task owns its
//!    C block outright: it loops over the k-blocks ascending, packs its
//!    own A panel per block, and sweeps the microtiles.
//!
//! # Worker-local A-panel scratch
//!
//! The A panel a compute task packs is scratch: `pack_a` fully
//! overwrites the prefix the task reads (zero-padding included), so its
//! prior contents are dead.  Instead of a fresh `vec!` per task — which
//! charged a malloc/free round-trip to every task of every optimizer-
//! path small GEMM — each pool thread keeps one grow-only arena
//! ([`with_a_scratch`]) reused across tasks, runs and shapes.  Reuse
//! cannot affect results: the task reads only the `pack_a`-overwritten
//! prefix, so the value stream into the microkernel is identical whether
//! the buffer is fresh or recycled (`prop_pool.rs` pins this across
//! thread counts, grains and dirty-arena interleavings).  A re-entrant
//! task (a kernel dispatched from inside another task's scratch scope)
//! falls back to a one-off allocation rather than alias the arena.
//!
//! Per C element the k-accumulation order is ascending (KC blocks in
//! order, k ascending inside the kernel) and is entirely contained in the
//! element's owning task — independent of blocking, task grain, steal
//! order and thread count — so results are bit-identical for any
//! `RMM_THREADS`.

use super::dispatch::{self, MicroKernel};
use super::micro::{MR, NR};
use super::pack::{pack_a, pack_b};
use super::threads;
use super::tune::{self, Blocking};
use crate::tensor::pool::{self, SharedMut};
use crate::tensor::Tensor;

// Cache blocking (MC rows per task / A block, KC k-depth per packed
// block, NC columns per B slab) comes from `tune::blocking()`: the
// shipped `tune::DEFAULT` (128, 256, 1024 — L2-sized A blocks, an
// L3-resident B slab) unless a `kernels.tuned` config section installed
// an autotuned winner.  Read once per GEMM call; see the tune module
// doc for why blocking is bit-invisible.
//
// The microkernel likewise comes from `dispatch::active_kernel()` —
// portable, scalar, or an explicit AVX2/AVX-512/NEON tile — fetched
// once per call and copied into the pool tasks as a plain fn pointer.

/// Minimum FLOP count before fanning out to the pool (below this the
/// dispatch cost dominates).
const PAR_FLOP_THRESHOLD: f64 = 4.0e6;

thread_local! {
    /// Per-thread grow-only arena for packed A panels (see module doc).
    static A_SCRATCH: std::cell::RefCell<Vec<f32>> =
        std::cell::RefCell::new(Vec::new());
}

/// Hand `f` a `len`-float scratch slice from this thread's arena.  The
/// slice contents are unspecified — callers must fully overwrite what
/// they read (gemm_block does, via `pack_a`).  Falls back to a fresh
/// allocation if the arena is already borrowed (re-entrant dispatch).
fn with_a_scratch<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    A_SCRATCH.with(|cell| match cell.try_borrow_mut() {
        Ok(mut buf) => {
            if buf.len() < len {
                buf.resize(len, 0.0);
            }
            f(&mut buf[..len])
        }
        Err(_) => f(&mut vec![0.0f32; len]),
    })
}

/// Read-only strided view of a logical `rows × cols` f32 matrix.
#[derive(Clone, Copy)]
pub struct MatRef<'a> {
    pub data: &'a [f32],
    pub rows: usize,
    pub cols: usize,
    pub row_stride: usize,
    pub col_stride: usize,
}

impl<'a> MatRef<'a> {
    /// View a tensor as-is (row-major).
    pub fn dense(t: &'a Tensor) -> Self {
        MatRef {
            data: &t.data,
            rows: t.rows,
            cols: t.cols,
            row_stride: t.cols,
            col_stride: 1,
        }
    }

    /// View a tensor's transpose without materializing it.
    pub fn transposed(t: &'a Tensor) -> Self {
        MatRef {
            data: &t.data,
            rows: t.cols,
            cols: t.rows,
            row_stride: 1,
            col_stride: t.cols,
        }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.row_stride + j * self.col_stride]
    }
}

/// Rounded-up panel width of a `nc`-column B slab.
#[inline]
fn padded_cols(nc: usize) -> usize {
    (nc + NR - 1) / NR * NR
}

/// The row-block task grain the pool driver picks for an `m`-row GEMM at
/// `nt` participants (MR-aligned, at most the *tuned* MC, so autotuned
/// blocking and work-stealing granularity cannot drift apart).  Exposed
/// so the benches can report the grain next to the GFLOP/s numbers.
pub fn gemm_task_grain(m: usize, nt: usize) -> usize {
    pool::task_grain(m, nt, MR, tune::blocking().mc)
}

/// out = a · b for logical views (out must be zeroed, `a.cols == b.rows`).
pub fn gemm(a: MatRef<'_>, b: MatRef<'_>, out: &mut Tensor) {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    debug_assert_eq!(a.cols, b.rows);
    debug_assert_eq!((out.rows, out.cols), (m, n));
    if m == 0 || n == 0 || k == 0 {
        return; // out is already zero
    }
    let blk = tune::blocking();
    let kern = dispatch::active_kernel();
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    let nt = if flops < PAR_FLOP_THRESHOLD { 1 } else { threads::num_threads() };

    let n_pc = (k + blk.kc - 1) / blk.kc;
    let grain = gemm_task_grain(m, nt);
    let n_ic = (m + grain - 1) / grain;
    // Staging for one NC-wide column slab of B at full k-depth; block pci
    // lives at the closed-form offset pcols·pc (its k-blocks are pcols·kc
    // each, stacked in pc order).
    let mut bbuf = vec![0.0f32; padded_cols(n.min(blk.nc)) * k];
    let cptr = SharedMut::new(out.data.as_mut_ptr());

    let mut jc = 0;
    while jc < n {
        let nc = blk.nc.min(n - jc);
        let pcols = padded_cols(nc);
        // ---- wave 1: pack this slab's k-blocks (one pool task each) ----
        {
            let bptr = SharedMut::new(bbuf.as_mut_ptr());
            pool::global().run(nt, n_pc, |pci| {
                let pc = pci * blk.kc;
                let kc = blk.kc.min(k - pc);
                // SAFETY: destination ranges [pcols·pc, pcols·(pc + kc))
                // are disjoint across tasks and within bbuf's prefix.
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(bptr.ptr().add(pcols * pc), pcols * kc)
                };
                pack_b(dst, b, pc, kc, jc, nc);
            });
        }
        let bslab = &bbuf[..pcols * k];

        // ---- wave 2: row-block compute tasks over disjoint C blocks ----
        pool::global().run(nt, n_ic, |ici| {
            let i0 = ici * grain;
            let mrows = grain.min(m - i0);
            gemm_block(a, bslab, pcols, k, n, jc, nc, i0, mrows, cptr, blk, kern);
        });
        jc += blk.nc;
    }
}

/// Compute the C block rows `i0 .. i0 + mrows` × columns `jc .. jc + nc`
/// against the slab's pre-packed B image (`bslab`, k-blocks stacked at
/// `pcols·pc`).  The block is owned exclusively by this task: k-blocks
/// accumulate in ascending order through a stack tile, so every element's
/// f32 accumulation sequence is fixed.  The A panel lives in the
/// worker-local scratch arena — `pack_a` overwrites every element the
/// microkernel reads, so arena reuse is invisible to the result.
#[allow(clippy::too_many_arguments)]
fn gemm_block(
    a: MatRef<'_>,
    bslab: &[f32],
    pcols: usize,
    k: usize,
    n: usize,
    jc: usize,
    nc: usize,
    i0: usize,
    mrows: usize,
    c: SharedMut<f32>,
    blk: Blocking,
    kern: MicroKernel,
) {
    if mrows == 0 {
        return;
    }
    let a_panel_rows = (mrows + MR - 1) / MR * MR; // mrows <= blk.mc by grain clamp
    with_a_scratch(a_panel_rows * blk.kc.min(k), |abuf| {
        let mut tile = [[0.0f32; NR]; MR];

        let mut pci = 0;
        while pci * blk.kc < k {
            let pc = pci * blk.kc;
            let kc = blk.kc.min(k - pc);
            pack_a(abuf, a, i0, mrows, pc, kc);
            let slab = &bslab[pcols * pc..pcols * pc + pcols * kc];

            let mut jp = 0;
            while jp < nc {
                let nr = NR.min(nc - jp);
                let bp = &slab[(jp / NR) * NR * kc..(jp / NR) * NR * kc + NR * kc];
                let mut ip = 0;
                while ip < mrows {
                    let mr = MR.min(mrows - ip);
                    let ap = &abuf[(ip / MR) * MR * kc..(ip / MR) * MR * kc + MR * kc];
                    // load C tile (padded lanes start at zero; the packers
                    // zero-pad A/B so they stay inert)
                    for (r, trow) in tile.iter_mut().enumerate() {
                        if r < mr {
                            let c0 = (i0 + ip + r) * n + jc + jp;
                            // SAFETY: this task owns C rows [i0, i0+mrows)
                            // × cols [jc, jc+nc); c0..c0+nr is inside it.
                            let src = unsafe {
                                std::slice::from_raw_parts(c.ptr().add(c0) as *const f32, nr)
                            };
                            trow[..nr].copy_from_slice(src);
                            for v in trow[nr..].iter_mut() {
                                *v = 0.0;
                            }
                        } else {
                            *trow = [0.0; NR];
                        }
                    }
                    kern(kc, ap, bp, &mut tile);
                    for (r, trow) in tile.iter().enumerate().take(mr) {
                        let c0 = (i0 + ip + r) * n + jc + jp;
                        // SAFETY: same exclusive region as the load above.
                        let dst =
                            unsafe { std::slice::from_raw_parts_mut(c.ptr().add(c0), nr) };
                        dst.copy_from_slice(&trow[..nr]);
                    }
                    ip += MR;
                }
                jp += NR;
            }
            pci += 1;
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::philox::PhiloxStream;

    fn randt(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut s = PhiloxStream::new(seed, 3);
        Tensor::from_fn(rows, cols, |_, _| s.next_normal())
    }

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let mut c = Tensor::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f64;
                for k in 0..a.cols {
                    acc += (a.at(i, k) * b.at(k, j)) as f64;
                }
                *c.at_mut(i, j) = acc as f32;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive_across_blocking_edges() {
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (7, 13, 11),
            (8, 8, 8),
            (9, 17, 33),
            (130, 70, 150),
            (257, 300, 129),
        ] {
            let a = randt(m, k, 1);
            let b = randt(k, n, 2);
            let mut c = Tensor::zeros(m, n);
            gemm(MatRef::dense(&a), MatRef::dense(&b), &mut c);
            let want = naive(&a, &b);
            assert!(c.max_abs_diff(&want) < 1e-3, "({m},{k},{n})");
        }
    }

    #[test]
    fn gemm_transposed_views() {
        let a = randt(23, 31, 3); // used as Aᵀ: logical 31 x 23
        let b = randt(23, 19, 4);
        let mut c = Tensor::zeros(31, 19);
        gemm(MatRef::transposed(&a), MatRef::dense(&b), &mut c);
        let want = naive(&a.transpose(), &b);
        assert!(c.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn gemm_zero_dims_are_noops() {
        for &(m, k, n) in &[(0usize, 5usize, 7usize), (5, 0, 7), (5, 7, 0)] {
            let a = randt(m, k, 5);
            let b = randt(k, n, 6);
            let mut c = Tensor::zeros(m, n);
            gemm(MatRef::dense(&a), MatRef::dense(&b), &mut c);
            assert_eq!(c.data, vec![0.0f32; m * n]);
        }
    }

    #[test]
    fn gemm_is_bit_identical_across_thread_counts_and_grains() {
        let _g = pool::knob_test_lock();
        // Shape big enough to clear PAR_FLOP_THRESHOLD and straddle the
        // MR/KC boundaries; every (threads, grain) combination must agree
        // bit-for-bit because each C element's accumulation order lives
        // entirely inside its owning task.
        let (m, k, n) = (163usize, 291usize, 137usize);
        let a = randt(m, k, 7);
        let b = randt(k, n, 8);
        let reference = {
            threads::set_threads_override(1);
            let mut c = Tensor::zeros(m, n);
            gemm(MatRef::dense(&a), MatRef::dense(&b), &mut c);
            c
        };
        for nt in [2usize, 3, 16] {
            for grain in [0usize, 8, 40] {
                threads::set_threads_override(nt);
                pool::set_grain_override(grain);
                let mut c = Tensor::zeros(m, n);
                gemm(MatRef::dense(&a), MatRef::dense(&b), &mut c);
                assert_eq!(c.data, reference.data, "nt={nt} grain={grain}");
            }
        }
        threads::set_threads_override(0);
        pool::set_grain_override(0);
    }

    #[test]
    fn gemm_is_bit_identical_across_blockings() {
        let _g = pool::knob_test_lock();
        // Blocking only regroups the ascending-k accumulation (KC blocks
        // in order, k ascending inside each) — it cannot reorder any
        // element's f32 sequence, so the autotuner is free to persist any
        // candidate without breaking sweep byte-reproducibility.
        let (m, k, n) = (150usize, 270usize, 190usize);
        let a = randt(m, k, 9);
        let b = randt(k, n, 10);
        tune::set_blocking_override(None).unwrap();
        let reference = {
            let mut c = Tensor::zeros(m, n);
            gemm(MatRef::dense(&a), MatRef::dense(&b), &mut c);
            c
        };
        for blk in tune::candidates() {
            tune::set_blocking_override(Some(blk)).unwrap();
            let mut c = Tensor::zeros(m, n);
            gemm(MatRef::dense(&a), MatRef::dense(&b), &mut c);
            assert_eq!(c.data, reference.data, "{blk:?}");
        }
        tune::set_blocking_override(None).unwrap();
    }

    #[test]
    fn task_grain_tracks_tuned_mc_and_stays_mr_aligned() {
        let _g = pool::knob_test_lock();
        tune::set_blocking_override(None).unwrap();
        for blk in tune::candidates() {
            tune::set_blocking_override(Some(blk)).unwrap();
            for (m, nt) in [(512usize, 1usize), (512, 4), (4096, 2), (7, 3)] {
                let g = gemm_task_grain(m, nt);
                assert!(g >= MR && g % MR == 0, "grain {g} not MR-aligned");
                assert!(
                    g <= blk.mc,
                    "grain {g} exceeds tuned MC {} (m={m}, nt={nt})",
                    blk.mc
                );
            }
        }
        tune::set_blocking_override(None).unwrap();
    }
}
