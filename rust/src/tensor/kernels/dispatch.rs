//! Runtime SIMD dispatch for the GEMM microkernel: a one-time cached CPU
//! feature probe selects the widest implementation the hardware supports,
//! overridable (strictly) via `RMM_SIMD` or the config/CLI layer.
//!
//! # Levels and probe order
//!
//! | level      | tile strategy                         | requires            |
//! |------------|---------------------------------------|---------------------|
//! | `avx512`   | 4 × zmm row-pair accumulators         | AVX-512F (probed)   |
//! | `avx2`     | 8 × ymm row accumulators              | AVX2 (probed)       |
//! | `neon`     | 16 × q-register half-row accumulators | aarch64 (baseline)  |
//! | `portable` | autovectorized [`micro::kernel`]      | —                   |
//! | `scalar`   | per-element reference loop            | —                   |
//!
//! The auto probe picks the first *supported* level in the order
//! `avx512 → avx2 → neon → portable`; `scalar` is never auto-selected
//! (it exists as the forced reference for the dispatch-identity tests).
//!
//! # Bit-identity contract
//!
//! Every level performs, per C element, the *same* f32 operation
//! sequence as the portable tile: ascending-k, one IEEE multiply then
//! one IEEE add per step, never a fused multiply-add (no intrinsic FMA,
//! and Rust/LLVM do not contract separate mul/add without fast-math).
//! SIMD lane width only changes how many independent elements advance
//! per instruction — IEEE lane arithmetic is element-independent and the
//! packers' zero padding contributes exact zeros — so kernel output is
//! bit-identical across every dispatch level.  `prop_kernels.rs` pins
//! this across levels × thread counts; `scripts/ci.sh` gates it end to
//! end with `RMM_SIMD=portable` vs auto.
//!
//! # Override precedence
//!
//! [`set_simd_override`] (config `kernels.simd` / CLI `--simd`) >
//! `RMM_SIMD` env (read once, cached; malformed or unsupported values
//! are *rejected*, never silently defaulted) > the probe.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use anyhow::{bail, Result};

use super::micro::{self, MR, NR};

/// Env var forcing a dispatch level (`scalar|portable|avx2|avx512|neon`).
pub const SIMD_ENV: &str = "RMM_SIMD";

/// A microkernel implementation selectable at runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// Per-element reference loop (never auto-selected).
    Scalar,
    /// The autovectorized generic tile ([`micro::kernel`]).
    Portable,
    /// 8-wide AVX2 row accumulators (x86/x86_64 with AVX2).
    Avx2,
    /// 16-wide AVX-512F row-pair accumulators (x86/x86_64 with AVX-512F).
    Avx512,
    /// 4-wide NEON half-row accumulators (aarch64 baseline).
    Neon,
}

impl SimdLevel {
    pub const ALL: [SimdLevel; 5] = [
        SimdLevel::Scalar,
        SimdLevel::Portable,
        SimdLevel::Avx2,
        SimdLevel::Avx512,
        SimdLevel::Neon,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Portable => "portable",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Avx512 => "avx512",
            SimdLevel::Neon => "neon",
        }
    }

    pub fn parse(s: &str) -> Option<SimdLevel> {
        Some(match s.to_ascii_lowercase().as_str() {
            "scalar" => SimdLevel::Scalar,
            "portable" => SimdLevel::Portable,
            "avx2" => SimdLevel::Avx2,
            "avx512" => SimdLevel::Avx512,
            "neon" => SimdLevel::Neon,
            _ => return None,
        })
    }

    /// Strict parse with the canonical knob error shape (name, offending
    /// value, valid domain) — config/CLI/env surfaces all route through
    /// this so a typo can never silently fall back to the probe.
    pub fn parse_or_err(s: &str) -> Result<SimdLevel> {
        SimdLevel::parse(s).ok_or_else(|| {
            anyhow::anyhow!(
                "{SIMD_ENV} must be one of scalar|portable|avx2|avx512|neon, got '{s}'"
            )
        })
    }

    /// Whether this build, on this CPU, can run the level right now.
    pub fn supported(self) -> bool {
        match self {
            SimdLevel::Scalar | SimdLevel::Portable => true,
            SimdLevel::Avx2 => {
                #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(any(target_arch = "x86", target_arch = "x86_64")))]
                {
                    false
                }
            }
            SimdLevel::Avx512 => {
                #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
                {
                    std::arch::is_x86_feature_detected!("avx512f")
                }
                #[cfg(not(any(target_arch = "x86", target_arch = "x86_64")))]
                {
                    false
                }
            }
            SimdLevel::Neon => cfg!(target_arch = "aarch64"),
        }
    }
}

/// The levels this build + CPU can actually run, in `ALL` order.
pub fn supported_levels() -> Vec<SimdLevel> {
    SimdLevel::ALL.iter().copied().filter(|l| l.supported()).collect()
}

/// The auto-selected level: widest supported, `scalar` never chosen.
/// Cached after the first call (feature detection itself caches too, but
/// the fixed answer makes the precedence chain obviously race-free).
pub fn probe() -> SimdLevel {
    static PROBED: OnceLock<SimdLevel> = OnceLock::new();
    *PROBED.get_or_init(|| {
        for l in [SimdLevel::Avx512, SimdLevel::Avx2, SimdLevel::Neon] {
            if l.supported() {
                return l;
            }
        }
        SimdLevel::Portable
    })
}

// 0 = no override; otherwise 1 + index into SimdLevel::ALL.
static OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Install (or clear, with `None`) the process-global dispatch override —
/// the config/CLI layer's entry point, highest precedence.  Rejects
/// levels this CPU cannot run instead of letting the first GEMM trap.
pub fn set_simd_override(level: Option<SimdLevel>) -> Result<()> {
    match level {
        None => OVERRIDE.store(0, Ordering::Relaxed),
        Some(l) => {
            if !l.supported() {
                bail!(
                    "{SIMD_ENV} level '{}' is not supported by this CPU (supported: {})",
                    l.name(),
                    supported_levels()
                        .iter()
                        .map(|l| l.name())
                        .collect::<Vec<_>>()
                        .join(", ")
                );
            }
            let idx = SimdLevel::ALL.iter().position(|&x| x == l).unwrap() as u8;
            OVERRIDE.store(idx + 1, Ordering::Relaxed);
        }
    }
    Ok(())
}

fn override_level() -> Option<SimdLevel> {
    match OVERRIDE.load(Ordering::Relaxed) {
        0 => None,
        v => Some(SimdLevel::ALL[(v - 1) as usize]),
    }
}

/// Strict read of `RMM_SIMD`: unknown names and valid-but-unsupported
/// levels are both errors.  The CLI calls this once at startup so a bad
/// value surfaces as a normal error message; library paths that hit the
/// cached copy first ([`env_level`]) panic with the same text.
pub fn check_env() -> Result<Option<SimdLevel>> {
    match std::env::var(SIMD_ENV) {
        Err(_) => Ok(None),
        Ok(v) => {
            let l = SimdLevel::parse_or_err(v.trim())?;
            if !l.supported() {
                // Route through the same unsupported-level message.
                set_simd_override(Some(l))?;
            }
            Ok(Some(l))
        }
    }
}

fn env_level() -> Option<SimdLevel> {
    static ENV: OnceLock<Option<SimdLevel>> = OnceLock::new();
    *ENV.get_or_init(|| check_env().unwrap_or_else(|e| panic!("{e}")))
}

/// The level the next kernel call will run at: override > env > probe.
pub fn active_level() -> SimdLevel {
    override_level().or_else(env_level).unwrap_or_else(probe)
}

/// The shared microkernel shape: `kernel(kc, ap, bp, acc)` with `ap` an
/// MR-row k-major panel and `bp` an NR-column k-major panel (see
/// [`micro::kernel`]).  A plain fn pointer so the blocked drivers fetch
/// it once per GEMM and pool tasks copy it freely.
pub type MicroKernel = fn(usize, &[f32], &[f32], &mut [[f32; NR]; MR]);

/// Per-element reference microkernel: the same ascending-k mul-then-add
/// sequence per C element as every other level, written as the plainest
/// possible loop.  Forced via `RMM_SIMD=scalar`; the dispatch-identity
/// tests diff every other level against it.
pub fn kernel_scalar(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
    for (r, row) in acc.iter_mut().enumerate() {
        for (c, out) in row.iter_mut().enumerate() {
            let mut v = *out;
            for k in 0..kc {
                v += ap[k * MR + r] * bp[k * NR + c];
            }
            *out = v;
        }
    }
}

/// The microkernel implementing `level`.  Panics if the level is not
/// supported here — dispatch only hands out callable pointers, which is
/// what makes the `unsafe` target-feature kernels sound to wrap safely.
pub fn kernel_for(level: SimdLevel) -> MicroKernel {
    assert!(
        level.supported(),
        "SIMD level '{}' not supported on this CPU",
        level.name()
    );
    match level {
        SimdLevel::Scalar => kernel_scalar,
        SimdLevel::Portable => micro::kernel,
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        SimdLevel::Avx2 => super::micro_avx2::kernel,
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        SimdLevel::Avx512 => super::micro_avx512::kernel,
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => super::micro_neon::kernel,
        #[allow(unreachable_patterns)]
        _ => unreachable!("supported() gated above"),
    }
}

/// The microkernel for [`active_level`] — what the blocked GEMM driver
/// and the streamed projection fetch once per call.
pub fn active_kernel() -> MicroKernel {
    kernel_for(active_level())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_and_strict_rejection() {
        for l in SimdLevel::ALL {
            assert_eq!(SimdLevel::parse(l.name()), Some(l));
        }
        assert_eq!(SimdLevel::parse("AVX2"), Some(SimdLevel::Avx2));
        assert_eq!(SimdLevel::parse("sse9"), None);
        let err = SimdLevel::parse_or_err("sse9").unwrap_err().to_string();
        assert!(err.contains("RMM_SIMD"), "{err}");
        assert!(err.contains("'sse9'"), "{err}");
        assert!(err.contains("avx512"), "{err}");
    }

    #[test]
    fn probe_never_picks_scalar_and_is_supported() {
        let p = probe();
        assert_ne!(p, SimdLevel::Scalar);
        assert!(p.supported());
        assert_eq!(probe(), p); // cached, stable
    }

    #[test]
    fn override_precedence_and_unsupported_rejection() {
        // Portable is supported everywhere; forcing it must stick.
        set_simd_override(Some(SimdLevel::Portable)).unwrap();
        assert_eq!(active_level(), SimdLevel::Portable);
        set_simd_override(None).unwrap();
        // Whatever env/probe now yields must be a supported level.
        assert!(active_level().supported());
        // An unsupported level errors instead of installing.
        if let Some(&bad) = SimdLevel::ALL.iter().find(|l| !l.supported()) {
            let err = set_simd_override(Some(bad)).unwrap_err().to_string();
            assert!(err.contains(bad.name()), "{err}");
            assert!(err.contains("not supported"), "{err}");
        }
    }

    #[test]
    fn every_supported_level_matches_scalar_bitwise() {
        // Microtile-granularity identity check (prop_kernels.rs pins the
        // full GEMM/projection surface): same packed panels through every
        // callable kernel must produce byte-identical tiles.
        let kc = 37;
        let ap: Vec<f32> = (0..kc * MR)
            .map(|i| ((i * 2654435761usize) % 1000) as f32 * 1e-3 - 0.5)
            .collect();
        let bp: Vec<f32> = (0..kc * NR)
            .map(|i| ((i * 40503usize) % 997) as f32 * 2e-3 - 1.0)
            .collect();
        let mut want = [[0.1f32; NR]; MR];
        kernel_scalar(kc, &ap, &bp, &mut want);
        for l in supported_levels() {
            let mut got = [[0.1f32; NR]; MR];
            kernel_for(l)(kc, &ap, &bp, &mut got);
            for r in 0..MR {
                assert_eq!(
                    got[r].map(f32::to_bits),
                    want[r].map(f32::to_bits),
                    "level {} row {r}",
                    l.name()
                );
            }
        }
    }
}
