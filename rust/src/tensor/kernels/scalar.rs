//! Reference scalar backend: the seed crate's single-threaded blocked
//! loops, kept as the correctness baseline the `Packed` backend is pinned
//! against (and as the honest "before" side of BENCH_kernels.json).
//!
//! One deliberate change from the seed: the `if aik == 0.0 { continue; }`
//! branch inside the k-loop is gone.  It bought nothing on dense inputs
//! and put a data-dependent branch in front of every vectorizable axpy;
//! the only genuinely sparse sketch family (RowSample) now has an explicit
//! gather path in `rmm::sketch` instead of relying on zero-skipping here.

use crate::tensor::Tensor;

const BLOCK: usize = 64;

/// C = A · B, i-k-j loop order with blocking.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Tensor::zeros(m, n);
    for i0 in (0..m).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(m);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in i0..i1 {
                let arow = &a.data[i * k..(i + 1) * k];
                let crow = &mut c.data[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = arow[kk];
                    let brow = &b.data[kk * n..(kk + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    }
    c
}

/// C = Aᵀ · B  (A: (k, m), B: (k, n) → C: (m, n)) without materializing Aᵀ.
pub fn matmul_at(a: &Tensor, b: &Tensor) -> Tensor {
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Tensor::zeros(m, n);
    for kk in 0..k {
        let arow = &a.data[kk * m..(kk + 1) * m];
        let brow = &b.data[kk * n..(kk + 1) * n];
        for i in 0..m {
            let aik = arow[i];
            let crow = &mut c.data[i * n..(i + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
    c
}

/// C = A · Bᵀ  (A: (m, k), B: (n, k) → C: (m, n)) without materializing Bᵀ.
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Tensor::zeros(m, n);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut c.data[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            let brow = &b.data[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (av, bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            *cv = acc;
        }
    }
    c
}
