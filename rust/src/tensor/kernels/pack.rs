//! Panel packing: copy cache-block-sized pieces of A and B into contiguous
//! microkernel-order buffers (k-major MR-row / NR-column panels), zero-
//! padding block edges so the microkernel never branches on bounds.
//!
//! Packing reads through a strided [`MatRef`] view, which is how the `Aᵀ·B`
//! and `A·Bᵀ` variants reuse the same kernel without materializing a
//! transpose: the view swaps strides instead.

use super::micro::{MR, NR};
use super::packed::MatRef;

/// Pack the `mc × kc` block of `a` starting at (`i0`, `k0`) into MR-row
/// panels: `dst[p*MR*kc + k*MR + r] = a[i0 + p*MR + r, k0 + k]`, rows past
/// `mc` zero-filled.  `dst` must hold `ceil(mc/MR)*MR*kc` floats.
pub fn pack_a(dst: &mut [f32], a: MatRef<'_>, i0: usize, mc: usize, k0: usize, kc: usize) {
    let panels = (mc + MR - 1) / MR;
    debug_assert!(dst.len() >= panels * MR * kc);
    for p in 0..panels {
        let base = p * MR * kc;
        let rows = MR.min(mc - p * MR);
        for k in 0..kc {
            let d = &mut dst[base + k * MR..base + k * MR + MR];
            for (r, dv) in d.iter_mut().enumerate() {
                *dv = if r < rows { a.at(i0 + p * MR + r, k0 + k) } else { 0.0 };
            }
        }
    }
}

/// Pack the `kc × nc` block of `b` starting at (`k0`, `j0`) into NR-column
/// panels: `dst[p*NR*kc + k*NR + c] = b[k0 + k, j0 + p*NR + c]`, columns
/// past `nc` zero-filled.  `dst` must hold `ceil(nc/NR)*NR*kc` floats.
pub fn pack_b(dst: &mut [f32], b: MatRef<'_>, k0: usize, kc: usize, j0: usize, nc: usize) {
    let panels = (nc + NR - 1) / NR;
    debug_assert!(dst.len() >= panels * NR * kc);
    for p in 0..panels {
        let base = p * NR * kc;
        let cols = NR.min(nc - p * NR);
        if b.col_stride == 1 && cols == NR {
            // Contiguous rows (the dense row-major case): straight memcpy
            // of each k-row of the panel.
            for k in 0..kc {
                let src0 = (k0 + k) * b.row_stride + (j0 + p * NR);
                dst[base + k * NR..base + k * NR + NR]
                    .copy_from_slice(&b.data[src0..src0 + NR]);
            }
        } else {
            for k in 0..kc {
                let d = &mut dst[base + k * NR..base + k * NR + NR];
                for (c, dv) in d.iter_mut().enumerate() {
                    *dv = if c < cols { b.at(k0 + k, j0 + p * NR + c) } else { 0.0 };
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn pack_a_layout_and_padding() {
        let t = Tensor::from_fn(5, 4, |i, j| (i * 10 + j) as f32);
        let a = MatRef::dense(&t);
        let (mc, kc) = (5, 3);
        let mut dst = vec![f32::NAN; ((mc + MR - 1) / MR) * MR * kc];
        pack_a(&mut dst, a, 0, mc, 1, kc);
        // element (i=2, k=1) -> a[2, 2] = 22, stored at k*MR + r = 1*8 + 2
        assert_eq!(dst[MR + 2], 22.0);
        // padded rows are zero
        assert_eq!(dst[MR + 7], 0.0);
    }

    #[test]
    fn pack_b_dense_and_strided_agree() {
        let t = Tensor::from_fn(6, 9, |i, j| (i * 100 + j) as f32);
        let dense = MatRef::dense(&t);
        let tt = t.transpose(); // 9 x 6
        let strided = MatRef::transposed(&tt); // logical 6 x 9 again
        let (kc, nc) = (4, 9);
        let npanels = (nc + NR - 1) / NR;
        let mut d1 = vec![f32::NAN; npanels * NR * kc];
        let mut d2 = vec![f32::NAN; npanels * NR * kc];
        pack_b(&mut d1, dense, 1, kc, 0, nc);
        pack_b(&mut d2, strided, 1, kc, 0, nc);
        assert_eq!(d1, d2);
        // spot check: (k=0, j=3) -> b[1, 3] = 103 at panel 0, offset 0*NR+3
        assert_eq!(d1[3], 103.0);
        // padded col in panel 1: j = 8 valid (108..), j = 9.. zero
        assert_eq!(d1[NR * kc + 1], 0.0); // panel 1, k=0, c=1 -> j=9 -> pad
    }
}
