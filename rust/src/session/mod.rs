//! Per-worker warm session: the long-lived state one sweep worker (or
//! bench driver) carries **across** fine-tuning runs, so same-variant
//! cells stop paying full cold start.
//!
//! The paper's evidence is grids of independent runs (variant × task ×
//! ρ × seed); a worker process used to rebuild the tokenizer, `TaskGen`,
//! and `Trainer` from the manifest for every cell, and only reused the
//! engine's compile cache by accident of worker lifetime.  A [`Session`]
//! makes that reuse deliberate.  It owns:
//!
//! * the [`Engine`] — its executable cache ([`crate::runtime::ExeCache`])
//!   persists across cells, so every same-variant cell after the first
//!   reuses compiled `fwd`/`bwd`/`eval` executables (hit/miss/evict
//!   counters surface in `RunResult` and `rmm_micro --json`);
//! * the [`Manifest`] (optional: data-only experiments such as the
//!   `mockdata` selftest grid run without artifacts);
//! * per-variant [`TrainerSetup`]s — the init-param blob read once per
//!   warm variant, with param names/sizes — shared across that variant's
//!   cells;
//! * per-vocab [`Tokenizer`]s (Arc-backed, so a cache hit is a handle
//!   clone);
//! * per-`(task, seq_len, vocab, batch_size, seed)` dev-batch sets for
//!   the final dev-metric pass, bounded by [`DEV_CACHE_CAP`] with
//!   oldest-first eviction.
//!
//! # The warm ≡ cold contract
//!
//! Caching must be **observation-free**: every cached object is either a
//! pure function of its key (tokenizer, dev batches — regenerating them
//! yields identical bytes) or cloned per cell from pristine state
//! (`TrainerSetup::init_params`), and all randomness stays in seeded
//! Philox streams derived from per-cell seeds that never see cache
//! state.  A warm-session sweep therefore commits fragments
//! byte-identical to the cold serial path for any cell order, worker
//! count, and `--session-cache on|off` — pinned by
//! `tests/prop_session.rs` and the `sweep-selftest --grid data` CI gate.
//! `--session-cache off` keeps the session API but rebuilds everything
//! per call (the cold path made explicit, and the control arm of the
//! byte-identity gate).

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::TrainerSetup;
use crate::data::{Batch, Batcher, Split, Task, TaskGen, Tokenizer};
use crate::runtime::{Engine, Manifest};
use crate::sweep::fleet::ArtifactCache;

/// Most dev-batch sets kept warm at once (oldest evicted first).  Dev
/// splits are small, but a long sweep can touch many (task, seed) pairs
/// and an unbounded cache would grow with the grid.
pub const DEV_CACHE_CAP: usize = 16;

/// Dev-batch sets a session keeps when it is retained *across* sweeps
/// by the daemon ([`Session::retain_across_sweeps`]).  Tighter than
/// [`DEV_CACHE_CAP`]: between sweeps only the hottest tail is worth
/// holding, since the next sweep's key set is unknown.
pub const CROSS_SWEEP_DEV_KEEP: usize = 4;

/// Cache traffic counters — scheduling/telemetry only, never results.
/// The artifact-cache counters (`art_*`) surface **only** here, i.e. in
/// worker stderr: like the exe-cache counters they are deliberately
/// kept out of fragment JSON, so shared-cache warm-start stays
/// invisible to merged reports.
#[derive(Debug, Default, Clone, Copy)]
pub struct SessionStats {
    pub setup_hits: u64,
    pub setup_misses: u64,
    pub tokenizer_hits: u64,
    pub tokenizer_misses: u64,
    pub dev_hits: u64,
    pub dev_misses: u64,
    pub dev_evictions: u64,
    /// Trainer setups warm-started from the shared on-disk cache.
    pub art_setup_hits: u64,
    /// Dev-batch sets warm-started from the shared on-disk cache.
    pub art_dev_hits: u64,
    /// Blobs this session published to the shared cache (first writer).
    pub art_publishes: u64,
}

impl SessionStats {
    /// One-line telemetry summary for worker stderr.
    pub fn summary(&self) -> String {
        format!(
            "setup {}h/{}m, tokenizer {}h/{}m, dev {}h/{}m/{}ev, \
             artifact-cache {}sh/{}dh/{}pub",
            self.setup_hits,
            self.setup_misses,
            self.tokenizer_hits,
            self.tokenizer_misses,
            self.dev_hits,
            self.dev_misses,
            self.dev_evictions,
            self.art_setup_hits,
            self.art_dev_hits,
            self.art_publishes
        )
    }
}

type DevKey = (Task, usize, usize, usize, u64);

pub struct Session {
    /// Present only for engine-backed sessions: the data-only path
    /// (`mockdata`, mock orchestration smokes) must stay runnable on
    /// hosts where PJRT client construction fails, and must not pay its
    /// startup cost for cells that never execute an artifact.
    engine: Option<Engine>,
    manifest: Option<Manifest>,
    caching: bool,
    setups: HashMap<String, Arc<TrainerSetup>>,
    tokenizers: HashMap<usize, Tokenizer>,
    dev_batches: HashMap<DevKey, Arc<Vec<Batch>>>,
    dev_order: VecDeque<DevKey>,
    /// Shared on-disk artifact cache (`--artifact-cache on`): fleet
    /// warm-start for trainer setups and dev-batch sets.  `None` (the
    /// default) keeps the per-process in-memory behavior exactly as
    /// before.
    artifacts: Option<ArtifactCache>,
    pub stats: SessionStats,
}

impl Session {
    /// A worker session over an artifact manifest (the real-cell path).
    pub fn new(engine: Engine, manifest: Manifest, caching: bool) -> Session {
        Session::build(Some(engine), Some(manifest), caching)
    }

    /// A session without engine or artifacts, for data-only experiments
    /// (`mockdata` cells): tokenizer + dataset caches work, engine cells
    /// fail fast.
    pub fn data_only(caching: bool) -> Session {
        Session::build(None, None, caching)
    }

    fn build(engine: Option<Engine>, manifest: Option<Manifest>, caching: bool) -> Session {
        Session {
            engine,
            manifest,
            caching,
            setups: HashMap::new(),
            tokenizers: HashMap::new(),
            dev_batches: HashMap::new(),
            dev_order: VecDeque::new(),
            artifacts: None,
            stats: SessionStats::default(),
        }
    }

    /// Attach (or detach) the sweep's shared artifact cache.  Daemon
    /// workers re-attach per sweep — the cache lives under each sweep
    /// directory, while the session outlives sweeps.  The cache assumes
    /// immutable artifact dirs (the `make artifacts` contract): setup
    /// blobs are keyed by manifest dir + variant, dev blobs by the full
    /// `DevKey`, and every blob is digest-verified on load, so a torn
    /// or mismatched entry costs a regeneration, never a wrong result.
    pub fn set_artifact_cache(&mut self, cache: Option<ArtifactCache>) {
        self.artifacts = cache;
    }

    /// Is a shared artifact cache attached?
    pub fn has_artifact_cache(&self) -> bool {
        self.artifacts.is_some()
    }

    /// Is warm-state reuse enabled (`--session-cache on`, the default)?
    pub fn caching(&self) -> bool {
        self.caching
    }

    pub fn manifest(&self) -> Result<&Manifest> {
        self.manifest
            .as_ref()
            .context("session has no artifact manifest (data-only session)")
    }

    /// Split-borrow the engine and manifest together — the shape a
    /// trainer loop needs (`Trainer` borrows the manifest for its whole
    /// life while every step mutably borrows the engine).
    pub fn engine_manifest(&mut self) -> Result<(&mut Engine, &Manifest)> {
        match (self.engine.as_mut(), self.manifest.as_ref()) {
            (Some(e), Some(m)) => Ok((e, m)),
            _ => Err(anyhow::anyhow!(
                "session has no engine/manifest (data-only session)"
            )),
        }
    }

    /// The tokenizer for a vocabulary size — a handle clone on a warm
    /// hit, a fresh build otherwise.  Pure in `vocab`, so caching can
    /// never change a generated stream.
    pub fn tokenizer(&mut self, vocab: usize) -> Tokenizer {
        if self.caching {
            if let Some(t) = self.tokenizers.get(&vocab) {
                self.stats.tokenizer_hits += 1;
                return t.clone();
            }
        }
        self.stats.tokenizer_misses += 1;
        let t = Tokenizer::new(vocab);
        if self.caching {
            self.tokenizers.insert(vocab, t.clone());
        }
        t
    }

    /// The warm, variant-level trainer state (init params + param-spec
    /// plumbing), loaded once per warm variant.  Per-cell trainers clone
    /// the pristine params out of it (`Trainer::from_setup`), so reuse
    /// is invisible to results.
    pub fn trainer_setup(&mut self, variant_name: &str) -> Result<Arc<TrainerSetup>> {
        if self.caching {
            if let Some(s) = self.setups.get(variant_name) {
                self.stats.setup_hits += 1;
                return Ok(s.clone());
            }
        }
        self.stats.setup_misses += 1;
        // Shared-cache warm start: a fresh worker process loads the
        // variant's spilled setup blob instead of re-reading the init
        // params cold; the blob was encoded bit-exactly from the same
        // pure manifest load, so reuse is observation-free.
        let art_key = match (&self.artifacts, self.manifest.as_ref()) {
            (Some(_), Some(m)) => {
                Some(ArtifactCache::setup_key(&m.dir, variant_name))
            }
            _ => None,
        };
        if let (Some(cache), Some(key)) = (self.artifacts.clone(), art_key) {
            if let Some(setup) = cache.load_setup(key) {
                if setup.variant_name == variant_name {
                    self.stats.art_setup_hits += 1;
                    let setup = Arc::new(setup);
                    if self.caching {
                        self.setups.insert(variant_name.to_string(), setup.clone());
                    }
                    return Ok(setup);
                }
            }
        }
        let manifest = self.manifest()?;
        let variant = manifest.variant(variant_name)?;
        let setup = Arc::new(TrainerSetup::load(manifest, variant)?);
        if let (Some(cache), Some(key)) = (self.artifacts.clone(), art_key) {
            // Publish best-effort: a failed publish costs the next
            // process its warm start, never this cell its result.
            if let Ok(true) = cache.store_setup(key, &setup) {
                self.stats.art_publishes += 1;
            }
        }
        if self.caching {
            self.setups.insert(variant_name.to_string(), setup.clone());
        }
        Ok(setup)
    }

    /// The canonical dev-batch sequence for `(task, seq_len, vocab,
    /// batch_size, seed)` — exactly what `Batcher::new(gen, Dev, bsz, 0)`
    /// yields, materialized once and shared across the same-key cells of
    /// a sweep (same task + seed at different ρ/sketch).  Returns `None`
    /// when caching is off: callers then stream the identical sequence
    /// themselves (e.g. through the eval prefetcher).
    pub fn cached_dev_batches(
        &mut self,
        task: Task,
        seq_len: usize,
        vocab: usize,
        batch_size: usize,
        seed: u64,
    ) -> Option<Arc<Vec<Batch>>> {
        if !self.caching {
            return None;
        }
        let key = (task, seq_len, vocab, batch_size, seed);
        if let Some(b) = self.dev_batches.get(&key) {
            self.stats.dev_hits += 1;
            return Some(b.clone());
        }
        self.stats.dev_misses += 1;
        let art_key = self
            .artifacts
            .as_ref()
            .map(|_| ArtifactCache::dev_key(task.name(), seq_len, vocab, batch_size, seed));
        // Shared-cache warm start: the canonical batch sequence is a
        // pure function of the key, so a blob another worker spilled is
        // bit-identical to what regeneration would produce.
        if let (Some(cache), Some(k)) = (self.artifacts.clone(), art_key) {
            if let Some(b) = cache.load_dev(k) {
                self.stats.art_dev_hits += 1;
                let batches = Arc::new(b);
                self.insert_dev(key, batches.clone());
                return Some(batches);
            }
        }
        let tok = self.tokenizer(vocab);
        let gen = TaskGen::new(task, &tok, seq_len, seed);
        let batches: Arc<Vec<Batch>> =
            Arc::new(Batcher::new(&gen, Split::Dev, batch_size, 0).collect());
        if let (Some(cache), Some(k)) = (self.artifacts.clone(), art_key) {
            if let Ok(true) = cache.store_dev(k, &batches) {
                self.stats.art_publishes += 1;
            }
        }
        self.insert_dev(key, batches.clone());
        Some(batches)
    }

    /// Insert a dev-batch set under the bounded-cache policy (oldest
    /// evicted first past [`DEV_CACHE_CAP`]).
    fn insert_dev(&mut self, key: DevKey, batches: Arc<Vec<Batch>>) {
        while self.dev_batches.len() >= DEV_CACHE_CAP {
            match self.dev_order.pop_front() {
                Some(old) => {
                    if self.dev_batches.remove(&old).is_some() {
                        self.stats.dev_evictions += 1;
                    }
                }
                None => break,
            }
        }
        self.dev_order.push_back(key);
        self.dev_batches.insert(key, batches);
    }

    /// Drop every warm cache (trainer setups, tokenizers, dev batches)
    /// while keeping the engine, manifest, caching mode, and stats.
    /// Safe at any cell boundary by the warm ≡ cold contract: every
    /// evicted object is regenerated byte-identically on next use, so
    /// eviction can shift hit/miss counters but never a result.  The
    /// chaos harness's `session.evict` fault calls this between cells
    /// to prove exactly that.
    pub fn evict_warm_state(&mut self) {
        self.setups.clear();
        self.tokenizers.clear();
        self.dev_batches.clear();
        self.dev_order.clear();
    }

    /// Cross-sweep retention policy for daemon workers: keep the warm
    /// setups and tokenizers (small, variant-keyed, exactly what the
    /// next sweep of the same tenant re-hits) but trim the dev-batch
    /// cache — the bulky, per-(task, seed) state — down to
    /// [`CROSS_SWEEP_DEV_KEEP`] newest entries.  Safe at any sweep
    /// boundary by the warm ≡ cold contract: retention can only shift
    /// hit/miss counters, never a committed fragment.
    pub fn retain_across_sweeps(&mut self) {
        while self.dev_batches.len() > CROSS_SWEEP_DEV_KEEP {
            match self.dev_order.pop_front() {
                Some(old) => {
                    if self.dev_batches.remove(&old).is_some() {
                        self.stats.dev_evictions += 1;
                    }
                }
                None => break,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn data_session(caching: bool) -> Session {
        Session::data_only(caching)
    }

    #[test]
    fn data_only_session_has_no_manifest() {
        let mut s = data_session(true);
        assert!(s.manifest().is_err());
        assert!(s.engine_manifest().is_err());
        assert!(s.trainer_setup("any").is_err());
    }

    #[test]
    fn tokenizer_cache_hits_and_misses() {
        let mut s = data_session(true);
        let a = s.tokenizer(64);
        let b = s.tokenizer(64);
        let c = s.tokenizer(128);
        assert_eq!(a.vocab_size(), b.vocab_size());
        assert_eq!(c.vocab_size(), 128);
        assert_eq!(s.stats.tokenizer_hits, 1);
        assert_eq!(s.stats.tokenizer_misses, 2);

        let mut cold = data_session(false);
        cold.tokenizer(64);
        cold.tokenizer(64);
        assert_eq!(cold.stats.tokenizer_hits, 0);
        assert_eq!(cold.stats.tokenizer_misses, 2);
    }

    #[test]
    fn dev_cache_returns_canonical_batches_and_bounds_growth() {
        let mut s = data_session(true);
        let warm = s.cached_dev_batches(Task::Wnli, 16, 64, 8, 3).unwrap();
        // identical to a fresh cold regeneration
        let tok = Tokenizer::new(64);
        let gen = TaskGen::new(Task::Wnli, &tok, 16, 3);
        let cold: Vec<Batch> = Batcher::new(&gen, Split::Dev, 8, 0).collect();
        assert_eq!(warm.len(), cold.len());
        for (a, b) in warm.iter().zip(&cold) {
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.labels_f, b.labels_f);
            assert_eq!(a.valid, b.valid);
        }
        // a second fetch hits
        let again = s.cached_dev_batches(Task::Wnli, 16, 64, 8, 3).unwrap();
        assert_eq!(s.stats.dev_hits, 1);
        assert!(Arc::ptr_eq(&warm, &again));
        // the cache stays bounded under many distinct keys
        for seed in 0..(2 * DEV_CACHE_CAP as u64) {
            s.cached_dev_batches(Task::Wnli, 16, 64, 8, 100 + seed);
        }
        assert!(s.dev_batches.len() <= DEV_CACHE_CAP);
        assert!(s.stats.dev_evictions > 0);
    }

    #[test]
    fn caching_off_returns_no_dev_cache() {
        let mut s = data_session(false);
        assert!(s.cached_dev_batches(Task::Wnli, 16, 64, 8, 3).is_none());
        assert_eq!(s.stats.dev_misses, 0);
    }

    #[test]
    fn evicted_warm_state_regenerates_identically() {
        let mut s = data_session(true);
        let before = s.cached_dev_batches(Task::Wnli, 16, 64, 8, 3).unwrap();
        s.evict_warm_state();
        assert!(s.dev_batches.is_empty() && s.tokenizers.is_empty());
        // the refetch is a miss (the Arc is new) with identical bytes
        let after = s.cached_dev_batches(Task::Wnli, 16, 64, 8, 3).unwrap();
        assert!(!Arc::ptr_eq(&before, &after));
        assert_eq!(before.len(), after.len());
        for (a, b) in before.iter().zip(after.iter()) {
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.labels_f, b.labels_f);
            assert_eq!(a.valid, b.valid);
        }
        assert_eq!(s.stats.dev_misses, 2);
    }

    #[test]
    fn cross_sweep_retention_trims_dev_batches_oldest_first_keeps_the_rest() {
        let mut s = data_session(true);
        s.tokenizer(64);
        for seed in 0..DEV_CACHE_CAP as u64 {
            s.cached_dev_batches(Task::Wnli, 16, 64, 8, seed).unwrap();
        }
        let evictions_before = s.stats.dev_evictions;
        s.retain_across_sweeps();
        assert_eq!(s.dev_batches.len(), CROSS_SWEEP_DEV_KEEP);
        assert_eq!(
            s.stats.dev_evictions,
            evictions_before + (DEV_CACHE_CAP - CROSS_SWEEP_DEV_KEEP) as u64
        );
        // Newest keys survive (oldest-first trim)...
        for seed in (DEV_CACHE_CAP - CROSS_SWEEP_DEV_KEEP) as u64..DEV_CACHE_CAP as u64 {
            assert!(s.dev_batches.contains_key(&(Task::Wnli, 16, 64, 8, seed)), "seed {seed}");
        }
        // ...tokenizers stay warm, and the call is idempotent.
        assert!(!s.tokenizers.is_empty());
        s.retain_across_sweeps();
        assert_eq!(s.dev_batches.len(), CROSS_SWEEP_DEV_KEEP);
        // A retained survivor still hits with identical content.
        let last = DEV_CACHE_CAP as u64 - 1;
        let hits_before = s.stats.dev_hits;
        s.cached_dev_batches(Task::Wnli, 16, 64, 8, last).unwrap();
        assert_eq!(s.stats.dev_hits, hits_before + 1);
    }

    #[test]
    fn stats_summary_is_one_line() {
        let s = SessionStats { setup_hits: 2, ..Default::default() };
        let line = s.summary();
        assert!(line.contains("setup 2h/0m"), "{line}");
        assert!(line.contains("artifact-cache 0sh/0dh/0pub"), "{line}");
        assert!(!line.contains('\n'));
    }

    #[test]
    fn artifact_cache_warm_starts_a_fresh_session_bit_identically() {
        let dir = std::env::temp_dir()
            .join(format!("rmm_session_artcache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // session A regenerates cold and publishes to the shared cache
        let mut a = data_session(true);
        a.set_artifact_cache(Some(ArtifactCache::open(&dir).unwrap()));
        assert!(a.has_artifact_cache());
        let published = a.cached_dev_batches(Task::Wnli, 16, 64, 8, 3).unwrap();
        assert_eq!(a.stats.art_dev_hits, 0);
        assert_eq!(a.stats.art_publishes, 1);
        // a brand-new session (a fresh worker process's stand-in) with
        // empty in-memory caches warm-starts from the shared blob …
        let mut b = data_session(true);
        b.set_artifact_cache(Some(ArtifactCache::open(&dir).unwrap()));
        let warm = b.cached_dev_batches(Task::Wnli, 16, 64, 8, 3).unwrap();
        assert_eq!(b.stats.dev_misses, 1, "in-memory cache was cold");
        assert_eq!(b.stats.art_dev_hits, 1, "disk cache must hit");
        assert_eq!(b.stats.art_publishes, 0);
        assert!(b.stats.summary().contains("artifact-cache 0sh/1dh/0pub"));
        // … and the loaded batches are bit-identical to regeneration
        let tok = Tokenizer::new(64);
        let gen = TaskGen::new(Task::Wnli, &tok, 16, 3);
        let cold: Vec<Batch> = Batcher::new(&gen, Split::Dev, 8, 0).collect();
        assert_eq!(warm.len(), cold.len());
        assert_eq!(published.len(), cold.len());
        for (w, c) in warm.iter().zip(&cold) {
            assert_eq!(w.tokens, c.tokens);
            assert_eq!(
                w.mask.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                c.mask.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(w.labels_i, c.labels_i);
            assert_eq!(
                w.labels_f.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                c.labels_f.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!((w.batch_size, w.seq_len, w.valid), (c.batch_size, c.seq_len, c.valid));
        }
        // a second fetch in session B is now an in-memory hit, not disk
        b.cached_dev_batches(Task::Wnli, 16, 64, 8, 3).unwrap();
        assert_eq!(b.stats.dev_hits, 1);
        assert_eq!(b.stats.art_dev_hits, 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
