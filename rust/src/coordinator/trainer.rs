//! The training coordinator: fwd → activation store → bwd → optimizer.
//!
//! This is where the three layers meet at run time.  Each step:
//!
//! 1. upload params + batch + step seed, execute the `fwd` artifact;
//! 2. stage every residual output in the [`ActivationStore`] — with RMM
//!    variants these are the sketches `X_proj = SᵀX`, so the store's peak
//!    byte count *is* the paper's stored-activation measurement;
//! 3. drain the store into the `bwd` artifact (the same seed reproduces
//!    every sketch matrix S bit-exactly inside the HLO);
//! 4. clip gradients, step the schedule + optimizer on the host.

use std::time::Instant;

use anyhow::{bail, Context as _, Result};

use crate::config::TrainConfig;
use crate::data::{Batch, Batcher, MetricAccum, Split, Task, TaskGen, Tokenizer};
use crate::memory::ActivationStore;
use crate::rng::philox;
use crate::runtime::{Engine, Entry, HostValue, Manifest, Role, Variant};

use super::optimizer::{Optimizer, OptimizerConfig};
use super::schedule::Schedule;

/// The variant-dependent, seed-independent half of trainer construction:
/// everything derived from the manifest alone — the pristine initial
/// parameters read from the variant's init blob, and the param-spec
/// plumbing (names, sizes) of its `fwd` entry.
///
/// The warm-session layer (`crate::session`) builds one `TrainerSetup`
/// per warm variant and reuses it across that variant's sweep cells;
/// [`Trainer::new`] builds a throwaway one per run (the cold path).
/// Reuse is observation-free by construction: a per-cell [`Trainer`]
/// *clones* the pristine init params, so no optimizer step, schedule
/// position, or Philox draw of one cell can leak into the next — the
/// warm path is byte-identical to cold (pinned by `tests/prop_session`).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainerSetup {
    /// Which manifest variant this setup answers for.
    pub variant_name: String,
    /// Pristine initial parameters, in `fwd`-entry param order.
    pub init_params: Vec<Vec<f32>>,
    pub param_names: Vec<String>,
    pub param_sizes: Vec<usize>,
}

impl TrainerSetup {
    /// Load the warm state for one variant (reads the init-param blob).
    pub fn load(manifest: &Manifest, variant: &Variant) -> Result<TrainerSetup> {
        let init_params = manifest.load_init_params(variant)?;
        let entry = variant.entry("fwd")?;
        let param_specs: Vec<_> =
            entry.args.iter().filter(|a| a.role == Role::Param).collect();
        Ok(TrainerSetup {
            variant_name: variant.name.clone(),
            init_params,
            param_names: param_specs.iter().map(|s| s.name.clone()).collect(),
            param_sizes: param_specs.iter().map(|s| s.elements()).collect(),
        })
    }
}

/// Variance-probe scalars (Fig. 4/7 series), present for probe variants.
#[derive(Debug, Clone, Copy)]
pub struct ProbeStats {
    pub d2_sgd: f64,
    pub d2_rmm: f64,
    pub alpha: f64,
    pub ratio_lhs: f64,
    pub bound_rhs: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub step: usize,
    pub loss: f64,
    pub lr: f64,
    pub grad_norm: f64,
    /// Peak bytes held in the activation store during this step.
    pub residual_bytes: usize,
    pub probe: Option<ProbeStats>,
    pub step_time_s: f64,
}

pub struct Trainer<'m> {
    pub manifest: &'m Manifest,
    pub variant: &'m Variant,
    pub task: Task,
    pub cfg: TrainConfig,
    pub params: Vec<Vec<f32>>,
    pub param_names: Vec<String>,
    opt: Optimizer,
    sched: Schedule,
    pub step_idx: usize,
    pub store: ActivationStore<HostValue>,
    pub peak_residual_bytes: usize,
}

impl<'m> Trainer<'m> {
    /// Cold-path construction: derive a fresh [`TrainerSetup`] and build
    /// from it.  Equivalent to the warm path by construction.
    pub fn new(
        manifest: &'m Manifest,
        variant: &'m Variant,
        task: Task,
        cfg: TrainConfig,
    ) -> Result<Trainer<'m>> {
        let setup = TrainerSetup::load(manifest, variant)?;
        Trainer::from_setup(manifest, variant, &setup, task, cfg)
    }

    /// Per-cell construction over warm, variant-level state: clones the
    /// pristine init params out of `setup` and re-derives everything
    /// seed/config-dependent (optimizer moments, LR schedule, step
    /// counter, activation store) from scratch.
    pub fn from_setup(
        manifest: &'m Manifest,
        variant: &'m Variant,
        setup: &TrainerSetup,
        task: Task,
        cfg: TrainConfig,
    ) -> Result<Trainer<'m>> {
        if setup.variant_name != variant.name {
            bail!(
                "trainer setup for variant '{}' used with variant '{}'",
                setup.variant_name,
                variant.name
            );
        }
        // Consistency: the task's head must match the variant geometry.
        if task.n_classes() != variant.config.n_classes
            || task.is_regression() != variant.config.regression
        {
            bail!(
                "task '{}' ({} classes, regression={}) does not match variant '{}' \
                 ({} classes, regression={})",
                task.name(),
                task.n_classes(),
                task.is_regression(),
                variant.name,
                variant.config.n_classes,
                variant.config.regression
            );
        }
        let opt = Optimizer::new(
            &cfg.optimizer,
            OptimizerConfig {
                weight_decay: cfg.weight_decay,
                beta1: cfg.beta1,
                beta2: cfg.beta2,
                eps: cfg.eps,
                momentum: 0.9,
            },
            &setup.param_names,
            &setup.param_sizes,
        )?;
        let sched =
            Schedule::from_config(&cfg.schedule, cfg.lr, cfg.warmup_steps, cfg.steps);
        Ok(Trainer {
            manifest,
            variant,
            task,
            cfg,
            params: setup.init_params.clone(),
            param_names: setup.param_names.clone(),
            opt,
            sched,
            step_idx: 0,
            store: ActivationStore::new(),
            peak_residual_bytes: 0,
        })
    }

    /// Warm-start parameters from a checkpoint by name+size match (loads
    /// the encoder body, keeps the fresh task head when shapes differ).
    pub fn load_matching(&mut self, names: &[String], params: &[Vec<f32>]) -> usize {
        let mut loaded = 0;
        for (name, value) in names.iter().zip(params) {
            if let Some(i) = self.param_names.iter().position(|n| n == name) {
                if self.params[i].len() == value.len() {
                    self.params[i].clone_from(value);
                    loaded += 1;
                }
            }
        }
        loaded
    }

    /// Per-step seed: Philox-derived from (cfg.seed, step) so every step's
    /// sketches are independent but exactly reproducible.
    pub fn step_seed(&self) -> [u32; 2] {
        let (lo, hi) = philox::split_seed(self.cfg.seed);
        let w = philox::philox4x32(
            [self.step_idx as u32, (self.step_idx >> 32) as u32, 0x57E9, 0],
            [lo, hi],
        );
        [w[0], w[1]]
    }

    fn batch_args(&self, entry: &Entry, batch: &Batch, seed: [u32; 2]) -> Result<Vec<HostValue>> {
        let mut args = Vec::with_capacity(entry.args.len());
        for spec in &entry.args {
            match spec.role {
                Role::Param => {
                    let i = args.len(); // params come first and in order
                    args.push(HostValue::F32(self.params[i].clone()));
                }
                Role::Tokens => args.push(HostValue::I32(batch.tokens.clone())),
                Role::Mask => args.push(HostValue::F32(batch.mask.clone())),
                Role::Labels => {
                    if self.variant.config.regression {
                        args.push(HostValue::F32(batch.labels_f.clone()));
                    } else {
                        args.push(HostValue::I32(batch.labels_i.clone()));
                    }
                }
                Role::Seed => args.push(HostValue::U32(seed.to_vec())),
                Role::Residual => break, // handled by the caller (bwd)
                other => bail!("unexpected arg role {other:?} in entry"),
            }
        }
        Ok(args)
    }

    /// One optimization step over a batch.
    pub fn train_step(&mut self, engine: &mut Engine, batch: &Batch) -> Result<StepStats> {
        let t0 = Instant::now();
        let fwd = self.variant.entry("fwd")?;
        let bwd = self.variant.entry("bwd")?;
        let seed = self.step_seed();

        // ---- forward ----
        let args = self.batch_args(fwd, batch, seed)?;
        let outputs = engine.execute(self.manifest, fwd, &args)?;

        let mut loss = f64::NAN;
        self.store.reset_peak();
        for (spec, value) in fwd.outputs.iter().zip(outputs) {
            match spec.role {
                Role::Metric if spec.name == "loss" => {
                    loss = value.as_f32()?[0] as f64;
                }
                Role::Residual => {
                    let bytes = spec.bytes();
                    self.store.put(&spec.name, value, bytes);
                }
                _ => {} // logits unused during training
            }
        }
        let residual_bytes = self.store.stats().peak_bytes;
        self.peak_residual_bytes = self.peak_residual_bytes.max(residual_bytes);

        // ---- backward (drains the store in bwd-arg order) ----
        let mut args = self.batch_args(bwd, batch, seed)?;
        for spec in bwd.residual_args() {
            let v = self
                .store
                .take(&spec.name)
                .with_context(|| format!("missing residual '{}'", spec.name))?;
            args.push(v);
        }
        if !self.store.is_empty() {
            bail!("{} residuals left unconsumed", self.store.len());
        }
        let outputs = engine.execute(self.manifest, bwd, &args)?;

        let mut grads: Vec<Vec<f32>> = Vec::with_capacity(self.params.len());
        let mut probe_vals = Vec::new();
        for (spec, value) in bwd.outputs.iter().zip(outputs) {
            match spec.role {
                Role::Grad => grads.push(match value {
                    HostValue::F32(v) => v,
                    _ => bail!("non-f32 gradient '{}'", spec.name),
                }),
                Role::Probe => probe_vals.push(value.as_f32()?[0] as f64),
                _ => {}
            }
        }
        if grads.len() != self.params.len() {
            bail!("got {} grads for {} params", grads.len(), self.params.len());
        }

        // ---- host-side update ----
        let grad_norm = Optimizer::clip_gradients(&mut grads, self.cfg.clip_norm);
        let lr = self.sched.lr_at(self.step_idx);
        self.opt.step(&mut self.params, &grads, lr);

        let probe = (probe_vals.len() == 5).then(|| ProbeStats {
            d2_sgd: probe_vals[0],
            d2_rmm: probe_vals[1],
            alpha: probe_vals[2],
            ratio_lhs: probe_vals[3],
            bound_rhs: probe_vals[4],
        });

        let stats = StepStats {
            step: self.step_idx,
            loss,
            lr,
            grad_norm,
            residual_bytes,
            probe,
            step_time_s: t0.elapsed().as_secs_f64(),
        };
        self.step_idx += 1;
        Ok(stats)
    }

    /// Forward-only loss over a batch (used for eval-loss curves, Fig. 5).
    pub fn eval_loss(&mut self, engine: &mut Engine, batch: &Batch) -> Result<f64> {
        let fwd = self.variant.entry("fwd")?;
        let seed = [0u32, 0u32]; // fixed seed: eval determinism
        let args = self.batch_args(fwd, batch, seed)?;
        let outputs = engine.execute(self.manifest, fwd, &args)?;
        for (spec, value) in fwd.outputs.iter().zip(outputs) {
            if spec.role == Role::Metric && spec.name == "loss" {
                return Ok(value.as_f32()?[0] as f64);
            }
        }
        bail!("fwd entry has no loss output")
    }

    /// Dev-set evaluation with the task's GLUE metric (uses the `eval`
    /// entry — logits only, no residuals).  Builds the canonical dev
    /// stream itself; callers that already hold the dev batches (warm
    /// session cache) or want them prefetched use [`Self::eval_score`]
    /// directly — the batch sequence, and therefore the score, is
    /// identical either way.
    pub fn evaluate(&mut self, engine: &mut Engine, tok: &Tokenizer) -> Result<f64> {
        let gen = TaskGen::new(self.task, tok, self.variant.config.seq_len, self.cfg.seed);
        let batches = Batcher::new(&gen, Split::Dev, self.variant.config.batch_size, 0);
        self.eval_score(engine, batches)
    }

    /// Dev-metric pass over an explicit batch stream (owned batches or
    /// borrows of cached ones).  The stream must be the canonical dev
    /// sequence for this trainer's `(task, seed)` — see [`Self::evaluate`].
    pub fn eval_score<I>(&mut self, engine: &mut Engine, batches: I) -> Result<f64>
    where
        I: IntoIterator,
        I::Item: std::borrow::Borrow<Batch>,
    {
        let eval = self.variant.entry("eval")?;
        let mut acc = MetricAccum::new();
        let n_classes = self.variant.config.n_classes;
        for batch in batches {
            let batch = batch.borrow();
            let mut args = Vec::with_capacity(eval.args.len());
            for spec in &eval.args {
                match spec.role {
                    Role::Param => {
                        let i = args.len();
                        args.push(HostValue::F32(self.params[i].clone()));
                    }
                    Role::Tokens => args.push(HostValue::I32(batch.tokens.clone())),
                    Role::Mask => args.push(HostValue::F32(batch.mask.clone())),
                    other => bail!("unexpected eval arg role {other:?}"),
                }
            }
            let outputs = engine.execute(self.manifest, eval, &args)?;
            let logits = outputs
                .first()
                .context("eval produced no outputs")?
                .as_f32()?;
            acc.add_logits(
                self.task,
                logits,
                n_classes,
                &batch.labels_i,
                &batch.labels_f,
                batch.valid,
            );
        }
        Ok(acc.score(self.task))
    }
}
