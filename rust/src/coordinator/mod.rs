//! L3 coordinator: trainer (fwd → activation store → bwd → optimizer),
//! optimizers, LR schedules, metrics logging and checkpoints.

pub mod checkpoint;
pub mod metrics_log;
pub mod optimizer;
pub mod schedule;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use metrics_log::MetricsLog;
pub use optimizer::{Optimizer, OptimizerConfig};
pub use schedule::Schedule;
pub use trainer::{ProbeStats, StepStats, Trainer, TrainerSetup};
