//! JSONL metrics sink: one JSON object per line, flushed eagerly so
//! partial runs are still analyzable; the bench harness re-reads these
//! files to assemble figures (loss curves, variance series, throughput).

use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

pub struct MetricsLog {
    writer: Option<BufWriter<File>>,
}

impl MetricsLog {
    /// A sink writing to `path` (parents created), truncating any old file.
    pub fn create(path: &Path) -> Result<MetricsLog> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let f = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(path)
            .with_context(|| format!("opening metrics log {path:?}"))?;
        Ok(MetricsLog { writer: Some(BufWriter::new(f)) })
    }

    /// A no-op sink (for tests / ephemeral runs).
    pub fn null() -> MetricsLog {
        MetricsLog { writer: None }
    }

    pub fn log(&mut self, record: Json) {
        if let Some(w) = &mut self.writer {
            let _ = writeln!(w, "{}", record.to_string());
            let _ = w.flush();
        }
    }

    /// Read a JSONL file back into records.
    pub fn read(path: &Path) -> Result<Vec<Json>> {
        let f = File::open(path).with_context(|| format!("reading {path:?}"))?;
        let mut out = Vec::new();
        for line in BufReader::new(f).lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            out.push(Json::parse(&line).with_context(|| format!("bad line in {path:?}"))?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let dir = std::env::temp_dir().join(format!("mlog_{}", std::process::id()));
        let path = dir.join("m.jsonl");
        let mut log = MetricsLog::create(&path).unwrap();
        log.log(Json::obj(vec![("step", Json::num(1.0)), ("loss", Json::num(0.5))]));
        log.log(Json::obj(vec![("step", Json::num(2.0)), ("loss", Json::num(0.4))]));
        drop(log);
        let recs = MetricsLog::read(&path).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1].get("loss").as_f64(), Some(0.4));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn null_sink_is_silent() {
        let mut log = MetricsLog::null();
        log.log(Json::num(1.0)); // must not panic
    }
}
