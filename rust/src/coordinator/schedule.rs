//! Learning-rate schedules (Fairseq GLUE recipe: linear warmup → linear
//! decay; plus constant and polynomial variants for ablations).

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// Linear warmup to `lr` over `warmup` steps, then linear decay to 0
    /// at `total` steps.
    Linear { lr: f64, warmup: usize, total: usize },
    Constant { lr: f64, warmup: usize },
    /// Polynomial decay with given power after warmup.
    Poly { lr: f64, warmup: usize, total: usize, power: f64 },
}

impl Schedule {
    pub fn from_config(name: &str, lr: f64, warmup: usize, total: usize) -> Schedule {
        match name {
            "const" => Schedule::Constant { lr, warmup },
            "poly" => Schedule::Poly { lr, warmup, total, power: 2.0 },
            _ => Schedule::Linear { lr, warmup, total },
        }
    }

    /// LR for a 0-based step index.
    pub fn lr_at(&self, step: usize) -> f64 {
        let warm = |lr: f64, warmup: usize| -> Option<f64> {
            if warmup > 0 && step < warmup {
                Some(lr * (step + 1) as f64 / warmup as f64)
            } else {
                None
            }
        };
        match *self {
            Schedule::Linear { lr, warmup, total } => warm(lr, warmup).unwrap_or_else(|| {
                let total = total.max(warmup + 1);
                let frac = (total - step.min(total)) as f64 / (total - warmup) as f64;
                lr * frac.clamp(0.0, 1.0)
            }),
            Schedule::Constant { lr, warmup } => warm(lr, warmup).unwrap_or(lr),
            Schedule::Poly { lr, warmup, total, power } => {
                warm(lr, warmup).unwrap_or_else(|| {
                    let total = total.max(warmup + 1);
                    let frac = (total - step.min(total)) as f64 / (total - warmup) as f64;
                    lr * frac.clamp(0.0, 1.0).powf(power)
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_shape() {
        let s = Schedule::Linear { lr: 1.0, warmup: 10, total: 110 };
        assert!((s.lr_at(0) - 0.1).abs() < 1e-12);
        assert!((s.lr_at(9) - 1.0).abs() < 1e-12);
        assert!(s.lr_at(40) < 1.0);
        assert!(s.lr_at(109) < 0.05);
        assert_eq!(s.lr_at(200), 0.0);
    }

    #[test]
    fn constant_after_warmup() {
        let s = Schedule::Constant { lr: 0.5, warmup: 4 };
        assert!(s.lr_at(0) < 0.5);
        assert_eq!(s.lr_at(4), 0.5);
        assert_eq!(s.lr_at(1000), 0.5);
    }

    #[test]
    fn poly_decays_faster_than_linear() {
        let lin = Schedule::Linear { lr: 1.0, warmup: 0, total: 100 };
        let pol = Schedule::Poly { lr: 1.0, warmup: 0, total: 100, power: 2.0 };
        assert!(pol.lr_at(50) < lin.lr_at(50));
    }

    #[test]
    fn monotone_during_warmup() {
        let s = Schedule::Linear { lr: 1.0, warmup: 5, total: 50 };
        for i in 1..5 {
            assert!(s.lr_at(i) > s.lr_at(i - 1));
        }
    }
}
