//! Checkpoints: raw-f32 parameter blobs + a JSON sidecar with the variant
//! name, step and parameter sizes.  Format-compatible with the
//! `artifacts/init_*.bin` blobs emitted by aot.py (same concatenation
//! order), so a "pre-trained" checkpoint can seed any variant that shares
//! the geometry — which is exactly how the Table 2 harness warm-starts
//! fine-tuning.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

pub struct Checkpoint {
    pub step: usize,
    pub variant: String,
    /// Parameter names aligned with `params` (enables name-matched partial
    /// warm starts across head geometries).
    pub names: Vec<String>,
    pub params: Vec<Vec<f32>>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut blob = Vec::new();
        for p in &self.params {
            for v in p {
                blob.extend_from_slice(&v.to_le_bytes());
            }
        }
        std::fs::write(path, &blob).with_context(|| format!("writing {path:?}"))?;
        let meta = Json::obj(vec![
            ("step", Json::num(self.step as f64)),
            ("variant", Json::str(self.variant.clone())),
            (
                "names",
                Json::Arr(self.names.iter().map(|n| Json::str(n.clone())).collect()),
            ),
            (
                "sizes",
                Json::Arr(
                    self.params.iter().map(|p| Json::num(p.len() as f64)).collect(),
                ),
            ),
        ]);
        std::fs::write(meta_path(path), meta.to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let meta_text = std::fs::read_to_string(meta_path(path))
            .with_context(|| format!("reading sidecar for {path:?}"))?;
        let meta = Json::parse(&meta_text)?;
        let sizes: Vec<usize> = meta
            .get("sizes")
            .as_arr()
            .context("sizes")?
            .iter()
            .map(|s| s.as_usize().context("size"))
            .collect::<Result<_>>()?;
        let blob = std::fs::read(path)?;
        let total: usize = sizes.iter().sum();
        if blob.len() != total * 4 {
            bail!("checkpoint {path:?}: blob is {} bytes, expected {}", blob.len(), total * 4);
        }
        let mut params = Vec::with_capacity(sizes.len());
        let mut off = 0;
        for n in sizes {
            let vals: Vec<f32> = blob[off..off + n * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            params.push(vals);
            off += n * 4;
        }
        let names = meta
            .get("names")
            .as_arr()
            .map(|a| {
                a.iter()
                    .map(|n| n.as_str().unwrap_or("").to_string())
                    .collect::<Vec<_>>()
            })
            .unwrap_or_default();
        Ok(Checkpoint {
            step: meta.get("step").as_usize().unwrap_or(0),
            variant: meta.get("variant").as_str().unwrap_or("").to_string(),
            names,
            params,
        })
    }
}

fn meta_path(path: &Path) -> std::path::PathBuf {
    let mut p = path.as_os_str().to_owned();
    p.push(".json");
    p.into()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ckpt_{}", std::process::id()));
        let path = dir.join("model.bin");
        let ck = Checkpoint {
            step: 7,
            variant: "v".into(),
            names: vec!["a".into(), "b".into()],
            params: vec![vec![1.0, -2.5], vec![3.25]],
        };
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 7);
        assert_eq!(back.variant, "v");
        assert_eq!(back.params, ck.params);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupted_blob_rejected() {
        let dir = std::env::temp_dir().join(format!("ckpt2_{}", std::process::id()));
        let path = dir.join("model.bin");
        let ck = Checkpoint {
            step: 0,
            variant: "v".into(),
            names: vec!["a".into()],
            params: vec![vec![1.0]],
        };
        ck.save(&path).unwrap();
        std::fs::write(&path, b"xx").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
