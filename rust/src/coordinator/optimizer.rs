//! Host-side optimizers over the flat parameter list.
//!
//! Gradients come back from the `bwd` artifact as f32 vectors; the
//! optimizer updates happen on the host (L3), which keeps the HLO programs
//! pure functions and lets the coordinator own all mutable state.  AdamW
//! with decoupled weight decay is the default (the Fairseq GLUE recipe the
//! paper uses); SGD/momentum/Adam exist for ablations.

use anyhow::{bail, Result};

/// Which parameters receive weight decay (AdamW convention: matrices yes,
/// biases and LayerNorm gains no).
pub fn decay_mask(name: &str) -> bool {
    name.ends_with("_w")
        || name.ends_with(".w")
        || name.ends_with(".tok")
        || name.ends_with(".pos")
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OptimizerConfig {
    pub weight_decay: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub momentum: f64,
}

impl Default for OptimizerConfig {
    fn default() -> Self {
        Self { weight_decay: 0.01, beta1: 0.9, beta2: 0.98, eps: 1e-6, momentum: 0.9 }
    }
}

enum State {
    Sgd,
    Momentum { v: Vec<Vec<f32>> },
    Adam { m: Vec<Vec<f32>>, v: Vec<Vec<f32>>, decoupled_decay: bool },
}

pub struct Optimizer {
    state: State,
    cfg: OptimizerConfig,
    /// Per-parameter weight-decay applicability (from names).
    decay: Vec<bool>,
    t: usize,
}

impl Optimizer {
    pub fn new(
        kind: &str,
        cfg: OptimizerConfig,
        param_names: &[String],
        param_sizes: &[usize],
    ) -> Result<Optimizer> {
        let zeros =
            || param_sizes.iter().map(|&n| vec![0.0f32; n]).collect::<Vec<_>>();
        let state = match kind {
            "sgd" => State::Sgd,
            "momentum" => State::Momentum { v: zeros() },
            "adam" => State::Adam { m: zeros(), v: zeros(), decoupled_decay: false },
            "adamw" => State::Adam { m: zeros(), v: zeros(), decoupled_decay: true },
            other => bail!("unknown optimizer '{other}'"),
        };
        Ok(Optimizer {
            state,
            cfg,
            decay: param_names.iter().map(|n| decay_mask(n)).collect(),
            t: 0,
        })
    }

    /// Global-norm gradient clipping; returns the pre-clip norm.
    pub fn clip_gradients(grads: &mut [Vec<f32>], max_norm: f64) -> f64 {
        let norm: f64 = grads
            .iter()
            .flat_map(|g| g.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt();
        if max_norm > 0.0 && norm > max_norm {
            let scale = (max_norm / norm) as f32;
            for g in grads.iter_mut() {
                for x in g.iter_mut() {
                    *x *= scale;
                }
            }
        }
        norm
    }

    /// Apply one update with learning rate `lr`.
    pub fn step(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>], lr: f64) {
        assert_eq!(params.len(), grads.len());
        self.t += 1;
        let c = self.cfg;
        match &mut self.state {
            State::Sgd => {
                for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
                    let wd = if self.decay[i] { c.weight_decay } else { 0.0 } as f32;
                    for (pv, gv) in p.iter_mut().zip(g) {
                        *pv -= (lr as f32) * (gv + wd * *pv);
                    }
                }
            }
            State::Momentum { v } => {
                for (i, ((p, g), vi)) in
                    params.iter_mut().zip(grads).zip(v.iter_mut()).enumerate()
                {
                    let wd = if self.decay[i] { c.weight_decay } else { 0.0 } as f32;
                    let mu = c.momentum as f32;
                    for k in 0..p.len() {
                        vi[k] = mu * vi[k] + g[k] + wd * p[k];
                        p[k] -= (lr as f32) * vi[k];
                    }
                }
            }
            State::Adam { m, v, decoupled_decay } => {
                let b1 = c.beta1;
                let b2 = c.beta2;
                let bc1 = 1.0 - b1.powi(self.t as i32);
                let bc2 = 1.0 - b2.powi(self.t as i32);
                for i in 0..params.len() {
                    let wd = if self.decay[i] { c.weight_decay } else { 0.0 };
                    let (p, g) = (&mut params[i], &grads[i]);
                    let (mi, vi) = (&mut m[i], &mut v[i]);
                    for k in 0..p.len() {
                        let gk = if *decoupled_decay {
                            g[k] as f64
                        } else {
                            g[k] as f64 + wd * p[k] as f64 // classic Adam: L2 in grad
                        };
                        let mk = b1 * mi[k] as f64 + (1.0 - b1) * gk;
                        let vk = b2 * vi[k] as f64 + (1.0 - b2) * gk * gk;
                        mi[k] = mk as f32;
                        vi[k] = vk as f32;
                        let mhat = mk / bc1;
                        let vhat = vk / bc2;
                        let mut upd = lr * mhat / (vhat.sqrt() + c.eps);
                        if *decoupled_decay {
                            upd += lr * wd * p[k] as f64;
                        }
                        p[k] -= upd as f32;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quadratic_descends(kind: &str) {
        // minimize f(p) = 0.5‖p − target‖²; grad = p − target
        let target = [3.0f32, -2.0, 0.5];
        let names = vec!["x_w".to_string()];
        let mut opt = Optimizer::new(
            kind,
            OptimizerConfig { weight_decay: 0.0, ..Default::default() },
            &names,
            &[3],
        )
        .unwrap();
        let mut params = vec![vec![0.0f32; 3]];
        for _ in 0..400 {
            let grads =
                vec![params[0].iter().zip(&target).map(|(p, t)| p - t).collect()];
            opt.step(&mut params, &grads, 0.05);
        }
        for (p, t) in params[0].iter().zip(&target) {
            assert!((p - t).abs() < 0.05, "{kind}: {p} vs {t}");
        }
    }

    #[test]
    fn all_optimizers_descend() {
        for kind in ["sgd", "momentum", "adam", "adamw"] {
            quadratic_descends(kind);
        }
    }

    #[test]
    fn unknown_optimizer_rejected() {
        assert!(Optimizer::new("rmsprop", Default::default(), &[], &[]).is_err());
    }

    #[test]
    fn clipping_scales_to_max_norm() {
        let mut grads = vec![vec![3.0f32, 4.0]]; // norm 5
        let norm = Optimizer::clip_gradients(&mut grads, 1.0);
        assert!((norm - 5.0).abs() < 1e-9);
        let new_norm: f64 = grads[0].iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        assert!((new_norm - 1.0).abs() < 1e-5);
    }

    #[test]
    fn clipping_noop_below_threshold() {
        let mut grads = vec![vec![0.3f32, 0.4]];
        Optimizer::clip_gradients(&mut grads, 1.0);
        assert_eq!(grads[0], vec![0.3, 0.4]);
    }

    #[test]
    fn weight_decay_only_on_matrices() {
        assert!(decay_mask("blk0.q_w"));
        assert!(decay_mask("emb.tok"));
        assert!(!decay_mask("blk0.q_b"));
        assert!(!decay_mask("blk0.ln1_g"));
    }

    #[test]
    fn adamw_decay_shrinks_weights_without_grads() {
        let names = vec!["x_w".to_string()];
        let mut opt = Optimizer::new(
            "adamw",
            OptimizerConfig { weight_decay: 0.1, ..Default::default() },
            &names,
            &[1],
        )
        .unwrap();
        let mut params = vec![vec![1.0f32]];
        let grads = vec![vec![0.0f32]];
        for _ in 0..10 {
            opt.step(&mut params, &grads, 0.1);
        }
        assert!(params[0][0] < 1.0);
        assert!(params[0][0] > 0.8);
    }
}
