//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse argv-style strings. `flag_names` lists options that take no
    /// value (everything else with `--` is assumed to consume one).
    pub fn parse(argv: &[String], flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else if i + 1 < argv.len() {
                    out.options.insert(rest.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = Args::parse(
            &sv(&["train", "--steps", "100", "--rho=0.5", "--verbose", "extra"]),
            &["verbose"],
        );
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.get_f64("rho", 1.0), 0.5);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&[]), &[]);
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_or("x", "d"), "d");
    }

    #[test]
    fn trailing_option_becomes_flag() {
        let a = Args::parse(&sv(&["--dangling"]), &[]);
        assert!(a.has_flag("dangling"));
    }
}
