//! First-party utility substrates (the offline build has no serde/clap/
//! criterion/proptest): JSON codec, CLI argument parsing, timing/statistics
//! for the bench harness, and a seeded property-test runner.

pub mod bench;
pub mod cli;
pub mod env;
pub mod fnv;
pub mod json;
pub mod prop;
pub mod stats;
