//! Minimal JSON codec (parse + serialize).
//!
//! First-party substrate: the offline build exposes only the `xla` crate
//! closure, so the artifact manifest, config files, metric logs and bench
//! reports all go through this module instead of serde_json.  Supports the
//! full JSON grammar except exotic number forms (`NaN`/`Infinity` are
//! rejected, as in RFC 8259).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.  Objects use a BTreeMap so serialization is
/// deterministic (stable diffs for config/report files).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64()
            .and_then(|n| if n.fract() == 0.0 { Some(n as i64) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]`-style access; returns Null for missing keys/non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    // -- serialization -----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad utf8 in number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: recurse for the low half.
                            if (0xD800..0xDC00).contains(&cp) {
                                self.i += 5;
                                if self.peek() != Some(b'\\') {
                                    return Err(self.err("lone high surrogate"));
                                }
                                self.i += 1;
                                if self.peek() != Some(b'u') {
                                    return Err(self.err("lone high surrogate"));
                                }
                                let hex2 = std::str::from_utf8(
                                    &self.b[self.i + 1..self.i + 5],
                                )
                                .map_err(|_| self.err("bad \\u escape"))?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| self.err("bad \\u escape"))?;
                                let c = 0x10000
                                    + ((cp - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                out.push(
                                    char::from_u32(c)
                                        .ok_or_else(|| self.err("bad surrogate"))?,
                                );
                                self.i += 4; // the final advance below adds 1
                            } else {
                                out.push(
                                    char::from_u32(cp)
                                        .ok_or_else(|| self.err("bad codepoint"))?,
                                );
                                self.i += 4;
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"obj":{"k":"v","n":null},"s":"q\"uo\\te","t":true}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn pretty_is_reparseable() {
        let v = Json::obj(vec![
            ("x", Json::num(1.0)),
            ("y", Json::arr(vec![Json::str("a"), Json::Bool(false)])),
        ]);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn get_on_missing_is_null() {
        let v = Json::parse("{}").unwrap();
        assert!(v.get("nope").is_null());
    }
}
