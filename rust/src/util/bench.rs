//! Bench runner (criterion is unavailable offline): warmup + timed
//! iterations with mean/std/percentiles, criterion-like console output and
//! a JSON report for EXPERIMENTS.md regeneration.

use std::time::Instant;

use super::json::Json;
use super::stats;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_ns", Json::num(self.mean_ns)),
            ("std_ns", Json::num(self.std_ns)),
            ("p50_ns", Json::num(self.p50_ns)),
            ("p95_ns", Json::num(self.p95_ns)),
        ])
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub struct Bencher {
    pub results: Vec<BenchResult>,
    /// Target wall-time per benchmark (seconds).
    pub budget_s: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        let budget_s = std::env::var("BENCH_BUDGET_S")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1.0);
        Self { results: Vec::new(), budget_s }
    }

    /// Time `f`, auto-calibrating the iteration count to the budget.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        // calibration: run once to estimate cost
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let target_iters = ((self.budget_s / once) as usize).clamp(5, 10_000);
        // warmup ~10%
        for _ in 0..(target_iters / 10).max(1) {
            f();
        }
        let mut samples = Vec::with_capacity(target_iters);
        for _ in 0..target_iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: target_iters,
            mean_ns: stats::mean(&samples),
            std_ns: stats::stddev(&samples),
            p50_ns: stats::percentile(&samples, 50.0),
            p95_ns: stats::percentile(&samples, 95.0),
        };
        println!(
            "{:<52} time: [{} ± {}]  p95: {}  ({} iters)",
            res.name,
            fmt_ns(res.mean_ns),
            fmt_ns(res.std_ns),
            fmt_ns(res.p95_ns),
            res.iters
        );
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Write accumulated results as JSON (one file per bench binary).
    pub fn write_report(&self, path: &str) {
        let arr = Json::Arr(self.results.iter().map(|r| r.to_json()).collect());
        if let Some(dir) = std::path::Path::new(path).parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        if let Err(e) = std::fs::write(path, arr.to_string_pretty()) {
            eprintln!("warn: could not write bench report {path}: {e}");
        } else {
            println!("report -> {path}");
        }
    }
}

/// Opaque value sink preventing the optimizer from deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        std::env::set_var("BENCH_BUDGET_S", "0.05");
        let mut b = Bencher::new();
        let r = b.bench("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters >= 5);
    }
}
