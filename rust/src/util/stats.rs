//! Small statistics helpers shared by metrics and the bench harness.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Unbiased sample standard deviation (0.0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile via linear interpolation on a sorted copy. `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (s[hi] - s[lo]) * (rank - lo as f64)
    }
}

/// Pearson correlation coefficient.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if x.len() < 2 {
        return 0.0;
    }
    let (mx, my) = (mean(x), mean(y));
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for i in 0..x.len() {
        num += (x[i] - mx) * (y[i] - my);
        dx += (x[i] - mx).powi(2);
        dy += (y[i] - my).powi(2);
    }
    let den = (dx * dy).sqrt();
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
    .clamp(-1.0, 1.0 + f64::EPSILON * n)
}

/// Spearman rank correlation (average ranks for ties).
pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    pearson(&ranks(x), &ranks(y))
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut r = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            r[idx[k]] = avg;
        }
        i = j + 1;
    }
    r
}

/// Matthews correlation coefficient for binary predictions (CoLA's metric).
pub fn matthews(tp: usize, tn: usize, fp: usize, fn_: usize) -> f64 {
    let (tp, tn, fp, fn_) = (tp as f64, tn as f64, fp as f64, fn_ as f64);
    let den = ((tp + fp) * (tp + fn_) * (tn + fp) * (tn + fn_)).sqrt();
    if den == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fn_) / den
    }
}

/// F1 score for the positive class (MRPC/QQP's metric).
pub fn f1(tp: usize, fp: usize, fn_: usize) -> f64 {
    let denom = 2 * tp + fp + fn_;
    if denom == 0 {
        0.0
    } else {
        2.0 * tp as f64 / denom as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect() {
        let x = [1.0, 2.0, 3.0];
        let y = [2.0, 4.0, 6.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-9);
        let yneg = [3.0, 2.0, 1.0];
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [1.0, 8.0, 27.0, 64.0];
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn spearman_ties() {
        let x = [1.0, 1.0, 2.0];
        let r = ranks(&x);
        assert_eq!(r, vec![1.5, 1.5, 3.0]);
    }

    #[test]
    fn matthews_known_values() {
        assert!((matthews(10, 10, 0, 0) - 1.0).abs() < 1e-12);
        assert!((matthews(0, 0, 10, 10) + 1.0).abs() < 1e-12);
        assert_eq!(matthews(0, 0, 0, 0), 0.0);
    }

    #[test]
    fn f1_known_values() {
        assert!((f1(5, 0, 0) - 1.0).abs() < 1e-12);
        assert!((f1(5, 5, 5) - 0.5).abs() < 1e-12);
        assert_eq!(f1(0, 0, 0), 0.0);
    }
}
