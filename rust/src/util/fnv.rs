//! FNV-1a 64-bit hashing — the one copy of the fold shared by the
//! deterministic mock-cell runner (`sweep::mock_cell`) and the
//! session-layer data digests (`bench_harness::runner::run_data_cell`),
//! so the offset basis / prime can never drift between them.

pub const OFFSET_BASIS: u64 = 0xcbf2_9ce4_8422_2325;
const PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold bytes into a running FNV-1a state (start from [`OFFSET_BASIS`]).
pub fn fold(mut h: u64, bytes: impl IntoIterator<Item = u8>) -> u64 {
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// One-shot FNV-1a hash of a byte stream.
pub fn hash(bytes: impl IntoIterator<Item = u8>) -> u64 {
    fold(OFFSET_BASIS, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_vectors() {
        // Canonical FNV-1a 64 test vectors.
        assert_eq!(hash("".bytes()), 0xcbf2_9ce4_8422_2325);
        assert_eq!(hash("a".bytes()), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(hash("foobar".bytes()), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn fold_composes() {
        let whole = hash("abcdef".bytes());
        let split = fold(fold(OFFSET_BASIS, "abc".bytes()), "def".bytes());
        assert_eq!(whole, split);
    }
}
