//! Strict env-knob parsing with one canonical error shape.
//!
//! Every `RMM_*` knob an operator can *set* must reject a malformed
//! value instead of silently defaulting: someone who exported
//! `RMM_POOL_GRAIN=1o24` meant to bound task granularity, and quietly
//! running with the derived grain hides the typo until a perf report
//! makes no sense.  The error shape is uniform across knobs —
//! `<NAME> must be <domain>, got '<value>'` — matching
//! `RMM_EXE_CACHE_CAP` (the first strict knob) and `RMM_SIMD`.
//!
//! The `parse_*` functions are pure `Result` parsers (unit-testable);
//! the `var_*` wrappers read the process environment and treat an unset
//! variable as "no preference".

use anyhow::Result;

/// Parse a positive (>= 1) integer knob value with the canonical error.
pub fn parse_positive_usize(name: &str, v: &str) -> Result<usize> {
    match v.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Ok(n),
        _ => Err(anyhow::anyhow!("{name} must be a positive integer, got '{v}'")),
    }
}

/// Parse a non-negative integer knob value with the canonical error.
/// `zero_means` names the zero semantics in the message (e.g.
/// "0 = unbounded") so the domain stays self-describing.
pub fn parse_usize_with_zero(name: &str, zero_means: &str, v: &str) -> Result<usize> {
    v.trim().parse::<usize>().map_err(|_| {
        anyhow::anyhow!("{name} must be a non-negative integer ({zero_means}), got '{v}'")
    })
}

/// Read a positive-integer env knob: `Ok(None)` when unset, the
/// canonical error when set to anything that is not an integer >= 1.
pub fn var_positive_usize(name: &str) -> Result<Option<usize>> {
    match std::env::var(name) {
        Err(_) => Ok(None),
        Ok(v) => parse_positive_usize(name, &v).map(Some),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn positive_accepts_and_trims() {
        assert_eq!(parse_positive_usize("K", "3").unwrap(), 3);
        assert_eq!(parse_positive_usize("K", " 17 ").unwrap(), 17);
    }

    #[test]
    fn positive_rejects_with_canonical_shape() {
        for bad in ["0", "-1", "1o24", "", "3.5", "many"] {
            let err = parse_positive_usize("RMM_POOL_GRAIN", bad)
                .unwrap_err()
                .to_string();
            assert!(err.contains("RMM_POOL_GRAIN"), "{err}");
            assert!(err.contains(&format!("'{bad}'")), "{err}");
            assert!(err.contains("positive integer"), "{err}");
        }
    }

    #[test]
    fn zero_allowing_variant_keeps_zero_and_names_its_meaning() {
        assert_eq!(parse_usize_with_zero("C", "0 = unbounded", "0").unwrap(), 0);
        let err = parse_usize_with_zero("RMM_EXE_CACHE_CAP", "0 = unbounded", "-2")
            .unwrap_err()
            .to_string();
        assert!(err.contains("RMM_EXE_CACHE_CAP"), "{err}");
        assert!(err.contains("'-2'"), "{err}");
        assert!(err.contains("0 = unbounded"), "{err}");
    }
}
