//! Seeded property-test runner (proptest is unavailable offline).
//!
//! A property is a closure over a `Gen` (backed by the Philox substrate);
//! the runner executes it across N deterministic seeds and reports the
//! failing seed on panic, so failures are exactly reproducible:
//!
//! ```ignore
//! prop_check("theorem 2.3 bound", 200, |g| {
//!     let x = g.tensor(2..=32, 1..=12);
//!     ...
//! });
//! ```

use crate::rng::philox::PhiloxStream;
use crate::tensor::Tensor;

pub struct Gen {
    rng: PhiloxStream,
    pub case_seed: u64,
}

impl Gen {
    pub fn new(case_seed: u64) -> Self {
        Self { rng: PhiloxStream::new(case_seed, 3), case_seed }
    }

    pub fn usize_in(&mut self, lo: usize, hi_incl: usize) -> usize {
        lo + self.rng.next_below((hi_incl - lo + 1) as u32) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    pub fn normal(&mut self) -> f32 {
        self.rng.next_normal()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn seed_pair(&mut self) -> (u32, u32) {
        (self.rng.next_u32(), self.rng.next_u32())
    }

    /// Random normal tensor with dims drawn from inclusive ranges.
    pub fn tensor(
        &mut self,
        rows: std::ops::RangeInclusive<usize>,
        cols: std::ops::RangeInclusive<usize>,
    ) -> Tensor {
        let r = self.usize_in(*rows.start(), *rows.end());
        let c = self.usize_in(*cols.start(), *cols.end());
        let mut t = Tensor::zeros(r, c);
        for v in &mut t.data {
            *v = self.rng.next_normal();
        }
        t
    }
}

/// Run `body` over `cases` deterministic generator seeds; panics with the
/// failing case seed attached so the case replays exactly.
pub fn prop_check(name: &str, cases: u64, mut body: impl FnMut(&mut Gen)) {
    for case in 0..cases {
        let case_seed = 0x5EED_0000 + case;
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen::new(case_seed);
            body(&mut g);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property '{name}' failed at case {case} (seed {case_seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_is_deterministic() {
        let mut a = Gen::new(42);
        let mut b = Gen::new(42);
        for _ in 0..10 {
            assert_eq!(a.usize_in(0, 1000), b.usize_in(0, 1000));
        }
    }

    #[test]
    fn ranges_respected() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let v = g.usize_in(3, 7);
            assert!((3..=7).contains(&v));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&f));
        }
    }

    #[test]
    fn prop_check_passes_trivial() {
        prop_check("trivial", 10, |g| {
            let t = g.tensor(1..=4, 1..=4);
            assert!(t.rows >= 1 && t.cols <= 4);
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn prop_check_reports_failure() {
        prop_check("fails", 5, |g| {
            assert!(g.usize_in(0, 10) > 100);
        });
    }
}
