//! `repro` — CLI for the rmmlinear coordinator.
//!
//! ```text
//! repro train --variant small_cls2_r50_gauss --task cola --steps 400
//! repro eval  --variant small_cls2_r50_gauss --task cola --checkpoint runs/ck.bin
//! repro pretrain --steps 600 --out runs/pretrained.bin
//! repro bench-table2 [--tasks cola,sst2] [--steps 300] [--shards 3] [--resume]
//! repro bench-table3 | bench-table4 | bench-budget | bench-fig3 | bench-fig4 | bench-fig5 | bench-fig6
//! repro sweep-worker --dir reports/sweep_table2 --shard 0/3
//! repro sweep-selftest [--shards 2]
//! repro inspect-artifacts
//! repro memory-model --rho 0.1 [--roberta]
//! ```

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use rmmlinear::bench_harness as bench;
use rmmlinear::config::{SweepConfig, TrainConfig, LR_SCHEDULES};
use rmmlinear::coordinator::{Checkpoint, MetricsLog, Trainer};
use rmmlinear::data::{Task, Tokenizer};
use rmmlinear::memory::{MemoryModel, ModelGeometry};
use rmmlinear::runtime::{Engine, Manifest};
use rmmlinear::session::Session;
use rmmlinear::sweep::{self, CellCtx, DynamicConfig, Schedule, Shard, SweepSpec};
use rmmlinear::util::cli::Args;
use rmmlinear::util::json::Json;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Strict `--prefetch-depth` parse: a present flag must be a positive
/// integer (mirroring the config-file validation of
/// `train.prefetch_depth` — silently clamping a 0/garbage depth would
/// make the CLI and config surfaces disagree on what is invalid).
fn prefetch_depth_arg(args: &Args) -> Result<Option<usize>> {
    match args.get("prefetch-depth") {
        None => Ok(None),
        Some(v) => v
            .parse::<usize>()
            .ok()
            .filter(|&n| n >= 1)
            .map(Some)
            .with_context(|| {
                format!("--prefetch-depth must be a positive integer, got '{v}'")
            }),
    }
}

fn train_config(args: &Args) -> Result<TrainConfig> {
    let mut t = TrainConfig::default();
    t.steps = args.get_usize("steps", t.steps);
    t.warmup_steps = args.get_usize("warmup", (t.steps / 16).max(1));
    t.lr = args.get_f64("lr", t.lr);
    t.weight_decay = args.get_f64("weight-decay", t.weight_decay);
    t.clip_norm = args.get_f64("clip-norm", t.clip_norm);
    t.optimizer = args.get_or("optimizer", &t.optimizer).to_string();
    if let Some(s) = args.get("schedule") {
        // sweep-scheduler values (static|dynamic) are not LR schedules;
        // they are consumed by `sweep_schedule` instead
        if LR_SCHEDULES.contains(&s) {
            t.schedule = s.to_string();
        }
    }
    t.log_every = args.get_usize("log-every", t.log_every);
    t.seed = args.get_u64("seed", t.seed);
    t.prefetch = args.has_flag("prefetch");
    if let Some(d) = prefetch_depth_arg(args)? {
        t.prefetch_depth = d;
    }
    Ok(t)
}

fn load_manifest(args: &Args) -> Result<Manifest> {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    Manifest::load(&dir)
}

fn reports_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("reports", "reports"))
}

/// Sweep defaults from the `--config` file's `sweep` section (CLI flags
/// take precedence).  `run()` already loaded and applied this file for
/// backend/pool knobs; re-reading it here keeps the cmd handlers free of
/// threading, and a failure *now* (file changed or vanished since) is an
/// error, not a silent fall-back to defaults.
fn sweep_defaults(args: &Args) -> Result<SweepConfig> {
    match args.get("config") {
        Some(p) => Ok(rmmlinear::config::ExperimentConfig::load(Path::new(p))?.sweep),
        None => Ok(SweepConfig::default()),
    }
}

fn parse_seeds(args: &Args, default: u64) -> Vec<u64> {
    args.get("seeds")
        .map(|s| {
            s.split(',')
                .filter_map(|x| x.trim().parse().ok())
                .collect::<Vec<u64>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| vec![default])
}

/// Resolve the sweep scheduler + lease TTL from the `--sweep-schedule` /
/// `--schedule` / `--lease-ttl-ms` flags and the config's `sweep`
/// section.  `--schedule` is shared with the LR schedule (disjoint value
/// sets keep it unambiguous); `--sweep-schedule` exists so a single
/// invocation can say both, e.g. `--schedule poly --sweep-schedule
/// dynamic`, and it always wins over `--schedule`.
fn sweep_schedule(
    args: &Args,
    defaults: &SweepConfig,
) -> Result<(Schedule, u64)> {
    let flag = match (args.get("sweep-schedule"), args.get("schedule")) {
        (Some(s), _) => Some(Schedule::parse(s).with_context(|| {
            format!("unknown --sweep-schedule '{s}' (static|dynamic)")
        })?),
        (None, Some(s)) if LR_SCHEDULES.contains(&s) => None,
        (None, Some(s)) => Some(Schedule::parse(s).with_context(|| {
            format!("unknown --schedule '{s}' (sweep: static|dynamic; LR: linear|const|poly)")
        })?),
        (None, None) => None,
    };
    let schedule = match flag {
        Some(s) => s,
        None => match defaults.schedule.as_deref() {
            Some(s) => Schedule::parse(s)
                .with_context(|| format!("bad config sweep.schedule '{s}' (static|dynamic)"))?,
            None => Schedule::Static,
        },
    };
    let ttl = lease_ttl_arg(args)?
        .unwrap_or_else(|| defaults.lease_ttl_ms.unwrap_or(sweep::DEFAULT_LEASE_TTL_MS));
    Ok((schedule, ttl))
}

/// Resolve an `on|off` flag (e.g. `--session-cache`, `--affinity`)
/// against its config default; absent everywhere means `default`.
fn on_off_flag(
    args: &Args,
    flag: &str,
    config_value: Option<bool>,
    default: bool,
) -> Result<bool> {
    match args.get(flag) {
        Some("on") => Ok(true),
        Some("off") => Ok(false),
        Some(other) => bail!("--{flag} must be 'on' or 'off', got '{other}'"),
        None => Ok(config_value.unwrap_or(default)),
    }
}

/// `--session-cache on|off` (config: `sweep.session_cache`, default on):
/// warm per-worker session reuse across sweep cells.
fn session_cache_flag(args: &Args, defaults: &SweepConfig) -> Result<bool> {
    on_off_flag(args, "session-cache", defaults.session_cache, true)
}

/// `--affinity on|off` (config: `sweep.affinity`, default on): dynamic
/// scheduler's warm-variant claim preference.
fn affinity_flag(args: &Args, defaults: &SweepConfig) -> Result<bool> {
    on_off_flag(args, "affinity", defaults.affinity, true)
}

/// `--artifact-cache on|off` (config: `sweep.artifact_cache`, default
/// off): shared on-disk warm-start blobs (`cache/`) plus the fleet
/// worker registry (`workers/`) under the sweep dir.
fn artifact_cache_flag(args: &Args, defaults: &SweepConfig) -> Result<bool> {
    on_off_flag(args, "artifact-cache", defaults.artifact_cache, false)
}

/// Build the warm session a run executes through: the engine plus
/// manifest-backed caches (`--session-cache off` keeps construction but
/// disables reuse — the explicit cold path).
fn load_session(args: &Args) -> Result<Session> {
    let caching = session_cache_flag(args, &sweep_defaults(args)?)?;
    Ok(Session::new(Engine::cpu()?, load_manifest(args)?, caching))
}

/// Strict `--mem-budget` resolve (CLI > config `rmm.mem_budget` > 0.5):
/// the closed-loop controller's allowed residual fraction of the exact
/// ρ=1 layer store, in (0, 1] — the same validation the config file
/// enforces, so the two surfaces agree on what is invalid.
fn mem_budget_arg(args: &Args) -> Result<f64> {
    if let Some(v) = args.get("mem-budget") {
        return v
            .parse::<f64>()
            .ok()
            .filter(|b| b.is_finite() && *b > 0.0 && *b <= 1.0)
            .with_context(|| {
                format!("--mem-budget must be a number in (0, 1], got '{v}'")
            });
    }
    if let Some(path) = args.get("config") {
        let cfg = rmmlinear::config::ExperimentConfig::load(Path::new(path))?;
        if let Some(b) = cfg.rmm.mem_budget {
            return Ok(b);
        }
    }
    Ok(0.5)
}

/// Strict `--lease-ttl-ms` parse: a present flag must be a positive
/// integer (mirroring the config-file validation — a 0/garbage TTL would
/// make every in-flight claim instantly stealable, not "off").
fn lease_ttl_arg(args: &Args) -> Result<Option<u64>> {
    match args.get("lease-ttl-ms") {
        None => Ok(None),
        Some(v) => v
            .parse::<u64>()
            .ok()
            .filter(|&n| n >= 1)
            .map(Some)
            .with_context(|| {
                format!("--lease-ttl-ms must be a positive integer (ms), got '{v}'")
            }),
    }
}

/// Strict `--chaos-seed` parse: a present flag must be a `u64` (the
/// seed keys the whole fault schedule, so a silently-dropped garbage
/// value would turn a "reproduce this failure" invocation into a
/// fault-free run).
fn chaos_seed_arg(args: &Args) -> Result<Option<u64>> {
    match args.get("chaos-seed") {
        None => Ok(None),
        Some(v) => v.parse::<u64>().map(Some).ok().with_context(|| {
            format!("--chaos-seed must be an unsigned integer, got '{v}'")
        }),
    }
}

/// Strict `--respawn-budget` parse with a chaos-aware default: under
/// chaos a kill is an *expected* event, so crashed workers respawn (3
/// by default); without chaos the historical fail-fast behavior (0)
/// is preserved unless the flag or `sweep.respawn_budget` says
/// otherwise.
fn respawn_budget_arg(args: &Args, defaults: &SweepConfig, chaos: bool) -> Result<u32> {
    match args.get("respawn-budget") {
        Some(v) => v.parse::<u32>().map(Some).ok().with_context(|| {
            format!("--respawn-budget must be a non-negative integer, got '{v}'")
        }).map(|o| o.unwrap_or(0)),
        None => Ok(defaults
            .respawn_budget
            .unwrap_or(if chaos { 3 } else { 0 })),
    }
}

/// Resolve the chaos seed + profile from flags and the config's `sweep`
/// section.  The **seed** is the on-switch: a profile without a seed is
/// inert (there is no schedule to compile), mirroring how the worker
/// side only installs chaos when `--chaos-seed` is present.
fn chaos_opts(args: &Args, defaults: &SweepConfig) -> Result<Option<(u64, String)>> {
    let seed = match chaos_seed_arg(args)?.or(defaults.chaos_seed) {
        Some(s) => s,
        None => return Ok(None),
    };
    let profile = args
        .get("chaos-profile")
        .map(str::to_string)
        .or_else(|| defaults.chaos_profile.clone())
        .unwrap_or_else(|| rmmlinear::chaos::DEFAULT_PROFILE.to_string());
    // Validate orchestrator-side so a typo'd profile fails before any
    // worker spawns (explicit `point@hit=action` schedules validate
    // their grammar here too, via the same compile path).
    rmmlinear::chaos::compile(seed, &profile, 0)
        .with_context(|| format!("bad --chaos-profile '{profile}'"))?;
    Ok(Some((seed, profile)))
}

/// Run a sweep spec to completion and return the merged, cell-ordered
/// results: `--shards 1` executes inline with one engine; `--shards N`
/// self-spawns N `sweep-worker` processes (each with its own engine) and
/// merges their fragments.  `--schedule static` (default) assigns cells
/// round-robin; `--schedule dynamic` lets workers pull cells through the
/// claim/lease store.  Every path produces the same fragment set, so the
/// merged report is identical for deterministic cells.
fn run_sweep(args: &Args, spec: &SweepSpec, name: &str) -> Result<Vec<Json>> {
    let defaults = sweep_defaults(args)?;
    let shards = args.get_usize("shards", defaults.shards.unwrap_or(1)).max(1);
    let resume = args.has_flag("resume") || defaults.resume;
    let (schedule, ttl) = sweep_schedule(args, &defaults)?;
    let session_cache = session_cache_flag(args, &defaults)?;
    let affinity = affinity_flag(args, &defaults)?;
    let artifact_cache = artifact_cache_flag(args, &defaults)?;
    let chaos = chaos_opts(args, &defaults)?;
    let respawn_budget = respawn_budget_arg(args, &defaults, chaos.is_some())?;
    let dir = reports_dir(args).join(format!("sweep_{name}"));
    sweep::resume::prepare(&dir, spec, resume)?;
    if shards <= 1 {
        if chaos.is_some() {
            // Chaos targets worker *processes* (kills are real exits and
            // respawns are real relaunches); the inline path has no
            // process boundary to fault, so the seed is ignored rather
            // than killing the orchestrator itself.
            eprintln!(
                "sweep[{name}]: --chaos-seed ignored for inline runs; \
                 use --shards N (N >= 1 worker processes) to inject faults"
            );
        }
        // Engine-free experiments (the budget grid runs on Philox probe
        // tensors) must not demand artifacts just to run inline.
        let mut session = match spec.experiment.as_str() {
            "mock" | "mockdata" | "budget" => Session::data_only(session_cache),
            s if s.starts_with("synth-") => Session::data_only(session_cache),
            _ => Session::new(Engine::cpu()?, load_manifest(args)?, session_cache),
        };
        if artifact_cache {
            session.set_artifact_cache(Some(sweep::fleet::ArtifactCache::open(&dir)?));
        }
        let mut runner = |cell: &sweep::Cell, ctx: &CellCtx<'_>| {
            bench::runner::run_cell(&mut session, spec, cell, ctx)
        };
        match schedule {
            Schedule::Static => {
                sweep::run_shard(&dir, spec, Shard::SERIAL, &mut runner)?;
            }
            Schedule::Dynamic => {
                // one in-process dynamic worker — same claim path as the
                // multi-worker case, so a second orchestrator pointed at
                // the same dir (e.g. another machine on a shared store)
                // cooperates instead of duplicating cells
                let cfg = DynamicConfig::new("orchestrator", ttl).with_affinity(affinity);
                let reg = if artifact_cache {
                    sweep::fleet::register(&dir, &cfg.worker, ttl).ok()
                } else {
                    None
                };
                let run =
                    sweep::run_dynamic_registered(&dir, spec, &cfg, reg.as_ref(), &mut runner)?;
                if let Some(reg) = reg {
                    reg.deregister();
                }
                eprintln!("sweep[{name}]: {}", run.summary());
            }
        }
    } else {
        // pass the environment-shaping options through to the workers
        let mut extra = Vec::new();
        for key in
            ["artifacts", "backend", "threads", "pool-grain", "simd", "config", "reports"]
        {
            if let Some(v) = args.get(key) {
                extra.push(format!("--{key}"));
                extra.push(v.to_string());
            }
        }
        extra.push("--session-cache".to_string());
        extra.push(if session_cache { "on" } else { "off" }.to_string());
        extra.push("--artifact-cache".to_string());
        extra.push(if artifact_cache { "on" } else { "off" }.to_string());
        if schedule == Schedule::Dynamic {
            extra.push("--schedule".to_string());
            extra.push("dynamic".to_string());
            extra.push("--lease-ttl-ms".to_string());
            extra.push(ttl.to_string());
            extra.push("--affinity".to_string());
            extra.push(if affinity { "on" } else { "off" }.to_string());
        }
        if let Some((seed, profile)) = &chaos {
            extra.push("--chaos-seed".to_string());
            extra.push(seed.to_string());
            extra.push("--chaos-profile".to_string());
            extra.push(profile.clone());
        }
        sweep::spawn_workers(&dir, shards, &extra, respawn_budget)?;
    }
    sweep::merge::merge(&dir, spec)
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(
        argv,
        &[
            "roberta",
            "all-tasks",
            "verbose",
            "help",
            "resume",
            "prefetch",
            "drain",
            "replay-verify",
            "retune",
        ],
    );
    use rmmlinear::tensor::kernels;
    use rmmlinear::tensor::pool;
    // Backend precedence: --backend flag > config file > RMM_BACKEND env.
    // Pool knobs follow the same layering (flag > config > RMM_THREADS /
    // RMM_POOL_GRAIN env, which the pool re-reads per run).
    let mut backend_chosen = false;
    if let Some(path) = args.get("config") {
        let cfg = rmmlinear::config::ExperimentConfig::load(Path::new(path))?;
        backend_chosen = cfg.apply_backend(); // false if no 'backend' key
        cfg.apply_pool(); // no-op if no 'pool' section
        cfg.apply_kernels()?; // no-op if no 'kernels' section
    }
    if let Some(bk) = args.get("backend") {
        let kind = kernels::BackendKind::parse(bk)
            .with_context(|| format!("unknown --backend '{bk}' (packed|scalar)"))?;
        kernels::set_backend(kind);
        backend_chosen = true;
    }
    if !backend_chosen {
        kernels::init_from_env(); // RMM_BACKEND, default packed
    }
    if let Some(t) = args.get("threads") {
        let n: usize = t
            .parse()
            .ok()
            .filter(|&n| n >= 1)
            .with_context(|| format!("--threads must be a positive integer, got '{t}'"))?;
        kernels::threads::set_threads_override(n);
    }
    if let Some(g) = args.get("pool-grain") {
        let n: usize = g
            .parse()
            .ok()
            .filter(|&n| n >= 1)
            .with_context(|| format!("--pool-grain must be a positive integer, got '{g}'"))?;
        pool::set_grain_override(n);
    }
    // SIMD precedence: --simd flag > config `kernels.simd` (applied
    // above) > RMM_SIMD env > CPU probe.  The env var is validated up
    // front even when a higher layer wins, so a typo'd RMM_SIMD fails
    // here as a normal error instead of panicking from the first kernel
    // call (or silently losing to the probe).
    kernels::dispatch::check_env()?;
    if let Some(s) = args.get("simd") {
        let level = kernels::dispatch::SimdLevel::parse(s).with_context(|| {
            format!("--simd must be one of scalar|portable|avx2|avx512|neon, got '{s}'")
        })?;
        kernels::dispatch::set_simd_override(Some(level))?;
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "pretrain" => cmd_pretrain(&args),
        "bench-table2" => cmd_table2(&args),
        "bench-table3" => cmd_table3(&args),
        "bench-table4" => cmd_table4(&args),
        "bench-budget" => cmd_budget(&args),
        "bench-fig3" => cmd_fig3(&args),
        "bench-fig4" => cmd_fig4(&args),
        "bench-fig5" => cmd_fig5(&args),
        "bench-fig6" => cmd_fig6(&args),
        "sweep-worker" => cmd_sweep_worker(&args),
        "sweep-selftest" => cmd_sweep_selftest(&args),
        "sweep-enqueue" => cmd_sweep_enqueue(&args),
        "sweep-daemon" => cmd_sweep_daemon(&args),
        "inspect-artifacts" => cmd_inspect(&args),
        "memory-model" => cmd_memory_model(&args),
        "tune-kernels" => cmd_tune_kernels(&args),
        "kernel-digest" => cmd_kernel_digest(&args),
        "help" | _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
repro — Memory-Efficient Backpropagation through Large Linear Layers (repro)

USAGE: repro <command> [--key value ...]

COMMANDS
  train             fine-tune a variant on a synthetic GLUE task
                    --variant NAME --task NAME [--steps N --lr F --seed N]
                    [--warm-start ck.bin] [--out runs/NAME]
  eval              evaluate a checkpoint on a task's dev split
                    --variant NAME --task NAME --checkpoint FILE
  pretrain          train on the MNLI-like corpus and save a body checkpoint
                    [--steps N] [--out runs/pretrained.bin]
  bench-table2      GLUE scores vs rho sweep (paper Table 2)
                    [--tasks cola,sst2,...|all] [--rhos 1.0,0.5,...] [--steps N]
                    [--seeds 1,2,3] [--shards N] [--resume]
  bench-table3      peak memory + saving per (task, batch, rho) (Table 3)
                    [--shards N] [--resume]
  bench-table4      sketch-family comparison on CoLA (Table 4)
                    [--shards N] [--resume]
  bench-budget      equal-budget estimator comparison: all seven estimator
                    configurations (five families + wtacrs + avjp-gauss)
                    and the closed-loop controller at one per-step memory
                    budget; engine-free (Philox probe tensors), every
                    (family, rho) choice recorded in the fragment
                    [--mem-budget F] [--seeds 1,2] [--shards N] [--resume]
  sweep-worker      run one worker of a prepared sweep (self-spawned by the
                    table drivers) --dir DIR --shard i/N
                    [--schedule static|dynamic --lease-ttl-ms N]
                    [--session-cache on|off --affinity on|off]
                    [--artifact-cache on|off] (on: registers in the
                    fleet registry under --dir and warm-starts from the
                    shared blob cache)
  sweep-selftest    sweep-machinery smoke: serial vs --shards N worker
                    processes must merge byte-identically
                    [--schedule static|dynamic]
                    [--grid mock|data|budget|synth-easy|synth-medium|
                     synth-hard]
                    [--session-cache on|off] [--artifact-cache on|off]
                    [--synth-seed N]
                    [--chaos-seed N [--chaos-profile P]] (--grid data
                    runs the warm session layer's data path; --grid
                    budget runs the closed-loop variance controller's
                    engine-free cells, pinning its recorded (family,
                    rho) choice sequences; synth-* are seeded workload
                    grids with skewed planned costs; chaos faults hit
                    only the sharded side — the serial reference stays
                    cold and fault-free); --out FILE writes the serial
                    reference report bytes (exactly what a daemon run
                    writes to reports/<id>.json, for byte comparison)
  sweep-enqueue     queue a selftest grid spec for a sweep daemon:
                    creates <queue>/incoming/<lane>/<name>.json
                    exclusively (re-queueing while queued is an error)
                    --queue DIR [--grid G] [--lane L] [--name N]
                    [--synth-seed N]
  sweep-daemon      serve sweeps from a queue directory: lanes drain
                    round-robin (fair across tenants) through warm
                    in-process workers; per-lane depth over --queue-cap
                    is shed to rejected/; typed JSONL events go to
                    stdout and <queue>/events.jsonl (append-only tee);
                    a restart resumes anything left in active/ from its
                    committed fragments
                    --queue DIR [--workers N --queue-cap N --poll-ms N]
                    [--drain] [--replay-verify] [--lease-ttl-ms N]
                    [--session-cache on|off --affinity on|off]
                    [--artifact-cache on|off] [--respawn-budget N]
                    [--chaos-seed N --chaos-profile P --chaos-gen G]
                    (--drain exits once the queue is empty;
                    --replay-verify re-parses the tee after a drain and
                    requires an exact round-trip of the emitted stream;
                    --chaos-gen G >= 1 on restart filters already-fired
                    kills, like --worker-gen for workers)
  bench-fig3        memory vs batch size [--all-tasks] (Fig 3/8)
  bench-fig4        variance-probe series (Fig 4/7)
  bench-fig5        loss curves vs rho [--task mnli] (Fig 5/9)
  bench-fig6        relative throughput vs rho (Fig 6)
  inspect-artifacts dump the manifest (variants, entries, arg counts)
  memory-model      analytic memory model [--rho F] [--batch N] [--roberta]
  tune-kernels      time the packed GEMM over the cache-blocking candidate
                    grid and print GFLOP/s per (MC,KC,NC); with --config
                    FILE the winner is persisted into the file's
                    kernels.tuned section.  A config that already carries
                    kernels.tuned is applied without re-timing (sweeps
                    never re-probe); --retune forces a fresh probe
                    [--reps N (default 3)] [--simd LEVEL]

COMMON OPTIONS
  --artifacts DIR   artifact directory (default: artifacts)
  --reports DIR     bench report directory (default: reports)
  --config FILE     experiment config JSON (applies its 'backend' key and
                    'pool' section: {\"threads\": N, \"grain_rows\": N})
  --backend NAME    host GEMM backend: packed (default) | scalar
                    (overrides --config; env override: RMM_BACKEND)
  --threads N       compute-pool participants per parallel run
                    (overrides --config; env: RMM_THREADS, re-read per
                    run; results are bit-identical for every value)
  --pool-grain N    rows per pool task for row-partitioned kernels
                    (overrides --config; env: RMM_POOL_GRAIN; load
                    balance only, never affects results)
  --simd LEVEL      force the GEMM microkernel dispatch level: scalar |
                    portable | avx2 | avx512 | neon (default: widest
                    level the CPU supports; config: kernels.simd; env:
                    RMM_SIMD — malformed or unsupported values are
                    rejected, never silently defaulted; results are
                    bit-identical at every level)
  --shards N        distribute a sweep's grid across N self-spawned worker
                    processes (default 1 = inline; config: sweep.shards;
                    merged reports are cell-order independent)
  --schedule MODE   sweep cell scheduler: static (round-robin --shard i/N,
                    default) | dynamic (atomic claim/lease work stealing
                    over the fragment dir — no stragglers under skewed
                    cell costs; config: sweep.schedule).  LR-schedule
                    values (linear|const|poly) still select the training
                    schedule; the value sets are disjoint.  To set both
                    at once, use --sweep-schedule static|dynamic (always
                    wins) alongside --schedule for the LR curve
  --lease-ttl-ms N  dynamic schedule only: claim age after which a cell
                    is considered abandoned and reclaimable; the trainer
                    refreshes its lease before step 0, every log_every
                    steps, and per eval batch, so this need only exceed
                    the longest stretch between ticks (log_every steps,
                    or one step with its one-time compile), not cell
                    wall time (default 600000; config:
                    sweep.lease_ttl_ms)
  --session-cache M  on|off (default on): reuse warm per-worker session
                    state — compiled executables, per-variant trainer
                    setups, tokenizer/dataset caches — across a worker's
                    sweep cells (config: sweep.session_cache).  Byte-
                    invisible in reports; off = explicit cold path
  --affinity M      on|off (default on): dynamic workers prefer unclaimed
                    cells matching their warm (variant, task) key before
                    canonical order, maximizing session reuse (config:
                    sweep.affinity); pure claim-order preference
  --artifact-cache M  on|off (default off): fleet mode — each dynamic
                    worker registers in workers/ under the sweep dir
                    (liveness via registry + lease heartbeats; stale
                    entries reclaimed like stale claims) and warm-starts
                    from cache/, a shared self-verifying blob store of
                    init-param and dev-batch artifacts published
                    create-exclusively by whichever worker computes them
                    first (config: sweep.artifact_cache).  Byte-
                    invisible in reports: blobs round-trip bit-exactly
                    and hit/publish counters go to stderr only
  --resume          reuse completed-cell manifests from a killed sweep
                    (config: sweep.resume); only missing cells rerun
  --prefetch        assemble the next batch on a background thread while
                    the trainer consumes the current one (bit-identical
                    to synchronous batching; config: train.prefetch)
  --prefetch-depth N  finished batches allowed to queue ahead of the
                    consumer when prefetching (default 1 = double
                    buffering; bit-identical at every depth; config:
                    train.prefetch_depth); also drives the eval-batch
                    prefetcher of the final dev-metric pass
  --chaos-seed N    seeded fault injection into the sweep's worker
                    processes: worker kills, corrupted/torn fragment
                    commits, transient claim-store IO errors, clock
                    skew, session evictions (config: sweep.chaos_seed).
                    Same seed + profile => identical fault schedule.
                    Merged reports stay byte-identical to a fault-free
                    run — chaos may only cost retries/respawns, never
                    results.  Worker processes only; ignored inline
  --chaos-profile P light | crash (default) | heavy, or an explicit
                    schedule '[w<slot>:]<point>@<hit>=<action>;...'
                    (actions: err:<kind> kill delay:<ms> skew:<ms>
                    truncate garbage evict; config: sweep.chaos_profile)
  --respawn-budget N  total crashed-worker respawns the sweep
                    supervisor allows before failing the sweep
                    (default 3 under chaos, else 0 = fail fast;
                    config: sweep.respawn_budget)
  --synth-seed N    seed for the synth-easy|medium|hard selftest grids
                    (default 1); cells and their planned costs are a
                    pure function of the seed
  --mem-budget F    bench-budget: allowed residual fraction of the exact
                    rho=1 layer store, in (0, 1] (default 0.5; config:
                    rmm.mem_budget — the CLI flag wins); the closed-loop
                    controller picks the minimum-variance (family, rho)
                    whose projection fits the budget
";

fn cmd_train(args: &Args) -> Result<()> {
    let manifest = load_manifest(args)?;
    let vname = args.get("variant").context("--variant required")?;
    let task = Task::parse(args.get("task").context("--task required")?)
        .context("unknown task")?;
    let cfg = train_config(args)?;
    let variant = manifest.variant(vname)?;
    let mut engine = Engine::cpu()?;
    let tok = Tokenizer::new(variant.config.vocab_size);
    let mut trainer = Trainer::new(&manifest, variant, task, cfg.clone())?;

    if let Some(ck_path) = args.get("warm-start") {
        let ck = Checkpoint::load(Path::new(ck_path))?;
        let n = trainer.load_matching(&ck.names, &ck.params);
        println!("warm start from {ck_path}: {n}/{} params", trainer.params.len());
    }

    let out_dir = PathBuf::from(args.get_or("out", "runs/train"));
    let mut log = MetricsLog::create(&out_dir.join("metrics.jsonl"))?;

    use rmmlinear::data::{Batcher, Split, TaskGen};
    let gen = TaskGen::new(task, &tok, variant.config.seq_len, cfg.seed);
    let mut epoch = 0u64;
    let mut batches = Batcher::new(&gen, Split::Train, variant.config.batch_size, epoch);
    println!(
        "training {vname} on {} ({} params, rho={}, sketch={})",
        task.name(),
        variant.param_count,
        variant.config.rho,
        variant.config.sketch
    );
    for step in 0..cfg.steps {
        let batch = match batches.next() {
            Some(b) => b,
            None => {
                epoch += 1;
                batches =
                    Batcher::new(&gen, Split::Train, variant.config.batch_size, epoch);
                batches.next().unwrap()
            }
        };
        let s = trainer.train_step(&mut engine, &batch)?;
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            println!(
                "step {:>5}  loss {:.4}  lr {:.2e}  |g| {:.3}  resid {:.1} KiB  {:.0} ms",
                s.step,
                s.loss,
                s.lr,
                s.grad_norm,
                s.residual_bytes as f64 / 1024.0,
                s.step_time_s * 1e3
            );
            log.log(Json::obj(vec![
                ("step", Json::num(s.step as f64)),
                ("loss", Json::num(s.loss)),
                ("lr", Json::num(s.lr)),
                ("grad_norm", Json::num(s.grad_norm)),
            ]));
        }
        if cfg.eval_every != 0 && step > 0 && step % cfg.eval_every == 0 {
            let score = trainer.evaluate(&mut engine, &tok)?;
            println!("step {:>5}  dev {} = {:.2}", step, task.name(), score);
        }
    }
    let score = trainer.evaluate(&mut engine, &tok)?;
    println!("final dev {} = {score:.2}", task.name());
    let ck = Checkpoint {
        step: cfg.steps,
        variant: vname.to_string(),
        names: trainer.param_names.clone(),
        params: trainer.params.clone(),
    };
    ck.save(&out_dir.join("checkpoint.bin"))?;
    println!("checkpoint -> {}", out_dir.join("checkpoint.bin").display());
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let manifest = load_manifest(args)?;
    let vname = args.get("variant").context("--variant required")?;
    let task = Task::parse(args.get("task").context("--task required")?)
        .context("unknown task")?;
    let variant = manifest.variant(vname)?;
    let mut engine = Engine::cpu()?;
    let tok = Tokenizer::new(variant.config.vocab_size);
    let mut trainer = Trainer::new(&manifest, variant, task, train_config(args)?)?;
    if let Some(ck_path) = args.get("checkpoint") {
        let ck = Checkpoint::load(Path::new(ck_path))?;
        let n = trainer.load_matching(&ck.names, &ck.params);
        println!("loaded {n} params from {ck_path}");
    }
    let score = trainer.evaluate(&mut engine, &tok)?;
    println!("dev {} = {score:.2}", task.name());
    Ok(())
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    // "Pre-training" analogue: train the encoder body on the biggest task
    // (MNLI-like) so Table 2 fine-tuning can warm-start, mirroring the
    // paper's pretrained-RoBERTa setting.
    let manifest = load_manifest(args)?;
    let mut engine = Engine::cpu()?;
    let mut cfg = train_config(args)?;
    if args.get("steps").is_none() {
        cfg.steps = 600;
    }
    let variant = manifest.variant("small_cls3_r100_gauss")?;
    let mut trainer = Trainer::new(&manifest, variant, Task::Mnli, cfg.clone())?;
    let tok = Tokenizer::new(variant.config.vocab_size);
    use rmmlinear::data::{Batcher, Split, TaskGen};
    let gen = TaskGen::new(Task::Mnli, &tok, variant.config.seq_len, cfg.seed);
    let mut epoch = 0;
    let mut batches = Batcher::new(&gen, Split::Train, variant.config.batch_size, epoch);
    for _ in 0..cfg.steps {
        let batch = match batches.next() {
            Some(b) => b,
            None => {
                epoch += 1;
                batches =
                    Batcher::new(&gen, Split::Train, variant.config.batch_size, epoch);
                batches.next().unwrap()
            }
        };
        trainer.train_step(&mut engine, &batch)?;
    }
    let score = trainer.evaluate(&mut engine, &tok)?;
    println!("pretrain: mnli dev = {score:.2}");
    let out = PathBuf::from(args.get_or("out", "runs/pretrained.bin"));
    Checkpoint {
        step: cfg.steps,
        variant: "small_cls3_r100_gauss".into(),
        names: trainer.param_names.clone(),
        params: trainer.params.clone(),
    }
    .save(&out)?;
    println!("pretrained body -> {}", out.display());
    Ok(())
}

fn parse_rhos(args: &Args, default: &[f64]) -> Vec<f64> {
    args.get("rhos")
        .map(|s| s.split(',').filter_map(|r| r.trim().parse().ok()).collect())
        .unwrap_or_else(|| default.to_vec())
}

fn cmd_table2(args: &Args) -> Result<()> {
    let tasks = bench::table2::tasks_from_arg(args.get("tasks"));
    if tasks.is_empty() {
        bail!("no valid tasks in --tasks");
    }
    let rhos = parse_rhos(args, &bench::table2::RHOS);
    let mut cfg = train_config(args)?;
    if args.get("steps").is_none() {
        cfg.steps = 300;
    }
    cfg.eval_every = usize::MAX;
    let seeds = parse_seeds(args, cfg.seed);
    let spec = bench::table2::spec(&tasks, &rhos, &seeds, cfg);
    let results = run_sweep(args, &spec, "table2")?;
    let report = bench::table2::assemble(&spec, &results);
    bench::write_report(&reports_dir(args), "table2", &report)
}

fn cmd_table3(args: &Args) -> Result<()> {
    let mut cfg = TrainConfig::default();
    cfg.steps = args.get_usize("steps", 5);
    cfg.seed = args.get_u64("seed", cfg.seed);
    cfg.prefetch = args.has_flag("prefetch");
    if let Some(d) = prefetch_depth_arg(args)? {
        cfg.prefetch_depth = d;
    }
    let spec = bench::table3::spec(cfg);
    let results = run_sweep(args, &spec, "table3")?;
    let report = bench::table3::assemble(&spec, &results);
    bench::write_report(&reports_dir(args), "table3", &report)
}

fn cmd_table4(args: &Args) -> Result<()> {
    let mut cfg = train_config(args)?;
    if args.get("steps").is_none() {
        cfg.steps = 300;
    }
    let spec = bench::table4::spec(cfg);
    let results = run_sweep(args, &spec, "table4")?;
    let report = bench::table4::assemble(&spec, &results);
    bench::write_report(&reports_dir(args), "table4", &report)
}

fn cmd_budget(args: &Args) -> Result<()> {
    let cfg = train_config(args)?;
    let budget = mem_budget_arg(args)?;
    let seeds = parse_seeds(args, cfg.seed);
    let spec = bench::budget::spec(cfg, budget, &seeds);
    let results = run_sweep(args, &spec, "budget")?;
    let report = bench::budget::assemble(&spec, &results);
    bench::write_report(&reports_dir(args), "budget", &report)
}

/// Strict sweep-scheduler parse for the worker/selftest entries (no
/// LR-schedule fallback: these commands never train from flags).
fn worker_schedule(args: &Args) -> Result<Schedule> {
    match args.get("sweep-schedule").or_else(|| args.get("schedule")) {
        Some(s) => Schedule::parse(s)
            .with_context(|| format!("unknown --schedule '{s}' (static|dynamic)")),
        None => Ok(Schedule::Static),
    }
}

/// One worker of a sweep, in this process — the contract `spawn_workers`
/// relies on: load `sweep.json` from `--dir`, run cells (the `--shard
/// i/N` subset under the static schedule; whatever it can claim under
/// `--schedule dynamic`), exit 0 iff every cell it ran committed.  The
/// worker owns one warm [`Session`] for its whole life (the point of the
/// session layer: same-variant cells share compiled executables, trainer
/// setups and dataset caches; `--session-cache off` disables reuse).
/// The "mock" experiment needs no artifacts, engine or session (used by
/// sweep-selftest and the orchestration tests); "mockdata" needs a
/// data-only session; `--mock-cell-ms N` inflates mock cell cost so the
/// crash/steal tests can kill a worker mid-lease.
fn cmd_sweep_worker(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get("dir").context("--dir required")?);
    let spec = sweep::resume::load_spec(&dir)?;
    let schedule = worker_schedule(args)?;
    let defaults = sweep_defaults(args)?;
    let session_cache = session_cache_flag(args, &defaults)?;
    let affinity = affinity_flag(args, &defaults)?;
    let mock_cost = std::time::Duration::from_millis(args.get_u64("mock-cell-ms", 0));
    // Install the fault schedule before the first sweep-store op, so
    // even the initial claim/fragment probe runs under chaos.  The slot
    // comes from the supervisor (`--worker-slot`), the generation from
    // `--worker-gen` — a respawned worker re-derives the *same* seeded
    // schedule minus already-fired kills, which is what makes a chaos
    // run replayable end to end.
    if let Some(seed) = chaos_seed_arg(args)? {
        rmmlinear::chaos::install(&rmmlinear::chaos::InstallOpts {
            seed,
            profile: args
                .get_or("chaos-profile", rmmlinear::chaos::DEFAULT_PROFILE)
                .to_string(),
            slot: args.get_usize("worker-slot", 0),
            generation: args.get_usize("worker-gen", 0) as u32,
            exit_on_kill: true,
            verbose: true,
        })?;
    }
    // One session per worker process, warm across every cell it runs.
    // "mock"/"mockdata" and the seeded synthetic grids need no
    // artifacts or engine — the synth tiers exist precisely so chaos
    // runs can hammer the orchestration layer without real training.
    let mut session = match spec.experiment.as_str() {
        "mock" | "mockdata" | "budget" => Session::data_only(session_cache),
        s if s.starts_with("synth-") => Session::data_only(session_cache),
        _ => Session::new(Engine::cpu()?, load_manifest(args)?, session_cache),
    };
    let artifact_cache = artifact_cache_flag(args, &defaults)?;
    if artifact_cache {
        session.set_artifact_cache(Some(sweep::fleet::ArtifactCache::open(&dir)?));
    }
    let mut runner = |cell: &sweep::Cell, ctx: &CellCtx<'_>| -> Result<Json> {
        if !mock_cost.is_zero() && spec.experiment == "mock" {
            std::thread::sleep(mock_cost);
        }
        bench::runner::run_cell(&mut session, &spec, cell, ctx)
    };
    match schedule {
        Schedule::Static => {
            let shard =
                Shard::parse(args.get("shard").context("--shard i/N required (static)")?)?;
            let ran = sweep::run_shard(&dir, &spec, shard, &mut runner)?;
            eprintln!("sweep-worker {shard}: ran {ran} cells");
        }
        Schedule::Dynamic => {
            let ttl = lease_ttl_arg(args)?.unwrap_or(sweep::DEFAULT_LEASE_TTL_MS);
            let cfg = DynamicConfig::new("worker", ttl).with_affinity(affinity);
            let worker = cfg.worker.clone();
            // Fleet registry entry for the life of this process:
            // registration rides `--artifact-cache` (both are fleet
            // machinery under the shared mount) and is best-effort —
            // the registry is observability, never correctness.  A
            // chaos-killed worker leaks its entry; liveness then ages
            // out of the registry heartbeat exactly like a stale claim.
            let reg = if artifact_cache {
                sweep::fleet::register(&dir, &worker, ttl).ok()
            } else {
                None
            };
            let run =
                sweep::run_dynamic_registered(&dir, &spec, &cfg, reg.as_ref(), &mut runner)?;
            if let Some(reg) = reg {
                reg.deregister();
            }
            eprintln!("sweep-worker {worker} (dynamic): {}", run.summary());
        }
    }
    eprintln!(
        "sweep-worker session cache [{}]: {}",
        if session_cache { "on" } else { "off" },
        session.stats.summary()
    );
    Ok(())
}

/// Resolve a `--grid` name into its sweep spec — shared by
/// `sweep-selftest` (runs it) and `sweep-enqueue` (queues it for the
/// daemon), so both paths describe exactly the same cells.
fn grid_spec(args: &Args, grid: &str) -> Result<SweepSpec> {
    Ok(match grid {
        "mock" => sweep::selftest_spec(),
        "data" => sweep::selftest_data_spec(),
        "budget" => sweep::selftest_budget_spec(),
        g if g.starts_with("synth-") => {
            sweep::synth_spec(args.get_u64("synth-seed", 1), &g["synth-".len()..])?
        }
        other => bail!(
            "unknown --grid '{other}' (mock|data|budget|synth-easy|synth-medium|synth-hard)"
        ),
    })
}

/// End-to-end smoke of the sweep machinery: a serial run and an
/// `--shards N` run through real worker processes must merge to
/// byte-identical reports, under either `--schedule`.  `--grid mock`
/// (default) exercises pure orchestration; `--grid data` runs the
/// `mockdata` session grid — the serial reference is always computed
/// **cold** (`--session-cache off`) while the workers honor the given
/// `--session-cache`, so CI running the selftest with `on` and `off`
/// pins warm ≡ cold ≡ serial byte-identity of the session layer.
fn cmd_sweep_selftest(args: &Args) -> Result<()> {
    let shards = args.get_usize("shards", 2).max(1);
    let schedule = worker_schedule(args)?;
    let grid = args.get_or("grid", "mock");
    let spec = grid_spec(args, grid)?;
    let session_cache = session_cache_flag(args, &SweepConfig::default())?;
    let artifact_cache = artifact_cache_flag(args, &SweepConfig::default())?;
    let chaos = chaos_opts(args, &SweepConfig::default())?;
    let respawn_budget =
        respawn_budget_arg(args, &SweepConfig::default(), chaos.is_some())?;
    let base = std::env::temp_dir().join(format!(
        "rmm_sweep_selftest_{}_{}_{}",
        grid,
        schedule.name(),
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&base);

    let serial_dir = base.join("serial");
    sweep::resume::prepare(&serial_dir, &spec, false)?;
    let mut cold = Session::data_only(false);
    sweep::run_shard(&serial_dir, &spec, Shard::SERIAL, &mut |c, ctx| {
        bench::runner::run_cell(&mut cold, &spec, c, ctx)
    })?;
    let serial = Json::Arr(sweep::merge::merge(&serial_dir, &spec)?).to_string_pretty();
    if let Some(out) = args.get("out") {
        // Exactly the bytes the daemon writes to `reports/<id>.json`
        // ([`rmmlinear::daemon::report_bytes`]), so a plain `cmp`
        // between this file and a daemon report pins the
        // daemon-vs-CLI byte-identity contract.
        std::fs::write(Path::new(out), format!("{serial}\n"))
            .with_context(|| format!("writing serial reference report to {out}"))?;
    }

    let sharded_dir = base.join("sharded");
    sweep::resume::prepare(&sharded_dir, &spec, false)?;
    let mut extra = vec![
        "--session-cache".to_string(),
        if session_cache { "on" } else { "off" }.to_string(),
        "--artifact-cache".to_string(),
        if artifact_cache { "on" } else { "off" }.to_string(),
    ];
    if schedule == Schedule::Dynamic {
        extra.push("--schedule".to_string());
        extra.push("dynamic".to_string());
    }
    // Chaos (kills, corrupted commits, transient IO, clock skew) hits
    // ONLY the sharded side — the serial reference stays fault-free, so
    // the byte-compare below pins the acceptance invariant: a chaos run
    // must merge to exactly the fault-free report.  Under the dynamic
    // schedule a killed worker's claim must go stale fast enough for a
    // respawn to reclaim it, hence the short default lease TTL.
    if let Some((seed, profile)) = &chaos {
        extra.push("--chaos-seed".to_string());
        extra.push(seed.to_string());
        extra.push("--chaos-profile".to_string());
        extra.push(profile.clone());
        if schedule == Schedule::Dynamic {
            extra.push("--lease-ttl-ms".to_string());
            extra.push(lease_ttl_arg(args)?.unwrap_or(3_000).to_string());
        }
    }
    sweep::spawn_workers(&sharded_dir, shards, &extra, respawn_budget)?;
    let sharded =
        Json::Arr(sweep::merge::merge(&sharded_dir, &spec)?).to_string_pretty();

    std::fs::remove_dir_all(&base).ok();
    let chaos_tag = match &chaos {
        Some((seed, profile)) => format!(", chaos {profile}#{seed}"),
        None => String::new(),
    };
    if serial != sharded {
        bail!(
            "sweep selftest FAILED: {shards}-worker {} merged report ({grid} grid, \
             session cache {}{chaos_tag}) differs from cold serial",
            schedule.name(),
            if session_cache { "on" } else { "off" },
        );
    }
    println!(
        "sweep selftest[{grid}/{}]: {} cells across {shards} worker processes \
         (session cache {}{chaos_tag}), byte-identical merged report",
        schedule.name(),
        spec.cells.len(),
        if session_cache { "on" } else { "off" },
    );
    Ok(())
}

/// Daemon defaults from the `--config` file's `daemon` section (CLI
/// flags take precedence), mirroring [`sweep_defaults`].
fn daemon_defaults(args: &Args) -> Result<rmmlinear::config::DaemonConfig> {
    match args.get("config") {
        Some(p) => Ok(rmmlinear::config::ExperimentConfig::load(Path::new(p))?.daemon),
        None => Ok(rmmlinear::config::DaemonConfig::default()),
    }
}

/// Write a sweep spec into a daemon queue's `incoming/<lane>/` under
/// create-exclusive semantics: queueing the same (lane, name) twice is
/// an error until the daemon moves the first copy on.  The spec comes
/// from the same `--grid` resolver as `sweep-selftest`, so a queued
/// grid and a directly-run grid are cell-for-cell identical — the basis
/// of the daemon-vs-CLI byte-identity contract.
fn cmd_sweep_enqueue(args: &Args) -> Result<()> {
    let queue = PathBuf::from(args.get("queue").context("--queue DIR required")?);
    let grid = args.get_or("grid", "mock");
    let spec = grid_spec(args, grid)?;
    let lane = args.get_or("lane", "default");
    // Default name: the grid itself, with synth grids disambiguated by
    // seed (two seeds of synth-easy are different sweeps).
    let default_name = match grid {
        g if g.starts_with("synth-") => {
            format!("{g}-s{}", args.get_u64("synth-seed", 1))
        }
        g => g.to_string(),
    };
    let name = args.get_or("name", &default_name);
    rmmlinear::daemon::queue::ensure_layout(&queue)?;
    let path = rmmlinear::daemon::queue::enqueue(&queue, lane, name, &spec)?;
    println!(
        "enqueued {} ({} cells) at {}",
        rmmlinear::daemon::queue::sweep_id(lane, name),
        spec.cells.len(),
        path.display()
    );
    Ok(())
}

/// Persistent sweep orchestrator: serve specs from a queue directory
/// through warm in-process worker threads, emitting the typed JSONL
/// event stream (stdout + teed to `<queue>/events.jsonl`).  See the
/// "Daemon queue + event contract" section of the [`rmmlinear::sweep`]
/// module doc for the full contract.  Crash recovery is free: the
/// fragment store is the only state, so restarting the daemon resumes
/// any sweep left in `active/` from its committed cells.
fn cmd_sweep_daemon(args: &Args) -> Result<()> {
    let queue = PathBuf::from(args.get("queue").context("--queue DIR required")?);
    let defaults = daemon_defaults(args)?;
    let chaos_seed = chaos_seed_arg(args)?;
    if let Some(seed) = chaos_seed {
        // Same install idiom as sweep-worker, but the daemon IS the
        // faulted process (its workers are threads, not children):
        // slot is fixed at 0 and `--chaos-gen` plays the role of
        // `--worker-gen` — a post-crash restart passes gen >= 1 so
        // already-fired kills are filtered from the replayed schedule.
        rmmlinear::chaos::install(&rmmlinear::chaos::InstallOpts {
            seed,
            profile: args
                .get_or("chaos-profile", rmmlinear::chaos::DEFAULT_PROFILE)
                .to_string(),
            slot: 0,
            generation: args.get_usize("chaos-gen", 0) as u32,
            exit_on_kill: true,
            verbose: true,
        })?;
    }
    let sw = sweep_defaults(args)?;
    let opts = rmmlinear::daemon::DaemonOpts {
        queue,
        workers: args.get_usize("workers", defaults.workers.unwrap_or(1)).max(1),
        queue_cap: args
            .get_usize(
                "queue-cap",
                defaults.queue_cap.unwrap_or(rmmlinear::daemon::DEFAULT_QUEUE_CAP),
            )
            .max(1),
        lease_ttl_ms: lease_ttl_arg(args)?
            .unwrap_or_else(|| sw.lease_ttl_ms.unwrap_or(sweep::DEFAULT_LEASE_TTL_MS)),
        affinity: affinity_flag(args, &sw)?,
        session_cache: session_cache_flag(args, &sw)?,
        artifact_cache: artifact_cache_flag(args, &sw)?,
        drain: args.has_flag("drain"),
        poll_ms: args.get_u64(
            "poll-ms",
            defaults.poll_ms.unwrap_or(rmmlinear::daemon::DEFAULT_POLL_MS),
        ),
        respawn_budget: respawn_budget_arg(args, &sw, chaos_seed.is_some())?,
        stdout_events: true,
        replay_verify: args.has_flag("replay-verify"),
    };
    let summary = rmmlinear::daemon::run(&opts)?;
    eprintln!(
        "sweep-daemon: {} sweep(s) merged, {} rejected, {} events emitted",
        summary.merged,
        summary.rejected,
        summary.events.len()
    );
    Ok(())
}

fn cmd_fig3(args: &Args) -> Result<()> {
    let mut session = load_session(args)?;
    let tasks = if args.has_flag("all-tasks") {
        Task::ALL.to_vec()
    } else {
        vec![Task::Cola]
    };
    let steps = args.get_usize("steps", 3);
    let report = bench::fig3::run(&mut session, &tasks, steps)?;
    bench::write_report(&reports_dir(args), "fig3", &report)
}

fn cmd_fig4(args: &Args) -> Result<()> {
    let mut session = load_session(args)?;
    let mut cfg = train_config(args)?;
    if args.get("steps").is_none() {
        cfg.steps = 200;
    }
    cfg.log_every = 1;
    let report = bench::fig4::run(&mut session, cfg)?;
    bench::write_report(&reports_dir(args), "fig4", &report)
}

fn cmd_fig5(args: &Args) -> Result<()> {
    let mut session = load_session(args)?;
    let task = Task::parse(args.get_or("task", "mnli")).context("unknown task")?;
    let mut cfg = train_config(args)?;
    if args.get("steps").is_none() {
        cfg.steps = 300;
    }
    cfg.log_every = (cfg.steps / 16).max(1);
    let report = bench::fig5::run(&mut session, task, cfg)?;
    bench::write_report(&reports_dir(args), "fig5", &report)
}

fn cmd_fig6(args: &Args) -> Result<()> {
    let mut session = load_session(args)?;
    let task = Task::parse(args.get_or("task", "cola")).context("unknown task")?;
    let steps = args.get_usize("steps", 30);
    let report = bench::fig6::run(&mut session, task, steps)?;
    bench::write_report(&reports_dir(args), "fig6", &report)
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let manifest = load_manifest(args)?;
    println!("{} variants in {}", manifest.variants.len(), manifest.dir.display());
    for (name, v) in &manifest.variants {
        let c = &v.config;
        println!(
            "{name:<34} rows={:<5} b_proj={:<5} rho={:<4} sketch={:<10} params={} entries=[{}]",
            v.rows,
            v.b_proj,
            c.rho,
            c.sketch,
            v.param_count,
            v.entries.keys().cloned().collect::<Vec<_>>().join(",")
        );
        if args.has_flag("verbose") {
            for (ename, e) in &v.entries {
                let resid = e.residual_args().count().max(e.residual_outputs().count());
                println!(
                    "    {ename}: {} args, {} outputs, {} residuals",
                    e.args.len(),
                    e.outputs.len(),
                    resid
                );
            }
        }
    }
    Ok(())
}

/// Time the packed GEMM over the blocking candidate grid and persist the
/// winner into `--config`'s `kernels.tuned` section.  A config already
/// carrying a tuned blocking is *applied*, never re-timed — sweeps can
/// invoke this unconditionally and pay the probe cost exactly once per
/// machine; `--retune` forces a fresh probe.
fn cmd_tune_kernels(args: &Args) -> Result<()> {
    use rmmlinear::tensor::kernels::{dispatch, tune};
    let path = args.get("config").map(PathBuf::from);
    if let (Some(p), false) = (&path, args.has_flag("retune")) {
        let cfg = rmmlinear::config::ExperimentConfig::load(p)?;
        if let Some((mc, kc, nc)) = cfg.kernels.tuned {
            cfg.apply_kernels()?;
            println!(
                "tune-kernels: {} already has kernels.tuned (mc={mc} kc={kc} nc={nc}); \
                 applied without re-timing (--retune forces a fresh probe)",
                p.display()
            );
            return Ok(());
        }
    }
    let reps = args.get_usize("reps", 3);
    eprintln!(
        "tune-kernels: timing {} blockings (simd={}, best of {reps} reps)",
        tune::candidates().len(),
        dispatch::active_level().name()
    );
    let (best, rows) = tune::autotune(reps);
    for (b, gf) in &rows {
        println!(
            "mc={:<4} kc={:<4} nc={:<5} {gf:>8.2} GFLOP/s{}",
            b.mc,
            b.kc,
            b.nc,
            if *b == best { "  <- best" } else { "" }
        );
    }
    tune::set_blocking_override(Some(best))?;
    if let Some(p) = &path {
        let mut cfg = rmmlinear::config::ExperimentConfig::load(p)?;
        cfg.kernels.tuned = Some((best.mc, best.kc, best.nc));
        std::fs::write(p, format!("{}\n", cfg.to_json().to_string_pretty()))
            .with_context(|| format!("writing tuned blocking to {}", p.display()))?;
        println!("tune-kernels: kernels.tuned -> {}", p.display());
    }
    Ok(())
}

/// Hidden subcommand backing the forced-dispatch matrix in
/// `prop_kernels.rs`: print FNV-1a digests of the kernel surfaces (all
/// three GEMM orientations on MR/NR-remainder shapes, all six streamed
/// projection families, the batched SORS fast path) so a subprocess grid
/// over `RMM_SIMD` × `RMM_THREADS` can byte-compare dispatch levels
/// without shipping tensors across process boundaries.
fn cmd_kernel_digest(_args: &Args) -> Result<()> {
    use rmmlinear::rmm::fft::sors_project_fast;
    use rmmlinear::rmm::sketch::{project_streamed, SketchKind};
    use rmmlinear::rng::philox::PhiloxStream;
    use rmmlinear::tensor::{matmul, matmul_at, matmul_bt, Tensor};
    use rmmlinear::util::fnv;

    fn digest(t: &Tensor) -> u64 {
        fnv::hash(t.data.iter().flat_map(|v| v.to_bits().to_le_bytes()))
    }
    fn probe(rows: usize, cols: usize, tag: u64) -> Tensor {
        let mut s = PhiloxStream::new(0x00d1_6000 + tag, 11);
        Tensor::from_fn(rows, cols, |_, _| s.next_normal())
    }

    // Adversarial GEMM shapes: m % MR != 0, n % NR != 0, odd k, plus one
    // aligned shape so both the remainder and steady-state tile paths are
    // in the digest.
    for (i, &(m, k, n)) in [(13, 29, 17), (70, 33, 41), (128, 64, 96)].iter().enumerate() {
        let tag = i as u64 * 4;
        let a = probe(m, k, tag);
        let b = probe(k, n, tag + 1);
        let at = probe(k, m, tag + 2);
        let bt = probe(n, k, tag + 3);
        println!("matmul[{m}x{k}x{n}]: {:016x}", digest(&matmul(&a, &b)));
        println!("matmul_at[{m}x{k}x{n}]: {:016x}", digest(&matmul_at(&at, &b)));
        println!("matmul_bt[{m}x{k}x{n}]: {:016x}", digest(&matmul_bt(&a, &bt)));
    }
    // All six streamed projection families on a remainder-heavy shape.
    let x = probe(53, 37, 100);
    for kind in [
        SketchKind::Gauss,
        SketchKind::Rademacher,
        SketchKind::Dct,
        SketchKind::Dft,
        SketchKind::RowSample,
        SketchKind::WtaCrs,
    ] {
        let p = project_streamed(kind, &x, 19, (7, 9));
        println!("project[{}]: {:016x}", kind.name(), digest(&p));
    }
    // Batched SORS fast path (needs power-of-two batch rows).
    let xs = probe(64, 40, 200);
    println!("sors[dct]: {:016x}", digest(&sors_project_fast(true, &xs, 24, (5, 6))));
    println!("sors[dft]: {:016x}", digest(&sors_project_fast(false, &xs, 24, (5, 6))));
    Ok(())
}

fn cmd_memory_model(args: &Args) -> Result<()> {
    let rho = args.get_f64("rho", 0.1);
    let geom = if args.has_flag("roberta") {
        ModelGeometry::roberta_base(args.get_usize("batch", 128), args.get_usize("seq", 128))
    } else {
        ModelGeometry {
            vocab_size: args.get_usize("vocab", 256),
            seq_len: args.get_usize("seq", 32),
            batch_size: args.get_usize("batch", 16),
            d_model: args.get_usize("d-model", 64),
            n_heads: args.get_usize("heads", 4),
            n_layers: args.get_usize("layers", 2),
            d_ff: args.get_usize("d-ff", 256),
            n_classes: args.get_usize("classes", 2),
        }
    };
    let m = MemoryModel::new(geom, rho);
    let base = MemoryModel::new(geom, 1.0);
    println!("geometry: {geom:?}");
    println!("params:           {:>14}", m.geom.param_count());
    println!("rho:              {rho:>14}");
    println!("b_proj:           {:>14} (rows {})", m.b_proj(), geom.rows());
    println!("residual bytes:   {:>14} (baseline {})", m.residual_bytes(), base.residual_bytes());
    println!("total bytes:      {:>14} (baseline {})", m.total_bytes(), base.total_bytes());
    println!("residual saving:  {:>13.1}%", m.residual_saving());
    println!("total saving:     {:>13.1}%", m.saving_vs_baseline());
    Ok(())
}
