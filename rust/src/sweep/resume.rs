//! Sweep directory lifecycle + resume-from-manifests.
//!
//! A sweep directory holds `sweep.json` (the serialized [`SweepSpec`],
//! the contract between orchestrator and workers), `cells/` (one
//! fragment per completed cell, see [`super::merge`], plus transient
//! `.claim` lease files under the dynamic schedule, see
//! [`super::claim`]), and per-worker stderr logs.  Resume is *implicit
//! in the fragment set*: a worker skips any cell whose valid fragment
//! already exists, so restarting a killed sweep with `--resume` reruns
//! only the missing cells and the merged report is byte-identical to an
//! uninterrupted run.  Claim files carry **no** completion state, so
//! `prepare(resume=true)` clears every leftover claim outright: the
//! killed run's stale leases would otherwise stall the resumed sweep in
//! the poll loop for up to the lease TTL, and sweeping a claim that
//! some still-live worker (another machine on a shared store) holds at
//! worst duplicates that one cell — benign, because duplicated
//! deterministic cells commit identical fragments.  Without `--resume`,
//! `prepare` clears the fragment directory — fragments and claims both
//! — so every cell reruns from scratch.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::grid::SweepSpec;
use super::merge;
use super::retry;

/// Per-cell fragment directory inside a sweep directory.
pub fn cells_dir(dir: &Path) -> PathBuf {
    dir.join("cells")
}

/// The serialized spec the workers read.
pub fn spec_path(dir: &Path) -> PathBuf {
    dir.join("sweep.json")
}

/// Create/refresh the sweep directory: clear fragments (and claims)
/// unless resuming, then (re)write `sweep.json` atomically.  Fragments
/// kept across a resume are revalidated against the new spec at read
/// time, so a grid change between runs silently invalidates only the
/// affected cells; claims superseded by a valid fragment are deleted.
pub fn prepare(dir: &Path, spec: &SweepSpec, resume: bool) -> Result<()> {
    let cdir = cells_dir(dir);
    if !resume && cdir.exists() {
        std::fs::remove_dir_all(&cdir)
            .with_context(|| format!("clearing sweep fragments {cdir:?}"))?;
    }
    if !resume {
        // A fresh run also clears the fleet registry (`workers/`): a
        // prior run's entries describe workers of *that* run, and a
        // stale one would otherwise advertise phantom liveness until
        // its TTL.  The artifact cache (`cache/`) is deliberately
        // KEPT — its blobs are keyed by content-determining inputs
        // only, so warm-starting a fresh run from them is exactly as
        // byte-safe as a worker warm-starting mid-run.
        let wdir = super::fleet::workers_dir(dir);
        if wdir.exists() {
            std::fs::remove_dir_all(&wdir)
                .with_context(|| format!("clearing fleet registry {wdir:?}"))?;
        }
    }
    std::fs::create_dir_all(&cdir)
        .with_context(|| format!("creating sweep dir {cdir:?}"))?;
    if resume {
        // Only fragments (`cell_<i>.json`) carry state.  Sweep the dead
        // run's other leavings: claim files (stale leases would stall
        // the resumed sweep until the TTL — see module doc for why this
        // is always safe), steal graves (`.claim.stale.*` from a thief
        // killed mid-reclaim), and orphaned staging files
        // (`.json.tmp.*` from a worker killed between write and
        // rename), which would otherwise accumulate across resumes.
        if let Ok(entries) = std::fs::read_dir(&cdir) {
            for entry in entries.flatten() {
                let name = entry.file_name();
                let name = name.to_string_lossy();
                if name.contains(".claim") || name.contains(".json.tmp") {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }
    }
    let tmp = dir.join("sweep.json.tmp");
    let text = spec.to_json().to_string_pretty();
    // Chaos fault point "resume.spec"; transient write errors retry
    // like every other sweep-store op.
    retry::io_retry("resume.spec", || {
        crate::chaos::fault("resume.spec")?;
        std::fs::write(&tmp, text.as_bytes())
    })
    .with_context(|| format!("writing {tmp:?}"))?;
    std::fs::rename(&tmp, spec_path(dir)).context("committing sweep.json")?;
    Ok(())
}

/// Load the spec a `prepare` call committed (the worker-side entry).
pub fn load_spec(dir: &Path) -> Result<SweepSpec> {
    let path = spec_path(dir);
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading sweep spec {path:?}"))?;
    let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
    SweepSpec::from_json(&j)
}

/// Completion bitmap over the spec's cells (true = valid fragment
/// present).  Diagnostic helper; workers use the per-cell check inline.
pub fn completed(dir: &Path, spec: &SweepSpec) -> Vec<bool> {
    let cdir = cells_dir(dir);
    spec.cells
        .iter()
        .map(|c| merge::read_fragment(&cdir, spec, c).is_some())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("rmm_resume_test_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn spec2() -> SweepSpec {
        let mut s = SweepSpec::new("mock", TrainConfig::default());
        s.push("v0", "cola", 1.0, "gauss", 1, 0);
        s.push("v1", "sst2", 0.5, "dft", 2, 0);
        s
    }

    #[test]
    fn prepare_writes_loadable_spec() {
        let dir = tmp("spec");
        let spec = spec2();
        prepare(&dir, &spec, false).unwrap();
        let back = load_spec(&dir).unwrap();
        assert_eq!(back.cells, spec.cells);
        assert_eq!(back.experiment, "mock");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prepare_clears_fragments_unless_resuming() {
        let dir = tmp("clear");
        let spec = spec2();
        prepare(&dir, &spec, false).unwrap();
        merge::write_fragment(&cells_dir(&dir), &spec, &spec.cells[0], &Json::num(1.0))
            .unwrap();
        assert_eq!(completed(&dir, &spec), vec![true, false]);
        // resume keeps the fragment …
        prepare(&dir, &spec, true).unwrap();
        assert_eq!(completed(&dir, &spec), vec![true, false]);
        // … a fresh run clears it
        prepare(&dir, &spec, false).unwrap();
        assert_eq!(completed(&dir, &spec), vec![false, false]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resume_clears_claims_and_tmp_litter_but_keeps_fragments() {
        use super::super::claim;
        let dir = tmp("claims");
        let spec = spec2();
        prepare(&dir, &spec, false).unwrap();
        let cdir = cells_dir(&dir);
        merge::write_fragment(&cdir, &spec, &spec.cells[0], &Json::num(1.0)).unwrap();
        // a killed run's full debris: claims on a completed and an
        // incomplete cell, a steal grave, an orphaned staging file
        std::fs::write(claim::claim_path(&cdir, 0), "").unwrap();
        std::fs::write(claim::claim_path(&cdir, 1), "").unwrap();
        let grave = cdir.join("cell_00001.claim.stale.w-9-0.0");
        let orphan = cdir.join("cell_00001.json.tmp.9999.3");
        std::fs::write(&grave, "").unwrap();
        std::fs::write(&orphan, "{trunc").unwrap();
        prepare(&dir, &spec, true).unwrap();
        assert!(
            !claim::claim_path(&cdir, 0).exists()
                && !claim::claim_path(&cdir, 1).exists()
                && !grave.exists()
                && !orphan.exists(),
            "resume must clear claims, graves and tmp litter"
        );
        assert_eq!(
            completed(&dir, &spec),
            vec![true, false],
            "resume must keep the fragment set untouched"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fresh_prepare_clears_the_registry_but_keeps_the_artifact_cache() {
        use super::super::fleet;
        let dir = tmp("fleet");
        let spec = spec2();
        prepare(&dir, &spec, false).unwrap();
        let reg = fleet::register(&dir, "w-old", 60_000).unwrap();
        let cache = fleet::ArtifactCache::open(&dir).unwrap();
        cache.store_dev(7, &[]).unwrap();
        std::mem::forget(reg); // simulate a killed worker leaking its entry
        // resume keeps both (the entry is someone's liveness evidence) …
        prepare(&dir, &spec, true).unwrap();
        assert!(!fleet::live_workers(&dir, 60_000).is_empty());
        // … a fresh run drops the registry and keeps the cache blobs
        prepare(&dir, &spec, false).unwrap();
        assert!(fleet::live_workers(&dir, 60_000).is_empty());
        assert!(fleet::ArtifactCache::open(&dir).unwrap().load_dev(7).is_some());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
