//! Atomic filesystem claim/lease protocol over the sweep fragment
//! directory — the coordination substrate of the dynamic scheduler
//! (`sweep::scheduler`).
//!
//! # Protocol
//!
//! A worker that wants to run cell `i` creates `cells/cell_<i>.claim`
//! with `O_CREAT | O_EXCL` ([`try_claim`]).  Create-exclusive is the
//! *only* acquisition path, so the OS guarantees **exactly one winner**
//! per claim file no matter how many workers (threads or processes, even
//! across machines sharing the fragment store) race for the same cell.
//! The claim embeds the worker id and a heartbeat timestamp
//! (unix-epoch ms) as JSON.
//!
//! A claim is a **lease**, not a lock: if its age exceeds the TTL it is
//! *stale* and any worker may reclaim the cell.  Reclaim renames the
//! stale file aside (rename is atomic; exactly one thief wins it) and
//! re-enters the create-exclusive race.  Staleness is judged by the
//! embedded heartbeat when the file parses, falling back to the file
//! mtime for a torn write (a worker killed between `open` and
//! `write_all`) — a torn claim is never mistaken for a live one
//! forever, and never yields a second winner.
//!
//! The steal is **verified after capture**: between a thief's staleness
//! read and its rename, a faster thief can complete an entire steal and
//! re-claim, leaving a *fresh* claim at the path.  The renamer therefore
//! re-judges the file it actually captured; if it robbed a live claim it
//! restores it via `hard_link` (atomic — loses to any newer claim) and
//! reports the cell held.  Only a ≥3-party interleaving inside that
//! microsecond window can still admit a duplicate owner, which is the
//! benign duplicate-run corner described below.
//!
//! Mutual exclusion here is a *scheduling efficiency* property, not a
//! correctness property: if a stale-but-alive worker and its reclaimer
//! both finish the same cell, both commit the same deterministic
//! fragment via the atomic tmp+rename in `sweep::merge`, and the merged
//! report is unchanged.  Correctness always comes from the fragment set;
//! claims only keep workers from duplicating work.
//!
//! Completed cells need no claim at all — a valid fragment supersedes
//! any claim file (the scheduler deletes leftover claims when it sees
//! the fragment, and `resume::prepare` sweeps them on `--resume`).
//!
//! # Flaky mounts and skewed clocks
//!
//! Claim-store filesystem ops (create-exclusive open, heartbeat
//! refresh, reclaim rename) run under bounded jittered-backoff retry
//! (`sweep::retry`) for *transient* `io::Error`s, so a flaky shared
//! mount degrades to latency instead of a dead worker; fatal kinds
//! still fail fast, and `ClaimGuard`'s drop release stays best-effort.
//! Staleness tolerates clock skew between hosts, in **both**
//! directions, by treating the embedded heartbeat as *evidence of
//! liveness only* — it can keep a claim alive, never condemn it.  The
//! effective age is the **minimum** of the heartbeat age (when the
//! heartbeat is plausible) and the file mtime age: an embedded
//! heartbeat more than one TTL in the *reader's* future cannot belong
//! to a live worker refreshing on schedule, so it is discounted and
//! the claim is judged by mtime like a torn write — a dead worker with
//! a fast clock wedges its cell for one TTL, not skew + TTL.
//! Symmetrically, a heartbeat deep in the reader's *past* (a slow
//! writer clock, or a fast reader clock) does not get a live claim
//! robbed as long as its refreshes keep the file **mtime** fresh —
//! mtime comes from the store's own clock, which every reader of a
//! shared mount agrees on.  The heartbeat value is parsed strictly
//! (non-negative integer below 2^53, the same bound the config layer
//! enforces for seeds); anything else — negative, fractional,
//! non-finite, or overflowing the f64-lossless range — is treated as a
//! torn write and judged by mtime.  Each op is also a named chaos
//! fault point (`claim.create` / `claim.refresh` / `claim.reclaim`,
//! plus `clock` skew through [`now_ms`]) — see the sweep module doc's
//! chaos-knobs section.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::{Context, Result};

use super::grid::MAX_JSON_SEED;
use super::retry;
use crate::util::json::Json;

/// Claim-file path for a cell inside the sweep's `cells/` directory
/// (sibling of the `cell_<index>.json` fragment; `merge` looks fragments
/// up by exact path, so claim files are invisible to it).
pub fn claim_path(cells_dir: &Path, index: usize) -> PathBuf {
    cells_dir.join(format!("cell_{index:05}.claim"))
}

/// Milliseconds since the unix epoch (the heartbeat clock).  An
/// installed chaos clock-skew fault shifts this process's view of it —
/// exactly how a badly-synced host on a shared claim store behaves.
pub fn now_ms() -> u64 {
    let real = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0);
    match crate::chaos::skew_ms() {
        0 => real,
        s if s > 0 => real.saturating_add(s as u64),
        s => real.saturating_sub(s.unsigned_abs()),
    }
}

/// A process-unique worker id: `<label>-<pid>-<seq>`.  The pid makes ids
/// unique across worker processes sharing a fragment store on one host;
/// the sequence number makes them unique across threads in one process.
pub fn worker_id(label: &str) -> String {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    format!("{label}-{}-{}", std::process::id(), SEQ.fetch_add(1, Ordering::Relaxed))
}

/// The parsed content of a claim file.
#[derive(Debug, Clone, PartialEq)]
pub struct ClaimInfo {
    pub worker: String,
    pub heartbeat_ms: u64,
}

/// The canonical lease-file body — shared with the fleet registry
/// (`sweep::fleet`), whose entries are judged by the same staleness
/// rule.
pub(crate) fn claim_body(worker: &str, heartbeat_ms: u64) -> String {
    Json::obj(vec![
        ("heartbeat_ms", Json::num(heartbeat_ms as f64)),
        ("worker", Json::str(worker)),
    ])
    .to_string_pretty()
}

/// Strictly parse a `heartbeat_ms` value: a non-negative integer below
/// 2^53 (the same f64-lossless bound the config layer enforces for
/// seeds).  A float cast alone would wrap negatives through `as u64`
/// and silently lose precision above 2^53, corrupting liveness math —
/// anything outside the strict range reads as absent, i.e. a torn
/// write that falls back to mtime staleness.
fn parse_heartbeat_ms(j: &Json) -> Option<u64> {
    let v = j.as_f64()?;
    if !v.is_finite() || v < 0.0 || v.fract() != 0.0 || v >= MAX_JSON_SEED as f64 {
        return None;
    }
    Some(v as u64)
}

/// Read a cell's claim, if present and parseable (diagnostics; the
/// scheduler itself only needs [`try_claim`]).  A claim whose
/// `heartbeat_ms` fails the strict parse is reported as torn (absent).
pub fn read_claim(cells_dir: &Path, index: usize) -> Option<ClaimInfo> {
    let text = std::fs::read_to_string(claim_path(cells_dir, index)).ok()?;
    let j = Json::parse(&text).ok()?;
    Some(ClaimInfo {
        worker: j.get("worker").as_str()?.to_string(),
        heartbeat_ms: parse_heartbeat_ms(j.get("heartbeat_ms"))?,
    })
}

/// Best-effort removal of a cell's claim file (used when a valid
/// fragment supersedes it, and by `resume::prepare`).
pub fn remove_claim(cells_dir: &Path, index: usize) {
    let _ = std::fs::remove_file(claim_path(cells_dir, index));
}

/// Age of the claim at `path` in ms, `None` if the file vanished.
///
/// The heartbeat is evidence of *liveness only*: the effective age is
/// the **minimum** of the plausible heartbeat age and the file mtime
/// age, so a claim stays live if *either* clock says so, and goes
/// stale only when both agree.
///
/// A heartbeat more than `ttl_ms` in the reader's *future* is clock
/// skew, not liveness — a live worker refreshing within one TTL can
/// never be that far ahead of any honest reader — so it is discounted
/// and only the mtime counts.  (A heartbeat at most `ttl_ms` ahead
/// reads as age 0: mild NTP drift never gets a live claim robbed.)
/// Symmetrically, a heartbeat deep in the reader's *past* — a slow
/// writer clock, or a fast reader — cannot condemn a claim whose
/// refreshes keep the mtime fresh: mtime comes from the store's own
/// clock, the one clock all readers of a shared mount agree on.
/// A torn or out-of-range heartbeat (strict parse) leaves mtime as
/// the only witness.
pub(crate) fn age_ms(path: &Path, ttl_ms: u64) -> Option<u64> {
    let now = now_ms();
    let mut hb_age = None;
    if let Ok(text) = std::fs::read_to_string(path) {
        if let Ok(j) = Json::parse(&text) {
            if let Some(hb) = parse_heartbeat_ms(j.get("heartbeat_ms")) {
                if hb <= now.saturating_add(ttl_ms) {
                    hb_age = Some(now.saturating_sub(hb));
                }
                // else: future-skewed heartbeat, judge by mtime alone
            }
        }
    }
    let mtime = std::fs::metadata(path).ok()?.modified().ok()?;
    let mtime_ms = mtime.duration_since(UNIX_EPOCH).ok()?.as_millis() as u64;
    let mtime_age = now.saturating_sub(mtime_ms);
    Some(hb_age.map_or(mtime_age, |h| h.min(mtime_age)))
}

/// Outcome of one claim attempt.
pub enum ClaimAttempt {
    /// This worker owns the cell until it releases (or its lease goes
    /// stale).  Dropping the guard releases the claim, so a worker that
    /// errors out never wedges the cell for a full TTL.
    Won(ClaimGuard),
    /// Another worker holds a fresh lease (or won a reclaim race);
    /// revisit the cell on a later pass.
    Held,
}

/// Try to claim `cells/cell_<index>.claim` for `worker`.  Exactly one
/// concurrent claimant wins; stale leases (age > `ttl_ms`) are renamed
/// aside and re-raced.  Contention beyond a few rounds reports [`Held`]
/// — the scheduler's pass loop retries naturally.
///
/// [`Held`]: ClaimAttempt::Held
pub fn try_claim(
    cells_dir: &Path,
    index: usize,
    worker: &str,
    ttl_ms: u64,
) -> Result<ClaimAttempt> {
    let path = claim_path(cells_dir, index);
    for round in 0..4u32 {
        // Transient create errors (flaky mount) retry in place; the
        // protocol's AlreadyExists race signal is not transient and
        // passes straight through to the lease logic below.
        let opened = retry::io_retry(&format!("claim.create:{index}:{worker}"), || {
            crate::chaos::fault("claim.create")?;
            std::fs::OpenOptions::new().write(true).create_new(true).open(&path)
        });
        match opened {
            Ok(mut f) => {
                // A failed/torn body write degrades to mtime-based
                // staleness, never to a second winner — ignore it.
                let _ = f.write_all(claim_body(worker, now_ms()).as_bytes());
                return Ok(ClaimAttempt::Won(ClaimGuard {
                    path,
                    worker: worker.to_string(),
                    released: false,
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                match age_ms(&path, ttl_ms) {
                    // Vanished between open and stat (released or
                    // stolen): re-enter the create race.
                    None => continue,
                    Some(age) if age <= ttl_ms => return Ok(ClaimAttempt::Held),
                    Some(_) => {
                        // Stale lease: capture it by atomic rename (one
                        // thief wins; losers see NotFound and loop) …
                        let grave = cells_dir
                            .join(format!("cell_{index:05}.claim.stale.{worker}.{round}"));
                        let captured =
                            retry::io_retry(&format!("claim.reclaim:{index}:{worker}"), || {
                                crate::chaos::fault("claim.reclaim")?;
                                std::fs::rename(&path, &grave)
                            });
                        if captured.is_err() {
                            continue; // lost the steal race (or a flaky
                                      // mount gave up): re-judge
                        }
                        // … then verify the capture: a faster thief may
                        // have stolen-and-reclaimed between our read and
                        // our rename, in which case we just robbed a
                        // LIVE claim (TOCTOU) and must put it back.
                        let stale = age_ms(&grave, ttl_ms).map_or(true, |age| age > ttl_ms);
                        if stale {
                            let _ = std::fs::remove_file(&grave);
                            continue; // legitimate steal: re-race create
                        }
                        // hard_link is atomic and fails if a newer claim
                        // already took the path (that claimant owns it).
                        let _ = std::fs::hard_link(&grave, &path);
                        let _ = std::fs::remove_file(&grave);
                        return Ok(ClaimAttempt::Held);
                    }
                }
            }
            Err(e) => {
                return Err(e).with_context(|| format!("creating claim {path:?}"))
            }
        }
    }
    Ok(ClaimAttempt::Held)
}

/// A held claim.  Release after committing the cell's fragment; dropping
/// without release (error/unwind path) also removes the claim file so
/// other workers can retry the cell immediately instead of waiting out
/// the lease.
pub struct ClaimGuard {
    path: PathBuf,
    worker: String,
    released: bool,
}

impl ClaimGuard {
    pub fn worker(&self) -> &str {
        &self.worker
    }

    /// Re-stamp the heartbeat (tmp + rename, so readers never see a torn
    /// claim).  Long-running cell runners can call this to keep a lease
    /// fresh past the TTL; the scheduler's contract is otherwise that the
    /// TTL exceeds the worst-case cell wall time.
    pub fn refresh(&self) -> Result<()> {
        let tmp = self.path.with_extension(format!("claim.hb.{}", std::process::id()));
        retry::io_retry(&format!("claim.refresh:{}", self.worker), || {
            crate::chaos::fault("claim.refresh")?;
            std::fs::write(&tmp, claim_body(&self.worker, now_ms()))
        })
        .with_context(|| format!("writing heartbeat {tmp:?}"))?;
        retry::io_retry(&format!("claim.refresh.commit:{}", self.worker), || {
            crate::chaos::fault("claim.refresh")?;
            std::fs::rename(&tmp, &self.path)
        })
        .with_context(|| format!("committing heartbeat {:?}", self.path))?;
        Ok(())
    }

    /// Remove the claim file (after the fragment is committed).
    pub fn release(mut self) {
        self.released = true;
        let _ = std::fs::remove_file(&self.path);
    }
}

impl Drop for ClaimGuard {
    fn drop(&mut self) {
        if !self.released {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("rmm_claim_test_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn worker_ids_are_unique() {
        let a = worker_id("w");
        let b = worker_id("w");
        assert_ne!(a, b);
        assert!(a.contains(&std::process::id().to_string()));
    }

    #[test]
    fn create_exclusive_has_one_winner() {
        let d = tmp("one_winner");
        let first = try_claim(&d, 3, "alpha", 60_000).unwrap();
        let ga = match first {
            ClaimAttempt::Won(g) => g,
            ClaimAttempt::Held => panic!("first claimant must win"),
        };
        assert!(matches!(try_claim(&d, 3, "beta", 60_000).unwrap(), ClaimAttempt::Held));
        // the claim file records the winner + a recent heartbeat
        let info = read_claim(&d, 3).unwrap();
        assert_eq!(info.worker, "alpha");
        assert!(now_ms().saturating_sub(info.heartbeat_ms) < 60_000);
        // release frees the cell for the next claimant
        ga.release();
        assert!(matches!(try_claim(&d, 3, "beta", 60_000).unwrap(), ClaimAttempt::Won(_)));
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn guard_drop_releases_the_claim() {
        let d = tmp("drop");
        {
            let _g = match try_claim(&d, 0, "w", 60_000).unwrap() {
                ClaimAttempt::Won(g) => g,
                ClaimAttempt::Held => panic!(),
            };
            assert!(claim_path(&d, 0).exists());
        }
        assert!(!claim_path(&d, 0).exists(), "drop must remove the claim");
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn stale_lease_is_reclaimable_fresh_is_not() {
        let d = tmp("stale");
        // a killed worker's claim: the heartbeat is ancient AND the
        // file mtime goes stale (no refresh re-stamps it) — both
        // witnesses agree, so the lease is reclaimable
        std::fs::write(claim_path(&d, 7), claim_body("dead-worker", 1)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(40));
        match try_claim(&d, 7, "thief", 25).unwrap() {
            ClaimAttempt::Won(g) => {
                assert_eq!(read_claim(&d, 7).unwrap().worker, "thief");
                g.release();
            }
            ClaimAttempt::Held => panic!("stale lease must be reclaimable"),
        }
        // a live claim with a current heartbeat is not stealable
        std::fs::write(claim_path(&d, 7), claim_body("live-worker", now_ms())).unwrap();
        assert!(matches!(try_claim(&d, 7, "thief", 60_000).unwrap(), ClaimAttempt::Held));
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn out_of_range_heartbeats_are_torn_writes_judged_by_mtime() {
        let d = tmp("strict_hb");
        // Corrupt heartbeats a lossy float cast would have silently
        // accepted: negative (wraps through `as u64`), ≥2^53 (loses
        // precision), fractional.  All must read as torn — absent from
        // read_claim, mtime-judged for staleness.
        for (i, hb) in ["-5", "9007199254740993", "12.5"].iter().enumerate() {
            let body = format!("{{\"heartbeat_ms\": {hb}, \"worker\": \"w\"}}");
            std::fs::write(claim_path(&d, i), body).unwrap();
            assert!(read_claim(&d, i).is_none(), "heartbeat {hb} must parse as torn");
            // fresh mtime shields it under a generous TTL …
            assert!(matches!(try_claim(&d, i, "t", 60_000).unwrap(), ClaimAttempt::Held));
        }
        // … and mtime-staleness reclaims it (a negative heartbeat cast
        // through f64→u64 would have wrapped to a huge "future" value
        // and wedged the cell forever)
        std::thread::sleep(std::time::Duration::from_millis(40));
        for i in 0..3 {
            match try_claim(&d, i, "t", 25).unwrap() {
                ClaimAttempt::Won(g) => g.release(),
                ClaimAttempt::Held => panic!("torn heartbeat must age by mtime"),
            }
        }
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn torn_claim_ages_by_mtime() {
        let d = tmp("torn");
        // an empty (torn) claim file: unparseable, so staleness falls
        // back to mtime — fresh now, held under a generous TTL
        std::fs::write(claim_path(&d, 2), "").unwrap();
        assert!(matches!(try_claim(&d, 2, "w", 60_000).unwrap(), ClaimAttempt::Held));
        // with a zero TTL the same torn file goes stale as soon as its
        // mtime-age ticks past 0 ms
        std::thread::sleep(std::time::Duration::from_millis(15));
        match try_claim(&d, 2, "w", 0).unwrap() {
            ClaimAttempt::Won(g) => g.release(),
            ClaimAttempt::Held => panic!("torn claim must go stale by mtime"),
        }
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn refresh_restamps_the_heartbeat() {
        let d = tmp("refresh");
        let g = match try_claim(&d, 1, "w", 60_000).unwrap() {
            ClaimAttempt::Won(g) => g,
            ClaimAttempt::Held => panic!(),
        };
        let hb0 = read_claim(&d, 1).unwrap().heartbeat_ms;
        std::thread::sleep(std::time::Duration::from_millis(5));
        g.refresh().unwrap();
        let hb1 = read_claim(&d, 1).unwrap().heartbeat_ms;
        assert!(hb1 > hb0, "refresh must advance the heartbeat ({hb0} -> {hb1})");
        g.release();
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn future_skewed_heartbeat_falls_back_to_mtime() {
        let d = tmp("future_hb");
        // A dead worker whose clock ran an hour ahead: trusting the
        // embedded heartbeat would read "age 0" and shield the claim
        // for skew + TTL.  Beyond one TTL of future skew we judge by
        // mtime instead — fresh mtime still holds under a generous TTL…
        std::fs::write(claim_path(&d, 4), claim_body("fast-clock", now_ms() + 3_600_000))
            .unwrap();
        assert!(matches!(try_claim(&d, 4, "w", 5_000).unwrap(), ClaimAttempt::Held));
        // …but the claim goes stale as soon as the mtime-age passes a
        // short TTL, instead of an hour from now.
        std::thread::sleep(std::time::Duration::from_millis(25));
        match try_claim(&d, 4, "w", 10).unwrap() {
            ClaimAttempt::Won(g) => g.release(),
            ClaimAttempt::Held => panic!("future-skewed heartbeat must age by mtime"),
        }
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn mildly_future_heartbeat_within_ttl_stays_live() {
        let d = tmp("drift_hb");
        // A *live* worker a couple of seconds ahead (ordinary NTP
        // drift) must not be robbed: within one TTL the embedded
        // heartbeat is trusted as-is and reads as age 0.
        std::fs::write(claim_path(&d, 5), claim_body("drifty", now_ms() + 2_000)).unwrap();
        assert!(matches!(try_claim(&d, 5, "w", 60_000).unwrap(), ClaimAttempt::Held));
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn past_skewed_heartbeat_with_fresh_mtime_stays_live() {
        let d = tmp("slow_hb");
        // A *live* worker with a slow clock stamps heartbeats that are
        // already "old" to every honest reader — but its refreshes
        // keep the file mtime fresh, and mtime comes from the store's
        // clock, which reader and writer share.  The heartbeat can
        // only prove liveness, never staleness: the claim must NOT be
        // robbed just because the embedded clock lags.
        std::fs::write(
            claim_path(&d, 6),
            claim_body("slow-clock", now_ms().saturating_sub(5_000)),
        )
        .unwrap();
        assert!(
            matches!(try_claim(&d, 6, "thief", 1_000).unwrap(), ClaimAttempt::Held),
            "past-skewed heartbeat with a fresh mtime must stay live"
        );
        // Once the worker dies and the mtime goes stale too, the claim
        // is reclaimable — both witnesses now agree.
        std::thread::sleep(std::time::Duration::from_millis(40));
        match try_claim(&d, 6, "thief", 25).unwrap() {
            ClaimAttempt::Won(g) => g.release(),
            ClaimAttempt::Held => panic!("dead slow-clock worker must be reclaimable"),
        }
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn remove_claim_is_idempotent() {
        let d = tmp("remove");
        remove_claim(&d, 9); // nothing there: fine
        std::fs::write(claim_path(&d, 9), claim_body("w", now_ms())).unwrap();
        remove_claim(&d, 9);
        assert!(!claim_path(&d, 9).exists());
        std::fs::remove_dir_all(&d).unwrap();
    }
}
