//! Cross-machine fleet primitives over the shared sweep mount: a
//! **worker registry** (`workers/` — who is part of this sweep, with
//! liveness) and a **shared artifact cache** (`cache/` — warm-start
//! blobs so a brand-new worker process skips cold start).
//!
//! Both live as siblings of `cells/` inside the sweep directory and are
//! **invisible to the merge**: fragments are looked up by exact path in
//! `cells/`, so nothing here can ever perturb a report.  The canonical
//! prose contract (mount layout, registry lifecycle, cache key/commit
//! rules) is the "Fleet registry + artifact cache" section of the
//! `sweep` module doc.
//!
//! # Registry
//!
//! A worker joining a sweep creates `workers/<worker_id>.json`
//! create-exclusively ([`register`]) — the same exactly-one-winner
//! acquisition the claim store uses — with the claim-file body shape
//! (`{"heartbeat_ms": N, "worker": id}`).  The returned
//! [`RegistryGuard`] re-stamps the heartbeat ([`RegistryGuard::
//! heartbeat`], chaos point `registry.heartbeat`) whenever the in-cell
//! lease ticks through `CellCtx`, deregisters on clean release, and
//! best-effort removes the file on drop.  Liveness is judged by the
//! claim store's symmetric rule (min of plausible-heartbeat age and
//! mtime age — see `sweep::claim`): [`live_workers`] lists the live
//! membership, [`reclaim_stale`] sweeps entries whose worker died
//! without deregistering, mirroring `claim`'s stale reclaim.  Workers
//! are **elastic**: registration is not an admission gate — a worker
//! that registers after `run_dynamic` started simply claims whatever
//! cells remain, and one that deregisters mid-sweep leaves the rest to
//! the survivors.  The registry is observability + fleet accounting,
//! never scheduling state.
//!
//! # Artifact cache
//!
//! [`ArtifactCache`] spills the two expensive warm-session objects to
//! the mount so *new* worker processes warm-start: per-variant
//! [`TrainerSetup`] init-param blobs (keyed by FNV of the manifest dir
//! + variant name) and dev-batch sets (keyed by FNV of task, seq_len,
//! vocab, batch_size, seed — exactly the session's `DevKey`).  Entries
//! are self-verifying binary blobs (magic, key echo, payload, FNV
//! digest); any mismatch reads as absent, so a torn or corrupted cache
//! entry costs a regeneration, never a wrong result.  Publication uses
//! the writer-unique tmp + `hard_link` idiom (the queue's enqueue
//! idiom): every concurrent writer encodes identical bytes for a key
//! (the cached objects are pure functions of their keys), exactly one
//! `hard_link` wins the final path, and losers just discard their tmp.
//! The publish carries the chaos point `cache.publish`.  Cache traffic
//! surfaces only in `SessionStats` (worker stderr) — never in fragment
//! JSON — so warm-start is observation-free and warm ≡ cold
//! byte-identity holds with the cache on, off, pre-seeded, or torn.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use crate::coordinator::TrainerSetup;
use crate::data::Batch;
use crate::util::fnv;

use super::claim;
use super::retry;

/// The registry directory inside a sweep directory.
pub fn workers_dir(dir: &Path) -> PathBuf {
    dir.join("workers")
}

/// The shared artifact-cache directory inside a sweep directory.
pub fn cache_dir(dir: &Path) -> PathBuf {
    dir.join("cache")
}

/// Registry-entry path for one worker.
pub fn registry_path(dir: &Path, worker: &str) -> PathBuf {
    workers_dir(dir).join(format!("{worker}.json"))
}

/// Join the sweep's fleet: create `workers/<worker>.json`
/// create-exclusively with a fresh heartbeat.  A leftover entry under
/// the same id (a rebooted host re-using a pid) is reclaimed when
/// stale, exactly like a stale claim; a *live* same-id entry is a
/// caller bug (worker ids are process-unique) and errors out.
pub fn register(dir: &Path, worker: &str, ttl_ms: u64) -> Result<RegistryGuard> {
    let wdir = workers_dir(dir);
    std::fs::create_dir_all(&wdir)
        .with_context(|| format!("creating registry dir {wdir:?}"))?;
    let path = registry_path(dir, worker);
    for _ in 0..2 {
        let opened = retry::io_retry(&format!("registry.register:{worker}"), || {
            std::fs::OpenOptions::new().write(true).create_new(true).open(&path)
        });
        match opened {
            Ok(mut f) => {
                use std::io::Write;
                let _ = f.write_all(claim::claim_body(worker, claim::now_ms()).as_bytes());
                return Ok(RegistryGuard {
                    path,
                    worker: worker.to_string(),
                    released: false,
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                let stale = claim::age_ms(&path, ttl_ms).map_or(true, |age| age > ttl_ms);
                if !stale {
                    bail!("worker '{worker}' is already registered and live at {path:?}");
                }
                let _ = std::fs::remove_file(&path);
                continue;
            }
            Err(e) => return Err(e).with_context(|| format!("registering {path:?}")),
        }
    }
    bail!("registering worker '{worker}': lost the re-register race twice")
}

/// A registered fleet membership.  Deregister on clean exit; dropping
/// without deregistering also removes the entry (error/unwind path),
/// and a worker killed outright leaves a stale entry for
/// [`reclaim_stale`].
pub struct RegistryGuard {
    path: PathBuf,
    worker: String,
    released: bool,
}

impl RegistryGuard {
    pub fn worker(&self) -> &str {
        &self.worker
    }

    /// Re-stamp the registry heartbeat (tmp + rename, like a claim
    /// refresh, so readers never see a torn entry).  Chaos point
    /// `registry.heartbeat` on both the stage and the commit.
    pub fn heartbeat(&self) -> Result<()> {
        let tmp = self.path.with_extension(format!("json.hb.{}", std::process::id()));
        retry::io_retry(&format!("registry.heartbeat:{}", self.worker), || {
            crate::chaos::fault("registry.heartbeat")?;
            std::fs::write(&tmp, claim::claim_body(&self.worker, claim::now_ms()))
        })
        .with_context(|| format!("writing registry heartbeat {tmp:?}"))?;
        retry::io_retry(&format!("registry.heartbeat.commit:{}", self.worker), || {
            crate::chaos::fault("registry.heartbeat")?;
            std::fs::rename(&tmp, &self.path)
        })
        .with_context(|| format!("committing registry heartbeat {:?}", self.path))?;
        Ok(())
    }

    /// Leave the fleet cleanly (remove the registry entry).
    pub fn deregister(mut self) {
        self.released = true;
        let _ = std::fs::remove_file(&self.path);
    }
}

impl Drop for RegistryGuard {
    fn drop(&mut self) {
        if !self.released {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// The sorted ids of every *live* registered worker (entries within
/// the TTL under the claim store's symmetric staleness rule).  Stale
/// entries are skipped, not removed — that is [`reclaim_stale`]'s job.
pub fn live_workers(dir: &Path, ttl_ms: u64) -> Vec<String> {
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir(workers_dir(dir)) else {
        return out;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let Some(worker) = name.strip_suffix(".json") else {
            continue; // heartbeat staging litter
        };
        if claim::age_ms(&path, ttl_ms).is_some_and(|age| age <= ttl_ms) {
            out.push(worker.to_string());
        }
    }
    out.sort();
    out
}

/// Sweep stale registry entries (workers that died without
/// deregistering), mirroring the claim store's stale reclaim.  Returns
/// how many entries were removed.  Best-effort: a concurrent
/// deregister or re-register loses nothing.
pub fn reclaim_stale(dir: &Path, ttl_ms: u64) -> usize {
    let mut removed = 0;
    let Ok(entries) = std::fs::read_dir(workers_dir(dir)) else {
        return 0;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if !name.ends_with(".json") {
            continue;
        }
        let stale = claim::age_ms(&path, ttl_ms).map_or(false, |age| age > ttl_ms);
        if stale && std::fs::remove_file(&path).is_ok() {
            removed += 1;
        }
    }
    removed
}

// ---------------------------------------------------------------------------
// Artifact cache
// ---------------------------------------------------------------------------

/// Format magic for cache blobs; bump on any layout change so old
/// entries read as absent instead of mis-decoding.
const CACHE_MAGIC: &[u8; 8] = b"rmmfle01";

/// A handle on the sweep's shared `cache/` directory.  All methods are
/// infallible-by-absence: a missing, torn, or mismatched entry loads
/// as `None` and a failed publish is reported, never fatal — the cache
/// only ever trades regeneration cost, not correctness.
#[derive(Debug, Clone)]
pub struct ArtifactCache {
    dir: PathBuf,
}

impl ArtifactCache {
    /// Open (creating if needed) the cache under a sweep directory.
    pub fn open(sweep_dir: &Path) -> Result<ArtifactCache> {
        let dir = cache_dir(sweep_dir);
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating artifact cache {dir:?}"))?;
        Ok(ArtifactCache { dir })
    }

    pub fn root(&self) -> &Path {
        &self.dir
    }

    /// Cache key for a variant's [`TrainerSetup`]: FNV of the manifest
    /// directory + variant name, so two sweeps over *different*
    /// artifact sets can never alias even when variant names collide.
    pub fn setup_key(manifest_dir: &Path, variant: &str) -> u64 {
        fnv::hash(
            format!("setup|{}|{variant}", manifest_dir.display())
                .bytes(),
        )
    }

    /// Cache key for a dev-batch set: FNV of the session `DevKey`
    /// (task, seq_len, vocab, batch_size, seed) — the tuple the batch
    /// sequence is a pure function of.
    pub fn dev_key(task: &str, seq_len: usize, vocab: usize, batch_size: usize, seed: u64) -> u64 {
        fnv::hash(format!("dev|{task}|{seq_len}|{vocab}|{batch_size}|{seed}").bytes())
    }

    fn blob_path(&self, kind: &str, key: u64) -> PathBuf {
        self.dir.join(format!("{kind}_{key:016x}.bin"))
    }

    /// Load a variant's spilled [`TrainerSetup`], if a valid blob for
    /// this key exists.
    pub fn load_setup(&self, key: u64) -> Option<TrainerSetup> {
        let payload = read_blob(&self.blob_path("setup", key), key)?;
        decode_setup(&payload)
    }

    /// Publish a variant's [`TrainerSetup`].  Returns `true` when this
    /// writer's bytes won the `hard_link` (first publisher), `false`
    /// when an identical blob was already there.
    pub fn store_setup(&self, key: u64, setup: &TrainerSetup) -> Result<bool> {
        self.publish("setup", key, &encode_setup(setup))
    }

    /// Load a spilled dev-batch set, if a valid blob for this key
    /// exists.
    pub fn load_dev(&self, key: u64) -> Option<Vec<Batch>> {
        let payload = read_blob(&self.blob_path("dev", key), key)?;
        decode_batches(&payload)
    }

    /// Publish a dev-batch set (see [`ArtifactCache::store_setup`] for
    /// the return contract).
    pub fn store_dev(&self, key: u64, batches: &[Batch]) -> Result<bool> {
        self.publish("dev", key, &encode_batches(batches))
    }

    /// Commit `payload` under `<kind>_<key>.bin` via writer-unique tmp
    /// + `hard_link`: rename would let a later (possibly torn) writer
    /// replace a good blob, while `hard_link` fails with
    /// `AlreadyExists` once *any* writer has published — and because
    /// every writer encodes the same pure-function-of-key bytes, the
    /// loser's blob is identical to the winner's.  Chaos point
    /// `cache.publish` on the link; transient IO retries like every
    /// other mount op.
    fn publish(&self, kind: &str, key: u64, payload: &[u8]) -> Result<bool> {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let path = self.blob_path(kind, key);
        if path.exists() {
            return Ok(false);
        }
        let mut blob = Vec::with_capacity(payload.len() + 32);
        blob.extend_from_slice(CACHE_MAGIC);
        put_u64(&mut blob, key);
        put_u64(&mut blob, payload.len() as u64);
        blob.extend_from_slice(payload);
        put_u64(&mut blob, fnv::hash(payload.iter().copied()));
        let tmp = self.dir.join(format!(
            "{kind}_{key:016x}.tmp.{}.{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        retry::io_retry(&format!("cache.stage:{kind}:{key:016x}"), || {
            std::fs::write(&tmp, &blob)
        })
        .with_context(|| format!("staging cache blob {tmp:?}"))?;
        let linked = retry::io_retry(&format!("cache.publish:{kind}:{key:016x}"), || {
            crate::chaos::fault("cache.publish")?;
            std::fs::hard_link(&tmp, &path)
        });
        let _ = std::fs::remove_file(&tmp);
        match linked {
            Ok(()) => Ok(true),
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => Ok(false),
            Err(e) => Err(e).with_context(|| format!("publishing cache blob {path:?}")),
        }
    }
}

/// Read + verify a cache blob, returning its payload.  Every mismatch
/// — short file, wrong magic, key echo, length, or digest — reads as
/// absent.
fn read_blob(path: &Path, key: u64) -> Option<Vec<u8>> {
    let bytes = std::fs::read(path).ok()?;
    let mut rd = Rd { b: &bytes, at: 0 };
    if rd.take(8)? != CACHE_MAGIC.as_slice() || rd.u64()? != key {
        return None;
    }
    let len = rd.u64()? as usize;
    let payload = rd.take(len)?.to_vec();
    let digest = rd.u64()?;
    if rd.at != bytes.len() || digest != fnv::hash(payload.iter().copied()) {
        return None;
    }
    Some(payload)
}

// -- deterministic little-endian encoding -----------------------------------

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u64(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    put_u64(out, v.len() as u64);
    for x in v {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

fn put_i32s(out: &mut Vec<u8>, v: &[i32]) {
    put_u64(out, v.len() as u64);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bounds-checked little-endian reader over a blob payload.
struct Rd<'a> {
    b: &'a [u8],
    at: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.at.checked_add(n)?;
        if end > self.b.len() {
            return None;
        }
        let s = &self.b[self.at..end];
        self.at = end;
        Some(s)
    }

    fn u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }

    /// A length prefix that must be satisfiable by the remaining bytes
    /// (`elem` bytes per element) — rejects hostile/torn lengths before
    /// any allocation.
    fn len(&mut self, elem: usize) -> Option<usize> {
        let n = self.u64()? as usize;
        if n.checked_mul(elem)? > self.b.len() - self.at {
            return None;
        }
        Some(n)
    }

    fn str(&mut self) -> Option<String> {
        let n = self.len(1)?;
        String::from_utf8(self.take(n)?.to_vec()).ok()
    }

    fn f32s(&mut self) -> Option<Vec<f32>> {
        let n = self.len(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(f32::from_bits(u32::from_le_bytes(self.take(4)?.try_into().ok()?)));
        }
        Some(v)
    }

    fn i32s(&mut self) -> Option<Vec<i32>> {
        let n = self.len(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(i32::from_le_bytes(self.take(4)?.try_into().ok()?));
        }
        Some(v)
    }
}

fn encode_setup(s: &TrainerSetup) -> Vec<u8> {
    let mut out = Vec::new();
    put_str(&mut out, &s.variant_name);
    put_u64(&mut out, s.init_params.len() as u64);
    for p in &s.init_params {
        put_f32s(&mut out, p);
    }
    put_u64(&mut out, s.param_names.len() as u64);
    for n in &s.param_names {
        put_str(&mut out, n);
    }
    put_u64(&mut out, s.param_sizes.len() as u64);
    for z in &s.param_sizes {
        put_u64(&mut out, *z as u64);
    }
    out
}

fn decode_setup(b: &[u8]) -> Option<TrainerSetup> {
    let mut rd = Rd { b, at: 0 };
    let variant_name = rd.str()?;
    let n = rd.len(8)?;
    let mut init_params = Vec::with_capacity(n);
    for _ in 0..n {
        init_params.push(rd.f32s()?);
    }
    let n = rd.len(8)?;
    let mut param_names = Vec::with_capacity(n);
    for _ in 0..n {
        param_names.push(rd.str()?);
    }
    let n = rd.len(8)?;
    let mut param_sizes = Vec::with_capacity(n);
    for _ in 0..n {
        param_sizes.push(rd.u64()? as usize);
    }
    if rd.at != b.len() {
        return None;
    }
    Some(TrainerSetup { variant_name, init_params, param_names, param_sizes })
}

fn encode_batches(batches: &[Batch]) -> Vec<u8> {
    let mut out = Vec::new();
    put_u64(&mut out, batches.len() as u64);
    for b in batches {
        put_u64(&mut out, b.batch_size as u64);
        put_u64(&mut out, b.seq_len as u64);
        put_u64(&mut out, b.valid as u64);
        put_i32s(&mut out, &b.tokens);
        put_f32s(&mut out, &b.mask);
        put_i32s(&mut out, &b.labels_i);
        put_f32s(&mut out, &b.labels_f);
    }
    out
}

fn decode_batches(b: &[u8]) -> Option<Vec<Batch>> {
    let mut rd = Rd { b, at: 0 };
    let n = rd.len(8)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let batch_size = rd.u64()? as usize;
        let seq_len = rd.u64()? as usize;
        let valid = rd.u64()? as usize;
        out.push(Batch {
            tokens: rd.i32s()?,
            mask: rd.f32s()?,
            labels_i: rd.i32s()?,
            labels_f: rd.f32s()?,
            batch_size,
            seq_len,
            valid,
        });
    }
    if rd.at != b.len() {
        return None;
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("rmm_fleet_test_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn register_heartbeat_deregister_roundtrip() {
        let d = tmp("roundtrip");
        let g = register(&d, "fleet-w0", 60_000).unwrap();
        assert!(registry_path(&d, "fleet-w0").exists());
        assert_eq!(live_workers(&d, 60_000), vec!["fleet-w0".to_string()]);
        // second registration under the same live id is a caller bug
        assert!(register(&d, "fleet-w0", 60_000).is_err());
        g.heartbeat().unwrap();
        g.deregister();
        assert!(!registry_path(&d, "fleet-w0").exists());
        assert!(live_workers(&d, 60_000).is_empty());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn drop_removes_the_registry_entry() {
        let d = tmp("drop");
        {
            let _g = register(&d, "fleet-w1", 60_000).unwrap();
            assert!(registry_path(&d, "fleet-w1").exists());
        }
        assert!(!registry_path(&d, "fleet-w1").exists());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn stale_entries_are_invisible_and_reclaimable_fresh_are_not() {
        let d = tmp("stale");
        std::fs::create_dir_all(workers_dir(&d)).unwrap();
        // a dead worker: ancient heartbeat AND stale mtime
        std::fs::write(registry_path(&d, "dead"), claim::claim_body("dead", 1)).unwrap();
        let live = register(&d, "alive", 60_000).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(40));
        live.heartbeat().unwrap(); // re-stamps mtime + heartbeat
        assert_eq!(live_workers(&d, 25), vec!["alive".to_string()]);
        assert_eq!(reclaim_stale(&d, 25), 1);
        assert!(!registry_path(&d, "dead").exists());
        assert!(registry_path(&d, "alive").exists());
        // a same-id re-register over a stale leftover succeeds
        std::fs::write(registry_path(&d, "reborn"), claim::claim_body("reborn", 1)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(40));
        let g = register(&d, "reborn", 25).unwrap();
        g.deregister();
        live.deregister();
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn past_skewed_registry_heartbeat_with_fresh_mtime_stays_live() {
        let d = tmp("skew");
        std::fs::create_dir_all(workers_dir(&d)).unwrap();
        // The claim store's symmetric skew rule applies to the registry
        // too: a slow writer clock stamps "old" heartbeats, but its
        // refreshes keep the mtime fresh — the worker must read live.
        std::fs::write(
            registry_path(&d, "slow"),
            claim::claim_body("slow", claim::now_ms().saturating_sub(5_000)),
        )
        .unwrap();
        assert_eq!(live_workers(&d, 1_000), vec!["slow".to_string()]);
        assert_eq!(reclaim_stale(&d, 1_000), 0);
        // a *future*-skewed heartbeat is discounted and judged by mtime
        std::fs::write(
            registry_path(&d, "fast"),
            claim::claim_body("fast", claim::now_ms() + 3_600_000),
        )
        .unwrap();
        std::thread::sleep(std::time::Duration::from_millis(40));
        let live = live_workers(&d, 25);
        assert!(!live.contains(&"fast".to_string()), "{live:?}");
        std::fs::remove_dir_all(&d).unwrap();
    }

    fn setup_fixture() -> TrainerSetup {
        TrainerSetup {
            variant_name: "v0".into(),
            init_params: vec![vec![1.5, -2.25, 0.0], vec![f32::MIN_POSITIVE]],
            param_names: vec!["w".into(), "b".into()],
            param_sizes: vec![3, 1],
        }
    }

    fn batch_fixture(seed: i32) -> Batch {
        Batch {
            tokens: vec![seed, seed + 1, seed + 2, seed + 3],
            mask: vec![1.0, 1.0, 0.5, 0.0],
            labels_i: vec![0, 1],
            labels_f: vec![0.25, -1.75],
            batch_size: 2,
            seq_len: 2,
            valid: 2,
        }
    }

    #[test]
    fn setup_blob_roundtrips_byte_exactly() {
        let d = tmp("setup_blob");
        let cache = ArtifactCache::open(&d).unwrap();
        let setup = setup_fixture();
        let key = ArtifactCache::setup_key(Path::new("/art"), "v0");
        assert!(cache.load_setup(key).is_none());
        assert!(cache.store_setup(key, &setup).unwrap(), "first publish wins");
        assert!(!cache.store_setup(key, &setup).unwrap(), "second publish is a no-op");
        assert_eq!(cache.load_setup(key).unwrap(), setup);
        // a different key never aliases
        let other = ArtifactCache::setup_key(Path::new("/art"), "v1");
        assert!(cache.load_setup(other).is_none());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn dev_blob_roundtrips_byte_exactly() {
        let d = tmp("dev_blob");
        let cache = ArtifactCache::open(&d).unwrap();
        let batches = vec![batch_fixture(10), batch_fixture(90)];
        let key = ArtifactCache::dev_key("wnli", 16, 64, 8, 3);
        assert!(cache.load_dev(key).is_none());
        assert!(cache.store_dev(key, &batches).unwrap());
        let back = cache.load_dev(key).unwrap();
        assert_eq!(back.len(), 2);
        for (a, b) in back.iter().zip(&batches) {
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(
                a.mask.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.mask.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(a.labels_i, b.labels_i);
            assert_eq!(
                a.labels_f.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.labels_f.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!((a.batch_size, a.seq_len, a.valid), (b.batch_size, b.seq_len, b.valid));
        }
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn corrupt_or_mismatched_blobs_read_as_absent() {
        let d = tmp("corrupt");
        let cache = ArtifactCache::open(&d).unwrap();
        let key = ArtifactCache::dev_key("rte", 16, 64, 8, 0);
        cache.store_dev(key, &[batch_fixture(1)]).unwrap();
        let path = cache.root().join(format!("dev_{key:016x}.bin"));
        let good = std::fs::read(&path).unwrap();
        // truncation
        std::fs::remove_file(&path).unwrap();
        std::fs::write(&path, &good[..good.len() / 2]).unwrap();
        assert!(cache.load_dev(key).is_none());
        // single-bit payload corruption trips the digest
        let mut flipped = good.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 1;
        std::fs::remove_file(&path).unwrap();
        std::fs::write(&path, &flipped).unwrap();
        assert!(cache.load_dev(key).is_none());
        // a blob stored under a different key never loads for this one
        std::fs::remove_file(&path).unwrap();
        std::fs::write(&path, &good).unwrap();
        let wrong = ArtifactCache::dev_key("rte", 16, 64, 8, 1);
        std::fs::write(cache.root().join(format!("dev_{wrong:016x}.bin")), &good).unwrap();
        assert!(cache.load_dev(wrong).is_none());
        // garbage bytes are absent, not an error
        std::fs::write(&path, b"not a cache blob").unwrap();
        assert!(cache.load_dev(key).is_none());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn concurrent_publishers_commit_exactly_one_identical_blob() {
        let d = tmp("race");
        let cache = ArtifactCache::open(&d).unwrap();
        let key = ArtifactCache::dev_key("mrpc", 16, 64, 8, 7);
        let barrier = std::sync::Arc::new(std::sync::Barrier::new(4));
        let wins: Vec<bool> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let cache = cache.clone();
                    let barrier = barrier.clone();
                    s.spawn(move || {
                        barrier.wait();
                        cache.store_dev(key, &[batch_fixture(5)]).unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(wins.iter().filter(|w| **w).count() <= 1, "{wins:?}");
        assert_eq!(cache.load_dev(key).unwrap().len(), 1);
        // no tmp litter survives the race
        let litter: Vec<_> = std::fs::read_dir(cache.root())
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(litter.is_empty(), "{litter:?}");
        std::fs::remove_dir_all(&d).unwrap();
    }
}
