//! Dynamic work-stealing cell scheduler: workers pull the next
//! unclaimed, un-completed cell through the claim/lease protocol
//! (`sweep::claim`) instead of filtering the grid by `index % N`.
//!
//! # Why dynamic
//!
//! The static `--shard i/N` assignment is a pure function of the grid —
//! zero coordination, but it strands stragglers when cell costs are
//! skewed: an MNLI cell costs orders of magnitude more than a WNLI cell,
//! so one shard can still be grinding while the others sit idle.  Under
//! the dynamic schedule, every worker scans the grid in canonical order
//! and claims the first incomplete, unclaimed cell; fast workers simply
//! claim more cells, so no worker idles while unclaimed cells remain.
//!
//! # The contract (see `sweep/mod.rs` for the full claim/lease prose)
//!
//! * Work distribution is **only** about which worker runs a cell —
//!   never about what the cell computes or where its fragment lands.
//!   The merged report stays a pure function of the fragment set, so a
//!   dynamic sweep is byte-identical to the serial run for any worker
//!   count, claim interleaving, or crash/reclaim history
//!   (`tests/prop_sched.rs` pins worker counts {1, 2, 3, 7}).
//! * A valid fragment supersedes any claim: workers check the fragment
//!   before claiming and delete leftover claim files they find on
//!   completed cells.
//! * Workers run until **every** cell has a valid fragment, polling
//!   while other workers hold live leases.  A worker that dies
//!   mid-lease leaves a claim that goes stale after `lease_ttl_ms`;
//!   a surviving worker reclaims and finishes the cell.  The TTL must
//!   exceed the worst-case cell wall time (default 10 minutes) — a
//!   too-short TTL only costs duplicated work, never a wrong report,
//!   because duplicated deterministic cells commit identical fragments.
//! * A cell runner error aborts *this* worker (releasing its claim via
//!   the guard so others can retry immediately); a deterministic
//!   failure therefore fails every worker rather than hanging the
//!   sweep.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::claim::{self, ClaimAttempt};
use super::grid::{Cell, SweepSpec};
use super::{merge, resume};

/// Default lease TTL: long enough that no real fine-tuning cell outlives
/// its lease (claims are only reclaimed from *dead* workers), short
/// enough that a crashed sweep heals in minutes.
pub const DEFAULT_LEASE_TTL_MS: u64 = 600_000;

/// Idle back-off between grid passes when every incomplete cell is
/// leased to someone else: ttl/4 (a stale lease is noticed within ~25%
/// of its TTL), clamped so short test TTLs stay responsive and long
/// production TTLs don't hammer the claim store — each idle pass costs
/// one claim read per incomplete cell, which on the shared network
/// fragment store of a cross-machine sweep is traffic worth bounding.
fn poll_ms(lease_ttl_ms: u64) -> u64 {
    (lease_ttl_ms / 4).clamp(10, 500)
}

/// Which cell scheduler a sweep uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Round-robin ownership (`--shard i/N`, `sweep::shard`): zero
    /// coordination, the fallback when no shared claim store is wanted.
    Static,
    /// Claim/lease work stealing over the fragment directory.
    Dynamic,
}

impl Schedule {
    pub fn parse(s: &str) -> Option<Schedule> {
        match s {
            "static" => Some(Schedule::Static),
            "dynamic" => Some(Schedule::Dynamic),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Schedule::Static => "static",
            Schedule::Dynamic => "dynamic",
        }
    }
}

/// Per-worker settings for a dynamic run.
#[derive(Debug, Clone)]
pub struct DynamicConfig {
    /// Unique worker id embedded in claim files (diagnostics + steal
    /// attribution).
    pub worker: String,
    /// Lease age beyond which another worker may reclaim a cell.
    pub lease_ttl_ms: u64,
}

impl DynamicConfig {
    pub fn new(label: &str, lease_ttl_ms: u64) -> DynamicConfig {
        DynamicConfig { worker: claim::worker_id(label), lease_ttl_ms: lease_ttl_ms.max(1) }
    }
}

/// Run cells under the dynamic schedule until the whole grid is
/// complete, committing one fragment per cell won.  Returns the indices
/// of the cells *this* worker ran (in completion order) — the sum over
/// all workers covers the grid exactly once unless a lease was
/// reclaimed from a live worker (see module doc).
pub fn run_dynamic(
    dir: &Path,
    spec: &SweepSpec,
    cfg: &DynamicConfig,
    runner: &mut dyn FnMut(&Cell) -> Result<Json>,
) -> Result<Vec<usize>> {
    let cdir = resume::cells_dir(dir);
    std::fs::create_dir_all(&cdir).with_context(|| format!("creating {cdir:?}"))?;
    // A cell observed complete stays complete for the rest of this run
    // (the spec is fixed and fragments are only ever replaced by
    // identical re-commits), so memoize completions instead of re-reading
    // and re-validating every fragment on every poll pass — without this
    // a worker waiting on one straggler would re-parse the whole
    // completed grid every POLL_MS.  Cell index == grid position by the
    // spec contract (`grid::SweepSpec::from_json` enforces it).
    let mut done = vec![false; spec.cells.len()];
    let mut ran = Vec::new();
    loop {
        let mut all_done = true;
        let mut claimed_any = false;
        for (i, cell) in spec.cells.iter().enumerate() {
            if done[i] {
                continue;
            }
            if merge::read_fragment(&cdir, spec, cell).is_some() {
                // First observation of this cell's completion: a valid
                // fragment supersedes any claim — clean up leftovers
                // from killed workers so the directory converges to
                // fragments only.
                claim::remove_claim(&cdir, cell.index);
                done[i] = true;
                continue;
            }
            all_done = false;
            match claim::try_claim(&cdir, cell.index, &cfg.worker, cfg.lease_ttl_ms)? {
                ClaimAttempt::Held => {}
                ClaimAttempt::Won(guard) => {
                    // Re-check under the claim: a reclaimed worker may
                    // have committed between our fragment check and the
                    // claim win.
                    if merge::read_fragment(&cdir, spec, cell).is_some() {
                        guard.release();
                        done[i] = true;
                        continue;
                    }
                    // On error the guard drops here, releasing the
                    // claim so other workers can retry immediately.
                    let result = runner(cell).with_context(|| {
                        format!(
                            "sweep cell {} ({} on {}, rho={})",
                            cell.index, cell.variant, cell.task, cell.rho
                        )
                    })?;
                    merge::write_fragment(&cdir, spec, cell, &result)?;
                    guard.release();
                    done[i] = true;
                    ran.push(cell.index);
                    claimed_any = true;
                }
            }
        }
        if all_done {
            return Ok(ran);
        }
        if !claimed_any {
            // every incomplete cell is leased elsewhere: wait for either
            // a fragment to land or a lease to go stale
            std::thread::sleep(std::time::Duration::from_millis(poll_ms(
                cfg.lease_ttl_ms,
            )));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::{self, Shard};
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("rmm_scheduler_test_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn report(dir: &Path, spec: &SweepSpec) -> String {
        Json::Arr(merge::merge(dir, spec).unwrap()).to_string_pretty()
    }

    #[test]
    fn schedule_parses() {
        assert_eq!(Schedule::parse("static"), Some(Schedule::Static));
        assert_eq!(Schedule::parse("dynamic"), Some(Schedule::Dynamic));
        assert_eq!(Schedule::parse("linear"), None);
        assert_eq!(Schedule::Dynamic.name(), "dynamic");
    }

    #[test]
    fn single_dynamic_worker_matches_static_serial() {
        let spec = sweep::selftest_spec();
        let sdir = tmp("serial");
        resume::prepare(&sdir, &spec, false).unwrap();
        sweep::run_shard(&sdir, &spec, Shard::SERIAL, &mut |c| Ok(sweep::mock_cell(c)))
            .unwrap();
        let serial = report(&sdir, &spec);

        let ddir = tmp("dynamic");
        resume::prepare(&ddir, &spec, false).unwrap();
        let cfg = DynamicConfig::new("t", 60_000);
        let ran = run_dynamic(&ddir, &spec, &cfg, &mut |c| Ok(sweep::mock_cell(c)))
            .unwrap();
        assert_eq!(ran.len(), spec.cells.len());
        assert_eq!(report(&ddir, &spec), serial, "dynamic must merge like serial");

        // resume semantics: a second dynamic pass finds everything done
        let ran = run_dynamic(&ddir, &spec, &cfg, &mut |c| Ok(sweep::mock_cell(c)))
            .unwrap();
        assert!(ran.is_empty(), "completed cells must not rerun");

        std::fs::remove_dir_all(&sdir).unwrap();
        std::fs::remove_dir_all(&ddir).unwrap();
    }

    #[test]
    fn valid_fragment_supersedes_claim() {
        let spec = sweep::selftest_spec();
        let dir = tmp("supersede");
        resume::prepare(&dir, &spec, false).unwrap();
        let cdir = resume::cells_dir(&dir);
        // cell 0 already completed …
        merge::write_fragment(&cdir, &spec, &spec.cells[0], &sweep::mock_cell(&spec.cells[0]))
            .unwrap();
        // … but a killed worker left a *fresh-looking* claim on it
        match claim::try_claim(&cdir, 0, "dead-but-fresh", 60_000).unwrap() {
            ClaimAttempt::Won(g) => std::mem::forget(g), // leak: simulate a kill
            ClaimAttempt::Held => panic!("claim dir should start empty"),
        }
        let cfg = DynamicConfig::new("t", 60_000);
        let mut ran_cells = Vec::new();
        run_dynamic(&dir, &spec, &cfg, &mut |c| {
            ran_cells.push(c.index);
            Ok(sweep::mock_cell(c))
        })
        .unwrap();
        assert!(!ran_cells.contains(&0), "completed cell 0 must not rerun");
        assert_eq!(ran_cells.len(), spec.cells.len() - 1);
        assert!(
            !claim::claim_path(&cdir, 0).exists(),
            "leftover claim on a completed cell must be cleaned up"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
