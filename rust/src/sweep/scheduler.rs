//! Dynamic work-stealing cell scheduler: workers pull the next
//! unclaimed, un-completed cell through the claim/lease protocol
//! (`sweep::claim`) instead of filtering the grid by `index % N`.
//!
//! # Why dynamic
//!
//! The static `--shard i/N` assignment is a pure function of the grid —
//! zero coordination, but it strands stragglers when cell costs are
//! skewed: an MNLI cell costs orders of magnitude more than a WNLI cell,
//! so one shard can still be grinding while the others sit idle.  Under
//! the dynamic schedule, every worker scans the grid and claims the
//! first incomplete, unclaimed cell; fast workers simply claim more
//! cells, so no worker idles while unclaimed cells remain.
//!
//! # Affinity
//!
//! With a warm per-worker `Session` (`crate::session`), *which* cell a
//! worker claims next decides how much warm state it reuses: a
//! same-variant cell hits the engine's compiled executables and the
//! cached trainer setup; a same-(variant, task) cell additionally hits
//! the dataset caches.  When `DynamicConfig::affinity` is on (the
//! default), a worker therefore prefers unclaimed cells matching its
//! last-run cell's [`Cell::affinity_key`] — exact (variant, task) match
//! first, then same variant, then canonical order.  Affinity is a pure
//! claim-order preference: coverage, crash healing and the merged
//! report are exactly as without it (`tests/prop_session.rs` pins the
//! grouping and the skewed-grid single-cover property).
//!
//! # The contract (see `sweep/mod.rs` for the full claim/lease prose)
//!
//! * Work distribution is **only** about which worker runs a cell —
//!   never about what the cell computes or where its fragment lands.
//!   The merged report stays a pure function of the fragment set, so a
//!   dynamic sweep is byte-identical to the serial run for any worker
//!   count, claim interleaving, affinity preference, or crash/reclaim
//!   history (`tests/prop_sched.rs` pins worker counts {1, 2, 3, 7}).
//! * A valid fragment supersedes any claim: workers check the fragment
//!   before claiming and delete leftover claim files they find on
//!   completed cells.
//! * Workers run until **every** cell has a valid fragment, polling
//!   while other workers hold live leases.  A worker that dies
//!   mid-lease leaves a claim that goes stale after `lease_ttl_ms`;
//!   a surviving worker reclaims and finishes the cell.  The TTL must
//!   exceed the worst-case *stretch between heartbeats* (runners under
//!   a lease get a [`CellCtx`]; the trainer ticks it before step 0,
//!   every `log_every` steps, and per dev-eval batch, so the stretch is
//!   `log_every` steps or one compile-carrying step; a runner that
//!   never ticks needs the TTL above its wall
//!   time) — a too-short TTL only costs duplicated work, never a wrong
//!   report, because duplicated deterministic cells commit identical
//!   fragments.  Duplicates are counted ([`DynamicRun::duplicates`])
//!   and surface in the sweep summary instead of vanishing.
//! * A cell runner error aborts *this* worker (releasing its claim via
//!   the guard so others can retry immediately); a deterministic
//!   failure therefore fails every worker rather than hanging the
//!   sweep.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::claim::{self, ClaimAttempt};
use super::fleet;
use super::grid::{Cell, SweepSpec};
use super::{merge, resume, CellCtx};

/// Default lease TTL: long enough that no real fine-tuning cell outlives
/// its lease (claims are only reclaimed from *dead* workers), short
/// enough that a crashed sweep heals in minutes.
pub const DEFAULT_LEASE_TTL_MS: u64 = 600_000;

/// Idle back-off between grid passes when every incomplete cell is
/// leased to someone else: ttl/4 (a stale lease is noticed within ~25%
/// of its TTL), clamped so short test TTLs stay responsive and long
/// production TTLs don't hammer the claim store — each idle pass costs
/// one claim read per incomplete cell, which on the shared network
/// fragment store of a cross-machine sweep is traffic worth bounding.
fn poll_ms(lease_ttl_ms: u64) -> u64 {
    (lease_ttl_ms / 4).clamp(10, 500)
}

/// Which cell scheduler a sweep uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Round-robin ownership (`--shard i/N`, `sweep::shard`): zero
    /// coordination, the fallback when no shared claim store is wanted.
    Static,
    /// Claim/lease work stealing over the fragment directory.
    Dynamic,
}

impl Schedule {
    pub fn parse(s: &str) -> Option<Schedule> {
        match s {
            "static" => Some(Schedule::Static),
            "dynamic" => Some(Schedule::Dynamic),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Schedule::Static => "static",
            Schedule::Dynamic => "dynamic",
        }
    }
}

/// Per-worker settings for a dynamic run.
#[derive(Debug, Clone)]
pub struct DynamicConfig {
    /// Unique worker id embedded in claim files (diagnostics + steal
    /// attribution).
    pub worker: String,
    /// Lease age beyond which another worker may reclaim a cell.
    pub lease_ttl_ms: u64,
    /// Prefer unclaimed cells matching the worker's warm affinity key
    /// (variant, then task) before canonical order.  On by default; a
    /// pure claim-order preference, invisible in merged reports.
    pub affinity: bool,
}

impl DynamicConfig {
    pub fn new(label: &str, lease_ttl_ms: u64) -> DynamicConfig {
        DynamicConfig {
            worker: claim::worker_id(label),
            lease_ttl_ms: lease_ttl_ms.max(1),
            affinity: true,
        }
    }

    /// Builder-style override of the affinity preference.
    pub fn with_affinity(mut self, affinity: bool) -> DynamicConfig {
        self.affinity = affinity;
        self
    }
}

/// What one dynamic worker did over a [`run_dynamic`] call — returned
/// so orchestrators can surface the scheduling telemetry (the sweep
/// summary line) instead of losing it.
#[derive(Debug, Default, Clone)]
pub struct DynamicRun {
    /// Cell indices this worker ran, in completion order.  The union
    /// over all workers covers the grid exactly once unless a lease was
    /// reclaimed from a live worker (see module doc).
    pub ran: Vec<usize>,
    /// Benign duplicate executions detected: this worker finished a run
    /// only to find another worker's fragment already committed (a claim
    /// race or a reclaimed-but-alive holder).  Both fragments are
    /// byte-identical for deterministic cells, so duplicates waste work,
    /// never correctness.
    pub duplicates: u64,
    /// Cells won while the worker's warm affinity key matched the cell's
    /// variant — i.e. claims where warm state was actually reusable.
    pub affinity_claims: u64,
}

impl DynamicRun {
    /// One-line scheduling telemetry for worker/orchestrator summaries.
    pub fn summary(&self) -> String {
        format!(
            "{} cells ({} affinity-matched, {} duplicate runs)",
            self.ran.len(),
            self.affinity_claims,
            self.duplicates
        )
    }
}

/// Candidate claim order for one pass: exact (variant, task) matches of
/// the warm key first, then same-variant cells, then the rest — each
/// tier in canonical order, so with no warm key (or affinity off) the
/// order *is* canonical.
fn affinity_order(
    candidates: &[usize],
    spec: &SweepSpec,
    warm: Option<&(String, String)>,
) -> Vec<usize> {
    let Some((wv, wt)) = warm else {
        return candidates.to_vec();
    };
    let mut exact = Vec::new();
    let mut same_variant = Vec::new();
    let mut rest = Vec::new();
    for &i in candidates {
        let (v, t) = spec.cells[i].affinity_key();
        if v == wv && t == wt {
            exact.push(i);
        } else if v == wv {
            same_variant.push(i);
        } else {
            rest.push(i);
        }
    }
    exact.extend(same_variant);
    exact.extend(rest);
    exact
}

/// Run cells under the dynamic schedule until the whole grid is
/// complete, committing one fragment per cell won.  The runner receives
/// a [`CellCtx`] carrying the held lease, so long cells can tick their
/// heartbeat.  Returns this worker's [`DynamicRun`].
pub fn run_dynamic(
    dir: &Path,
    spec: &SweepSpec,
    cfg: &DynamicConfig,
    runner: &mut dyn FnMut(&Cell, &CellCtx<'_>) -> Result<Json>,
) -> Result<DynamicRun> {
    run_dynamic_registered(dir, spec, cfg, None, runner)
}

/// [`run_dynamic`] for a fleet-registered worker: the registry entry's
/// heartbeat is re-stamped on every grid pass and rides along on every
/// in-cell [`CellCtx::tick`], so registry liveness tracks lease
/// liveness exactly.  Registration is *not* an admission gate — a
/// worker registering after the sweep started (elastic join) simply
/// claims whatever cells remain, and `registry: None` degrades to the
/// plain dynamic run.
pub fn run_dynamic_registered(
    dir: &Path,
    spec: &SweepSpec,
    cfg: &DynamicConfig,
    registry: Option<&fleet::RegistryGuard>,
    runner: &mut dyn FnMut(&Cell, &CellCtx<'_>) -> Result<Json>,
) -> Result<DynamicRun> {
    let cdir = resume::cells_dir(dir);
    std::fs::create_dir_all(&cdir).with_context(|| format!("creating {cdir:?}"))?;
    // A cell observed complete stays complete for the rest of this run
    // (the spec is fixed and fragments are only ever replaced by
    // identical re-commits), so memoize completions instead of re-reading
    // and re-validating every fragment on every poll pass — without this
    // a worker waiting on one straggler would re-parse the whole
    // completed grid every POLL_MS.  Cell index == grid position by the
    // spec contract (`grid::SweepSpec::from_json` enforces it).
    let mut done = vec![false; spec.cells.len()];
    let mut run = DynamicRun::default();
    // The warm affinity key: the (variant, task) of the last cell this
    // worker ran, i.e. what its session currently has warm.
    let mut warm: Option<(String, String)> = None;
    loop {
        // A registered worker proves fleet liveness once per grid pass
        // (and per in-cell tick below).  Best-effort like every
        // heartbeat: a missed re-stamp costs observability, never a
        // result.  (A chaos *kill* scheduled on `registry.heartbeat`
        // still exits a worker process outright, mid-sweep, exactly
        // like a real death — only injected IO errors are swallowed.)
        if let Some(reg) = registry {
            let _ = reg.heartbeat();
        }
        // Pass 1: refresh completion knowledge over the incomplete set.
        let mut candidates = Vec::new();
        for (i, cell) in spec.cells.iter().enumerate() {
            if done[i] {
                continue;
            }
            if merge::read_fragment(&cdir, spec, cell).is_some() {
                // First observation of this cell's completion: a valid
                // fragment supersedes any claim — clean up leftovers
                // from killed workers so the directory converges to
                // fragments only.
                claim::remove_claim(&cdir, cell.index);
                done[i] = true;
                continue;
            }
            candidates.push(i);
        }
        if candidates.is_empty() {
            // Final pre-merge pass: the memo above trusts that a cell
            // observed complete *stays* complete, but a fragment can be
            // corrupted after it was seen valid (a lying mount, an
            // operator mangling `cells/`, a chaos `truncate` landing
            // post-commit).  Re-validate every memoized completion
            // before declaring the grid done; any regressed cell flips
            // back to incomplete and re-runs — deterministic cells
            // re-commit identical bytes, so healing is invisible in the
            // merged report.
            let mut regressed = false;
            for (i, cell) in spec.cells.iter().enumerate() {
                if merge::read_fragment(&cdir, spec, cell).is_none() {
                    done[i] = false;
                    regressed = true;
                }
            }
            if !regressed {
                return Ok(run);
            }
            continue;
        }
        // Pass 2: claim in affinity-preferred order; after each win the
        // warm key changes, so break back out to re-rank the remainder.
        let order = if cfg.affinity {
            affinity_order(&candidates, spec, warm.as_ref())
        } else {
            candidates
        };
        let mut claimed_any = false;
        for &i in &order {
            let cell = &spec.cells[i];
            match claim::try_claim(&cdir, cell.index, &cfg.worker, cfg.lease_ttl_ms)? {
                ClaimAttempt::Held => {}
                ClaimAttempt::Won(guard) => {
                    // Re-check under the claim: a reclaimed worker may
                    // have committed between our fragment check and the
                    // claim win.
                    if merge::read_fragment(&cdir, spec, cell).is_some() {
                        guard.release();
                        done[i] = true;
                        continue;
                    }
                    // Named chaos fault point "sched.cell", reached
                    // with the lease held — the canonical
                    // worker-dies-mid-lease injection.  In a worker
                    // process a scheduled kill is a process::exit (no
                    // Drop runs, the claim file stays behind exactly
                    // like SIGKILL); in-process it surfaces as an error
                    // after *leaking* the guard, so the lease is
                    // likewise left for the stale-reclaim machinery.
                    if let Err(e) = crate::chaos::fault("sched.cell") {
                        std::mem::forget(guard);
                        return Err(e).with_context(|| {
                            format!("chaos fault before sweep cell {}", cell.index)
                        });
                    }
                    // Daemon event hook, deliberately at the same seam
                    // as the chaos fault: a claim that would have died
                    // here never reports itself claimed.  No-op unless
                    // a daemon event sink is installed.
                    crate::daemon::events::cell_claimed(cell.index, &cfg.worker);
                    // On error the guard drops here, releasing the
                    // claim so other workers can retry immediately.
                    let ctx = CellCtx::under_lease_registered(&guard, registry);
                    let result = runner(cell, &ctx).with_context(|| {
                        format!(
                            "sweep cell {} ({} on {}, rho={})",
                            cell.index, cell.variant, cell.task, cell.rho
                        )
                    })?;
                    // A fragment that appeared while we ran means another
                    // worker duplicated this cell (claim race / live
                    // reclaim).  Count it; committing our identical bytes
                    // over it is harmless.
                    if merge::read_fragment(&cdir, spec, cell).is_some() {
                        run.duplicates += 1;
                    }
                    merge::commit_fragment(&cdir, spec, cell, &result)?;
                    guard.release();
                    done[i] = true;
                    run.ran.push(cell.index);
                    // Daemon event hook: the cell's fragment is durable
                    // and its lease released.
                    crate::daemon::events::cell_done(cell.index, &cfg.worker);
                    claimed_any = true;
                    let same_variant =
                        warm.as_ref().is_some_and(|(wv, _)| wv == &cell.variant);
                    let same_key = warm
                        .as_ref()
                        .is_some_and(|(wv, wt)| wv == &cell.variant && wt == &cell.task);
                    if cfg.affinity && same_variant {
                        run.affinity_claims += 1;
                    }
                    warm = Some((cell.variant.clone(), cell.task.clone()));
                    // The claim order only depends on the warm key, so
                    // keep draining this pass's ranking while the key is
                    // unchanged (and always under `affinity: false`,
                    // where ranking is canonical); re-rank only when the
                    // key moved — this keeps the original
                    // many-wins-per-pass behavior instead of an O(cells²)
                    // rescan per completed cell.
                    if cfg.affinity && !same_key {
                        break;
                    }
                }
            }
        }
        if !claimed_any {
            // every incomplete cell is leased elsewhere: wait for either
            // a fragment to land or a lease to go stale
            std::thread::sleep(std::time::Duration::from_millis(poll_ms(
                cfg.lease_ttl_ms,
            )));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::{self, Shard};
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("rmm_scheduler_test_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn report(dir: &Path, spec: &SweepSpec) -> String {
        Json::Arr(merge::merge(dir, spec).unwrap()).to_string_pretty()
    }

    #[test]
    fn schedule_parses() {
        assert_eq!(Schedule::parse("static"), Some(Schedule::Static));
        assert_eq!(Schedule::parse("dynamic"), Some(Schedule::Dynamic));
        assert_eq!(Schedule::parse("linear"), None);
        assert_eq!(Schedule::Dynamic.name(), "dynamic");
    }

    #[test]
    fn single_dynamic_worker_matches_static_serial() {
        let spec = sweep::selftest_spec();
        let sdir = tmp("serial");
        resume::prepare(&sdir, &spec, false).unwrap();
        sweep::run_shard(&sdir, &spec, Shard::SERIAL, &mut |c, _| {
            Ok(sweep::mock_cell(c))
        })
        .unwrap();
        let serial = report(&sdir, &spec);

        let ddir = tmp("dynamic");
        resume::prepare(&ddir, &spec, false).unwrap();
        let cfg = DynamicConfig::new("t", 60_000);
        let run = run_dynamic(&ddir, &spec, &cfg, &mut |c, _| Ok(sweep::mock_cell(c)))
            .unwrap();
        assert_eq!(run.ran.len(), spec.cells.len());
        assert_eq!(run.duplicates, 0, "a lone worker can never duplicate");
        assert_eq!(report(&ddir, &spec), serial, "dynamic must merge like serial");

        // resume semantics: a second dynamic pass finds everything done
        let run = run_dynamic(&ddir, &spec, &cfg, &mut |c, _| Ok(sweep::mock_cell(c)))
            .unwrap();
        assert!(run.ran.is_empty(), "completed cells must not rerun");

        std::fs::remove_dir_all(&sdir).unwrap();
        std::fs::remove_dir_all(&ddir).unwrap();
    }

    #[test]
    fn valid_fragment_supersedes_claim() {
        let spec = sweep::selftest_spec();
        let dir = tmp("supersede");
        resume::prepare(&dir, &spec, false).unwrap();
        let cdir = resume::cells_dir(&dir);
        // cell 0 already completed …
        merge::write_fragment(&cdir, &spec, &spec.cells[0], &sweep::mock_cell(&spec.cells[0]))
            .unwrap();
        // … but a killed worker left a *fresh-looking* claim on it
        match claim::try_claim(&cdir, 0, "dead-but-fresh", 60_000).unwrap() {
            ClaimAttempt::Won(g) => std::mem::forget(g), // leak: simulate a kill
            ClaimAttempt::Held => panic!("claim dir should start empty"),
        }
        let cfg = DynamicConfig::new("t", 60_000);
        let mut ran_cells = Vec::new();
        run_dynamic(&dir, &spec, &cfg, &mut |c, _| {
            ran_cells.push(c.index);
            Ok(sweep::mock_cell(c))
        })
        .unwrap();
        assert!(!ran_cells.contains(&0), "completed cell 0 must not rerun");
        assert_eq!(ran_cells.len(), spec.cells.len() - 1);
        assert!(
            !claim::claim_path(&cdir, 0).exists(),
            "leftover claim on a completed cell must be cleaned up"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn affinity_order_tiers_by_variant_then_task() {
        let mut spec = SweepSpec::new("mock", crate::config::TrainConfig::default());
        // interleaved variants and tasks
        spec.push("A", "t0", 1.0, "gauss", 0, 0); // 0
        spec.push("B", "t0", 1.0, "gauss", 0, 0); // 1
        spec.push("A", "t1", 1.0, "gauss", 0, 0); // 2
        spec.push("B", "t1", 1.0, "gauss", 0, 0); // 3
        spec.push("A", "t0", 1.0, "gauss", 1, 0); // 4
        let all: Vec<usize> = (0..spec.cells.len()).collect();
        // no warm key: canonical
        assert_eq!(affinity_order(&all, &spec, None), all);
        // warm (A, t0): exact matches 0,4 first, then A cells, then rest
        let warm = ("A".to_string(), "t0".to_string());
        assert_eq!(affinity_order(&all, &spec, Some(&warm)), vec![0, 4, 2, 1, 3]);
        // warm key absent from the candidates degrades to canonical
        let warm = ("Z".to_string(), "t9".to_string());
        assert_eq!(affinity_order(&all, &spec, Some(&warm)), all);
    }

    #[test]
    fn lone_affinity_worker_groups_same_variant_cells() {
        let mut spec = SweepSpec::new("mock", crate::config::TrainConfig::default());
        for seed in 0..3u64 {
            for v in ["A", "B"] {
                spec.push(v, "t", 1.0, "gauss", seed, 0); // A B A B A B
            }
        }
        let dir = tmp("affinity_group");
        resume::prepare(&dir, &spec, false).unwrap();
        let cfg = DynamicConfig::new("t", 60_000);
        let run = run_dynamic(&dir, &spec, &cfg, &mut |c, _| Ok(sweep::mock_cell(c)))
            .unwrap();
        // first claim is canonical (cell 0, variant A); affinity then
        // drains A (2, 4) before touching B (1, 3, 5)
        assert_eq!(run.ran, vec![0, 2, 4, 1, 3, 5]);
        assert_eq!(run.affinity_claims, 4, "2 extra A wins + 2 follow-on B wins");
        std::fs::remove_dir_all(&dir).unwrap();

        // with affinity off the same grid runs in canonical order
        let dir = tmp("affinity_off");
        resume::prepare(&dir, &spec, false).unwrap();
        let cfg = DynamicConfig::new("t", 60_000).with_affinity(false);
        let run = run_dynamic(&dir, &spec, &cfg, &mut |c, _| Ok(sweep::mock_cell(c)))
            .unwrap();
        assert_eq!(run.ran, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(
            run.affinity_claims, 0,
            "affinity-off runs must not report affinity telemetry"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn duplicate_commits_are_counted_not_lost() {
        let spec = sweep::selftest_spec();
        let dir = tmp("dup");
        resume::prepare(&dir, &spec, false).unwrap();
        let cdir = resume::cells_dir(&dir);
        let cfg = DynamicConfig::new("t", 60_000);
        // simulate a racing worker: mid-run, the first cell's fragment
        // lands under our claim (what a reclaimed-but-alive holder does)
        let mut first = true;
        let run = run_dynamic(&dir, &spec, &cfg, &mut |c, _| {
            if first {
                first = false;
                merge::write_fragment(&cdir, &spec, c, &sweep::mock_cell(c)).unwrap();
            }
            Ok(sweep::mock_cell(c))
        })
        .unwrap();
        assert_eq!(run.duplicates, 1, "the raced cell must be counted");
        assert_eq!(run.ran.len(), spec.cells.len());
        assert!(run.summary().contains("1 duplicate run"), "{}", run.summary());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fragment_corrupted_after_completion_is_revalidated_and_rerun() {
        let spec = sweep::selftest_spec();
        let dir = tmp("corrupt_after");
        resume::prepare(&dir, &spec, false).unwrap();
        let cdir = resume::cells_dir(&dir);
        let cfg = DynamicConfig::new("t", 60_000);
        // While running the grid's LAST cell, corrupt cell 0's already-
        // committed (and already-memoized) fragment: without the final
        // pre-merge re-validation the worker would return with a
        // corrupt fragment in place and the merge would fail.
        let last = spec.cells.len() - 1;
        let mut runs_of_zero = 0usize;
        let run = run_dynamic(&dir, &spec, &cfg, &mut |c, _| {
            if c.index == 0 {
                runs_of_zero += 1;
            }
            if c.index == last {
                std::fs::write(
                    merge::fragment_path(&cdir, &spec.cells[0]),
                    "{\"cell\": corrupted-after-complete",
                )
                .unwrap();
            }
            Ok(sweep::mock_cell(c))
        })
        .unwrap();
        assert_eq!(runs_of_zero, 2, "the regressed cell must re-run");
        assert_eq!(run.ran.len(), spec.cells.len() + 1);
        // the healed grid merges exactly like an untouched serial run
        let sdir = tmp("corrupt_after_serial");
        resume::prepare(&sdir, &spec, false).unwrap();
        sweep::run_shard(&sdir, &spec, Shard::SERIAL, &mut |c, _| {
            Ok(sweep::mock_cell(c))
        })
        .unwrap();
        assert_eq!(report(&dir, &spec), report(&sdir, &spec));
        std::fs::remove_dir_all(&dir).unwrap();
        std::fs::remove_dir_all(&sdir).unwrap();
    }

    #[test]
    fn registered_worker_rides_registry_heartbeat_on_cell_ticks() {
        let spec = sweep::selftest_spec();
        let dir = tmp("registered");
        resume::prepare(&dir, &spec, false).unwrap();
        let cfg = DynamicConfig::new("t", 60_000);
        let reg = fleet::register(&dir, &cfg.worker, 60_000).unwrap();
        let rpath = fleet::registry_path(&dir, &cfg.worker);
        let mut saw_live = false;
        let run = run_dynamic_registered(&dir, &spec, &cfg, Some(&reg), &mut |c, ctx| {
            std::thread::sleep(std::time::Duration::from_millis(2));
            ctx.tick(); // re-stamps lease AND registry entry
            saw_live = saw_live
                || fleet::live_workers(&dir, 60_000).contains(&cfg.worker);
            Ok(sweep::mock_cell(c))
        })
        .unwrap();
        assert_eq!(run.ran.len(), spec.cells.len());
        assert!(saw_live, "the running worker must be visible in the registry");
        assert!(rpath.exists());
        reg.deregister();
        assert!(!rpath.exists(), "clean exit must deregister");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn runner_ctx_carries_a_tickable_lease() {
        let spec = sweep::selftest_spec();
        let dir = tmp("ctx_tick");
        resume::prepare(&dir, &spec, false).unwrap();
        let cdir = resume::cells_dir(&dir);
        let cfg = DynamicConfig::new("t", 60_000);
        let mut ticked = 0usize;
        run_dynamic(&dir, &spec, &cfg, &mut |c, ctx| {
            assert!(ctx.has_heartbeat(), "dynamic cells must run under a lease");
            let before = claim::read_claim(&cdir, c.index).expect("claim present");
            std::thread::sleep(std::time::Duration::from_millis(15));
            ctx.tick();
            let after = claim::read_claim(&cdir, c.index).expect("claim survives tick");
            assert!(after.heartbeat_ms > before.heartbeat_ms, "tick must re-stamp");
            ticked += 1;
            Ok(sweep::mock_cell(c))
        })
        .unwrap();
        assert_eq!(ticked, spec.cells.len());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
