//! Per-cell manifest (fragment) IO and the cell-order-independent merge.
//!
//! Each completed cell is one JSON file `cells/cell_<index>.json` of the
//! form `{"cell": <cell>, "result": <result>}`.  Fragments are written
//! atomically (tmp + rename), so a killed worker can never leave a
//! half-written manifest that a later resume would trust.  Reading
//! validates the embedded cell against the current spec — a stale
//! fragment from a different grid is treated as absent, never merged.
//!
//! `merge` walks the spec's canonical cell order and looks fragments up
//! by index, so the merged result list — and any report assembled from
//! it — is a pure function of the fragment *set*, independent of which
//! worker produced a fragment, under which schedule (static shards or
//! dynamic claim/lease stealing), or in what order cells completed.
//! Lookups are by exact fragment path, so the `.claim` lease files and
//! `.json.tmp` staging files the dynamic scheduler and atomic commits
//! leave in `cells/` are invisible to the merge.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::grid::{Cell, SweepSpec};
use super::resume;

/// Fragment path for a cell inside the sweep's `cells/` directory.
pub fn fragment_path(cells_dir: &Path, cell: &Cell) -> PathBuf {
    cells_dir.join(format!("cell_{:05}.json", cell.index))
}

/// Atomically commit a completed cell's manifest.  The fragment embeds
/// both the cell it answers for *and* the spec's train config, so resume
/// validation covers the full grid contract.
///
/// The staging file name is writer-unique (pid + per-process sequence):
/// under the dynamic schedule a stale-but-alive worker and its reclaimer
/// can commit the same cell concurrently, and a shared tmp path would
/// let their writes interleave before the rename.  With unique staging,
/// each rename publishes one writer's complete bytes — last one wins,
/// which is harmless because deterministic cells commit identical
/// content.
pub fn write_fragment(
    cells_dir: &Path,
    spec: &SweepSpec,
    cell: &Cell,
    result: &Json,
) -> Result<()> {
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let body = Json::obj(vec![
        ("cell", cell.to_json()),
        ("train", spec.train.to_json()),
        ("result", result.clone()),
    ]);
    let path = fragment_path(cells_dir, cell);
    let tmp = path.with_extension(format!(
        "json.tmp.{}.{}",
        std::process::id(),
        SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    std::fs::write(&tmp, body.to_string_pretty())
        .with_context(|| format!("writing fragment {tmp:?}"))?;
    std::fs::rename(&tmp, &path).with_context(|| format!("committing {path:?}"))?;
    Ok(())
}

/// The cell's result, iff its fragment exists, parses, embeds exactly
/// this cell (same index, variant, task, ρ, sketch, seed, batch) *and*
/// was produced under this spec's train config.  Any mismatch —
/// truncated file, stale grid, different `--steps`/`--lr`, hand-edited
/// cell — reads as "not completed" so the cell reruns instead of
/// smuggling a stale row into the merge.
pub fn read_fragment(cells_dir: &Path, spec: &SweepSpec, cell: &Cell) -> Option<Json> {
    let text = std::fs::read_to_string(fragment_path(cells_dir, cell)).ok()?;
    let j = Json::parse(&text).ok()?;
    let embedded = Cell::from_json(j.get("cell")).ok()?;
    if &embedded != cell {
        return None;
    }
    // TrainConfig JSON round-trips byte-exactly (prop-pinned), so
    // structural equality here is the "same training settings" check.
    if j.get("train") != &spec.train.to_json() {
        return None;
    }
    let result = j.get("result");
    if result.is_null() {
        return None;
    }
    Some(result.clone())
}

/// Merge every cell's result in canonical grid order.  Fails listing the
/// missing/invalid cell indices if the sweep is incomplete.
pub fn merge(dir: &Path, spec: &SweepSpec) -> Result<Vec<Json>> {
    let cdir = resume::cells_dir(dir);
    let mut out = Vec::with_capacity(spec.cells.len());
    let mut missing = Vec::new();
    for cell in &spec.cells {
        match read_fragment(&cdir, spec, cell) {
            Some(r) => out.push(r),
            None => missing.push(cell.index),
        }
    }
    if !missing.is_empty() {
        let shown: Vec<String> =
            missing.iter().take(8).map(|i| i.to_string()).collect();
        bail!(
            "sweep merge: {}/{} cells missing or invalid (indices {}{})",
            missing.len(),
            spec.cells.len(),
            shown.join(","),
            if missing.len() > 8 { ",…" } else { "" }
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("rmm_merge_test_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn spec2() -> SweepSpec {
        let mut s = SweepSpec::new("mock", TrainConfig::default());
        s.push("v0", "cola", 1.0, "gauss", 1, 0);
        s.push("v1", "sst2", 0.5, "dft", 2, 0);
        s
    }

    #[test]
    fn fragment_roundtrip_and_validation() {
        let dir = tmp("roundtrip");
        let cdir = resume::cells_dir(&dir);
        std::fs::create_dir_all(&cdir).unwrap();
        let spec = spec2();
        let result = Json::obj(vec![("score", Json::num(12.5))]);
        write_fragment(&cdir, &spec, &spec.cells[0], &result).unwrap();
        assert_eq!(read_fragment(&cdir, &spec, &spec.cells[0]), Some(result));
        // a different cell must not read cell 0's fragment
        assert!(read_fragment(&cdir, &spec, &spec.cells[1]).is_none());
        // a stale fragment (same index, different grid) reads as absent
        let mut stale = spec.cells[0].clone();
        stale.variant = "other_variant".into();
        assert!(read_fragment(&cdir, &spec, &stale).is_none());
        // a fragment from different *training settings* reads as absent
        let mut retrained = spec.clone();
        retrained.train.steps += 1;
        assert!(read_fragment(&cdir, &retrained, &spec.cells[0]).is_none());
        // garbage on disk reads as absent, not as an error
        std::fs::write(fragment_path(&cdir, &spec.cells[0]), "{trunc").unwrap();
        assert!(read_fragment(&cdir, &spec, &spec.cells[0]).is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn merge_reports_missing_cells() {
        let dir = tmp("missing");
        let cdir = resume::cells_dir(&dir);
        std::fs::create_dir_all(&cdir).unwrap();
        let spec = spec2();
        write_fragment(&cdir, &spec, &spec.cells[1], &Json::num(1.0)).unwrap();
        let err = merge(&dir, &spec).unwrap_err();
        assert!(format!("{err}").contains("1/2 cells"), "{err}");
        write_fragment(&cdir, &spec, &spec.cells[0], &Json::num(0.0)).unwrap();
        let all = merge(&dir, &spec).unwrap();
        assert_eq!(all, vec![Json::num(0.0), Json::num(1.0)]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn merge_ignores_claim_and_tmp_files() {
        let dir = tmp("ignores_claims");
        let cdir = resume::cells_dir(&dir);
        std::fs::create_dir_all(&cdir).unwrap();
        let spec = spec2();
        for cell in &spec.cells {
            write_fragment(&cdir, &spec, cell, &Json::num(cell.index as f64)).unwrap();
        }
        let clean = merge(&dir, &spec).unwrap();
        // litter the directory with everything a dynamic sweep can leave
        // behind: live claims, stale graves, torn tmp commits
        std::fs::write(super::super::claim::claim_path(&cdir, 0), "{}").unwrap();
        std::fs::write(cdir.join("cell_00001.claim.stale.w-1-0.0"), "").unwrap();
        std::fs::write(cdir.join("cell_00001.json.tmp"), "{trunc").unwrap();
        assert_eq!(merge(&dir, &spec).unwrap(), clean, "stray files must not perturb merge");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
