//! Per-cell manifest (fragment) IO and the cell-order-independent merge.
//!
//! Each completed cell is one JSON file `cells/cell_<index>.json` of the
//! form `{"cell": <cell>, "result": <result>}`.  Fragments are written
//! atomically (tmp + rename), so a killed worker can never leave a
//! half-written manifest that a later resume would trust.  Reading
//! validates the embedded cell against the current spec — a stale
//! fragment from a different grid is treated as absent, never merged.
//!
//! `merge` walks the spec's canonical cell order and looks fragments up
//! by index, so the merged result list — and any report assembled from
//! it — is a pure function of the fragment *set*, independent of which
//! worker produced a fragment, under which schedule (static shards or
//! dynamic claim/lease stealing), or in what order cells completed.
//! Lookups are by exact fragment path, so the `.claim` lease files and
//! `.json.tmp` staging files the dynamic scheduler and atomic commits
//! leave in `cells/` are invisible to the merge.
//!
//! Fragment *validity* is a tolerant, diagnosable contract
//! ([`fragment_status`]): unknown top-level fields are ignored (forward
//! compatibility), and every way a fragment can be wrong — unreadable,
//! garbage bytes, stale grid, different train config — is reported with
//! its file path and reason, so a chaos-corrupted (or operator-mangled)
//! sweep is diagnosable from the merge error alone.  Schedulers keep
//! using the boolean view ([`read_fragment`]): any invalid fragment
//! simply reads as "not completed" and the cell reruns.
//!
//! Commits go through [`commit_fragment`], which verifies the published
//! bytes by re-reading them and re-stages on mismatch — the defense
//! against torn/corrupting writes, whether injected by the chaos
//! harness (`fragment.stage` / `fragment.commit` fault points) or
//! produced by a lying mount.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::grid::{Cell, SweepSpec};
use super::resume;
use super::retry;

/// Fragment path for a cell inside the sweep's `cells/` directory.
pub fn fragment_path(cells_dir: &Path, cell: &Cell) -> PathBuf {
    cells_dir.join(format!("cell_{:05}.json", cell.index))
}

/// Atomically commit a completed cell's manifest.  The fragment embeds
/// both the cell it answers for *and* the spec's train config, so resume
/// validation covers the full grid contract.
///
/// The staging file name is writer-unique (pid + per-process sequence):
/// under the dynamic schedule a stale-but-alive worker and its reclaimer
/// can commit the same cell concurrently, and a shared tmp path would
/// let their writes interleave before the rename.  With unique staging,
/// each rename publishes one writer's complete bytes — last one wins,
/// which is harmless because deterministic cells commit identical
/// content.
///
/// Both the staging write and the publishing rename retry transient IO
/// errors (`sweep::retry`) and carry chaos fault points; the staged
/// bytes are rebuilt per attempt so a retried attempt stages clean
/// bytes even if a chaos corruption mangled the previous one.
pub fn write_fragment(
    cells_dir: &Path,
    spec: &SweepSpec,
    cell: &Cell,
    result: &Json,
) -> Result<()> {
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let body = Json::obj(vec![
        ("cell", cell.to_json()),
        ("train", spec.train.to_json()),
        ("result", result.clone()),
    ]);
    let staged = body.to_string_pretty().into_bytes();
    let path = fragment_path(cells_dir, cell);
    let tmp = path.with_extension(format!(
        "json.tmp.{}.{}",
        std::process::id(),
        SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    retry::io_retry(&format!("fragment.stage:{}", cell.index), || {
        let mut bytes = staged.clone();
        crate::chaos::corrupt("fragment.stage", &mut bytes)?;
        std::fs::write(&tmp, &bytes)
    })
    .with_context(|| format!("writing fragment {tmp:?}"))?;
    retry::io_retry(&format!("fragment.commit:{}", cell.index), || {
        crate::chaos::fault("fragment.commit")?;
        std::fs::rename(&tmp, &path)
    })
    .with_context(|| format!("committing {path:?}"))?;
    Ok(())
}

/// How many times [`commit_fragment`] will (re)write before giving up.
const COMMIT_VERIFY_ATTEMPTS: usize = 3;

/// [`write_fragment`] + read-back verification: commit the fragment,
/// then confirm the published file actually validates for this cell,
/// re-staging up to [`COMMIT_VERIFY_ATTEMPTS`] times.  A corrupted
/// commit (torn write, chaos `truncate`/`garbage` injection) is thereby
/// healed in place instead of silently leaving an invalid fragment for
/// the merge to trip over.  Schedulers commit through this.
pub fn commit_fragment(
    cells_dir: &Path,
    spec: &SweepSpec,
    cell: &Cell,
    result: &Json,
) -> Result<()> {
    let mut last_reason = String::new();
    for _ in 0..COMMIT_VERIFY_ATTEMPTS {
        write_fragment(cells_dir, spec, cell, result)?;
        match fragment_status(cells_dir, spec, cell) {
            FragmentStatus::Valid(_) => {
                // Daemon event hook: the fragment is durable *and*
                // verified (no-op without an installed event sink).
                crate::daemon::events::fragment_committed(cell.index);
                return Ok(());
            }
            FragmentStatus::Missing => last_reason = "fragment missing after commit".to_string(),
            FragmentStatus::Invalid { reason, .. } => last_reason = reason,
        }
    }
    bail!(
        "committing fragment for cell {}: still invalid after {} attempts ({})",
        cell.index,
        COMMIT_VERIFY_ATTEMPTS,
        last_reason
    )
}

/// Verdict on a cell's on-disk fragment.
#[derive(Debug, Clone, PartialEq)]
pub enum FragmentStatus {
    /// Fragment exists and validates; carries the embedded result.
    Valid(Json),
    /// No fragment file (the cell simply has not completed).
    Missing,
    /// A file exists but cannot be trusted — with the path and a
    /// human-readable reason for the sweep summary.
    Invalid { path: PathBuf, reason: String },
}

/// Judge the cell's fragment.  Valid iff the file parses, embeds
/// exactly this cell (same index, variant, task, ρ, sketch, seed,
/// batch), was produced under this spec's train config, and carries a
/// non-null result.  Unknown top-level fields are tolerated — only the
/// contract keys are inspected — so newer writers can annotate
/// fragments without invalidating them for older readers.
pub fn fragment_status(cells_dir: &Path, spec: &SweepSpec, cell: &Cell) -> FragmentStatus {
    let path = fragment_path(cells_dir, cell);
    let invalid = |reason: String| FragmentStatus::Invalid {
        path: path.clone(),
        reason,
    };
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return FragmentStatus::Missing,
        Err(e) => return invalid(format!("unreadable: {e}")),
    };
    let j = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            return invalid(format!(
                "parse error at byte {}: {} (line: {})",
                e.offset,
                e.msg,
                offending_line_snippet(&text, e.offset)
            ))
        }
    };
    let embedded = match Cell::from_json(j.get("cell")) {
        Ok(c) => c,
        Err(e) => return invalid(format!("embedded cell unparseable: {e}")),
    };
    if &embedded != cell {
        return invalid(format!(
            "embedded cell mismatch (found index {} variant '{}' task '{}', \
             expected index {} variant '{}' task '{}')",
            embedded.index, embedded.variant, embedded.task, cell.index, cell.variant, cell.task
        ));
    }
    // TrainConfig JSON round-trips byte-exactly (prop-pinned), so
    // structural equality here is the "same training settings" check.
    if j.get("train") != &spec.train.to_json() {
        return invalid("train config mismatch (fragment from different settings)".to_string());
    }
    let result = j.get("result");
    if result.is_null() {
        return invalid("missing result".to_string());
    }
    FragmentStatus::Valid(result.clone())
}

/// The first 80 bytes (backed off to a char boundary, `…` when cut) of
/// the line containing byte `offset`, Debug-quoted so control bytes
/// stay printable.  Lets a daemon operator triage a corrupted fragment
/// from the merge diagnostic / event log alone, without shelling into
/// the fragment store.
fn offending_line_snippet(text: &str, offset: usize) -> String {
    let mut at = offset.min(text.len());
    while at > 0 && !text.is_char_boundary(at) {
        at -= 1;
    }
    let start = text[..at].rfind('\n').map(|i| i + 1).unwrap_or(0);
    let end = text[start..].find('\n').map(|i| start + i).unwrap_or(text.len());
    let line = &text[start..end];
    const MAX: usize = 80;
    if line.len() <= MAX {
        format!("{line:?}")
    } else {
        let mut cut = MAX;
        while !line.is_char_boundary(cut) {
            cut -= 1;
        }
        format!("{:?}…", &line[..cut])
    }
}

/// The cell's result, iff its fragment validates — the boolean view of
/// [`fragment_status`] the schedulers poll with.  Any mismatch —
/// truncated file, stale grid, different `--steps`/`--lr`, hand-edited
/// cell — reads as "not completed" so the cell reruns instead of
/// smuggling a stale row into the merge.
pub fn read_fragment(cells_dir: &Path, spec: &SweepSpec, cell: &Cell) -> Option<Json> {
    // Chaos read fault: a transient read error makes the fragment look
    // absent, which is always safe — the cell just reruns and commits
    // identical bytes.
    if crate::chaos::fault("fragment.read").is_err() {
        return None;
    }
    match fragment_status(cells_dir, spec, cell) {
        FragmentStatus::Valid(result) => Some(result),
        _ => None,
    }
}

/// Merge every cell's result in canonical grid order.  Fails listing
/// the missing/invalid cell indices if the sweep is incomplete, with a
/// per-fragment diagnosis (file path + reason) for every *invalid*
/// fragment so corrupted runs are debuggable from the summary alone.
pub fn merge(dir: &Path, spec: &SweepSpec) -> Result<Vec<Json>> {
    let cdir = resume::cells_dir(dir);
    let mut out = Vec::with_capacity(spec.cells.len());
    let mut missing = Vec::new();
    let mut invalid = Vec::new();
    for cell in &spec.cells {
        match fragment_status(&cdir, spec, cell) {
            FragmentStatus::Valid(r) => out.push(r),
            FragmentStatus::Missing => missing.push(cell.index),
            FragmentStatus::Invalid { path, reason } => {
                missing.push(cell.index);
                invalid.push(format!("  cell {} ({}): {}", cell.index, path.display(), reason));
            }
        }
    }
    if !missing.is_empty() {
        let shown: Vec<String> =
            missing.iter().take(8).map(|i| i.to_string()).collect();
        bail!(
            "sweep merge: {}/{} cells missing or invalid (indices {}{}){}{}",
            missing.len(),
            spec.cells.len(),
            shown.join(","),
            if missing.len() > 8 { ",…" } else { "" },
            if invalid.is_empty() { "" } else { "\ninvalid fragments:\n" },
            invalid.join("\n")
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("rmm_merge_test_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn spec2() -> SweepSpec {
        let mut s = SweepSpec::new("mock", TrainConfig::default());
        s.push("v0", "cola", 1.0, "gauss", 1, 0);
        s.push("v1", "sst2", 0.5, "dft", 2, 0);
        s
    }

    #[test]
    fn fragment_roundtrip_and_validation() {
        let dir = tmp("roundtrip");
        let cdir = resume::cells_dir(&dir);
        std::fs::create_dir_all(&cdir).unwrap();
        let spec = spec2();
        let result = Json::obj(vec![("score", Json::num(12.5))]);
        write_fragment(&cdir, &spec, &spec.cells[0], &result).unwrap();
        assert_eq!(read_fragment(&cdir, &spec, &spec.cells[0]), Some(result));
        // a different cell must not read cell 0's fragment
        assert!(read_fragment(&cdir, &spec, &spec.cells[1]).is_none());
        // a stale fragment (same index, different grid) reads as absent
        let mut stale = spec.cells[0].clone();
        stale.variant = "other_variant".into();
        assert!(read_fragment(&cdir, &spec, &stale).is_none());
        // a fragment from different *training settings* reads as absent
        let mut retrained = spec.clone();
        retrained.train.steps += 1;
        assert!(read_fragment(&cdir, &retrained, &spec.cells[0]).is_none());
        // garbage on disk reads as absent, not as an error
        std::fs::write(fragment_path(&cdir, &spec.cells[0]), "{trunc").unwrap();
        assert!(read_fragment(&cdir, &spec, &spec.cells[0]).is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn merge_reports_missing_cells() {
        let dir = tmp("missing");
        let cdir = resume::cells_dir(&dir);
        std::fs::create_dir_all(&cdir).unwrap();
        let spec = spec2();
        write_fragment(&cdir, &spec, &spec.cells[1], &Json::num(1.0)).unwrap();
        let err = merge(&dir, &spec).unwrap_err();
        assert!(format!("{err}").contains("1/2 cells"), "{err}");
        write_fragment(&cdir, &spec, &spec.cells[0], &Json::num(0.0)).unwrap();
        let all = merge(&dir, &spec).unwrap();
        assert_eq!(all, vec![Json::num(0.0), Json::num(1.0)]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn merge_ignores_claim_and_tmp_files() {
        let dir = tmp("ignores_claims");
        let cdir = resume::cells_dir(&dir);
        std::fs::create_dir_all(&cdir).unwrap();
        let spec = spec2();
        for cell in &spec.cells {
            write_fragment(&cdir, &spec, cell, &Json::num(cell.index as f64)).unwrap();
        }
        let clean = merge(&dir, &spec).unwrap();
        // litter the directory with everything a dynamic sweep can leave
        // behind: live claims, stale graves, torn tmp commits
        std::fs::write(super::super::claim::claim_path(&cdir, 0), "{}").unwrap();
        std::fs::write(cdir.join("cell_00001.claim.stale.w-1-0.0"), "").unwrap();
        std::fs::write(cdir.join("cell_00001.json.tmp"), "{trunc").unwrap();
        assert_eq!(merge(&dir, &spec).unwrap(), clean, "stray files must not perturb merge");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn merge_diagnoses_invalid_fragments_with_path_and_reason() {
        let dir = tmp("diagnose");
        let cdir = resume::cells_dir(&dir);
        std::fs::create_dir_all(&cdir).unwrap();
        let spec = spec2();
        write_fragment(&cdir, &spec, &spec.cells[1], &Json::num(1.0)).unwrap();
        // garbage bytes where cell 0's fragment should be
        std::fs::write(fragment_path(&cdir, &spec.cells[0]), "{\"cell\": garbage").unwrap();
        let err = format!("{}", merge(&dir, &spec).unwrap_err());
        assert!(err.contains("1/2 cells"), "{err}");
        assert!(err.contains("cell 0"), "{err}");
        assert!(err.contains("cell_00000.json"), "{err}");
        assert!(err.contains("parse error at byte"), "{err}");
        // The diagnostic embeds the offending line itself, quoted.
        assert!(err.contains("(line: \"{"), "{err}");
        assert!(err.contains("garbage"), "{err}");
        // A long garbage line is truncated to its first 80 bytes.
        let long = format!("{{\"cell\": {}", "z".repeat(300));
        std::fs::write(fragment_path(&cdir, &spec.cells[0]), &long).unwrap();
        let err = format!("{}", merge(&dir, &spec).unwrap_err());
        assert!(err.contains('…'), "snippet must mark truncation: {err}");
        assert!(!err.contains(&"z".repeat(100)), "snippet must stay bounded: {err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn offending_line_snippet_targets_the_line_and_bounds_its_length() {
        let text = "ok line\nbad line here\nrest";
        let off = text.find("bad").unwrap() + 4;
        assert_eq!(offending_line_snippet(text, off), "\"bad line here\"");
        // Offsets past the end clamp to the final line.
        assert_eq!(offending_line_snippet("tail", 999), "\"tail\"");
        // >80-byte lines truncate at a char boundary with an ellipsis.
        let long = "x".repeat(200);
        let snip = offending_line_snippet(&long, 150);
        assert!(snip.ends_with('…'), "{snip}");
        assert_eq!(snip.len(), 80 + 2 + '…'.len_utf8());
        // Multi-byte text never panics on a mid-char cut.
        let uni = "é".repeat(100);
        let snip = offending_line_snippet(&uni, 81);
        assert!(snip.len() <= 80 + 2 + '…'.len_utf8());
    }

    #[test]
    fn fragment_status_tolerates_unknown_fields_and_names_mismatches() {
        let dir = tmp("tolerant");
        let cdir = resume::cells_dir(&dir);
        std::fs::create_dir_all(&cdir).unwrap();
        let spec = spec2();
        write_fragment(&cdir, &spec, &spec.cells[0], &Json::num(2.0)).unwrap();
        // a newer writer annotating fragments must not invalidate them
        let path = fragment_path(&cdir, &spec.cells[0]);
        let mut j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        if let Json::Obj(map) = &mut j {
            map.insert("future_annotation".to_string(), Json::str("ignored"));
        }
        std::fs::write(&path, j.to_string_pretty()).unwrap();
        assert!(matches!(
            fragment_status(&cdir, &spec, &spec.cells[0]),
            FragmentStatus::Valid(_)
        ));
        // a fragment from different training settings names the reason
        let mut retrained = spec.clone();
        retrained.train.steps += 1;
        match fragment_status(&cdir, &retrained, &spec.cells[0]) {
            FragmentStatus::Invalid { reason, .. } => {
                assert!(reason.contains("train config mismatch"), "{reason}")
            }
            other => panic!("expected Invalid, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn commit_fragment_verifies_the_published_bytes() {
        let dir = tmp("commit_verify");
        let cdir = resume::cells_dir(&dir);
        std::fs::create_dir_all(&cdir).unwrap();
        let spec = spec2();
        commit_fragment(&cdir, &spec, &spec.cells[0], &Json::num(3.0)).unwrap();
        assert_eq!(read_fragment(&cdir, &spec, &spec.cells[0]), Some(Json::num(3.0)));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
