//! Declarative sweep grids: a [`SweepSpec`] is the full, serializable
//! description of one experiment sweep — the experiment key the worker
//! dispatches on, the base [`TrainConfig`], and the ordered list of
//! [`Cell`]s (variant × task × ρ × sketch × seed × batch).
//!
//! The spec's JSON form (`sweep.json` in the sweep directory) is the
//! *only* thing a `sweep-worker` process needs besides its `--shard i/N`
//! assignment: workers never rebuild the grid from CLI arguments, so the
//! orchestrator and every worker are guaranteed to agree on cell indices.

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::util::json::Json;

/// Largest cell seed that survives the JSON f64 round-trip losslessly
/// (2^53).  Bigger seeds would make the orchestrator's in-memory cell
/// disagree with every worker's parsed copy, so fragments could never
/// validate and a sweep would rerun forever — reject them up front.
pub const MAX_JSON_SEED: u64 = 1 << 53;

/// One sweep cell — a single fine-tuning run.  `index` is the cell's
/// position in the canonical grid order and doubles as its identity for
/// sharding (`index % shards`), fragment naming, and merge ordering.
#[derive(Debug, Clone, PartialEq)]
pub struct Cell {
    pub index: usize,
    /// Artifact variant name (a key of manifest.json).
    pub variant: String,
    /// Synthetic-GLUE task name.
    pub task: String,
    /// Compression ratio ρ (1.0 = no RMM).
    pub rho: f64,
    /// Sketch-family axis; "none" marks a no-RMM baseline row.
    pub sketch: String,
    /// Per-cell training seed (overrides the spec's base `train.seed`).
    pub seed: u64,
    /// Batch-size axis (Table 3); 0 = the variant's own batch size.
    pub batch: usize,
}

impl Cell {
    /// Validate the grid axes that would otherwise only fail deep inside
    /// `sketch()`/`project_streamed` on a degenerate grid: ρ must be a
    /// finite ratio in (0, 1] (which pins the derived `b_proj` into
    /// `[1, B]` for every batch), and the sketch string must be either a
    /// non-estimator marker ("none" baseline rows, the `budget` grid's
    /// controller markers "auto"/"avjp-auto") or parse as an estimator
    /// configuration — unknown names report the offender and the full
    /// valid family list, case-insensitively.
    pub fn validate_axes(rho: f64, sketch: &str) -> Result<()> {
        if !rho.is_finite() || rho <= 0.0 || rho > 1.0 {
            bail!(
                "cell.rho must be a finite compression ratio in (0, 1], got {rho} \
                 (the derived b_proj must stay within [1, B])"
            );
        }
        let lower = sketch.trim().to_ascii_lowercase();
        if !matches!(lower.as_str(), "none" | "auto" | "avjp-auto") {
            crate::rmm::EstimatorSpec::parse(&lower)
                .with_context(|| format!("cell.sketch '{sketch}'"))?;
        }
        Ok(())
    }

    /// Warm-session affinity key, most-significant first: cells sharing a
    /// *variant* share compiled executables and trainer setup; cells also
    /// sharing a *task* share dataset caches.  The dynamic scheduler
    /// prefers unclaimed cells matching a worker's warm key before
    /// falling back to canonical order — a pure scheduling preference
    /// that can never change what a cell computes.
    pub fn affinity_key(&self) -> (&str, &str) {
        (&self.variant, &self.task)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("index", Json::num(self.index as f64)),
            ("variant", Json::str(self.variant.clone())),
            ("task", Json::str(self.task.clone())),
            ("rho", Json::num(self.rho)),
            ("sketch", Json::str(self.sketch.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("batch", Json::num(self.batch as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Cell> {
        let seed_f = j.get("seed").as_f64().context("cell.seed")?;
        if seed_f < 0.0 || seed_f.fract() != 0.0 || seed_f > MAX_JSON_SEED as f64 {
            bail!("cell.seed {seed_f} outside the losslessly serializable range");
        }
        let rho = j.get("rho").as_f64().context("cell.rho")?;
        let sketch = j.get("sketch").as_str().context("cell.sketch")?.to_string();
        Cell::validate_axes(rho, &sketch)?;
        Ok(Cell {
            index: j.get("index").as_usize().context("cell.index")?,
            variant: j.get("variant").as_str().context("cell.variant")?.to_string(),
            task: j.get("task").as_str().context("cell.task")?.to_string(),
            rho,
            sketch,
            seed: seed_f as u64,
            batch: j.get("batch").as_usize().context("cell.batch")?,
        })
    }
}

/// A full sweep: experiment key + base train config + canonical cell list.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Dispatch key for the cell runner: "table2" | "table3" | "table4"
    /// | "mock" (the deterministic self-test grid).
    pub experiment: String,
    /// Base training config; each cell overrides `seed` with its own.
    pub train: TrainConfig,
    pub cells: Vec<Cell>,
}

impl SweepSpec {
    pub fn new(experiment: impl Into<String>, train: TrainConfig) -> SweepSpec {
        SweepSpec { experiment: experiment.into(), train, cells: Vec::new() }
    }

    /// Append a cell in canonical grid order (its index is its position).
    /// Panics on a seed above [`MAX_JSON_SEED`] — such a cell could never
    /// validate its own fragment after the spec's JSON round-trip — and on
    /// axes [`Cell::validate_axes`] rejects: a grid driver constructing a
    /// degenerate cell is a bug worth failing loudly at build time.
    pub fn push(
        &mut self,
        variant: impl Into<String>,
        task: impl Into<String>,
        rho: f64,
        sketch: impl Into<String>,
        seed: u64,
        batch: usize,
    ) {
        assert!(
            seed <= MAX_JSON_SEED,
            "cell seed {seed} cannot round-trip JSON (must be <= 2^53)"
        );
        let sketch = sketch.into();
        if let Err(e) = Cell::validate_axes(rho, &sketch) {
            panic!("invalid sweep cell: {e:#}");
        }
        let index = self.cells.len();
        self.cells.push(Cell {
            index,
            variant: variant.into(),
            task: task.into(),
            rho,
            sketch: sketch.into(),
            seed,
            batch,
        });
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("experiment", Json::str(self.experiment.clone())),
            ("train", self.train.to_json()),
            ("cells", Json::Arr(self.cells.iter().map(|c| c.to_json()).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SweepSpec> {
        let experiment = j
            .get("experiment")
            .as_str()
            .context("sweep.experiment")?
            .to_string();
        if experiment.is_empty() {
            bail!("sweep.experiment must be non-empty");
        }
        let train = TrainConfig::from_json(j.get("train")).context("sweep.train")?;
        let cells = j
            .get("cells")
            .as_arr()
            .context("sweep.cells")?
            .iter()
            .map(Cell::from_json)
            .collect::<Result<Vec<_>>>()?;
        for (pos, cell) in cells.iter().enumerate() {
            if cell.index != pos {
                bail!(
                    "sweep.cells out of canonical order: cell at position {pos} \
                     has index {}",
                    cell.index
                );
            }
        }
        Ok(SweepSpec { experiment, train, cells })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_spec() -> SweepSpec {
        let mut s = SweepSpec::new("mock", TrainConfig::default());
        s.push("v0", "cola", 1.0, "gauss", 42, 0);
        s.push("v1", "sst2", 0.1, "dct", 7, 16);
        s
    }

    #[test]
    fn spec_json_roundtrip() {
        let s = demo_spec();
        let j = s.to_json();
        let back = SweepSpec::from_json(&j).unwrap();
        assert_eq!(back.experiment, "mock");
        assert_eq!(back.train, s.train);
        assert_eq!(back.cells, s.cells);
        // byte-stable re-encode (the merge contract relies on this)
        assert_eq!(j.to_string_pretty(), back.to_json().to_string_pretty());
    }

    #[test]
    fn push_assigns_sequential_indices() {
        let s = demo_spec();
        assert_eq!(s.cells[0].index, 0);
        assert_eq!(s.cells[1].index, 1);
        assert_eq!(s.cells[1].batch, 16);
    }

    #[test]
    #[should_panic(expected = "cannot round-trip JSON")]
    fn push_rejects_unserializable_seed() {
        let mut s = SweepSpec::new("mock", TrainConfig::default());
        s.push("v", "cola", 1.0, "gauss", MAX_JSON_SEED + 1, 0);
    }

    #[test]
    fn from_json_rejects_unserializable_seed() {
        let mut j = demo_spec().to_json();
        if let Json::Obj(map) = &mut j {
            if let Some(Json::Arr(cells)) = map.get_mut("cells") {
                if let Json::Obj(cell) = &mut cells[0] {
                    cell.insert("seed".to_string(), Json::num(2f64.powi(54)));
                }
            }
        }
        assert!(SweepSpec::from_json(&j).is_err());
    }

    fn with_cell0_field(mut j: Json, field: &str, value: Json) -> Json {
        if let Json::Obj(map) = &mut j {
            if let Some(Json::Arr(cells)) = map.get_mut("cells") {
                if let Json::Obj(cell) = &mut cells[0] {
                    cell.insert(field.to_string(), value);
                }
            }
        }
        j
    }

    #[test]
    fn from_json_rejects_degenerate_rho() {
        for bad in [0.0, -1.0, 1.5, f64::NAN, f64::INFINITY] {
            // NaN/inf can't travel through our JSON, so splice post-parse
            let j = with_cell0_field(
                demo_spec().to_json(),
                "rho",
                if bad.is_finite() { Json::num(bad) } else { Json::Null },
            );
            let err = SweepSpec::from_json(&j).unwrap_err().to_string();
            if bad.is_finite() {
                assert!(err.contains("(0, 1]"), "rho={bad}: {err}");
            }
        }
        assert!(Cell::validate_axes(f64::NAN, "gauss").is_err());
        assert!(Cell::validate_axes(f64::INFINITY, "gauss").is_err());
        assert!(Cell::validate_axes(1.0, "gauss").is_ok());
    }

    #[test]
    fn from_json_rejects_unknown_sketch_with_full_list() {
        let j = with_cell0_field(demo_spec().to_json(), "sketch", Json::str("bogus"));
        let err = format!("{:#}", SweepSpec::from_json(&j).unwrap_err());
        for name in crate::rmm::SketchKind::valid_names() {
            assert!(err.contains(name), "missing '{name}' in: {err}");
        }
        assert!(err.contains("'bogus'"), "{err}");
    }

    #[test]
    fn sketch_axis_accepts_markers_estimators_and_mixed_case() {
        for ok in ["none", "auto", "avjp-auto", "avjp-gauss", "WtaCrs", "DCT"] {
            assert!(Cell::validate_axes(0.5, ok).is_ok(), "{ok}");
        }
        let j = with_cell0_field(demo_spec().to_json(), "sketch", Json::str("avjp-dft"));
        assert!(SweepSpec::from_json(&j).is_ok());
    }

    #[test]
    #[should_panic(expected = "invalid sweep cell")]
    fn push_rejects_degenerate_axes() {
        let mut s = SweepSpec::new("mock", TrainConfig::default());
        s.push("v", "cola", 0.0, "gauss", 1, 0);
    }

    #[test]
    fn from_json_rejects_out_of_order_cells() {
        let mut j = demo_spec().to_json();
        if let Json::Obj(map) = &mut j {
            if let Some(Json::Arr(cells)) = map.get_mut("cells") {
                cells.swap(0, 1);
            }
        }
        assert!(SweepSpec::from_json(&j).is_err());
    }
}
