//! Sharded sweep orchestrator: declarative experiment grids executed
//! across worker processes (or in-process shards), merged into one
//! canonical report, resumable after a kill.
//!
//! This module is the canonical reference for the **shard / merge /
//! resume contract** (mirroring `tensor/pool/mod.rs` for the pool
//! knobs).  The paper's headline evidence is sweep-shaped — Table 2
//! (score vs ρ), Table 3 (memory per task/batch/ρ), Table 4 (sketch
//! families) are grids of *independent* fine-tuning runs — so the grid,
//! not the single run, is the unit this layer schedules.
//!
//! # The contract
//!
//! * **Grid** ([`grid`]) — a [`SweepSpec`] lists the cells in canonical
//!   order; a cell's `index` is its identity.  The spec serializes to
//!   `sweep.json` inside the sweep directory and is the only input a
//!   worker needs besides its shard assignment.
//! * **Shard** ([`shard`]) — cells are owned round-robin:
//!   shard `i/N` runs exactly the cells with `index % N == i`.  The
//!   assignment is a pure function of the grid, so worker cell sets are
//!   disjoint and exhaustive by construction, with no work list to
//!   communicate and no coordination while running.
//! * **Merge** ([`merge`]) — each completed cell commits one fragment
//!   `cells/cell_<index>.json` atomically (tmp + rename), embedding the
//!   cell it answers for.  The merge walks the spec order and looks
//!   fragments up by index: the merged result list is a pure function
//!   of the fragment *set*, independent of shard count, completion
//!   order, or which process wrote which fragment.  That is why
//!   `--shards 1` and `--shards 3` produce **byte-identical merged
//!   reports** whenever the per-cell results are deterministic (the
//!   mock grid used by `repro sweep-selftest` and `tests/prop_sweep.rs`;
//!   real runs are deterministic in everything except wall-clock
//!   timing fields).
//! * **Resume** ([`resume`]) — completion state *is* the fragment set.
//!   A worker skips any cell whose valid fragment exists, so rerunning
//!   a killed sweep with `--resume` executes only the missing cells.
//!   Fragments are validated against the current spec at read time —
//!   both the embedded cell *and* the embedded train config must match
//!   (mismatch ⇒ treated as absent ⇒ cell reruns) — so neither a grid
//!   edit nor changed training settings (`--steps`, `--lr`, …) between
//!   runs can smuggle stale rows into a report.
//!
//! # Execution modes
//!
//! * **Worker processes** — [`spawn_workers`] self-spawns the current
//!   binary once per shard with the `sweep-worker --dir D --shard i/N`
//!   contract (see `main.rs`); each worker owns its own `Engine` and
//!   manifest, giving true multi-process parallelism for engine-bound
//!   cells.
//! * **In-process** — [`run_shard`] with [`Shard::SERIAL`] runs every
//!   cell inline (the `--shards 1` path), and [`run_shards_pooled`]
//!   fans shards out as `tensor::pool` tasks for cheap (`Sync`) cell
//!   runners such as the mock grid.

pub mod grid;
pub mod merge;
pub mod resume;
pub mod shard;

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

pub use grid::{Cell, SweepSpec};
pub use shard::Shard;

/// Run every not-yet-completed cell owned by `shard`, committing one
/// fragment per cell.  Returns how many cells actually ran (completed
/// cells with valid fragments are skipped — the resume path).
pub fn run_shard(
    dir: &Path,
    spec: &SweepSpec,
    shard: Shard,
    runner: &mut dyn FnMut(&Cell) -> Result<Json>,
) -> Result<usize> {
    let cdir = resume::cells_dir(dir);
    std::fs::create_dir_all(&cdir)
        .with_context(|| format!("creating {cdir:?}"))?;
    let mut ran = 0usize;
    for cell in spec.cells.iter().filter(|c| shard.owns(c.index)) {
        if merge::read_fragment(&cdir, spec, cell).is_some() {
            continue;
        }
        let result = runner(cell).with_context(|| {
            format!(
                "sweep cell {} ({} on {}, rho={})",
                cell.index, cell.variant, cell.task, cell.rho
            )
        })?;
        merge::write_fragment(&cdir, spec, cell, &result)?;
        ran += 1;
    }
    Ok(ran)
}

/// Run all `shards` shards concurrently as `tensor::pool` tasks inside
/// this process.  The runner must be `Sync`; shards write disjoint
/// fragment files, so this upholds the pool's disjoint-output contract.
pub fn run_shards_pooled(
    dir: &Path,
    spec: &SweepSpec,
    shards: usize,
    runner: &(dyn Fn(&Cell) -> Result<Json> + Sync),
) -> Result<()> {
    let shards = shards.max(1);
    let errors = std::sync::Mutex::new(Vec::<String>::new());
    crate::tensor::pool::global().run(shards, shards, |s| {
        let shard = Shard { index: s, of: shards };
        let mut f = |c: &Cell| runner(c);
        if let Err(e) = run_shard(dir, spec, shard, &mut f) {
            errors.lock().unwrap().push(format!("shard {shard}: {e:#}"));
        }
    });
    let errs = errors.into_inner().unwrap();
    if !errs.is_empty() {
        bail!("in-process sweep failed: {}", errs.join("; "));
    }
    Ok(())
}

/// Spawn one `sweep-worker` process per shard from the current binary
/// and wait for all of them.  The worker contract (implemented by
/// `main.rs`) is: `<exe> sweep-worker --dir <dir> --shard i/N [passthrough
/// args]` — the worker loads `sweep.json`, runs its shard, and exits 0
/// iff every owned cell committed a fragment.
pub fn spawn_workers(dir: &Path, shards: usize, extra_args: &[String]) -> Result<()> {
    let exe = std::env::current_exe().context("locating current executable")?;
    let mut children = Vec::with_capacity(shards);
    for i in 0..shards {
        let child = std::process::Command::new(&exe)
            .arg("sweep-worker")
            .arg("--dir")
            .arg(dir)
            .arg("--shard")
            .arg(format!("{i}/{shards}"))
            .args(extra_args)
            .spawn()
            .with_context(|| format!("spawning sweep worker {i}/{shards}"))?;
        children.push((i, child));
    }
    let mut failed = Vec::new();
    for (i, mut child) in children {
        match child.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => failed.push(format!("shard {i}/{shards} exited {status}")),
            Err(e) => failed.push(format!("shard {i}/{shards} wait failed: {e}")),
        }
    }
    if !failed.is_empty() {
        bail!("sweep workers failed: {}", failed.join("; "));
    }
    Ok(())
}

/// Deterministic mock cell runner: a pure FNV-1a hash of the cell's
/// identity.  Backs the orchestration tests and `repro sweep-selftest`,
/// where per-cell determinism makes shard-count byte-identity checkable
/// without an engine or artifacts.
pub fn mock_cell(cell: &Cell) -> Json {
    let key = format!(
        "{}|{}|{}|{}|{}|{}|{}",
        cell.index, cell.variant, cell.task, cell.rho, cell.sketch, cell.seed, cell.batch
    );
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    Json::obj(vec![
        ("id", Json::str(key)),
        ("score", Json::num((h % 10_000) as f64 / 100.0)),
        ("loss", Json::num(((h >> 16) % 1_000) as f64 / 1_000.0)),
        ("steps", Json::num(((h >> 32) % 500) as f64)),
    ])
}

/// The grid `repro sweep-selftest` and CI's smoke sweep run: 24 mock
/// cells spanning every grid axis.
pub fn selftest_spec() -> SweepSpec {
    let mut spec = SweepSpec::new("mock", crate::config::TrainConfig::default());
    let rhos = [1.0, 0.5, 0.1];
    let sketches = ["gauss", "dct"];
    for (r, &rho) in rhos.iter().enumerate() {
        for t in 0..4usize {
            for (s, &sketch) in sketches.iter().enumerate() {
                spec.push(
                    format!("mock_v{t}_r{r}"),
                    format!("task{t}"),
                    rho,
                    sketch,
                    s as u64,
                    0,
                );
            }
        }
    }
    spec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_cell_is_deterministic_and_finite() {
        let spec = selftest_spec();
        for cell in &spec.cells {
            let a = mock_cell(cell);
            let b = mock_cell(cell);
            assert_eq!(a, b);
            let s = a.to_string_pretty();
            assert!(!s.contains("NaN") && !s.contains("inf"), "{s}");
        }
        // distinct cells produce distinct results
        assert_ne!(mock_cell(&spec.cells[0]), mock_cell(&spec.cells[1]));
    }

    #[test]
    fn selftest_grid_covers_all_axes() {
        let spec = selftest_spec();
        assert_eq!(spec.cells.len(), 24);
        assert_eq!(spec.experiment, "mock");
        assert!(spec.cells.iter().any(|c| c.sketch == "dct"));
        assert!(spec.cells.iter().any(|c| (c.rho - 0.1).abs() < 1e-12));
    }

    #[test]
    fn run_shard_skips_completed_cells() {
        let dir = std::env::temp_dir()
            .join(format!("rmm_sweep_mod_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spec = selftest_spec();
        resume::prepare(&dir, &spec, false).unwrap();
        let ran = run_shard(&dir, &spec, Shard::SERIAL, &mut |c| Ok(mock_cell(c)))
            .unwrap();
        assert_eq!(ran, spec.cells.len());
        // second pass: everything already committed
        let mut reran = 0usize;
        let ran = run_shard(&dir, &spec, Shard::SERIAL, &mut |c| {
            reran += 1;
            Ok(mock_cell(c))
        })
        .unwrap();
        assert_eq!(reran, 0, "must not rerun completed cells");
        assert_eq!(ran, 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
