//! Sweep orchestrator: declarative experiment grids executed across
//! worker processes (or in-process workers), merged into one canonical
//! report, resumable after a kill, and scheduled either statically
//! (round-robin shards) or dynamically (claim/lease work stealing).
//!
//! This module is the canonical reference for the **shard / claim /
//! merge / resume contract** (mirroring `tensor/pool/mod.rs` for the
//! pool knobs).  The paper's headline evidence is sweep-shaped — Table 2
//! (score vs ρ), Table 3 (memory per task/batch/ρ), Table 4 (sketch
//! families) are grids of *independent* fine-tuning runs — so the grid,
//! not the single run, is the unit this layer schedules.
//!
//! # Grid catalog
//!
//! Engine-backed experiment keys: `table2` (score vs ρ), `table3`
//! (memory per task/batch/ρ), `table4` (sketch families on CoLA).
//! Engine-free keys runnable anywhere (CI, selftests): `mock`
//! ([`selftest_spec`], pure FNV cells), `mockdata`
//! ([`selftest_data_spec`], the warm session layer's real data path),
//! `synth-easy|medium|hard` ([`synth_spec`], seeded workload grids with
//! skewed planned costs for the chaos harness), and `budget`
//! ([`selftest_budget_spec`] for the selftest; `bench_harness::budget`
//! builds the full accuracy-vs-memory-at-equal-budget table comparing
//! all seven estimator configurations — five families plus `wtacrs` and
//! an `avjp-*` per-path variant — against the closed-loop controller
//! rows, with every (family, ρ) choice recorded in the fragment).
//!
//! # The contract
//!
//! * **Grid** ([`grid`]) — a [`SweepSpec`] lists the cells in canonical
//!   order; a cell's `index` is its identity.  The spec serializes to
//!   `sweep.json` inside the sweep directory and is the only input a
//!   worker needs besides its schedule.
//! * **Static schedule** ([`shard`]) — cells are owned round-robin:
//!   shard `i/N` runs exactly the cells with `index % N == i`.  The
//!   assignment is a pure function of the grid, so worker cell sets are
//!   disjoint and exhaustive by construction, with no work list to
//!   communicate and no coordination while running.  This is the
//!   zero-coordination fallback (`--schedule static`, the default) and
//!   the contract `tests/prop_sweep.rs` pins.
//! * **Dynamic schedule** ([`claim`] + [`scheduler`]) — workers pull
//!   the next incomplete, unclaimed cell instead of filtering by index.
//!   A **claim** is a create-exclusive file `cells/cell_<i>.claim`
//!   embedding the worker id and a heartbeat timestamp; the OS makes
//!   exactly one claimant win per cell.  A claim is a **lease**: when
//!   its age (embedded heartbeat, or file mtime for a torn write)
//!   exceeds the TTL (`--lease-ttl-ms`, default 10 min), any worker may
//!   **reclaim** the cell — the stale file is atomically renamed aside
//!   and the create-exclusive race re-runs.  A valid fragment
//!   supersedes any claim: workers check fragments first and delete
//!   leftover claim files on completed cells.  Workers run until every
//!   cell has a valid fragment, so a worker killed mid-lease is healed
//!   by the survivors after the TTL.  Because the claim store *is* the
//!   fragment directory, pointing several machines at one shared
//!   fragment store shards a sweep across them with no extra
//!   coordination.  Claim races can at worst duplicate a cell run
//!   (stale-but-alive holder + reclaimer); both commit the same
//!   deterministic fragment, so scheduling never changes the report.
//! * **Merge** ([`merge`]) — each completed cell commits one fragment
//!   `cells/cell_<index>.json` atomically (tmp + rename), embedding the
//!   cell it answers for.  The merge walks the spec order and looks
//!   fragments up by exact path — claim files and tmp files in the same
//!   directory are invisible to it.  The merged result list is a pure
//!   function of the fragment *set*, independent of schedule, worker
//!   count, completion order, or which process wrote which fragment.
//!   That is why `--shards 1`, `--shards 3`, and `--schedule dynamic`
//!   with any worker count produce **byte-identical merged reports**
//!   whenever the per-cell results are deterministic (the mock grid
//!   used by `repro sweep-selftest`, `tests/prop_sweep.rs`, and
//!   `tests/prop_sched.rs`; real runs are deterministic in everything
//!   except wall-clock timing fields).
//! * **Resume** ([`resume`]) — completion state *is* the fragment set.
//!   A worker skips any cell whose valid fragment exists, so rerunning
//!   a killed sweep with `--resume` executes only the missing cells.
//!   Fragments are validated against the current spec at read time —
//!   both the embedded cell *and* the embedded train config must match
//!   (mismatch ⇒ treated as absent ⇒ cell reruns) — so neither a grid
//!   edit nor changed training settings (`--steps`, `--lr`, …) between
//!   runs can smuggle stale rows into a report.  Claim files never
//!   carry completion state: `prepare(resume=true)` clears every
//!   leftover claim (so a killed run's stale leases cannot stall the
//!   resumed sweep until the TTL; a still-live worker whose claim is
//!   swept at worst duplicates one cell, which is benign), and a fresh
//!   run clears the directory outright.
//!
//! # Execution modes
//!
//! * **Worker processes** — [`spawn_workers`] self-spawns the current
//!   binary once per worker with the `sweep-worker --dir D --shard i/N
//!   [--schedule dynamic --lease-ttl-ms T]` contract (see `main.rs`);
//!   each worker owns its own warm `Session` (engine + executable cache
//!   + per-variant trainer setups + dataset caches, `crate::session`),
//!   giving true multi-process parallelism for engine-bound cells.
//!   Worker stderr streams live through the orchestrator and is mirrored
//!   to `worker_<i>.stderr.log` in the sweep directory; a failing
//!   worker's exit status and stderr tail surface in the error.
//! * **In-process** — [`run_shard`] with [`Shard::SERIAL`] runs every
//!   cell inline (the `--shards 1` path), [`run_dynamic`] drives one
//!   dynamic worker on the current thread, and [`run_shards_pooled`]
//!   fans static shards out as `tensor::pool` tasks for cheap (`Sync`)
//!   cell runners such as the mock grid.
//!
//! # Cross-machine sharding recipe
//!
//! The claim dir **is** the shared store, so sharding a sweep across
//! machines needs no coordinator — only a shared mount:
//!
//! * **Layout** — export one sweep directory (`sweep_<name>/`) on a
//!   shared filesystem and mount it at the same path on every host; the
//!   orchestrating host runs `prepare` once (writing `sweep.json`), then
//!   every host points workers at it: `repro sweep-worker --dir
//!   /mnt/sweeps/sweep_table2 --schedule dynamic`.  Everything stateful
//!   lives under `cells/` (fragments + claims); `worker_<i>.stderr.log`
//!   files are per-orchestrator and never conflict.  The filesystem must
//!   honor `O_CREAT|O_EXCL` and atomic same-directory `rename` — local
//!   disks, NFSv4+ and CIFS with hard semantics do; object-store gateways
//!   generally do **not** and must not back a claim dir.
//! * **Clock skew** — claim staleness compares a *reader's* clock
//!   against the *writer's* embedded heartbeat, so the effective lease a
//!   remote worker observes is `lease_ttl_ms ± skew`.  Keep hosts under
//!   NTP discipline and size the TTL so `max cell wall time + max
//!   expected skew < lease_ttl_ms`; with the 10-minute default, tens of
//!   seconds of skew are harmless.  Skew can only shorten/stretch
//!   leases, never corrupt a report: a too-early reclaim duplicates one
//!   deterministic cell (benign, and now counted — see
//!   `scheduler::DynamicRun::duplicates`), a too-late one just waits.
//! * **Heartbeats** — long cells should keep their lease fresh instead
//!   of forcing a TTL above worst-case wall time: the trainer ticks its
//!   claim's heartbeat before step 0, every `log_every` steps, and per
//!   dev-eval batch (`RunOpts::tick` plumbed through [`CellCtx`]), so
//!   `--lease-ttl-ms` may safely drop below the cell wall time as long
//!   as it comfortably exceeds the longest stretch between ticks —
//!   `log_every` steps, or a single step carrying the variant's
//!   one-time compile.
//!
//! # Chaos knobs (fault injection)
//!
//! The `crate::chaos` subsystem fuzzes this whole stack reproducibly:
//! `--chaos-seed N [--chaos-profile P]` compiles, per worker slot, a
//! deterministic **chaos schedule** and installs it in each
//! `sweep-worker` process.  The orchestrator and the selftest's serial
//! reference always run fault-free; fault points are zero-cost no-ops
//! when chaos is off.
//!
//! * **Fault points** — `claim.create`, `claim.refresh`,
//!   `claim.reclaim` (claim-store ops, inside their retry loops),
//!   `fragment.stage`, `fragment.commit`, `fragment.read` (fragment
//!   IO), `sched.cell` (start of a claimed cell, lease held — where
//!   kills fire), `resume.spec` (spec write), `session.evict`
//!   (warm-cache drop before a cell), `registry.heartbeat` (the fleet
//!   registry re-stamp), `cache.publish` (the artifact-cache blob
//!   `hard_link` commit), `daemon.dequeue` (the daemon's queue→active
//!   rename), `event.tee` (the daemon's `events.jsonl` append), and
//!   `clock` (persistent heartbeat-clock skew via `claim::now_ms`).
//! * **Schedule grammar** — `[w<slot>:]<point>@<hit>=<action>`,
//!   `;`-separated; actions are `err:<kind>`, `kill`, `delay:<ms>`,
//!   `skew:<±ms>`, `truncate`, `garbage`, `evict`.  `--chaos-profile`
//!   names a built-in profile (`light` | `crash` | `heavy`) or, if it
//!   contains `@`, is parsed as an explicit schedule.
//! * **Seed reproducibility** — the compiled schedule is a pure
//!   function of `(seed, profile, slot)`, and hit counters are
//!   worker-local, so the same seed replays the identical per-worker
//!   fault sequence regardless of cross-worker interleaving.  Kill
//!   faults fire once per slot: a respawned worker (generation > 0)
//!   filters them out of its schedule.
//! * **Why reports survive** — every injected fault lands on a path
//!   the contract already prices: transient IO errors degrade to
//!   bounded jittered retries ([`retry`]), corrupt/torn commits are
//!   caught and re-staged by commit verification
//!   ([`merge::commit_fragment`]), kills leave a stale lease for
//!   reclaim (plus the orchestrator-side respawn budget of
//!   [`spawn_workers_supervised`]), skew only stretches or shortens
//!   leases, and cache eviction is invisible by the warm ≡ cold
//!   session contract.  `repro sweep-selftest --chaos-seed N` and
//!   `tests/prop_chaos.rs` pin merged-report byte-identity against the
//!   fault-free serial run.
//!
//! # Daemon queue + event contract
//!
//! `repro sweep-daemon` (`crate::daemon`) turns this layer into a
//! persistent service; this section is the canonical reference for its
//! queue layout and JSONL event contract (ROADMAP: "Sweep-as-a-service
//! daemon").
//!
//! * **Queue layout** — one `--queue` directory:
//!   `incoming/<lane>/<name>.json` (queued specs; a *lane* is a tenant,
//!   charset `[A-Za-z0-9-]` — no underscore, so the `__` in the sweep
//!   id `<lane>__<name>` is unambiguous; names are `[A-Za-z0-9_-]`),
//!   `active/` (the spec being run), `done/`, `rejected/`,
//!   `sweeps/<id>/` (per-sweep fragment store — the sole state),
//!   `reports/<id>.json` (merged reports in the exact `sweep-selftest
//!   --out` byte format), and `events.jsonl` (the raw event tee).
//!   Enqueue (`repro sweep-enqueue`) stages to a unique tmp name and
//!   publishes via `hard_link` — the claim layer's create-exclusive
//!   idiom, so concurrent enqueues of one `(lane, name)` have exactly
//!   one winner and no torn spec is ever visible.  Dequeue is a rename
//!   into `active/`; a daemon killed at any instant is recovered by the
//!   next run, which processes `active/` first and resume-prepares the
//!   sweep dir (fragments make the re-run a resume).
//! * **Fairness + backpressure** — lanes are served round-robin (first
//!   non-empty lane cyclically after the last lane served); within a
//!   lane, specs run in name order.  Queue depth is bounded per lane
//!   (`--queue-cap`): excess specs move to `rejected/` with a typed
//!   `sweep_rejected` event carrying the observed depth and the cap.
//! * **Event schema** — one compact JSON object per line; `type` is the
//!   snake_case discriminant, `t_ms` a unix-ms timestamp (the only
//!   nondeterministic field).  Synthetic ids are assigned monotonically
//!   from 1 by emitter and replay parser alike — never on the wire.
//!
//!   | type                 | payload fields             |
//!   |----------------------|----------------------------|
//!   | `daemon_started`     | `queue`, `workers`         |
//!   | `sweep_queued`       | `sweep`, `lane`            |
//!   | `sweep_rejected`     | `sweep`, `lane`, `depth`, `cap` |
//!   | `sweep_started`      | `sweep`, `lane`, `cells`   |
//!   | `cell_claimed`       | `sweep`, `cell`, `worker`  |
//!   | `cell_done`          | `sweep`, `cell`, `worker`  |
//!   | `fragment_committed` | `sweep`, `cell`            |
//!   | `worker_respawned`   | `sweep`, `slot`, `gen`     |
//!   | `sweep_merged`       | `sweep`, `cells`           |
//!   | `daemon_stopped`     | `sweeps`                   |
//!
//!   `cell_claimed` / `cell_done` / `fragment_committed` are emitted by
//!   hooks at this module's existing chaos fault-point seams
//!   (`sched.cell`, `fragment.commit`) and are zero-cost no-ops unless
//!   a daemon sink is installed.
//! * **Replay guarantees** — `daemon::events::parse_lines` tolerates
//!   CRLF line endings, blank lines, and a torn trailing line; an
//!   unknown `type`, malformed JSON, or missing required field yields a
//!   per-line diagnostic (never a hard error) and consumes no id;
//!   unknown extra fields on known types are ignored.  Replay of a teed
//!   `events.jsonl` therefore reproduces the emitted typed stream
//!   exactly — ids, order, payloads — which `sweep-daemon
//!   --replay-verify` checks after every drain, and
//!   `tests/prop_events.rs` pins.  The log is a pure **witness**: the
//!   daemon never reads it back for decisions, so a lost tee line
//!   (`event.tee` chaos) costs observability, never correctness.
//!
//! # Fleet registry + artifact cache
//!
//! `--artifact-cache on` turns a shared sweep directory into a **fleet
//! mount** ([`fleet`]; ROADMAP: "Cross-machine fleet").  Two sibling
//! directories join `cells/` — both invisible to [`merge`], which looks
//! fragments up by exact path:
//!
//! ```text
//! sweep_<name>/
//!   sweep.json            the spec (the only coordination input)
//!   cells/                fragments + claims (the sole sweep state)
//!   workers/<id>.json     fleet registry: one entry per live worker
//!   cache/<kind>_<key>.bin  shared warm-start artifact blobs
//! ```
//!
//! * **Registry lifecycle** — a worker joining a sweep creates
//!   `workers/<worker_id>.json` create-exclusively ([`fleet::register`],
//!   the claim idiom with the same `{"heartbeat_ms", "worker"}` body);
//!   an existing entry is taken over only when stale by the claim
//!   layer's symmetric skew rule (min of plausible-heartbeat age and
//!   mtime age — a heartbeat in the reader's past with a fresh mtime is
//!   *live*).  The entry is re-stamped once per scheduler grid pass and
//!   on every [`CellCtx::tick`], so fleet liveness is exactly as fresh
//!   as lease liveness; [`fleet::live_workers`] lists live ids,
//!   [`fleet::reclaim_stale`] sweeps dead ones.  Deregistration on
//!   clean exit (or guard drop) removes the entry.  **Elastic
//!   join/leave is free**: a worker registering after `run_dynamic`
//!   started simply claims whatever cells remain, and a killed worker's
//!   entry ages out like its stale lease.  The registry is pure
//!   observability — merged reports never depend on it, and a fresh
//!   (non-resume) `prepare` clears it.
//! * **Cache key/commit contract** — blobs are keyed by FNV-1a over
//!   exactly the inputs the artifact is a pure function of: trainer
//!   init-param setups by `(manifest dir, variant)`, dev-batch sets by
//!   `(task, seq_len, vocab, batch_size, seed)`.  A writer stages the
//!   self-verifying blob (magic + key echo + length + payload + FNV
//!   digest, all f32s as `to_bits` LE) to a process-unique tmp name and
//!   publishes with `hard_link` — concurrent writers compute identical
//!   bytes and exactly one wins (`cache.publish` fault point inside the
//!   retry loop).  Readers treat *any* mismatch — magic, key, length,
//!   digest, trailing bytes — as absence and recompute, so a torn or
//!   corrupted blob costs one cold start, never a wrong report.
//!   Warm ≡ cold byte-identity is preserved by construction: a cache
//!   hit hands back bit-exactly what the miss path would compute.
//!   Hit/publish counters live in `SessionStats` and surface on
//!   **stderr only** (`session.stats.summary()`), never in fragments.
//! * **Mount-less schedulers** — when workers cannot share a mount at
//!   all, [`shard::affinity_assignment`] computes a static cell→shard
//!   map co-locating same-`(variant, task)` cells, so each worker still
//!   warm-starts across its whole assignment from its private state.

pub mod claim;
pub mod fleet;
pub mod grid;
pub mod merge;
pub mod resume;
pub mod retry;
pub mod scheduler;
pub mod shard;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

pub use fleet::ArtifactCache;
pub use grid::{Cell, SweepSpec};
pub use scheduler::{
    run_dynamic, run_dynamic_registered, DynamicConfig, DynamicRun, Schedule,
    DEFAULT_LEASE_TTL_MS,
};
pub use shard::Shard;

/// Per-cell execution context a scheduler hands its runner.  It carries
/// the lease heartbeat: a runner executing under a dynamic-schedule
/// claim can [`tick`](CellCtx::tick) to keep the lease fresh from
/// inside a long cell (the trainer loop does, every `log_every` steps),
/// so `--lease-ttl-ms` may drop below cell wall time.  A worker
/// registered in the fleet registry ([`fleet::register`]) additionally
/// rides its registry heartbeat on the same ticks, so fleet liveness is
/// exactly as fresh as lease liveness.  Under the static schedule (or
/// no scheduler at all) there is no lease and `tick` is a no-op.
pub struct CellCtx<'a> {
    heartbeat: Option<&'a claim::ClaimGuard>,
    registry: Option<&'a fleet::RegistryGuard>,
}

impl<'a> CellCtx<'a> {
    /// Context for runs outside any lease (static shards, direct calls).
    pub fn none() -> CellCtx<'static> {
        CellCtx { heartbeat: None, registry: None }
    }

    /// Context for a cell run under a held claim.
    pub fn under_lease(guard: &'a claim::ClaimGuard) -> CellCtx<'a> {
        CellCtx { heartbeat: Some(guard), registry: None }
    }

    /// Context for a cell run under a held claim by a fleet-registered
    /// worker: ticks re-stamp the registry entry alongside the lease.
    pub fn under_lease_registered(
        guard: &'a claim::ClaimGuard,
        registry: Option<&'a fleet::RegistryGuard>,
    ) -> CellCtx<'a> {
        CellCtx { heartbeat: Some(guard), registry }
    }

    pub fn has_heartbeat(&self) -> bool {
        self.heartbeat.is_some()
    }

    /// Best-effort heartbeat refresh (lease + registry).  Errors are
    /// swallowed: a missed re-stamp at worst lets the lease go stale,
    /// which duplicates one deterministic cell — never a wrong report
    /// (and a stale registry entry costs fleet observability only).
    pub fn tick(&self) {
        if let Some(guard) = self.heartbeat {
            let _ = guard.refresh();
        }
        if let Some(reg) = self.registry {
            let _ = reg.heartbeat();
        }
    }
}

/// Run every not-yet-completed cell owned by `shard`, committing one
/// fragment per cell.  Returns how many cells actually ran (completed
/// cells with valid fragments are skipped — the resume path).
pub fn run_shard(
    dir: &Path,
    spec: &SweepSpec,
    shard: Shard,
    runner: &mut dyn FnMut(&Cell, &CellCtx<'_>) -> Result<Json>,
) -> Result<usize> {
    let cdir = resume::cells_dir(dir);
    std::fs::create_dir_all(&cdir)
        .with_context(|| format!("creating {cdir:?}"))?;
    let mut ran = 0usize;
    for cell in spec.cells.iter().filter(|c| shard.owns(c.index)) {
        if merge::read_fragment(&cdir, spec, cell).is_some() {
            continue;
        }
        let result = runner(cell, &CellCtx::none()).with_context(|| {
            format!(
                "sweep cell {} ({} on {}, rho={})",
                cell.index, cell.variant, cell.task, cell.rho
            )
        })?;
        merge::commit_fragment(&cdir, spec, cell, &result)?;
        ran += 1;
    }
    Ok(ran)
}

/// Run all `shards` shards concurrently as `tensor::pool` tasks inside
/// this process.  The runner must be `Sync`; shards write disjoint
/// fragment files, so this upholds the pool's disjoint-output contract.
pub fn run_shards_pooled(
    dir: &Path,
    spec: &SweepSpec,
    shards: usize,
    runner: &(dyn Fn(&Cell) -> Result<Json> + Sync),
) -> Result<()> {
    let shards = shards.max(1);
    let errors = std::sync::Mutex::new(Vec::<String>::new());
    crate::tensor::pool::global().run(shards, shards, |s| {
        let shard = Shard { index: s, of: shards };
        let mut f = |c: &Cell, _: &CellCtx<'_>| runner(c);
        if let Err(e) = run_shard(dir, spec, shard, &mut f) {
            errors.lock().unwrap().push(format!("shard {shard}: {e:#}"));
        }
    });
    let errs = errors.into_inner().unwrap();
    if !errs.is_empty() {
        bail!("in-process sweep failed: {}", errs.join("; "));
    }
    Ok(())
}

/// Spawn one `sweep-worker` process per worker from the current binary,
/// supervise them, and wait for all of them.  The worker contract
/// (implemented by `main.rs`) is: `<exe> sweep-worker --dir <dir>
/// --shard i/N --worker-slot i --worker-gen g [passthrough args]` — the
/// worker loads `sweep.json`, runs its cells (its shard under the
/// static schedule; whatever it can claim when the extra args select
/// `--schedule dynamic`), and exits 0 iff every cell it owned or won
/// committed a fragment.  `respawn_budget` is the total number of
/// crashed-worker respawns allowed across the whole sweep (0 = the
/// fail-fast behavior).
pub fn spawn_workers(
    dir: &Path,
    shards: usize,
    extra_args: &[String],
    respawn_budget: u32,
) -> Result<()> {
    let exe = std::env::current_exe().context("locating current executable")?;
    spawn_workers_supervised(&exe, dir, shards, extra_args, respawn_budget)
}

/// Stderr capture path for worker `i` (sibling of `sweep.json`, outside
/// `cells/`, so fragments and claims never collide with it).
pub fn worker_log_path(dir: &Path, worker: usize) -> PathBuf {
    dir.join(format!("worker_{worker}.stderr.log"))
}

/// Stderr capture path for worker `i`, respawn generation `gen` (0 =
/// first launch keeps [`worker_log_path`]; each respawn logs to its own
/// file so a post-mortem can read every life of the slot).
pub fn worker_log_path_gen(dir: &Path, worker: usize, gen: u32) -> PathBuf {
    if gen == 0 {
        worker_log_path(dir, worker)
    } else {
        dir.join(format!("worker_{worker}.gen{gen}.stderr.log"))
    }
}

/// Lines of trailing stderr kept in memory per worker for the failure
/// diagnostic (the full stream goes to the log file and to our stderr).
const STDERR_TAIL_LINES: usize = 8;

/// Stream one worker's piped stderr line-by-line to this process's
/// stderr (live progress) and to its log file (post-mortems), keeping a
/// rolling [`STDERR_TAIL_LINES`]-line tail in memory for the failure
/// diagnostic.  An active reader means the pipe can never fill and
/// block the worker, however chatty it is.
fn tee_stderr(stderr: std::process::ChildStderr, log: &Path) -> String {
    use std::collections::VecDeque;
    use std::io::{BufRead, BufReader, Write};
    let mut logf = std::fs::File::create(log).ok();
    let mut tail: VecDeque<String> = VecDeque::with_capacity(STDERR_TAIL_LINES);
    for line in BufReader::new(stderr).lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        eprintln!("{line}");
        if let Some(f) = logf.as_mut() {
            let _ = writeln!(f, "{line}");
        }
        if tail.len() == STDERR_TAIL_LINES {
            tail.pop_front();
        }
        tail.push_back(line);
    }
    tail.into_iter().collect::<Vec<_>>().join("\n")
}

/// [`spawn_workers`] with an explicit worker binary and no respawn
/// budget — kept for integration tests that pin the fail-fast contract
/// (they pass `CARGO_BIN_EXE_repro`; the test binary's own
/// `current_exe` is not a sweep worker).
pub fn spawn_workers_with_exe(
    exe: &Path,
    dir: &Path,
    shards: usize,
    extra_args: &[String],
) -> Result<()> {
    spawn_workers_supervised(exe, dir, shards, extra_args, 0)
}

/// One supervised worker slot.
enum SlotState {
    Running {
        child: std::process::Child,
        tee: std::thread::JoinHandle<String>,
        gen: u32,
    },
    Finished,
}

/// Launch one worker process for `slot` at respawn generation `gen`,
/// wiring its stderr through a [`tee_stderr`] thread.
fn launch_worker(
    exe: &Path,
    dir: &Path,
    slot: usize,
    shards: usize,
    extra_args: &[String],
    gen: u32,
) -> Result<(std::process::Child, std::thread::JoinHandle<String>)> {
    let mut child = std::process::Command::new(exe)
        .arg("sweep-worker")
        .arg("--dir")
        .arg(dir)
        .arg("--shard")
        .arg(format!("{slot}/{shards}"))
        .arg("--worker-slot")
        .arg(slot.to_string())
        .arg("--worker-gen")
        .arg(gen.to_string())
        .args(extra_args)
        .stderr(std::process::Stdio::piped())
        .spawn()
        .with_context(|| format!("spawning sweep worker {slot}/{shards} (gen {gen})"))?;
    let stderr = child
        .stderr
        .take()
        .with_context(|| format!("taking worker {slot} stderr pipe"))?;
    let log = worker_log_path_gen(dir, slot, gen);
    let tee = std::thread::spawn(move || tee_stderr(stderr, &log));
    Ok((child, tee))
}

/// The supervising core behind [`spawn_workers`]: spawn every slot,
/// poll for exits, and respawn a crashed slot (next generation, same
/// shard assignment and passthrough args, plus a bumped `--worker-gen`)
/// while the shared `respawn_budget` lasts.
///
/// Each worker's stderr is piped through a tee thread ([`tee_stderr`]):
/// streamed live to this process's stderr, mirrored to
/// [`worker_log_path_gen`] for post-mortems, and tailed in memory so a
/// failing worker's error reports its **exit status and the last lines
/// of its stderr**, not a bare "worker failed".
///
/// Respawning is always safe: completion state lives in the fragment
/// set, so a respawned worker skips finished cells and at worst reruns
/// the one cell its predecessor died inside (after that cell's lease
/// goes stale).  A crash that outlives the budget fails the sweep with
/// the same exit-status + stderr-tail diagnostic as the fail-fast path
/// — a *deterministic* cell failure therefore still surfaces instead of
/// burning respawns forever.
pub fn spawn_workers_supervised(
    exe: &Path,
    dir: &Path,
    shards: usize,
    extra_args: &[String],
    respawn_budget: u32,
) -> Result<()> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating sweep dir {dir:?}"))?;
    let mut slots = Vec::with_capacity(shards);
    for i in 0..shards {
        let (child, tee) = launch_worker(exe, dir, i, shards, extra_args, 0)?;
        slots.push(SlotState::Running { child, tee, gen: 0 });
    }
    let mut budget = respawn_budget;
    let mut failed: Vec<String> = Vec::new();
    loop {
        let mut progressed = false;
        for i in 0..shards {
            let exited = match &mut slots[i] {
                SlotState::Running { child, .. } => match child.try_wait() {
                    Ok(None) => continue, // still running
                    Ok(Some(status)) => Ok(status),
                    Err(e) => Err(e),
                },
                SlotState::Finished => continue,
            };
            progressed = true;
            let old = std::mem::replace(&mut slots[i], SlotState::Finished);
            let (tee, gen) = match old {
                SlotState::Running { tee, gen, .. } => (tee, gen),
                SlotState::Finished => unreachable!("only Running slots reach here"),
            };
            let tail = tee.join().unwrap_or_default();
            match exited {
                Err(e) => failed.push(format!("worker {i}/{shards}: wait failed: {e}")),
                Ok(status) if status.success() => {}
                Ok(status) => {
                    if budget > 0 {
                        budget -= 1;
                        let next = gen + 1;
                        eprintln!(
                            "sweep supervisor: worker {i}/{shards} (gen {gen}) exited with \
                             {status}; respawning as gen {next} ({budget} respawns left)"
                        );
                        match launch_worker(exe, dir, i, shards, extra_args, next) {
                            Ok((child, tee)) => {
                                // Daemon event hook (no-op without an
                                // installed sink): slot respawn is part
                                // of the observable sweep narrative.
                                crate::daemon::events::worker_respawned(i, next as usize);
                                slots[i] = SlotState::Running { child, tee, gen: next };
                            }
                            Err(e) => failed
                                .push(format!("worker {i}/{shards}: respawn failed: {e:#}")),
                        }
                    } else if tail.is_empty() {
                        failed.push(format!(
                            "worker {i}/{shards} exited with {status} (no stderr output)"
                        ));
                    } else {
                        failed.push(format!(
                            "worker {i}/{shards} exited with {status}; stderr tail:\n{tail}"
                        ));
                    }
                }
            }
        }
        let running = slots
            .iter()
            .any(|s| matches!(s, SlotState::Running { .. }));
        if !running {
            break;
        }
        if !progressed {
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
    }
    if !failed.is_empty() {
        bail!("sweep workers failed:\n{}", failed.join("\n"));
    }
    Ok(())
}

/// Deterministic mock cell runner: a pure FNV-1a hash of the cell's
/// identity.  Backs the orchestration tests and `repro sweep-selftest`,
/// where per-cell determinism makes shard-count byte-identity checkable
/// without an engine or artifacts.
pub fn mock_cell(cell: &Cell) -> Json {
    let key = format!(
        "{}|{}|{}|{}|{}|{}|{}",
        cell.index, cell.variant, cell.task, cell.rho, cell.sketch, cell.seed, cell.batch
    );
    let h = crate::util::fnv::hash(key.bytes());
    Json::obj(vec![
        ("id", Json::str(key)),
        ("score", Json::num((h % 10_000) as f64 / 100.0)),
        ("loss", Json::num(((h >> 16) % 1_000) as f64 / 1_000.0)),
        ("steps", Json::num(((h >> 32) % 500) as f64)),
    ])
}

/// The grid `repro sweep-selftest` and CI's smoke sweep run: 24 mock
/// cells spanning every grid axis.
pub fn selftest_spec() -> SweepSpec {
    let mut spec = SweepSpec::new("mock", crate::config::TrainConfig::default());
    let rhos = [1.0, 0.5, 0.1];
    let sketches = ["gauss", "dct"];
    for (r, &rho) in rhos.iter().enumerate() {
        for t in 0..4usize {
            for (s, &sketch) in sketches.iter().enumerate() {
                spec.push(
                    format!("mock_v{t}_r{r}"),
                    format!("task{t}"),
                    rho,
                    sketch,
                    s as u64,
                    0,
                );
            }
        }
    }
    spec
}

/// The session-layer selftest grid (`repro sweep-selftest --grid data`):
/// `mockdata` cells over real synthetic-GLUE tasks, run through the warm
/// `Session`'s tokenizer + dataset caches and the prefetch pipeline
/// (depth 2) but no engine — so CI can pin warm-vs-cold byte-identity of
/// the session layer with real data generation and no artifacts.  The ρ
/// axis is data-invariant (as in the real Table 2 grid), so cells at the
/// same (task, seed) give the dataset caches genuine cross-cell reuse.
pub fn selftest_data_spec() -> SweepSpec {
    let mut train = crate::config::TrainConfig::default();
    train.prefetch = true;
    train.prefetch_depth = 2;
    let mut spec = SweepSpec::new("mockdata", train);
    for &task in &["wnli", "rte", "mrpc", "stsb"] {
        for &rho in &[1.0f64, 0.5] {
            for seed in 0..2u64 {
                spec.push(format!("data_{task}"), task, rho, "none", seed, 8);
            }
        }
    }
    spec
}

/// The closed-loop controller selftest grid (`repro sweep-selftest
/// --grid budget`): engine-free `budget` cells whose ρ axis carries the
/// per-step memory budget (`--mem-budget`) and whose sketch axis mixes
/// the controller markers ("auto" / "avjp-auto" — the controller picks
/// (family, ρ) per layer-step) with fixed estimator configurations
/// priced at one shared budget.  Probe tensors are Philox-generated from
/// the cell seed inside the runner, so each fragment — including its
/// recorded (family, ρ) choice sequence — is a pure function of the
/// cell; CI runs this grid at `RMM_THREADS` 1 and 4 to pin byte-identity
/// of the controller's decisions across thread and worker counts.
pub fn selftest_budget_spec() -> SweepSpec {
    let mut spec = SweepSpec::new("budget", crate::config::TrainConfig::default());
    for &budget in &[1.0f64, 0.5, 0.2, 0.1] {
        for &axis in &["auto", "avjp-auto"] {
            for seed in 0..2u64 {
                let variant = if axis == "auto" { "ctl_auto" } else { "ctl_avjp" };
                spec.push(variant, "probe", budget, axis, seed, 16);
            }
        }
    }
    // Fixed estimator configurations at one shared budget, so the grid
    // also exercises the equal-budget comparison path of the runner.
    for &est in &["gauss", "wtacrs", "avjp-gauss"] {
        spec.push(format!("est_{est}"), "probe", 0.5, est, 7, 16);
    }
    spec
}

/// Difficulty tiers of the seeded synthetic workload generator.
pub const SYNTH_TIERS: &[&str] = &["easy", "medium", "hard"];

/// Philox stream tag for synth-grid composition draws (tags 0–3 are
/// reserved by the RMM sketch/data streams; see `rng::philox`).
const SYNTH_STREAM: u32 = 7;

/// Seeded, difficulty-graded synthetic workload grid (experiment key
/// `synth-<tier>`) — the chaos harness's stress surface, grown out of
/// the PR 5 `mockdata` grid.  The grid's composition — cell count,
/// variant/task mix, ρ, and batch (data-shape) axes — is a pure
/// function of `(seed, tier)` via Philox draws, and every cell carries
/// a deterministic *planned cost* ([`synth_cost_ms`]) with
/// tier-controlled skew, so dynamic scheduling, affinity, and stealing
/// face meaningfully uneven work while the merged report stays a pure
/// function of the grid.
///
/// * `easy` — small grid, near-uniform cell costs.
/// * `medium` — mid-size grid, moderate cost skew.
/// * `hard` — large grid with a heavy-tailed cost distribution: a few
///   whale cells dominate, the worst case for static sharding and the
///   best case for work stealing.
pub fn synth_spec(seed: u64, tier: &str) -> Result<SweepSpec> {
    use crate::rng::philox::PhiloxStream;
    let (variants, tasks, rhos, n_cells): (u32, u32, &[f64], usize) = match tier {
        "easy" => (2, 2, &[1.0, 0.5], 8),
        "medium" => (3, 3, &[1.0, 0.5, 0.1], 18),
        "hard" => (4, 4, &[1.0, 0.5, 0.2, 0.1], 36),
        other => bail!("unknown synth tier '{other}' (easy|medium|hard)"),
    };
    let mut rng = PhiloxStream::new(seed, SYNTH_STREAM);
    let mut spec = SweepSpec::new(
        format!("synth-{tier}"),
        crate::config::TrainConfig::default(),
    );
    for _ in 0..n_cells {
        let v = rng.next_below(variants);
        let t = rng.next_below(tasks);
        let rho = rhos[rng.next_below(rhos.len() as u32) as usize];
        // Data-shape axis: batch 4 / 8 / 16.
        let batch = 4usize << rng.next_below(3);
        let cell_seed = rng.next_below(1 << 16) as u64;
        spec.push(
            format!("synth_v{v}"),
            format!("synth_t{t}"),
            rho,
            "gauss",
            cell_seed,
            batch,
        );
    }
    Ok(spec)
}

/// Planned cost of a synth cell in ms — deterministic in the cell's
/// identity, with tier-controlled skew: `hard` grids are heavy-tailed
/// (whale cells several times the base cost) precisely to stress
/// straggler handling under chaos.  The cost only shapes wall time
/// (the runner sleeps it); it never feeds measured time into the
/// fragment, so reports stay schedule-invariant.
pub fn synth_cost_ms(experiment: &str, cell: &Cell) -> u64 {
    let (base, whale): (u64, u64) = match experiment {
        "synth-easy" => (3, 1),   // near-uniform
        "synth-medium" => (8, 4), // moderate skew
        _ => (12, 8),             // synth-hard: heavy tail
    };
    let h = crate::util::fnv::hash(
        format!("cost|{experiment}|{}|{}", cell.index, cell.seed).bytes(),
    );
    let cost = h % base;
    if h % 7 == 0 {
        cost * whale
    } else {
        cost
    }
}

/// Deterministic synth cell result: the [`mock_cell`] FNV payload plus
/// the cell's *planned* cost — a pure function of identity, never
/// measured wall time, which would break byte-identity.
pub fn synth_cell(experiment: &str, cell: &Cell) -> Json {
    let mut j = mock_cell(cell);
    if let Json::Obj(map) = &mut j {
        map.insert(
            "planned_cost_ms".to_string(),
            Json::num(synth_cost_ms(experiment, cell) as f64),
        );
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_cell_is_deterministic_and_finite() {
        let spec = selftest_spec();
        for cell in &spec.cells {
            let a = mock_cell(cell);
            let b = mock_cell(cell);
            assert_eq!(a, b);
            let s = a.to_string_pretty();
            assert!(!s.contains("NaN") && !s.contains("inf"), "{s}");
        }
        // distinct cells produce distinct results
        assert_ne!(mock_cell(&spec.cells[0]), mock_cell(&spec.cells[1]));
    }

    #[test]
    fn selftest_grid_covers_all_axes() {
        let spec = selftest_spec();
        assert_eq!(spec.cells.len(), 24);
        assert_eq!(spec.experiment, "mock");
        assert!(spec.cells.iter().any(|c| c.sketch == "dct"));
        assert!(spec.cells.iter().any(|c| (c.rho - 0.1).abs() < 1e-12));
    }

    #[test]
    fn selftest_data_grid_is_valid_and_reuses_tasks() {
        let spec = selftest_data_spec();
        assert_eq!(spec.experiment, "mockdata");
        assert!(spec.train.prefetch && spec.train.prefetch_depth > 1);
        // every task name must parse (the runner dispatches on it) …
        for cell in &spec.cells {
            assert!(
                crate::data::Task::parse(&cell.task).is_some(),
                "unparseable task '{}'",
                cell.task
            );
            assert!(cell.batch > 0, "data cells must carry a batch size");
        }
        // … and repeat across cells, so the session caches see reuse
        let distinct: std::collections::BTreeSet<&str> =
            spec.cells.iter().map(|c| c.task.as_str()).collect();
        assert!(distinct.len() < spec.cells.len());
        // the JSON round-trip the workers rely on
        let back = SweepSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.cells, spec.cells);
        assert_eq!(back.train, spec.train);
    }

    #[test]
    fn selftest_budget_grid_is_valid_and_round_trips() {
        let spec = selftest_budget_spec();
        assert_eq!(spec.experiment, "budget");
        // both controller modes, several budgets, plus fixed estimators
        assert!(spec.cells.iter().any(|c| c.sketch == "auto"));
        assert!(spec.cells.iter().any(|c| c.sketch == "avjp-auto"));
        assert!(spec.cells.iter().any(|c| c.sketch == "wtacrs"));
        assert!(spec.cells.iter().any(|c| c.sketch == "avjp-gauss"));
        let budgets: std::collections::BTreeSet<u64> =
            spec.cells.iter().map(|c| c.rho.to_bits()).collect();
        assert!(budgets.len() >= 4, "budget axis collapsed");
        for cell in &spec.cells {
            assert!(cell.batch > 0, "budget cells must carry probe rows");
        }
        // the JSON round-trip the workers rely on (also proves every
        // sketch-axis string passes Cell::validate_axes)
        let back = SweepSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.cells, spec.cells);
    }

    #[test]
    fn run_shard_skips_completed_cells() {
        let dir = std::env::temp_dir()
            .join(format!("rmm_sweep_mod_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spec = selftest_spec();
        resume::prepare(&dir, &spec, false).unwrap();
        let ran = run_shard(&dir, &spec, Shard::SERIAL, &mut |c, _| Ok(mock_cell(c)))
            .unwrap();
        assert_eq!(ran, spec.cells.len());
        // second pass: everything already committed
        let mut reran = 0usize;
        let ran = run_shard(&dir, &spec, Shard::SERIAL, &mut |c, _| {
            reran += 1;
            Ok(mock_cell(c))
        })
        .unwrap();
        assert_eq!(reran, 0, "must not rerun completed cells");
        assert_eq!(ran, 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn cell_ctx_without_lease_ticks_as_noop() {
        let ctx = CellCtx::none();
        assert!(!ctx.has_heartbeat());
        ctx.tick(); // must not panic or touch the filesystem
    }

    #[test]
    fn synth_grids_are_seeded_tiered_and_round_trip() {
        let mut sizes = Vec::new();
        for &tier in SYNTH_TIERS {
            let a = synth_spec(11, tier).unwrap();
            let b = synth_spec(11, tier).unwrap();
            assert_eq!(a.cells, b.cells, "synth-{tier} not reproducible");
            assert_eq!(a.experiment, format!("synth-{tier}"));
            sizes.push(a.cells.len());
            // a different seed reshuffles the grid composition
            let c = synth_spec(12, tier).unwrap();
            assert_ne!(a.cells, c.cells, "synth-{tier} ignores the seed");
            // the JSON round-trip the workers rely on
            let back = SweepSpec::from_json(&a.to_json()).unwrap();
            assert_eq!(back.cells, a.cells);
        }
        assert!(sizes[0] < sizes[1] && sizes[1] < sizes[2], "{sizes:?}");
        assert!(synth_spec(0, "impossible").is_err());
    }

    #[test]
    fn synth_costs_are_deterministic_skewed_and_kept_out_of_results() {
        let spec = synth_spec(11, "hard").unwrap();
        let costs: Vec<u64> = spec
            .cells
            .iter()
            .map(|c| synth_cost_ms(&spec.experiment, c))
            .collect();
        assert_eq!(
            costs,
            spec.cells
                .iter()
                .map(|c| synth_cost_ms(&spec.experiment, c))
                .collect::<Vec<u64>>()
        );
        // the hard tier's tail must actually be skewed, but bounded
        let max = *costs.iter().max().unwrap();
        let min = *costs.iter().min().unwrap();
        assert!(max > min, "hard tier costs degenerate: {costs:?}");
        assert!(max < 200, "whale cost {max} too large for CI");
        // the result embeds the *planned* cost, not a measured one
        let r = synth_cell(&spec.experiment, &spec.cells[0]);
        assert_eq!(r.get("planned_cost_ms"), &Json::num(costs[0] as f64));
        assert_eq!(r, synth_cell(&spec.experiment, &spec.cells[0]));
    }
}
