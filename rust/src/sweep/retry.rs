//! Bounded retry with deterministic jittered backoff for transient
//! claim-store IO.
//!
//! The claim/lease protocol runs over a possibly-shared (network)
//! mount, where flakiness surfaces as transient `io::Error`s (EINTR,
//! EAGAIN, timeouts) on the create-exclusive open, heartbeat re-stamp,
//! reclaim rename, or fragment staging write.  Before this module, any
//! such error outside `AlreadyExists` in `try_claim` aborted the
//! worker; wrapped in [`io_retry`], a flaky mount degrades to latency
//! instead of a dead worker.  Genuinely fatal kinds (permission
//! denied, disk full, …) still fail on first sight.
//!
//! The backoff jitter is *deterministic* — an FNV hash of
//! `(label, attempt)` — so retries never introduce nondeterminism into
//! anything observable, while distinct labels (worker ids, cell
//! indices) desynchronize workers hammering the same claim store.
//! This is also what makes the chaos harness's injected transient
//! errors (`crate::chaos`) replayable: the fault is placed *inside*
//! the retried closure, one attempt consumes it, and the next attempt
//! proceeds at a schedule-independent delay.

use std::io;
use std::time::Duration;

use crate::util::fnv;

/// Total attempts per op: 1 initial + up to `MAX_ATTEMPTS - 1`
/// retries.  Worst-case added latency is ~`2^MAX_ATTEMPTS` ms — well
/// under any lease TTL, so retrying never costs a claim.
pub const MAX_ATTEMPTS: u32 = 4;

/// Error kinds worth re-issuing the op for.  `AlreadyExists` is
/// deliberately absent: for the create-exclusive claim open it is the
/// protocol's "lost the race" signal, not an error.
pub fn is_transient(kind: io::ErrorKind) -> bool {
    matches!(
        kind,
        io::ErrorKind::Interrupted | io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
    )
}

/// Backoff before retry number `attempt` (0-based): base `2^attempt`
/// ms plus an FNV-derived jitter in `[0, base)` keyed by the label.
pub fn backoff(label: &str, attempt: u32) -> Duration {
    let base = 1u64 << attempt.min(5);
    let jitter = fnv::hash(label.bytes().chain(attempt.to_le_bytes())) % base;
    Duration::from_millis(base + jitter)
}

/// Run `op`, retrying transient IO errors up to [`MAX_ATTEMPTS`] total
/// attempts with [`backoff`] sleeps in between.  `label` keys the
/// jitter — embed something per-call-site-unique (worker id, index).
pub fn io_retry<T>(label: &str, op: impl FnMut() -> io::Result<T>) -> io::Result<T> {
    io_retry_n(label, MAX_ATTEMPTS, op)
}

/// [`io_retry`] with an explicit total-attempt budget.  `attempts <= 1`
/// means exactly one attempt: `op` runs once and *any* error — even a
/// transient kind — propagates unchanged.  On exhaustion the error
/// returned is the one from the **last** attempt (each retry replaces
/// the previous error, so the caller sees the freshest failure).
pub fn io_retry_n<T>(
    label: &str,
    attempts: u32,
    mut op: impl FnMut() -> io::Result<T>,
) -> io::Result<T> {
    let mut attempt = 0u32;
    loop {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) if is_transient(e.kind()) && attempt + 1 < attempts => {
                std::thread::sleep(backoff(label, attempt));
                attempt += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flaky(failures: usize, kind: io::ErrorKind) -> impl FnMut() -> io::Result<u32> {
        let mut left = failures;
        move || {
            if left > 0 {
                left -= 1;
                Err(io::Error::new(kind, "flaky"))
            } else {
                Ok(7)
            }
        }
    }

    #[test]
    fn transient_errors_heal_within_the_budget() {
        let got = io_retry("t", flaky(MAX_ATTEMPTS as usize - 1, io::ErrorKind::Interrupted));
        assert_eq!(got.unwrap(), 7);
    }

    #[test]
    fn exhausting_the_budget_propagates_the_last_error() {
        let got = io_retry("t", flaky(MAX_ATTEMPTS as usize, io::ErrorKind::TimedOut));
        assert_eq!(got.unwrap_err().kind(), io::ErrorKind::TimedOut);
    }

    #[test]
    fn exhaustion_returns_the_last_attempts_error_instance() {
        // Each attempt fails with a *distinct* error; the caller must
        // see the final one, not the first (the freshest diagnosis of
        // a persistently flaky mount).
        let mut calls = 0u32;
        let got: io::Result<()> = io_retry("t", || {
            calls += 1;
            Err(io::Error::new(io::ErrorKind::TimedOut, format!("attempt {calls}")))
        });
        let e = got.unwrap_err();
        assert_eq!(calls, MAX_ATTEMPTS);
        assert_eq!(e.kind(), io::ErrorKind::TimedOut);
        assert_eq!(e.to_string(), format!("attempt {MAX_ATTEMPTS}"));
    }

    #[test]
    fn zero_budget_runs_exactly_once_and_propagates_any_error() {
        for attempts in [0u32, 1] {
            let mut calls = 0u32;
            let got: io::Result<()> = io_retry_n("t", attempts, || {
                calls += 1;
                Err(io::Error::new(io::ErrorKind::Interrupted, "transient"))
            });
            assert_eq!(calls, 1, "attempts={attempts} must not retry");
            assert_eq!(got.unwrap_err().kind(), io::ErrorKind::Interrupted);
        }
        // And a success on the single allowed attempt still succeeds.
        assert_eq!(io_retry_n("t", 1, || Ok(3)).unwrap(), 3);
    }

    #[test]
    fn explicit_budgets_scale_the_healing_window() {
        let got = io_retry_n("t", 6, flaky(5, io::ErrorKind::WouldBlock));
        assert_eq!(got.unwrap(), 7);
        let got = io_retry_n("t", 5, flaky(5, io::ErrorKind::WouldBlock));
        assert_eq!(got.unwrap_err().kind(), io::ErrorKind::WouldBlock);
    }

    #[test]
    fn non_transient_errors_fail_on_first_sight() {
        let mut calls = 0u32;
        let got: io::Result<()> = io_retry("t", || {
            calls += 1;
            Err(io::Error::new(io::ErrorKind::PermissionDenied, "fatal"))
        });
        assert_eq!(got.unwrap_err().kind(), io::ErrorKind::PermissionDenied);
        assert_eq!(calls, 1);
    }

    #[test]
    fn backoff_is_deterministic_bounded_and_label_keyed() {
        for attempt in 0..MAX_ATTEMPTS {
            let base = 1u64 << attempt.min(5);
            let d = backoff("worker-1:3", attempt);
            assert_eq!(d, backoff("worker-1:3", attempt));
            assert!(d.as_millis() as u64 >= base);
            assert!((d.as_millis() as u64) < 2 * base);
        }
        // The jitter is keyed by (label, attempt) through FNV: a
        // changed label reseeds the whole sequence deterministically.
        let a: Vec<_> = (0..4).map(|i| backoff("w-a", i)).collect();
        assert_eq!(a, (0..4).map(|i| backoff("w-a", i)).collect::<Vec<_>>());
    }
}
