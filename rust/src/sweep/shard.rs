//! Static shard assignment: the `--shard i/N` contract.
//!
//! Cells are assigned to shards round-robin on the canonical cell index
//! (`cell.index % N == i`).  The assignment is a pure function of the
//! grid, so the orchestrator never has to communicate a work list to a
//! worker — the spec plus `i/N` fully determines what a worker runs, and
//! any two workers' cell sets are disjoint by construction.
//!
//! This is the `--schedule static` fallback (and default): zero
//! coordination, but skewed cell costs can leave stragglers.  The
//! dynamic claim/lease scheduler (`super::scheduler`) trades a shared
//! claim store for balanced pulls; both produce the same fragment set
//! and therefore byte-identical merged reports.

use std::fmt;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    /// This shard's position, `0 <= index < of`.
    pub index: usize,
    /// Total shard count, `>= 1`.
    pub of: usize,
}

impl Shard {
    /// The single-shard (serial) assignment: owns every cell.
    pub const SERIAL: Shard = Shard { index: 0, of: 1 };

    pub fn new(index: usize, of: usize) -> Result<Shard> {
        if of == 0 {
            bail!("shard count must be >= 1");
        }
        if index >= of {
            bail!("shard index {index} out of range for {of} shards");
        }
        Ok(Shard { index, of })
    }

    /// Parse the CLI form "i/N".
    pub fn parse(s: &str) -> Result<Shard> {
        let (i, n) = s
            .split_once('/')
            .with_context(|| format!("shard '{s}' must be of the form i/N"))?;
        let index: usize = i
            .trim()
            .parse()
            .with_context(|| format!("bad shard index in '{s}'"))?;
        let of: usize = n
            .trim()
            .parse()
            .with_context(|| format!("bad shard count in '{s}'"))?;
        Shard::new(index, of)
    }

    /// Does this shard own the cell at `cell_index`?
    pub fn owns(&self, cell_index: usize) -> bool {
        cell_index % self.of == self.index
    }
}

impl fmt::Display for Shard {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}", self.index, self.of)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips() {
        let s = Shard::parse("2/5").unwrap();
        assert_eq!(s, Shard { index: 2, of: 5 });
        assert_eq!(s.to_string(), "2/5");
        assert_eq!(Shard::parse("0/1").unwrap(), Shard::SERIAL);
    }

    #[test]
    fn parse_rejects_bad_forms() {
        for bad in ["", "3", "a/b", "2/2", "5/3", "1/0", "-1/2"] {
            assert!(Shard::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn shards_partition_every_cell_exactly_once() {
        for of in [1usize, 2, 3, 7] {
            for cell in 0..100 {
                let owners = (0..of)
                    .filter(|&i| Shard { index: i, of }.owns(cell))
                    .count();
                assert_eq!(owners, 1, "cell {cell} of {of} shards");
            }
        }
    }

    #[test]
    fn serial_owns_everything() {
        assert!((0..50).all(|c| Shard::SERIAL.owns(c)));
    }
}
