//! Static shard assignment: the `--shard i/N` contract.
//!
//! Cells are assigned to shards round-robin on the canonical cell index
//! (`cell.index % N == i`).  The assignment is a pure function of the
//! grid, so the orchestrator never has to communicate a work list to a
//! worker — the spec plus `i/N` fully determines what a worker runs, and
//! any two workers' cell sets are disjoint by construction.
//!
//! This is the `--schedule static` fallback (and default): zero
//! coordination, but skewed cell costs can leave stragglers.  The
//! dynamic claim/lease scheduler (`super::scheduler`) trades a shared
//! claim store for balanced pulls; both produce the same fragment set
//! and therefore byte-identical merged reports.
//!
//! For schedulers that cannot share a mount at all — so neither the
//! dynamic claim store nor its affinity-preferring claim order is
//! available — [`affinity_assignment`] computes a static cell→shard map
//! that co-locates same-[`Cell::affinity_key`] cells on one shard, so
//! every worker still reuses its warm `Session` state (compiled
//! executables, trainer setups, dataset caches) across its whole
//! assignment.  Like `index % N`, it is a pure function of the grid:
//! every host computes the identical map from `sweep.json` alone.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Context, Result};

use super::grid::SweepSpec;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    /// This shard's position, `0 <= index < of`.
    pub index: usize,
    /// Total shard count, `>= 1`.
    pub of: usize,
}

impl Shard {
    /// The single-shard (serial) assignment: owns every cell.
    pub const SERIAL: Shard = Shard { index: 0, of: 1 };

    pub fn new(index: usize, of: usize) -> Result<Shard> {
        if of == 0 {
            bail!("shard count must be >= 1");
        }
        if index >= of {
            bail!("shard index {index} out of range for {of} shards");
        }
        Ok(Shard { index, of })
    }

    /// Parse the CLI form "i/N".
    pub fn parse(s: &str) -> Result<Shard> {
        let (i, n) = s
            .split_once('/')
            .with_context(|| format!("shard '{s}' must be of the form i/N"))?;
        let index: usize = i
            .trim()
            .parse()
            .with_context(|| format!("bad shard index in '{s}'"))?;
        let of: usize = n
            .trim()
            .parse()
            .with_context(|| format!("bad shard count in '{s}'"))?;
        Shard::new(index, of)
    }

    /// Does this shard own the cell at `cell_index`?
    pub fn owns(&self, cell_index: usize) -> bool {
        cell_index % self.of == self.index
    }
}

impl fmt::Display for Shard {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}", self.index, self.of)
    }
}

/// Affinity-aware static cell→shard map: returns `assignment` with
/// `assignment[cell.index]` = owning shard, for `of` shards.
///
/// Cells sharing an [`affinity_key`] (variant, task) are always
/// co-located on one shard, so a mount-less static worker reuses its
/// warm session state across its whole assignment instead of paying
/// cold start per interleaved cell.  Groups are placed largest-first
/// onto the currently lightest shard (ties broken by first-appearance
/// order, then lowest shard index) — the classic LPT greedy, fully
/// deterministic, so every host derives the identical map from
/// `sweep.json` alone.  Like `index % N` this only decides *who runs
/// what*: the fragment set, and therefore the merged report, is
/// unchanged.
///
/// [`affinity_key`]: super::grid::Cell::affinity_key
pub fn affinity_assignment(spec: &SweepSpec, of: usize) -> Vec<usize> {
    let of = of.max(1);
    // Group cell indices by affinity key, remembering each group's
    // first appearance in canonical order for deterministic tie-breaks.
    let mut groups: BTreeMap<(String, String), Vec<usize>> = BTreeMap::new();
    for cell in &spec.cells {
        let (v, t) = cell.affinity_key();
        groups.entry((v.to_string(), t.to_string())).or_default().push(cell.index);
    }
    let mut ordered: Vec<Vec<usize>> = groups.into_values().collect();
    // Largest group first; equal sizes by first cell index (canonical
    // appearance), so the sort is a total deterministic order.
    ordered.sort_by_key(|g| (std::cmp::Reverse(g.len()), g[0]));
    let mut load = vec![0usize; of];
    let mut assignment = vec![0usize; spec.cells.len()];
    for group in ordered {
        let shard = (0..of).min_by_key(|&s| (load[s], s)).unwrap_or(0);
        load[shard] += group.len();
        for i in group {
            assignment[i] = shard;
        }
    }
    assignment
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips() {
        let s = Shard::parse("2/5").unwrap();
        assert_eq!(s, Shard { index: 2, of: 5 });
        assert_eq!(s.to_string(), "2/5");
        assert_eq!(Shard::parse("0/1").unwrap(), Shard::SERIAL);
    }

    #[test]
    fn parse_rejects_bad_forms() {
        for bad in ["", "3", "a/b", "2/2", "5/3", "1/0", "-1/2"] {
            assert!(Shard::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn shards_partition_every_cell_exactly_once() {
        for of in [1usize, 2, 3, 7] {
            for cell in 0..100 {
                let owners = (0..of)
                    .filter(|&i| Shard { index: i, of }.owns(cell))
                    .count();
                assert_eq!(owners, 1, "cell {cell} of {of} shards");
            }
        }
    }

    #[test]
    fn serial_owns_everything() {
        assert!((0..50).all(|c| Shard::SERIAL.owns(c)));
    }

    fn affinity_spec() -> SweepSpec {
        let mut spec =
            SweepSpec::new("mock", crate::config::TrainConfig::default());
        // 3 variants × 2 tasks × 3 seeds, interleaved so `index % N`
        // would scatter every affinity group across all shards
        for seed in 0..3u64 {
            for v in ["A", "B", "C"] {
                for t in ["t0", "t1"] {
                    spec.push(v, t, 1.0, "gauss", seed, 0);
                }
            }
        }
        spec
    }

    #[test]
    fn affinity_assignment_partitions_exactly_once_and_colocates_keys() {
        let spec = affinity_spec();
        for of in [1usize, 2, 3, 7] {
            let assignment = affinity_assignment(&spec, of);
            assert_eq!(assignment.len(), spec.cells.len());
            // every cell is owned by exactly one in-range shard
            assert!(assignment.iter().all(|&s| s < of), "{of} shards");
            // same-key cells always share a shard
            let mut owner: std::collections::HashMap<(&str, &str), usize> =
                std::collections::HashMap::new();
            for cell in &spec.cells {
                let s = assignment[cell.index];
                let prev = owner.entry(cell.affinity_key()).or_insert(s);
                assert_eq!(*prev, s, "{:?} split across shards", cell.affinity_key());
            }
        }
    }

    #[test]
    fn affinity_assignment_balances_group_counts() {
        let spec = affinity_spec(); // 6 groups of 3 cells
        let assignment = affinity_assignment(&spec, 3);
        let mut load = [0usize; 3];
        for &s in &assignment {
            load[s] += 1;
        }
        assert_eq!(load, [6, 6, 6], "6 equal groups over 3 shards must balance");
        // degenerate shard counts: everything on shard 0
        assert!(affinity_assignment(&spec, 1).iter().all(|&s| s == 0));
        assert!(affinity_assignment(&spec, 0).iter().all(|&s| s == 0));
        // determinism: recomputation is identical (every host agrees)
        assert_eq!(assignment, affinity_assignment(&spec, 3));
    }
}
