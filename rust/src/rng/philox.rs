//! Philox4x32-10 counter-based PRNG — bit-identical to the jnp version in
//! `python/compile/kernels/prng.py` (both are pinned to the Random123
//! reference vectors).  Counter-based means any element of any random
//! stream is O(1) addressable, which is what lets the sketch matrix S be
//! "stored" as a 64-bit seed.

pub const PHILOX_M0: u32 = 0xD251_1F53;
pub const PHILOX_M1: u32 = 0xCD9E_8D57;
pub const PHILOX_W0: u32 = 0x9E37_79B9;
pub const PHILOX_W1: u32 = 0xBB67_AE85;

/// Stream tags, shared with the python side (prng.py).
pub const STREAM_SKETCH: u32 = 0;
pub const STREAM_ROWSEL: u32 = 1;
pub const STREAM_SIGNS: u32 = 2;
pub const STREAM_DATA: u32 = 3;
/// WTA-CRS winner permutation + complement draws (rust-only family; the
/// synthetic sweep grid uses stream 7 — keep new tags clear of it).
pub const STREAM_WTA: u32 = 4;

#[inline]
fn mulhilo(a: u32, b: u32) -> (u32, u32) {
    let p = (a as u64) * (b as u64);
    ((p >> 32) as u32, p as u32)
}

/// One Philox4x32 block: counter (c0..c3) + key (k0, k1) -> 4 u32 words.
#[inline]
pub fn philox4x32(mut c: [u32; 4], mut k: [u32; 2]) -> [u32; 4] {
    for r in 0..10 {
        let (hi0, lo0) = mulhilo(PHILOX_M0, c[0]);
        let (hi1, lo1) = mulhilo(PHILOX_M1, c[2]);
        c = [hi1 ^ c[1] ^ k[0], lo1, hi0 ^ c[3] ^ k[1], lo0];
        if r != 9 {
            k[0] = k[0].wrapping_add(PHILOX_W0);
            k[1] = k[1].wrapping_add(PHILOX_W1);
        }
    }
    c
}

/// The element-addressed form used for sketch matrices: counter encodes
/// (i, j, stream, 0), key is the 64-bit seed.
#[inline]
pub fn element_words(i: u32, j: u32, seed: (u32, u32), stream: u32) -> [u32; 4] {
    philox4x32([i, j, stream, 0], [seed.0, seed.1])
}

/// A convenient sequential stream over Philox blocks (for host-side data
/// generation where element addressing is unnecessary).
pub struct PhiloxStream {
    key: [u32; 2],
    counter: u64,
    buf: [u32; 4],
    pos: usize,
    stream: u32,
}

impl PhiloxStream {
    pub fn new(seed: u64, stream: u32) -> Self {
        Self {
            key: [seed as u32, (seed >> 32) as u32],
            counter: 0,
            buf: [0; 4],
            pos: 4,
            stream,
        }
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        if self.pos == 4 {
            self.buf = philox4x32(
                [
                    self.counter as u32,
                    (self.counter >> 32) as u32,
                    self.stream,
                    1, // sequential-mode marker: disjoint from element mode (c3 = 0)
                ],
                self.key,
            );
            self.counter += 1;
            self.pos = 0;
        }
        let v = self.buf[self.pos];
        self.pos += 1;
        v
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        (self.next_u32() as u64) << 32 | self.next_u32() as u64
    }

    /// Uniform in [0, bound) via the multiply-shift trick (negligible bias).
    #[inline]
    pub fn next_below(&mut self, bound: u32) -> u32 {
        (((self.next_u32() as u64) * (bound as u64)) >> 32) as u32
    }

    /// Uniform in the open interval (0, 1), top-24-bit construction —
    /// matches prng.uniform01 on the python side.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        uniform01(self.next_u32())
    }

    /// Standard normal via Box-Muller.
    #[inline]
    pub fn next_normal(&mut self) -> f32 {
        let a = self.next_u32();
        let b = self.next_u32();
        normal_pair(a, b).0
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// u32 -> f32 uniform in (0, 1); identical construction to the jnp side.
#[inline]
pub fn uniform01(bits: u32) -> f32 {
    ((bits >> 8) as f32 + 0.5) * (1.0 / (1 << 24) as f32)
}

/// Box-Muller: two u32 words -> two standard normals.
#[inline]
pub fn normal_pair(a: u32, b: u32) -> (f32, f32) {
    let u1 = uniform01(a);
    let u2 = uniform01(b);
    let r = (-2.0 * u1.ln()).sqrt();
    let theta = 2.0 * std::f32::consts::PI * u2;
    (r * theta.cos(), r * theta.sin())
}

/// Sketch-element draws, bit-compatible with prng.element_normal /
/// element_rademacher / element_uniform_int on the python side.
/// (A pair-mapped variant was tried and reverted — see EXPERIMENTS.md
/// §Perf iteration 1 — to keep the mapping identical to the lowered HLO.)
#[inline]
pub fn element_normal(i: u32, j: u32, seed: (u32, u32), stream: u32) -> f32 {
    let w = element_words(i, j, seed, stream);
    normal_pair(w[0], w[1]).0
}

#[inline]
pub fn element_rademacher(i: u32, j: u32, seed: (u32, u32), stream: u32) -> f32 {
    let w = element_words(i, j, seed, stream);
    if w[0] & 1 == 1 {
        1.0
    } else {
        -1.0
    }
}

#[inline]
pub fn element_uniform_int(
    i: u32,
    j: u32,
    seed: (u32, u32),
    bound: u32,
    stream: u32,
) -> u32 {
    let w = element_words(i, j, seed, stream);
    (((w[0] as u64) * (bound as u64)) >> 32) as u32
}

/// Split a 64-bit seed into the (lo, hi) pair used as the Philox key.
#[inline]
pub fn split_seed(seed: u64) -> (u32, u32) {
    (seed as u32, (seed >> 32) as u32)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Random123 reference vectors (Salmon et al., SC'11) — the same three
    /// pinned on the python side in test_prng.py.
    #[test]
    fn reference_vectors() {
        assert_eq!(
            philox4x32([0; 4], [0; 2]),
            [0x6627_e8d5, 0xe169_c58d, 0xbc57_ac4c, 0x9b00_dbd8]
        );
        assert_eq!(
            philox4x32([u32::MAX; 4], [u32::MAX; 2]),
            [0x408f_276d, 0x41c8_3b0e, 0xa20b_c7c6, 0x6d54_51fd]
        );
        assert_eq!(
            philox4x32(
                [0x243f_6a88, 0x85a3_08d3, 0x1319_8a2e, 0x0370_7344],
                [0xa409_3822, 0x299f_31d0]
            ),
            [0xd16c_fe09, 0x94fd_cceb, 0x5001_e420, 0x2412_6ea1]
        );
    }

    #[test]
    fn stream_determinism() {
        let mut a = PhiloxStream::new(42, STREAM_DATA);
        let mut b = PhiloxStream::new(42, STREAM_DATA);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn stream_seed_sensitivity() {
        let mut a = PhiloxStream::new(1, STREAM_DATA);
        let mut b = PhiloxStream::new(2, STREAM_DATA);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn normal_moments() {
        let mut s = PhiloxStream::new(7, STREAM_DATA);
        let n = 40_000;
        let xs: Vec<f32> = (0..n).map(|_| s.next_normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn next_below_is_in_range_and_uniformish() {
        let mut s = PhiloxStream::new(9, STREAM_DATA);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[s.next_below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut s = PhiloxStream::new(3, STREAM_DATA);
        let mut v: Vec<u32> = (0..100).collect();
        s.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn element_mode_disjoint_from_sequential() {
        // same seed: element draws and stream draws must not collide
        let w1 = element_words(0, 0, (5, 0), STREAM_SKETCH);
        let mut s = PhiloxStream::new(5, STREAM_SKETCH);
        let w2 = [s.next_u32(), s.next_u32(), s.next_u32(), s.next_u32()];
        assert_ne!(w1, w2);
    }

    #[test]
    fn split_seed_roundtrip() {
        assert_eq!(split_seed(0x1234_5678_90AB_CDEF), (0x90AB_CDEF, 0x1234_5678));
    }
}
