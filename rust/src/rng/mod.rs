//! Deterministic randomness substrate (Philox4x32-10, counter-based).
//!
//! Everything random in the Rust layer — synthetic data generation, splits,
//! host-side sketches, property-test case generation — flows through this
//! module, so every run is exactly reproducible from (seed, stream) and
//! bit-compatible with the Python/Pallas side where streams are shared.

pub mod philox;

pub use philox::{split_seed, PhiloxStream};
