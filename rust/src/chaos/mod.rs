//! Deterministic fault injection for the sweep orchestration stack.
//!
//! The claim/lease/resume/session contract promises that the merged
//! sweep report is a pure function of the fragment set under any
//! crash, race, or cache state.  This module turns that promise into a
//! fuzzable property: a seed compiles into a reproducible **chaos
//! schedule** ([`schedule`]) of kills, corruptions, transient IO
//! errors, clock skew, and delays, delivered through named **fault
//! points** threaded through `sweep::claim`, `sweep::scheduler`,
//! `sweep::resume`, `sweep::merge`, and the session layer.
//!
//! Fault points are zero-cost when chaos is off: every entry is a
//! single relaxed atomic load.  When a schedule is [`install`]ed, each
//! point keeps a process-local hit counter; the scheduled `(point,
//! hit)` pairs fire in op-count order, so a worker replays the
//! identical fault sequence at identical local op counts regardless of
//! how other workers interleave with it.  The fired-fault log
//! ([`fired`], also mirrored to stderr when verbose) is what tests pin
//! replay identity against.
//!
//! Installation is process-global (one schedule per worker process,
//! matching the one-schedule-per-slot model).  In-process tests that
//! install chaos must serialize on a lock — see `tests/prop_chaos.rs`;
//! library unit tests never install.

mod schedule;

pub use schedule::{
    compile, parse_schedule, validate_profile, FaultAction, FaultSpec, DEFAULT_PROFILE, POINTS,
    PROFILES,
};

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::Mutex;

use anyhow::Result;

/// Exit code a chaos [`FaultAction::Kill`] terminates a worker process
/// with — distinguishable from ordinary failures in supervisor logs
/// and respawn accounting.
pub const KILL_EXIT_CODE: i32 = 86;

static ENABLED: AtomicBool = AtomicBool::new(false);
static SKEW_MS: AtomicI64 = AtomicI64::new(0);
static STATE: Mutex<Option<State>> = Mutex::new(None);

struct State {
    entries: Vec<FaultSpec>,
    counts: BTreeMap<String, u64>,
    fired: Vec<String>,
    slot: usize,
    generation: u32,
    exit_on_kill: bool,
    verbose: bool,
}

/// How chaos is installed into a process.
#[derive(Debug, Clone)]
pub struct InstallOpts {
    pub seed: u64,
    /// Named profile (`light`|`crash`|`heavy`) or, if it contains `@`,
    /// an explicit schedule in the grammar (see [`schedule`] docs).
    pub profile: String,
    /// Worker slot: the orchestrator's spawn index, also the Philox
    /// stream tag, so every slot draws an independent schedule.
    pub slot: usize,
    /// Respawn generation, 0 = first launch.  Kill faults are filtered
    /// out at generation > 0 — a kill fires once per worker slot — so
    /// a respawned worker replaying the same schedule does not kill
    /// itself at the same hit count forever.
    pub generation: u32,
    /// Kill semantics: worker processes `exit(`[`KILL_EXIT_CODE`]`)`,
    /// skipping every `Drop` exactly like SIGKILL, so held claims are
    /// left behind for the stale-lease machinery.  In-process installs
    /// (tests) get a distinguished non-transient `io::Error` instead.
    pub exit_on_kill: bool,
    /// Mirror fired faults to stderr (ends up in worker logs, which is
    /// how subprocess tests assert replay identity).
    pub verbose: bool,
}

impl Default for InstallOpts {
    fn default() -> Self {
        InstallOpts {
            seed: 0,
            profile: DEFAULT_PROFILE.to_string(),
            slot: 0,
            generation: 0,
            exit_on_kill: false,
            verbose: false,
        }
    }
}

/// Compile and install this process's chaos schedule, replacing any
/// previous installation (hit counters reset).
pub fn install(opts: &InstallOpts) -> Result<()> {
    let mut entries = compile(opts.seed, &opts.profile, opts.slot)?;
    if opts.generation > 0 {
        entries.retain(|e| e.action != FaultAction::Kill);
    }
    // Clock skew is a persistent property of the worker, not a per-hit
    // fault: fold every skew entry into one offset at install time.
    let skew: i64 = entries
        .iter()
        .filter_map(|e| match e.action {
            FaultAction::SkewMs(ms) => Some(ms),
            _ => None,
        })
        .sum();
    entries.retain(|e| !matches!(e.action, FaultAction::SkewMs(_)));
    let mut guard = STATE.lock().unwrap_or_else(|p| p.into_inner());
    *guard = Some(State {
        entries,
        counts: BTreeMap::new(),
        fired: Vec::new(),
        slot: opts.slot,
        generation: opts.generation,
        exit_on_kill: opts.exit_on_kill,
        verbose: opts.verbose,
    });
    SKEW_MS.store(skew, Ordering::Relaxed);
    ENABLED.store(true, Ordering::Release);
    Ok(())
}

/// Disable chaos and drop all state.  A no-op when chaos is off.
pub fn clear() {
    ENABLED.store(false, Ordering::Release);
    SKEW_MS.store(0, Ordering::Relaxed);
    *STATE.lock().unwrap_or_else(|p| p.into_inner()) = None;
}

/// Is a chaos schedule installed?  The fast path every fault point
/// checks first.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Acquire)
}

/// This process's injected clock skew in ms (0 when chaos is off).
/// `sweep::claim::now_ms` applies it to every heartbeat read/write,
/// modeling a badly-synced host on a shared claim store.
pub fn skew_ms() -> i64 {
    if !enabled() {
        return 0;
    }
    SKEW_MS.load(Ordering::Relaxed)
}

/// The fired-fault log so far, one formatted line per fault, in firing
/// order — the replay-identity witness for tests.
pub fn fired() -> Vec<String> {
    if !enabled() {
        return Vec::new();
    }
    STATE
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .as_ref()
        .map(|s| s.fired.clone())
        .unwrap_or_default()
}

/// Consume one hit at `point`: every call advances the point's
/// process-local counter, and the scheduled action (if any) for this
/// hit index is returned and logged.
fn hit(point: &str) -> Option<FaultAction> {
    if !enabled() {
        return None;
    }
    let mut guard = STATE.lock().unwrap_or_else(|p| p.into_inner());
    let st = guard.as_mut()?;
    let count = st.counts.entry(point.to_string()).or_insert(0);
    let idx = *count;
    *count += 1;
    let action = st
        .entries
        .iter()
        .find(|e| e.point == point && e.hit == idx)
        .map(|e| e.action)?;
    let line = format!(
        "chaos[w{}.g{}]: {point}@{idx} {}",
        st.slot,
        st.generation,
        action.name()
    );
    st.fired.push(line.clone());
    if st.verbose {
        eprintln!("{line}");
    }
    Some(action)
}

fn kill_now(point: &str) -> std::io::Error {
    let exit = STATE
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .as_ref()
        .map(|s| s.exit_on_kill)
        .unwrap_or(false);
    if exit {
        // Fired log line already went to stderr; exit without running
        // any Drop so held claims stay behind, exactly like SIGKILL.
        std::process::exit(KILL_EXIT_CODE);
    }
    std::io::Error::new(
        std::io::ErrorKind::Other,
        format!("chaos kill at {point} (in-process)"),
    )
}

fn apply(point: &str, action: FaultAction, staged: Option<&mut Vec<u8>>) -> std::io::Result<()> {
    match action {
        FaultAction::Err(kind) => Err(std::io::Error::new(
            kind,
            format!("chaos: injected {kind:?} at {point}"),
        )),
        FaultAction::Kill => Err(kill_now(point)),
        FaultAction::DelayMs(ms) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            Ok(())
        }
        FaultAction::Truncate => {
            if let Some(bytes) = staged {
                let keep = bytes.len() / 2;
                bytes.truncate(keep);
            }
            Ok(())
        }
        FaultAction::Garbage => {
            if let Some(bytes) = staged {
                *bytes = b"{\"chaos\": garbage, not json\n".to_vec();
            }
            Ok(())
        }
        // Skew is consumed at install; evict via should_evict().  If
        // scheduled at a plain fault point they are harmless no-ops.
        FaultAction::SkewMs(_) | FaultAction::Evict => Ok(()),
    }
}

/// The general fault point: a no-op unless this hit is scheduled.
/// Call sites place this *inside* their retry closure, so an injected
/// transient error is consumed by one attempt and the retry's next
/// attempt sees the next hit index (usually clean).
pub fn fault(point: &str) -> std::io::Result<()> {
    match hit(point) {
        None => Ok(()),
        Some(action) => apply(point, action, None),
    }
}

/// Fault point over staged bytes (fragment staging): corruption
/// actions mutate `staged` in place — the corrupt bytes then really
/// get written, to be caught by commit verification downstream.
/// Everything else behaves like [`fault`].
pub fn corrupt(point: &str, staged: &mut Vec<u8>) -> std::io::Result<()> {
    match hit(point) {
        None => Ok(()),
        Some(action) => apply(point, action, Some(staged)),
    }
}

/// Did a scheduled session-eviction fault fire?  The cell runner
/// checks once per cell and drops the warm session caches — safe by
/// the warm ≡ cold session contract.
pub fn should_evict() -> bool {
    matches!(hit("session.evict"), Some(FaultAction::Evict))
}
