//! Chaos schedules: compiling a `(seed, profile, worker slot)` triple
//! into a deterministic list of [`FaultSpec`]s.
//!
//! The compiled schedule is a **pure function** of its inputs — two
//! processes (or two runs, today and next month) given the same triple
//! produce byte-identical entries.  That is the harness's seed
//! reproducibility guarantee: a failing chaos seed from CI replays the
//! exact same fault sequence on a laptop.  Named profiles draw their
//! hit indices and error kinds from a Philox stream keyed by
//! `seed ^ fnv(profile)` with the worker slot as the stream tag, so
//! every slot sees an independent but fully determined schedule.
//!
//! A profile string containing `@` is treated as an **explicit
//! schedule** in the grammar below instead of a named profile — the
//! escape hatch for reproducing a specific scenario by hand:
//!
//! ```text
//! schedule := entry (';' entry)*
//! entry    := ['w'<slot>':'] <point> '@' <hit> '=' <action>
//! action   := 'err:'<kind> | 'kill' | 'delay:'<ms> | 'skew:'<±ms>
//!           | 'truncate' | 'garbage' | 'evict'
//! kind     := 'interrupted' | 'wouldblock' | 'timedout'
//!           | 'notfound' | 'permissiondenied' | 'other'
//! ```
//!
//! `point` must be one of [`POINTS`]; `hit` is the 0-based count of
//! times that point is reached by the worker before the fault fires.
//! An entry without a `w<slot>:` scope applies to every slot.

use std::io::ErrorKind;

use anyhow::{bail, Context, Result};

use crate::rng::philox::PhiloxStream;
use crate::util::fnv;

/// Every named fault point in the codebase — the single source of
/// truth shared by the grammar parser and the call sites.  See the
/// "chaos knobs" section of the `sweep` module doc for where each one
/// sits.
pub const POINTS: &[&str] = &[
    "claim.create",
    "claim.refresh",
    "claim.reclaim",
    "fragment.stage",
    "fragment.commit",
    "fragment.read",
    "sched.cell",
    "resume.spec",
    "session.evict",
    "registry.heartbeat",
    "cache.publish",
    "daemon.dequeue",
    "event.tee",
    "clock",
];

/// Named profiles [`compile`] understands.
pub const PROFILES: &[&str] = &["light", "crash", "heavy"];

/// The profile used when `--chaos-seed` is given without
/// `--chaos-profile`.  "crash" covers the acceptance triad: a worker
/// killed mid-lease, a corrupted fragment, and transient claim-store
/// IO errors.
pub const DEFAULT_PROFILE: &str = "crash";

/// What a fault point does when its scheduled hit arrives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Fail the wrapped op with an injected `io::Error` of this kind.
    /// Transient kinds exercise the bounded-retry path; fatal kinds
    /// exercise fail-fast.
    Err(ErrorKind),
    /// Die mid-lease: worker processes `exit(KILL_EXIT_CODE)` (no Drop
    /// runs, like SIGKILL); in-process installs surface a
    /// distinguished non-transient error instead.
    Kill,
    /// Sleep this long before the op proceeds (slow mount / GC pause).
    DelayMs(u64),
    /// Persistent clock skew for the whole process; only meaningful at
    /// point `clock` and consumed once at install time.
    SkewMs(i64),
    /// Halve the staged bytes before they are written (torn write).
    Truncate,
    /// Replace the staged bytes with non-JSON garbage.
    Garbage,
    /// Drop the warm session caches before the next cell.
    Evict,
}

impl FaultAction {
    /// Round-trippable name, also used in fired-fault log lines.
    pub fn name(self) -> String {
        match self {
            FaultAction::Err(k) => format!("err:{}", kind_name(k)),
            FaultAction::Kill => "kill".to_string(),
            FaultAction::DelayMs(ms) => format!("delay:{ms}"),
            FaultAction::SkewMs(ms) => format!("skew:{ms}"),
            FaultAction::Truncate => "truncate".to_string(),
            FaultAction::Garbage => "garbage".to_string(),
            FaultAction::Evict => "evict".to_string(),
        }
    }
}

fn kind_name(k: ErrorKind) -> &'static str {
    match k {
        ErrorKind::Interrupted => "interrupted",
        ErrorKind::WouldBlock => "wouldblock",
        ErrorKind::TimedOut => "timedout",
        ErrorKind::NotFound => "notfound",
        ErrorKind::PermissionDenied => "permissiondenied",
        _ => "other",
    }
}

fn parse_kind(s: &str) -> Result<ErrorKind> {
    Ok(match s {
        "interrupted" => ErrorKind::Interrupted,
        "wouldblock" => ErrorKind::WouldBlock,
        "timedout" => ErrorKind::TimedOut,
        "notfound" => ErrorKind::NotFound,
        "permissiondenied" => ErrorKind::PermissionDenied,
        "other" => ErrorKind::Other,
        other => bail!(
            "unknown io error kind '{other}' \
             (interrupted|wouldblock|timedout|notfound|permissiondenied|other)"
        ),
    })
}

/// One scheduled fault: the `hit`-th time (0-based) `point` is reached
/// by worker `slot`, `action` fires.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultSpec {
    /// `None` = applies to every worker slot.
    pub slot: Option<usize>,
    pub point: String,
    pub hit: u64,
    pub action: FaultAction,
}

fn parse_action(s: &str) -> Result<FaultAction> {
    if let Some(k) = s.strip_prefix("err:") {
        return Ok(FaultAction::Err(parse_kind(k)?));
    }
    if let Some(ms) = s.strip_prefix("delay:") {
        return Ok(FaultAction::DelayMs(
            ms.parse().context("delay wants integer ms")?,
        ));
    }
    if let Some(ms) = s.strip_prefix("skew:") {
        return Ok(FaultAction::SkewMs(
            ms.parse().context("skew wants signed integer ms")?,
        ));
    }
    match s {
        "kill" => Ok(FaultAction::Kill),
        "truncate" => Ok(FaultAction::Truncate),
        "garbage" => Ok(FaultAction::Garbage),
        "evict" => Ok(FaultAction::Evict),
        other => bail!(
            "unknown chaos action '{other}' \
             (err:<kind>|kill|delay:<ms>|skew:<±ms>|truncate|garbage|evict)"
        ),
    }
}

/// Parse the explicit schedule grammar (module doc).  Entries are kept
/// in text order; empty entries (trailing `;`) are ignored.
pub fn parse_schedule(text: &str) -> Result<Vec<FaultSpec>> {
    let mut out = Vec::new();
    for raw in text.split(';') {
        let entry = raw.trim();
        if entry.is_empty() {
            continue;
        }
        // A leading `w<digits>:` scopes the entry to one worker slot.
        // Nothing else in an entry can look like that prefix: points
        // never start with 'w' followed by digits and a colon.
        let (slot, rest) = match entry.split_once(':') {
            Some((head, tail)) => match head
                .strip_prefix('w')
                .filter(|d| !d.is_empty() && d.chars().all(|c| c.is_ascii_digit()))
                .and_then(|d| d.parse::<usize>().ok())
            {
                Some(n) => (Some(n), tail),
                None => (None, entry),
            },
            None => (None, entry),
        };
        let (point_hit, action) = rest
            .split_once('=')
            .with_context(|| format!("chaos entry '{entry}': expected <point>@<hit>=<action>"))?;
        let (point, hit) = point_hit
            .split_once('@')
            .with_context(|| format!("chaos entry '{entry}': expected <point>@<hit>"))?;
        let point = point.trim();
        if !POINTS.contains(&point) {
            bail!(
                "chaos entry '{entry}': unknown fault point '{point}' (known: {})",
                POINTS.join(", ")
            );
        }
        let hit: u64 = hit
            .trim()
            .parse()
            .with_context(|| format!("chaos entry '{entry}': hit must be a 0-based integer"))?;
        let action = parse_action(action.trim()).with_context(|| format!("chaos entry '{entry}'"))?;
        out.push(FaultSpec {
            slot,
            point: point.to_string(),
            hit,
            action,
        });
    }
    if out.is_empty() {
        bail!("empty chaos schedule");
    }
    Ok(out)
}

/// Compile worker `slot`'s fault schedule for `(seed, profile)`.
/// Explicit schedules (profile contains `@`) are parsed; named
/// profiles are generated.  Either way the result is filtered down to
/// entries that apply to `slot`, and is a pure function of the inputs.
pub fn compile(seed: u64, profile: &str, slot: usize) -> Result<Vec<FaultSpec>> {
    let entries = if profile.contains('@') {
        parse_schedule(profile)?
    } else {
        named_profile(seed, profile, slot)?
    };
    Ok(entries
        .into_iter()
        .filter(|e| e.slot.map_or(true, |s| s == slot))
        .collect())
}

/// Cheap validation for config/CLI: does this profile string compile?
pub fn validate_profile(profile: &str) -> Result<()> {
    compile(0, profile, 0).map(|_| ())
}

const TRANSIENT: [ErrorKind; 3] = [
    ErrorKind::Interrupted,
    ErrorKind::WouldBlock,
    ErrorKind::TimedOut,
];

fn named_profile(seed: u64, profile: &str, slot: usize) -> Result<Vec<FaultSpec>> {
    let mut rng = PhiloxStream::new(seed ^ fnv::hash(profile.bytes()), slot as u32);
    let here = Some(slot);
    let mut out = Vec::new();
    let mut push = |point: &str, hit: u64, action: FaultAction| {
        out.push(FaultSpec {
            slot: here,
            point: point.to_string(),
            hit,
            action,
        });
    };
    match profile {
        // One transient claim-store error plus a small commit delay:
        // exercises the retry path without killing anything.
        "light" => {
            let kind = TRANSIENT[rng.next_below(3) as usize];
            push("claim.create", rng.next_below(3) as u64, FaultAction::Err(kind));
            push(
                "fragment.commit",
                rng.next_below(2) as u64,
                FaultAction::DelayMs(1 + rng.next_below(20) as u64),
            );
        }
        // The acceptance triad.  Slot 0 corrupts its first staged
        // fragment and then dies mid-lease on a later cell; every slot
        // sees a transient claim-store error; other slots get clock
        // skew and a slow commit so leases and ordering are stressed
        // while slot 0 crashes.
        "crash" => {
            let kind = TRANSIENT[rng.next_below(3) as usize];
            push("claim.create", rng.next_below(2) as u64, FaultAction::Err(kind));
            if slot == 0 {
                push("fragment.stage", 0, FaultAction::Garbage);
                push("sched.cell", 1 + rng.next_below(2) as u64, FaultAction::Kill);
            } else {
                let magnitude = 500 + rng.next_below(2000) as i64;
                let sign = if rng.next_below(2) == 0 { 1 } else { -1 };
                push("clock", 0, FaultAction::SkewMs(sign * magnitude));
                push(
                    "fragment.commit",
                    0,
                    FaultAction::DelayMs(rng.next_below(30) as u64),
                );
            }
        }
        // Everything at once: claim-store errors on create and
        // refresh, torn/garbage staging, slow commits, cache
        // eviction, clock skew, and kills on the first two slots.
        "heavy" => {
            let kind = TRANSIENT[rng.next_below(3) as usize];
            push("claim.create", rng.next_below(3) as u64, FaultAction::Err(kind));
            let kind = TRANSIENT[rng.next_below(3) as usize];
            push("claim.refresh", rng.next_below(2) as u64, FaultAction::Err(kind));
            let corrupt = if slot % 2 == 0 {
                FaultAction::Truncate
            } else {
                FaultAction::Garbage
            };
            push("fragment.stage", rng.next_below(2) as u64, corrupt);
            push(
                "fragment.commit",
                rng.next_below(3) as u64,
                FaultAction::DelayMs(1 + rng.next_below(40) as u64),
            );
            push("session.evict", rng.next_below(2) as u64, FaultAction::Evict);
            let magnitude = rng.next_below(5000) as i64;
            let sign = if rng.next_below(2) == 0 { 1 } else { -1 };
            push("clock", 0, FaultAction::SkewMs(sign * magnitude));
            if slot <= 1 {
                push("sched.cell", 1 + rng.next_below(3) as u64, FaultAction::Kill);
            }
        }
        other => bail!(
            "unknown chaos profile '{other}' (known: {}; or an explicit \
             '<point>@<hit>=<action>;…' schedule)",
            PROFILES.join(", ")
        ),
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_grammar_parses_scopes_hits_and_actions() {
        let s = parse_schedule(
            "w2:claim.create@1=err:interrupted; sched.cell@0=kill; \
             fragment.stage@3=garbage; clock@0=skew:-250; fragment.commit@2=delay:7;",
        )
        .unwrap();
        assert_eq!(s.len(), 5);
        assert_eq!(s[0].slot, Some(2));
        assert_eq!(s[0].point, "claim.create");
        assert_eq!(s[0].hit, 1);
        assert_eq!(s[0].action, FaultAction::Err(ErrorKind::Interrupted));
        assert_eq!(s[1].slot, None);
        assert_eq!(s[1].action, FaultAction::Kill);
        assert_eq!(s[3].action, FaultAction::SkewMs(-250));
        assert_eq!(s[4].action, FaultAction::DelayMs(7));
    }

    #[test]
    fn bad_schedules_are_rejected_with_context() {
        for bad in [
            "",
            "claim.create@1",            // no action
            "nosuchpoint@0=kill",        // unknown point
            "claim.create@x=kill",       // bad hit
            "claim.create@0=explode",    // unknown action
            "claim.create@0=err:eieio",  // unknown kind
            "claim.create@0=skew:fast",  // bad skew
        ] {
            assert!(parse_schedule(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn compile_is_deterministic_and_slot_filtered() {
        for profile in PROFILES {
            for slot in 0..4usize {
                let a = compile(11, profile, slot).unwrap();
                let b = compile(11, profile, slot).unwrap();
                assert_eq!(a, b, "{profile} slot {slot} not reproducible");
                assert!(!a.is_empty(), "{profile} slot {slot} compiled empty");
                assert!(
                    a.iter().all(|e| e.slot.map_or(true, |s| s == slot)),
                    "{profile} slot {slot} kept foreign entries"
                );
            }
        }
        // Explicit schedules filter by scope too.
        let only_w1 = compile(0, "w1:sched.cell@0=kill", 0).unwrap();
        assert!(only_w1.is_empty());
        let only_w1 = compile(0, "w1:sched.cell@0=kill", 1).unwrap();
        assert_eq!(only_w1.len(), 1);
    }

    #[test]
    fn crash_profile_carries_the_acceptance_triad_on_slot_0() {
        let s = compile(11, "crash", 0).unwrap();
        assert!(s.iter().any(|e| e.action == FaultAction::Kill));
        assert!(s.iter().any(|e| e.action == FaultAction::Garbage));
        assert!(s
            .iter()
            .any(|e| matches!(e.action, FaultAction::Err(k) if super::TRANSIENT.contains(&k))));
    }

    #[test]
    fn action_names_round_trip_through_the_grammar() {
        for action in [
            FaultAction::Err(ErrorKind::TimedOut),
            FaultAction::Kill,
            FaultAction::DelayMs(12),
            FaultAction::SkewMs(-900),
            FaultAction::Truncate,
            FaultAction::Garbage,
            FaultAction::Evict,
        ] {
            let text = format!("sched.cell@4={}", action.name());
            let parsed = parse_schedule(&text).unwrap();
            assert_eq!(parsed[0].action, action, "{text}");
        }
    }
}
