//! Sketch matrices S ∈ R^{B×B_proj} with E[S Sᵀ] = I — the pure-Rust mirror
//! of `python/compile/kernels/ref.py`.  Element values for gauss/rademacher
//! and the SORS row-selection/signs are *bit-compatible* with the Python
//! side (same Philox counters), so golden tests can pin the two stacks
//! against each other.

use crate::rng::philox::{
    element_normal, element_rademacher, element_uniform_int, STREAM_ROWSEL,
    STREAM_SIGNS, STREAM_SKETCH,
};
use crate::tensor::Tensor;

/// Sketch families (paper §2.1, §3.5 + the Adelman-style row sampler).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SketchKind {
    Gauss,
    Rademacher,
    Dct,
    Dft,
    RowSample,
}

impl SketchKind {
    pub fn parse(s: &str) -> Option<SketchKind> {
        Some(match s {
            "gauss" => SketchKind::Gauss,
            "rademacher" => SketchKind::Rademacher,
            "dct" => SketchKind::Dct,
            "dft" => SketchKind::Dft,
            "rowsample" => SketchKind::RowSample,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SketchKind::Gauss => "gauss",
            SketchKind::Rademacher => "rademacher",
            SketchKind::Dct => "dct",
            SketchKind::Dft => "dft",
            SketchKind::RowSample => "rowsample",
        }
    }

    pub const ALL: [SketchKind; 5] = [
        SketchKind::Gauss,
        SketchKind::Rademacher,
        SketchKind::Dct,
        SketchKind::Dft,
        SketchKind::RowSample,
    ];
}

/// Orthonormal DCT-II entry H[k, i] of order b (matches ref.dct_entry).
pub fn dct_entry(k: usize, i: usize, b: usize) -> f32 {
    let scale = if k == 0 { 1.0 / 2f32.sqrt() } else { 1.0 };
    scale
        * (2.0 / b as f32).sqrt()
        * ((std::f32::consts::PI * (2.0 * i as f32 + 1.0) * k as f32)
            / (2.0 * b as f32))
            .cos()
}

/// Orthonormal real-DFT entry H[k, i] of order b (matches ref.dft_entry).
pub fn dft_entry(k: usize, i: usize, b: usize) -> f32 {
    if k == 0 {
        return 1.0 / (b as f32).sqrt();
    }
    if b % 2 == 0 && k == b - 1 {
        return if i % 2 == 0 { 1.0 } else { -1.0 } / (b as f32).sqrt();
    }
    let m = ((k + 1) / 2) as f32;
    let ang = 2.0 * std::f32::consts::PI * m * i as f32 / b as f32;
    let v = if k % 2 == 1 { ang.cos() } else { ang.sin() };
    v * (2.0 / b as f32).sqrt()
}

/// SORS row selection: b_proj uniform indices in [0, b), with replacement.
pub fn row_selection(b: usize, b_proj: usize, seed: (u32, u32)) -> Vec<usize> {
    (0..b_proj)
        .map(|j| element_uniform_int(0, j as u32, seed, b as u32, STREAM_ROWSEL) as usize)
        .collect()
}

/// SORS sign flips: ±1 per input position.
pub fn sign_flips(b: usize, seed: (u32, u32)) -> Vec<f32> {
    (0..b)
        .map(|i| element_rademacher(0, i as u32, seed, STREAM_SIGNS))
        .collect()
}

/// Dense sketch matrix S (b × b_proj) — mirrors `ref.sketch`.
pub fn sketch(kind: SketchKind, b: usize, b_proj: usize, seed: (u32, u32)) -> Tensor {
    let inv = 1.0 / (b_proj as f32).sqrt();
    match kind {
        SketchKind::Gauss => Tensor::from_fn(b, b_proj, |i, j| {
            element_normal(i as u32, j as u32, seed, STREAM_SKETCH) * inv
        }),
        SketchKind::Rademacher => Tensor::from_fn(b, b_proj, |i, j| {
            element_rademacher(i as u32, j as u32, seed, STREAM_SKETCH) * inv
        }),
        SketchKind::Dct | SketchKind::Dft => {
            let sel = row_selection(b, b_proj, seed);
            let signs = sign_flips(b, seed);
            let scale = (b as f32 / b_proj as f32).sqrt();
            Tensor::from_fn(b, b_proj, |i, j| {
                let h = match kind {
                    SketchKind::Dct => dct_entry(sel[j], i, b),
                    _ => dft_entry(sel[j], i, b),
                };
                scale * signs[i] * h
            })
        }
        SketchKind::RowSample => {
            let sel = row_selection(b, b_proj, seed);
            let scale = (b as f32 / b_proj as f32).sqrt();
            Tensor::from_fn(b, b_proj, |i, j| if sel[j] == i { scale } else { 0.0 })
        }
    }
}

/// X_proj = Sᵀ X without materializing S (streamed, row-generated) — the
/// Rust analogue of the fused Pallas kernel's O(1)-memory-for-S property.
pub fn project_streamed(
    kind: SketchKind,
    x: &Tensor,
    b_proj: usize,
    seed: (u32, u32),
) -> Tensor {
    let (b, n) = (x.rows, x.cols);
    let mut out = Tensor::zeros(b_proj, n);
    match kind {
        SketchKind::Gauss => {
            let inv = 1.0 / (b_proj as f32).sqrt();
            for i in 0..b {
                let xrow = x.row(i);
                for j in 0..b_proj {
                    let s = element_normal(i as u32, j as u32, seed, STREAM_SKETCH)
                        * inv;
                    let orow = &mut out.data[j * n..(j + 1) * n];
                    for c in 0..n {
                        orow[c] += s * xrow[c];
                    }
                }
            }
        }
        SketchKind::Rademacher => {
            let inv = 1.0 / (b_proj as f32).sqrt();
            for i in 0..b {
                let xrow = x.row(i);
                for j in 0..b_proj {
                    let s =
                        element_rademacher(i as u32, j as u32, seed, STREAM_SKETCH) * inv;
                    let orow = &mut out.data[j * n..(j + 1) * n];
                    for c in 0..n {
                        orow[c] += s * xrow[c];
                    }
                }
            }
        }
        _ => {
            // Structured kinds: row-generate S via entries.
            let s = sketch(kind, b, b_proj, seed);
            return crate::tensor::matmul_at(&s, x);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::philox::PhiloxStream;
    use crate::tensor::{matmul, matmul_at, Tensor};

    fn randt(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut s = PhiloxStream::new(seed, 3);
        Tensor::from_fn(rows, cols, |_, _| s.next_normal())
    }

    #[test]
    fn transform_orthonormal() {
        for b in [4usize, 8, 16, 32] {
            for entry in [dct_entry as fn(usize, usize, usize) -> f32, dft_entry] {
                let h = Tensor::from_fn(b, b, |k, i| entry(k, i, b));
                let hh = matmul_bt_local(&h);
                for i in 0..b {
                    for j in 0..b {
                        let want = if i == j { 1.0 } else { 0.0 };
                        assert!(
                            (hh.at(i, j) - want).abs() < 2e-5,
                            "b={b} ({i},{j}) = {}",
                            hh.at(i, j)
                        );
                    }
                }
            }
        }
    }

    fn matmul_bt_local(h: &Tensor) -> Tensor {
        crate::tensor::matmul_bt(h, h)
    }

    #[test]
    fn unbiased_identity_montecarlo() {
        let (b, bp, trials) = (10, 5, 1500);
        for kind in SketchKind::ALL {
            let mut acc = Tensor::zeros(b, b);
            for t in 0..trials {
                let s = sketch(kind, b, bp, (t as u32 * 7919 + 3, 11));
                let sst = matmul(&s, &s.transpose());
                acc.add_assign(&sst);
            }
            acc.scale(1.0 / trials as f32);
            for i in 0..b {
                for j in 0..b {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!(
                        (acc.at(i, j) - want).abs() < 0.2,
                        "{kind:?} ({i},{j}) = {}",
                        acc.at(i, j)
                    );
                }
            }
        }
    }

    #[test]
    fn streamed_matches_dense() {
        let x = randt(24, 7, 5);
        for kind in SketchKind::ALL {
            let dense = matmul_at(&sketch(kind, 24, 9, (3, 4)), &x);
            let streamed = project_streamed(kind, &x, 9, (3, 4));
            assert!(dense.max_abs_diff(&streamed) < 1e-4, "{kind:?}");
        }
    }

    #[test]
    fn parse_roundtrip() {
        for kind in SketchKind::ALL {
            assert_eq!(SketchKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(SketchKind::parse("bogus"), None);
    }

    #[test]
    fn rowsample_structure() {
        let s = sketch(SketchKind::RowSample, 16, 8, (1, 2));
        let scale = (16.0f32 / 8.0).sqrt();
        for j in 0..8 {
            let nz: Vec<f32> =
                (0..16).map(|i| s.at(i, j)).filter(|v| *v != 0.0).collect();
            assert_eq!(nz.len(), 1);
            assert!((nz[0] - scale).abs() < 1e-6);
        }
    }
}
