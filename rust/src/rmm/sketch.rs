//! Sketch matrices S ∈ R^{B×B_proj} with E[S Sᵀ] = I — the pure-Rust mirror
//! of `python/compile/kernels/ref.py`.  Element values for gauss/rademacher
//! and the SORS row-selection/signs are *bit-compatible* with the Python
//! side (same Philox counters), so golden tests can pin the two stacks
//! against each other.
//!
//! `project_streamed` is the fused analogue of the Pallas kernel: S is
//! generated tile-by-tile from the Philox counters *inside* the blocked
//! accumulation loop and never materialized — for any sketch family,
//! including the structured DCT/DFT/rowsample paths that previously fell
//! back to dense `sketch()` + `matmul_at`.  Output row blocks are
//! dispatched as tasks on the persistent work-stealing pool
//! (`tensor::pool`; disjoint `&mut` blocks, stealable grain), the inner
//! tiles run through the runtime-dispatched SIMD microkernel
//! (`tensor::kernels::dispatch` — the same AVX2/AVX-512/NEON fast path
//! as the packed GEMM backend), and per output element the input rows
//! accumulate in ascending order with unfused multiply-then-add steps,
//! so the result is bit-identical to the original streaming loop
//! regardless of tiling, task grain, thread count or SIMD level.  The
//! gather families (rowsample / wtacrs) have no multiply-accumulate
//! inner loop at all — their sparsity-aware row copies are already
//! cheaper than any dense microkernel — so "all families on the fast
//! path" means: element families through the dispatched kernel, gather
//! families through the gather.

use crate::rng::philox::{
    element_normal, element_rademacher, element_uniform_int, PhiloxStream,
    STREAM_ROWSEL, STREAM_SIGNS, STREAM_SKETCH, STREAM_WTA,
};
use crate::tensor::kernels::dispatch;
use crate::tensor::kernels::micro::{MR, NR};
use crate::tensor::kernels::pack::pack_b;
use crate::tensor::kernels::packed::MatRef;
use crate::tensor::kernels::threads;
use crate::tensor::pool;
use crate::tensor::Tensor;

/// Sketch families (paper §2.1, §3.5 + the Adelman-style row sampler and
/// the WTA-CRS winner-take-all column-row sampler, arXiv 2305.15265).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SketchKind {
    Gauss,
    Rademacher,
    Dct,
    Dft,
    RowSample,
    WtaCrs,
}

impl SketchKind {
    /// Case-insensitive family lookup.  Returns `None` on unknown names;
    /// config/CLI surfaces must go through [`SketchKind::parse_or_err`]
    /// so typos are reported instead of silently defaulting.
    pub fn parse(s: &str) -> Option<SketchKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "gauss" => SketchKind::Gauss,
            "rademacher" => SketchKind::Rademacher,
            "dct" => SketchKind::Dct,
            "dft" => SketchKind::Dft,
            "rowsample" => SketchKind::RowSample,
            "wtacrs" => SketchKind::WtaCrs,
            _ => return None,
        })
    }

    /// Like [`SketchKind::parse`], but unknown names become an error that
    /// names the offender and lists every valid family.
    pub fn parse_or_err(s: &str) -> anyhow::Result<SketchKind> {
        SketchKind::parse(s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown sketch kind '{s}' (valid: {})",
                Self::valid_names().join(", ")
            )
        })
    }

    /// The canonical lowercase names, in `ALL` order.
    pub fn valid_names() -> Vec<&'static str> {
        Self::ALL.iter().map(|k| k.name()).collect()
    }

    pub fn name(&self) -> &'static str {
        match self {
            SketchKind::Gauss => "gauss",
            SketchKind::Rademacher => "rademacher",
            SketchKind::Dct => "dct",
            SketchKind::Dft => "dft",
            SketchKind::RowSample => "rowsample",
            SketchKind::WtaCrs => "wtacrs",
        }
    }

    pub const ALL: [SketchKind; 6] = [
        SketchKind::Gauss,
        SketchKind::Rademacher,
        SketchKind::Dct,
        SketchKind::Dft,
        SketchKind::RowSample,
        SketchKind::WtaCrs,
    ];
}

/// Orthonormal DCT-II entry H[k, i] of order b (matches ref.dct_entry).
pub fn dct_entry(k: usize, i: usize, b: usize) -> f32 {
    let scale = if k == 0 { 1.0 / 2f32.sqrt() } else { 1.0 };
    scale
        * (2.0 / b as f32).sqrt()
        * ((std::f32::consts::PI * (2.0 * i as f32 + 1.0) * k as f32)
            / (2.0 * b as f32))
            .cos()
}

/// Orthonormal real-DFT entry H[k, i] of order b (matches ref.dft_entry).
pub fn dft_entry(k: usize, i: usize, b: usize) -> f32 {
    if k == 0 {
        return 1.0 / (b as f32).sqrt();
    }
    if b % 2 == 0 && k == b - 1 {
        return if i % 2 == 0 { 1.0 } else { -1.0 } / (b as f32).sqrt();
    }
    let m = ((k + 1) / 2) as f32;
    let ang = 2.0 * std::f32::consts::PI * m * i as f32 / b as f32;
    let v = if k % 2 == 1 { ang.cos() } else { ang.sin() };
    v * (2.0 / b as f32).sqrt()
}

/// SORS row selection: b_proj uniform indices in [0, b), with replacement.
pub fn row_selection(b: usize, b_proj: usize, seed: (u32, u32)) -> Vec<usize> {
    (0..b_proj)
        .map(|j| element_uniform_int(0, j as u32, seed, b as u32, STREAM_ROWSEL) as usize)
        .collect()
}

/// SORS sign flips: ±1 per input position.
pub fn sign_flips(b: usize, seed: (u32, u32)) -> Vec<f32> {
    (0..b)
        .map(|i| element_rademacher(0, i as u32, seed, STREAM_SIGNS))
        .collect()
}

/// Number of deterministic "winner" columns WTA-CRS spends on a
/// (b, b_proj) shape: half the projection budget, capped at b.
pub fn wta_winner_count(b: usize, b_proj: usize) -> usize {
    (b_proj / 2).min(b)
}

/// WTA-CRS column plan: for each of the b_proj output columns, the source
/// row index and the scale of that column's single non-zero (scale 0.0
/// marks an all-zero column).
///
/// The first `c = wta_winner_count(b, b_proj)` columns are deterministic
/// winners — c *distinct* rows (the prefix of a Philox-shuffled
/// permutation of 0..b) copied at scale 1 — and the remaining
/// `m = b_proj − c` columns sample uniformly with replacement from the
/// b − c loser rows at scale sqrt((b−c)/m), so
/// E[S Sᵀ] = Σ_winners eᵢeᵢᵀ + m·(1/(b−c))·((b−c)/m)·Σ_losers eⱼeⱼᵀ = I.
/// When b_proj ≥ 2b the winners already cover every row, the surplus
/// columns are zero, and S Sᵀ = I exactly (a zero-variance sketch).
pub fn wta_plan(b: usize, b_proj: usize, seed: (u32, u32)) -> Vec<(usize, f32)> {
    if b == 0 || b_proj == 0 {
        return vec![(0, 0.0); b_proj];
    }
    let c = wta_winner_count(b, b_proj);
    let mut perm: Vec<usize> = (0..b).collect();
    let key = (seed.0 as u64) | ((seed.1 as u64) << 32);
    PhiloxStream::new(key, STREAM_WTA).shuffle(&mut perm);
    let mut plan: Vec<(usize, f32)> =
        perm.iter().take(c).map(|&i| (i, 1.0f32)).collect();
    let losers = b - c;
    if losers == 0 {
        plan.resize(b_proj, (0, 0.0));
        return plan;
    }
    let m = b_proj - c;
    let scale = (losers as f32 / m as f32).sqrt();
    for j in c..b_proj {
        let d = element_uniform_int(0, j as u32, seed, losers as u32, STREAM_WTA);
        plan.push((perm[c + d as usize], scale));
    }
    plan
}

/// Dense sketch matrix S (b × b_proj) — mirrors `ref.sketch`.
///
/// The structured kinds precompute the selection/sign vectors once and
/// fill rows directly (no per-element closure recomputation); RowSample
/// writes only its b_proj non-zeros.
pub fn sketch(kind: SketchKind, b: usize, b_proj: usize, seed: (u32, u32)) -> Tensor {
    let inv = 1.0 / (b_proj as f32).sqrt();
    match kind {
        SketchKind::Gauss => Tensor::from_fn(b, b_proj, |i, j| {
            element_normal(i as u32, j as u32, seed, STREAM_SKETCH) * inv
        }),
        SketchKind::Rademacher => Tensor::from_fn(b, b_proj, |i, j| {
            element_rademacher(i as u32, j as u32, seed, STREAM_SKETCH) * inv
        }),
        SketchKind::Dct | SketchKind::Dft => {
            let sel = row_selection(b, b_proj, seed);
            let signs = sign_flips(b, seed);
            let scale = (b as f32 / b_proj as f32).sqrt();
            let mut t = Tensor::zeros(b, b_proj);
            for i in 0..b {
                let w = scale * signs[i];
                let row = t.row_mut(i);
                for (rv, &k) in row.iter_mut().zip(&sel) {
                    let h = match kind {
                        SketchKind::Dct => dct_entry(k, i, b),
                        _ => dft_entry(k, i, b),
                    };
                    *rv = w * h;
                }
            }
            t
        }
        SketchKind::RowSample => {
            let mut t = Tensor::zeros(b, b_proj);
            if b > 0 {
                let sel = row_selection(b, b_proj, seed);
                let scale = (b as f32 / b_proj as f32).sqrt();
                for (j, &i) in sel.iter().enumerate() {
                    *t.at_mut(i, j) = scale;
                }
            }
            t
        }
        SketchKind::WtaCrs => {
            let mut t = Tensor::zeros(b, b_proj);
            for (j, &(i, scale)) in wta_plan(b, b_proj, seed).iter().enumerate() {
                if scale != 0.0 {
                    *t.at_mut(i, j) = scale;
                }
            }
            t
        }
    }
}

/// k-depth of the generated S panels: S is produced in `TILE_I × MR`
/// pieces (2 KiB, L1-resident) fed straight to the dispatched
/// microkernel as its packed A operand.
const TILE_I: usize = 64;
/// Historic S-tile width, kept as the basis of the task-grain cap so the
/// pool geometry (and hence task ownership) is unchanged by the
/// microkernel rework.
const TILE_J: usize = 64;
/// Columns of X packed per slab (NR-aligned); bounds the packed-X
/// staging buffer at `padded(min(n, X_SLAB)) · b` floats, mirroring the
/// GEMM driver's NC-slab policy.
const X_SLAB: usize = 1024;

/// Below this many multiply-adds the thread fan-out costs more than it
/// saves; stay on the caller's thread.
const PAR_MADD_THRESHOLD: f64 = 2.0e5;

/// Shared driver for the element-generated families: out = Sᵀ X where
/// `elem(i, j)` yields S[i, j] on the fly.  Parallel over output rows;
/// the inner tiles run through the *dispatched* GEMM microkernel
/// ([`dispatch::active_kernel`]), so the projection rides the same
/// AVX2/AVX-512/NEON fast path as the packed backend:
///
/// * X is packed once per column slab into NR-column k-major panels
///   (the microkernel's B operand) via the GEMM packer — read-only,
///   shared by every task;
/// * S panels are generated *directly* in MR-row k-major layout (the A
///   operand) from the Philox counters, `TILE_I` input rows at a time —
///   S still never exists outside one 2 KiB panel;
/// * each MR-row × NR-column output tile loads from the band, runs the
///   microkernel over ascending `i0` blocks, and stores back.
///
/// Per output element this performs the identical f32 sequence as the
/// original streaming loop — input rows ascending, one unfused multiply
/// then add per row — through every dispatch level (the no-FMA
/// contract, see `tensor::kernels::dispatch`), so results stay
/// bit-identical to the seed reference loop pinned in prop_kernels.rs.
fn project_streamed_elem<F>(x: &Tensor, b_proj: usize, elem: &F) -> Tensor
where
    F: Fn(usize, usize) -> f32 + Sync,
{
    let (b, n) = (x.rows, x.cols);
    let mut out = Tensor::zeros(b_proj, n);
    if b == 0 || n == 0 || b_proj == 0 {
        return out;
    }
    let work = b as f64 * b_proj as f64 * n as f64;
    let nt = if work < PAR_MADD_THRESHOLD { 1 } else { threads::num_threads() };
    let kern = dispatch::active_kernel();
    // Row blocks as pool tasks: MR alignment (finer than TILE_J, for
    // load balance at small b_proj) and a 4·TILE_J cap so steals stay
    // possible — the same geometry as the pre-microkernel driver.
    let grain = pool::task_grain(b_proj, nt, MR, 4 * TILE_J);
    let slab_w = n.min(X_SLAB);
    let mut xpack = vec![0.0f32; (slab_w + NR - 1) / NR * NR * b];
    let mut c0 = 0;
    while c0 < n {
        let w = X_SLAB.min(n - c0);
        let pw = (w + NR - 1) / NR * NR;
        pack_b(&mut xpack[..pw * b], MatRef::dense(x), 0, b, c0, w);
        let xp = &xpack[..pw * b];
        pool::par_row_blocks(nt, b_proj, n, grain, &mut out.data, &|j0, jrows, band| {
            let mut sbuf = [0.0f32; TILE_I * MR];
            let mut tile = [[0.0f32; NR]; MR];
            let mut jp = 0;
            while jp < jrows {
                let mr = MR.min(jrows - jp);
                let mut i0 = 0;
                while i0 < b {
                    let ib = TILE_I.min(b - i0);
                    // Generate the S panel for input rows i0.. and output
                    // rows j0+jp.. straight from the Philox counters, in
                    // packed-A layout (sbuf[di·MR + r]); rows past mr are
                    // exact zeros, inert like the GEMM packers' padding.
                    for di in 0..ib {
                        for r in 0..MR {
                            sbuf[di * MR + r] =
                                if r < mr { elem(i0 + di, j0 + jp + r) } else { 0.0 };
                        }
                    }
                    let mut t0 = 0;
                    while t0 < w {
                        let nr = NR.min(w - t0);
                        let xpanel = &xp[(t0 / NR) * NR * b + i0 * NR..][..ib * NR];
                        // load the output tile (padded lanes zeroed)
                        for (r, trow) in tile.iter_mut().enumerate() {
                            if r < mr {
                                let o0 = (jp + r) * n + c0 + t0;
                                trow[..nr].copy_from_slice(&band[o0..o0 + nr]);
                                for v in trow[nr..].iter_mut() {
                                    *v = 0.0;
                                }
                            } else {
                                *trow = [0.0; NR];
                            }
                        }
                        kern(ib, &sbuf[..ib * MR], xpanel, &mut tile);
                        for (r, trow) in tile.iter().enumerate().take(mr) {
                            let o0 = (jp + r) * n + c0 + t0;
                            band[o0..o0 + nr].copy_from_slice(&trow[..nr]);
                        }
                        t0 += NR;
                    }
                    i0 += TILE_I;
                }
                jp += MR;
            }
        });
        c0 += X_SLAB;
    }
    out
}

/// X_proj = Sᵀ X without materializing S (streamed, tile-generated) — the
/// Rust analogue of the fused Pallas kernel's O(1)-memory-for-S property,
/// now covering *all* sketch families:
///
/// * gauss / rademacher: S tiles generated from Philox element counters
///   inside the blocked axpy loop;
/// * dct / dft: selection + sign vectors hoisted once, transform entries
///   generated per tile (no dense S, no `matmul_at` fallback);
/// * rowsample: explicit sparsity-aware gather — b_proj scaled row copies,
///   no multiply-accumulate at all;
/// * wtacrs: same gather structure, but the first half of the budget is
///   spent on deterministic distinct "winner" rows (scale 1) and only the
///   remainder samples the loser complement (see [`wta_plan`]).
pub fn project_streamed(
    kind: SketchKind,
    x: &Tensor,
    b_proj: usize,
    seed: (u32, u32),
) -> Tensor {
    let (b, n) = (x.rows, x.cols);
    match kind {
        SketchKind::Gauss => {
            let inv = 1.0 / (b_proj as f32).sqrt();
            let elem = move |i: usize, j: usize| {
                element_normal(i as u32, j as u32, seed, STREAM_SKETCH) * inv
            };
            project_streamed_elem(x, b_proj, &elem)
        }
        SketchKind::Rademacher => {
            let inv = 1.0 / (b_proj as f32).sqrt();
            let elem = move |i: usize, j: usize| {
                element_rademacher(i as u32, j as u32, seed, STREAM_SKETCH) * inv
            };
            project_streamed_elem(x, b_proj, &elem)
        }
        SketchKind::Dct | SketchKind::Dft => {
            let sel = row_selection(b, b_proj, seed);
            let signs = sign_flips(b, seed);
            let scale = (b as f32 / b_proj as f32).sqrt();
            let use_dct = kind == SketchKind::Dct;
            let elem = move |i: usize, j: usize| {
                let h = if use_dct {
                    dct_entry(sel[j], i, b)
                } else {
                    dft_entry(sel[j], i, b)
                };
                (scale * signs[i]) * h
            };
            project_streamed_elem(x, b_proj, &elem)
        }
        SketchKind::RowSample => {
            let mut out = Tensor::zeros(b_proj, n);
            if b == 0 {
                return out; // no rows to sample
            }
            let sel = row_selection(b, b_proj, seed);
            let scale = (b as f32 / b_proj as f32).sqrt();
            for (j, &src) in sel.iter().enumerate() {
                let xrow = x.row(src);
                for (o, &xv) in out.row_mut(j).iter_mut().zip(xrow) {
                    *o = scale * xv;
                }
            }
            out
        }
        SketchKind::WtaCrs => {
            // One non-zero per S column, like rowsample: the fused path is
            // a scaled row gather, so S never exists here either.
            let mut out = Tensor::zeros(b_proj, n);
            if b == 0 {
                return out;
            }
            for (j, &(src, scale)) in wta_plan(b, b_proj, seed).iter().enumerate() {
                if scale == 0.0 {
                    continue; // surplus column beyond full winner coverage
                }
                let xrow = x.row(src);
                for (o, &xv) in out.row_mut(j).iter_mut().zip(xrow) {
                    *o = scale * xv;
                }
            }
            out
        }
    }
}

/// Lift a projected tensor back through the sketch: out = S Z, (b × n)
/// from Z (b_proj × n), without materializing S.  This is the grad-input
/// side of the fully-sketched backward (∂X ≈ S·(SᵀdY)·W reuses the dY
/// projection); `seed` and `b_proj = z.rows` must match the projection.
/// Element families reuse the tiled streaming driver with transposed
/// counters; the gather families scatter their single non-zero per column
/// in ascending column order, so results are bit-identical for any
/// thread count.
pub fn lift_streamed(
    kind: SketchKind,
    z: &Tensor,
    b: usize,
    seed: (u32, u32),
) -> Tensor {
    let (b_proj, n) = (z.rows, z.cols);
    match kind {
        SketchKind::Gauss => {
            let inv = 1.0 / (b_proj as f32).sqrt();
            let elem = move |i: usize, j: usize| {
                // S[j, i] — project's counters with (row, col) swapped
                element_normal(j as u32, i as u32, seed, STREAM_SKETCH) * inv
            };
            project_streamed_elem(z, b, &elem)
        }
        SketchKind::Rademacher => {
            let inv = 1.0 / (b_proj as f32).sqrt();
            let elem = move |i: usize, j: usize| {
                element_rademacher(j as u32, i as u32, seed, STREAM_SKETCH) * inv
            };
            project_streamed_elem(z, b, &elem)
        }
        SketchKind::Dct | SketchKind::Dft => {
            let sel = row_selection(b, b_proj, seed);
            let signs = sign_flips(b, seed);
            let scale = (b as f32 / b_proj as f32).sqrt();
            let use_dct = kind == SketchKind::Dct;
            let elem = move |i: usize, j: usize| {
                // S[j, i]: output row j is the S row, input row i the S col
                let h = if use_dct {
                    dct_entry(sel[i], j, b)
                } else {
                    dft_entry(sel[i], j, b)
                };
                (scale * signs[j]) * h
            };
            project_streamed_elem(z, b, &elem)
        }
        SketchKind::RowSample => {
            let mut out = Tensor::zeros(b, n);
            if b == 0 {
                return out;
            }
            let sel = row_selection(b, b_proj, seed);
            let scale = (b as f32 / b_proj as f32).sqrt();
            for (j, &dst) in sel.iter().enumerate() {
                let zrow = z.row(j);
                for (o, &zv) in out.row_mut(dst).iter_mut().zip(zrow) {
                    *o += scale * zv;
                }
            }
            out
        }
        SketchKind::WtaCrs => {
            let mut out = Tensor::zeros(b, n);
            if b == 0 {
                return out;
            }
            for (j, &(dst, scale)) in wta_plan(b, b_proj, seed).iter().enumerate() {
                if scale == 0.0 {
                    continue;
                }
                let zrow = z.row(j);
                for (o, &zv) in out.row_mut(dst).iter_mut().zip(zrow) {
                    *o += scale * zv;
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::philox::PhiloxStream;
    use crate::tensor::{matmul, matmul_at, Tensor};

    fn randt(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut s = PhiloxStream::new(seed, 3);
        Tensor::from_fn(rows, cols, |_, _| s.next_normal())
    }

    #[test]
    fn transform_orthonormal() {
        for b in [4usize, 8, 16, 32] {
            for entry in [dct_entry as fn(usize, usize, usize) -> f32, dft_entry] {
                let h = Tensor::from_fn(b, b, |k, i| entry(k, i, b));
                let hh = matmul_bt_local(&h);
                for i in 0..b {
                    for j in 0..b {
                        let want = if i == j { 1.0 } else { 0.0 };
                        assert!(
                            (hh.at(i, j) - want).abs() < 2e-5,
                            "b={b} ({i},{j}) = {}",
                            hh.at(i, j)
                        );
                    }
                }
            }
        }
    }

    fn matmul_bt_local(h: &Tensor) -> Tensor {
        crate::tensor::matmul_bt(h, h)
    }

    #[test]
    fn unbiased_identity_montecarlo() {
        let (b, bp, trials) = (10, 5, 1500);
        for kind in SketchKind::ALL {
            let mut acc = Tensor::zeros(b, b);
            for t in 0..trials {
                let s = sketch(kind, b, bp, (t as u32 * 7919 + 3, 11));
                let sst = matmul(&s, &s.transpose());
                acc.add_assign(&sst);
            }
            acc.scale(1.0 / trials as f32);
            for i in 0..b {
                for j in 0..b {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!(
                        (acc.at(i, j) - want).abs() < 0.2,
                        "{kind:?} ({i},{j}) = {}",
                        acc.at(i, j)
                    );
                }
            }
        }
    }

    #[test]
    fn streamed_matches_dense() {
        let x = randt(24, 7, 5);
        for kind in SketchKind::ALL {
            let dense = matmul_at(&sketch(kind, 24, 9, (3, 4)), &x);
            let streamed = project_streamed(kind, &x, 9, (3, 4));
            assert!(dense.max_abs_diff(&streamed) < 1e-4, "{kind:?}");
        }
    }

    // NOTE: exact (bit-level) agreement of the fused tiled path with the
    // seed streaming loop is pinned in rust/tests/prop_kernels.rs — kept
    // in one place so the reference loop cannot drift.

    #[test]
    fn lift_matches_dense() {
        let z = randt(9, 7, 6);
        for kind in SketchKind::ALL {
            let s = sketch(kind, 24, 9, (3, 4));
            let dense = matmul(&s, &z);
            let lifted = lift_streamed(kind, &z, 24, (3, 4));
            assert!(dense.max_abs_diff(&lifted) < 1e-4, "{kind:?}");
        }
        // degenerate shapes stay silent
        for kind in SketchKind::ALL {
            let p = lift_streamed(kind, &Tensor::zeros(4, 0), 8, (1, 2));
            assert_eq!((p.rows, p.cols), (8, 0));
            let p = lift_streamed(kind, &Tensor::zeros(4, 3), 0, (1, 2));
            assert_eq!((p.rows, p.cols), (0, 3));
        }
    }

    #[test]
    fn parse_roundtrip() {
        for kind in SketchKind::ALL {
            assert_eq!(SketchKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(SketchKind::parse("bogus"), None);
    }

    #[test]
    fn parse_is_case_insensitive_and_errors_name_the_valid_set() {
        assert_eq!(SketchKind::parse("GAUSS"), Some(SketchKind::Gauss));
        assert_eq!(SketchKind::parse("WtaCrs"), Some(SketchKind::WtaCrs));
        assert_eq!(SketchKind::parse_or_err("DFT").unwrap(), SketchKind::Dft);
        let err = SketchKind::parse_or_err("bogus").unwrap_err().to_string();
        assert!(err.contains("'bogus'"), "{err}");
        for kind in SketchKind::ALL {
            assert!(err.contains(kind.name()), "{err}");
        }
    }

    #[test]
    fn wtacrs_structure() {
        // b=16, bp=8: c=4 distinct winners at scale 1, then m=4 stochastic
        // columns drawn from the 12 losers at scale sqrt(12/4).
        let plan = wta_plan(16, 8, (1, 2));
        assert_eq!(plan.len(), 8);
        let winners: Vec<usize> = plan[..4].iter().map(|p| p.0).collect();
        let mut uniq = winners.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), 4, "winners must be distinct: {winners:?}");
        for &(_, s) in &plan[..4] {
            assert_eq!(s, 1.0);
        }
        let scale = (12.0f32 / 4.0).sqrt();
        for &(src, s) in &plan[4..] {
            assert!((s - scale).abs() < 1e-6);
            assert!(src < 16);
            assert!(!winners.contains(&src), "draws must come from losers");
        }
        // dense S matches the plan exactly: one non-zero per column
        let s = sketch(SketchKind::WtaCrs, 16, 8, (1, 2));
        for (j, &(src, sc)) in plan.iter().enumerate() {
            for i in 0..16 {
                let want = if i == src { sc } else { 0.0 };
                assert_eq!(s.at(i, j), want, "({i},{j})");
            }
        }
        // b_proj ≥ 2b: winners cover every row, surplus columns are zero
        // and S Sᵀ = I exactly (zero-variance regime)
        let s = sketch(SketchKind::WtaCrs, 4, 10, (3, 4));
        let sst = matmul(&s, &s.transpose());
        for i in 0..4 {
            for j in 0..4 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((sst.at(i, j) - want).abs() < 1e-6, "({i},{j})");
            }
        }
    }

    #[test]
    fn rowsample_structure() {
        let s = sketch(SketchKind::RowSample, 16, 8, (1, 2));
        let scale = (16.0f32 / 8.0).sqrt();
        for j in 0..8 {
            let nz: Vec<f32> =
                (0..16).map(|i| s.at(i, j)).filter(|v| *v != 0.0).collect();
            assert_eq!(nz.len(), 1);
            assert!((nz[0] - scale).abs() < 1e-6);
        }
    }

    #[test]
    fn empty_shapes_do_not_panic() {
        for kind in SketchKind::ALL {
            let x = Tensor::zeros(8, 0);
            let p = project_streamed(kind, &x, 4, (1, 2));
            assert_eq!((p.rows, p.cols), (4, 0));
        }
        let x = Tensor::zeros(8, 3);
        let p = project_streamed(SketchKind::Gauss, &x, 0, (1, 2));
        assert_eq!((p.rows, p.cols), (0, 3));
        let empty = Tensor::zeros(0, 3);
        let p = project_streamed(SketchKind::RowSample, &empty, 4, (1, 2));
        assert_eq!(p.data, vec![0.0f32; 12]);
    }
}
