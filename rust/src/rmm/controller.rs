//! Closed-loop per-layer estimator selection (the ROADMAP's "closed-loop
//! variance control" item).
//!
//! Instead of fixing (family, ρ) on a static grid axis, the controller
//! prices every candidate configuration *online* with the Lemma-2.2
//! closed forms in [`super::variance`] — exact forms for Gauss and the
//! sampling families, the paper's generic form for the SRHT-like
//! transforms — and selects the minimum-variance configuration whose
//! projected residual fits a per-step memory budget (`--mem-budget`,
//! config `rmm.mem_budget`: the allowed fraction of the exact ρ=1
//! residual).
//!
//! Determinism contract: `choose` is a pure function of (probe tensors,
//! budget, candidate sets).  The probe tensors in the sweep's `budget`
//! grid are Philox-generated from the cell seed, so a run's whole choice
//! sequence is a pure function of the cell and can be recorded in the
//! fragment JSON without breaking the byte-identity invariants — the
//! tie-break is "first candidate wins" in the fixed
//! families-outer/ρ-inner scan order, never a float ULP race.

use super::sketch::SketchKind;
use super::variance;
use crate::tensor::Tensor;
use crate::util::json::Json;

/// Default candidate compression ratios, scanned in this order
/// (descending memory, matching the sweep grids' ρ axis plus one finer
/// step).
pub const RHO_CANDIDATES: [f64; 5] = [1.0, 0.5, 0.2, 0.1, 0.05];

/// Bytes per stored f32.
const F32: usize = 4;

/// `b_proj` for a compression ratio — must stay identical to
/// `memory::accounting::MemoryModel::b_proj` so the controller prices the
/// same projection the tape would actually store.
pub fn b_proj_for(rho: f64, rows: usize) -> usize {
    if rho >= 1.0 {
        rows
    } else {
        ((rho * rows as f64).round() as usize).clamp(1, rows)
    }
}

/// One per-layer decision: the winning configuration and its price tags.
#[derive(Debug, Clone, PartialEq)]
pub struct Choice {
    pub family: SketchKind,
    /// Grad-input path kept exact (approximate-VJP mode, arXiv 2602.14701)
    /// — carried from the controller's configured per-path mode.
    pub approx_vjp: bool,
    pub rho: f64,
    pub b_proj: usize,
    /// Closed-form grad-weight variance of the winning configuration.
    pub d2: f64,
    /// Residual bytes this choice stores for the layer (b_proj · N · 4).
    pub bytes: usize,
}

impl Choice {
    /// The sweep's sketch-string form of this choice ("gauss",
    /// "avjp-wtacrs", …).
    pub fn estimator_name(&self) -> String {
        if self.approx_vjp {
            format!("avjp-{}", self.family.name())
        } else {
            self.family.name().to_string()
        }
    }

    pub fn to_json(&self) -> Json {
        let d2 = if self.d2.is_finite() {
            Json::num(self.d2)
        } else {
            Json::Null // non-finite metrics serialize as null, never NaN
        };
        Json::obj(vec![
            ("estimator", Json::str(self.estimator_name())),
            ("rho", Json::num(self.rho)),
            ("b_proj", Json::num(self.b_proj as f64)),
            ("d2", d2),
            ("bytes", Json::num(self.bytes as f64)),
        ])
    }
}

/// Per-layer closed-loop controller.
#[derive(Debug, Clone)]
pub struct Controller {
    /// Allowed residual fraction of the exact (ρ=1) layer store, in (0, 1].
    pub mem_budget: f64,
    /// Candidate families, scanned in order (outer loop).
    pub families: Vec<SketchKind>,
    /// Candidate ratios, scanned in order (inner loop).
    pub rhos: Vec<f64>,
    /// When true, every choice runs in approximate-VJP mode (sketch only
    /// on the grad-weight path, exact grad-input).
    pub approx_vjp: bool,
}

impl Controller {
    /// All six families over [`RHO_CANDIDATES`] under `mem_budget`.
    pub fn new(mem_budget: f64) -> Controller {
        Controller {
            mem_budget,
            families: SketchKind::ALL.to_vec(),
            rhos: RHO_CANDIDATES.to_vec(),
            approx_vjp: false,
        }
    }

    /// Price one (family, ρ) candidate on probe tensors X:(B,N), Y:(B,M):
    /// the closed-form grad-weight variance plus the residual bytes the
    /// tape would store.  `choose` scans these; the `budget` bench cells
    /// also price *fixed* estimator configurations through the same path
    /// so controller rows and fixed rows are directly comparable.
    pub fn price(&self, family: SketchKind, rho: f64, x: &Tensor, y: &Tensor) -> Choice {
        let b_proj = b_proj_for(rho, x.rows);
        Choice {
            family,
            approx_vjp: self.approx_vjp,
            rho,
            b_proj,
            d2: variance::d2_family(family, x, y, b_proj),
            bytes: b_proj * x.cols * F32,
        }
    }

    /// Pick the minimum-variance feasible configuration for one layer,
    /// given probe tensors X:(B,N), Y:(B,M) standing in for the stored
    /// activation and the incoming gradient.  If the budget admits no
    /// candidate (budget < 1/B), fall back to the cheapest one so the
    /// trainer still has a defined estimator — the fallback is equally
    /// deterministic.
    pub fn choose(&self, x: &Tensor, y: &Tensor) -> Choice {
        let rows = x.rows;
        let budget_rows = self.mem_budget * rows as f64 + 1e-9;
        let mut best: Option<Choice> = None;
        let mut fallback: Option<Choice> = None;
        for &family in &self.families {
            for &rho in &self.rhos {
                let cand = self.price(family, rho, x, y);
                match &fallback {
                    Some(f) if cand.b_proj >= f.b_proj => {}
                    _ => fallback = Some(cand.clone()),
                }
                if (cand.b_proj as f64) > budget_rows {
                    continue; // over budget
                }
                // strict less: the first candidate in scan order wins ties
                match &best {
                    Some(b) if cand.d2 >= b.d2 => {}
                    _ => best = Some(cand),
                }
            }
        }
        best.or(fallback)
            .expect("controller needs non-empty candidate sets")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::philox::PhiloxStream;

    fn randt(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut s = PhiloxStream::new(seed, 3);
        Tensor::from_fn(rows, cols, |_, _| s.next_normal())
    }

    #[test]
    fn pick_minimizes_over_all_feasible_candidates() {
        // With the whole residual allowed every (family, ρ) is feasible,
        // so the pick must price at or below the entire candidate grid.
        // (At ρ=1 that's WTA-CRS in practice: half the columns become
        // deterministic winners, cutting the stochastic pool in half.)
        let x = randt(32, 5, 1);
        let y = randt(32, 4, 2);
        let ctl = Controller::new(1.0);
        let pick = ctl.choose(&x, &y);
        for family in SketchKind::ALL {
            for &rho in &RHO_CANDIDATES {
                let bp = b_proj_for(rho, 32);
                assert!(
                    pick.d2 <= variance::d2_family(family, &x, &y, bp) + 1e-12,
                    "{family:?} rho={rho} beats the pick"
                );
            }
        }
        assert!(pick.bytes <= 32 * 5 * 4);
    }

    #[test]
    fn budget_constrains_bytes() {
        let x = randt(40, 6, 3);
        let y = randt(40, 3, 4);
        for budget in [1.0, 0.5, 0.2, 0.1] {
            let pick = Controller::new(budget).choose(&x, &y);
            assert!(
                pick.b_proj as f64 <= budget * 40.0 + 1e-9,
                "budget={budget} b_proj={}",
                pick.b_proj
            );
        }
    }

    #[test]
    fn impossible_budget_falls_back_to_cheapest() {
        let x = randt(8, 4, 5);
        let y = randt(8, 4, 6);
        // 0.05·8 = 0.4 rows: nothing feasible, fall back to b_proj = 1
        let pick = Controller::new(0.05).choose(&x, &y);
        assert_eq!(pick.b_proj, 1);
    }

    #[test]
    fn choice_is_deterministic_and_json_stable() {
        let x = randt(24, 5, 7);
        let y = randt(24, 4, 8);
        let a = Controller::new(0.3).choose(&x, &y);
        let b = Controller::new(0.3).choose(&x, &y);
        assert_eq!(a, b);
        assert_eq!(a.to_json().to_string_pretty(), b.to_json().to_string_pretty());
    }

    #[test]
    fn approx_vjp_mode_is_carried_into_the_choice() {
        let x = randt(16, 3, 9);
        let y = randt(16, 3, 10);
        let mut ctl = Controller::new(0.5);
        ctl.approx_vjp = true;
        let pick = ctl.choose(&x, &y);
        assert!(pick.approx_vjp);
        assert!(pick.estimator_name().starts_with("avjp-"));
    }
}
