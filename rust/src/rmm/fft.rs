//! Fast transforms: radix-2 complex FFT, fast orthonormal DCT-II and the
//! O(B log B) SORS projection path (paper §3.5's "theoretical computational
//! advantage" made concrete).
//!
//! The Pallas kernels express the transforms as structured matmuls (the
//! MXU-friendly form); this module provides the asymptotically-fast host
//! implementation so the crossover between O(B²N) dense sketching and
//! O(BN log B) structured sketching can actually be *measured*
//! (`rust/benches/fft_crossover.rs`).

use crate::rmm::sketch::{row_selection, sign_flips};
use crate::tensor::Tensor;

/// In-place iterative radix-2 Cooley-Tukey FFT over (re, im) pairs.
/// `n` must be a power of two.
pub fn fft_inplace(re: &mut [f64], im: &mut [f64]) {
    let n = re.len();
    assert_eq!(n, im.len());
    assert!(n.is_power_of_two(), "radix-2 FFT needs a power-of-two length");
    // bit reversal
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let mut len = 2;
    while len <= n {
        let ang = -2.0 * std::f64::consts::PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let (mut cur_r, mut cur_i) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let (ur, ui) = (re[i + k], im[i + k]);
                let (vr, vi) = (
                    re[i + k + len / 2] * cur_r - im[i + k + len / 2] * cur_i,
                    re[i + k + len / 2] * cur_i + im[i + k + len / 2] * cur_r,
                );
                re[i + k] = ur + vr;
                im[i + k] = ui + vi;
                re[i + k + len / 2] = ur - vr;
                im[i + k + len / 2] = ui - vi;
                let nr = cur_r * wr - cur_i * wi;
                cur_i = cur_r * wi + cur_i * wr;
                cur_r = nr;
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Orthonormal real-DFT coefficients of a real vector, in the row layout of
/// `dft_entry` (DC, cos/sin pairs, Nyquist), computed in O(n log n).
pub fn real_dft_ortho(x: &[f32]) -> Vec<f32> {
    let n = x.len();
    assert!(n.is_power_of_two() && n >= 2);
    let mut re: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    let mut im = vec![0.0f64; n];
    fft_inplace(&mut re, &mut im);
    let mut out = vec![0.0f32; n];
    let s1 = 1.0 / (n as f64).sqrt();
    let s2 = (2.0 / n as f64).sqrt();
    out[0] = (re[0] * s1) as f32;
    for m in 1..n / 2 {
        // row 2m−1: sqrt(2/n)·cos(2πmi/n) → Re F[m]; row 2m: sin → −Im F[m]
        out[2 * m - 1] = (re[m] * s2) as f32;
        out[2 * m] = (-im[m] * s2) as f32;
    }
    out[n - 1] = (re[n / 2] * s1) as f32;
    out
}

/// Fast orthonormal DCT-II via a length-n FFT of the even-odd permuted
/// sequence (Makhoul's method), O(n log n).
pub fn dct2_ortho(x: &[f32]) -> Vec<f32> {
    let n = x.len();
    assert!(n.is_power_of_two() && n >= 2);
    // v[i] = x[2i], v[n-1-i] = x[2i+1]
    let mut re = vec![0.0f64; n];
    let mut im = vec![0.0f64; n];
    for i in 0..n / 2 {
        re[i] = x[2 * i] as f64;
        re[n - 1 - i] = x[2 * i + 1] as f64;
    }
    fft_inplace(&mut re, &mut im);
    let mut out = vec![0.0f32; n];
    for k in 0..n {
        let ang = -std::f64::consts::PI * k as f64 / (2.0 * n as f64);
        let val = re[k] * ang.cos() - im[k] * ang.sin();
        let scale = if k == 0 {
            (1.0 / n as f64).sqrt()
        } else {
            (2.0 / n as f64).sqrt()
        };
        out[k] = (val * scale) as f32;
    }
    out
}

/// O(B·N·log B) SORS projection: X_proj = sqrt(B/B_proj)·Rᵀ·H·D·X computed
/// column-wise with the fast transform (B must be a power of two).
///
/// Columns are independent, so they are fanned out over the kernel thread
/// pool in contiguous bands; each band scatters into the shared output
/// afterwards (per-column results are identical to the serial loop).
pub fn sors_project_fast(
    use_dct: bool,
    x: &Tensor,
    b_proj: usize,
    seed: (u32, u32),
) -> Tensor {
    let (b, n) = (x.rows, x.cols);
    assert!(b.is_power_of_two());
    let sel = row_selection(b, b_proj, seed);
    let signs = sign_flips(b, seed);
    let scale = (b as f32 / b_proj as f32).sqrt();
    let mut out = Tensor::zeros(b_proj, n);
    if n == 0 || b_proj == 0 {
        return out;
    }

    // Spawn threads only when the transform work dwarfs spawn/join cost —
    // the crossover bench starts at B=64 where per-column FFTs are ~µs,
    // and inflating that regime would distort the very crossover measured.
    let work = n as f64 * b as f64 * (b as f64).log2().max(1.0);
    let nt = if work < 2.0e5 {
        1
    } else {
        crate::tensor::kernels::threads::num_threads().min(n)
    };

    if nt <= 1 {
        // Serial path: write straight into the output, no staging buffer.
        let mut col = vec![0.0f32; b];
        for c in 0..n {
            for i in 0..b {
                col[i] = signs[i] * x.at(i, c);
            }
            let coeffs = if use_dct { dct2_ortho(&col) } else { real_dft_ortho(&col) };
            for (j, &s) in sel.iter().enumerate() {
                *out.at_mut(j, c) = scale * coeffs[s];
            }
        }
        return out;
    }

    // Parallel path: contiguous column bands, each worker returning the
    // selected coefficients in column-major band layout
    // (local_c * b_proj + j), scattered into `out` afterwards.
    let band_coeffs = |c0: usize, c1: usize| -> Vec<f32> {
        let mut res = vec![0.0f32; (c1 - c0) * b_proj];
        let mut col = vec![0.0f32; b];
        for c in c0..c1 {
            for i in 0..b {
                col[i] = signs[i] * x.at(i, c);
            }
            let coeffs = if use_dct { dct2_ortho(&col) } else { real_dft_ortho(&col) };
            let dst = &mut res[(c - c0) * b_proj..(c - c0 + 1) * b_proj];
            for (d, &s) in dst.iter_mut().zip(&sel) {
                *d = scale * coeffs[s];
            }
        }
        res
    };
    let bands: Vec<(usize, usize)> = (0..nt)
        .map(|t| {
            let base = n / nt;
            let extra = n % nt;
            let c0 = t * base + t.min(extra);
            let c1 = c0 + base + usize::from(t < extra);
            (c0, c1)
        })
        .collect();
    let results: Vec<Vec<f32>> = std::thread::scope(|s| {
        let handles: Vec<_> = bands
            .iter()
            .map(|&(c0, c1)| s.spawn(move || band_coeffs(c0, c1)))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (&(c0, c1), res) in bands.iter().zip(&results) {
        for c in c0..c1 {
            let src = &res[(c - c0) * b_proj..(c - c0 + 1) * b_proj];
            for (j, &v) in src.iter().enumerate() {
                *out.at_mut(j, c) = v;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rmm::sketch::{dct_entry, dft_entry, sketch, SketchKind};
    use crate::rng::philox::PhiloxStream;
    use crate::tensor::matmul_at;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut s = PhiloxStream::new(seed, 3);
        (0..n).map(|_| s.next_normal()).collect()
    }

    #[test]
    fn fft_matches_dft_definition() {
        let n = 16;
        let x = randv(n, 1);
        let mut re: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let mut im = vec![0.0; n];
        fft_inplace(&mut re, &mut im);
        for k in 0..n {
            let (mut sr, mut si) = (0.0f64, 0.0f64);
            for (i, &v) in x.iter().enumerate() {
                let ang = -2.0 * std::f64::consts::PI * (k * i) as f64 / n as f64;
                sr += v as f64 * ang.cos();
                si += v as f64 * ang.sin();
            }
            assert!((re[k] - sr).abs() < 1e-8, "k={k}");
            assert!((im[k] - si).abs() < 1e-8, "k={k}");
        }
    }

    #[test]
    fn real_dft_matches_matrix() {
        for n in [4usize, 8, 32] {
            let x = randv(n, 2);
            let fast = real_dft_ortho(&x);
            for k in 0..n {
                let slow: f32 = (0..n).map(|i| dft_entry(k, i, n) * x[i]).sum();
                assert!((fast[k] - slow).abs() < 1e-4, "n={n} k={k}");
            }
        }
    }

    #[test]
    fn dct_matches_matrix() {
        for n in [4usize, 8, 64] {
            let x = randv(n, 3);
            let fast = dct2_ortho(&x);
            for k in 0..n {
                let slow: f32 = (0..n).map(|i| dct_entry(k, i, n) * x[i]).sum();
                assert!((fast[k] - slow).abs() < 1e-4, "n={n} k={k}");
            }
        }
    }

    #[test]
    fn fast_sors_matches_dense_sketch() {
        let mut s = PhiloxStream::new(9, 3);
        let x = Tensor::from_fn(32, 5, |_, _| s.next_normal());
        for (kind, use_dct) in [(SketchKind::Dct, true), (SketchKind::Dft, false)] {
            let dense = matmul_at(&sketch(kind, 32, 12, (5, 6)), &x);
            let fast = sors_project_fast(use_dct, &x, 12, (5, 6));
            assert!(dense.max_abs_diff(&fast) < 1e-4, "{kind:?}");
        }
    }

    #[test]
    #[should_panic]
    fn fft_rejects_non_power_of_two() {
        let mut re = vec![0.0; 6];
        let mut im = vec![0.0; 6];
        fft_inplace(&mut re, &mut im);
    }
}
