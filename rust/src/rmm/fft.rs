//! Fast transforms: radix-2 complex FFT, fast orthonormal DCT-II and the
//! O(B log B) SORS projection path (paper §3.5's "theoretical computational
//! advantage" made concrete).
//!
//! The Pallas kernels express the transforms as structured matmuls (the
//! MXU-friendly form); this module provides the asymptotically-fast host
//! implementation so the crossover between O(B²N) dense sketching and
//! O(BN log B) structured sketching can actually be *measured*
//! (`rust/benches/fft_crossover.rs`).
//!
//! # Batched SORS
//!
//! [`sors_project_fast`] no longer transforms X column-by-column: columns
//! are grouped into [`FFT_PANEL_W`]-wide panels, each panel is one task on
//! the persistent work-stealing pool (`tensor::pool`), and
//! [`fft_panel_inplace`] runs the butterfly schedule once per panel with
//! the column index as the unit-stride inner loop — twiddle factors are
//! computed once per (stage, k) instead of once per column, and the
//! per-lane arithmetic vectorizes.  Every lane executes *exactly* the
//! f64 operation sequence of the scalar [`fft_inplace`], so the batched
//! path is **bit-identical** to the column-by-column reference
//! ([`sors_project_cols`], kept for the crossover bench and the equality
//! tests) for any panel width, task grain and thread count.

use crate::rmm::sketch::{row_selection, sign_flips};
use crate::tensor::kernels::threads;
use crate::tensor::pool::{self, SharedMut};
use crate::tensor::Tensor;

/// In-place iterative radix-2 Cooley-Tukey FFT over (re, im) pairs.
/// `n` must be a power of two.
pub fn fft_inplace(re: &mut [f64], im: &mut [f64]) {
    let n = re.len();
    assert_eq!(n, im.len());
    assert!(n.is_power_of_two(), "radix-2 FFT needs a power-of-two length");
    // bit reversal
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let mut len = 2;
    while len <= n {
        let ang = -2.0 * std::f64::consts::PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let (mut cur_r, mut cur_i) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let (ur, ui) = (re[i + k], im[i + k]);
                let (vr, vi) = (
                    re[i + k + len / 2] * cur_r - im[i + k + len / 2] * cur_i,
                    re[i + k + len / 2] * cur_i + im[i + k + len / 2] * cur_r,
                );
                re[i + k] = ur + vr;
                im[i + k] = ui + vi;
                re[i + k + len / 2] = ur - vr;
                im[i + k + len / 2] = ui - vi;
                let nr = cur_r * wr - cur_i * wi;
                cur_i = cur_r * wi + cur_i * wr;
                cur_r = nr;
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Columns per batched-FFT panel (one pool task transforms one panel).
/// Eight f64 lanes keep a B=4096 panel's re+im working set ≈ 512 KiB and
/// give the stage loops a unit-stride inner dimension to vectorize over.
pub const FFT_PANEL_W: usize = 8;

/// Batched in-place radix-2 FFT over `w` interleaved complex sequences of
/// length `n` (layout: element `i` of lane `l` at `[i * w + l]`).
///
/// Runs the exact butterfly schedule of [`fft_inplace`] with an inner
/// loop over lanes; per lane the f64 operation sequence — bit-reversal
/// swaps, twiddle recurrence, butterfly adds — is identical to the scalar
/// code, so each lane's result is bit-identical to transforming that
/// column alone.
pub fn fft_panel_inplace(re: &mut [f64], im: &mut [f64], n: usize, w: usize) {
    assert!(w >= 1);
    assert_eq!(re.len(), n * w);
    assert_eq!(im.len(), n * w);
    assert!(n.is_power_of_two(), "radix-2 FFT needs a power-of-two length");
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            for l in 0..w {
                re.swap(i * w + l, j * w + l);
                im.swap(i * w + l, j * w + l);
            }
        }
    }
    let mut len = 2;
    while len <= n {
        let ang = -2.0 * std::f64::consts::PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let (mut cur_r, mut cur_i) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let u = (i + k) * w;
                let v = (i + k + len / 2) * w;
                for l in 0..w {
                    let (ur, ui) = (re[u + l], im[u + l]);
                    let (vr, vi) = (
                        re[v + l] * cur_r - im[v + l] * cur_i,
                        re[v + l] * cur_i + im[v + l] * cur_r,
                    );
                    re[u + l] = ur + vr;
                    im[u + l] = ui + vi;
                    re[v + l] = ur - vr;
                    im[v + l] = ui - vi;
                }
                let nr = cur_r * wr - cur_i * wi;
                cur_i = cur_r * wi + cur_i * wr;
                cur_r = nr;
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Orthonormal real-DFT coefficients of a real vector, in the row layout of
/// `dft_entry` (DC, cos/sin pairs, Nyquist), computed in O(n log n).
pub fn real_dft_ortho(x: &[f32]) -> Vec<f32> {
    let n = x.len();
    assert!(n.is_power_of_two() && n >= 2);
    let mut re: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    let mut im = vec![0.0f64; n];
    fft_inplace(&mut re, &mut im);
    let mut out = vec![0.0f32; n];
    let s1 = 1.0 / (n as f64).sqrt();
    let s2 = (2.0 / n as f64).sqrt();
    out[0] = (re[0] * s1) as f32;
    for m in 1..n / 2 {
        // row 2m−1: sqrt(2/n)·cos(2πmi/n) → Re F[m]; row 2m: sin → −Im F[m]
        out[2 * m - 1] = (re[m] * s2) as f32;
        out[2 * m] = (-im[m] * s2) as f32;
    }
    out[n - 1] = (re[n / 2] * s1) as f32;
    out
}

/// Fast orthonormal DCT-II via a length-n FFT of the even-odd permuted
/// sequence (Makhoul's method), O(n log n).
pub fn dct2_ortho(x: &[f32]) -> Vec<f32> {
    let n = x.len();
    assert!(n.is_power_of_two() && n >= 2);
    // v[i] = x[2i], v[n-1-i] = x[2i+1]
    let mut re = vec![0.0f64; n];
    let mut im = vec![0.0f64; n];
    for i in 0..n / 2 {
        re[i] = x[2 * i] as f64;
        re[n - 1 - i] = x[2 * i + 1] as f64;
    }
    fft_inplace(&mut re, &mut im);
    let mut out = vec![0.0f32; n];
    for k in 0..n {
        let ang = -std::f64::consts::PI * k as f64 / (2.0 * n as f64);
        let val = re[k] * ang.cos() - im[k] * ang.sin();
        let scale = if k == 0 {
            (1.0 / n as f64).sqrt()
        } else {
            (2.0 / n as f64).sqrt()
        };
        out[k] = (val * scale) as f32;
    }
    out
}

/// Below this `N·B·log₂B` work estimate the transform stays on the
/// caller's thread — the crossover bench starts at B=64 where per-column
/// FFTs are ~µs, and inflating that regime would distort the very
/// crossover being measured.
const PAR_WORK_THRESHOLD: f64 = 2.0e5;

/// O(B·N·log B) SORS projection, batched: X_proj = sqrt(B/B_proj)·Rᵀ·H·D·X
/// with columns transformed a panel at a time (B must be a power of two,
/// ≥ 2).  Panels are pool tasks writing disjoint column ranges of the
/// output; results are bit-identical to [`sors_project_cols`] for any
/// thread count.
pub fn sors_project_fast(
    use_dct: bool,
    x: &Tensor,
    b_proj: usize,
    seed: (u32, u32),
) -> Tensor {
    let (b, n) = (x.rows, x.cols);
    assert!(b.is_power_of_two() && b >= 2, "SORS fast path needs power-of-two B >= 2");
    let sel = row_selection(b, b_proj, seed);
    let signs = sign_flips(b, seed);
    let scale = (b as f32 / b_proj as f32).sqrt();
    let mut out = Tensor::zeros(b_proj, n);
    if n == 0 || b_proj == 0 {
        return out;
    }
    let panels = (n + FFT_PANEL_W - 1) / FFT_PANEL_W;
    let work = n as f64 * b as f64 * (b as f64).log2().max(1.0);
    let nt = if work < PAR_WORK_THRESHOLD { 1 } else { threads::num_threads().min(panels) };
    let optr = SharedMut::new(out.data.as_mut_ptr());
    let (sel, signs) = (&sel, &signs);
    pool::global().run(nt, panels, |p| {
        let c0 = p * FFT_PANEL_W;
        let w = FFT_PANEL_W.min(n - c0);
        sors_panel(use_dct, x, c0, w, signs, sel, scale, optr, n, b);
    });
    out
}

/// Column-by-column SORS projection (the PR-1 serial path): one scalar
/// FFT/DCT per column via [`real_dft_ortho`] / [`dct2_ortho`].  Kept as
/// the reference the batched path is pinned against — exactly, not
/// approximately — and as the "before" side of the crossover bench.
pub fn sors_project_cols(
    use_dct: bool,
    x: &Tensor,
    b_proj: usize,
    seed: (u32, u32),
) -> Tensor {
    let (b, n) = (x.rows, x.cols);
    assert!(b.is_power_of_two() && b >= 2, "SORS fast path needs power-of-two B >= 2");
    let sel = row_selection(b, b_proj, seed);
    let signs = sign_flips(b, seed);
    let scale = (b as f32 / b_proj as f32).sqrt();
    let mut out = Tensor::zeros(b_proj, n);
    if n == 0 || b_proj == 0 {
        return out;
    }
    let mut col = vec![0.0f32; b];
    for c in 0..n {
        for (i, cv) in col.iter_mut().enumerate() {
            *cv = signs[i] * x.at(i, c);
        }
        let coeffs = if use_dct { dct2_ortho(&col) } else { real_dft_ortho(&col) };
        for (j, &s) in sel.iter().enumerate() {
            *out.at_mut(j, c) = scale * coeffs[s];
        }
    }
    out
}

/// Transform one `w`-column panel (columns `c0 .. c0 + w` of X) and
/// scatter the selected, scaled coefficient rows into the output.
///
/// Mirrors the scalar pipeline operation-for-operation: sign flip in f32,
/// widen to f64, batched FFT ([`fft_panel_inplace`]), the
/// [`real_dft_ortho`] / [`dct2_ortho`] post-processing per *selected* row
/// only (coefficients nobody selected are never finalized), cast to f32,
/// scale in f32.
#[allow(clippy::too_many_arguments)]
fn sors_panel(
    use_dct: bool,
    x: &Tensor,
    c0: usize,
    w: usize,
    signs: &[f32],
    sel: &[usize],
    scale: f32,
    out: SharedMut<f32>,
    n: usize,
    b: usize,
) {
    let mut re = vec![0.0f64; b * w];
    let mut im = vec![0.0f64; b * w];
    if use_dct {
        // Makhoul permutation of the sign-flipped columns:
        // v[i] = col[2i], v[b-1-i] = col[2i+1].
        for i in 0..b / 2 {
            let even = x.row(2 * i);
            let odd = x.row(2 * i + 1);
            for l in 0..w {
                re[i * w + l] = (signs[2 * i] * even[c0 + l]) as f64;
                re[(b - 1 - i) * w + l] = (signs[2 * i + 1] * odd[c0 + l]) as f64;
            }
        }
    } else {
        for i in 0..b {
            let row = x.row(i);
            for l in 0..w {
                re[i * w + l] = (signs[i] * row[c0 + l]) as f64;
            }
        }
    }
    fft_panel_inplace(&mut re, &mut im, b, w);

    if use_dct {
        for (j, &s) in sel.iter().enumerate() {
            let ang = -std::f64::consts::PI * s as f64 / (2.0 * b as f64);
            let (ca, sa) = (ang.cos(), ang.sin());
            let sc = if s == 0 {
                (1.0 / b as f64).sqrt()
            } else {
                (2.0 / b as f64).sqrt()
            };
            for l in 0..w {
                let val = re[s * w + l] * ca - im[s * w + l] * sa;
                let cf = (val * sc) as f32;
                // SAFETY: this task owns columns [c0, c0 + w) of every
                // output row; j*n + c0 + l is inside that region.
                unsafe {
                    *out.ptr().add(j * n + c0 + l) = scale * cf;
                }
            }
        }
    } else {
        let s1 = 1.0 / (b as f64).sqrt();
        let s2 = (2.0 / b as f64).sqrt();
        for (j, &s) in sel.iter().enumerate() {
            for l in 0..w {
                // Row layout of `real_dft_ortho`: DC, (cos, sin) pairs,
                // Nyquist (b is even, so row b−1 is the Nyquist row).
                let cf = if s == 0 {
                    (re[l] * s1) as f32
                } else if s == b - 1 {
                    (re[(b / 2) * w + l] * s1) as f32
                } else if s % 2 == 1 {
                    (re[((s + 1) / 2) * w + l] * s2) as f32
                } else {
                    (-im[(s / 2) * w + l] * s2) as f32
                };
                // SAFETY: as above — disjoint column range per task.
                unsafe {
                    *out.ptr().add(j * n + c0 + l) = scale * cf;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rmm::sketch::{dct_entry, dft_entry, sketch, SketchKind};
    use crate::rng::philox::PhiloxStream;
    use crate::tensor::matmul_at;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut s = PhiloxStream::new(seed, 3);
        (0..n).map(|_| s.next_normal()).collect()
    }

    fn randt(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut s = PhiloxStream::new(seed, 3);
        Tensor::from_fn(rows, cols, |_, _| s.next_normal())
    }

    #[test]
    fn fft_matches_dft_definition() {
        let n = 16;
        let x = randv(n, 1);
        let mut re: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let mut im = vec![0.0; n];
        fft_inplace(&mut re, &mut im);
        for k in 0..n {
            let (mut sr, mut si) = (0.0f64, 0.0f64);
            for (i, &v) in x.iter().enumerate() {
                let ang = -2.0 * std::f64::consts::PI * (k * i) as f64 / n as f64;
                sr += v as f64 * ang.cos();
                si += v as f64 * ang.sin();
            }
            assert!((re[k] - sr).abs() < 1e-8, "k={k}");
            assert!((im[k] - si).abs() < 1e-8, "k={k}");
        }
    }

    #[test]
    fn panel_fft_is_bit_identical_to_scalar_fft_per_lane() {
        let (n, w) = (32usize, 3usize);
        let src = randv(n * w, 4);
        let mut pre: Vec<f64> = src.iter().map(|&v| v as f64).collect();
        let mut pim = vec![0.0f64; n * w];
        fft_panel_inplace(&mut pre, &mut pim, n, w);
        for l in 0..w {
            let mut re: Vec<f64> = (0..n).map(|i| src[i * w + l] as f64).collect();
            let mut im = vec![0.0f64; n];
            fft_inplace(&mut re, &mut im);
            for i in 0..n {
                assert_eq!(pre[i * w + l], re[i], "lane {l} re[{i}]");
                assert_eq!(pim[i * w + l], im[i], "lane {l} im[{i}]");
            }
        }
    }

    #[test]
    fn real_dft_matches_matrix() {
        for n in [4usize, 8, 32] {
            let x = randv(n, 2);
            let fast = real_dft_ortho(&x);
            for k in 0..n {
                let slow: f32 = (0..n).map(|i| dft_entry(k, i, n) * x[i]).sum();
                assert!((fast[k] - slow).abs() < 1e-4, "n={n} k={k}");
            }
        }
    }

    #[test]
    fn dct_matches_matrix() {
        for n in [4usize, 8, 64] {
            let x = randv(n, 3);
            let fast = dct2_ortho(&x);
            for k in 0..n {
                let slow: f32 = (0..n).map(|i| dct_entry(k, i, n) * x[i]).sum();
                assert!((fast[k] - slow).abs() < 1e-4, "n={n} k={k}");
            }
        }
    }

    #[test]
    fn batched_sors_exactly_matches_column_reference() {
        // Exact (bit-level) equality: every panel lane runs the same f64
        // op sequence as the scalar per-column pipeline.  Shapes cover
        // partial panels (n % FFT_PANEL_W != 0), n < panel, b_proj > b.
        for &(b, n, bp) in &[
            (2usize, 3usize, 2usize),
            (4, 1, 7),
            (32, 5, 12),
            (64, 8, 16),
            (64, 19, 64),
            (256, 9, 32),
        ] {
            let x = randt(b, n, b as u64 + n as u64);
            for use_dct in [true, false] {
                let cols = sors_project_cols(use_dct, &x, bp, (5, 6));
                let fast = sors_project_fast(use_dct, &x, bp, (5, 6));
                assert_eq!(cols.data, fast.data, "b={b} n={n} bp={bp} dct={use_dct}");
            }
        }
    }

    #[test]
    fn fast_sors_matches_dense_sketch() {
        let mut s = PhiloxStream::new(9, 3);
        let x = Tensor::from_fn(32, 5, |_, _| s.next_normal());
        for (kind, use_dct) in [(SketchKind::Dct, true), (SketchKind::Dft, false)] {
            let dense = matmul_at(&sketch(kind, 32, 12, (5, 6)), &x);
            let fast = sors_project_fast(use_dct, &x, 12, (5, 6));
            assert!(dense.max_abs_diff(&fast) < 1e-4, "{kind:?}");
        }
    }

    #[test]
    fn empty_sors_shapes() {
        let x = Tensor::zeros(8, 0);
        let p = sors_project_fast(true, &x, 4, (1, 2));
        assert_eq!((p.rows, p.cols), (4, 0));
        let x = Tensor::zeros(8, 3);
        let p = sors_project_fast(false, &x, 0, (1, 2));
        assert_eq!((p.rows, p.cols), (0, 3));
    }

    #[test]
    #[should_panic]
    fn fft_rejects_non_power_of_two() {
        let mut re = vec![0.0; 6];
        let mut im = vec![0.0; 6];
        fft_inplace(&mut re, &mut im);
    }
}
