//! Variance estimators of Section 2.3 — Lemma 2.1 (aposteriori SGD
//! variance), Lemma 2.2 (apriori RMM variance), Theorem 2.3 (ratio bound).
//! Mirrors `python/compile/variance.py` / `kernels/ref.py`; the property
//! tests here and in `rust/tests/prop_variance.rs` are the Rust-side proof
//! obligations for the paper's theory.

use crate::tensor::{matmul_at, Tensor};

/// Lemma 2.1, eq. (9): D²_SGD(X, Y) for X:(B,N), Y:(B,M).
pub fn d2_sgd(x: &Tensor, y: &Tensor) -> f64 {
    assert_eq!(x.rows, y.rows, "X and Y must share the batch dimension");
    let b = x.rows as f64;
    assert!(x.rows > 1, "Lemma 2.1 needs B > 1");
    let mut row_term = 0.0f64;
    for k in 0..x.rows {
        row_term += x.row_norm2(k) * y.row_norm2(k);
    }
    let fro2 = matmul_at(x, y).fro2();
    (b / (b - 1.0)) * row_term - fro2 / (b - 1.0)
}

/// Lemma 2.2, eq. (11): D²_RMM(X, Y) — *as stated in the paper*.
///
/// NOTE (soundness finding, see EXPERIMENTS.md §Discrepancies): the
/// paper's proof of eq. (36) uses E[C²_li C²_pi] = E[C²]E[C²] for l = p,
/// which drops the Gaussian excess kurtosis (E[C⁴] = 3σ⁴).  The exact
/// Gaussian-sketch variance is [`d2_rmm_exact`] — same leading term, with
/// +‖XᵀY‖²_F instead of −‖XᵀY‖²_F.  In the regime the paper studies
/// (α = ‖XᵀY‖²/(‖X‖²‖Y‖²) ≪ 1 during training) the two agree to O(α),
/// which is why their empirical Fig. 4 looks consistent.  We expose both:
/// the paper's form reproduces Fig. 4/7, the exact form is pinned against
/// Monte-Carlo in the tests.
pub fn d2_rmm(x: &Tensor, y: &Tensor, b_proj: usize) -> f64 {
    assert_eq!(x.rows, y.rows);
    let fro2 = matmul_at(x, y).fro2();
    (x.fro2() * y.fro2() - fro2) / b_proj as f64
}

/// Exact apriori variance of the Gaussian-sketch RMM:
/// D² = (‖X‖²_F ‖Y‖²_F + ‖XᵀY‖²_F) / B_proj   (fourth moment included).
pub fn d2_rmm_exact(x: &Tensor, y: &Tensor, b_proj: usize) -> f64 {
    assert_eq!(x.rows, y.rows);
    let fro2 = matmul_at(x, y).fro2();
    (x.fro2() * y.fro2() + fro2) / b_proj as f64
}

/// Eq. (13): correlation ratio α ∈ [0, 1].
pub fn alpha(x: &Tensor, y: &Tensor) -> f64 {
    let den = x.fro2() * y.fro2();
    if den <= 0.0 {
        return 0.0;
    }
    matmul_at(x, y).fro2() / den
}

/// LHS of Theorem 2.3's inequality (12).
///
/// NOTE (second soundness finding, EXPERIMENTS.md §Discrepancies): the
/// paper's proof drops a +2‖X‖²‖Y‖² term between eqs. (43) and (45), so
/// the stated bound `lhs ≤ (α+1)/α` is false in general (counterexample
/// pinned in the tests).  The exact statement is the identity
/// [`theorem_identity_gap`]; in the training regime (many iid-ish rows)
/// the dropped term is dominated and the bound holds empirically — which
/// the Fig. 4 driver and the variance_monitor example confirm.
pub fn ratio_lhs(x: &Tensor, y: &Tensor, b_proj: usize) -> f64 {
    let d2s = d2_sgd(x, y);
    if d2s <= 0.0 {
        return f64::INFINITY;
    }
    (b_proj as f64 / (x.rows as f64 - 1.0)) * d2_rmm(x, y, b_proj) / d2s
}

/// RHS of Theorem 2.3's inequality: (α + 1)/α.
pub fn bound_rhs(x: &Tensor, y: &Tensor) -> f64 {
    let a = alpha(x, y);
    if a <= 0.0 {
        f64::INFINITY
    } else {
        (a + 1.0) / a
    }
}

/// The exact Theorem-2.3 identity:
/// `B_proj·D²_RMM − (B−1)·((α+1)/α)·D²_SGD = 2‖X‖²‖Y‖² − B·((α+1)/α)·Σ_k‖x_k‖²‖y_k‖²`.
/// Returns (lhs, rhs) of that identity for verification.
pub fn theorem_identity_gap(x: &Tensor, y: &Tensor, b_proj: usize) -> (f64, f64) {
    let b = x.rows as f64;
    let a = alpha(x, y);
    let factor = (a + 1.0) / a;
    let lhs = b_proj as f64 * d2_rmm(x, y, b_proj) - (b - 1.0) * factor * d2_sgd(x, y);
    let mut r = 0.0;
    for k in 0..x.rows {
        r += x.row_norm2(k) * y.row_norm2(k);
    }
    let rhs = 2.0 * x.fro2() * y.fro2() - b * factor * r;
    (lhs, rhs)
}

/// Σ_k ‖x_k‖²‖y_k‖² — the row-correlation term shared by the sampling
/// estimators' exact variances (Lemma 2.1's first term without the B/(B−1)
/// prefactor).
fn row_norm_product_sum(x: &Tensor, y: &Tensor) -> f64 {
    assert_eq!(x.rows, y.rows);
    let mut r = 0.0f64;
    for k in 0..x.rows {
        r += x.row_norm2(k) * y.row_norm2(k);
    }
    r
}

/// Exact apriori variance of the uniform CRS / rowsample estimator:
/// B_proj iid uniform row draws at scale sqrt(B/B_proj), giving
/// D² = (B·Σ_k‖x_k‖²‖y_k‖² − ‖XᵀY‖²_F) / B_proj.
pub fn d2_rowsample(x: &Tensor, y: &Tensor, b_proj: usize) -> f64 {
    let b = x.rows as f64;
    let r = row_norm_product_sum(x, y);
    let fro2 = matmul_at(x, y).fro2();
    (b * r - fro2) / b_proj as f64
}

/// Exact apriori variance of the WTA-CRS estimator (arXiv 2305.15265,
/// uniform-mass data-independent form implemented in
/// [`super::sketch::wta_plan`]): c = min(B_proj/2, B) deterministic
/// distinct winner rows plus m = B_proj − c uniform draws (with
/// replacement) from the B − c losers.  With R = Σ_k‖x_k‖²‖y_k‖²,
/// F = ‖XᵀY‖²_F and t = B − c, the expectation over the uniformly random
/// winner subset gives
///
/// D² = (1/m)·[ t·(t/B)·R − ( (t/B)·R + t(t−1)/(B(B−1))·(F − R) ) ]
///
/// which reduces to the uniform-CRS form at c = 0 and to 0 when the
/// winners cover every row (B_proj ≥ 2B ⇒ S Sᵀ = I exactly).
pub fn d2_wtacrs(x: &Tensor, y: &Tensor, b_proj: usize) -> f64 {
    assert_eq!(x.rows, y.rows);
    let b = x.rows;
    let c = super::sketch::wta_winner_count(b, b_proj);
    if c >= b {
        return 0.0;
    }
    let m = (b_proj - c) as f64;
    let t = (b - c) as f64;
    let bf = b as f64;
    let r = row_norm_product_sum(x, y);
    let fro2 = matmul_at(x, y).fro2();
    // pair-inclusion coefficient P(k,l ∈ losers, k ≠ l); zero when there
    // are no pairs (guards the 0/0 at B = 1 or t = 1)
    let pair = if b > 1 && t > 1.0 {
        (t * (t - 1.0)) / (bf * (bf - 1.0))
    } else {
        0.0
    };
    let e_r_l = (t / bf) * r; // E[Σ_{k∈L}‖x_k‖²‖y_k‖²]
    let e_f_l = e_r_l + pair * (fro2 - r); // E[‖Σ_{k∈L} x_k y_kᵀ‖²_F]
    ((t * e_r_l) - e_f_l) / m
}

/// Per-family closed-form variance at a given B_proj — the price the
/// closed-loop controller (`rmm::controller`) evaluates online.  Gauss
/// uses the exact fourth-moment form, the sampling families use their
/// exact CRS forms, and the SRHT-like transforms fall back to the paper's
/// generic Lemma-2.2 expression (Monte-Carlo-pinned to a factor-2 band in
/// `prop_theory`).
pub fn d2_family(
    kind: super::sketch::SketchKind,
    x: &Tensor,
    y: &Tensor,
    b_proj: usize,
) -> f64 {
    use super::sketch::SketchKind;
    match kind {
        SketchKind::Gauss => d2_rmm_exact(x, y, b_proj),
        SketchKind::RowSample => d2_rowsample(x, y, b_proj),
        SketchKind::WtaCrs => d2_wtacrs(x, y, b_proj),
        SketchKind::Rademacher | SketchKind::Dct | SketchKind::Dft => {
            d2_rmm(x, y, b_proj)
        }
    }
}

/// Grad-weight-path variance of the approximate-VJP estimator
/// (arXiv 2602.14701): the sketch touches only ∂W, so the ∂W variance is
/// the underlying family's closed form unchanged — while the grad-input
/// path is exact (zero variance), which is the configuration's whole
/// advantage and what the equal-budget table expresses.
pub fn d2_approx_vjp(
    kind: super::sketch::SketchKind,
    x: &Tensor,
    y: &Tensor,
    b_proj: usize,
) -> f64 {
    d2_family(kind, x, y, b_proj)
}

/// Monte-Carlo estimate of D²(X,Y) = E‖XᵀSSᵀY − XᵀY‖²_F for a sketch kind —
/// the empirical check of Lemma 2.2 (exact only for Gauss).
pub fn d2_montecarlo(
    kind: super::sketch::SketchKind,
    x: &Tensor,
    y: &Tensor,
    b_proj: usize,
    trials: usize,
    seed0: u32,
) -> f64 {
    let exact = matmul_at(x, y);
    let mut acc = 0.0f64;
    for t in 0..trials {
        let s = super::sketch::sketch(kind, x.rows, b_proj, (seed0 + 101 * t as u32, 7));
        let xs = matmul_at(&s, x); // (b_proj, N)
        let ys = matmul_at(&s, y); // (b_proj, M)
        let est = matmul_at(&xs, &ys); // XᵀSSᵀY
        acc += est.sub(&exact).fro2();
    }
    acc / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rmm::sketch::SketchKind;
    use crate::rng::philox::PhiloxStream;

    fn randt(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut s = PhiloxStream::new(seed, 3);
        Tensor::from_fn(rows, cols, |_, _| s.next_normal())
    }

    #[test]
    fn lemma21_zero_for_rank_one_identical_rows() {
        let x = Tensor::from_vec(4, 2, vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0]);
        let y = Tensor::from_vec(4, 3, vec![1.0; 12]);
        assert!(d2_sgd(&x, &y).abs() < 1e-6);
    }

    #[test]
    fn lemma22_scaling() {
        let x = randt(12, 5, 1);
        let y = randt(12, 7, 2);
        let v5 = d2_rmm(&x, &y, 5);
        let v10 = d2_rmm(&x, &y, 10);
        assert!((v10 - v5 / 2.0).abs() < 1e-9 * v5.abs().max(1.0));
    }

    #[test]
    fn exact_lemma22_matches_montecarlo_gauss() {
        let x = randt(10, 4, 3);
        let y = randt(10, 3, 4);
        let formula = d2_rmm_exact(&x, &y, 4);
        let mc = d2_montecarlo(SketchKind::Gauss, &x, &y, 4, 4000, 13);
        let rel = (mc - formula).abs() / formula;
        assert!(rel < 0.15, "mc={mc} formula={formula} rel={rel}");
    }

    #[test]
    fn paper_lemma22_underestimates_by_two_cross_terms() {
        // The paper's eq. (11) equals the exact variance minus
        // 2‖XᵀY‖²/B_proj — document the discrepancy precisely.
        let x = randt(12, 5, 7);
        let y = randt(12, 6, 8);
        let q = matmul_at(&x, &y).fro2();
        for bp in [2usize, 5, 11] {
            let gap = d2_rmm_exact(&x, &y, bp) - d2_rmm(&x, &y, bp);
            assert!((gap - 2.0 * q / bp as f64).abs() < 1e-6 * gap.abs().max(1.0));
        }
    }

    #[test]
    fn paper_and_exact_agree_when_alpha_small() {
        // Decorrelated X and Y (α → 0): the paper's formula is accurate.
        let x = randt(64, 8, 9);
        let y = randt(64, 8, 10);
        let a = alpha(&x, &y);
        assert!(a < 0.05, "alpha {a}");
        let rel = (d2_rmm_exact(&x, &y, 8) - d2_rmm(&x, &y, 8)) / d2_rmm_exact(&x, &y, 8);
        assert!(rel < 0.1, "rel {rel}");
    }

    #[test]
    fn theorem23_bound_random_matrices() {
        for seed in 0..50u64 {
            let x = randt(8, 5, seed * 2 + 1);
            let y = randt(8, 6, seed * 2 + 2);
            let lhs = ratio_lhs(&x, &y, 4);
            let rhs = bound_rhs(&x, &y);
            assert!(lhs <= rhs * 1.001, "seed={seed} lhs={lhs} rhs={rhs}");
        }
    }

    #[test]
    fn adversarial_example_eqs_14_16() {
        // Paper's ε example: XᵀY = 0, ratio unbounded as ε → 0.
        for &eps in &[0.5f32, 0.1, 0.01] {
            let x = Tensor::from_vec(2, 2, vec![1.0, 0.0, -eps, 0.0]);
            let y = Tensor::from_vec(2, 2, vec![1.0, 0.0, 1.0 / eps, 0.0]);
            // eq. (15): (B−1)·D²_SGD = 4
            assert!((d2_sgd(&x, &y) * 1.0 - 4.0).abs() < 1e-2, "eps={eps}");
            // eq. (16): B_proj·D²_RMM = 2 + ε² + ε⁻²
            let want = 2.0 + (eps * eps) as f64 + (1.0 / (eps * eps)) as f64;
            let got = d2_rmm(&x, &y, 1);
            assert!((got - want).abs() / want < 1e-3, "eps={eps} got={got}");
            assert_eq!(alpha(&x, &y), 0.0);
        }
    }

    #[test]
    fn alpha_bounds() {
        for seed in 0..20u64 {
            let x = randt(6, 4, seed + 100);
            let y = randt(6, 4, seed + 200);
            let a = alpha(&x, &y);
            assert!((0.0..=1.0 + 1e-9).contains(&a));
        }
        // α = 1 when Y = X and X has orthogonal... α=1 requires rank-1: x single row? B>1: use Y=X rank one
        let x = Tensor::from_vec(2, 1, vec![1.0, 1.0]);
        let a = alpha(&x, &x);
        assert!((a - 1.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn lemma21_requires_b_gt_1() {
        let x = Tensor::zeros(1, 3);
        let y = Tensor::zeros(1, 3);
        d2_sgd(&x, &y);
    }

    #[test]
    fn rowsample_closed_form_matches_montecarlo() {
        let x = randt(16, 4, 31);
        let y = randt(16, 3, 32);
        for bp in [4usize, 8] {
            let formula = d2_rowsample(&x, &y, bp);
            let mc = d2_montecarlo(SketchKind::RowSample, &x, &y, bp, 3000, 17);
            let rel = (mc - formula).abs() / formula;
            assert!(rel < 0.15, "bp={bp} mc={mc} formula={formula} rel={rel}");
        }
    }

    #[test]
    fn wtacrs_closed_form_matches_montecarlo() {
        let x = randt(16, 4, 41);
        let y = randt(16, 3, 42);
        for bp in [4usize, 8, 12] {
            let formula = d2_wtacrs(&x, &y, bp);
            let mc = d2_montecarlo(SketchKind::WtaCrs, &x, &y, bp, 3000, 19);
            let rel = (mc - formula).abs() / formula;
            assert!(rel < 0.15, "bp={bp} mc={mc} formula={formula} rel={rel}");
        }
    }

    #[test]
    fn wtacrs_reduces_to_uniform_crs_and_vanishes_at_full_coverage() {
        let x = randt(12, 5, 51);
        let y = randt(12, 6, 52);
        // b_proj = 1 ⇒ c = 0: identical to the uniform CRS form
        let a = d2_wtacrs(&x, &y, 1);
        let b = d2_rowsample(&x, &y, 1);
        assert!((a - b).abs() < 1e-9 * b.abs().max(1.0), "{a} vs {b}");
        // b_proj ≥ 2B ⇒ winners cover every row: zero variance, and the
        // Monte-Carlo agrees up to f32 summation-order noise
        assert_eq!(d2_wtacrs(&x, &y, 24), 0.0);
        let mc = d2_montecarlo(SketchKind::WtaCrs, &x, &y, 24, 10, 23);
        assert!(mc < 1e-4, "mc={mc}");
    }

    #[test]
    fn wtacrs_beats_uniform_crs_once_winners_shrink_the_pool() {
        // Closed-form comparison: D²_wta/D²_uni = B_proj·t(t−1) / (m·B(B−1))
        // (both are multiples of B·R − F).  The data-independent winner
        // budget pays off once B_proj is a large fraction of B — at small
        // B_proj the uniform estimator wins, which is exactly the kind of
        // shape-dependent tradeoff the controller prices per layer.
        let x = randt(16, 4, 300);
        let y = randt(16, 5, 400);
        for bp in [12usize, 16, 24] {
            assert!(d2_wtacrs(&x, &y, bp) < d2_rowsample(&x, &y, bp), "bp={bp}");
        }
        assert!(d2_wtacrs(&x, &y, 2) >= d2_rowsample(&x, &y, 2));
    }

    #[test]
    fn family_dispatch_and_avjp_alias() {
        let x = randt(10, 3, 61);
        let y = randt(10, 4, 62);
        assert_eq!(d2_family(SketchKind::Gauss, &x, &y, 5), d2_rmm_exact(&x, &y, 5));
        assert_eq!(
            d2_family(SketchKind::RowSample, &x, &y, 5),
            d2_rowsample(&x, &y, 5)
        );
        assert_eq!(d2_family(SketchKind::WtaCrs, &x, &y, 5), d2_wtacrs(&x, &y, 5));
        assert_eq!(d2_family(SketchKind::Dct, &x, &y, 5), d2_rmm(&x, &y, 5));
        for kind in SketchKind::ALL {
            assert_eq!(
                d2_approx_vjp(kind, &x, &y, 5),
                d2_family(kind, &x, &y, 5)
            );
        }
    }
}
