//! Pure-Rust RMM reference: sketches, randomized matmul, variance theory,
//! fast transforms.  This is the *CPU-side* mirror of the Pallas/JAX stack —
//! used for property tests, cross-language golden checks, host baselines in
//! the benches, and the Adelman-style comparison.

pub mod fft;
pub mod sketch;
pub mod variance;

pub use sketch::SketchKind;

use crate::tensor::{matmul_at, Tensor};

/// Exact ∂W = Yᵀ X (paper eq. 3; baseline path).
pub fn exact_grad_w(y: &Tensor, x: &Tensor) -> Tensor {
    matmul_at(y, x)
}

/// Algorithm 1 forward side: X_proj = Sᵀ X.
pub fn project(kind: SketchKind, x: &Tensor, b_proj: usize, seed: (u32, u32)) -> Tensor {
    sketch::project_streamed(kind, x, b_proj, seed)
}

/// Algorithm 1 backward side: ∂W ≈ (Sᵀ Y)ᵀ X_proj (paper eq. 4).
pub fn rmm_grad_w(
    kind: SketchKind,
    y: &Tensor,
    x_proj: &Tensor,
    seed: (u32, u32),
) -> Tensor {
    let y_proj = sketch::project_streamed(kind, y, x_proj.rows, seed);
    matmul_at(&y_proj, x_proj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::philox::PhiloxStream;

    fn randt(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut s = PhiloxStream::new(seed, 3);
        Tensor::from_fn(rows, cols, |_, _| s.next_normal())
    }

    #[test]
    fn rmm_grad_is_unbiased() {
        let x = randt(16, 4, 1);
        let y = randt(16, 6, 2);
        let exact = exact_grad_w(&y, &x);
        for kind in SketchKind::ALL {
            let trials = 800;
            let mut acc = Tensor::zeros(6, 4);
            for t in 0..trials {
                let seed = (t as u32 * 31 + 1, 9);
                let xp = project(kind, &x, 8, seed);
                let g = rmm_grad_w(kind, &y, &xp, seed);
                acc.add_assign(&g);
            }
            acc.scale(1.0 / trials as f32);
            let scale = exact.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            assert!(
                acc.max_abs_diff(&exact) < 0.25 * scale.max(1.0),
                "{kind:?}: {}",
                acc.max_abs_diff(&exact)
            );
        }
    }

    #[test]
    fn rmm_grad_matches_explicit_sketch_algebra() {
        let x = randt(12, 3, 3);
        let y = randt(12, 5, 4);
        let seed = (21, 22);
        for kind in SketchKind::ALL {
            let s = sketch::sketch(kind, 12, 6, seed);
            let want = matmul_at(
                &crate::tensor::matmul_at(&s, &y),
                &crate::tensor::matmul_at(&s, &x),
            ); // (Sᵀy)ᵀ(Sᵀx)
            let got = rmm_grad_w(kind, &y, &project(kind, &x, 6, seed), seed);
            assert!(got.max_abs_diff(&want) < 1e-3, "{kind:?}");
        }
    }

    #[test]
    fn full_width_gauss_sketch_approximates_exact() {
        // With b_proj = many ≫ B the estimate concentrates near exact.
        let x = randt(8, 3, 5);
        let y = randt(8, 4, 6);
        let exact = exact_grad_w(&y, &x);
        let xp = project(SketchKind::Gauss, &x, 4096, (7, 8));
        let g = rmm_grad_w(SketchKind::Gauss, &y, &xp, (7, 8));
        let scale = exact.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!(g.max_abs_diff(&exact) < 0.15 * scale, "{}", g.max_abs_diff(&exact));
    }
}
